"""repro.ps — the real parameter-server runtime.

The contract under test, in three layers:

 1. ``core.easgd_flat`` (the optimizer math shared by the DES simulator and
    the real runtime) is equivalent to the ``core.easgd`` pytree oracle.
 2. The ``repro.comm`` round structures are executable (a numpy executor
    allreduces correctly for every registered schedule) and price exactly
    like the closed-form cost functions the DES charges.
 3. DES↔real cross-check (the ISSUE's acceptance): with a fixed seed and
    deterministic admission, the repro.ps runtime reproduces the
    ``core.async_engine`` iterate sequence BITWISE (same event order ⇒ same
    weights), and measured sync round counts equal the registry's round
    structure.
"""
import dataclasses

import numpy as np
import pytest

from repro import comm, ps
from repro.core import costmodel, easgd_flat
from repro.core import easgd as easgd_lib
from repro.core.async_engine import ALGORITHMS, PSEngine, SimConfig
from repro.core.easgd import EASGDConfig

NET = costmodel.Network("test-net", 2e-6, 1 / 10e9)
CFG = EASGDConfig(eta=0.05, rho=0.07, mu=0.9)


# ---------------------------------------------------------------------------
# (1) easgd_flat == core.easgd oracle
# ---------------------------------------------------------------------------

def _rand(n=64, seed=0):
    rng = np.random.RandomState(seed)
    return [rng.randn(n) for _ in range(4)]


def test_flat_worker_rules_match_pytree_oracle():
    w, g, c, v = _rand()
    # eq 1 (EASGD worker rule)
    for algo in easgd_flat.EASGD_WORKER_RULE:
        w1 = w.copy()
        easgd_flat.worker_step(algo, w1, v.copy(), g, c, CFG)
        want = easgd_lib.easgd_worker_update(w, g, c, CFG)
        np.testing.assert_allclose(w1, np.asarray(want), rtol=1e-12)
    # eqs 5–6 (MEASGD)
    w1, v1 = w.copy(), v.copy()
    easgd_flat.worker_step("async_measgd", w1, v1, g, c, CFG)
    want_w, want_v = easgd_lib.measgd_worker_update(w, v, g, c, CFG)
    np.testing.assert_allclose(w1, np.asarray(want_w), rtol=1e-12)
    np.testing.assert_allclose(v1, np.asarray(want_v), rtol=1e-12)
    # eqs 3–4 (MSGD)
    w1, v1 = w.copy(), v.copy()
    easgd_flat.worker_step("async_msgd", w1, v1, g, c, CFG)
    want_w, want_v = easgd_lib.msgd_update(w, v, g, CFG)
    np.testing.assert_allclose(w1, np.asarray(want_w), rtol=1e-12)
    # plain SGD
    w1 = w.copy()
    easgd_flat.worker_step("async_sgd", w1, v.copy(), g, c, CFG)
    np.testing.assert_allclose(w1, np.asarray(easgd_lib.sgd_update(w, g, CFG)),
                               rtol=1e-12)


def test_flat_master_rules_match_pytree_oracle():
    w, g, c, v = _rand(seed=1)
    # async elastic absorb = worker rule + single-worker center pull
    c1, w1 = c.copy(), w.copy()
    easgd_flat.master_absorb("async_easgd", c1, v.copy(), w1, v.copy(), g,
                             CFG)
    w_want = np.asarray(easgd_lib.easgd_worker_update(w, g, c, CFG))
    c_want = np.asarray(easgd_lib.center_update_single(c, w_want, CFG))
    np.testing.assert_allclose(c1, c_want, rtol=1e-12)
    # sync center update (eq 2, mean form)
    c1 = c.copy()
    easgd_flat.sync_master_easgd(c1, w, 4, CFG)
    np.testing.assert_allclose(
        c1, np.asarray(easgd_lib.center_update_from_mean(c, w, 4, CFG)),
        rtol=1e-12)
    # sync momentum SGD on the mean gradient == msgd_update
    c1, v1 = c.copy(), v.copy()
    easgd_flat.sync_master_sgd(c1, v1, g, CFG)
    want_c, want_v = easgd_lib.msgd_update(c, v, g, CFG)
    np.testing.assert_allclose(c1, np.asarray(want_c), rtol=1e-12)
    np.testing.assert_allclose(v1, np.asarray(want_v), rtol=1e-12)


# ---------------------------------------------------------------------------
# (2) round structures: executable + priced like the closed forms
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", list(comm.names()))
def test_rounds_cost_equals_closed_form(name):
    sched = comm.get(name)
    for p in (2, 4, 8, 16):
        for n in (1e3, 4e6):
            want = sched.cost(n, p, NET)
            got = sched.cost_from_rounds(n, p, NET)
            np.testing.assert_allclose(got, want, rtol=1e-12,
                                       err_msg=f"{name} p={p}")
    assert sched.rounds(1) == []


def test_round_robin_rounds_any_p():
    # round_robin is the only schedule without a pow2 constraint on rounds
    for p in (3, 5, 6):
        sched = comm.get("round_robin")
        np.testing.assert_allclose(sched.cost_from_rounds(1e4, p, NET),
                                   sched.cost(1e4, p, NET), rtol=1e-12)


@pytest.mark.parametrize("name", list(comm.names()))
def test_message_spans_pin_segment_bytes(name):
    """Every message's explicit segment offsets (``Message.span`` — the
    SEGMENT frame's address on the p2p wire) must agree with its ``frac``,
    and summing them must reproduce ``Schedule.bytes_from_rounds`` — the
    byte side of the same structure ``cost_from_rounds`` prices in time."""
    sched = comm.get(name)
    for p in (2, 4, 8):
        n = 24 * p                       # divisible by every chunk count
        span_bytes = 0.0
        for rnd in sched.rounds(p, n * 8, NET):
            for m in rnd:
                a, b = m.span(n)
                assert 0 <= a < b <= n, (name, p, m)
                np.testing.assert_allclose((b - a) / n, m.frac, rtol=1e-12)
                span_bytes += (b - a) * 8
        np.testing.assert_allclose(
            span_bytes, sched.bytes_from_rounds(n * 8, p, NET), rtol=1e-12,
            err_msg=f"{name} p={p}")


def test_rounds_wire_serialization_roundtrip():
    """The master ships rounds to jax-free p2p workers as JSON; the
    roundtrip must be lossless for every schedule."""
    import json

    from repro.comm.rounds import rounds_from_wire, rounds_to_wire
    for name in comm.names():
        for p in (2, 4):
            rounds = comm.get(name).rounds(p, 1e4, NET)
            wire_form = json.loads(json.dumps(rounds_to_wire(rounds)))
            assert rounds_from_wire(wire_form) == rounds, (name, p)


@pytest.mark.parametrize("name", list(comm.names()))
def test_rounds_execute_allreduce(name):
    """ps.execute_rounds applied to the registry's rounds must leave every
    worker holding the global sum — for every schedule."""
    rng = np.random.RandomState(0)
    for p in (2, 4, 8):
        n = 24
        vals = rng.randn(p, n)
        want = vals.sum(0)
        mailbox = np.zeros((p + 1, n))
        mailbox[:p] = vals
        ps.execute_rounds(mailbox, n, comm.get(name).rounds(p, n * 8, NET))
        for i in range(p):
            np.testing.assert_allclose(mailbox[i], want, rtol=1e-12,
                                       err_msg=f"{name} p={p} rank{i}")


def test_hierarchical_cost_is_two_level():
    """hierarchical = ring over the inner group + butterfly across groups."""
    from repro.comm.schedules import _inner_size
    for p in (4, 8, 16):
        m = _inner_size(p)
        want = (costmodel.t_ring_allreduce(1e6, m, NET)
                + costmodel.t_butterfly_allreduce(1e6, p // m, NET))
        np.testing.assert_allclose(comm.get("hierarchical").cost(1e6, p, NET),
                                   want, rtol=1e-12)


# ---------------------------------------------------------------------------
# (3) DES↔real cross-check
# ---------------------------------------------------------------------------

def _des_run(algo, P, iters):
    w0, grad_fn, eval_fn = ps.make_numpy_mlp()
    eng = PSEngine(grad_fn, eval_fn, w0, CFG,
                   SimConfig(n_workers=P, compute_jitter=0.0, seed=0,
                             schedule="round_robin"))
    return eng.run(algo, total_iters=iters)


def _real_run(algo, P, iters, **kw):
    cfg = ps.PSConfig(algorithm=algo, n_workers=P, total_iters=iters,
                      transport="thread", schedule="round_robin",
                      deterministic=True, eval_every_iters=10**9, **kw)
    return ps.run_ps(ps.NUMPY_MLP, CFG, cfg)


@pytest.mark.parametrize("algo,P", [
    ("async_easgd", 2), ("async_easgd", 4),
    ("sync_easgd", 2), ("sync_easgd", 3), ("sync_easgd", 4),
    ("original_easgd", 3), ("sync_sgd", 4), ("async_measgd", 2),
])
def test_des_real_iterates_bitwise(algo, P):
    """The ISSUE's cross-check: identical event order ⇒ identical weights.
    DES with zero jitter pops workers cyclically; the real runtime under
    deterministic admission serves the same order; the round_robin sync
    schedule accumulates in rank order exactly like np.mean. The SAME
    in-place math (core.easgd_flat) then gives bit-identical float64
    iterates — zero tolerance."""
    iters = 72
    des = _des_run(algo, P, iters)
    real = _real_run(algo, P, iters)
    assert des.total_iters == real.total_iters
    np.testing.assert_array_equal(des.center, real.center)
    np.testing.assert_array_equal(des.workers, real.workers)


def test_des_real_close_under_tree_schedule():
    """Non-rank-order schedules change only the SUMMATION ORDER of the
    cross-worker mean — iterates agree to float64 reduction noise."""
    iters, P = 60, 4
    w0, grad_fn, eval_fn = ps.make_numpy_mlp()
    eng = PSEngine(grad_fn, eval_fn, w0, CFG,
                   SimConfig(n_workers=P, compute_jitter=0.0, seed=0,
                             schedule="tree"))
    des = eng.run("sync_easgd", total_iters=iters)
    cfg = ps.PSConfig(algorithm="sync_easgd", n_workers=P, total_iters=iters,
                      transport="thread", schedule="tree",
                      deterministic=True, eval_every_iters=10**9)
    real = ps.run_ps(ps.NUMPY_MLP, CFG, cfg)
    np.testing.assert_allclose(real.center, des.center, rtol=1e-9, atol=1e-9)


def test_emulated_wire_changes_clock_not_math():
    """Wire emulation must only add (deadline-paced) time: the iterates
    stay bitwise identical to the un-emulated run."""
    slow_wire = costmodel.Network("tiny-emu", 1e-4, 1e-9)
    a = _real_run("async_easgd", 2, 40)
    b = _real_run("async_easgd", 2, 40, emulate_net=slow_wire)
    np.testing.assert_array_equal(a.center, b.center)
    assert b.total_time_s > 40 * 2 * 1e-4  # the wire time was actually paid


@pytest.mark.parametrize("schedule", ["tree", "ring", "round_robin",
                                      "hierarchical"])
def test_sync_round_counts_match_registry(schedule):
    """Measured rounds == training rounds × the registry's round count."""
    P, iters = 4, 48
    cfg = ps.PSConfig(algorithm="sync_easgd", n_workers=P, total_iters=iters,
                      transport="thread", schedule=schedule,
                      eval_every_iters=10**9)
    res = ps.run_ps(ps.NUMPY_MLP, CFG, cfg)
    n_rounds = -(-iters // P)
    want = n_rounds * len(comm.get(schedule).rounds(P))
    assert res.counters["sync_rounds"] == want
    assert res.counters["messages"] == n_rounds * sum(
        len(r) for r in comm.get(schedule).rounds(P))


@pytest.mark.parametrize("algo", list(ALGORITHMS))
def test_every_algorithm_runs_thread(algo):
    cfg = ps.PSConfig(algorithm=algo, n_workers=2, total_iters=40,
                      transport="thread", schedule="ring",
                      eval_every_iters=20)
    res = ps.run_ps(ps.NUMPY_MLP, CFG, cfg)
    assert res.total_iters == 40
    assert np.isfinite(res.final_metric)
    assert np.all(np.isfinite(res.center))
    assert res.history   # monitor recorded accuracy-vs-time points


def test_process_transport_runs_and_counts():
    """Both acceptance transports: a real multiprocessing run (spawn,
    shared RawArrays) completes, counts its exchanges, and learns."""
    cfg = ps.PSConfig(algorithm="async_easgd", n_workers=2, total_iters=60,
                      transport="process", schedule="ring",
                      eval_every_iters=30)
    res = ps.run_ps(ps.NUMPY_MLP, CFG, cfg)
    assert res.total_iters == 60
    assert res.counters["messages"] == 120
    assert np.isfinite(res.final_metric)


def test_process_transport_rejects_closures():
    built = ps.make_numpy_mlp()
    cfg = ps.PSConfig(algorithm="async_easgd", n_workers=2, total_iters=10,
                      transport="process")
    with pytest.raises(ValueError, match="ProblemSpec"):
        ps.run_ps(built, CFG, cfg)


def test_ps_config_validates_algorithm():
    with pytest.raises(AssertionError):
        ps.PSConfig(algorithm="nope")


def test_calibration_sim_config_discipline():
    """original_easgd is priced at serialized (full-core) compute; the
    concurrent families at the measured concurrent rate."""
    cal = ps.Calibration(n=1000, n_workers=4, transport="thread",
                         t_grad_serial=1e-3, t_grad_concurrent=3e-3,
                         t_axpy=1e-5, alpha=2e-5)
    assert cal.sim_config("original_easgd", "ring").t_compute == 1e-3
    assert cal.sim_config("async_easgd", "ring").t_compute == 3e-3
    assert cal.sim_config("sync_easgd", "ring",
                          net=NET).net is NET


def test_pow2_only_schedules_fail_fast():
    """Finding from review: a pow2-only round structure at non-pow2 P must
    refuse loudly, not corrupt the allreduce or crash the comm executor."""
    with pytest.raises(ValueError, match="power-of-two"):
        comm.get("tree").rounds(3)
    with pytest.raises(ValueError, match="power-of-two"):
        ps.run_ps(ps.NUMPY_MLP, CFG,
                  ps.PSConfig(algorithm="sync_easgd", n_workers=3,
                              total_iters=12, schedule="butterfly"))


def test_choose_never_proposes_butterfly_for_non_pow2():
    from repro.core.elastic import ElasticConfig
    assert comm.choose(100, 6, NET) == "ring"          # latency-bound, p=6
    assert ElasticConfig(schedule="auto").resolve_schedule(6, 100) == "ring"
    # pow2 latency-bound still picks butterfly
    assert comm.choose(100, 8, NET) == "butterfly"


# ---------------------------------------------------------------------------
# (4) τ>1 communication periods in the real runtime
# ---------------------------------------------------------------------------

def test_local_step_matches_oracles():
    """The between-exchange rule: velocity algorithms follow eqs 3–4,
    everything else plain SGD — pinned against the pytree oracle."""
    w, g, _, v = _rand(seed=5)
    w1, v1 = w.copy(), v.copy()
    easgd_flat.local_step("async_measgd", w1, v1, g, CFG)
    want_w, want_v = easgd_lib.msgd_update(w, v, g, CFG)
    np.testing.assert_allclose(w1, np.asarray(want_w), rtol=1e-12)
    np.testing.assert_allclose(v1, np.asarray(want_v), rtol=1e-12)
    w1 = w.copy()
    easgd_flat.local_step("async_easgd", w1, v.copy(), g, CFG)
    np.testing.assert_allclose(
        w1, np.asarray(easgd_lib.sgd_update(w, g, CFG)), rtol=1e-12)


def _tau_run(algo, tau, iters=48, P=2, **kw):
    e = EASGDConfig(eta=0.1, rho=0.1, mu=0.9, tau=tau)
    cfg = ps.PSConfig(algorithm=algo, n_workers=P, total_iters=iters,
                      transport="thread", schedule="ring",
                      eval_every_iters=10**9, **kw)
    return ps.run_ps(ps.NUMPY_MLP, e, cfg)


@pytest.mark.parametrize("algo", ["async_easgd", "async_measgd",
                                  "sync_easgd", "hogwild_easgd",
                                  "original_easgd"])
def test_tau_cuts_wire_traffic_by_tau(algo):
    """τ=4 must move EXACTLY 1/4 of τ=1's exchange traffic for the same
    number of gradient steps — Table 3's bandwidth lever, counted."""
    r1, r4 = _tau_run(algo, 1), _tau_run(algo, 4)
    assert r1.total_iters == r4.total_iters == 48
    assert r1.counters["wire_bytes"] == 4 * r4.counters["wire_bytes"]
    assert r1.counters["messages"] == 4 * r4.counters["messages"]
    assert np.isfinite(r4.final_metric)


def test_tau_sweep_comm_fraction_drops():
    """Table 3's spirit on the measured clock: under an emulated wire the
    communication FRACTION of total time falls as τ grows. Exchange traffic
    is asserted exactly monotone across the sweep; the measured-fraction
    comparison sticks to the 4x-apart endpoints (this box's compute noise
    is tens of ms — see memory — so adjacent τ points can't be ordered by
    wall clock reliably, but a 4x wire difference can)."""
    slow = costmodel.Network("tau-emu", 8e-3, 1e-9)
    fracs, bytes_ = {}, {}
    for tau in (1, 2, 4):
        res = _tau_run("async_easgd", tau, emulate_net=slow)
        exchanges = res.counters["messages"] // 2
        t_wire = exchanges * 2 * 8.01e-3        # FCFS serializes the wire
        fracs[tau] = t_wire / res.total_time_s
        bytes_[tau] = res.counters["wire_bytes"]
    assert bytes_[1] > bytes_[2] > bytes_[4], bytes_
    assert fracs[1] > fracs[4], fracs


def test_tau_sync_round_counts_match_registry():
    """sync family with τ: exchanges happen every P·τ iterations, and each
    executes the registry's full round structure."""
    P, iters, tau = 2, 48, 3
    e = EASGDConfig(eta=0.1, rho=0.1, mu=0.9, tau=tau)
    for sched in ("ring", "tree"):
        cfg = ps.PSConfig(algorithm="sync_easgd", n_workers=P,
                          total_iters=iters, transport="thread",
                          schedule=sched, eval_every_iters=10**9)
        res = ps.run_ps(ps.NUMPY_MLP, e, cfg)
        n_rounds = -(-iters // (P * tau))
        assert res.counters["sync_rounds"] == \
            n_rounds * len(comm.get(sched).rounds(P))
        assert res.total_iters == n_rounds * P * tau


def test_tau_one_unchanged_bitwise():
    """τ=1 must reproduce the pre-τ runtime exactly (the DES cross-check
    depends on it): explicit τ=1 equals the default config bitwise."""
    a = _real_run("async_easgd", 2, 48)
    e = EASGDConfig(eta=CFG.eta, rho=CFG.rho, mu=CFG.mu, tau=1)
    cfg = ps.PSConfig(algorithm="async_easgd", n_workers=2, total_iters=48,
                      transport="thread", schedule="round_robin",
                      deterministic=True, eval_every_iters=10**9)
    b = ps.run_ps(ps.NUMPY_MLP, e, cfg)
    np.testing.assert_array_equal(a.center, b.center)


# ---------------------------------------------------------------------------
# (5) jax-backed problems in spawned workers
# ---------------------------------------------------------------------------

def test_jax_problem_builds_thread_closures():
    """The same spec serves the thread transport in-process (the jax jit
    closures are built once and shared by the worker threads)."""
    cfg = ps.PSConfig(algorithm="async_easgd", n_workers=2, total_iters=30,
                      transport="thread", eval_every_iters=10**9)
    res = ps.run_ps(ps.JAX_MLP, CFG, cfg)
    assert res.total_iters == 30
    assert np.isfinite(res.final_metric)


def test_jax_problem_in_spawned_process_workers():
    """Spawn-safety gate: the factory pins children to CPU before their
    first jax import, so multiprocessing workers rebuild and jit the
    problem inside a fresh interpreter."""
    pytest.importorskip("jax")
    cfg = ps.PSConfig(algorithm="async_easgd", n_workers=2, total_iters=30,
                      transport="process", eval_every_iters=10**9)
    res = ps.run_ps(ps.JAX_MLP, CFG, cfg, join_timeout_s=300.0)
    assert res.total_iters == 30
    assert res.counters["messages"] == 60
    assert np.isfinite(res.final_metric)
