"""The bucketed overlap data plane (ISSUE 6), bottom-up.

 1. Boundary policy: cuts land on layer edges, respect the target size,
    and the jax-free align constant cannot drift from the packer's.
 2. Bitwise law: the bucketed exchange is a VIEW of the monolithic
    schedule — same final weights, bit for bit, on the thread transport
    (sync_easgd/sync_sgd × ring/tree × P∈{2,3,4}) and through the real
    TCP p2p wire (overlap on and off).
 3. Accounting: per-bucket mesh byte counters partition the registry's
    ``bytes_from_rounds`` total exactly; schedule-level counters are
    identical with and without bucketing.
 4. The fused Pallas per-bucket update matches easgd_flat at ZERO
    tolerance (subprocess with the pinned no-FMA XLA flags — the same
    environment spawned p2p workers get).
"""
import dataclasses
import os
import socket
import subprocess
import sys
import threading

import numpy as np
import pytest

from repro import comm, ps
from repro.comm import rounds as comm_rounds
from repro.core.easgd import EASGDConfig

CFG = EASGDConfig(eta=0.05, rho=0.07, mu=0.9)
SRC = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src")


# ---------------------------------------------------------------------------
# (1) boundary policy
# ---------------------------------------------------------------------------

def test_elastic_align_constant_pins_packer_block():
    """rounds.ELASTIC_UPDATE_ALIGN is the jax-free copy of the packer's
    kernel tile — the two constants must never drift."""
    from repro.core.packing import ELASTIC_UPDATE_BLOCK
    assert comm_rounds.ELASTIC_UPDATE_ALIGN == ELASTIC_UPDATE_BLOCK


def test_bucket_boundaries_cut_at_layer_edges():
    sizes = [1024, 32, 128, 4]
    b = comm_rounds.bucket_boundaries(sizes, 1188, 32)
    assert b == [0, 1024, 1056, 1184, 1188]
    # target bigger than any layer group -> single bucket
    assert comm_rounds.bucket_boundaries(sizes, 1188, 10**6) == [0, 1188]
    # no layer structure -> uniform slabs
    assert comm_rounds.bucket_boundaries(None, 10, 4) == [0, 4, 8, 10]
    # align rounds cuts UP and drops colliding ones
    b = comm_rounds.bucket_boundaries([100, 100, 100], 300, 100, align=128)
    assert b[0] == 0 and b[-1] == 300
    assert all(c % 128 == 0 for c in b[1:-1])


def test_default_boundaries_align_only_at_block_scale():
    align = comm_rounds.ELASTIC_UPDATE_ALIGN
    # small buckets (tests, tiny problems): cut exactly at layer edges
    assert comm_rounds.default_bucket_boundaries(
        [100, 100, 100], 300, 800) == [0, 100, 200, 300]
    # block-scale buckets: interior cuts are kernel-tile aligned
    sizes = [align + 7, align - 3, 2 * align]
    n = sum(sizes) + 5
    b = comm_rounds.default_bucket_boundaries(sizes, n, align * 8)
    assert all(c % align == 0 for c in b[1:-1])


def test_bucket_rounds_partition_every_span():
    """Clipped spans across buckets reassemble each message's monolithic
    span exactly — nothing lost, nothing duplicated, order preserved."""
    P, n = 4, 1000
    rounds = comm_rounds.ring_rounds(P)
    bounds = comm_rounds.bucket_boundaries(None, n, 130)
    plans = comm_rounds.bucket_rounds(rounds, n, bounds)
    assert len(plans) == len(bounds) - 1
    for r_idx, rnd in enumerate(rounds):
        for m in rnd:
            a, b = m.span(n)
            got = sorted(
                span for plan in plans
                for mm, span in plan[r_idx] if mm is m)
            assert got[0][0] == a and got[-1][1] == b
            for (_, e0), (s1, _) in zip(got[:-1], got[1:]):
                assert e0 == s1          # contiguous, non-overlapping


# ---------------------------------------------------------------------------
# (2) the bitwise law
# ---------------------------------------------------------------------------

def _thread_run(algo, P, schedule, bucket_bytes, iters=36):
    cfg = ps.PSConfig(algorithm=algo, n_workers=P, total_iters=iters,
                      transport="thread", schedule=schedule,
                      eval_every_iters=10**9, bucket_bytes=bucket_bytes)
    return ps.run_ps(ps.NUMPY_MLP, CFG, cfg)


@pytest.mark.parametrize("algo", ["sync_easgd", "sync_sgd"])
@pytest.mark.parametrize("schedule,P", [
    ("ring", 2), ("ring", 3), ("ring", 4),   # ring takes any P
    ("tree", 2), ("tree", 4),                # tree is power-of-two only
])
def test_bucketed_bitwise_vs_monolithic_thread(algo, schedule, P):
    """Bucketing is a view, not a re-chunking: same final center and
    worker weights, bit for bit, and the schedule-level counters do not
    even notice (one exchange costs the same sync_rounds/messages/
    wire_bytes either way)."""
    mono = _thread_run(algo, P, schedule, bucket_bytes=0)
    bucketed = _thread_run(algo, P, schedule, bucket_bytes=256)
    np.testing.assert_array_equal(mono.center, bucketed.center)
    np.testing.assert_array_equal(mono.workers, bucketed.workers)
    for key in ("sync_rounds", "messages", "wire_bytes"):
        assert mono.counters[key] == bucketed.counters[key], key


@pytest.mark.parametrize("overlap", [True, False])
def test_bucketed_bitwise_through_tcp_p2p_wire(overlap):
    """The real thing: a bucketed, (optionally) overlapped TCP p2p run
    lands on exactly the bits of the monolithic thread run — streaming
    the row as per-layer SEGMENT buckets while compute proceeds moves
    time, never math. The BYE-folded overlap counters must exist and the
    comm clock must be positive."""
    P, iters = 3, 36
    mono = _thread_run("sync_easgd", P, "ring", bucket_bytes=0, iters=iters)
    cfg = ps.PSConfig(algorithm="sync_easgd", n_workers=P,
                      total_iters=iters, transport="tcp", schedule="ring",
                      sync_plane="p2p", eval_every_iters=10**9,
                      bucket_bytes=256, overlap=overlap)
    p2p = ps.run_ps(ps.NUMPY_MLP, CFG, cfg)
    np.testing.assert_array_equal(mono.center, p2p.center)
    np.testing.assert_array_equal(mono.workers, p2p.workers)
    assert p2p.counters["n_buckets"] > 1
    assert p2p.counters["comm_s"] > 0.0
    if not overlap:
        # inline exchange: everything the comm clock saw was exposed
        assert p2p.counters["overlapped_s"] == 0.0


def test_pallas_update_backend_bitwise_through_tcp_p2p():
    """update_backend='pallas' puts the fused elastic-update kernel on
    the real per-bucket path of spawned TCP workers (which get the no-FMA
    XLA pin from worker_env) — and the run still lands on the monolithic
    numpy thread run's exact bits."""
    P, iters = 2, 8
    mono = _thread_run("sync_easgd", P, "ring", bucket_bytes=0, iters=iters)
    cfg = ps.PSConfig(algorithm="sync_easgd", n_workers=P,
                      total_iters=iters, transport="tcp", schedule="ring",
                      sync_plane="p2p", eval_every_iters=10**9,
                      bucket_bytes=2048, update_backend="pallas")
    p2p = ps.run_ps(ps.NUMPY_MLP, CFG, cfg, join_timeout_s=900.0)
    np.testing.assert_array_equal(mono.center, p2p.center)
    np.testing.assert_array_equal(mono.workers, p2p.workers)


# ---------------------------------------------------------------------------
# (3) accounting
# ---------------------------------------------------------------------------

def test_per_bucket_byte_counters_partition_registry_total():
    """Σ_workers bucket_send_bytes[b] == the registry's bytes_from_rounds
    clipped to bucket b — and summing over buckets recovers the monolithic
    total exactly (clipping partitions every span)."""
    from repro.comm.rounds import peer_pairs, ring_rounds
    from repro.net.peer import PeerMesh

    P, n = 3, 999
    rounds = ring_rounds(P)
    bounds = comm_rounds.bucket_boundaries(None, n, 250)
    meshes = [PeerMesh(w, "t", bind_host="127.0.0.1", timeout_s=30)
              for w in range(P)]
    directory = {w: ("127.0.0.1", m.port) for w, m in enumerate(meshes)}
    rows = [np.arange(n) * (w + 1.0) for w in range(P)]
    errs, threads = [], []

    def _run(wid):
        try:
            meshes[wid].connect(directory, peer_pairs(rounds))
            meshes[wid].set_rounds(rounds, n, boundaries=bounds)
            meshes[wid].execute_exchange(rows[wid])
        except BaseException as e:          # noqa: BLE001
            errs.append(e)

    for wid in range(P):
        threads.append(threading.Thread(target=_run, args=(wid,)))
        threads[-1].start()
    for th in threads:
        th.join(timeout=60)
    for m in meshes:
        m.close()
    assert not errs, errs
    want = rows[0] * 0 + sum(np.arange(n) * (w + 1.0) for w in range(P))
    for row in rows:
        np.testing.assert_array_equal(row, want)

    measured = np.zeros(len(bounds) - 1, dtype=np.int64)
    for m in meshes:
        measured += np.asarray(m.bucket_send_bytes, np.int64)
    predicted = []
    for lo, hi in zip(bounds[:-1], bounds[1:]):
        tot = 0
        for rnd in rounds:
            for msg in rnd:
                span = comm_rounds.clip_span(msg, n, lo, hi)
                if span is not None:
                    tot += (span[1] - span[0]) * 8
        predicted.append(tot)
    assert list(measured) == predicted
    assert int(measured.sum()) == int(
        comm_rounds.bytes_from_rounds(rounds, n * 8))


# ---------------------------------------------------------------------------
# (4) the fused kernel at zero tolerance
# ---------------------------------------------------------------------------

_KERNEL_SCRIPT = r"""
import numpy as np
from types import SimpleNamespace
from repro.core import easgd_flat
from repro.kernels.elastic_update import (fused_sync_easgd_update,
                                          fused_sync_sgd_update)
rng = np.random.default_rng(7)
for n in (1188, 4096, 131072, 131072 + 777):
    P, eta, rho, mu = 4, 0.05, 0.07, 0.9
    cfg = SimpleNamespace(eta=eta, rho=rho, mu=mu, alpha=eta * rho)
    w = rng.standard_normal(n); g = rng.standard_normal(n)
    c = rng.standard_normal(n); r = rng.standard_normal(n) * P
    w_ref, c_ref = w.copy(), c.copy()
    easgd_flat.worker_step("sync_easgd", w_ref, None, g, c_ref, cfg)
    easgd_flat.sync_master_easgd(c_ref, r / P, P, cfg)
    w_new, c_new = fused_sync_easgd_update(w, g, c, r, P, eta, rho)
    assert np.array_equal(w_ref, w_new), ("easgd w", n)
    assert np.array_equal(c_ref, c_new), ("easgd c", n)
    v = rng.standard_normal(n)
    c2_ref, v2_ref = c.copy(), v.copy()
    easgd_flat.sync_master_sgd(c2_ref, v2_ref, r / P, cfg)
    c2, v2 = fused_sync_sgd_update(c, v, r, P, eta, mu)
    assert np.array_equal(c2_ref, c2), ("sgd c", n)
    assert np.array_equal(v2_ref, v2), ("sgd v", n)
print("BITWISE-OK")
"""


def test_fused_kernels_match_easgd_flat_zero_tolerance():
    """The kernels are f64 and share easgd_flat's exact operation order;
    under the pinned no-FMA ISA (the same flags worker_env ships to
    pallas-backend workers) XLA cannot contract a·b+c, so the outputs are
    IDENTICAL bits — asserted with array_equal, no tolerance. Runs in a
    subprocess because XLA_FLAGS must be set before jax initializes."""
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    env["JAX_PLATFORMS"] = "cpu"
    env["XLA_FLAGS"] = "--xla_cpu_max_isa=SSE4_2"
    out = subprocess.run([sys.executable, "-c", _KERNEL_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr
    assert "BITWISE-OK" in out.stdout


# ---------------------------------------------------------------------------
# the zoo rides the same rails
# ---------------------------------------------------------------------------

def test_zoo_layer_sizes_drive_boundaries():
    """Every zoo problem advertises its layer structure, and the runtime's
    boundary policy cuts the padded row on it."""
    from repro.ps import zoo
    w0, grad_fn, _ = ps.NUMPY_MLP.build()
    assert sum(grad_fn.layer_sizes) == w0.size
    b = comm_rounds.default_bucket_boundaries(grad_fn.layer_sizes,
                                              w0.size, 2048)
    assert b[0] == 0 and b[-1] == w0.size and len(b) > 2
    assert "gemma3-27b" in zoo.zoo_names()
    spec = zoo.resolve("gemma3-27b")
    assert spec.factory == "repro.ps.zoo:make_zoo_lm"
