"""repro.obs.live + repro.obs.regress + the worker preemption plane.

 1. Store primitives: ring wraparound, TimeSeries tail, sparklines.
 2. HealthDetector units: uniform rates never flag; a slowed worker flags
    after ``strikes`` consecutive passes and recovers; heartbeat silence
    flags; rate math waits until every worker is actually iterating.
 3. End-to-end: a real 3-worker tcp run with ONE link slowed 8x under the
    emulated wire produces a straggler event naming that wid within a few
    heartbeat intervals (``PSResult.health`` + ``counters``); a uniform
    run stays quiet; ``link_slow`` changes the clock, never the math
    (bitwise pin); telemetry off (default) attaches nothing.
 4. STATS/monitor: ``launch.monitor.fetch_stats`` against a live master
    mid-run, ``obs.live.render`` output, the --telemetry-jsonl stream and
    its offline --from-jsonl rendering.
 5. Preemption: SIGTERM mid-run → clean BYE → the master raises a
    structured error naming the worker; the worker exits 0 and its
    --heartbeat-file was being touched.
 6. obs.regress: self-comparison passes, a synthetic 2x iters/s drop
    fails (direction-aware), --warn-only and history-dir modes.
"""
import json
import os
import signal
import socket
import subprocess
import sys
import threading
import time

import numpy as np
import pytest

from repro import ps
from repro.core import costmodel
from repro.core.easgd import EASGDConfig
from repro.launch import monitor
from repro.obs import live, regress

CFG = EASGDConfig(eta=0.05, rho=0.07, mu=0.9)
NET = costmodel.Network("tiny-emu", 5e-3, 1e-9)
REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _free_port() -> int:
    s = socket.socket()
    s.bind(("127.0.0.1", 0))
    port = s.getsockname()[1]
    s.close()
    return port


# ---------------------------------------------------------------------------
# (1) store primitives
# ---------------------------------------------------------------------------

def test_ring_wraparound_keeps_newest_in_order():
    r = live.Ring(capacity=4)
    for i in range(6):
        r.push(float(i), float(i * 10))
    assert r.n == 4
    t, v = r.values()
    assert list(t) == [2.0, 3.0, 4.0, 5.0]
    assert list(v) == [20.0, 30.0, 40.0, 50.0]
    assert r.last() == (5.0, 50.0)


def test_ring_partial_fill():
    r = live.Ring(capacity=8)
    assert r.last() is None
    r.push(1.0, 2.0)
    t, v = r.values()
    assert list(t) == [1.0] and list(v) == [2.0]


def test_timeseries_store_tail_and_nonnumeric():
    ts = live.TimeSeries(capacity=4)
    for i in range(6):
        ts.record(0, "rate_ips", i, float(i))
    ts.record(1, "iters", 7, 0.5)
    ts.record(0, "note", "not-a-number", 1.0)   # silently dropped
    assert ts.wids() == [0, 1]
    assert ts.metrics(0) == ["rate_ips"]
    assert ts.last(0, "rate_ips") == 5.0
    assert ts.last(0, "nope") is None
    tail = ts.tail(k=2)
    assert tail[0]["rate_ips"] == [[4.0, 4.0], [5.0, 5.0]]
    assert tail[1]["iters"] == [[0.5, 7.0]]


def test_sparkline():
    s = live.sparkline([0, 1, 2, 3])
    assert len(s) == 4
    assert s[0] == live._SPARK[0] and s[-1] == live._SPARK[-1]
    assert live.sparkline([]) == ""
    assert live.sparkline([5, 5]) == live._SPARK[3] * 2   # flat series
    assert len(live.sparkline(range(100), width=24)) == 24


# ---------------------------------------------------------------------------
# (2) detector units
# ---------------------------------------------------------------------------

def test_detector_uniform_rates_never_flag():
    det = live.HealthDetector(3, deadline_factor=2.0, stale_after_s=5.0)
    for i in range(50):
        evs = det.observe(float(i), {0: 10.0, 1: 10.2, 2: 9.8},
                          {0: 0.1, 1: 0.1, 2: 0.1})
        assert evs == []
    assert det.flagged == {}


def test_detector_flags_slow_worker_after_strikes_then_recovers():
    det = live.HealthDetector(3, deadline_factor=2.0, strikes=2)
    slow = {0: 10.0, 1: 10.0, 2: 2.0}
    assert det.observe(0.0, slow, {}) == []        # strike 1: debounced
    evs = det.observe(1.0, slow, {})               # strike 2: flag
    assert len(evs) == 1
    assert evs[0]["kind"] == "straggler" and evs[0]["wid"] == 2
    assert evs[0]["rate_ips"] == 2.0
    assert det.flagged == {2: "straggler"}
    assert det.observe(2.0, slow, {}) == []        # steady state: no re-emit
    evs = det.observe(3.0, {0: 10.0, 1: 10.0, 2: 9.5}, {})
    assert evs[0]["kind"] == "recovered" and evs[0]["wid"] == 2
    assert det.flagged == {}


def test_detector_waits_for_every_rate():
    # during problem build one worker reports rate 0 — median math over a
    # partial fleet would be meaningless, so no straggler verdicts yet
    det = live.HealthDetector(3, strikes=1)
    assert det.observe(0.0, {0: 10.0, 1: 10.0, 2: 0.0}, {}) == []
    assert det.observe(1.0, {0: 10.0, 1: 10.0, 2: None}, {}) == []
    assert det.flagged == {}


def test_detector_heartbeat_silence_flags():
    det = live.HealthDetector(2, stale_after_s=1.0, strikes=2)
    assert det.observe(0.0, {}, {0: 0.1, 1: 5.0}) == []
    evs = det.observe(1.0, {}, {0: 0.1, 1: 6.0})
    assert evs == [{"t": 1.0, "kind": "hb_stale", "wid": 1,
                    "hb_age_s": 6.0}]
    assert det.flagged == {1: "hb_stale"}


def test_live_monitor_counts_events_and_streams_jsonl(tmp_path):
    from repro.obs import metrics
    reg = metrics.Registry()
    path = str(tmp_path / "t.jsonl")
    # 3 workers: with only 2 the straggler itself drags the median past
    # its own delay, so a median-deadline policy can never flag it
    mon = live.LiveMonitor(3, hb_interval_s=0.1, jsonl_path=path,
                           counters=reg, meta={"algorithm": "unit"})
    mon.ingest_hb(0, {"iters": 10, "rate_ips": 10.0})
    mon.ingest_hb(1, {"iters": 10, "rate_ips": 10.0})
    mon.ingest_hb(2, {"iters": 1, "rate_ips": 1.0})
    for _ in range(2):                             # strikes=2 default
        mon.sample(staleness={0: 0.0, 1: 0.0, 2: 0.0},
                   gauges={"iters": 21})
    mon.mark_worker_event(1, "worker_left", "test")
    snap = mon.snapshot(k=4)
    mon.close()
    kinds = [e["kind"] for e in snap["events"]]
    assert "straggler" in kinds and "worker_left" in kinds
    assert reg.counter("health_events").value == len(snap["events"])
    assert snap["gauges"]["iters"] == 21.0
    assert snap["workers"][2]["rate_ips"][-1][1] == 1.0
    lines = [json.loads(x) for x in open(path)]
    # eager run-header + 2 samples + the eagerly-flushed event record
    # (lifecycle events must reach the stream even if the run ends before
    # the sampler's next tick — ft.membership reads them post-mortem)
    assert len(lines) == 4
    assert lines[0]["meta"] == {"algorithm": "unit"} \
        and "workers" not in lines[0]
    assert lines[1]["workers"]["0"]["rate_ips"] == 10.0
    assert lines[3]["events"][0]["kind"] == "worker_left" \
        and "workers" not in lines[3]


# ---------------------------------------------------------------------------
# (3) end-to-end: a real straggler on a real wire
# ---------------------------------------------------------------------------

def _live_cfg(link_slow, iters=240, **kw):
    # hogwild: each worker's reply deadline overlaps the others', so a
    # per-link pacing stretch becomes a genuine per-worker rate divergence
    return ps.PSConfig(algorithm="hogwild_easgd", n_workers=3,
                       total_iters=iters, transport="tcp", schedule="ring",
                       eval_every_iters=10**9, emulate_net=NET,
                       link_slow=link_slow, hb_interval_s=0.2, **kw)


def test_tcp_straggler_detected_and_named():
    res = ps.run_ps(ps.NUMPY_MLP, CFG,
                    _live_cfg((1.0, 1.0, 8.0), telemetry=True))
    assert res.health is not None
    stragglers = [e for e in res.health["events"]
                  if e["kind"] == "straggler"]
    assert stragglers, res.health["events"]
    assert all(e["wid"] == 2 for e in stragglers)
    # detection latency: strikes=2 at heartbeat-period sampling ⇒ the flag
    # lands 2 heartbeat intervals after divergence is first observable
    # (rates need one hb round to become positive; allow CI jitter)
    assert stragglers[0]["t"] <= 6 * 0.2 + 0.1, stragglers[0]
    assert stragglers[0]["rate_ips"] < stragglers[0]["median_rate_ips"]
    assert res.counters["health_events"] == len(res.health["events"])
    assert set(res.health["workers"]) == {0, 1, 2}


def test_tcp_uniform_run_stays_quiet():
    res = ps.run_ps(ps.NUMPY_MLP, CFG, _live_cfg(None, iters=120,
                                                 telemetry=True))
    bad = [e for e in res.health["events"]
           if e["kind"] in ("straggler", "hb_stale")]
    assert bad == [], bad
    assert res.counters["health_events"] == 0
    assert set(res.health["workers"]) == {0, 1, 2}
    assert all(m["iters"] == 40.0 for m in res.health["workers"].values())


def test_link_slow_changes_clock_not_math():
    def det_run(transport, **kw):
        cfg = ps.PSConfig(algorithm="async_easgd", n_workers=3,
                          total_iters=36, transport=transport,
                          schedule="round_robin", deterministic=True,
                          eval_every_iters=10**9, **kw)
        return ps.run_ps(ps.NUMPY_MLP, CFG, cfg)
    a = det_run("thread")
    b = det_run("tcp", emulate_net=NET, link_slow=(1.0, 1.0, 3.0))
    np.testing.assert_array_equal(a.center, b.center)
    np.testing.assert_array_equal(a.workers, b.workers)


def test_telemetry_off_is_the_default_and_attaches_nothing():
    cfg = ps.PSConfig(algorithm="async_easgd", n_workers=2, total_iters=20,
                      transport="thread", eval_every_iters=10**9)
    assert not cfg.telemetry_on
    res = ps.run_ps(ps.NUMPY_MLP, CFG, cfg)
    assert res.health is None
    assert "health_events" not in res.counters


def test_shared_memory_transport_gets_aggregate_telemetry(tmp_path):
    # no per-worker heartbeats off-wire: aggregate gauges only, no flags
    path = str(tmp_path / "thread.jsonl")
    cfg = ps.PSConfig(algorithm="async_easgd", n_workers=2, total_iters=200,
                      transport="thread", eval_every_iters=10**9,
                      telemetry_jsonl=path, telemetry_interval_s=0.01)
    res = ps.run_ps(ps.NUMPY_MLP, CFG, cfg)
    assert res.health is not None
    assert res.health["n_samples"] >= 1
    assert res.health["events"] == []
    assert res.counters["health_events"] == 0
    rec = [json.loads(x) for x in open(path)][-1]
    assert rec["gauges"]["iters"] == 200


def test_link_slow_validation():
    with pytest.raises(AssertionError, match="tcp"):
        ps.PSConfig(algorithm="async_easgd", transport="thread",
                    n_workers=2, link_slow=(1.0, 2.0))
    with pytest.raises(AssertionError, match="emulate"):
        ps.PSConfig(algorithm="async_easgd", transport="tcp",
                    n_workers=2, link_slow=(1.0, 2.0))
    with pytest.raises(AssertionError, match="one factor per worker"):
        ps.PSConfig(algorithm="async_easgd", transport="tcp", n_workers=3,
                    emulate_net=NET, link_slow=(1.0, 2.0))
    with pytest.raises(AssertionError):
        ps.PSConfig(algorithm="async_easgd", transport="tcp", n_workers=2,
                    emulate_net=NET, link_slow=(1.0, 0.5))


# ---------------------------------------------------------------------------
# (4) STATS frame + monitor
# ---------------------------------------------------------------------------

def test_monitor_fetches_and_renders_a_live_run(tmp_path):
    port = _free_port()
    jsonl = str(tmp_path / "telem.jsonl")
    cfg = _live_cfg((1.0, 1.0, 8.0), telemetry=True,
                    telemetry_jsonl=jsonl, tcp_port=port)
    snaps, token_errs = [], []

    def _poll():
        deadline = time.monotonic() + 60
        while time.monotonic() < deadline:
            try:
                snap = monitor.fetch_stats("127.0.0.1", port, k=8)
            except OSError:
                time.sleep(0.1)          # master still in rendezvous
                continue
            snaps.append(snap)
            if snap.get("n_samples", 0) >= 3:
                try:
                    monitor.fetch_stats("127.0.0.1", port, token="wrong")
                    token_errs.append(None)
                except RuntimeError as exc:
                    token_errs.append(exc)
                return
            time.sleep(0.2)

    th = threading.Thread(target=_poll, daemon=True)
    th.start()
    res = ps.run_ps(ps.NUMPY_MLP, CFG, cfg)
    th.join(timeout=10)
    assert res.total_iters == 240
    assert snaps, "monitor never fetched a STATS snapshot mid-run"
    snap = snaps[-1]
    assert snap["meta"]["algorithm"] == "hogwild_easgd"
    assert snap["meta"]["transport"] == "tcp"
    # JSON round trip stringifies wid keys; render handles both
    assert {"0", "1", "2"} <= set(snap["workers"])
    out = live.render(snap)
    assert "rate history" in out
    for w in (0, 1, 2):
        assert f"\n   {w} " in out, out
    assert token_errs and isinstance(token_errs[0], RuntimeError)
    # the JSONL stream parses and renders offline, straggler included:
    # line 0 is the eager run-header, the rest are samples
    lines = [json.loads(x) for x in open(jsonl)]
    assert lines[0]["meta"]["algorithm"] == "hogwild_easgd"
    assert len(lines) > 1 and all("t" in r and "workers" in r
                                  for r in lines[1:])
    offline = monitor.snap_from_jsonl(jsonl)
    assert offline["meta"]["algorithm"] == "hogwild_easgd"
    out2 = live.render(offline)
    assert "straggler" in out2, out2
    assert monitor.main(["--from-jsonl", jsonl]) == 0


# ---------------------------------------------------------------------------
# (5) preemption: SIGTERM → clean BYE
# ---------------------------------------------------------------------------

def test_sigterm_mid_run_is_a_clean_named_departure(tmp_path):
    port = _free_port()
    hb_file = str(tmp_path / "w1.hb")
    env = dict(os.environ)
    env["PYTHONPATH"] = (os.path.join(REPO_ROOT, "src") + os.pathsep
                         + env.get("PYTHONPATH", "")).rstrip(os.pathsep)
    cfg = ps.PSConfig(algorithm="async_easgd", n_workers=2,
                      total_iters=4000, transport="tcp", schedule="ring",
                      eval_every_iters=10**9, emulate_net=NET,
                      tcp_port=port, spawn_workers=False,
                      telemetry=True, hb_interval_s=0.2)
    procs = [subprocess.Popen(
        [sys.executable, "-m", "repro.net.worker",
         "--connect", f"127.0.0.1:{port}", "--wid", str(w),
         "--sync-plane", "master"]
        + (["--heartbeat-file", hb_file] if w == 1 else []),
        env=env) for w in (0, 1)]
    killer = threading.Timer(
        2.5, lambda: procs[1].send_signal(signal.SIGTERM))
    killer.start()
    try:
        with pytest.raises(RuntimeError, match="worker 1 left the run"):
            ps.run_ps(ps.NUMPY_MLP, CFG, cfg, join_timeout_s=60.0)
    finally:
        killer.cancel()
        for p in procs:
            try:
                p.wait(timeout=15)
            except subprocess.TimeoutExpired:
                p.kill()
    assert procs[1].returncode == 0      # clean exit, not a crash
    from repro.ft.watchdog import Watchdog
    assert Watchdog.is_alive(hb_file, timeout_s=60.0)


# ---------------------------------------------------------------------------
# (6) the regression gate
# ---------------------------------------------------------------------------

_BENCH = {
    "module": "p2p_overlap_smoke",
    "ok": True,
    "iters_per_sec": 100.0,
    "exposed_s": 0.5,
    "meta": {"git_sha": "deadbeef"},            # skipped by the flattener
    "rows": [{"name": "ring", "us_per_call": 12.0,
              "derived": "final_err=0.040;t_to_0.25=0.202s;speedup=5.3x"}],
}


def _write(path, rec):
    with open(path, "w") as f:
        json.dump(rec, f)
    return str(path)


def test_flatten_handles_rows_derived_and_lists():
    flat = regress.flatten_metrics(_BENCH)
    assert flat["iters_per_sec"] == 100.0
    assert flat["rows.ring.us_per_call"] == 12.0
    assert flat["rows.ring.t_to_0.25"] == 0.202      # "s" unit stripped
    assert flat["rows.ring.speedup"] == 5.3          # "x" unit stripped
    assert not any(k.startswith("meta") for k in flat)
    assert regress.flatten_metrics(
        {"bucket_send_bytes": [10, 20, 30]})["bucket_send_bytes.sum"] == 60


def test_regress_self_comparison_passes(tmp_path):
    p = _write(tmp_path / "base.json", _BENCH)
    assert regress.main([p, p]) == 0


def test_regress_fails_on_2x_throughput_drop(tmp_path):
    base = _write(tmp_path / "base.json", _BENCH)
    cur = _write(tmp_path / "cur.json",
                 {**_BENCH, "iters_per_sec": 50.0})
    assert regress.main([base, cur, "--metrics", "iters_per_sec"]) == 1
    assert regress.main([base, cur, "--metrics", "iters_per_sec",
                         "--warn-only"]) == 0
    # direction-aware: the same 2x change UP is an improvement, not a fail
    up = _write(tmp_path / "up.json", {**_BENCH, "iters_per_sec": 200.0})
    assert regress.main([base, up]) == 0


def test_regress_cost_metrics_fail_on_rise(tmp_path):
    base = _write(tmp_path / "base.json", _BENCH)
    cur = _write(tmp_path / "cur.json", {**_BENCH, "exposed_s": 2.0})
    assert regress.main([base, cur, "--metrics", "exposed_s"]) == 1


def test_regress_history_dir_compares_two_newest(tmp_path):
    d = tmp_path / "hist"
    d.mkdir()
    _write(d / "aaa.json", _BENCH)
    time.sleep(0.05)                     # mtime order decides base/current
    _write(d / "bbb.json", {**_BENCH, "iters_per_sec": 40.0})
    assert regress.main([str(d)]) == 1
    assert regress.main([str(d), "--warn-only"]) == 0


def test_regress_unknown_metrics_drift_never_fails(tmp_path):
    base = _write(tmp_path / "base.json", {"mystery_quantity": 1.0})
    cur = _write(tmp_path / "cur.json", {"mystery_quantity": 9.0})
    assert regress.main([base, cur]) == 0
