"""Pallas kernels vs pure-jnp oracles (interpret=True on CPU), with
shape/dtype sweeps per the deliverable."""
import warnings

warnings.filterwarnings("ignore")

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.models.attention import blocked_attention


@pytest.mark.parametrize(
    "B,S,H,KVH,D,causal,window,dtype",
    [
        (2, 64, 4, 2, 32, True, 0, jnp.float32),
        (1, 100, 2, 2, 16, True, 9, jnp.float32),
        (2, 128, 4, 1, 64, False, 0, jnp.bfloat16),
        (1, 256, 8, 4, 128, True, 64, jnp.float32),
        (1, 96, 4, 4, 8, True, 0, jnp.bfloat16),
    ],
)
def test_flash_attention_kernel(B, S, H, KVH, D, causal, window, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, S, H, D), dtype)
    k = jax.random.normal(ks[1], (B, S, KVH, D), dtype)
    v = jax.random.normal(ks[2], (B, S, KVH, D), dtype)
    out = ops.flash_attention(q, k, v, causal=causal, window=window,
                              block_q=32, block_k=32, interpret=True)
    want = blocked_attention(q, k, v, causal=causal, window=window)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32),
        rtol=tol, atol=tol)


def test_flash_attention_vs_dense():
    """Independent dense (S×S) oracle."""
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (2, 64, 4, 16))
    k = jax.random.normal(ks[1], (2, 64, 4, 16))
    v = jax.random.normal(ks[2], (2, 64, 4, 16))
    out = ops.flash_attention(q, k, v, causal=True, block_q=16, block_k=16,
                              interpret=True)
    want = ref.flash_attention_dense_ref(
        q.transpose(0, 2, 1, 3).reshape(8, 64, 16),
        k.transpose(0, 2, 1, 3).reshape(8, 64, 16),
        v.transpose(0, 2, 1, 3).reshape(8, 64, 16), causal=True)
    want = want.reshape(2, 4, 64, 16).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("n,block,dtype", [
    (1 << 12, 1024, jnp.float32),
    (1 << 14, 4096, jnp.float32),
    (1 << 12, 4096, jnp.bfloat16),
])
def test_elastic_update_kernel(n, block, dtype):
    ks = jax.random.split(jax.random.PRNGKey(2), 5)
    w, v, g, c, m = (jax.random.normal(k, (n,), dtype) for k in ks)
    out = ops.elastic_update(w, v, g, c, m, eta=0.1, rho=0.05, mu=0.9,
                             n_workers=4, block=block, interpret=True)
    want = ref.elastic_update_ref(w, v, g, c, m, eta=0.1, rho=0.05, mu=0.9,
                                  n_workers=4)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-6
    for a, b in zip(out, want):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=tol, atol=tol)


@pytest.mark.parametrize("BH,S,P,N,L", [
    (4, 128, 32, 64, 32),
    (2, 64, 16, 16, 16),
    (1, 256, 64, 128, 64),
])
def test_ssd_intra_kernel(BH, S, P, N, L):
    ks = jax.random.split(jax.random.PRNGKey(3), 4)
    a = -jax.nn.softplus(jax.random.normal(ks[0], (BH, S)))
    x = jax.random.normal(ks[1], (BH, S, P))
    b = jax.random.normal(ks[2], (BH, S, N))
    c = jax.random.normal(ks[3], (BH, S, N))
    out = ops.ssd_intra_chunk(a, x, b, c, chunk=L, interpret=True)
    want = ref.ssd_intra_ref(a, x, b, c, chunk=L)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("T,d,V,bt,bv", [
    (64, 32, 300, 16, 128),       # vocab not a multiple of the tile
    (100, 16, 512, 32, 128),      # tokens not a multiple of the tile
    (32, 64, 1000, 32, 256),
])
def test_fused_cross_entropy_kernel(T, d, V, bt, bv):
    ks = jax.random.split(jax.random.PRNGKey(5), 3)
    h = jax.random.normal(ks[0], (T, d))
    w = jax.random.normal(ks[1], (d, V)) * 0.1
    t = jax.random.randint(ks[2], (T,), 0, V)
    out = ops.fused_cross_entropy(h, w, t, block_t=bt, block_v=bv,
                                  interpret=True)
    want = ref.fused_ce_ref(h, w, t)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_flash_train_custom_vjp_matches_autodiff():
    ks = jax.random.split(jax.random.PRNGKey(4), 4)
    from repro.models.attention import flash_attention_train
    q = jax.random.normal(ks[0], (2, 96, 4, 16))
    k = jax.random.normal(ks[1], (2, 96, 2, 16))
    v = jax.random.normal(ks[2], (2, 96, 2, 16))
    dout = jax.random.normal(ks[3], (2, 96, 4, 16))
    kw = dict(causal=True, window=11, q_block=32, kv_block=16)
    f1 = lambda q, k, v: jnp.sum(flash_attention_train(q, k, v, **kw) * dout)
    f2 = lambda q, k, v: jnp.sum(blocked_attention(q, k, v, causal=True,
                                                   window=11, q_block=32,
                                                   kv_block=16) * dout)
    g1 = jax.grad(f1, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(f2, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-4)
