"""repro.net — the TCP transport, bottom-up.

 1. Wire protocol: framing roundtrip, partial reads, zero-copy recv_into,
    heartbeat transparency, sign-EF payloads with per-link error feedback
    (numpy codec consistent with the jax codec in core.compression).
 2. Localhost TCP runs: every algorithm family completes on 2 real worker
    processes; rejection paths fail fast.
 3. The ISSUE's acceptance: TCP-vs-thread weights BITWISE identical for
    the deterministic sync family (and the async turnstile, which shares
    the DES zero-jitter event order); sign_ef on the wire cuts measured
    bytes ≥4x at matched final loss; emulated wire changes the clock, not
    the math.
"""
import socket
import threading

import numpy as np
import pytest

from repro import ps
from repro.core import compression, costmodel
from repro.core.easgd import EASGDConfig
from repro.net import wire

CFG = EASGDConfig(eta=0.05, rho=0.07, mu=0.9)


# ---------------------------------------------------------------------------
# (1) wire protocol
# ---------------------------------------------------------------------------

def _link_pair(codec_a="none", codec_b="none"):
    a, b = socket.socketpair()
    return wire.Link(a, codec=codec_a), wire.Link(b, codec=codec_b)


class _Slot:
    def __init__(self):
        self.value = 0


def test_wire_array_roundtrip_and_counters():
    counters = {"messages": _Slot(), "wire_bytes": _Slot()}
    tx, rx = _link_pair()
    tx.counters = counters
    arr = np.random.RandomState(0).randn(1000)
    tx.send_array(wire.WEIGHTS, arr, wid=3)
    frame = rx.recv_header()
    assert frame.ftype == wire.WEIGHTS and frame.wid == 3
    assert frame.size == 8000
    got = rx.recv_array(frame)
    np.testing.assert_array_equal(got, arr)
    assert counters["messages"].value == 1
    assert counters["wire_bytes"].value == 8000 + wire.HEADER_SIZE
    tx.close(), rx.close()


def test_wire_recv_into_is_zero_copy_path():
    tx, rx = _link_pair()
    arr = np.arange(512, dtype=np.float64)
    out = np.zeros(512)
    tx.send_array(wire.GRAD, arr)
    got = rx.recv_array(rx.recv_header(), out)
    assert got is out                         # landed in the caller's buffer
    np.testing.assert_array_equal(out, arr)
    tx.close(), rx.close()


def test_wire_partial_reads_reassemble():
    """Frames split into tiny TCP segments must reassemble byte-perfectly
    (the recv loop's whole job)."""
    a, b = socket.socketpair()
    rx = wire.Link(b)
    arr = np.random.RandomState(1).randn(300)
    header = wire._HEADER.pack(wire.MAGIC, wire.VERSION, wire.WEIGHTS, 0, 0,
                               wire.CODEC_NONE, arr.nbytes)
    blob = header + arr.tobytes()

    def _dribble():
        for i in range(0, len(blob), 7):      # 7-byte segments
            a.sendall(blob[i:i + 7])

    th = threading.Thread(target=_dribble)
    th.start()
    frame = rx.recv_header()
    got = rx.recv_array(frame)
    th.join()
    np.testing.assert_array_equal(got, arr)
    a.close(), rx.close()


def test_wire_heartbeats_are_transparent():
    tx, rx = _link_pair()
    tx.send_simple(wire.HEARTBEAT)
    tx.send_simple(wire.HEARTBEAT)
    tx.send_array(wire.GRAD, np.ones(4))
    frame = rx.recv_header()                  # skips the two heartbeats
    assert frame.ftype == wire.GRAD
    tx.close(), rx.close()


def test_wire_bad_magic_raises():
    a, b = socket.socketpair()
    rx = wire.Link(b)
    a.sendall(b"XX" + bytes(wire.HEADER_SIZE - 2))
    with pytest.raises(wire.WireError, match="bad frame header"):
        rx.recv_header()
    a.close(), rx.close()


def test_sign_ef_codec_roundtrip_and_error_feedback():
    rng = np.random.RandomState(2)
    buf = rng.randn(501)                      # odd length: padded bit tail
    err = np.zeros(501)
    payload, err1 = compression.sign_ef_encode_np(buf, err)
    assert len(payload) == compression.sign_ef_wire_nbytes(501)
    dec = compression.sign_ef_decode_np(payload)
    scale = np.mean(np.abs(buf))
    np.testing.assert_allclose(dec, np.sign(buf + 1e-300) * scale, rtol=1e-12)
    np.testing.assert_allclose(err1, buf - dec, rtol=1e-12)
    # EF carries the residual: the NEXT message corrects toward the truth
    payload2, _ = compression.sign_ef_encode_np(buf, err1)
    dec2 = compression.sign_ef_decode_np(payload2)
    np.testing.assert_array_less(
        np.abs((dec + dec2) / 2 - buf).mean(), np.abs(dec - buf).mean())


def test_sign_ef_numpy_matches_jax_codec():
    """One sign-EF definition, two realizations: the numpy wire codec and
    the jitted collective codec must agree on signs, scale, and EF state."""
    import jax.numpy as jnp
    rng = np.random.RandomState(3)
    buf = rng.randn(256).astype(np.float32)
    err = np.zeros(256, np.float32)
    (signs, scale), ef_jax = compression.SIGN_EF.encode(
        jnp.asarray(buf), jnp.asarray(err))
    payload, ef_np = compression.sign_ef_encode_np(
        buf.astype(np.float64), err.astype(np.float64))
    dec_np = compression.sign_ef_decode_np(payload)
    np.testing.assert_allclose(float(scale), np.abs(buf).mean(), rtol=1e-6)
    np.testing.assert_array_equal(np.sign(dec_np), np.asarray(signs))
    np.testing.assert_allclose(ef_np, np.asarray(ef_jax), atol=1e-6)


def test_sign_ef_segmented_payload_keeps_scales_apart():
    """τ>1 stacks [grad|w] into one frame; each segment must carry its OWN
    sign-EF scale — a shared scale would let weight magnitudes (~1) drown
    the gradient's (~1e-2), which measurably breaks convergence."""
    tx, rx = _link_pair(codec_a="sign_ef")
    rng = np.random.RandomState(7)
    grad, w = 0.01 * rng.randn(400), 1.0 + rng.randn(400)
    tx.send_array(wire.GRAD, np.concatenate([grad, w]), segments=2)
    got = rx.recv_array(rx.recv_header())
    g_dec, w_dec = got[:400], got[400:]
    np.testing.assert_allclose(np.abs(g_dec).max(), np.abs(grad).mean(),
                               rtol=1e-9)        # grad-scale, not w-scale
    np.testing.assert_allclose(np.abs(w_dec).max(), np.abs(w).mean(),
                               rtol=1e-9)
    assert np.abs(g_dec).max() < 0.1 * np.abs(w_dec).max()
    tx.close(), rx.close()


def test_tcp_sign_ef_with_tau_converges():
    """The reproduced review finding: sign_ef + τ=4 must stay near the
    uncompressed run (per-segment scales + per-(type,segment) EF). Error
    feedback needs EXCHANGES — not iterations — to absorb the 1-bit
    transient, so τ=4 gets 4x the iterations for the same exchange count."""
    e = EASGDConfig(eta=0.1, rho=0.1, mu=0.9, tau=4)
    errs = {}
    for codec in ("none", "sign_ef"):
        cfg = _tcp_cfg("async_easgd", iters=960, wire_compression=codec,
                       eval_every_iters=10**9)
        errs[codec] = ps.run_ps(ps.NUMPY_MLP, e, cfg).final_metric
    assert errs["sign_ef"] <= errs["none"] + 0.10, errs


def test_wire_compression_rejected_off_tcp():
    """The shared-memory transports move no frames — a config claiming
    compression there must fail fast, not silently report raw bytes."""
    with pytest.raises(AssertionError, match="tcp-transport"):
        ps.PSConfig(algorithm="async_easgd", transport="thread",
                    wire_compression="sign_ef")


def test_sign_ef_payload_over_link():
    tx, rx = _link_pair(codec_a="sign_ef")
    arr = np.random.RandomState(4).randn(800)
    n_wire = tx.send_array(wire.GRAD, arr)
    assert n_wire == compression.sign_ef_wire_nbytes(800)   # 1 bit/element
    assert n_wire < arr.nbytes / 8
    got = rx.recv_array(rx.recv_header())
    np.testing.assert_allclose(got, np.sign(arr) * np.abs(arr).mean(),
                               rtol=1e-12)
    tx.close(), rx.close()


def test_measure_link_returns_sane_alpha_beta():
    alpha, beta = wire.measure_link(reps=10, big_bytes=400_000)
    assert 1e-7 <= alpha < 0.5
    assert 1e-12 <= beta < 1e-5


# ---------------------------------------------------------------------------
# (2) localhost TCP runs — 2 real worker processes per run
# ---------------------------------------------------------------------------

def _tcp_cfg(algo, P=2, iters=40, **kw):
    kw.setdefault("eval_every_iters", 10**9)
    return ps.PSConfig(algorithm=algo, n_workers=P, total_iters=iters,
                       transport="tcp", schedule="ring", **kw)


@pytest.mark.parametrize("algo", [
    "original_easgd",                  # round-robin family
    "async_easgd", "async_measgd",     # FCFS family (elastic + velocity)
    "hogwild_easgd",                   # lock-free family
    "sync_easgd", "sync_sgd",          # barriered family
])
def test_tcp_smoke_every_family(algo):
    res = ps.run_ps(ps.NUMPY_MLP, CFG, _tcp_cfg(algo))
    assert res.total_iters == 40
    assert res.transport == "tcp"
    assert np.isfinite(res.final_metric)
    assert np.all(np.isfinite(res.center))
    assert res.counters["messages"] > 0
    assert res.counters["wire_bytes"] > 0


def test_tcp_rejects_prebuilt_closures():
    built = ps.make_numpy_mlp()
    with pytest.raises(ValueError, match="ProblemSpec"):
        ps.run_ps(built, CFG, _tcp_cfg("async_easgd", iters=10))


def test_tcp_rejects_deterministic_with_compression():
    with pytest.raises(ValueError, match="deterministic"):
        ps.run_ps(ps.NUMPY_MLP, CFG,
                  _tcp_cfg("async_easgd", deterministic=True,
                           wire_compression="sign_ef"))


def test_tcp_rendezvous_times_out_without_workers():
    cfg = _tcp_cfg("async_easgd", spawn_workers=False)
    with pytest.raises(RuntimeError, match="rendezvous timeout"):
        ps.run_ps(ps.NUMPY_MLP, CFG, cfg, join_timeout_s=2.0)


def test_tcp_worker_is_jax_free(subproc):
    """The worker's import footprint must stay numpy-only — that is what
    keeps remote worker startup under a second."""
    subproc("""
        import sys
        import repro.net.worker
        import repro.net.peer
        import repro.comm.rounds
        import repro.ps.problems
        import repro.obs
        import repro.ft                  # lazy package: straggler/watchdog
        import repro.ft.straggler       # the live plane's detector math
        import repro.ft.watchdog        # the worker's preemption plane
        import repro.launch.monitor
        import repro.utils.timing
        assert "jax" not in sys.modules, "worker pulled jax in"
    """, n_devices=1)


# ---------------------------------------------------------------------------
# (3) acceptance: bitwise cross-transport, sign-EF wire, emulation
# ---------------------------------------------------------------------------

def _det_run(algo, P, iters, transport, **kw):
    cfg = ps.PSConfig(algorithm=algo, n_workers=P, total_iters=iters,
                      transport=transport, schedule="round_robin",
                      deterministic=True, eval_every_iters=10**9, **kw)
    return ps.run_ps(ps.NUMPY_MLP, CFG, cfg)


@pytest.mark.parametrize("algo,P", [
    ("sync_easgd", 2), ("sync_easgd", 3), ("sync_sgd", 4),
    ("async_easgd", 2),
])
def test_tcp_thread_iterates_bitwise(algo, P):
    """Deterministic admission ⇒ identical event order ⇒ the TCP master and
    the thread transport produce bit-identical float64 weights — the wire
    moved every byte faithfully."""
    thread = _det_run(algo, P, 72, "thread")
    tcp = _det_run(algo, P, 72, "tcp")
    assert thread.total_iters == tcp.total_iters
    np.testing.assert_array_equal(thread.center, tcp.center)
    np.testing.assert_array_equal(thread.workers, tcp.workers)


def test_tcp_emulated_wire_changes_clock_not_math():
    slow = costmodel.Network("tiny-emu", 1e-3, 1e-9)
    a = _det_run("async_easgd", 2, 40, "tcp")
    b = _det_run("async_easgd", 2, 40, "tcp", emulate_net=slow)
    np.testing.assert_array_equal(a.center, b.center)
    assert b.total_time_s > 40 * 2 * 1e-3     # the wire time was actually paid


def test_tcp_sign_ef_cuts_wire_bytes_4x_at_matched_loss():
    """The ISSUE's wire-compression acceptance, in miniature: ≥4x fewer
    measured bytes per exchange (we get ~60x: 1 bit vs 8 bytes per element,
    both directions), with error feedback holding convergence."""
    runs = {}
    for codec in ("none", "sign_ef"):
        cfg = _tcp_cfg("async_easgd", iters=240, wire_compression=codec,
                       eval_every_iters=120)
        runs[codec] = ps.run_ps(
            ps.NUMPY_MLP, EASGDConfig(eta=0.1, rho=0.1, mu=0.9), cfg)
    b_none = runs["none"].counters["wire_bytes"]
    b_sign = runs["sign_ef"].counters["wire_bytes"]
    assert b_none >= 4 * b_sign, (b_none, b_sign)
    # matched loss: EF keeps the compressed run within noise of the raw one
    assert runs["sign_ef"].final_metric <= runs["none"].final_metric + 0.10


# ---------------------------------------------------------------------------
# (4) the p2p sync data plane (ISSUE 4): workers execute Schedule.rounds
#     over direct worker↔worker links; the master degrades to control plane
# ---------------------------------------------------------------------------

def _plane_run(algo, P, plane, schedule, iters=48, transport="tcp", **kw):
    kw.setdefault("deterministic", True)
    cfg = ps.PSConfig(algorithm=algo, n_workers=P, total_iters=iters,
                      transport=transport, schedule=schedule,
                      eval_every_iters=10**9,
                      **({"sync_plane": plane} if transport == "tcp" else {}),
                      **kw)
    return ps.run_ps(ps.NUMPY_MLP, CFG, cfg)


@pytest.mark.parametrize("algo,P,schedule", [
    ("sync_easgd", 2, "tree"),
    ("sync_easgd", 3, "ring"),             # non-power-of-two ring
    ("sync_sgd", 4, "butterfly"),
])
def test_p2p_thread_tcp_triangle_bitwise(algo, P, schedule):
    """The thread↔tcp cross-check extended to a thread↔tcp↔p2p TRIANGLE:
    under deterministic admission all three planes produce bit-identical
    float64 weights. The p2p side holds because every worker's mailbox row
    ends bitwise equal to the centralized mailbox[0] (ring/tree copy one
    accumulation chain everywhere; butterfly rows differ only in the ORDER
    of commutative IEEE additions), so the per-worker center replicas
    advance in lockstep with the master-plane center."""
    thread = _plane_run(algo, P, None, schedule, transport="thread")
    master = _plane_run(algo, P, "master", schedule)
    p2p = _plane_run(algo, P, "p2p", schedule)
    assert thread.total_iters == master.total_iters == p2p.total_iters
    np.testing.assert_array_equal(thread.center, master.center)
    np.testing.assert_array_equal(thread.center, p2p.center)
    np.testing.assert_array_equal(thread.workers, p2p.workers)
    assert p2p.schedule == f"{schedule}+p2p"


@pytest.mark.parametrize("schedule,P", [
    ("ring", 2), ("ring", 4), ("butterfly", 2), ("butterfly", 4),
])
def test_p2p_per_link_bytes_match_registry(schedule, P):
    """Measured per-link byte counters == the registry's prediction: each
    worker pair's counter must equal exchanges × Σ (header + span bytes)
    over that pair's messages — every SEGMENT frame accounted, nothing
    else on the peer links."""
    from repro.net.peer import predicted_link_bytes

    from repro import comm
    iters = 24
    res = _plane_run("sync_easgd", P, "p2p", schedule, iters=iters)
    n = res.center.size
    padded = n + (-n) % P
    exchanges = -(-iters // P)
    per_exchange = predicted_link_bytes(
        comm.get(schedule).rounds(P, n * 8), padded)
    want = {f"{i}-{j}": exchanges * b for (i, j), b in per_exchange.items()}
    assert res.counters["peer_link_bytes"] == want
    # and the registry's total-byte accounting agrees (modulo headers and
    # the row padding the wire moves)
    frames = res.counters["peer_messages"]
    payload = res.counters["peer_wire_bytes"] - frames * wire.HEADER_SIZE
    expect_payload = exchanges * comm.get(schedule).bytes_from_rounds(
        padded * 8, P)
    np.testing.assert_allclose(payload, expect_payload, rtol=1e-12)


def test_p2p_master_link_bytes_collapse_4x():
    """THE acceptance criterion: ring at P=4 on loopback moves ≥4x fewer
    bytes through the master link under sync_plane='p2p' than under
    'master', at bitwise-identical final weights (deterministic
    admission). Also pins the ~2N(P−1)/P per-worker ring traffic."""
    master = _plane_run("sync_easgd", 4, "master", "ring", iters=64)
    p2p = _plane_run("sync_easgd", 4, "p2p", "ring", iters=64)
    np.testing.assert_array_equal(master.center, p2p.center)
    np.testing.assert_array_equal(master.workers, p2p.workers)
    b_master = master.counters["master_link_bytes"]
    b_p2p = p2p.counters["master_link_bytes"]
    assert b_master >= 4 * b_p2p, (b_master, b_p2p)
    # per-link ring traffic: each of the P ring links carries 2(P−1)
    # chunks of padded/P elements per exchange — ≈ 2N(P−1)/P per worker
    n, P = p2p.center.size, 4
    padded = n + (-n) % P
    exchanges = 64 // P
    per_link = exchanges * 2 * (P - 1) * (padded // P * 8 + wire.HEADER_SIZE)
    assert all(b == per_link
               for b in p2p.counters["peer_link_bytes"].values()), \
        p2p.counters["peer_link_bytes"]


def test_p2p_sign_ef_per_peer_link_matched_loss():
    """sign-EF composes per peer link exactly as per master link: 1-bit
    SEGMENT payloads with per-(link, segment) error feedback cut peer
    bytes ≥4x while the barriered sync run stays at matched loss (the
    event order is deterministic, so these numbers are stable)."""
    e = EASGDConfig(eta=0.1, rho=0.1, mu=0.9)
    runs = {}
    for codec in ("none", "sign_ef"):
        runs[codec] = _plane_run("sync_sgd", 2, "p2p", "butterfly",
                                 iters=240, deterministic=False,
                                 wire_compression=codec)
    assert (runs["none"].counters["peer_wire_bytes"]
            >= 4 * runs["sign_ef"].counters["peer_wire_bytes"])
    assert (runs["sign_ef"].final_metric
            <= runs["none"].final_metric + 0.10), \
        {k: r.final_metric for k, r in runs.items()}


def test_p2p_huge_rows_stream_without_helper_threads_or_deadlock():
    """Regression for the retired PR-4 escape hatch: rows far past the
    kernel's socket buffering used to need a helper-thread sender to
    survive the everyone-sends-first cycle. The select-driven round engine
    must complete a row 4x larger than SO_SNDBUF with both sides sending
    full-row segments to each other — and must do it on the caller's
    thread alone (no helper threads; thread count is pinned)."""
    from repro.comm.rounds import butterfly_rounds, peer_pairs
    from repro.net.peer import PeerMesh

    probe = socket.socket()
    sndbuf = probe.getsockopt(socket.SOL_SOCKET, socket.SO_SNDBUF)
    probe.close()
    n = (4 * sndbuf) // 8 + 1               # row bytes > 4 * SO_SNDBUF
    rounds = butterfly_rounds(2)
    meshes = [PeerMesh(w, "t", bind_host="127.0.0.1", timeout_s=30)
              for w in (0, 1)]
    directory = {w: ("127.0.0.1", m.port) for w, m in enumerate(meshes)}
    rows = [np.arange(n) * 1.0, np.arange(n) * 2.0]
    want = rows[0] + rows[1]
    errs, threads = [], []
    thread_counts = {}

    def _run(wid):
        try:
            meshes[wid].connect(directory, peer_pairs(rounds))
            meshes[wid].set_rounds(rounds, n)
            before = {t.ident for t in threading.enumerate()}
            meshes[wid].execute_exchange(rows[wid])
            after = {t.ident for t in threading.enumerate()}
            thread_counts[wid] = len(after - before)
        except BaseException as e:          # noqa: BLE001
            errs.append(e)

    for wid in (0, 1):
        threads.append(threading.Thread(target=_run, args=(wid,)))
        threads[-1].start()
    for th in threads:
        th.join(timeout=60)
    alive = [th for th in threads if th.is_alive()]
    for m in meshes:
        m.close()
    assert not alive, "p2p exchange deadlocked on huge rows"
    assert not errs, errs
    assert thread_counts == {0: 0, 1: 0}, thread_counts
    np.testing.assert_array_equal(rows[0], want)
    np.testing.assert_array_equal(rows[1], want)


def test_p2p_sign_ef_control_plane_reports_are_exact():
    """CENTER / final WSTATE are one-shot state transfers — they must
    bypass the lossy wire codec (a sign-quantized 'final center' would
    collapse every |w| to one magnitude and the master would eval the
    wrong model)."""
    e = EASGDConfig(eta=0.1, rho=0.1, mu=0.9)
    res = _plane_run("sync_sgd", 2, "p2p", "butterfly", iters=40,
                     deterministic=False, wire_compression="sign_ef")
    # trained weights have a rich magnitude spectrum; sign*scale has 1
    assert len(np.unique(np.abs(res.center))) > res.center.size // 2
    assert len(np.unique(np.abs(res.workers[0]))) > res.center.size // 2


def test_segment_ef_streams_keyed_by_chunk_and_op():
    """A ring link carries a chunk's reduce-scatter partials AND its
    all-gather broadcasts: two sign-EF streams whose residuals must not
    mix. The EF state must key on (chunk, op), not chunk alone."""
    tx, rx = _link_pair(codec_a="sign_ef")
    arr = np.random.RandomState(5).randn(64)
    tx.send_array(wire.SEGMENT, arr, ef_tag=(0, "add"))
    tx.send_array(wire.SEGMENT, arr, ef_tag=(0, "set"))
    assert len(tx._ef) == 2, list(tx._ef)   # distinct residual per stream
    rx.recv_discard(rx.recv_header())
    rx.recv_discard(rx.recv_header())
    tx.close(), rx.close()


def test_p2p_rejected_off_tcp_and_off_sync_family():
    with pytest.raises(AssertionError, match="sync_plane"):
        ps.PSConfig(algorithm="sync_easgd", transport="thread",
                    sync_plane="p2p")
    with pytest.raises(AssertionError, match="sync_plane"):
        ps.PSConfig(algorithm="async_easgd", transport="tcp",
                    sync_plane="p2p")


def test_p2p_rejects_master_routed_schedule():
    """round_robin's rounds address the MASTER endpoint — there is no p2p
    version of a schedule that IS the master plane."""
    with pytest.raises(ValueError, match="master plane"):
        _plane_run("sync_easgd", 2, "p2p", "round_robin", iters=8)


def test_p2p_emulated_wire_changes_clock_not_math():
    slow = costmodel.Network("tiny-emu", 1e-3, 1e-9)
    a = _plane_run("sync_easgd", 2, "p2p", "ring", iters=40)
    b = _plane_run("sync_easgd", 2, "p2p", "ring", iters=40,
                   emulate_net=slow)
    np.testing.assert_array_equal(a.center, b.center)
    # ring P=2 has 2 rounds per exchange, each paced ≥ α=1ms, 20 exchanges
    assert b.total_time_s > 20 * 2 * 1e-3


def test_tcp_counters_count_real_frames():
    """FCFS, 2 workers, τ=1: every exchange is exactly one GRAD up + one
    WEIGHTS down; plus the initial distribution. wire_bytes is the real
    socket payload+header volume of those frames."""
    res = ps.run_ps(ps.NUMPY_MLP, CFG, _tcp_cfg("async_easgd", iters=30))
    n = res.center.size
    msgs = res.counters["messages"]
    # 30 grads up + ~30 weights down, plus the initial distribution and the
    # in-flight grads discarded at shutdown (≤ ~3 frames per worker)
    assert 2 * 30 <= msgs <= 2 * 30 + 6 * 2, msgs
    assert res.counters["wire_bytes"] >= msgs * (n * 8 + wire.HEADER_SIZE) * 0.9
