"""EASGD update-rule invariants + packed/unpacked equivalence + compression.

Property tests (hypothesis) cover the algebraic identities the paper's
method relies on; exact-match tests pin the packed shard_map implementation
to the per-tensor reference. hypothesis is optional (requirements-dev.txt):
when absent the property tests are skipped and deterministic fallbacks
keep the invariants covered.
"""
import warnings

warnings.filterwarnings("ignore")

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:      # property tests skipped, fallbacks below
    given = settings = st = None

from repro.core import (
    EASGDConfig, ElasticConfig, Packer,
    elastic_apply_gradients, elastic_init,
)
from repro.core import compression, easgd
from repro.utils.jaxcompat import auto_mesh
from repro.core.elastic import n_pods_of


def tree_allclose(a, b, rtol=1e-5, atol=1e-6):
    for x, y in zip(jax.tree_util.tree_leaves(a),
                    jax.tree_util.tree_leaves(b)):
        np.testing.assert_allclose(np.asarray(x, np.float32),
                                   np.asarray(y, np.float32),
                                   rtol=rtol, atol=atol)


def _check_packer_roundtrip(tree, align=8):
    tree = {k: jnp.asarray(v) for k, v in tree.items()}
    pk = Packer(tree, align=align) if align is not None else Packer(tree)
    back = pk.unpack(pk.pack(tree))
    tree_allclose(tree, back)
    return pk


def _check_rho_zero_is_momentum_sgd(eta, mu):
    """ρ=0 degenerates eqs 5-6 to plain momentum SGD (eqs 3-4)."""
    cfg = EASGDConfig(eta=eta, rho=0.0, mu=mu)
    w = {"a": jnp.ones((3, 2))}
    v = {"a": jnp.zeros((3, 2))}
    g = {"a": jnp.full((3, 2), 0.3)}
    c = {"a": jnp.full((3, 2), 7.0)}   # center shouldn't matter at ρ=0
    w1, v1 = easgd.measgd_worker_update(w, v, g, c, cfg)
    w2, v2 = easgd.msgd_update(w, v, g, cfg)
    tree_allclose(w1, w2)
    tree_allclose(v1, v2)


if st is not None:

    @st.composite
    def small_tree(draw):
        n = draw(st.integers(1, 4))
        tree = {}
        for i in range(n):
            shape = tuple(draw(st.lists(st.integers(1, 5), min_size=0,
                                        max_size=3)))
            tree[f"p{i}"] = np.asarray(
                draw(st.lists(st.floats(-2, 2, width=32),
                              min_size=int(np.prod(shape) or 1),
                              max_size=int(np.prod(shape) or 1))),
                np.float32).reshape(shape)
        return tree

    @settings(max_examples=25, deadline=None)
    @given(small_tree())
    def test_packer_roundtrip(tree):
        _check_packer_roundtrip(tree)

    @settings(max_examples=20, deadline=None)
    @given(st.floats(0.001, 0.5), st.floats(0.0, 0.99))
    def test_rho_zero_is_momentum_sgd(eta, mu):
        _check_rho_zero_is_momentum_sgd(eta, mu)


def test_packer_roundtrip_deterministic():
    """hypothesis-free coverage of the roundtrip (incl. default alignment
    and scalar/empty-shape leaves)."""
    rng = np.random.RandomState(0)
    tree = {"w": rng.randn(3, 4).astype(np.float32),
            "b": rng.randn(7).astype(np.float32),
            "s": np.float32(1.5)}
    _check_packer_roundtrip(tree, align=8)
    # default alignment = the Pallas elastic-update tile (shared constant)
    from repro.core.packing import ELASTIC_UPDATE_BLOCK
    pk = _check_packer_roundtrip({"w": jnp.ones((5, 3))}, align=None)
    assert pk.align == ELASTIC_UPDATE_BLOCK
    assert pk.buffer_size == ELASTIC_UPDATE_BLOCK  # padded to one full tile


def test_rho_zero_is_momentum_sgd_deterministic():
    for eta, mu in ((0.01, 0.0), (0.1, 0.9), (0.5, 0.99)):
        _check_rho_zero_is_momentum_sgd(eta, mu)


def test_center_update_forms_agree():
    """Eq 2 via sum, via mean, and via P sequential single-worker updates
    agree (single-worker form composes only to first order — use the exact
    sum/mean pair)."""
    cfg = EASGDConfig(eta=0.1, rho=0.2)
    P_ = 4
    rng = np.random.RandomState(0)
    ws = [jnp.asarray(rng.randn(5), jnp.float32) for _ in range(P_)]
    center = jnp.asarray(rng.randn(5), jnp.float32)
    s = easgd.center_update_from_sum(center, sum(ws), P_, cfg)
    m = easgd.center_update_from_mean(center, sum(ws) / P_, P_, cfg)
    tree_allclose(s, m)


def test_fused_flat_matches_tensor_rules():
    cfg = EASGDConfig(eta=0.05, rho=0.1, mu=0.9)
    rng = np.random.RandomState(1)
    n, P_ = 64, 3
    w, v, g, c = (jnp.asarray(rng.randn(n), jnp.float32) for _ in range(4))
    mean_w = jnp.asarray(rng.randn(n), jnp.float32)
    w2, v2, c2 = easgd.fused_elastic_step_flat(w, v, g, c, mean_w, P_, cfg)
    v_ref = cfg.mu * v - cfg.eta * g
    w_ref = w + v_ref - cfg.eta * cfg.rho * (w - c)
    c_ref = c + cfg.alpha * P_ * (mean_w - c)
    tree_allclose((w2, v2, c2), (w_ref, v_ref, c_ref))


@pytest.mark.parametrize("compression_name", ["none", "bf16", "sign_ef"])
def test_packed_unpacked_equivalence(compression_name):
    """The packed shard_map exchange == per-tensor reference (exact for
    'none'; compression changes numerics by design, so only 'none' is
    exact)."""
    cfg_kw = dict(easgd=EASGDConfig(eta=0.05, rho=0.1, mu=0.9))
    params = {"w": jnp.arange(12, dtype=jnp.float32).reshape(3, 4) / 10,
              "b": jnp.ones((4,))}
    st_ = elastic_init(ElasticConfig(**cfg_kw), 0) if False else None
    cfg_u = ElasticConfig(packed=False, **cfg_kw)
    cfg_p = ElasticConfig(packed=True, compression=compression_name,
                          **cfg_kw)
    state = elastic_init(params, cfg_u, n_pods=2)
    grads = jax.tree_util.tree_map(
        lambda x: jnp.full_like(x, 0.2).at[0].set(-0.1), state.params)
    mesh = auto_mesh((1, 1), ("data", "model"))
    from jax.sharding import PartitionSpec as P
    pspecs = {"w": P(), "b": P()}
    out_u = elastic_apply_gradients(state, grads, cfg_u)
    state_p = elastic_init(params, cfg_p, n_pods=2)
    out_p = elastic_apply_gradients(state_p, grads, cfg_p, mesh=mesh,
                                    param_specs=pspecs, pod_axis=None)
    if compression_name == "none":
        tree_allclose(out_u.params, out_p.params)
        tree_allclose(out_u.center, out_p.center)
    else:
        # compressed exchange must still move the center toward the mean
        for k in params:
            assert np.all(np.isfinite(np.asarray(out_p.params[k])))


def test_tau_period():
    """τ=3: center only updates on steps 0, 3, 6, ..."""
    cfg = ElasticConfig(easgd=EASGDConfig(eta=0.1, rho=0.1, mu=0.0, tau=3),
                        packed=False)
    params = {"w": jnp.ones((2, 2))}
    state = elastic_init(params, cfg, n_pods=2)
    grads = {"w": jnp.stack([jnp.full((2, 2), 1.0),
                             jnp.full((2, 2), -0.4)])}
    centers = []
    for _ in range(6):
        state = elastic_apply_gradients(state, grads, cfg)
        centers.append(np.asarray(state.center["w"]).copy())
    # steps 1,2 (no exchange): center frozen; step 3 (step%3==0): moves
    assert np.allclose(centers[1], centers[0])
    assert np.allclose(centers[2], centers[1])
    assert not np.allclose(centers[3], centers[2])


def test_sign_ef_error_feedback_converges():
    """With error feedback, the compressed mean tracks the true mean: the
    accumulated EF error stays bounded while the center approaches the
    workers' mean."""
    cfg = ElasticConfig(easgd=EASGDConfig(eta=0.2, rho=0.5, mu=0.0),
                        packed=True, compression="sign_ef")
    params = {"w": jnp.zeros((16,))}
    state = elastic_init(params, cfg, n_pods=2)
    # workers pinned apart by antisymmetric gradients; center should stay ~0
    grads = {"w": jnp.stack([jnp.ones(16), -jnp.ones(16)])}
    mesh = auto_mesh((1, 1), ("data", "model"))
    from jax.sharding import PartitionSpec as P
    for _ in range(10):
        state = elastic_apply_gradients(state, grads, cfg, mesh=mesh,
                                        param_specs={"w": P()},
                                        pod_axis=None)
    assert np.all(np.abs(np.asarray(state.center["w"])) < 1.0)
    assert np.all(np.isfinite(np.asarray(state.ef_error["w"])))


def test_consensus_contraction():
    """Pure elastic dynamics (zero grads): workers and center contract
    toward each other (the EASGD stability condition)."""
    cfg = ElasticConfig(easgd=EASGDConfig(eta=0.5, rho=0.5, mu=0.0),
                        packed=False)
    params = {"w": jnp.zeros((8,))}
    state = elastic_init(params, cfg, n_pods=3)
    # spread the workers out
    spread = jnp.stack([jnp.full((8,), -1.0), jnp.zeros((8,)),
                        jnp.full((8,), 1.0)])
    state = state._replace(params={"w": spread})
    zeros = {"w": jnp.zeros_like(spread)}
    def spread_of(s):
        return float(jnp.max(jnp.abs(
            s.params["w"] - s.center["w"][None])))
    s0 = spread_of(state)
    for _ in range(5):
        state = elastic_apply_gradients(state, zeros, cfg)
    assert spread_of(state) < s0
