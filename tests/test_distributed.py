"""Multi-device integration tests (subprocess with 8 host devices):
collective schedule equivalence, sharded train-step vs reference, sharded
serve vs reference, multi-pod EASGD semantics, reduced-mesh dry-run smoke."""
import pytest


def test_collective_schedules_equal_psum(subproc):
    subproc("""
        import warnings; warnings.filterwarnings('ignore')
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import collectives
        from repro.utils.jaxcompat import auto_mesh
        mesh = auto_mesh((8,), ('x',))
        x = jnp.arange(64, dtype=jnp.float32) * 0.25 - 3.0
        for algo in ['psum', 'butterfly', 'ring', 'round_robin']:
            out = collectives.shard_map_allreduce(mesh, x, 'x', algo)
            np.testing.assert_allclose(np.asarray(out)[0],
                                       np.asarray(x) * 8, rtol=1e-6)
        print('collectives OK')
    """)


def test_hierarchical_allreduce(subproc):
    subproc("""
        import warnings; warnings.filterwarnings('ignore')
        import jax, jax.numpy as jnp, numpy as np
        from functools import partial
        from jax.sharding import PartitionSpec as P
        from repro.core import collectives
        from repro.utils.jaxcompat import auto_mesh, shard_map
        mesh = auto_mesh((2, 4), ('pod', 'data'))
        @partial(shard_map, mesh=mesh, in_specs=P(('pod', 'data')),
                 out_specs=P(('pod', 'data')), check_vma=False)
        def f(x):
            # local shard is this device's 16-element row
            return collectives.hierarchical_allreduce(
                x, 'data', 'pod', inner='ring', outer='butterfly')
        x = jnp.arange(8 * 16, dtype=jnp.float32).reshape(8, 16)
        out = f(x.reshape(-1))
        want = x.sum(0)
        np.testing.assert_allclose(np.asarray(out).reshape(8, 16)[0], want,
                                   rtol=1e-6)
        print('hierarchical OK')
    """)


def test_multipod_train_step_matches_reference(subproc):
    subproc("""
        import warnings; warnings.filterwarnings('ignore')
        import jax, jax.numpy as jnp, numpy as np
        from repro import configs
        from repro.core.easgd import EASGDConfig
        from repro.core.elastic import ElasticConfig
        from repro.core import elastic
        from repro.runtime.train import build_train_step
        from repro.models import transformer as tfm
        from repro.models.common import init_params

        from repro.utils.jaxcompat import auto_mesh
        mesh = auto_mesh((2, 2, 2), ('pod', 'data', 'model'))
        cfg = configs.get('gemma3-4b').reduced
        ecfg = ElasticConfig(easgd=EASGDConfig(eta=0.05, rho=0.02, mu=0.9),
                             packed=True)
        build = build_train_step(cfg, ecfg, mesh, n_pods=2, per_pod_batch=4,
                                 seq=16, microbatches=2)
        state = build.init_state()
        key = jax.random.PRNGKey(7)
        tokens = jax.random.randint(key, (2, 4, 16), 0, cfg.vocab_size)
        batch = {'tokens': tokens, 'targets': jnp.roll(tokens, -1, -1),
                 'mask': jnp.ones((2, 4, 16), jnp.float32)}
        state1, metrics = build.step(state, batch)
        assert np.isfinite(metrics['loss'])

        # reference: unsharded, no microbatching, unpacked exchange
        params = init_params(tfm.model_defs(cfg), jax.random.PRNGKey(0),
                             cfg.param_dtype)
        st_ref = elastic.init(params, ecfg, 2)
        gfn = jax.vmap(jax.value_and_grad(
            lambda p, b: tfm.lm_loss(cfg, p, b), has_aux=True))
        (_, _), grads = gfn(st_ref.params, batch)
        st_ref1 = elastic.apply_gradients(
            st_ref, grads, ElasticConfig(easgd=ecfg.easgd, packed=False))
        err = max(float(jnp.max(jnp.abs(
            a.astype(jnp.float32) - b.astype(jnp.float32))))
            for a, b in zip(jax.tree_util.tree_leaves(state1.params),
                            jax.tree_util.tree_leaves(st_ref1.params)))
        assert err < 5e-3, err   # bf16 reduction-order noise only
        print('multipod train OK, err', err)
    """, timeout=1200)


def test_sharded_serve_matches_reference(subproc):
    subproc("""
        import warnings; warnings.filterwarnings('ignore')
        import dataclasses
        import jax, jax.numpy as jnp, numpy as np
        from repro import configs
        from repro.runtime.serve import build_serve_steps
        from repro.models import transformer as tfm
        from repro.models.common import init_params

        from repro.utils.jaxcompat import auto_mesh
        mesh = auto_mesh((4, 2), ('data', 'model'))
        cfg = dataclasses.replace(configs.get('deepseek-v2-236b').reduced,
                                  compute_dtype=jnp.float32)
        B, L = 8, 32
        build = build_serve_steps(cfg, mesh, batch=B, max_len=L)
        params = init_params(tfm.model_defs(cfg), jax.random.PRNGKey(0),
                             cfg.param_dtype)
        toks = jax.random.randint(jax.random.PRNGKey(1), (B, L - 4), 0,
                                  cfg.vocab_size)
        logits, caches = build.prefill(params, toks, {})
        pos = jnp.full((B,), L - 4, jnp.int32)
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        logits2, caches = build.decode(params, caches, tok, pos, {})
        caches_ref = tfm.init_caches(cfg, B, L)
        lg_ref, caches_ref = tfm.prefill(cfg, params, toks, caches_ref)
        lg2_ref, _ = tfm.decode_step(cfg, params, tok, caches_ref, pos)
        np.testing.assert_allclose(np.asarray(logits), np.asarray(lg_ref),
                                   rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(logits2), np.asarray(lg2_ref),
                                   rtol=1e-4, atol=1e-4)
        print('sharded serve OK')
    """, timeout=1200)


def test_dryrun_smoke_reduced_mesh(subproc):
    """lower+compile reduced configs for train & decode on an 8-dev
    multi-pod mesh, with memory/cost/collective extraction — the dry-run
    machinery end-to-end."""
    subproc("""
        import warnings; warnings.filterwarnings('ignore')
        import jax, jax.numpy as jnp
        from repro import configs
        from repro.core.easgd import EASGDConfig
        from repro.core.elastic import ElasticConfig
        from repro.runtime.train import build_train_step, make_batch_defs
        from repro.runtime.serve import build_serve_steps
        from repro.launch import hloparse

        from repro.utils.jaxcompat import auto_mesh
        mesh = auto_mesh((2, 2, 2), ('pod', 'data', 'model'))
        for aid in ['recurrentgemma-2b', 'grok-1-314b']:
            cfg = configs.get(aid).reduced
            build = build_train_step(
                cfg, ElasticConfig(easgd=EASGDConfig()), mesh, n_pods=2,
                per_pod_batch=4, seq=16, microbatches=2)
            lowered = build.step.lower(build.abstract_state,
                                       make_batch_defs(cfg, 2, 4, 16))
            compiled = lowered.compile()
            ma = compiled.memory_analysis()
            assert ma.temp_size_in_bytes >= 0
            pc = hloparse.parse_costs(compiled.as_text())
            assert pc.flops > 0
            print(aid, 'train lower+compile OK, collective bytes',
                  pc.collective_bytes)

        cfg = configs.get('mamba2-780m').reduced
        from repro.utils.jaxcompat import auto_mesh
        mesh2 = auto_mesh((4, 2), ('data', 'model'))
        sb = build_serve_steps(cfg, mesh2, batch=8, max_len=64)
        tok = jax.ShapeDtypeStruct((8, 1), jnp.int32)
        pos = jax.ShapeDtypeStruct((8,), jnp.int32)
        compiled = sb.decode.lower(sb.abstract_params, sb.abstract_caches,
                                   tok, pos, {}).compile()
        print('decode lower+compile OK')
    """, timeout=1800)
