"""repro.obs — distributed tracing, clock alignment, and the measured
time breakdown.

The contract under test:

 1. ``obs.trace`` hot path: preallocated, lock-free, never grows past
    capacity (drops instead), and costs NOTHING when tracing is off —
    no tracer is ever created (the registry stays empty).
 2. ``obs.clock``: the min-RTT estimator recovers a known synthetic
    offset exactly, and over a real socket pair |offset| ≤ rtt.
 3. ``obs.report``: merging shifts worker spans by their clock offset
    onto the master timeline; ``breakdown`` reproduces the Table-3
    accounting; the Chrome export round-trips as JSON with one pid per
    worker.
 4. End to end on the runtime: traced runs on every transport produce a
    merged timeline (thread registry, process spill files, tcp BYE
    payloads with real clock sync), spans are monotone and
    non-overlapping per thread, heartbeat-piggybacked telemetry reaches
    the master's counters, and — the invariant that matters — tracing
    NEVER changes the math: thread-off, thread-on and tcp-p2p-on runs
    stay bitwise identical.
"""
import json
import socket
import threading

import numpy as np
import pytest

from repro import ps
from repro.core import costmodel
from repro.core.easgd import EASGDConfig
from repro.obs import clock as obs_clock
from repro.obs import metrics as obs_metrics
from repro.obs import report as obs_report
from repro.obs import trace as obs_trace

NET = costmodel.Network("test-net", 2e-6, 1 / 10e9)
CFG = EASGDConfig(eta=0.05, rho=0.07, mu=0.9)


# ---------------------------------------------------------------------------
# (1) obs.trace — the hot path
# ---------------------------------------------------------------------------

def test_tracer_prealloc_and_overflow_drops():
    t = obs_trace.Tracer("main", wid=3, capacity=4)
    for i in range(6):
        t.record(obs_trace.COMPUTE, float(i), float(i) + 0.5, arg=i)
    assert t.n == 4 and t.dropped == 2
    spans = t.spans()
    assert spans == [[obs_trace.COMPUTE, float(i), float(i) + 0.5, i]
                     for i in range(4)]
    # wire form must be plain JSON scalars (BYE carries it verbatim)
    assert json.loads(json.dumps(spans)) == spans


def test_registry_drain_and_stats():
    obs_trace.drain()
    a = obs_trace.tracer("main", wid=0, capacity=8)
    b = obs_trace.tracer("comm", wid=0, capacity=8)
    a.record(obs_trace.COMPUTE, 0.0, 1.0)
    st = obs_trace.stats()
    assert st["tracers"] == 2 and st["records"] == 1 and st["dropped"] == 0
    drained = obs_trace.drain()
    assert {t.name for t in drained} == {"main", "comm"}
    assert b in drained
    assert obs_trace.stats() == {"tracers": 0, "records": 0, "dropped": 0}


def test_spill_roundtrip_creates_missing_dir(tmp_path):
    payload = {"clock": {"offset_s": 0.1, "rtt_s": 0.2},
               "threads": {"main": [[0, 1.0, 2.0, 0]]}, "dropped": 0}
    path = obs_trace.dump_spill(str(tmp_path / "deep" / "dir"), 5, payload)
    assert path.endswith("trace-w5.json")
    assert obs_trace.load_spill(path) == payload


def test_metrics_registry_and_count_round():
    reg = obs_metrics.Registry()
    reg.add("wire_bytes", 100)
    reg.add("wire_bytes", 50)
    reg.set("hb_staleness_max_s", 1.5)
    # adoption: an externally-owned cell joins under a name, unchanged
    ext = obs_metrics.Slot(7)
    assert reg.counter("messages", cell=ext) is ext
    reg["messages"].value += 1
    assert ext.value == 8
    snap = reg.snapshot()
    assert snap["wire_bytes"] == 150 and snap["hb_staleness_max_s"] == 1.5
    assert "messages" in reg and len(reg) == 3

    # count_round: one round = 1 sync_round, len(rnd) messages, Σ frac·n·8
    class _Msg:
        def __init__(self, frac):
            self.frac = frac
    counters = obs_metrics.Registry()
    for name in ("sync_rounds", "messages", "wire_bytes"):
        counters.counter(name)
    obs_metrics.count_round(counters, [_Msg(0.5), _Msg(0.25)], 1000)
    assert counters.snapshot() == {
        "sync_rounds": 1, "messages": 2,
        "wire_bytes": int(0.75 * 1000 * 8)}


# ---------------------------------------------------------------------------
# (2) obs.clock
# ---------------------------------------------------------------------------

def test_clock_combine_recovers_known_offset_at_min_rtt():
    # symmetric exchange: tm = t0 + rtt/2 + offset; keep the min-rtt sample
    good = (10.0, 10.05 + 1.5, 10.1)     # rtt 0.1, offset +1.5
    noisy = (20.0, 20.25 + 9.9, 20.5)    # rtt 0.5 — queueing-inflated
    cs = obs_clock.combine([noisy, good])
    assert cs.offset_s == pytest.approx(1.5)
    assert cs.rtt_s == pytest.approx(0.1)
    assert cs.probes == 2
    assert json.loads(json.dumps(cs.to_wire()))["offset_s"] == cs.offset_s


def test_clock_sync_over_real_link_offset_bounded_by_rtt():
    from repro.net import wire
    a, b = socket.socketpair()
    la, lb = wire.Link(a), wire.Link(b)

    def _echo(n):
        for _ in range(n):
            obs_clock.answer(lb, lb.recv_header(), wid=0)

    th = threading.Thread(target=_echo, args=(5,), daemon=True)
    th.start()
    cs = obs_clock.sync_over_link(la, wid=0, probes=5)
    th.join(timeout=5)
    # same process, same perf_counter: the true offset is 0, the estimate
    # is bounded by half the observed round trip
    assert cs.probes == 5 and cs.rtt_s > 0
    assert abs(cs.offset_s) <= cs.rtt_s
    la.close(), lb.close()


# ---------------------------------------------------------------------------
# (3) obs.report — merge, breakdown, Chrome export (pure)
# ---------------------------------------------------------------------------

def _payload(offset, spans, rtt=0.01):
    return {"clock": {"offset_s": offset, "rtt_s": rtt},
            "threads": {"main": spans}, "dropped": 0}


def test_merge_shifts_spans_onto_master_clock():
    spans = [[obs_trace.COMPUTE, 0.0, 1.0, 0]]
    merged = obs_report.merge_traces(
        {0: _payload(2.0, spans), 1: _payload(-1.0, spans)},
        master={"threads": {"serve": [[obs_trace.EVAL, 5.0, 5.1, 0]]}})
    assert merged["workers"][0]["threads"]["main"][0][1:3] == [2.0, 3.0]
    assert merged["workers"][1]["threads"]["main"][0][1:3] == [-1.0, 0.0]
    # master spans ride along unshifted
    assert merged["master"]["threads"]["serve"][0][1:3] == [5.0, 5.1]


def test_breakdown_table3_accounting():
    spans = [[obs_trace.COMPUTE, 0.0, 1.0, 0],
             [obs_trace.COMM_WAIT, 1.0, 1.5, 0],
             [obs_trace.UPDATE, 1.5, 1.6, -1],
             # comm-busy: overlaps compute, must NOT enter the shares
             [obs_trace.EXCHANGE, 0.2, 0.9, 0]]
    rep = obs_report.breakdown(obs_report.merge_traces({0: _payload(0, spans)}))
    w = rep["workers"][0]
    assert w["wall_s"] == pytest.approx(1.6)
    assert w["compute_share"] == pytest.approx(1.0 / 1.6, abs=1e-3)
    assert w["comm_share"] == pytest.approx(0.5 / 1.6, abs=1e-3)
    assert w["update_share"] == pytest.approx(0.1 / 1.6, abs=1e-3)
    assert w["comm_busy_s"] == pytest.approx(0.7)
    assert rep["mean_comm_share"] == w["comm_share"]


def test_chrome_trace_exports_one_pid_per_worker():
    spans = [[obs_trace.COMPUTE, 1.0, 2.0, 0]]
    merged = obs_report.merge_traces(
        {0: _payload(0.0, spans), 1: _payload(0.0, spans)},
        master={"threads": {"serve": [[obs_trace.EVAL, 1.0, 1.1, 0]]}})
    ct = json.loads(json.dumps(obs_report.chrome_trace(merged)))
    xs = [e for e in ct["traceEvents"] if e["ph"] == "X"]
    assert {e["pid"] for e in xs} == {0, 1, 9999}
    assert all(e["ts"] >= 0 and e["dur"] > 0 for e in xs)
    names = {e["args"]["name"] for e in ct["traceEvents"]
             if e["name"] == "process_name"}
    assert names == {"worker 0", "worker 1", "master"}


# ---------------------------------------------------------------------------
# (4) the runtime, traced — every transport
# ---------------------------------------------------------------------------

def _run(algo, transport, iters=24, P=2, **kw):
    kw.setdefault("eval_every_iters", 10**9)
    cfg = ps.PSConfig(algorithm=algo, n_workers=P, total_iters=iters,
                      transport=transport, schedule="ring", **kw)
    return ps.run_ps(ps.NUMPY_MLP, CFG, cfg)


def test_tracing_off_is_the_default_and_costs_nothing():
    obs_trace.drain()
    res = _run("sync_easgd", "thread", emulate_net=NET)
    assert res.trace is None
    # no tracer was ever created — the registry IS the disabled state
    assert obs_trace.stats() == {"tracers": 0, "records": 0, "dropped": 0}


def test_thread_trace_spans_monotone_and_report_sane():
    res = _run("sync_easgd", "thread", emulate_net=NET, trace=True)
    assert res.trace is not None
    assert set(res.trace["workers"]) == {0, 1}
    for w in res.trace["workers"].values():
        spans = w["threads"]["main"]
        assert len(spans) > 0
        kinds = {s[0] for s in spans}
        assert obs_trace.COMPUTE in kinds and obs_trace.BARRIER in kinds
        for k, t0, t1, _arg in spans:
            assert t1 >= t0
        # a thread's spans are sequential code sections: non-overlapping,
        # recorded in time order
        for prev, cur in zip(spans, spans[1:]):
            assert cur[1] >= prev[2] - 1e-9
    # the comm executor's EXCHANGE spans live on the master side, disjoint
    ex = [s for s in res.trace["master"]["threads"]["comm"]
          if s[0] == obs_trace.EXCHANGE]
    assert len(ex) >= 2
    for prev, cur in zip(ex, ex[1:]):
        assert cur[1] >= prev[2] - 1e-9
    rep = res.trace["report"]
    assert 0.0 < rep["mean_compute_share"] <= 1.0
    assert 0.0 <= rep["mean_comm_share"] <= 1.0


def test_process_transport_spills_and_merges(tmp_path):
    res = _run("async_easgd", "process", iters=60, trace=True,
               trace_dir=str(tmp_path))
    assert res.trace is not None
    assert set(res.trace["workers"]) == {0, 1}
    for wid in (0, 1):
        # the spill file is the cross-process trace carrier
        spill = obs_trace.load_spill(obs_trace.spill_path(str(tmp_path), wid))
        assert spill["threads"]["main"]
        assert res.trace["workers"][wid]["threads"]["main"]
    assert "report" in res.trace


def test_tcp_trace_real_clock_sync_and_recv_wait():
    res = _run("sync_easgd", "tcp", trace=True, emulate_net=NET)
    assert res.trace is not None and set(res.trace["workers"]) == {0, 1}
    for w in res.trace["workers"].values():
        # real clock estimate from the rendezvous probes: loopback rtt is
        # positive and the offset error is bounded by it
        assert w["rtt_s"] > 0
        assert abs(w["offset_s"]) <= w["rtt_s"]
        kinds = {s[0] for s in w["threads"]["main"]}
        assert obs_trace.COMPUTE in kinds and obs_trace.RECV_WAIT in kinds
    # every worker's α observation surfaced from the same probes
    assert set(res.counters["link_alpha_s"]) == {0, 1}
    ct = obs_report.chrome_trace(res.trace)
    assert {e["pid"] for e in ct["traceEvents"]
            if e["ph"] == "X"} >= {0, 1}


def test_heartbeat_telemetry_reaches_master_counters():
    res = _run("async_easgd", "tcp", iters=240,
               emulate_net=costmodel.PS_WIRE, hb_interval_s=0.05)
    telem = res.counters["worker_telemetry"]
    assert set(telem) <= {0, 1} and len(telem) >= 1
    for t in telem.values():
        assert t["iters"] >= 0 and t["rate_ips"] >= 0


def test_traced_runs_stay_bitwise_identical():
    """The guard satellite: tracing must never perturb the math. Thread
    with tracing off, thread with tracing on, and tcp p2p with tracing on
    produce bit-identical float64 weights under deterministic admission."""
    kw = dict(iters=48, deterministic=True)
    off = _run("sync_easgd", "thread", **kw)
    on = _run("sync_easgd", "thread", trace=True, **kw)
    p2p = _run("sync_easgd", "tcp", trace=True, sync_plane="p2p", **kw)
    assert off.total_iters == on.total_iters == p2p.total_iters
    np.testing.assert_array_equal(off.center, on.center)
    np.testing.assert_array_equal(off.center, p2p.center)
    np.testing.assert_array_equal(off.workers, on.workers)
    np.testing.assert_array_equal(off.workers, p2p.workers)
    assert on.trace is not None and p2p.trace is not None


def test_bucketed_p2p_trace_bitwise_and_exposed_matches_counter():
    """Bucketed-overlap p2p with tracing on: still bitwise vs monolithic
    thread (tracing off), and the span-measured exposed-comm agrees with
    the BYE ``exposed_s`` counter — two independent accountings of the
    same waits (the CI smoke pins the same invariant)."""
    kw = dict(iters=24, deterministic=True)
    mono = _run("sync_easgd", "thread", **kw)
    res = _run("sync_easgd", "tcp", sync_plane="p2p", trace=True,
               bucket_bytes=4096, overlap=True,
               emulate_net=costmodel.PS_WIRE, **kw)
    np.testing.assert_array_equal(mono.center, res.center)
    np.testing.assert_array_equal(mono.workers, res.workers)
    span_exposed = sum(w["exposed_comm_s"]
                       for w in res.trace["report"]["workers"].values())
    counter_exposed = res.counters["exposed_s"]
    assert counter_exposed > 0
    assert span_exposed == pytest.approx(counter_exposed,
                                         rel=0.25, abs=0.02)
    # the per-bucket comm-thread spans made it home too
    comm_kinds = set()
    for w in res.trace["workers"].values():
        for s in w["threads"].get("comm", []):
            comm_kinds.add(s[0])
    assert obs_trace.BUCKET in comm_kinds
