"""Per-arch smoke tests (REDUCED configs, one fwd/train step on CPU, shape +
finiteness assertions) and serve-path equivalence."""
import warnings

warnings.filterwarnings("ignore")

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import transformer as tfm
from repro.models.common import init_params

ARCH_IDS = sorted(configs.ARCHS)


def _batch_for(cfg, key, B=2, S=24):
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": tokens, "targets": jnp.roll(tokens, -1, 1),
             "mask": jnp.ones((B, S), jnp.float32)}
    if cfg.mrope_sections is not None:
        batch["mrope_positions"] = jnp.broadcast_to(
            jnp.arange(S)[None, None], (3, B, S)).astype(jnp.int32)
    if cfg.patch_embed_tokens:
        batch["patch_embeds"] = 0.02 * jax.random.normal(
            key, (B, cfg.patch_embed_tokens, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_arch_smoke_forward_and_grad(arch_id):
    """Reduced config: loss finite, grads finite, logits shaped (B,S?,V)."""
    spec = configs.get(arch_id)
    cfg = spec.reduced
    params = init_params(tfm.model_defs(cfg), jax.random.PRNGKey(0),
                         cfg.param_dtype)
    batch = _batch_for(cfg, jax.random.PRNGKey(1))
    loss, metrics = tfm.lm_loss(cfg, params, batch)
    assert np.isfinite(float(loss)), (arch_id, loss)
    assert 0.0 <= float(metrics["accuracy"]) <= 1.0
    h, _, _ = tfm.forward(cfg, params, batch["tokens"],
                          mrope_positions=batch.get("mrope_positions"),
                          patch_embeds=batch.get("patch_embeds"))
    assert h.shape == batch["tokens"].shape + (cfg.d_model,)
    logits = tfm.logits_at(cfg, params, h[:, -1])
    assert logits.shape == (batch["tokens"].shape[0], cfg.vocab_size)

    grads, _ = jax.grad(lambda p: tfm.lm_loss(cfg, p, batch),
                        has_aux=True)(params)
    for leaf in jax.tree_util.tree_leaves(grads):
        assert np.all(np.isfinite(np.asarray(leaf, np.float32))), arch_id


@pytest.mark.parametrize("arch_id", ["gemma3-4b", "mamba2-780m",
                                     "recurrentgemma-2b",
                                     "deepseek-v2-236b", "phi3-mini-3.8b"])
def test_decode_matches_teacher_forcing(arch_id):
    """prefill + step-by-step decode == full forward (fp32, no MoE drops)."""
    spec = configs.get(arch_id)
    cfg = dataclasses.replace(spec.reduced, compute_dtype=jnp.float32)
    if cfg.moe is not None:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    params = init_params(tfm.model_defs(cfg), jax.random.PRNGKey(0),
                         jnp.float32)
    B, S = 2, 24
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                cfg.vocab_size)
    h, _, _ = tfm.forward(cfg, params, tokens)
    ref = tfm.logits_at(cfg, params, h[:, -1])
    Sp = S - 4
    caches = tfm.init_caches(cfg, B, max_len=S)
    lg, caches = tfm.prefill(cfg, params, tokens[:, :Sp], caches)
    for t in range(Sp, S):
        pos = jnp.full((B,), t, jnp.int32)
        lg, caches = tfm.decode_step(cfg, params, tokens[:, t:t + 1],
                                     caches, pos)
    np.testing.assert_allclose(np.asarray(lg), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


def test_all_cells_accounted():
    """40 assigned cells; skips only long_500k on pure full-attention archs."""
    cells = configs.cells()
    assert len(cells) == 40
    skipped = [(a, s) for a, s, ok in cells if not ok]
    assert all(s == "long_500k" for _, s in skipped)
    assert {a for a, _ in skipped} == {
        "qwen1.5-4b", "phi3-mini-3.8b", "qwen2-vl-72b", "musicgen-medium",
        "grok-1-314b", "deepseek-v2-236b"}
    assert sum(ok for _, _, ok in cells) == 34


def test_full_configs_match_assignment():
    """Pin the published numbers (guards accidental config drift)."""
    c = configs.get("gemma3-4b").config
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab_size) == (34, 2560, 8, 4, 10240, 262144)
    c = configs.get("deepseek-v2-236b").config
    assert (c.n_layers, c.d_model, c.n_heads, c.vocab_size) == \
        (60, 5120, 128, 102400)
    assert (c.moe.n_experts, c.moe.top_k, c.moe.n_shared) == (160, 6, 2)
    assert c.mla.kv_lora_rank == 512
    c = configs.get("grok-1-314b").config
    assert (c.n_layers, c.d_model, c.d_ff, c.moe.n_experts, c.moe.top_k) \
        == (64, 6144, 32768, 8, 2)
    c = configs.get("mamba2-780m").config
    assert (c.n_layers, c.d_model, c.ssm.d_state) == (48, 1536, 128)
    c = configs.get("qwen2-vl-72b").config
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff) == \
        (80, 8192, 64, 8, 29568)
    assert sum(c.mrope_sections) == c.head_dim // 2


def test_param_counts_close_to_published():
    """Total parameter counts should be near the nameplate sizes.
    (Re-implemented here — importing launch.dryrun would set XLA_FLAGS.)"""
    import jax as _jax

    def count(cfg):
        defs = tfm.model_defs(cfg)
        leaves = _jax.tree_util.tree_leaves(
            defs, is_leaf=lambda x: hasattr(x, "logical"))
        total = 0
        for d in leaves:
            n = 1
            for s in d.shape:
                n *= s
            total += n
        return total

    for arch_id, nominal, tol in [
        ("gemma3-4b", 4e9, 0.35), ("gemma3-27b", 27e9, 0.25),
        ("qwen1.5-4b", 4e9, 0.3), ("phi3-mini-3.8b", 3.8e9, 0.25),
        ("qwen2-vl-72b", 72e9, 0.25), ("mamba2-780m", 0.78e9, 0.3),
        ("recurrentgemma-2b", 2e9, 0.6), ("grok-1-314b", 314e9, 0.15),
        ("deepseek-v2-236b", 236e9, 0.15),
    ]:
        n = count(configs.get(arch_id).config)
        assert abs(n - nominal) / nominal < tol, (arch_id, n, nominal)
