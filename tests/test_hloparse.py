"""The loop-aware HLO cost parser vs XLA cost_analysis (loop-free graphs)
and vs ground truth on scans."""
import warnings

warnings.filterwarnings("ignore")

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch import hloparse


def _compile(fn, *args):
    return jax.jit(fn).lower(*args).compile()


def test_matches_cost_analysis_loop_free():
    n = 256
    w1 = jnp.ones((n, n))
    w2 = jnp.ones((n, 2 * n))

    def f(x):
        return jax.nn.relu(x @ w1) @ w2

    c = _compile(f, jnp.ones((8, n)))
    ca = c.cost_analysis()
    if isinstance(ca, (list, tuple)):   # jax 0.4.x returns [dict]
        ca = ca[0]
    pc = hloparse.parse_costs(c.as_text())
    np.testing.assert_allclose(pc.flops, ca["flops"], rtol=0.05)


def test_scan_flops_multiplied():
    n, k = 128, 9
    w = jnp.ones((n, n))

    def f(x):
        out, _ = jax.lax.scan(lambda c, _: (c @ w, None), x, None, length=k)
        return out

    c = _compile(f, jnp.ones((n, n)))
    pc = hloparse.parse_costs(c.as_text())
    np.testing.assert_allclose(pc.flops, 2 * n**3 * k, rtol=0.01)
    assert k in pc.while_trip_counts.values()


def test_nested_scan_flops():
    n, ko, ki = 128, 5, 3
    w = jnp.ones((n, n))

    def f(x):
        def outer(c, _):
            c2, _ = jax.lax.scan(lambda c3, _: (c3 @ w, None), c, None,
                                 length=ki)
            return c2, None
        out, _ = jax.lax.scan(outer, x, None, length=ko)
        return out

    c = _compile(f, jnp.ones((n, n)))
    pc = hloparse.parse_costs(c.as_text())
    np.testing.assert_allclose(pc.flops, 2 * n**3 * ko * ki, rtol=0.01)


def test_collectives_counted_with_trips(subproc):
    subproc("""
        import jax, jax.numpy as jnp
        from functools import partial
        from jax.sharding import PartitionSpec as P
        from repro.launch import hloparse
        import numpy as np
        from repro.utils.jaxcompat import auto_mesh, shard_map
        mesh = auto_mesh((8,), ('x',))
        @partial(shard_map, mesh=mesh, in_specs=P('x'), out_specs=P('x'),
                 check_vma=False)
        def body(x):
            def step(c, _):
                return jax.lax.psum(c, 'x') * 0.1, None
            out, _ = jax.lax.scan(step, x, None, length=5)
            return out
        c = jax.jit(body).lower(jnp.ones((8, 1024))).compile()
        pc = hloparse.parse_costs(c.as_text())
        counts = pc.counts_by_collective
        assert counts.get('all-reduce', 0) == 5, counts
        # each all-reduce moves the 1024-float local shard
        assert abs(pc.collective_bytes - 5 * 1024 * 4) < 1e-6, \\
            pc.bytes_by_collective
        print('OK')
    """, n_devices=8)


def test_sign_ef_collective_bytes_by_dtype(subproc):
    """Post-compression wire accounting: a sign-EF exchange's collective
    payload parses as int8 signs (1 byte/element — exactly the model's
    ``jit_wire_bytes_per_element``) plus a scalar f32 scale, so the HLO
    report and ``comm.choose``'s auto decision agree on bytes."""
    subproc("""
        import jax, jax.numpy as jnp
        from functools import partial
        from jax.sharding import PartitionSpec as P
        from repro.launch import hloparse
        from repro import comm
        from repro.core import compression
        from repro.utils.jaxcompat import auto_mesh, shard_map
        mesh = auto_mesh((4,), ('pod',))
        plan = comm.make_plan('psum', 'sign_ef', n_total=4, axis_name='pod')
        n = 4096
        @partial(shard_map, mesh=mesh, in_specs=(P('pod'), P('pod')),
                 out_specs=P('pod'), check_vma=False)
        def body(delta, ef):
            mean, _ = plan.reduce_mean_flat(delta, ef)
            return mean[None]
        x = jnp.ones((4, 1, n)); e = jnp.zeros((4, 1, n))
        c = jax.jit(body).lower(x, e).compile()
        pc = hloparse.parse_costs(c.as_text())
        by_dt = pc.collective_bytes_by_dtype
        assert by_dt.get('s8', 0) == n, by_dt        # signs: 1 byte/element
        assert 0 < by_dt.get('f32', 0) <= 64, by_dt  # the scalar scale
        model = plan.wire_bytes(n)                   # jit accounting
        assert abs(by_dt['s8'] - model) < 1, (by_dt, model)
        print('OK')
    """, n_devices=4)


def test_tensor_bytes_parsing():
    assert hloparse._tensor_bytes_public("f32[128,256]{1,0}") == 128 * 256 * 4
    assert hloparse._tensor_bytes_public(
        "(bf16[8]{0}, s32[2,2]{1,0})") == 16 + 16
    assert hloparse._tensor_bytes_public("pred[]") == 1
