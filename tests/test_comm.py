"""The repro.comm contract: every registered schedule is (a) allreduce-
equivalent to lax.psum on a host device mesh, (b) priced by a cost function
that is monotone in message size, and (c) priced IDENTICALLY by the DES
engine for the same (P, bytes) — one registry, three consumers.
"""
import numpy as np
import pytest

from repro import comm
from repro.core import costmodel
from repro.core.async_engine import PSEngine, SimConfig
from repro.core.easgd import EASGDConfig

NET = costmodel.Network("test-net", 2e-6, 1 / 10e9)


# ---------------------------------------------------------------------------
# (a) runnable: schedule == psum on a real (host) mesh
# ---------------------------------------------------------------------------

def test_every_schedule_equals_psum(subproc):
    subproc("""
        import warnings; warnings.filterwarnings('ignore')
        import jax, jax.numpy as jnp, numpy as np
        from repro import comm
        from repro.utils.jaxcompat import auto_mesh
        mesh = auto_mesh((8,), ('x',))
        x = jnp.arange(96, dtype=jnp.float32) * 0.125 - 3.0
        want = np.asarray(x) * 8
        for algo in comm.names():
            out = comm.shard_map_allreduce(mesh, x, 'x', algo)
            np.testing.assert_allclose(np.asarray(out)[0], want, rtol=1e-6,
                                       err_msg=algo)
        # 'auto' resolves through comm.choose and must also be correct
        out = comm.shard_map_allreduce(mesh, x, 'x', 'auto')
        np.testing.assert_allclose(np.asarray(out)[0], want, rtol=1e-6)
        print('schedules OK')
    """)


def test_exchange_plan_runs_every_schedule(subproc):
    """ExchangePlan.exchange == cross-pod mean for every schedule, on a
    4-pod mesh, called inside shard_map (the runtime's usage pattern)."""
    subproc("""
        import warnings; warnings.filterwarnings('ignore')
        from functools import partial
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro import comm
        from repro.utils.jaxcompat import auto_mesh, shard_map
        mesh = auto_mesh((4,), ('pod',))
        vals = jnp.stack([jnp.full((6,), float(i)) for i in range(4)])
        for name in comm.names():
            plan = comm.make_plan(name, axis_name='pod', n_total=4)
            @partial(shard_map, mesh=mesh, in_specs=P('pod'),
                     out_specs=P('pod'), check_vma=False)
            def f(x):
                tree = {'w': x[0]}
                return plan.exchange(tree)['w'][None]
            out = f(vals)
            want = np.full((6,), 1.5)  # mean of 0,1,2,3
            for row in np.asarray(out):
                np.testing.assert_allclose(row, want, rtol=1e-6,
                                           err_msg=name)
        print('exchange plans OK')
    """)


# ---------------------------------------------------------------------------
# (b) cost functions: monotone in bytes, sane in P
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", list(comm.names()))
def test_cost_monotone_in_bytes(name):
    sched = comm.get(name)
    for p in (2, 4, 8, 16):
        costs = [sched.cost(n, p, NET)
                 for n in (1e2, 1e4, 1e6, 1e8)]
        assert all(c > 0 for c in costs), (name, p, costs)
        assert costs == sorted(costs), (name, p, costs)
    assert sched.cost(1e6, 1, NET) == 0.0  # single participant: free


def test_cost_orderings_match_paper():
    """Θ(P) round-robin must dominate the log/ring schedules at scale, and
    psum (tuned-library best) must be the min of butterfly/ring."""
    n = 4e6
    for p in (4, 16, 64):
        rr = comm.get("round_robin").cost(n, p, NET)
        tree = comm.get("tree").cost(n, p, NET)
        ring = comm.get("ring").cost(n, p, NET)
        bfly = comm.get("butterfly").cost(n, p, NET)
        psum = comm.get("psum").cost(n, p, NET)
        assert rr > tree > bfly, (p, rr, tree, bfly)
        assert psum == min(bfly, ring)


# ---------------------------------------------------------------------------
# (c) the DES engine prices through the SAME registry
# ---------------------------------------------------------------------------

def _engine(n=1000, p=4, schedule="tree"):
    w0 = np.zeros(n)
    sim = SimConfig(n_workers=p, net=NET, compute_jitter=0.0,
                    schedule=schedule, t_compute=1e-6,
                    t_update_per_byte=0.0, eval_every_iters=10**9)
    return PSEngine(lambda w, s, i: np.zeros_like(w), lambda w: 0.0,
                    w0, EASGDConfig(), sim)


@pytest.mark.parametrize("name", list(comm.names()))
def test_engine_exchange_price_is_registry_price(name):
    eng = _engine(schedule=name)
    assert eng.t_exchange() == comm.get(name).cost(eng.nbytes, 4, NET)


@pytest.mark.parametrize("name", list(comm.names()))
def test_sync_sgd_charges_registry_cost_per_step(name):
    """Non-tautological: run the sync loop and check the clock was charged
    exactly steps × registry-cost (sync SGD cannot overlap its all-reduce)."""
    p, steps = 4, 5
    eng = _engine(p=p, schedule=name)
    r = eng.run("sync_sgd", total_iters=p * steps)
    want = steps * comm.get(name).cost(eng.nbytes, p, NET)
    np.testing.assert_allclose(r.breakdown["param_comm"], want, rtol=1e-12)


def test_original_easgd_full_cycle_is_round_robin_cost():
    """P iterations of Original EASGD = one full round-robin cycle under
    the registry's pricing."""
    p = 4
    eng = _engine(p=p, schedule="tree")
    r = eng.run("original_easgd", total_iters=p)
    want = comm.get("round_robin").cost(eng.nbytes, p, NET)
    np.testing.assert_allclose(r.breakdown["param_comm"], want, rtol=1e-12)


# ---------------------------------------------------------------------------
# plan-level wire accounting
# ---------------------------------------------------------------------------

def test_plan_compression_shrinks_wire_and_cost():
    none = comm.make_plan("ring", "none", n_total=8)
    sign = comm.make_plan("ring", "sign_ef", n_total=8)
    n_elems = 1_000_000
    # jit accounting: signs cross the mesh as int8 (the in-flight sum must
    # address them) — 4x fewer bytes than f32, matching the compiled HLO
    assert sign.wire_bytes(n_elems) == pytest.approx(
        none.wire_bytes(n_elems) / 4, rel=1e-6)
    # framed accounting: the repro.net byte-stream wire bit-packs for real
    assert sign.framed_wire_bytes(n_elems) < \
        none.framed_wire_bytes(n_elems) / 8
    assert sign.cost_s(n_elems, NET) < none.cost_s(n_elems, NET)


def test_plan_overlap_hides_comm():
    plan = comm.make_plan("tree", overlap=True, n_total=8)
    blocking = comm.make_plan("tree", overlap=False, n_total=8)
    n_elems = 1_000_000
    t = plan.cost_s(n_elems, NET)
    assert plan.visible_cost_s(n_elems, NET, t_compute=2 * t) == 0.0
    assert blocking.visible_cost_s(n_elems, NET, t_compute=2 * t) == t


# ---------------------------------------------------------------------------
# first-class hierarchical schedule + ElasticConfig schedule="auto"
# ---------------------------------------------------------------------------

def test_hierarchical_is_registered_and_selectable():
    assert "hierarchical" in comm.names()
    from repro.core.elastic import ElasticConfig
    cfg = ElasticConfig(schedule="hierarchical")
    plan = cfg.exchange_plan("pod", 8)
    assert plan.schedule.name == "hierarchical"
    # pow2-only constraint surfaces at plan build, not deep in tracing
    with pytest.raises(ValueError, match="power-of-two"):
        comm.make_plan("hierarchical", axis_name="pod", n_total=6)


def test_elastic_auto_schedule_resolution():
    """schedule='auto' resolves through comm.choose from the packed wire
    bytes and pod count at build time (latency-bound → butterfly,
    bandwidth-bound → ring), and stays lazy without a buffer size."""
    from repro.core import costmodel
    from repro.core.elastic import ElasticConfig
    cfg = ElasticConfig(schedule="auto")
    assert cfg.resolve_schedule(8, 100) == "butterfly"
    assert cfg.resolve_schedule(8, 50_000_000) == "ring"
    assert cfg.resolve_schedule(8, 100) == comm.choose(
        400, 8, costmodel.TPU_DCI)
    assert cfg.resolve_schedule(1, 100) == "psum"       # single pod
    assert cfg.resolve_schedule(8, None) == "psum"      # size unknown
    plan = cfg.exchange_plan(None, 8, n_elements=50_000_000)
    assert plan.schedule.name == "ring"
    # compression shrinks the wire bytes the chooser sees
    sign = ElasticConfig(schedule="auto", compression="sign_ef")
    assert sign.resolve_schedule(8, 3000) == "butterfly"


def test_auto_schedule_builds_train_step(subproc):
    subproc("""
        import warnings; warnings.filterwarnings('ignore')
        import jax
        from repro import configs
        from repro.core.easgd import EASGDConfig
        from repro.core.elastic import ElasticConfig
        from repro.runtime.train import build_train_step
        from repro.utils.jaxcompat import auto_mesh
        mesh = auto_mesh((2, 2, 2), ('pod', 'data', 'model'))
        cfg = configs.get('gemma3-4b').reduced
        build = build_train_step(
            cfg, ElasticConfig(easgd=EASGDConfig(), schedule='auto'), mesh,
            n_pods=2, per_pod_batch=4, seq=16)
        name = build.exchange_plan.schedule.name
        assert name in ('butterfly', 'ring'), name
        print('auto resolved to', name)
    """)
