"""repro.ft.membership — elastic fault-tolerant membership, bottom-up.

 1. Units: the membership state machine (transitions, epochs, survivor
    sets), dense-rank round remapping, the flat-row elastic scale ops,
    the bounded retry dial, and deterministic chaos injection.
 2. The failure matrix, end-to-end on real worker processes: SIGKILL
    mid-run shrinks P=4→P=3 through a RECONFIGURE epoch; SIGTERM is a
    clean ``preempted`` departure; a respawned worker rejoins and the run
    re-expands to the next epoch; a chaos-refused HELLO dial is absorbed
    by the backoff bitwise-invisibly.
 3. The honest boundary: ``elastic=False`` (default) keeps every failure
    a hard error, exactly as before this module existed.
"""
import socket
import subprocess
import sys
import threading
import time

import numpy as np
import pytest

from repro import ps
from repro.comm import rounds as comm_rounds
from repro.core import costmodel
from repro.core.easgd import EASGDConfig
from repro.ft import chaos as ft_chaos
from repro.ft import elastic_scale, membership
from repro.net import server as net_server
from repro.net import wire

CFG = EASGDConfig(eta=0.05, rho=0.07, mu=0.9)
NET = costmodel.Network("tiny-emu", 5e-3, 1e-9)


def _free_port() -> int:
    with socket.socket() as s:
        s.bind(("127.0.0.1", 0))
        return s.getsockname()[1]


# ---------------------------------------------------------------------------
# (1a) the state machine
# ---------------------------------------------------------------------------

def test_membership_lifecycle_and_epochs():
    t = membership.MembershipTable(3)
    assert all(t.state(w) == membership.JOINED for w in range(3))
    for w in range(3):
        t.mark_ready(w)
    assert t.survivors() == [0, 1, 2] and t.joiners() == []

    t.mark_dead(1, "socket drop")
    assert t.is_lost(1) and not t.is_lost(0)
    assert t.survivors() == [0, 2]
    assert t.advance_epoch() == 1

    # a respawn re-enters as JOINED and only becomes ACTIVE at the NEXT
    # completed reconfiguration — it never computes in the current epoch
    t.mark_rejoined(1)
    assert t.state(1) == membership.JOINED
    assert t.joiners() == [1] and t.survivors() == [0, 2]
    assert t.members[1].epoch == t.epoch + 1
    assert t.advance_epoch() == 2
    assert t.survivors() == [0, 1, 2]

    snap = t.snapshot()
    assert snap["epoch"] == 2
    assert snap["members"] == {0: "active", 1: "active", 2: "active"}
    assert any(tr["from"] == "dead" and tr["to"] == "joined"
               for tr in snap["transitions"])


def test_membership_suspect_and_left_paths():
    t = membership.MembershipTable(2)
    t.mark_ready(0), t.mark_ready(1)
    t.mark_suspect(0)
    # a suspect stays in the survivor set (benefit of the doubt) and is
    # rehabilitated by the next epoch
    assert t.state(0) == membership.SUSPECT
    assert t.survivors() == [0, 1]
    t.advance_epoch()
    assert t.state(0) == membership.ACTIVE
    t.mark_left(1, "preempted")
    assert t.state(1) == membership.LEFT and t.is_lost(1)
    # suspect only demotes ACTIVE members — a LEFT worker stays LEFT
    t.mark_suspect(1)
    assert t.state(1) == membership.LEFT


def test_dense_rank_map_and_remap_rounds():
    assert membership.dense_rank_map([0, 1, 3]) == {0: 0, 1: 1, 2: 3}
    rounds = [[comm_rounds.Message(0, 1, frac=0.5, chunk=0, chunks=2),
               comm_rounds.Message(2, comm_rounds.MASTER)],
              [comm_rounds.Message(2, 0, op="set")]]
    out = comm_rounds.remap_rounds(rounds, {0: 0, 1: 1, 2: 3})
    assert [(m.src, m.dst) for m in out[0]] == [(0, 1),
                                                (3, comm_rounds.MASTER)]
    assert out[1][0].src == 3 and out[1][0].dst == 0
    # everything but the endpoints is untouched — the remapped structure
    # prices and executes exactly like the dense one
    assert (out[0][0].frac, out[0][0].chunk, out[0][0].chunks) == (0.5, 0, 2)
    assert out[1][0].op == "set"


def test_elastic_scale_flat_rows():
    rng = np.random.RandomState(0)
    w, v = rng.randn(3, 8), rng.randn(3, 8)
    center = rng.randn(8)
    w2, v2 = elastic_scale.pod_leave_rows(w, v, 1)
    assert w2.shape == (2, 8)
    np.testing.assert_array_equal(w2, w[[0, 2]])
    np.testing.assert_array_equal(v2, v[[0, 2]])
    w3, v3 = elastic_scale.pod_join_rows(w2, v2, center)
    assert w3.shape == (3, 8)
    np.testing.assert_array_equal(w3[-1], center)   # seeded FROM the center
    np.testing.assert_array_equal(v3[-1], 0.0)      # with zero momentum


# ---------------------------------------------------------------------------
# (1b) the bounded retry dial
# ---------------------------------------------------------------------------

def test_dial_backoff_raises_after_deadline():
    port = _free_port()                    # nothing listens here
    t0 = time.monotonic()
    with pytest.raises(wire.DialError, match=str(port)):
        wire.dial_with_backoff("127.0.0.1", port, deadline_s=0.3, seed=0)
    assert time.monotonic() - t0 >= 0.25   # it actually kept retrying


def test_dial_backoff_survives_late_listener():
    """A staggered multi-host start: the listener exists only after the
    worker already began dialing — the retry must absorb the gap."""
    srv = socket.socket()
    srv.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
    srv.bind(("127.0.0.1", 0))             # bound but NOT listening: refused
    port = srv.getsockname()[1]
    th = threading.Timer(0.3, srv.listen)
    th.start()
    try:
        conn = wire.dial_with_backoff("127.0.0.1", port, deadline_s=10.0,
                                      seed=1)
        conn.close()
    finally:
        th.join()
        srv.close()


def test_dial_backoff_refuse_fn_window():
    srv = socket.socket()
    srv.bind(("127.0.0.1", 0))
    srv.listen()
    port = srv.getsockname()[1]
    attempts = [0]

    def refuse():
        attempts[0] += 1
        return attempts[0] <= 3            # first 3 attempts refused

    try:
        conn = wire.dial_with_backoff("127.0.0.1", port, deadline_s=10.0,
                                      seed=2, refuse_fn=refuse)
        conn.close()
    finally:
        srv.close()
    assert attempts[0] == 4                # retried through the window


# ---------------------------------------------------------------------------
# (1c) chaos injection
# ---------------------------------------------------------------------------

def test_chaos_spec_roundtrip_and_validation():
    spec = ft_chaos.ChaosSpec(wid=2, kill_at_iter=10, signal="term",
                              dial_refuse_s=0.5)
    assert ft_chaos.ChaosSpec.from_env({ft_chaos.ENV_VAR: spec.to_env()}) \
        == spec
    assert ft_chaos.ChaosSpec.from_env({}) is None
    assert ft_chaos.ChaosSpec.from_config(None) is None
    assert ft_chaos.ChaosSpec.from_config(spec) is spec
    assert ft_chaos.ChaosSpec.from_config({"wid": 1}) \
        == ft_chaos.ChaosSpec(wid=1)
    with pytest.raises(AssertionError):
        ft_chaos.ChaosSpec(wid=0, signal="segv")
    with pytest.raises(AssertionError):
        ft_chaos.ChaosSpec(wid=0, dial_refuse_s=-1.0)


def test_chaos_clock_noop_and_refuse_window():
    clock = ft_chaos.clock_from_env({})    # no spec: always a no-op clock
    clock.maybe_fire(0, 10**9)             # must not signal anything
    assert not clock.refuse_dial(0)

    armed = ft_chaos.ChaosClock(ft_chaos.ChaosSpec(wid=1, dial_refuse_s=0.1))
    assert armed.refuse_dial(1)            # inside the window
    assert not armed.refuse_dial(0)        # wrong worker
    time.sleep(0.15)
    assert not armed.refuse_dial(1)        # window elapsed
    armed.maybe_fire(1, 50)                # kill_at_iter=-1: never fires


def test_config_gates():
    with pytest.raises(AssertionError, match="elastic"):
        ps.PSConfig(algorithm="sync_easgd", transport="thread", elastic=True)
    with pytest.raises(AssertionError, match="chaos"):
        ps.PSConfig(algorithm="sync_easgd", transport="process",
                    chaos={"wid": 0})
    with pytest.raises(AssertionError, match="segv"):
        ps.PSConfig(algorithm="sync_easgd", transport="tcp",
                    chaos={"wid": 0, "signal": "segv"})


def test_ft_modules_are_jax_free(subproc):
    """The elastic plane rides the thin TCP worker's startup path — it must
    not drag jax in (membership/chaos/flat-row scale ops are numpy-only)."""
    subproc("""
        import sys
        import repro.ft.membership
        import repro.ft.chaos
        import repro.ft.elastic_scale
        import repro.net.worker
        assert "jax" not in sys.modules, "elastic plane pulled jax in"
    """, n_devices=1)


# ---------------------------------------------------------------------------
# (2) the failure matrix — real worker processes, deterministic chaos
# ---------------------------------------------------------------------------

def _ecfg(algo="sync_easgd", P=4, iters=240, **kw):
    kw.setdefault("eval_every_iters", 10**9)
    kw.setdefault("schedule", "ring")
    kw.setdefault("sync_plane", "p2p")
    return ps.PSConfig(algorithm=algo, n_workers=P, total_iters=iters,
                       transport="tcp", elastic=True, **kw)


def test_elastic_sigkill_shrinks_p2p_run():
    """SIGKILL mid-run: the p2p sync plane freezes, reconfigures onto the
    3 survivors, and completes — loss comparable to a clean P=3 run."""
    res = ps.run_ps(ps.NUMPY_MLP, CFG, _ecfg(
        chaos={"wid": 2, "kill_at_iter": 20, "signal": "kill"}))
    kinds = [e["kind"] for e in res.health["events"]]
    assert "worker_dead" in kinds and "reconfigure" in kinds
    assert res.health["epoch"] >= 1
    assert res.health["membership"]["members"][2] == "dead"
    assert res.health["membership"]["members"][0] == "active"
    assert np.isfinite(res.final_metric)
    clean = ps.run_ps(ps.NUMPY_MLP, CFG, ps.PSConfig(
        algorithm="sync_easgd", n_workers=3, total_iters=180,
        transport="tcp", schedule="ring", sync_plane="p2p",
        eval_every_iters=10**9))
    # different gradient streams after the reconfigure — same training, so
    # a loose tolerance, not bitwise
    assert abs(res.final_metric - clean.final_metric) < 0.35


def test_elastic_sigterm_is_clean_departure():
    """SIGTERM: the watchdog converts it to a mid-run BYE — the membership
    table records LEFT/preempted, not DEAD, and the run still completes."""
    res = ps.run_ps(ps.NUMPY_MLP, CFG, _ecfg(
        chaos={"wid": 1, "kill_at_iter": 20, "signal": "term"}))
    evs = {e["kind"]: e for e in res.health["events"]}
    assert "worker_left" in evs and evs["worker_left"]["wid"] == 1
    assert evs["worker_left"]["detail"] == "preempted"
    assert "reconfigure" in evs
    assert res.health["membership"]["members"][1] == "left"
    assert np.isfinite(res.final_metric)


def test_elastic_master_plane_absorbs_kill():
    """The centralized async plane: a dead worker's mailbox slot is simply
    dropped; the survivors absorb the remaining iterations by arrival."""
    res = ps.run_ps(ps.NUMPY_MLP, CFG, ps.PSConfig(
        algorithm="async_easgd", n_workers=3, total_iters=120,
        transport="tcp", schedule="ring", eval_every_iters=10**9,
        elastic=True, chaos={"wid": 1, "kill_at_iter": 10, "signal": "kill"}))
    kinds = [e["kind"] for e in res.health["events"]]
    assert "worker_dead" in kinds
    assert res.health["membership"]["members"][1] == "dead"
    assert res.total_iters == 120          # survivors absorbed the quota
    assert np.isfinite(res.final_metric)


def test_elastic_respawn_rejoins_next_epoch():
    """The full lifecycle: SIGKILL at epoch 0 → survivors reconfigure to
    epoch 1 at P=3 → an external respawn (re-exec from REPRO_CLUSTER_SPEC)
    rejoins → epoch 2 re-expands to P=4 and everyone finishes ACTIVE."""
    port = _free_port()
    cfg = _ecfg(iters=600, tcp_port=port, emulate_net=NET,
                chaos={"wid": 2, "kill_at_iter": 10, "signal": "kill"})
    procs: list = []

    def _respawn():
        env = net_server.worker_env()
        env["REPRO_CLUSTER_SPEC"] = net_server.cluster_spec_env(
            "worker", 2, "127.0.0.1", port)
        procs.append(subprocess.Popen(
            [sys.executable, "-m", "repro.net.worker", "--rejoin"],
            env=env, stdout=subprocess.PIPE, stderr=subprocess.STDOUT,
            text=True))

    timer = threading.Timer(1.2, _respawn)
    timer.start()
    try:
        res = ps.run_ps(ps.NUMPY_MLP, CFG, cfg)
    finally:
        timer.cancel()
    assert procs, "respawn timer never fired"
    out, _ = procs[0].communicate(timeout=60)
    assert procs[0].returncode == 0, out
    kinds = [e["kind"] for e in res.health["events"]]
    assert kinds.count("reconfigure") == 2     # shrink, then re-expand
    assert "worker_rejoined" in kinds
    assert res.health["epoch"] == 2
    assert res.health["membership"]["members"] \
        == {0: "active", 1: "active", 2: "active", 3: "active"}
    assert np.isfinite(res.final_metric)


def test_chaos_dial_refuse_absorbed_bitwise():
    """A refused HELLO dial window (staggered start) is retried away by
    the backoff — the deterministic run's math is untouched, bitwise."""
    def _det(**kw):
        cfg = ps.PSConfig(algorithm="sync_easgd", n_workers=2,
                          total_iters=40, transport="tcp",
                          schedule="round_robin", deterministic=True,
                          eval_every_iters=10**9, **kw)
        return ps.run_ps(ps.NUMPY_MLP, CFG, cfg)
    a = _det()
    b = _det(chaos={"wid": 1, "dial_refuse_s": 0.4})
    np.testing.assert_array_equal(a.center, b.center)
    np.testing.assert_array_equal(a.workers, b.workers)


# ---------------------------------------------------------------------------
# (3) the honest boundary: elastic off keeps failures fatal
# ---------------------------------------------------------------------------

def test_kill_without_elastic_stays_fatal():
    with pytest.raises(RuntimeError, match="worker"):
        ps.run_ps(ps.NUMPY_MLP, CFG, ps.PSConfig(
            algorithm="sync_easgd", n_workers=2, total_iters=200,
            transport="tcp", schedule="ring", sync_plane="p2p",
            eval_every_iters=10**9,
            chaos={"wid": 1, "kill_at_iter": 10, "signal": "kill"}))
