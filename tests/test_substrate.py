"""Substrate subsystems: data pipeline, checkpointing, fault tolerance,
optimizers, async engine, DES, cost model."""
import warnings

warnings.filterwarnings("ignore")

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:      # property tests skipped, fallback below
    given = settings = st = None

from repro.core import costmodel
from repro.core.async_engine import ALGORITHMS, PSEngine, SimConfig
from repro.core.easgd import EASGDConfig
from repro.core.elastic import ElasticConfig
from repro.core import elastic
from repro.checkpoint import CheckpointManager
from repro.data import ShardedPipeline, SyntheticLMStream
from repro.ft import BoundedStaleness, Watchdog, pod_join, pod_leave, \
    rescale_pods
from repro import optim


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def _factory(shard, n_shards):
    return SyntheticLMStream(vocab_size=97, seq=16, batch=4, seed=7,
                             shard=shard, n_shards=n_shards)


def test_pipeline_deterministic_and_resumable():
    p1 = ShardedPipeline(_factory, n_pods=2)
    a = [p1.next() for _ in range(3)]
    p2 = ShardedPipeline(_factory, n_pods=2)
    b = [p2.next() for _ in range(3)]
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x["tokens"], y["tokens"])
    # resume from step 1 reproduces batch 1
    p2.restore(1)
    again = p2.next()
    np.testing.assert_array_equal(a[1]["tokens"], again["tokens"])


def test_pipeline_prefetch_matches_sync():
    ps = ShardedPipeline(_factory, n_pods=1)
    sync = [ps.next() for _ in range(4)]
    pa = ShardedPipeline(_factory, n_pods=1).start()
    try:
        async_ = [pa.next() for _ in range(4)]
    finally:
        pa.stop()
    for x, y in zip(sync, async_):
        np.testing.assert_array_equal(x["tokens"], y["tokens"])


def test_pipeline_shards_disjoint():
    p = ShardedPipeline(_factory, n_pods=2)
    b = p.next()
    assert b["tokens"].shape[0] == 2
    assert not np.array_equal(b["tokens"][0], b["tokens"][1])


def test_lm_stream_learnable_structure():
    """Next token is (mostly) an affine function of the current one — a
    bigram table gets well below uniform entropy accuracy."""
    s = SyntheticLMStream(vocab_size=31, seq=64, batch=32, seed=0)
    b = s.batch_at(0)
    t, tgt = b["tokens"], b["targets"]
    pred = (31 % 31 + 31) and ((t * (31 % 31 or 1)))  # noqa - see below
    # empirical: P(target == (a*t+7+i%5) mod V) must dominate chance
    hits = 0
    total = 0
    for i in range(63):
        want = (31 % 31 or 1)
        nxt = (31 * t[:, i] + 7 + ((i + 1) % 5)) % 31
        hits += np.sum(tgt[:, i] == t[:, i + 1])
        total += t.shape[0]
    assert hits / total == 1.0     # targets are the shifted tokens


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    cfg = ElasticConfig(easgd=EASGDConfig())
    state = elastic.init({"w": jnp.arange(6.0).reshape(2, 3)}, cfg, n_pods=2)
    mgr.save(3, state, extra={"data_step": 3})
    mgr.save(7, state._replace(step=jnp.asarray(7)), extra={"data_step": 7})
    assert mgr.all_steps() == [3, 7]
    restored, meta = mgr.restore(state)
    assert meta["extra"]["data_step"] == 7
    assert int(restored.step) == 7
    np.testing.assert_array_equal(np.asarray(restored.params["w"]),
                                  np.asarray(state.params["w"]))


def test_checkpoint_keep_n(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    state = {"w": jnp.ones((2,))}
    for s in (1, 2, 3, 4):
        mgr.save(s, state)
    assert mgr.all_steps() == [3, 4]


def test_checkpoint_async(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    state = {"w": jnp.ones((4,))}
    mgr.save_async(5, state)
    mgr.wait()
    restored, _ = mgr.restore(state)
    np.testing.assert_array_equal(np.asarray(restored["w"]), np.ones((4,)))


def test_checkpoint_shape_mismatch_rejected(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, {"w": jnp.ones((4,))})
    with pytest.raises(AssertionError):
        mgr.restore({"w": jnp.ones((5,))})


# ---------------------------------------------------------------------------
# fault tolerance
# ---------------------------------------------------------------------------

def test_pod_join_seeds_from_center():
    cfg = ElasticConfig(easgd=EASGDConfig())
    state = elastic.init({"w": jnp.full((3,), 2.0)}, cfg, n_pods=2)
    state = state._replace(center={"w": jnp.full((3,), 5.0)})
    grown = pod_join(state)
    assert grown.params["w"].shape[0] == 3
    np.testing.assert_allclose(np.asarray(grown.params["w"][2]), 5.0)
    np.testing.assert_allclose(np.asarray(grown.momentum["w"][2]), 0.0)


def test_pod_leave_and_rescale():
    cfg = ElasticConfig(easgd=EASGDConfig())
    state = elastic.init({"w": jnp.ones((3,))}, cfg, n_pods=4)
    marked = state.params["w"].at[2].set(9.0)
    state = state._replace(params={"w": marked})
    st2 = pod_leave(state, 2)
    assert st2.params["w"].shape[0] == 3
    assert not np.any(np.asarray(st2.params["w"]) == 9.0)
    st3 = rescale_pods(state, 6)
    assert st3.params["w"].shape[0] == 6
    # training continues after rescale
    grads = {"w": jnp.ones((6, 3))}
    out = elastic.apply_gradients(st3, grads, cfg)
    assert int(out.step) == 1


def test_bounded_staleness_mask():
    pol = BoundedStaleness(n_pods=8, deadline_factor=1.5)
    delays = [1, 1, 1, 1, 1, 1, 1, 10.0]
    mask = pol.participation(0, delays)
    assert mask.sum() == 7 and mask[-1] == 0
    # quorum guard
    pol2 = BoundedStaleness(n_pods=4, deadline_factor=0.01, min_quorum=0.5)
    mask2 = pol2.participation(0, [1.0, 1.1, 1.2, 1.3])
    assert mask2.sum() >= 2


def test_watchdog_heartbeat_and_stop(tmp_path):
    hb = str(tmp_path / "hb")
    wd = Watchdog(heartbeat_path=hb, interval_s=0.05,
                  install_signals=False).start_heartbeat()
    import time
    time.sleep(0.15)
    assert Watchdog.is_alive(hb, timeout_s=5)
    wd.should_stop.set()
    wd.close()


# ---------------------------------------------------------------------------
# optimizers
# ---------------------------------------------------------------------------

def test_momentum_sgd_matches_easgd_rho0():
    init, update = optim.momentum_sgd(lr=0.1, mu=0.9)
    params = {"w": jnp.ones((3,))}
    st = init(params)
    g = {"w": jnp.full((3,), 0.5)}
    p1, st = update(g, st, params)
    # hand-check: v = -0.05, w = 0.95
    np.testing.assert_allclose(np.asarray(p1["w"]), 0.95)


def test_adam_step_decreases_quadratic():
    init, update = optim.adam(lr=0.1)
    w = {"w": jnp.asarray([3.0, -2.0])}
    st = init(w)
    for _ in range(50):
        g = {"w": 2 * w["w"]}
        w, st = update(g, st, w)
    assert float(jnp.sum(jnp.square(w["w"]))) < 1.0


def test_schedules():
    s = optim.linear_warmup_cosine(1.0, warmup=10, total=100)
    assert float(s(0)) == 0.0
    assert abs(float(s(10)) - 1.0) < 1e-6
    assert float(s(100)) < 0.01
    sd = optim.step_decay(1.0, 0.5, 10)
    assert abs(float(sd(25)) - 0.25) < 1e-6


# ---------------------------------------------------------------------------
# async engine + cost model
# ---------------------------------------------------------------------------

def _tiny_engine(seed=0):
    rng = np.random.RandomState(seed)
    A = rng.randn(8, 8).astype(np.float64)
    A = A @ A.T / 8 + np.eye(8)          # SPD quadratic
    w_star = rng.randn(8)

    def grad_fn(w, step, worker):
        noise = np.random.RandomState(step * 131 + worker).randn(8) * 0.1
        return A @ (w - w_star) + noise

    def err_fn(w):
        return float(np.linalg.norm(w - w_star))

    # eta small enough that master-side momentum (async_msgd) stays stable
    # on this quadratic (the paper's Fig 6.2 shows MSGD's instability at
    # higher rates — MEASGD is the fix)
    return PSEngine(grad_fn, err_fn, np.zeros(8),
                    EASGDConfig(eta=0.015, rho=0.05, mu=0.9),
                    SimConfig(n_workers=4, t_compute=1e-3, seed=seed))


@pytest.mark.parametrize("algo", [a for a in ALGORITHMS
                                  if a != "async_msgd"])
def test_async_engine_runs_and_converges(algo):
    eng = _tiny_engine()
    res = eng.run(algo, total_iters=600)
    assert res.total_iters >= 600 or res.total_time_s > 0
    assert res.final_metric < 2.0          # moved toward w*
    assert 0 <= res.breakdown["fwd_bwd"]


def test_measgd_more_stable_than_msgd():
    """Paper Fig 6.2: worker-side momentum (MEASGD) is stable where
    master-side momentum (MSGD) compounds with asynchrony-induced implicit
    momentum and diverges."""
    msgd = _tiny_engine(0).run("async_msgd", total_iters=600)
    measgd = _tiny_engine(0).run("async_measgd", total_iters=600)
    assert measgd.final_metric < 2.0
    assert measgd.final_metric < msgd.final_metric


def test_async_engine_deterministic():
    r1 = _tiny_engine(3).run("hogwild_easgd", total_iters=300)
    r2 = _tiny_engine(3).run("hogwild_easgd", total_iters=300)
    assert r1.history == r2.history


def test_sync_easgd_faster_than_original():
    """The paper's headline ordering, on modeled time at equal iterations."""
    e1 = _tiny_engine(1)
    sync = e1.run("sync_easgd", total_iters=1000)
    orig = _tiny_engine(1).run("original_easgd", total_iters=1000)
    assert sync.total_time_s < orig.total_time_s


def test_costmodel_packed_beats_unpacked():
    sizes = [4_000] * 50
    for net in (costmodel.MELLANOX_FDR, costmodel.TPU_ICI):
        assert costmodel.t_packed(sizes, 16, net) < \
            costmodel.t_per_layer(sizes, 16, net)


def _check_tree_vs_roundrobin(p, nbytes):
    """Θ(log P) tree beats the Θ(P) round-robin for P ≥ 6. (Not P ≥ 4: at
    P=5 the two-phase tree's 2·⌈log2 5⌉ = 6 rounds lose to 5 serialized
    messages when latency dominates — 2·⌈log2 P⌉ ≤ P holds from P=6 up.)"""
    net = costmodel.MELLANOX_FDR
    if p >= 6:
        assert costmodel.t_tree_allreduce(nbytes, p, net) <= \
            costmodel.t_round_robin(nbytes, p, net)


if st is not None:

    @settings(max_examples=30, deadline=None)
    @given(st.integers(2, 512), st.floats(1e3, 1e9))
    def test_costmodel_tree_vs_roundrobin(p, nbytes):
        _check_tree_vs_roundrobin(p, nbytes)


def test_costmodel_tree_vs_roundrobin_deterministic():
    for p in (6, 7, 16, 511, 512):
        for nbytes in (1e3, 1e6, 1e9):
            _check_tree_vs_roundrobin(p, nbytes)
