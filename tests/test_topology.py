"""Topology-aware scale-out (heterogeneous two-level fabric).

 1. The Topology / LinkProfile model: link classing, wire roundtrips, the
    emulated-topology factory and its uniform special case.
 2. Two-level costs and the generalized hierarchical rounds: non-pow2 P
    with a pow2 group count resolves, a 1-host topology collapses bitwise
    to the flat cost model, per-wid pricing only charges a worker's own
    links.
 3. comm.choose under a two-level network: hierarchical wins exactly when
    cross-host links dominate AND the mesh is multi-host; weak/no cross
    penalty falls back to the flat choice.
 4. Runtime integration: homogeneous-topology thread runs stay bitwise
    equal to no-topology runs; tcp-p2p byte counters match the two-level
    registry prediction per link class; measured profiles feed the
    chooser; heartbeat/backlog scale-out knobs pin their P<=16 behavior.
"""
import dataclasses

import numpy as np
import pytest

from repro import comm, ps
from repro.comm import rounds as comm_rounds
from repro.comm import schedules as comm_schedules
from repro.core import costmodel
from repro.core.easgd import EASGDConfig

CFG = EASGDConfig(eta=0.05, rho=0.07, mu=0.9)
NB = 9504.0          # NUMPY_MLP: 1188 f64 weights on the wire


# ---------------------------------------------------------------------------
# (1) the model
# ---------------------------------------------------------------------------

def test_topology_link_classing():
    t = costmodel.emulated_topology(2, 4)
    assert t.p == 8 and t.hosts == 2 and t.slots == 4
    assert t.host_of(0) == t.host_of(3) == 0
    assert t.host_of(4) == t.host_of(7) == 1
    assert t.host_of(-1) == -1                   # the master is no host
    assert t.link(0, 3) is t.intra
    assert t.link(3, 4) is t.cross
    assert t.link(comm_rounds.MASTER, 5) is t.cross  # master↔worker: slow
    assert not t.uniform
    assert t.cross.alpha == pytest.approx(20 * t.intra.alpha)
    assert t.cross.beta == pytest.approx(4 * t.intra.beta)


def test_one_host_topology_is_uniform():
    t = costmodel.emulated_topology(1, 8)
    assert t.uniform
    assert t.link(0, 7) is t.intra


def test_unit_multipliers_collapse_to_uniform():
    # cross 1.0x/1.0x means "no penalty" — the factory makes that EXACTLY
    # uniform (same Network object), so such topologies take flat paths
    t = costmodel.emulated_topology(4, 2, cross_alpha_x=1.0,
                                    cross_beta_x=1.0)
    assert t.uniform and t.cross is t.intra


def test_emulated_topology_validates():
    with pytest.raises(ValueError):
        costmodel.emulated_topology(0, 8)
    with pytest.raises(ValueError):
        costmodel.emulated_topology(2, 0)


def test_topology_wire_roundtrip():
    t = costmodel.emulated_topology(2, 8)
    back = costmodel.Topology.from_wire(t.to_wire())
    assert back == t
    prof = costmodel.LinkProfile(topology=t, source="measured",
                                 detail={"alpha0_us": 12.5})
    back_p = costmodel.LinkProfile.from_wire(prof.to_wire())
    assert back_p.topology == t
    assert back_p.source == "measured"
    assert back_p.detail["alpha0_us"] == 12.5


# ---------------------------------------------------------------------------
# (2) two-level costs and generalized hierarchical rounds
# ---------------------------------------------------------------------------

def test_hierarchical_group_from_topology():
    t = costmodel.emulated_topology(2, 8)
    assert comm_rounds.topology_group(16, t) == 8
    # topology that does not tile p falls back to the flat default
    assert comm_rounds.topology_group(8, t) == comm_rounds._inner_size(8)
    assert comm_rounds.topology_group(16, None) == \
        comm_rounds._inner_size(16)


def test_hierarchical_rounds_non_pow2_p_pow2_groups():
    # P=24 as 4 hosts x 6 slots: 6-way inner rings, 4-way (pow2) outer
    # butterfly — the pow2_only constraint is on the GROUP COUNT now
    t = costmodel.emulated_topology(4, 6)
    rounds = comm_rounds.hierarchical_rounds(24, NB, topology=t)
    workers = {m.src for rnd in rounds for m in rnd} | \
              {m.dst for rnd in rounds for m in rnd}
    assert workers == set(range(24))
    # ...but a non-pow2 group count still refuses
    with pytest.raises(ValueError, match="power-of-two"):
        comm_rounds.hierarchical_rounds(24, NB,
                                        topology=costmodel.emulated_topology(
                                            3, 8))
    with pytest.raises(ValueError, match="tile"):
        comm_rounds.hierarchical_rounds(8, NB, group=3)


def test_schedule_rounds_pow2_gate_lifted_only_with_topology():
    sched = comm_schedules.get("hierarchical")
    t = costmodel.emulated_topology(4, 6)
    assert sched.rounds(24, NB, topology=t)      # lifted under a topology
    with pytest.raises(ValueError):              # flat stays pow2-only
        sched.rounds(24, NB)


def test_one_host_cost_topo_bitwise_equals_flat():
    # uniform topology must change NOTHING: cost_topo == cost bit for bit
    t = costmodel.Topology(hosts=1, slots=8, intra=costmodel.PS_WIRE,
                           cross=costmodel.PS_WIRE)
    for name in comm_schedules.names():
        sched = comm_schedules.get(name)
        if sched.pow2_only and 8 & 7:
            continue
        assert sched.cost_topo(NB, 8, t) == \
            sched.cost(NB, 8, costmodel.PS_WIRE), name


def test_t_rounds_uniform_equals_cost_from_rounds():
    # the per-link pricer reduces bitwise to the old uniform pricer when
    # every link is the same Network
    net = costmodel.PS_WIRE
    for name in ("ring", "butterfly", "tree", "hierarchical"):
        sched = comm_schedules.get(name)
        rounds = sched.rounds(8, NB)
        assert comm_rounds.t_rounds(rounds, NB, net=net) == \
            sched.cost_from_rounds(NB, 8, net), name


def test_t_rounds_per_wid_prices_own_links_only():
    t = costmodel.emulated_topology(2, 4)
    rounds = comm_rounds.hierarchical_rounds(8, NB, topology=t)
    full = comm_rounds.t_rounds(rounds, NB, topology=t)
    per_wid = [comm_rounds.t_rounds(rounds, NB, topology=t, wid=i)
               for i in range(8)]
    assert all(0 < p <= full for p in per_wid)
    # every worker touches a cross link in the outer butterfly, so the
    # spread comes from round membership, not link class here — but a
    # wid-filtered price must never exceed the global bound
    assert max(per_wid) == pytest.approx(full)


def test_two_level_hierarchical_closed_form():
    t = costmodel.emulated_topology(2, 8)
    want = (costmodel.t_ring_allreduce(NB, 8, t.intra)
            + costmodel.t_butterfly_allreduce(NB, 2, t.cross))
    assert costmodel.t_hierarchical_two_level(NB, t) == pytest.approx(want)


# ---------------------------------------------------------------------------
# (3) the chooser under two-level networks
# ---------------------------------------------------------------------------

def test_choose_hierarchical_iff_cross_dominates_and_multihost():
    # the canonical scale-out family: P/8 hosts x 8 slots, cross 20xA 4xB.
    # P=8 is ONE host (uniform -> flat ring); every multi-host point goes
    # hierarchical
    for p, want_hier in ((8, False), (16, True), (32, True), (64, True)):
        topo = costmodel.emulated_topology(max(p // 8, 1), 8)
        got = comm_schedules.choose(NB, p, topology=topo)
        assert (got == "hierarchical") == want_hier, (p, got)
    # no cross penalty -> uniform -> the flat choice, never hierarchical
    for p in (16, 32, 64):
        topo = costmodel.emulated_topology(p // 8, 8, cross_alpha_x=1.0,
                                           cross_beta_x=1.0)
        got = comm_schedules.choose(NB, p, topology=topo)
        assert got == comm_schedules.choose(NB, p, costmodel.PS_WIRE), \
            (p, got)


def test_choose_two_level_beats_flat_on_cross_bytes():
    # the reason hierarchical wins: it pays the slow links ⌈log2 hosts⌉
    # rounds instead of ring's 2(P-1)
    topo = costmodel.emulated_topology(2, 8)
    hier = comm_schedules.get("hierarchical").cost_topo(NB, 16, topo)
    ring = comm_schedules.get("ring").cost_topo(NB, 16, topo)
    butterfly = comm_schedules.get("butterfly").cost_topo(NB, 16, topo)
    assert hier < min(ring, butterfly)


def test_choose_non_pow2_p_with_pow2_groups():
    # P=24 on 4x6: flat butterfly is out (24 not pow2) but hierarchical's
    # 4 pow2 groups qualify — the chooser must CONSIDER it, not crash
    topo = costmodel.emulated_topology(4, 6)
    got = comm_schedules.choose(NB, 24, topology=topo)
    assert got in ("ring", "hierarchical")
    assert got == "hierarchical"      # 4 cross rounds vs ring's 46


def test_choose_profile_carries_topology():
    topo = costmodel.emulated_topology(2, 8)
    prof = costmodel.LinkProfile(topology=topo, source="analytic")
    assert comm_schedules.choose(NB, 16, profile=prof) == \
        comm_schedules.choose(NB, 16, topology=topo)


# ---------------------------------------------------------------------------
# (4) runtime integration
# ---------------------------------------------------------------------------

def _thread_cfg(P, topology, schedule="hierarchical", iters=24, **kw):
    return ps.PSConfig(algorithm="sync_easgd", n_workers=P,
                       total_iters=iters, transport="thread",
                       schedule=schedule, eval_every_iters=10**9,
                       deterministic=True, topology=topology, **kw)


def test_homogeneous_topology_thread_run_bitwise_equal():
    # a 1-host topology paces on the intra class but must not perturb the
    # math: center and workers bitwise-equal to the no-topology run
    base = ps.run_ps(ps.NUMPY_MLP, CFG,
                     _thread_cfg(4, None, schedule="ring"))
    topo = ps.run_ps(ps.NUMPY_MLP, CFG,
                     _thread_cfg(4, costmodel.emulated_topology(1, 4),
                                 schedule="ring"))
    np.testing.assert_array_equal(base.center, topo.center)
    np.testing.assert_array_equal(base.workers, topo.workers)


def test_thread_topology_auto_resolves_hierarchical():
    topo = costmodel.emulated_topology(2, 8)
    res = ps.run_ps(ps.NUMPY_MLP, CFG,
                    _thread_cfg(16, topo, schedule="auto", iters=16))
    assert res.schedule == "hierarchical"
    assert res.total_iters == 16


def test_psconfig_topology_asserts():
    topo = costmodel.emulated_topology(2, 4)
    with pytest.raises(AssertionError, match="REPLACES emulate_net"):
        _thread_cfg(8, topo, emulate_net=costmodel.PS_WIRE)
    with pytest.raises(AssertionError, match="n_workers"):
        _thread_cfg(4, topo)
    with pytest.raises(AssertionError, match="sync family"):
        dataclasses.replace(_thread_cfg(8, None), algorithm="async_easgd",
                            topology=topo)
    with pytest.raises(AssertionError, match="elastic"):
        ps.PSConfig(algorithm="sync_easgd", n_workers=8, transport="tcp",
                    schedule="ring", sync_plane="p2p", topology=topo,
                    elastic=True)
    with pytest.raises(AssertionError, match="link_profile"):
        _thread_cfg(8, None,
                    link_profile=costmodel.LinkProfile(topology=topo))


def test_hb_scaling_pins():
    # P <= 16: EXACTLY the configured knobs (the whole existing test
    # matrix rides on this); P = 64: 4x slower beat, timeout >= 12 beats
    for P in (2, 4, 8, 16):
        cfg = _thread_cfg(P, None, schedule="ring")
        assert cfg.hb_interval_eff_s() == cfg.hb_interval_s
    cfg64 = _thread_cfg(64, None, schedule="ring")
    assert cfg64.hb_interval_eff_s() == pytest.approx(
        cfg64.hb_interval_s * 4.0)
    assert cfg64.hb_timeout_eff_s() >= 12.0 * cfg64.hb_interval_eff_s()
    assert cfg64.hb_timeout_eff_s(16) == cfg64.hb_timeout_s or \
        cfg64.hb_timeout_eff_s(16) >= cfg64.hb_timeout_s


def test_accept_backlog_scales_with_p():
    from repro.net.server import accept_backlog
    assert accept_backlog(4) == 16            # small meshes keep headroom
    assert accept_backlog(8) == 16
    assert accept_backlog(16) == 24
    assert accept_backlog(64) == 72           # P=64 rendezvous all at once


def test_measured_link_profile_thread():
    cfg = _thread_cfg(8, costmodel.emulated_topology(2, 4))
    prof = ps.measured_link_profile(cfg)
    assert prof.source.startswith("measured")
    t = prof.topology
    # measured = declared + physical floor: never cheaper than declared
    assert t.intra.alpha >= cfg.topology.intra.alpha
    assert t.intra.beta >= cfg.topology.intra.beta
    assert t.cross.alpha >= cfg.topology.cross.alpha
    assert not t.uniform
    # and the chooser consumes it directly
    assert comm_schedules.choose(NB, 8, profile=prof) in \
        comm_schedules.names()


def test_calibrate_builds_profile_only_under_topology():
    cal_flat = ps.calibrate(ps.NUMPY_MLP,
                            _thread_cfg(4, None, schedule="ring"))
    assert cal_flat.profile is None
    cal_topo = ps.calibrate(ps.NUMPY_MLP,
                            _thread_cfg(8, costmodel.emulated_topology(2,
                                                                       4)))
    assert cal_topo.profile is not None
    assert cal_topo.profile.topology.hosts == 2


def test_tcp_p2p_topology_bytes_match_two_level_registry():
    # the CI smoke's oracle, as a unit test: a 2-host-emulated tcp-p2p run
    # whose per-link byte counters must equal the registry prediction per
    # link, and whose intra/cross totals must equal the host_of partition
    from repro.net.peer import predicted_link_bytes

    topo = costmodel.emulated_topology(2, 2)
    iters = 8
    cfg = ps.PSConfig(algorithm="sync_easgd", n_workers=4,
                      total_iters=iters, transport="tcp",
                      schedule="hierarchical", sync_plane="p2p",
                      deterministic=True, eval_every_iters=10**9,
                      topology=topo)
    res = ps.run_ps(ps.NUMPY_MLP, CFG, cfg)
    n = res.center.size
    padded = n + (-n) % 4
    exchanges = iters // 4
    per = predicted_link_bytes(
        comm.get("hierarchical").rounds(4, n * 8, topology=topo), padded)
    want = {f"{i}-{j}": exchanges * b for (i, j), b in per.items()}
    assert res.counters["peer_link_bytes"] == want
    intra = sum(b for (i, j), b in per.items()
                if topo.host_of(i) == topo.host_of(j)) * exchanges
    cross = sum(b for (i, j), b in per.items()
                if topo.host_of(i) != topo.host_of(j)) * exchanges
    assert res.counters["intra_host_bytes"] == intra
    assert res.counters["cross_host_bytes"] == cross
    assert intra > 0 and cross > 0


def test_des_weak_scaling_sees_topology():
    from repro.core.des import weak_scaling_efficiency
    net = costmodel.PS_WIRE
    topo = costmodel.emulated_topology(2, 8)
    flat = weak_scaling_efficiency(16, t_compute=5e-3, weight_bytes=NB,
                                   net=net, overlap=False,
                                   schedule="hierarchical")
    two = weak_scaling_efficiency(16, t_compute=5e-3, weight_bytes=NB,
                                  net=net, overlap=False,
                                  schedule="hierarchical", topology=topo)
    assert two < flat        # cross links make the exchange cost MORE
    uni = weak_scaling_efficiency(16, t_compute=5e-3, weight_bytes=NB,
                                  net=net, overlap=False,
                                  schedule="hierarchical",
                                  topology=costmodel.Topology(
                                      1, 16, net, net))
    assert uni == flat       # 1 host: bitwise the flat model
