"""Benchmark driver: one module per paper table/figure (+ kernels +
roofline). Prints ``name,us_per_call,derived`` CSV rows.

    PYTHONPATH=src python -m benchmarks.run [--quick] [--only fig6_8,...]
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback

MODULES = (
    "fig6_8_convergence",   # Figs 6 & 8: the nine algorithms, error vs time
    "table3_breakdown",     # Table 3 / Fig 11: breakdown + 5.3x
    "fig10_packing",        # Fig 10: packed vs per-layer communication
    "fig12_partitioning",   # Fig 12: chip partitioning sweep
    "table4_weakscaling",   # Table 4: weak scaling to 4352 cores
    "kernels_bench",        # Pallas kernel oracles + TPU projections
    "roofline",             # §Roofline table from the dry-run JSONL
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    failures = []
    for name in MODULES:
        if only and name not in only:
            continue
        t0 = time.time()
        print(f"# === benchmarks.{name} ===", flush=True)
        try:
            mod = __import__(f"benchmarks.{name}", fromlist=["main"])
            mod.main(quick=args.quick)
            print(f"# {name} done in {time.time()-t0:.1f}s", flush=True)
        except Exception:
            failures.append(name)
            print(f"# {name} FAILED:\n{traceback.format_exc()}", flush=True)
    if failures:
        print(f"# FAILURES: {failures}", flush=True)
        sys.exit(1)


if __name__ == "__main__":
    main()
