"""Benchmark driver: one module per paper table/figure (+ kernels +
roofline). Prints ``name,us_per_call,derived`` CSV rows.

    PYTHONPATH=src python -m benchmarks.run [--quick] [--only fig6_8,...]
                                            [--json]

``--json`` additionally writes one ``BENCH_<name>.json`` per module at the
repo root (rows + status + wall time) so the perf trajectory across PRs is
machine-readable, and appends the same record to
``bench_history/<name>/<git-sha>.json`` — the trail
``python -m repro.obs.regress bench_history/<name>`` gates on.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
import traceback

from benchmarks import common

MODULES = (
    "fig6_8_convergence",   # Figs 6 & 8: the nine algorithms, error vs time
    "table3_breakdown",     # Table 3 / Fig 11: breakdown + 5.3x
    "fig10_packing",        # Fig 10: packed vs per-layer communication
    "fig12_partitioning",   # Fig 12: chip partitioning sweep
    "table4_weakscaling",   # Table 4: weak scaling to 4352 cores
    "kernels_bench",        # Pallas kernel oracles + TPU projections
    "roofline",             # §Roofline table from the dry-run JSONL
)

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None)
    ap.add_argument("--json", action="store_true",
                    help="write BENCH_<name>.json per module at repo root")
    ap.add_argument("--real", action="store_true",
                    help="forward real=True to modules whose main() takes "
                         "it (fig6_8, table4: execute on the repro.ps "
                         "runtime instead of modeling only)")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    failures = []
    for name in MODULES:
        if only and name not in only:
            continue
        t0 = time.time()
        print(f"# === benchmarks.{name} ===", flush=True)
        if args.json:
            common.begin_json_capture()
        ok = True
        try:
            mod = __import__(f"benchmarks.{name}", fromlist=["main"])
            kw = {}
            if args.real:
                import inspect
                if "real" in inspect.signature(mod.main).parameters:
                    kw["real"] = True
            mod.main(quick=args.quick, **kw)
            print(f"# {name} done in {time.time()-t0:.1f}s", flush=True)
        except Exception:
            ok = False
            failures.append(name)
            print(f"# {name} FAILED:\n{traceback.format_exc()}", flush=True)
        if args.json:
            rows, module_meta = common.end_json_capture()
            rec = {"module": name, "ok": ok, "quick": args.quick,
                   "elapsed_s": round(time.time() - t0, 3),
                   "meta": {**common.run_metadata(), **module_meta},
                   "rows": rows}
            path = os.path.join(REPO_ROOT, f"BENCH_{name}.json")
            with open(path, "w") as f:
                json.dump(rec, f, indent=1)
            print(f"# wrote {path}", flush=True)
            # history trail for the regression gate: one file per commit,
            # newest-two compared by `repro.obs.regress bench_history/...`
            sha = str(rec["meta"].get("git_sha") or "nosha")[:12]
            hist_dir = os.path.join(REPO_ROOT, "bench_history", name)
            os.makedirs(hist_dir, exist_ok=True)
            hist_path = os.path.join(hist_dir, f"{sha}.json")
            with open(hist_path, "w") as f:
                json.dump(rec, f, indent=1)
            print(f"# appended {hist_path}", flush=True)
    if failures:
        print(f"# FAILURES: {failures}", flush=True)
        sys.exit(1)


if __name__ == "__main__":
    main()
