"""Shared benchmark scaffolding: a tiny trainable problem + timing setup."""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp
from jax import flatten_util

from repro.core import costmodel
from repro.core.async_engine import PSEngine, SimConfig
from repro.core.easgd import EASGDConfig
from repro.data.synthetic import make_classification_dataset
from repro.models import cnn


def make_mlp_problem(seed: int = 0, n_train: int = 4096, n_test: int = 1024,
                     d_in: int = 64, batch: int = 64, noise: float = 1.6):
    """A LeNet-stand-in classification problem small enough for this CPU
    but hard enough that optimizer schedules separate. Returns
    (w0_flat, grad_fn, err_fn, nbytes)."""
    x, y = make_classification_dataset(n_train + n_test, shape=(d_in,),
                                       noise=noise, seed=seed)
    xtr, ytr = x[:n_train], y[:n_train]
    xte, yte = x[n_train:], y[n_train:]
    params = cnn.mlp_init(jax.random.PRNGKey(seed), d_in=d_in, d_hidden=64,
                          depth=2)
    flat, unravel = flatten_util.ravel_pytree(params)

    @jax.jit
    def loss_flat(w, xb, yb):
        return cnn.xent_loss(cnn.mlp_apply(unravel(w), xb), yb)

    gfn = jax.jit(jax.grad(loss_flat))

    @jax.jit
    def err_flat(w):
        return 1.0 - cnn.accuracy(cnn.mlp_apply(unravel(w), xte), yte)

    rngs = {}

    def grad_fn(w, step, worker):
        rng = rngs.setdefault(worker, np.random.RandomState(1000 + worker))
        idx = rng.randint(0, n_train, size=batch)
        return np.asarray(gfn(jnp.asarray(w, jnp.float32), xtr[idx], ytr[idx]),
                          np.float64)

    def err_fn(w):
        return float(err_flat(jnp.asarray(w, jnp.float32)))

    return np.asarray(flat, np.float64), grad_fn, err_fn


def default_engine(seed=0, n_workers=4, t_compute=2e-3, **problem_kw):
    w0, grad_fn, err_fn = make_mlp_problem(seed=seed, **problem_kw)
    easgd = EASGDConfig(eta=0.05, rho=0.05, mu=0.9)
    sim = SimConfig(n_workers=n_workers, t_compute=t_compute, seed=seed)
    return PSEngine(grad_fn, err_fn, w0, easgd, sim)


_JSON_ROWS = None  # when a list, csv_row also records rows for --json output
_JSON_META = None  # module-contributed metadata for the current capture


def run_metadata() -> dict:
    """Environment fingerprint embedded in every BENCH_*.json so results
    are comparable across PRs: git SHA, library versions, machine shape."""
    import os
    import platform
    import subprocess
    import sys
    try:
        sha = subprocess.run(
            ["git", "rev-parse", "HEAD"], capture_output=True, text=True,
            cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
            timeout=10).stdout.strip() or None
    except Exception:                # noqa: BLE001 — metadata best-effort
        sha = None
    try:
        jax_version = jax.__version__
    except Exception:                # noqa: BLE001
        jax_version = None
    return {
        "git_sha": sha,
        "jax_version": jax_version,
        "numpy_version": np.__version__,
        "python": sys.version.split()[0],
        "platform": platform.platform(),
        "n_cpus": os.cpu_count(),
        "argv": sys.argv[1:],
    }


def json_meta(**kw) -> None:
    """Attach module-level run parameters (schedule, n_workers/pods, …) to
    the current --json capture; merged into the BENCH_*.json 'meta'."""
    if _JSON_META is not None:
        _JSON_META.update(kw)


def begin_json_capture():
    global _JSON_ROWS, _JSON_META
    _JSON_ROWS = []
    _JSON_META = {}


def end_json_capture() -> tuple:
    """-> (rows, module_meta)."""
    global _JSON_ROWS, _JSON_META
    rows, _JSON_ROWS = _JSON_ROWS, None
    meta, _JSON_META = _JSON_META, None
    return rows or [], meta or {}


def json_capture_active() -> bool:
    return _JSON_ROWS is not None


def csv_row(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.3f},{derived}")
    if _JSON_ROWS is not None:
        _JSON_ROWS.append(
            {"name": name, "us_per_call": us_per_call, "derived": derived})
