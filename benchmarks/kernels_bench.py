"""Microbenchmarks of the Pallas kernel oracles on CPU (wall time) + the
analytic TPU projection of each kernel's HBM-bound runtime.

(The Pallas kernels themselves validate in interpret mode; wall-clock here
measures the XLA oracle path — the kernels' TPU benefit is reported via the
bandwidth model, since this container has no TPU.)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import csv_row
from repro.core import costmodel
from repro.kernels import ref
from repro.utils.timing import time_fn


def run(quick: bool = False):
    key = jax.random.PRNGKey(0)
    chip = costmodel.TPU_V5E

    # fused elastic update: bandwidth floor = 5 reads + 3 writes
    n = 1 << 20
    ks = jax.random.split(key, 5)
    bufs = [jax.random.normal(k, (n,)) for k in ks]
    fn = jax.jit(lambda *b: ref.elastic_update_ref(
        *b, eta=0.01, rho=0.01, mu=0.9, n_workers=2))
    t = time_fn(fn, *bufs, iters=5)
    ideal_tpu = 8 * n * 4 / chip.hbm_bandwidth
    naive_tpu = 18 * n * 4 / chip.hbm_bandwidth   # unfused: each eq re-reads
    csv_row("kernels/elastic_update_oracle", t * 1e6,
            f"tpu_ideal={ideal_tpu*1e6:.1f}us;"
            f"tpu_unfused={naive_tpu*1e6:.1f}us;"
            f"fusion_win={naive_tpu/ideal_tpu:.2f}x")

    # flash attention: HBM O(S·D) vs naive O(S^2)
    B, S, H, D = 1, 1024 if quick else 2048, 4, 64
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, S, H, D), jnp.bfloat16)
    k = jax.random.normal(ks[1], (B, S, H, D), jnp.bfloat16)
    v = jax.random.normal(ks[2], (B, S, H, D), jnp.bfloat16)
    from repro.models.attention import blocked_attention
    fa = jax.jit(lambda q, k, v: blocked_attention(q, k, v, causal=True))
    t = time_fn(fa, q, k, v, iters=3)
    flash_bytes = 4 * B * S * H * D * 2
    naive_bytes = flash_bytes + 2 * B * H * S * S * 4
    csv_row("kernels/flash_attention_oracle", t * 1e6,
            f"S={S};tpu_hbm_flash={flash_bytes/chip.hbm_bandwidth*1e6:.1f}us;"
            f"tpu_hbm_naive={naive_bytes/chip.hbm_bandwidth*1e6:.1f}us")

    # ssd intra-chunk
    BH, S2, P_, N, L = 8, 512 if quick else 1024, 64, 128, 128
    ks = jax.random.split(key, 4)
    a = -jax.nn.softplus(jax.random.normal(ks[0], (BH, S2)))
    x = jax.random.normal(ks[1], (BH, S2, P_))
    b = jax.random.normal(ks[2], (BH, S2, N))
    c = jax.random.normal(ks[3], (BH, S2, N))
    fs = jax.jit(lambda a, x, b, c: ref.ssd_intra_ref(a, x, b, c, chunk=L))
    t = time_fn(fs, a, x, b, c, iters=3)
    flops = 2 * BH * S2 * L * (N + P_)
    csv_row("kernels/ssd_intra_oracle", t * 1e6,
            f"tpu_mxu={flops/costmodel.TPU_V5E.peak_flops*1e6:.2f}us")


def main(quick: bool = False):
    run(quick)


if __name__ == "__main__":
    main()
