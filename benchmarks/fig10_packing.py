"""Paper Fig 10 / §5.2: packed single-buffer vs per-layer communication.

Two measurements:
 1. α–β model (paper's own argument): L small messages vs 1 packed message
    on the paper's interconnects (Table 2) and on TPU ICI.
 2. REAL wall-clock microbenchmark on host devices: psum of L small arrays
    vs one packed flat buffer (8 host devices — the schedule effect is
    hardware-independent even if constants differ).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import csv_row
from repro.core import costmodel


# layer sizes of a LeNet-like net (paper's MNIST model): many small tensors
LENET_LAYER_BYTES = [600 * 4, 24 * 4, 2_400 * 4, 64 * 4, 150_000 * 4,
                     480 * 4, 40_000 * 4, 336 * 4, 3_360 * 4, 40 * 4]
# AlexNet-ish (paper Fig 10 uses AlexNet): 249 MB over ~16 tensors
ALEXNET_LAYER_BYTES = [
    35_000 * 4, 96 * 4, 614_000 * 4, 256 * 4, 885_000 * 4, 384 * 4,
    1_327_000 * 4, 384 * 4, 884_000 * 4, 256 * 4, 37_750_000 * 4,
    4_096 * 4, 16_777_000 * 4, 4_096 * 4, 4_096_000 * 4, 1_000 * 4,
]


def run_model(quick: bool = False):
    for net in (costmodel.MELLANOX_FDR, costmodel.INTEL_QDR,
                costmodel.INTEL_10GBE, costmodel.TPU_ICI):
        for name, sizes in (("lenet", LENET_LAYER_BYTES),
                            ("alexnet", ALEXNET_LAYER_BYTES)):
            p = 16
            t_unpacked = costmodel.t_per_layer(sizes, p, net)
            t_packed = costmodel.t_packed(sizes, p, net)
            csv_row(
                f"fig10/model/{net.name.replace(' ', '_')}/{name}",
                t_packed * 1e6,
                f"unpacked={t_unpacked*1e6:.1f}us;"
                f"speedup={t_unpacked/t_packed:.2f}x")


def run_measured(quick: bool = False):
    import jax
    import jax.numpy as jnp
    from repro.utils.jaxcompat import shard_map
    from repro.utils.timing import time_fn

    n_dev = jax.device_count()
    if n_dev < 2:
        csv_row("fig10/measured/skipped", 0.0, f"only {n_dev} device")
        return
    mesh = jax.make_mesh((n_dev,), ("x",))
    sizes = [s // 4 for s in LENET_LAYER_BYTES]
    arrs = [jnp.ones((n_dev, s), jnp.float32) for s in sizes]
    packed = jnp.ones((n_dev, sum(sizes)), jnp.float32)
    from jax.sharding import PartitionSpec as P
    from functools import partial

    @partial(shard_map, mesh=mesh, in_specs=(P("x"),) * len(arrs),
             out_specs=(P("x"),) * len(arrs), check_vma=False)
    def per_layer(*xs):
        return tuple(jax.lax.psum(x, "x") for x in xs)

    @partial(shard_map, mesh=mesh, in_specs=P("x"), out_specs=P("x"),
             check_vma=False)
    def one_packed(x):
        return jax.lax.psum(x, "x")

    t_u = time_fn(jax.jit(per_layer), *arrs, iters=5)
    t_p = time_fn(jax.jit(one_packed), packed, iters=5)
    csv_row("fig10/measured/per_layer", t_u * 1e6, f"{len(arrs)}_psums")
    csv_row("fig10/measured/packed", t_p * 1e6,
            f"speedup={t_u/max(t_p,1e-12):.2f}x")


def main(quick: bool = False):
    run_model(quick)
    run_measured(quick)


if __name__ == "__main__":
    main()
