"""Paper Fig 12 / §6.2: partitioning the chip into P groups (divide-and-
conquer). Paper: AlexNet/CIFAR on KNL — 1/4/8/16 parts give 1605/1025/823/
490 s to equal accuracy (≈3.3× at 16 parts), limited by MCDRAM capacity
(16 parts × (249 MB weights + 687 MB data) ≈ 15 GB ≈ MCDRAM).

We reproduce the sweep with the DES partition model on the paper's KNL
constants, then project the same divide-and-conquer onto a TPU v5e pod
(pods = NUMA groups — the DESIGN.md mapping).
"""
from __future__ import annotations

from benchmarks.common import csv_row
from repro.core import costmodel
from repro.core.des import partition_sweep_time

ALEXNET_BYTES = 249e6
CIFAR_BYTES = 687e6
MCDRAM = 16e9


def run(quick: bool = False):
    # per-epoch single-group compute time calibrated to the paper's 1-part
    # case (1605 s to target accuracy)
    t1 = 1605.0
    knl_internal = costmodel.Network("KNL on-chip", 2e-6, 1 / 100e9)
    base = None
    for parts in (1, 4, 8, 16, 32):
        t = partition_sweep_time(
            parts, t_compute_1=t1, weight_bytes=ALEXNET_BYTES,
            fast_mem_bytes=MCDRAM, data_bytes=CIFAR_BYTES, net=knl_internal)
        if base is None:
            base = t
        csv_row(f"fig12/knl/{parts}_parts", t * 1e6,
                f"t={t:.0f}s;speedup={base/t:.2f}x")
    # paper's observed points for comparison
    for parts, t_paper in ((1, 1605), (4, 1025), (8, 823), (16, 490)):
        csv_row(f"fig12/paper_reference/{parts}_parts", 0.0, f"{t_paper}s")

    # TPU projection: pods as groups (gemma3-4b train_4k per-step compute)
    w_bytes = 3.9e9 * 4
    for pods in (1, 2, 4, 8):
        t = partition_sweep_time(
            pods, t_compute_1=2.0, weight_bytes=w_bytes,
            fast_mem_bytes=float("inf"), data_bytes=0.0,
            net=costmodel.TPU_DCI)
        csv_row(f"fig12/tpu_pods/{pods}", t * 1e6, f"t_step_eff={t:.3f}s")


def main(quick: bool = False):
    run(quick)


if __name__ == "__main__":
    main()
