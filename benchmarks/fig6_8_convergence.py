"""Paper Figs 6 & 8: convergence of the nine methods (REAL training +
event-driven time model).

Two regimes, mirroring the paper's setting (deep nets, aggressive rates,
4-8 stale workers):

 * STRESSED (η=0.7, 8 workers): staleness-amplified plain SGD diverges
   while the elastic family stays stable — this is where the paper's
   orderings live:
     (1) Async EASGD beats Async SGD          (Fig 6.1)
     (3) Hogwild EASGD beats Hogwild SGD      (Fig 6.3)
     (4) Sync EASGD beats Original EASGD      (Fig 6.4; Θ(log P) vs Θ(P))
     (5) Sync/Hogwild EASGD fastest overall   (Fig 8)
 * STABLE (η=0.015): all methods converge; here the momentum claim shows:
     (2) Async MEASGD beats Async MSGD        (Fig 6.2 — worker-side
         momentum is stable where master-side momentum compounds with
         asynchrony-induced implicit momentum)

Emits one CSV row per method per regime + PASS/FAIL per claim.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import csv_row, make_mlp_problem
from repro.core.async_engine import ALGORITHMS, PSEngine, SimConfig
from repro.core.easgd import EASGDConfig


def time_to_target(history, target_err):
    for t, it, err in history:
        if err <= target_err:
            return t
    return float("inf")


def _run_regime(tag, eta, rho, n_workers, iters, seed=0, batch=16,
                noise=2.0):
    w0, grad_fn, err_fn = make_mlp_problem(seed=seed, noise=noise,
                                           batch=batch)
    eng = PSEngine(grad_fn, err_fn, w0,
                   EASGDConfig(eta=eta, rho=rho, mu=0.9),
                   SimConfig(n_workers=n_workers, t_compute=2e-3, seed=seed))
    out = {}
    for algo in ALGORITHMS:
        res = eng.run(algo, total_iters=iters)
        out[algo] = res
        csv_row(f"fig6_8/{tag}/{algo}",
                1e6 * res.total_time_s / max(res.total_iters, 1),
                f"final_err={res.final_metric:.3f};"
                f"t_to_0.25={time_to_target(res.history, 0.25):.3f}s")
    return out


def run(iters: int = 1500, seed: int = 0, quick: bool = False):
    if quick:
        iters = 1000

    stressed = _run_regime("stressed", eta=0.7, rho=0.3, n_workers=8,
                           iters=iters, seed=seed)
    # momentum regime: η where master-side momentum (MSGD) already
    # destabilizes under staleness but worker-side momentum (MEASGD) is fine
    stable = _run_regime("momentum", eta=0.1, rho=0.3, n_workers=8,
                         iters=max(iters // 2, 600), seed=seed)

    conv = lambda r: r.final_metric < 0.25          # converged?
    t25 = lambda r: time_to_target(r.history, 0.25)

    checks = {
        # Fig 6.1 / 6.3: elastic variants survive the stressed regime that
        # breaks their plain counterparts
        "async_easgd_beats_async_sgd":
            conv(stressed["async_easgd"]) and (
                not conv(stressed["async_sgd"])
                or t25(stressed["async_easgd"]) <= t25(stressed["async_sgd"])),
        "hogwild_easgd_beats_hogwild_sgd":
            conv(stressed["hogwild_easgd"]) and (
                not conv(stressed["hogwild_sgd"])
                or t25(stressed["hogwild_easgd"])
                <= t25(stressed["hogwild_sgd"])),
        # Fig 6.2: worker-side momentum stable where master-side is not
        "async_measgd_beats_async_msgd":
            t25(stable["async_measgd"]) <= t25(stable["async_msgd"]),
        # Fig 6.4: tree-reduction Sync EASGD ≫ round-robin Original
        "sync_easgd_beats_original":
            t25(stressed["sync_easgd"]) <= t25(stressed["original_easgd"]),
        # Fig 8: Sync/Hogwild EASGD tied-fastest among converged methods
        "sync_or_hogwild_easgd_fastest": (
            min(t25(stressed["sync_easgd"]), t25(stressed["hogwild_easgd"]))
            <= 1.05 * min((t25(r) for a, r in stressed.items()
                           if conv(r) and a not in ("sync_easgd",
                                                    "hogwild_easgd")),
                          default=float("inf"))
            or min(t25(stressed["sync_easgd"]),
                   t25(stressed["hogwild_easgd"])) < float("inf")
            and not any(conv(r) for a, r in stressed.items()
                        if a in ("async_sgd", "hogwild_sgd", "sync_sgd"))),
    }
    for k, v in checks.items():
        csv_row(f"fig6_8/check/{k}", 0.0, "PASS" if v else "FAIL")
    return (stressed, stable), checks


def main(quick: bool = False):
    run(quick=quick)


if __name__ == "__main__":
    main()
