"""Paper Figs 6 & 8: convergence of the nine methods (REAL training +
event-driven time model).

Two regimes, mirroring the paper's setting (deep nets, aggressive rates,
4-8 stale workers):

 * STRESSED (η=0.7, 8 workers): staleness-amplified plain SGD diverges
   while the elastic family stays stable — this is where the paper's
   orderings live:
     (1) Async EASGD beats Async SGD          (Fig 6.1)
     (3) Hogwild EASGD beats Hogwild SGD      (Fig 6.3)
     (4) Sync EASGD beats Original EASGD      (Fig 6.4; Θ(log P) vs Θ(P))
     (5) Sync/Hogwild EASGD fastest overall   (Fig 8)
 * STABLE (η=0.015): all methods converge; here the momentum claim shows:
     (2) Async MEASGD beats Async MSGD        (Fig 6.2 — worker-side
         momentum is stable where master-side momentum compounds with
         asynchrony-induced implicit momentum)

Emits one CSV row per method per regime + PASS/FAIL per claim.

``--real`` additionally runs every algorithm on the repro.ps runtime (real
multiprocessing workers + thread-transport smoke, deadline-paced emulated
wire — see repro.ps.runtime) and writes ``BENCH_ps_runtime.json``:
measured vs DES-predicted time-per-iteration, accuracy-vs-time curves for
both clocks, the sync schedule sweep with executed-round counts, the
paper-ordering checks, and a TCP-transport sweep (repro.net: real worker
processes behind real sockets, the loopback link's measured α–β, and the
sign-EF wire-compression bytes/round comparison at matched loss), plus
the bucketed-overlap row: the measured exposed-comm fraction of the same
deterministic p2p run monolithic / bucketed-inline / bucketed-overlapped,
bitwise-checked across all three (DESIGN.md §net bucketing).
"""
from __future__ import annotations

import dataclasses
import json
import os
import time

from benchmarks.common import csv_row, json_meta, make_mlp_problem, \
    run_metadata
from repro.core.async_engine import ALGORITHMS, PSEngine, SimConfig
from repro.core.easgd import EASGDConfig


def time_to_target(history, target_err):
    for t, it, err in history:
        if err <= target_err:
            return t
    return float("inf")


def _run_regime(tag, eta, rho, n_workers, iters, seed=0, batch=16,
                noise=2.0):
    w0, grad_fn, err_fn = make_mlp_problem(seed=seed, noise=noise,
                                           batch=batch)
    eng = PSEngine(grad_fn, err_fn, w0,
                   EASGDConfig(eta=eta, rho=rho, mu=0.9),
                   SimConfig(n_workers=n_workers, t_compute=2e-3, seed=seed))
    out = {}
    for algo in ALGORITHMS:
        res = eng.run(algo, total_iters=iters)
        out[algo] = res
        csv_row(f"fig6_8/{tag}/{algo}",
                1e6 * res.total_time_s / max(res.total_iters, 1),
                f"final_err={res.final_metric:.3f};"
                f"t_to_0.25={time_to_target(res.history, 0.25):.3f}s")
    return out


def run(iters: int = 1500, seed: int = 0, quick: bool = False):
    if quick:
        iters = 1000

    stressed = _run_regime("stressed", eta=0.7, rho=0.3, n_workers=8,
                           iters=iters, seed=seed)
    # momentum regime: η where master-side momentum (MSGD) already
    # destabilizes under staleness but worker-side momentum (MEASGD) is fine
    stable = _run_regime("momentum", eta=0.1, rho=0.3, n_workers=8,
                         iters=max(iters // 2, 600), seed=seed)

    conv = lambda r: r.final_metric < 0.25          # converged?
    t25 = lambda r: time_to_target(r.history, 0.25)

    checks = {
        # Fig 6.1 / 6.3: elastic variants survive the stressed regime that
        # breaks their plain counterparts
        "async_easgd_beats_async_sgd":
            conv(stressed["async_easgd"]) and (
                not conv(stressed["async_sgd"])
                or t25(stressed["async_easgd"]) <= t25(stressed["async_sgd"])),
        "hogwild_easgd_beats_hogwild_sgd":
            conv(stressed["hogwild_easgd"]) and (
                not conv(stressed["hogwild_sgd"])
                or t25(stressed["hogwild_easgd"])
                <= t25(stressed["hogwild_sgd"])),
        # Fig 6.2: worker-side momentum stable where master-side is not
        "async_measgd_beats_async_msgd":
            t25(stable["async_measgd"]) <= t25(stable["async_msgd"]),
        # Fig 6.4: tree-reduction Sync EASGD ≫ round-robin Original
        "sync_easgd_beats_original":
            t25(stressed["sync_easgd"]) <= t25(stressed["original_easgd"]),
        # Fig 8: Sync/Hogwild EASGD tied-fastest among converged methods
        "sync_or_hogwild_easgd_fastest": (
            min(t25(stressed["sync_easgd"]), t25(stressed["hogwild_easgd"]))
            <= 1.05 * min((t25(r) for a, r in stressed.items()
                           if conv(r) and a not in ("sync_easgd",
                                                    "hogwild_easgd")),
                          default=float("inf"))
            or min(t25(stressed["sync_easgd"]),
                   t25(stressed["hogwild_easgd"])) < float("inf")
            and not any(conv(r) for a, r in stressed.items()
                        if a in ("async_sgd", "hogwild_sgd", "sync_sgd"))),
    }
    for k, v in checks.items():
        csv_row(f"fig6_8/check/{k}", 0.0, "PASS" if v else "FAIL")
    return (stressed, stable), checks


# ---------------------------------------------------------------------------
# --real: the repro.ps runtime vs its own calibrated DES prediction
# ---------------------------------------------------------------------------

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SYNC_SCHEDULES = ("ring", "tree", "butterfly", "round_robin", "hierarchical")


def _one_real(ps, cal, easgd, cfg, net):
    """One algorithm through the shared measured-vs-DES protocol
    (``repro.ps.run_vs_des``) on the benchmark problem."""
    del net  # the protocol charges cfg.emulate_net to both clocks
    _, _, record = ps.run_vs_des(ps.NUMPY_MLP_MED, easgd, cfg, cal=cal)
    return record


def run_real(iters: int = 240, n_workers: int = 4, seed: int = 0,
             quick: bool = False, out_path: str | None = None) -> dict:
    from repro import ps
    from repro.core import costmodel

    if quick:
        iters = 120
    net = costmodel.PS_WIRE
    easgd = EASGDConfig(eta=0.1, rho=0.1, mu=0.9)
    base = ps.PSConfig(
        algorithm="sync_easgd", n_workers=n_workers, transport="process",
        schedule="ring", total_iters=iters, eval_every_iters=max(iters // 6, 20),
        emulate_net=net, seed=seed)
    t0 = time.time()
    cal = ps.calibrate(ps.NUMPY_MLP_MED, base,
                       samples=10 if quick else 20)
    records = []
    for algo in ALGORITHMS:
        cfg = dataclasses.replace(base, algorithm=algo)
        rec = _one_real(ps, cal, easgd, cfg, net)
        records.append(rec)
        csv_row(f"ps_runtime/{algo}", rec["measured_us_per_iter"],
                f"des={rec['des_us_per_iter']:.1f}us;"
                f"ratio={rec['measured_over_des']:.2f};"
                f"ips={rec['iters_per_sec']:.1f};"
                f"err={rec['final_err']:.3f}")

    # sync_easgd under every registered schedule: the measured clock must
    # track the registry's per-schedule pricing, and the executed round
    # count must equal the registry's round structure
    from repro import comm
    sweep = []
    sweep_schedules = SYNC_SCHEDULES[:2] if quick else SYNC_SCHEDULES
    for sched in sweep_schedules:
        cfg = dataclasses.replace(base, algorithm="sync_easgd",
                                  schedule=sched,
                                  total_iters=max(iters // 2, 60))
        rec = _one_real(ps, cal, easgd, cfg, net)
        n_rounds = -(-cfg.total_iters // n_workers)
        expect_rounds = n_rounds * len(comm.get(sched).rounds(n_workers))
        rec["expected_sync_rounds"] = expect_rounds
        rec["rounds_match"] = rec["counters"]["sync_rounds"] == expect_rounds
        sweep.append(rec)
        csv_row(f"ps_runtime/sweep/{sched}", rec["measured_us_per_iter"],
                f"des={rec['des_us_per_iter']:.1f}us;"
                f"ratio={rec['measured_over_des']:.2f};"
                f"rounds={'OK' if rec['rounds_match'] else 'MISMATCH'}")

    # thread-transport smoke: both backends execute for real
    threads = []
    for algo in ("async_easgd", "sync_easgd"):
        cfg = dataclasses.replace(base, algorithm=algo, transport="thread",
                                  total_iters=max(iters // 2, 60))
        rec = _one_real(ps, cal, easgd, cfg, net)
        threads.append(rec)
        csv_row(f"ps_runtime/thread/{algo}", rec["measured_us_per_iter"],
                f"ratio={rec['measured_over_des']:.2f}")

    # tcp transport (repro.net): real worker processes behind real sockets,
    # same measured-vs-DES protocol; the calibration additionally reports
    # the loopback link's measured α–β. The sync rows run the paper's tree
    # schedule: its paced rounds dominate the centralized master's real
    # distribution frames, keeping the comparison wire-bound (the regime
    # the emulation exists to restore).
    tcp_base = dataclasses.replace(base, transport="tcp", schedule="tree",
                                   total_iters=max(iters // 2, 60))
    cal_tcp = ps.calibrate(ps.NUMPY_MLP_MED, tcp_base,
                           samples=10 if quick else 20)
    tcp_algos = (("sync_easgd", "async_easgd") if quick else
                 ("sync_easgd", "sync_sgd", "async_easgd", "hogwild_easgd",
                  "original_easgd"))
    tcp_records = []
    for algo in tcp_algos:
        cfg = dataclasses.replace(tcp_base, algorithm=algo)
        rec = _one_real(ps, cal_tcp, easgd, cfg, net)
        tcp_records.append(rec)
        csv_row(f"ps_runtime/tcp/{algo}", rec["measured_us_per_iter"],
                f"des={rec['des_us_per_iter']:.1f}us;"
                f"ratio={rec['measured_over_des']:.2f};"
                f"err={rec['final_err']:.3f}")

    # sign-EF on the wire: measured bytes/round vs raw f64 at matched loss
    # (per-link error feedback absorbs the 1-bit quantization)
    sign_runs = {}
    for codec in ("none", "sign_ef"):
        # long enough that per-link error feedback has absorbed the 1-bit
        # quantization transient — "matched loss" is an asymptotic claim
        cfg = dataclasses.replace(
            tcp_base, algorithm="async_easgd", wire_compression=codec,
            total_iters=max(2 * iters, 480))
        res = ps.run_ps(ps.NUMPY_MLP_MED, easgd, cfg)
        exchanges = max(res.counters["messages"] // 2, 1)
        sign_runs[codec] = {
            "wire_bytes": res.counters["wire_bytes"],
            "bytes_per_round": res.counters["wire_bytes"] / exchanges,
            "final_err": res.final_metric,
            "total_time_s": res.total_time_s,
        }
        csv_row(f"ps_runtime/tcp/sign_ef/{codec}",
                sign_runs[codec]["bytes_per_round"],
                f"err={res.final_metric:.3f}")
    bytes_ratio = (sign_runs["none"]["bytes_per_round"]
                   / max(sign_runs["sign_ef"]["bytes_per_round"], 1))

    # p2p sync data plane (repro.net.peer): the same deterministic
    # sync_easgd/ring run on both planes — identical final weights
    # (bitwise), while the Θ(P·N)-per-round master incast collapses to the
    # control plane's Θ(N_center) and the per-worker ring traffic spreads
    # ~2N(P−1)/P over direct worker↔worker links
    import numpy as _np
    p2p_rows, p2p_weights = [], {}
    for plane in ("master", "p2p"):
        cfg = dataclasses.replace(
            tcp_base, algorithm="sync_easgd", schedule="ring",
            sync_plane=plane, deterministic=True,
            total_iters=max(iters // 2, 60))
        res, _, rec = ps.run_vs_des(ps.NUMPY_MLP_MED, easgd, cfg,
                                    cal=cal_tcp)
        p2p_weights[plane] = res.center
        rec["sync_plane"] = plane
        rec["master_link_bytes"] = res.counters["master_link_bytes"]
        if plane == "p2p":
            rec["peer_link_bytes"] = res.counters["peer_link_bytes"]
            rec["max_peer_link_bytes"] = max(
                res.counters["peer_link_bytes"].values())
        p2p_rows.append(rec)
        csv_row(f"ps_runtime/tcp/p2p/{plane}", rec["measured_us_per_iter"],
                f"des={rec['des_us_per_iter']:.1f}us;"
                f"ratio={rec['measured_over_des']:.2f};"
                f"master_bytes={rec['master_link_bytes']}")
    p2p_reduction = (p2p_rows[0]["master_link_bytes"]
                     / max(p2p_rows[1]["master_link_bytes"], 1))
    p2p_bitwise = bool(_np.array_equal(p2p_weights["master"],
                                       p2p_weights["p2p"]))

    # bucketed overlap (ISSUE 6): the same deterministic sync_easgd/ring
    # p2p run three ways — monolithic, bucketed with the exchange inline
    # (wire fully exposed), bucketed with bucket i's SEGMENT frames flying
    # while bucket i-1's update computes. Bucketing is a VIEW of the
    # monolithic schedule (spans clipped at layer-aligned edges, never
    # re-chunked) so all three finish with bitwise-equal weights; only the
    # measured exposed-comm fraction moves. comm_s/exposed_s/overlapped_s
    # are worker-reported (BYE) and folded by the master.
    overlap_rows, overlap_weights = [], {}
    for variant, bb, ov in (("monolithic", 0, False),
                            ("bucketed_no_overlap", 4096, False),
                            ("bucketed_overlap", 4096, True)):
        cfg = dataclasses.replace(
            tcp_base, algorithm="sync_easgd", schedule="ring",
            sync_plane="p2p", deterministic=True,
            bucket_bytes=bb, overlap=ov,
            total_iters=max(iters // 2, 60))
        res, _, rec = ps.run_vs_des(ps.NUMPY_MLP_MED, easgd, cfg,
                                    cal=cal_tcp)
        overlap_weights[variant] = res.center
        c = res.counters
        worker_wall = n_workers * res.total_time_s
        rec.update({
            "variant": variant, "bucket_bytes": bb, "overlap": ov,
            "n_buckets": c.get("n_buckets", 1),
            "comm_s": c.get("comm_s", 0.0),
            "exposed_comm_s": c.get("exposed_s", 0.0),
            "overlapped_s": c.get("overlapped_s", 0.0),
            # fraction of total worker wall-clock spent BLOCKED on the
            # exchange — the paper's "communication fraction", measured
            "exposed_comm_fraction":
                c.get("exposed_s", 0.0) / max(worker_wall, 1e-9),
        })
        overlap_rows.append(rec)
        csv_row(f"ps_runtime/tcp/overlap/{variant}",
                rec["measured_us_per_iter"],
                f"comm_frac={rec['exposed_comm_fraction']:.3f};"
                f"overlapped={rec['overlapped_s']:.2f}s;"
                f"buckets={rec['n_buckets']}")
    overlap_by = {r["variant"]: r for r in overlap_rows}
    overlap_bitwise = all(
        _np.array_equal(overlap_weights["monolithic"], overlap_weights[v])
        for v in ("bucketed_no_overlap", "bucketed_overlap"))

    by = {r["algorithm"]: r for r in records}
    ips = {a: by[a]["iters_per_sec"] for a in by}
    checks = {
        # acceptance: DES within 2x for the sync algorithms + every sync
        # schedule of the sweep
        "des_within_2x_sync": all(
            0.5 <= r["measured_over_des"] <= 2.0
            for r in [by["sync_easgd"], by["sync_sgd"]] + sweep),
        # the paper's qualitative ordering, measured for real
        "sync_easgd_ge_async_easgd":
            ips["sync_easgd"] >= 0.95 * ips["async_easgd"],
        "async_easgd_gt_original":
            ips["async_easgd"] > ips["original_easgd"],
        "rounds_match_registry": all(r["rounds_match"] for r in sweep),
        # tcp acceptance: the DES (charged the same emulated wire) predicts
        # the SOCKET transport's measured clock within 2x as well
        "des_within_2x_tcp": all(
            0.5 <= r["measured_over_des"] <= 2.0 for r in tcp_records),
        # sign-EF wire: ≥4x fewer measured bytes/round at matched loss
        "sign_ef_wire_ge_4x": bytes_ratio >= 4.0,
        "sign_ef_matched_loss": (
            sign_runs["sign_ef"]["final_err"]
            <= sign_runs["none"]["final_err"] + 0.08),
        # p2p data plane acceptance (ISSUE 4): ≥4x fewer bytes through the
        # master link at bitwise-identical final weights
        "p2p_master_bytes_ge_4x": p2p_reduction >= 4.0,
        "p2p_bitwise_equal_weights": p2p_bitwise,
        # bucketed overlap acceptance (ISSUE 6): overlap measurably hides
        # wire time (some comm ran under compute, and the exposed comm
        # fraction drops vs the identical bucketed run without overlap),
        # at bitwise-identical final weights across all three variants
        "overlap_bitwise_equal_weights": overlap_bitwise,
        "overlap_hides_wire": (
            overlap_by["bucketed_overlap"]["overlapped_s"] > 0.0
            and overlap_by["bucketed_overlap"]["exposed_comm_fraction"]
            < overlap_by["bucketed_no_overlap"]["exposed_comm_fraction"]),
    }
    for k, v in checks.items():
        csv_row(f"ps_runtime/check/{k}", 0.0, "PASS" if v else "FAIL")

    out = {
        "meta": {
            **run_metadata(),
            "n_workers": n_workers, "iters": iters, "quick": quick,
            "transport": "process (+thread smoke)",
            "emulated_wire": {"name": net.name, "alpha_s": net.alpha,
                              "beta_s_per_byte": net.beta},
            "calibration": {
                "n_params": cal.n,
                "t_grad_serial_us": 1e6 * cal.t_grad_serial,
                "t_grad_concurrent_us": 1e6 * cal.t_grad_concurrent,
                "t_axpy_us": 1e6 * cal.t_axpy,
            },
            "elapsed_s": round(time.time() - t0, 1),
        },
        "algorithms": records,
        "sync_schedule_sweep": sweep,
        "thread_smoke": threads,
        "tcp": {
            "algorithms": tcp_records,
            "link_calibration": {
                "alpha_us": 1e6 * cal_tcp.link_alpha,
                "beta_s_per_byte": cal_tcp.link_beta,
            },
            "sign_ef": {**sign_runs, "bytes_per_round_ratio": bytes_ratio},
            "p2p": {
                "rows": p2p_rows,
                "master_link_bytes_reduction": p2p_reduction,
                "bitwise_equal_weights": p2p_bitwise,
            },
            "bucketed_overlap": {
                "rows": overlap_rows,
                "bitwise_equal_weights": overlap_bitwise,
            },
        },
        "checks": checks,
    }
    path = out_path or os.path.join(REPO_ROOT, "BENCH_ps_runtime.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=1)
    print(f"# wrote {path}")
    return out


def main(quick: bool = False, real: bool = False):
    run(quick=quick)
    json_meta(n_workers=8, regimes=["stressed", "momentum"],
              algorithms=list(ALGORITHMS))
    if real:
        run_real(quick=quick)


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--real", action="store_true",
                    help="also execute every algorithm on the repro.ps "
                         "runtime and write BENCH_ps_runtime.json")
    ap.add_argument("--only-real", action="store_true",
                    help="skip the DES-only figures, run just the ps part")
    args = ap.parse_args()
    if args.only_real:
        run_real(quick=args.quick)
    else:
        main(quick=args.quick, real=args.real)
