"""Paper Table 4: weak scaling of GoogleNet/VGG on ImageNet, 68→4352 cores
(1→64 KNL nodes). Paper results: GoogleNet 91.6% @ 64 nodes; VGG 80.2%.

Model: per-node compute constant (weak scaling); communication = packed
tree/ring all-reduce of the weights over Cray Aries (α–β). The SAME model
projects our Sync-EASGD TPU fleet: intra-pod gradient all-reduce over ICI +
cross-pod elastic exchange over DCI every τ steps.

``--real`` additionally EXECUTES the weak-scaling curve on the repro.ps
runtime at P ∈ {8, 16, 32, 64} under an emulated two-level topology
(P/8 hosts × 8 slots, cross-host links 20×α 4×β): every run is deadline-
paced per link class, the schedule sweep measures ring/butterfly vs the
topology-aware hierarchical, and ``comm.choose`` on the MEASURED link
profile must select the measured winner — the measured half of Table 4,
written next to the analytic rows.
"""
from __future__ import annotations

from benchmarks.common import csv_row, json_meta
from repro.comm import schedules as comm_schedules
from repro.core import costmodel
from repro.core.des import weak_scaling_efficiency

ARIES = costmodel.Network("Cray Aries", 1.5e-6, 1 / 8e9)
GOOGLENET_BYTES = 53e6 * 4 / 4      # ~53 MB fp32 weights
VGG_BYTES = 575e6                    # paper: VGG-19 575 MB

# per-iteration compute times calibrated from Table 4's single-node rows
T_GOOGLENET = 1533.0 / 300
T_VGG = 1318.0 / 80

PAPER = {
    "googlenet": {2: .964, 4: .953, 8: .934, 16: .940, 32: .923, 64: .916},
    "vgg": {2: .915, 4: .890, 8: .865, 16: .807, 32: .785, 64: .802},
}


def run(quick: bool = False):
    # Straggler-limited weak scaling: σ is CALIBRATED from the paper's
    # 2-node efficiency alone, then the 4..64-node curve is PREDICTED.
    from repro.core.des import jitter_from_two_node_eff
    for name, (t_c, w) in (("googlenet", (T_GOOGLENET, GOOGLENET_BYTES)),
                           ("vgg", (T_VGG, VGG_BYTES))):
        sigma = jitter_from_two_node_eff(PAPER[name][2])
        csv_row(f"table4/{name}/calibrated_sigma", 0.0, f"{sigma:.4f}")
        for nodes in (1, 2, 4, 8, 16, 32, 64):
            eff = weak_scaling_efficiency(
                nodes, t_compute=t_c, weight_bytes=w, net=ARIES,
                jitter_sigma=sigma, overlap=True)
            ref = PAPER[name].get(nodes)
            csv_row(f"table4/{name}/{nodes}_nodes", 0.0,
                    f"eff={eff:.3f}" + (f";paper={ref:.3f}" if ref else ""))

    # TPU fleet projection: Sync EASGD cross-pod exchange, gemma3-27b,
    # weights 27e9*4B packed, τ ∈ {1, 4}; 2..64 pods over DCI. Priced
    # through the shared repro.comm registry (psum = tuned-library best).
    w = 27e9 * 4.0
    t_step = 3.0
    for tau in (1, 4):
        for pods in (2, 4, 8, 16, 64):
            t_comm = comm_schedules.get("psum").cost(
                w, pods, costmodel.TPU_DCI) / tau
            eff = t_step / max(t_step, t_comm)
            csv_row(f"table4/tpu_gemma27b/tau{tau}/{pods}_pods", 0.0,
                    f"eff={eff:.3f}")

    # SCHEDULE SWEEP: the same τ=1 projection under every registered
    # schedule — at DCI bandwidth the round-robin baseline collapses while
    # ring stays bandwidth-bound (the paper's §5.1 argument at fleet scale).
    for name in comm_schedules.names():
        for pods in (2, 8, 64):
            t_comm = comm_schedules.get(name).cost(w, pods,
                                                   costmodel.TPU_DCI)
            eff = t_step / max(t_step, t_comm)
            frac = t_comm / (t_comm + t_step)
            csv_row(f"table4/tpu_gemma27b/sweep/{name}/{pods}_pods", 0.0,
                    f"eff={eff:.3f};comm_frac_noverlap={frac:.3f}")

    # TWO-LEVEL TOPOLOGY (analytic half of the scale-out curve): the same
    # KNL fleet re-priced on a hosts × slots fabric where cross-host Aries
    # hops cost 20×α 4×β — flat ring serializes every chunk through the
    # slow links while hierarchical (intra-host ring × cross-host
    # butterfly) pays them only ⌈log2 hosts⌉ times. Same cost fabric the
    # --real runs pace their sleeps on.
    w_g = GOOGLENET_BYTES
    for nodes in (8, 16, 32, 64):
        topo = costmodel.emulated_topology(max(nodes // 8, 1), 8,
                                           intra=ARIES)
        for name in ("ring", "butterfly", "hierarchical"):
            t_comm = comm_schedules.get(name).cost_topo(w_g, nodes, topo)
            eff = weak_scaling_efficiency(
                nodes, t_compute=T_GOOGLENET, weight_bytes=w_g, net=ARIES,
                schedule=name, topology=topo, overlap=False)
            csv_row(f"table4/two_level/googlenet/{name}/{nodes}_nodes",
                    1e6 * t_comm,
                    f"t_comm_ms={1e3 * t_comm:.2f};eff={eff:.4f};"
                    f"hosts={topo.hosts};slots={topo.slots}")
        chosen = comm_schedules.choose(w_g, nodes, topology=topo)
        csv_row(f"table4/two_level/googlenet/choose/{nodes}_nodes", 0.0,
                f"schedule={chosen}")


SLOTS = 8          # the canonical scale-out family: P/8 hosts x 8 slots


def run_real(quick: bool = False) -> dict:
    """Measured weak scaling on the repro.ps runtime: P ∈ {8,16,32,64}
    sync_easgd under a two-level emulated topology, schedule sweep
    (ring / butterfly / hierarchical) on the thread plane + an auto-chosen
    tcp-p2p point, every exchange deadline-paced per link class. Returns
    the structured curve (also emitted as csv rows / json_meta)."""
    import dataclasses

    from repro import ps
    from repro.core.easgd import EASGDConfig

    easgd = EASGDConfig(eta=0.1, rho=0.1, mu=0.9)
    p_list = (8, 16) if quick else (8, 16, 32, 64)
    sweep = ("ring", "butterfly", "hierarchical")
    exchanges = 2 if quick else 4
    curve = []
    for P in p_list:
        topo = costmodel.emulated_topology(max(P // SLOTS, 1), SLOTS)
        base = ps.PSConfig(algorithm="sync_easgd", n_workers=P,
                           transport="thread", schedule="hierarchical",
                           total_iters=exchanges * P,
                           eval_every_iters=10**9, deterministic=True,
                           topology=topo)
        # ONE calibration per P: measures the live mesh's link profile
        # (physical floor + emulated classes); the pacing itself uses the
        # declared topology, so every schedule run pays the same wire
        cal = ps.calibrate(ps.NUMPY_MLP, base)
        chosen = base.resolved_schedule(cal.n * 8, profile=cal.profile)
        point = {"p": P, "hosts": topo.hosts, "slots": topo.slots,
                 "transport": "thread", "chosen_schedule": chosen,
                 "profile_source": getattr(cal.profile, "source", None),
                 "schedules": {}}
        for name in sweep:
            cfg = dataclasses.replace(base, schedule=name)
            res, _, rec = ps.run_vs_des(ps.NUMPY_MLP, easgd, cfg, cal=cal)
            t_step_ms = rec["measured_us_per_iter"] * P / 1e3
            point["schedules"][name] = {
                "t_step_ms": round(t_step_ms, 3),
                "measured_us_per_iter": round(
                    rec["measured_us_per_iter"], 2),
                "des_us_per_iter": round(rec["des_us_per_iter"], 2),
                "measured_over_des": round(rec["measured_over_des"], 3),
            }
            csv_row(f"table4/real/thread/{name}/{P}_workers",
                    rec["measured_us_per_iter"],
                    f"t_step_ms={t_step_ms:.2f};"
                    f"ratio={rec['measured_over_des']:.2f}")
        best_flat = min(point["schedules"][n]["t_step_ms"]
                        for n in ("ring", "butterfly"))
        t_hier = point["schedules"]["hierarchical"]["t_step_ms"]
        winner = min(point["schedules"],
                     key=lambda n: point["schedules"][n]["t_step_ms"])
        point.update({
            "best_flat_t_step_ms": best_flat,
            "measured_winner": winner,
            # the acceptance pair: at P>=16 (multi-host) hierarchical must
            # measurably beat the best flat schedule AND comm.choose on
            # the MEASURED profile must pick it
            "hier_beats_best_flat": t_hier < best_flat,
            "choose_picks_winner": chosen == winner,
        })
        csv_row(f"table4/real/thread/choose/{P}_workers", 0.0,
                f"chosen={chosen};winner={winner};"
                f"hier_over_best_flat={t_hier / best_flat:.3f}")
        curve.append(point)

    # weak-scaling efficiency per schedule, normalized at the single-host
    # P=8 point (ideal weak scaling: t_step flat in P)
    base_ms = {n: curve[0]["schedules"][n]["t_step_ms"] for n in sweep}
    for point in curve:
        point["efficiency"] = {
            n: round(base_ms[n] / point["schedules"][n]["t_step_ms"], 3)
            for n in sweep}
        for n in sweep:
            csv_row(f"table4/real/eff/{n}/{point['p']}_workers", 0.0,
                    f"eff={point['efficiency'][n]:.3f}")

    # the same fabric over real sockets: tcp-p2p, schedule resolved by
    # comm.choose from the measured profile (P kept modest — each worker
    # is a spawned process on this box). Both grids are MULTI-host (2x4,
    # 2x8): a 1-host tcp grid would pace on the intra class alone, and
    # real socket overheads rather than the emulated fabric would
    # dominate the measured/DES comparison.
    tcp_points = []
    for P, hosts in (((8, 2),) if quick else ((8, 2), (16, 2))):
        topo = costmodel.emulated_topology(hosts, P // hosts)
        cfg = ps.PSConfig(algorithm="sync_easgd", n_workers=P,
                          transport="tcp", sync_plane="p2p",
                          schedule="auto", total_iters=exchanges * P,
                          eval_every_iters=10**9, deterministic=True,
                          topology=topo)
        res, _, rec = ps.run_vs_des(ps.NUMPY_MLP, easgd, cfg)
        tp = {"p": P, "hosts": topo.hosts, "slots": topo.slots,
              "transport": "tcp-p2p",
              "chosen_schedule": res.schedule,
              "measured_us_per_iter": round(rec["measured_us_per_iter"], 2),
              "measured_over_des": round(rec["measured_over_des"], 3),
              "intra_host_bytes": res.counters.get("intra_host_bytes"),
              "cross_host_bytes": res.counters.get("cross_host_bytes"),
              "profile_source": rec.get("profile_source")}
        csv_row(f"table4/real/tcp_p2p/{P}_workers",
                rec["measured_us_per_iter"],
                f"schedule={res.schedule};"
                f"ratio={rec['measured_over_des']:.2f}")
        tcp_points.append(tp)

    measured = {"slots": SLOTS, "cross_alpha_x": 20.0, "cross_beta_x": 4.0,
                "exchanges": exchanges, "thread_curve": curve,
                "tcp_p2p": tcp_points}
    json_meta(measured_weak_scaling=measured)
    return measured


def main(quick: bool = False, real: bool = False):
    run(quick)
    json_meta(schedules=list(comm_schedules.names()),
              pods=[2, 8, 64], nodes=[1, 2, 4, 8, 16, 32, 64])
    if real:
        run_real(quick=quick)


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--real", action="store_true",
                    help="also execute the measured P ∈ {8..64} curve on "
                         "the repro.ps runtime (thread sweep + tcp-p2p)")
    args = ap.parse_args()
    main(quick=args.quick, real=args.real)
