"""Paper Table 4: weak scaling of GoogleNet/VGG on ImageNet, 68→4352 cores
(1→64 KNL nodes). Paper results: GoogleNet 91.6% @ 64 nodes; VGG 80.2%.

Model: per-node compute constant (weak scaling); communication = packed
tree/ring all-reduce of the weights over Cray Aries (α–β). The SAME model
projects our Sync-EASGD TPU fleet: intra-pod gradient all-reduce over ICI +
cross-pod elastic exchange over DCI every τ steps.
"""
from __future__ import annotations

from benchmarks.common import csv_row, json_meta
from repro.comm import schedules as comm_schedules
from repro.core import costmodel
from repro.core.des import weak_scaling_efficiency

ARIES = costmodel.Network("Cray Aries", 1.5e-6, 1 / 8e9)
GOOGLENET_BYTES = 53e6 * 4 / 4      # ~53 MB fp32 weights
VGG_BYTES = 575e6                    # paper: VGG-19 575 MB

# per-iteration compute times calibrated from Table 4's single-node rows
T_GOOGLENET = 1533.0 / 300
T_VGG = 1318.0 / 80

PAPER = {
    "googlenet": {2: .964, 4: .953, 8: .934, 16: .940, 32: .923, 64: .916},
    "vgg": {2: .915, 4: .890, 8: .865, 16: .807, 32: .785, 64: .802},
}


def run(quick: bool = False):
    # Straggler-limited weak scaling: σ is CALIBRATED from the paper's
    # 2-node efficiency alone, then the 4..64-node curve is PREDICTED.
    from repro.core.des import jitter_from_two_node_eff
    for name, (t_c, w) in (("googlenet", (T_GOOGLENET, GOOGLENET_BYTES)),
                           ("vgg", (T_VGG, VGG_BYTES))):
        sigma = jitter_from_two_node_eff(PAPER[name][2])
        csv_row(f"table4/{name}/calibrated_sigma", 0.0, f"{sigma:.4f}")
        for nodes in (1, 2, 4, 8, 16, 32, 64):
            eff = weak_scaling_efficiency(
                nodes, t_compute=t_c, weight_bytes=w, net=ARIES,
                jitter_sigma=sigma, overlap=True)
            ref = PAPER[name].get(nodes)
            csv_row(f"table4/{name}/{nodes}_nodes", 0.0,
                    f"eff={eff:.3f}" + (f";paper={ref:.3f}" if ref else ""))

    # TPU fleet projection: Sync EASGD cross-pod exchange, gemma3-27b,
    # weights 27e9*4B packed, τ ∈ {1, 4}; 2..64 pods over DCI. Priced
    # through the shared repro.comm registry (psum = tuned-library best).
    w = 27e9 * 4.0
    t_step = 3.0
    for tau in (1, 4):
        for pods in (2, 4, 8, 16, 64):
            t_comm = comm_schedules.get("psum").cost(
                w, pods, costmodel.TPU_DCI) / tau
            eff = t_step / max(t_step, t_comm)
            csv_row(f"table4/tpu_gemma27b/tau{tau}/{pods}_pods", 0.0,
                    f"eff={eff:.3f}")

    # SCHEDULE SWEEP: the same τ=1 projection under every registered
    # schedule — at DCI bandwidth the round-robin baseline collapses while
    # ring stays bandwidth-bound (the paper's §5.1 argument at fleet scale).
    for name in comm_schedules.names():
        for pods in (2, 8, 64):
            t_comm = comm_schedules.get(name).cost(w, pods,
                                                   costmodel.TPU_DCI)
            eff = t_step / max(t_step, t_comm)
            frac = t_comm / (t_comm + t_step)
            csv_row(f"table4/tpu_gemma27b/sweep/{name}/{pods}_pods", 0.0,
                    f"eff={eff:.3f};comm_frac_noverlap={frac:.3f}")


def main(quick: bool = False):
    run(quick)
    json_meta(schedules=list(comm_schedules.names()),
              pods=[2, 8, 64], nodes=[1, 2, 4, 8, 16, 32, 64])


if __name__ == "__main__":
    main()
