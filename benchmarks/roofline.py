"""Roofline table from the dry-run JSONL (EXPERIMENTS.md §Roofline).

Reads results/dryrun.jsonl and emits, per (arch × shape × mesh):
  compute/memory/collective terms (s), dominant bottleneck, MODEL_FLOPS,
  MODEL_FLOPS/HLO_FLOPS, roofline fraction, fits-16GB.
"""
from __future__ import annotations

import json
import os

from benchmarks.common import csv_row

DEFAULT_PATH = os.environ.get("DRYRUN_JSONL", "results/dryrun.jsonl")


def load(path=DEFAULT_PATH):
    rows = {}
    if not os.path.exists(path):
        return rows
    for line in open(path):
        try:
            r = json.loads(line)
        except json.JSONDecodeError:
            continue
        if r.get("ok"):
            rows[(r["arch"], r["shape"], r["mesh_kind"])] = r
    return rows


def run(quick: bool = False, path=DEFAULT_PATH):
    rows = load(path)
    if not rows:
        csv_row("roofline/missing", 0.0, f"no dry-run results at {path}")
        return
    for (arch, shape, mesh), r in sorted(rows.items()):
        rl = r["roofline"]
        csv_row(
            f"roofline/{arch}/{shape}/{mesh}",
            rl["bound_s"] * 1e6,
            f"dom={rl['dominant']};c={rl['compute_s']:.2e};"
            f"m={rl['memory_s']:.2e};n={rl['collective_s']:.2e};"
            f"useful={r.get('useful_flops_ratio', 0):.2f};"
            f"frac={r.get('roofline_fraction', 0):.3f};"
            f"peakGiB={r.get('peak_bytes_per_device', 0)/2**30:.1f};"
            f"fits={r.get('fits_16gb')}")


def main(quick: bool = False):
    run(quick)


if __name__ == "__main__":
    main()
