"""Paper Table 3 / Fig 11: per-part time breakdown of the EASGD variants and
the end-to-end speedup of Sync EASGD3 over Original EASGD.

The paper's multi-GPU box is modeled with its own constants: PCIe-switch
links for CPU↔GPU and GPU↔GPU, measured fwd/bwd per batch, and the paper's
iteration counts (Original EASGD needs 5× the iterations of the sync
variants at equal accuracy because only one worker trains per iteration —
its Table 3: 5000 vs 1000). Claims checked:
  * communication share: Original ≈ 87%, Sync EASGD3 ≈ 14%
  * end-to-end speedup Sync EASGD3 vs Original ≈ 5.3×

Plus a SCHEDULE SWEEP over the shared ``repro.comm`` registry: the same
Sync-EASGD3 configuration priced under every registered exchange schedule,
reproducing the round-robin-vs-tree gap (§5.1) under otherwise identical
conditions.

``measured_breakdown`` (CLI: ``--real``) re-derives the SAME row from real
spans instead of the cost model: two traced runs of the PS runtime over
real TCP sockets under the emulated paper wire — the centralized
monolithic master plane vs the bucketed-overlapped p2p plane — with
``repro.obs`` tracing on, reading comm%/compute%/update% out of
``PSResult.trace["report"]``. The measured analogue of the 87%→14%
narrative: same optimizer bits, the exposed-communication share collapses
when the exchange is bucketed, peer-to-peer, and overlapped with compute.
Both breakdowns land in ``BENCH_table3_breakdown.json`` side by side.
"""
from __future__ import annotations

import json
import os

from benchmarks.common import csv_row, json_capture_active, \
    json_meta
from repro.comm import schedules as comm_schedules
from repro.core.des import (
    GPU_BOX, breakdown_original_easgd, breakdown_sync_easgd,
)

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run(quick: bool = False):
    box = GPU_BOX
    # paper Table 3 setup: MNIST/LeNet on 4 GPUs; |W| = LeNet ~ 1.7 MB but
    # paper's AlexNet-sized runs use 249 MB — we report LeNet (their Table 3)
    rows = {}
    rows["original_easgd"] = breakdown_original_easgd(box, iters=5000)
    rows["sync_easgd1"] = breakdown_sync_easgd(box, iters=1000,
                                               weights_on="cpu",
                                               overlap=False)
    rows["sync_easgd2"] = breakdown_sync_easgd(box, iters=1000,
                                               weights_on="gpu",
                                               overlap=False)
    rows["sync_easgd3"] = breakdown_sync_easgd(box, iters=1000,
                                               weights_on="gpu",
                                               overlap=True)

    for name, r in rows.items():
        csv_row(f"table3/{name}", 1e6 * r.total_s / r.iters,
                f"total={r.total_s:.2f}s;comm_ratio={r.comm_ratio:.2f}")

    speedup = rows["original_easgd"].total_s / rows["sync_easgd3"].total_s
    csv_row("table3/speedup_sync3_vs_original", 0.0,
            f"{speedup:.2f}x (paper: 5.3x)")
    csv_row("table3/comm_ratio_original", 0.0,
            f"{rows['original_easgd'].comm_ratio:.2f} (paper: 0.87)")
    csv_row("table3/comm_ratio_sync3", 0.0,
            f"{rows['sync_easgd3'].comm_ratio:.2f} (paper: 0.14)")
    return rows, speedup


def schedule_sweep(iters: int = 1000, json_path: str | None = None) -> dict:
    """Sync EASGD3 (weights on GPU, overlap) under EVERY registered exchange
    schedule — same box, same iteration count, only the wire schedule moves.
    Writes the per-part/comm-fraction breakdown as JSON."""
    box = GPU_BOX
    sweep = {}
    for name in comm_schedules.names():
        r = breakdown_sync_easgd(box, iters=iters, weights_on="gpu",
                                 overlap=True, schedule=name)
        sweep[name] = {
            "total_s": r.total_s,
            "us_per_iter": 1e6 * r.total_s / r.iters,
            "comm_ratio": r.comm_ratio,
            "parts_s": dict(r.parts),
        }
        csv_row(f"table3/sweep/{name}", sweep[name]["us_per_iter"],
                f"comm_ratio={r.comm_ratio:.3f}")
    gap = sweep["round_robin"]["total_s"] / sweep["tree"]["total_s"]
    csv_row("table3/sweep/round_robin_vs_tree", 0.0,
            f"{gap:.2f}x slower (the paper's §5.1 schedule gap)")
    out = {"box": "GPU_BOX", "iters": iters, "schedules": sweep,
           "round_robin_vs_tree": gap}
    json_meta(sweep_box="GPU_BOX", sweep_iters=iters,
              schedules=list(sweep))
    # written only on explicit request or under run.py --json, so a plain
    # CSV benchmark run never clobbers the committed trajectory record
    if json_path or json_capture_active():
        path = json_path or os.path.join(REPO_ROOT,
                                         "BENCH_table3_schedule_sweep.json")
        with open(path, "w") as f:
            json.dump(out, f, indent=1)
    return out


MEASURED_P = 3
MEASURED_BATCH = 256     # heavier gradients: compute ≈ wire under PS_WIRE,
MEASURED_TAU = 4         # so overlap can bite; τ=4 is the paper's own
#                          communication-period lever (same τ on BOTH planes)


def _traced_run(plane: str, iters: int):
    """One traced run on real TCP sockets under the emulated paper wire.

    ``plane="master_monolithic"`` is Original EASGD — the paper's 87% row:
    every exchange moves monolithically through the master's links and the
    wire itself serializes the whole pipeline (Θ(P) turns, zero overlap).
    ``plane="p2p_overlap"`` is the Sync-EASGD3 analogue — the 14% row:
    layer-aligned buckets stream worker↔worker while the exchange-step
    gradient computes, per-bucket updates applied as buckets land."""
    from repro import ps
    from repro.core import costmodel
    from repro.core.easgd import EASGDConfig

    if plane == "master_monolithic":
        kw = dict(algorithm="original_easgd")
    else:
        kw = dict(algorithm="sync_easgd", schedule="ring",
                  sync_plane="p2p", bucket_bytes=4096, overlap=True)
    cfg = ps.PSConfig(
        n_workers=MEASURED_P, transport="tcp", total_iters=iters,
        eval_every_iters=10**9, emulate_net=costmodel.PS_WIRE,
        trace=True, **kw)
    return ps.run_ps(
        ps.spec("repro.ps.problems:make_numpy_mlp", batch=MEASURED_BATCH),
        EASGDConfig(eta=0.1, rho=0.1, mu=0.9, tau=MEASURED_TAU), cfg,
        join_timeout_s=300.0)


def _validate_chrome(trace: dict, P: int) -> bool:
    """The merged export must round-trip as JSON and put all P workers on
    one aligned timeline (one pid per worker)."""
    from repro.obs import report as obs_report

    ct = json.loads(json.dumps(obs_report.chrome_trace(trace)))
    events = ct.get("traceEvents", [])
    pids = {e["pid"] for e in events if e.get("ph") == "X"}
    return bool(events) and set(range(P)) <= pids


def measured_breakdown(quick: bool = False) -> dict:
    """The MEASURED Table-3 row: comm%/compute%/update% read out of real
    spans (``PSResult.trace["report"]``), Original EASGD's monolithic
    master plane vs the bucketed-overlapped p2p sync plane — the paper's
    87%→14% comparison re-derived from execution instead of the cost
    model. Same problem, same emulated wire; only the data plane moves."""
    # iters scale with τ so both planes see a similar number of exchanges
    iters = (24 if quick else 60) * MEASURED_TAU
    out = {}
    for plane in ("master_monolithic", "p2p_overlap"):
        res = _traced_run(plane, iters)
        rep = res.trace["report"]
        out[plane] = {
            "algorithm": res.algorithm,
            "schedule": res.schedule,
            "comm_share": rep["mean_comm_share"],
            "compute_share": rep["mean_compute_share"],
            "update_share": rep["mean_update_share"],
            "total_time_s": round(res.total_time_s, 4),
            "chrome_trace_valid": _validate_chrome(res.trace, MEASURED_P),
        }
        csv_row(f"table3/measured/{plane}_comm_share",
                100.0 * out[plane]["comm_share"],
                f"compute={out[plane]['compute_share']:.1%};"
                f"update={out[plane]['update_share']:.1%} (measured spans, "
                f"P={MEASURED_P}, tcp, emulated paper wire)")
    overlap_wins = (out["p2p_overlap"]["comm_share"]
                    < out["master_monolithic"]["comm_share"])
    checks = {
        "p2p_comm_share_below_master": "PASS" if overlap_wins else "FAIL",
        "chrome_trace_validates": (
            "PASS" if all(v["chrome_trace_valid"] for v in out.values())
            else "FAIL"),
    }
    csv_row("table3/measured/p2p_vs_master", 0.0,
            f"comm {out['master_monolithic']['comm_share']:.1%} -> "
            f"{out['p2p_overlap']['comm_share']:.1%} "
            f"[{checks['p2p_comm_share_below_master']}] — the paper's "
            f"87%->14% narrative, measured")
    json_meta(measured={"iters": iters, "workers": MEASURED_P,
                        "batch": MEASURED_BATCH, "tau": MEASURED_TAU,
                        "planes": out, "checks": checks})
    return {"planes": out, "checks": checks}


def main(quick: bool = False):
    run(quick=quick)
    schedule_sweep(iters=100 if quick else 1000)
    measured_breakdown(quick=quick)


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--real", action="store_true",
                    help="run ONLY the measured (traced, real-sockets) "
                         "breakdown")
    a = ap.parse_args()
    if a.real:
        print(json.dumps(measured_breakdown(quick=a.quick), indent=1))
    else:
        main(quick=a.quick)
