"""Paper Table 3 / Fig 11: per-part time breakdown of the EASGD variants and
the end-to-end speedup of Sync EASGD3 over Original EASGD.

The paper's multi-GPU box is modeled with its own constants: PCIe-switch
links for CPU↔GPU and GPU↔GPU, measured fwd/bwd per batch, and the paper's
iteration counts (Original EASGD needs 5× the iterations of the sync
variants at equal accuracy because only one worker trains per iteration —
its Table 3: 5000 vs 1000). Claims checked:
  * communication share: Original ≈ 87%, Sync EASGD3 ≈ 14%
  * end-to-end speedup Sync EASGD3 vs Original ≈ 5.3×

Plus a SCHEDULE SWEEP over the shared ``repro.comm`` registry: the same
Sync-EASGD3 configuration priced under every registered exchange schedule,
reproducing the round-robin-vs-tree gap (§5.1) under otherwise identical
conditions. The comm-fraction breakdown is written as JSON
(``BENCH_table3_schedule_sweep.json`` at the repo root) so the trajectory
is machine-readable across PRs.
"""
from __future__ import annotations

import json
import os

from benchmarks.common import csv_row, json_capture_active, \
    json_meta
from repro.comm import schedules as comm_schedules
from repro.core.des import (
    GPU_BOX, breakdown_original_easgd, breakdown_sync_easgd,
)

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run(quick: bool = False):
    box = GPU_BOX
    # paper Table 3 setup: MNIST/LeNet on 4 GPUs; |W| = LeNet ~ 1.7 MB but
    # paper's AlexNet-sized runs use 249 MB — we report LeNet (their Table 3)
    rows = {}
    rows["original_easgd"] = breakdown_original_easgd(box, iters=5000)
    rows["sync_easgd1"] = breakdown_sync_easgd(box, iters=1000,
                                               weights_on="cpu",
                                               overlap=False)
    rows["sync_easgd2"] = breakdown_sync_easgd(box, iters=1000,
                                               weights_on="gpu",
                                               overlap=False)
    rows["sync_easgd3"] = breakdown_sync_easgd(box, iters=1000,
                                               weights_on="gpu",
                                               overlap=True)

    for name, r in rows.items():
        csv_row(f"table3/{name}", 1e6 * r.total_s / r.iters,
                f"total={r.total_s:.2f}s;comm_ratio={r.comm_ratio:.2f}")

    speedup = rows["original_easgd"].total_s / rows["sync_easgd3"].total_s
    csv_row("table3/speedup_sync3_vs_original", 0.0,
            f"{speedup:.2f}x (paper: 5.3x)")
    csv_row("table3/comm_ratio_original", 0.0,
            f"{rows['original_easgd'].comm_ratio:.2f} (paper: 0.87)")
    csv_row("table3/comm_ratio_sync3", 0.0,
            f"{rows['sync_easgd3'].comm_ratio:.2f} (paper: 0.14)")
    return rows, speedup


def schedule_sweep(iters: int = 1000, json_path: str | None = None) -> dict:
    """Sync EASGD3 (weights on GPU, overlap) under EVERY registered exchange
    schedule — same box, same iteration count, only the wire schedule moves.
    Writes the per-part/comm-fraction breakdown as JSON."""
    box = GPU_BOX
    sweep = {}
    for name in comm_schedules.names():
        r = breakdown_sync_easgd(box, iters=iters, weights_on="gpu",
                                 overlap=True, schedule=name)
        sweep[name] = {
            "total_s": r.total_s,
            "us_per_iter": 1e6 * r.total_s / r.iters,
            "comm_ratio": r.comm_ratio,
            "parts_s": dict(r.parts),
        }
        csv_row(f"table3/sweep/{name}", sweep[name]["us_per_iter"],
                f"comm_ratio={r.comm_ratio:.3f}")
    gap = sweep["round_robin"]["total_s"] / sweep["tree"]["total_s"]
    csv_row("table3/sweep/round_robin_vs_tree", 0.0,
            f"{gap:.2f}x slower (the paper's §5.1 schedule gap)")
    out = {"box": "GPU_BOX", "iters": iters, "schedules": sweep,
           "round_robin_vs_tree": gap}
    json_meta(sweep_box="GPU_BOX", sweep_iters=iters,
              schedules=list(sweep))
    # written only on explicit request or under run.py --json, so a plain
    # CSV benchmark run never clobbers the committed trajectory record
    if json_path or json_capture_active():
        path = json_path or os.path.join(REPO_ROOT,
                                         "BENCH_table3_schedule_sweep.json")
        with open(path, "w") as f:
            json.dump(out, f, indent=1)
    return out


def main(quick: bool = False):
    run(quick=quick)
    schedule_sweep(iters=100 if quick else 1000)


if __name__ == "__main__":
    main()
