"""Paper Table 3 / Fig 11: per-part time breakdown of the EASGD variants and
the end-to-end speedup of Sync EASGD3 over Original EASGD.

The paper's multi-GPU box is modeled with its own constants: PCIe-switch
links for CPU↔GPU and GPU↔GPU, measured fwd/bwd per batch, and the paper's
iteration counts (Original EASGD needs 5× the iterations of the sync
variants at equal accuracy because only one worker trains per iteration —
its Table 3: 5000 vs 1000). Claims checked:
  * communication share: Original ≈ 87%, Sync EASGD3 ≈ 14%
  * end-to-end speedup Sync EASGD3 vs Original ≈ 5.3×
"""
from __future__ import annotations

import dataclasses

from benchmarks.common import csv_row
from repro.core import costmodel
from repro.core.des import (
    GPU_BOX, breakdown_original_easgd, breakdown_sync_easgd,
)


def run(quick: bool = False):
    box = GPU_BOX
    # paper Table 3 setup: MNIST/LeNet on 4 GPUs; |W| = LeNet ~ 1.7 MB but
    # paper's AlexNet-sized runs use 249 MB — we report LeNet (their Table 3)
    rows = {}
    rows["original_easgd"] = breakdown_original_easgd(box, iters=5000)
    rows["sync_easgd1"] = breakdown_sync_easgd(box, iters=1000,
                                               weights_on="cpu",
                                               overlap=False)
    rows["sync_easgd2"] = breakdown_sync_easgd(box, iters=1000,
                                               weights_on="gpu",
                                               overlap=False)
    rows["sync_easgd3"] = breakdown_sync_easgd(box, iters=1000,
                                               weights_on="gpu",
                                               overlap=True)

    for name, r in rows.items():
        csv_row(f"table3/{name}", 1e6 * r.total_s / r.iters,
                f"total={r.total_s:.2f}s;comm_ratio={r.comm_ratio:.2f}")

    speedup = rows["original_easgd"].total_s / rows["sync_easgd3"].total_s
    csv_row("table3/speedup_sync3_vs_original", 0.0,
            f"{speedup:.2f}x (paper: 5.3x)")
    csv_row("table3/comm_ratio_original", 0.0,
            f"{rows['original_easgd'].comm_ratio:.2f} (paper: 0.87)")
    csv_row("table3/comm_ratio_sync3", 0.0,
            f"{rows['sync_easgd3'].comm_ratio:.2f} (paper: 0.14)")
    return rows, speedup


def main(quick: bool = False):
    run(quick=quick)


if __name__ == "__main__":
    main()
