"""grok-1-314b [moe]: 64L d_model=6144 48H (GQA kv=8) d_ff=32768
vocab=131072, MoE 8 experts top-2. [hf:xai-org/grok-1; unverified]"""
import jax.numpy as jnp

from repro.configs.base import QUADRATIC_SHAPES, ArchSpec
from repro.models.common import ModelConfig, MoEConfig

FULL = ModelConfig(
    name="grok-1-314b",
    family="moe",
    n_layers=64,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=32768,
    vocab_size=131072,
    moe=MoEConfig(n_experts=8, top_k=2, n_shared=0, d_expert=32768,
                  capacity_factor=1.25),
    act="gelu",
    fsdp=True,
    param_dtype=jnp.bfloat16,    # 314B: bf16 params + bf16 opt state to fit
)

REDUCED = ModelConfig(
    name="grok1-reduced",
    family="moe",
    n_layers=3,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=512,
    moe=MoEConfig(n_experts=4, top_k=2, n_shared=0, d_expert=128,
                  capacity_factor=1.25, dispatch_groups=4),
    act="gelu",
    loss_chunk=64,
)

SPEC = ArchSpec(
    arch_id="grok-1-314b",
    config=FULL,
    reduced=REDUCED,
    shapes=QUADRATIC_SHAPES,   # long_500k SKIPPED: pure full attention
    notes="8 experts do not divide model axis 16 -> experts replicated, "
          "expert d_ff (32768) tensor-parallel over `model`; FSDP over "
          "`data`; bf16 params + bf16 optimizer state to fit 16 GB/chip.",
    momentum_dtype=jnp.bfloat16,
    center_dtype=jnp.bfloat16,
    train_microbatches=16,
)
