"""qwen1.5-4b [dense]: 40L d_model=2560 20H (GQA kv=20) d_ff=6912
vocab=151936, QKV bias. [hf:Qwen/Qwen1.5-0.5B family; hf]"""
from repro.configs.base import QUADRATIC_SHAPES, ArchSpec
from repro.models.common import ModelConfig

FULL = ModelConfig(
    name="qwen1.5-4b",
    family="dense",
    n_layers=40,
    d_model=2560,
    n_heads=20,
    n_kv_heads=20,
    head_dim=128,
    d_ff=6912,
    vocab_size=151936,
    qkv_bias=True,
    act="silu",
    rope_theta=1_000_000.0,
    fsdp=True,
)

REDUCED = ModelConfig(
    name="qwen1.5-4b-reduced",
    family="dense",
    n_layers=4,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    head_dim=16,
    d_ff=128,
    vocab_size=512,
    qkv_bias=True,
    act="silu",
    rope_theta=1_000_000.0,
    loss_chunk=64,
)

SPEC = ArchSpec(
    arch_id="qwen1.5-4b",
    config=FULL,
    reduced=REDUCED,
    shapes=QUADRATIC_SHAPES,   # long_500k SKIPPED: pure full attention
    notes="MHA (kv=20); QKV bias; 20 heads do not divide model axis 16 -> "
          "attention replicated over `model`, FFN/vocab tensor-parallel.",
)
