"""phi3-mini-3.8b [dense]: 32L d_model=3072 32H (kv=32) d_ff=8192
vocab=32064, RoPE SwiGLU. [arXiv:2404.14219; unverified]"""
from repro.configs.base import QUADRATIC_SHAPES, ArchSpec
from repro.models.common import ModelConfig

FULL = ModelConfig(
    name="phi3-mini-3.8b",
    family="dense",
    n_layers=32,
    d_model=3072,
    n_heads=32,
    n_kv_heads=32,
    head_dim=96,
    d_ff=8192,
    vocab_size=32064,
    act="silu",
    rope_theta=10_000.0,
    fsdp=True,
)

REDUCED = ModelConfig(
    name="phi3-mini-reduced",
    family="dense",
    n_layers=4,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    head_dim=16,
    d_ff=128,
    vocab_size=512,
    act="silu",
    loss_chunk=64,
)

SPEC = ArchSpec(
    arch_id="phi3-mini-3.8b",
    config=FULL,
    reduced=REDUCED,
    shapes=QUADRATIC_SHAPES,   # long_500k SKIPPED: pure full attention
    notes="MHA 32 heads (divides model axis); small 32k vocab.",
)
