"""gemma3-4b [dense]: 34L d_model=2560 8H (GQA kv=4) d_ff=10240 vocab=262144,
5:1 local:global sliding-window attention, 128k context.
[hf:google/gemma-3-1b-pt family; unverified]"""
import jax.numpy as jnp

from repro.configs.base import ALL_SHAPES, ArchSpec
from repro.models.common import ModelConfig

FULL = ModelConfig(
    name="gemma3-4b",
    family="dense",
    n_layers=34,
    d_model=2560,
    n_heads=8,
    n_kv_heads=4,
    head_dim=256,
    d_ff=10240,
    vocab_size=262144,
    pattern=("local",) * 5 + ("attn",),
    window=1024,
    rope_theta=10_000.0,
    rope_theta_global=1_000_000.0,
    qk_norm=True,
    act="gelu",
    tie_embeddings=True,
    fsdp=True,
)

REDUCED = ModelConfig(
    name="gemma3-4b-reduced",
    family="dense",
    n_layers=6,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=512,
    pattern=("local",) * 5 + ("attn",),
    window=8,
    rope_theta=10_000.0,
    rope_theta_global=1_000_000.0,
    qk_norm=True,
    act="gelu",
    tie_embeddings=True,
    loss_chunk=64,
)

SPEC = ArchSpec(
    arch_id="gemma3-4b",
    config=FULL,
    reduced=REDUCED,
    # long_500k RUNS: 5/6 of layers are O(window); global layers decode O(n)
    # against a seq-sharded KV cache.
    shapes=ALL_SHAPES,
    notes="5:1 local:global; window 1024; dual rope theta; qk-norm; tied "
          "embeddings; 262k vocab sharded over `model`.",
)
