"""qwen2-vl-72b [vlm]: 80L d_model=8192 64H (GQA kv=8) d_ff=29568
vocab=152064, M-RoPE, dynamic resolution. [arXiv:2409.12191; hf]

Backbone only — the vision tower is a STUB: input_specs() provides
precomputed patch embeddings merged into the leading positions.
"""
import jax.numpy as jnp

from repro.configs.base import QUADRATIC_SHAPES, ArchSpec
from repro.models.common import ModelConfig

FULL = ModelConfig(
    name="qwen2-vl-72b",
    family="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=29568,
    vocab_size=152064,
    qkv_bias=True,
    act="silu",
    rope_theta=1_000_000.0,
    mrope_sections=(16, 24, 24),        # t/h/w rotary split (sums to 64)
    patch_embed_tokens=256,             # vision stub: 256 leading positions
    fsdp=True,
)

REDUCED = ModelConfig(
    name="qwen2-vl-reduced",
    family="vlm",
    n_layers=4,
    d_model=64,
    n_heads=8,
    n_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=512,
    qkv_bias=True,
    act="silu",
    mrope_sections=(2, 3, 3),
    patch_embed_tokens=8,
    loss_chunk=64,
)

SPEC = ArchSpec(
    arch_id="qwen2-vl-72b",
    config=FULL,
    reduced=REDUCED,
    shapes=QUADRATIC_SHAPES,   # long_500k SKIPPED: pure full attention
    notes="M-RoPE with (16,24,24) sections; vision frontend stubbed via "
          "precomputed patch embeddings; FSDP (72B).",
    momentum_dtype=jnp.float32,
    center_dtype=jnp.bfloat16,
    train_microbatches=16,
)
