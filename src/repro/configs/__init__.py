"""Architecture registry: the 10 assigned archs + the paper's own models."""
from __future__ import annotations

from repro.configs.base import ALL_SHAPES, QUADRATIC_SHAPES, SHAPES, ArchSpec

from repro.configs import (
    gemma3_4b,
    qwen15_4b,
    phi3_mini,
    gemma3_27b,
    qwen2_vl_72b,
    mamba2_780m,
    musicgen_medium,
    recurrentgemma_2b,
    grok1_314b,
    deepseek_v2_236b,
)

ARCHS = {
    s.arch_id: s
    for s in (
        gemma3_4b.SPEC,
        qwen15_4b.SPEC,
        phi3_mini.SPEC,
        gemma3_27b.SPEC,
        qwen2_vl_72b.SPEC,
        mamba2_780m.SPEC,
        musicgen_medium.SPEC,
        recurrentgemma_2b.SPEC,
        grok1_314b.SPEC,
        deepseek_v2_236b.SPEC,
    )
}


def get(arch_id: str) -> ArchSpec:
    try:
        return ARCHS[arch_id]
    except KeyError:
        raise ValueError(
            f"unknown arch '{arch_id}'; have: {sorted(ARCHS)}"
        ) from None


def cells():
    """All (arch, shape) dry-run cells; 40 assigned minus documented skips."""
    out = []
    for aid, spec in ARCHS.items():
        for shape_id in ALL_SHAPES:
            out.append((aid, shape_id, spec.supports(shape_id)))
    return out
