"""gemma3-27b [dense]: 62L d_model=5376 32H (GQA kv=16) d_ff=21504
vocab=262144, 5:1 local:global, 128k. [hf:google/gemma-3 family; unverified]"""
import jax.numpy as jnp

from repro.configs.base import ALL_SHAPES, ArchSpec
from repro.models.common import ModelConfig

FULL = ModelConfig(
    name="gemma3-27b",
    family="dense",
    n_layers=62,
    d_model=5376,
    n_heads=32,
    n_kv_heads=16,
    head_dim=128,
    d_ff=21504,
    vocab_size=262144,
    pattern=("local",) * 5 + ("attn",),
    window=1024,
    rope_theta=10_000.0,
    rope_theta_global=1_000_000.0,
    qk_norm=True,
    act="gelu",
    tie_embeddings=True,
    fsdp=True,
)

REDUCED = ModelConfig(
    name="gemma3-27b-reduced",
    family="dense",
    n_layers=8,           # 1 period + 2 remainder
    d_model=96,
    n_heads=4,
    n_kv_heads=2,
    head_dim=24,
    d_ff=192,
    vocab_size=512,
    pattern=("local",) * 5 + ("attn",),
    window=8,
    rope_theta=10_000.0,
    rope_theta_global=1_000_000.0,
    qk_norm=True,
    act="gelu",
    tie_embeddings=True,
    fsdp=False,
    loss_chunk=64,
)

SPEC = ArchSpec(
    arch_id="gemma3-27b",
    config=FULL,
    reduced=REDUCED,
    shapes=ALL_SHAPES,
    notes="As gemma3-4b but FSDP over `data` (27B params); 62 = 10 periods "
          "of (5 local + 1 global) + 2 remainder local layers.",
    momentum_dtype=jnp.float32,
    center_dtype=jnp.bfloat16,
    train_microbatches=16,
)
