"""deepseek-v2-236b [moe]: 60L d_model=5120 128H, MLA kv_lora=512,
d_ff(expert)=1536, vocab=102400, 2 shared + 160 routed top-6.
[arXiv:2405.04434; hf]"""
import jax.numpy as jnp

from repro.configs.base import QUADRATIC_SHAPES, ArchSpec
from repro.models.common import MLAConfig, ModelConfig, MoEConfig

FULL = ModelConfig(
    name="deepseek-v2-236b",
    family="moe",
    n_layers=60,
    d_model=5120,
    n_heads=128,
    n_kv_heads=128,              # MLA: per-head K/V expanded from kv_lora
    head_dim=128,
    d_ff=1536,
    vocab_size=102400,
    pattern=("mla",),
    mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512, qk_nope_head_dim=128,
                  qk_rope_head_dim=64, v_head_dim=128),
    moe=MoEConfig(n_experts=160, top_k=6, n_shared=2, d_expert=1536,
                  capacity_factor=1.25),
    act="silu",
    fsdp=True,
    param_dtype=jnp.bfloat16,
)

REDUCED = ModelConfig(
    name="deepseek-v2-reduced",
    family="moe",
    n_layers=3,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    head_dim=16,
    d_ff=64,
    vocab_size=512,
    pattern=("mla",),
    mla=MLAConfig(q_lora_rank=32, kv_lora_rank=16, qk_nope_head_dim=16,
                  qk_rope_head_dim=8, v_head_dim=16),
    moe=MoEConfig(n_experts=8, top_k=2, n_shared=2, d_expert=64,
                  capacity_factor=1.25, dispatch_groups=4),
    act="silu",
    loss_chunk=64,
)

SPEC = ArchSpec(
    arch_id="deepseek-v2-236b",
    config=FULL,
    reduced=REDUCED,
    shapes=QUADRATIC_SHAPES,   # long_500k SKIPPED: full attention (MLA)
    notes="MLA: decode caches only (c_kv 512 + rope 64) per token and uses "
          "the absorbed-weight form. 160 experts / 16 model shards = 10 "
          "experts per shard (expert parallel); 2 shared experts dense.",
    momentum_dtype=jnp.bfloat16,
    center_dtype=jnp.bfloat16,
    train_microbatches=16,
)
