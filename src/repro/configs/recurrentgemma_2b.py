"""recurrentgemma-2b [hybrid]: 26L d_model=2560 10H (MQA kv=1) d_ff=7680
vocab=256000, RG-LRU : local attention = 2 : 1. [arXiv:2402.19427; hf]"""
from repro.configs.base import ALL_SHAPES, ArchSpec
from repro.models.common import ModelConfig, RGLRUConfig

FULL = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,
    head_dim=256,
    d_ff=7680,
    vocab_size=256000,
    pattern=("rglru", "rglru", "local"),
    window=2048,
    rglru=RGLRUConfig(width=2560, d_conv=4, c=8.0),
    act="gelu",
    tie_embeddings=True,
    fsdp=True,
)

REDUCED = ModelConfig(
    name="recurrentgemma-reduced",
    family="hybrid",
    n_layers=5,               # 1 period + 2 remainder rglru
    d_model=64,
    n_heads=4,
    n_kv_heads=1,
    head_dim=16,
    d_ff=128,
    vocab_size=512,
    pattern=("rglru", "rglru", "local"),
    window=8,
    rglru=RGLRUConfig(width=64, d_conv=4, c=8.0),
    act="gelu",
    tie_embeddings=True,
    loss_chunk=64,
)

SPEC = ArchSpec(
    arch_id="recurrentgemma-2b",
    config=FULL,
    reduced=REDUCED,
    shapes=ALL_SHAPES,        # long_500k RUNS: recurrence O(1), attn O(window)
    notes="Griffin block pattern (2 RG-LRU + 1 local-attn), window 2048, "
          "MQA kv=1 (replicated); 26 = 8 periods + 2 remainder RG-LRU.",
)
