"""Architecture specs: full config (dry-run only) + reduced config (smoke
tests) + the input-shape set each arch supports."""
from __future__ import annotations

import dataclasses
from typing import Any

import jax.numpy as jnp

from repro.models.common import ModelConfig


# The assigned input-shape set (all LM archs share it; long_500k only for
# sub-quadratic archs — see DESIGN.md §6).
SHAPES = {
    "train_4k": dict(kind="train", seq=4096, global_batch=256),
    "prefill_32k": dict(kind="prefill", seq=32768, global_batch=32),
    "decode_32k": dict(kind="decode", seq=32768, global_batch=128),
    "long_500k": dict(kind="decode", seq=524288, global_batch=1),
}

ALL_SHAPES = ("train_4k", "prefill_32k", "decode_32k", "long_500k")
QUADRATIC_SHAPES = ("train_4k", "prefill_32k", "decode_32k")


@dataclasses.dataclass(frozen=True)
class ArchSpec:
    arch_id: str
    config: ModelConfig            # the published full-size config
    reduced: ModelConfig           # same family, CPU-smoke-test sized
    shapes: tuple                  # supported shape ids
    notes: str = ""
    # optimizer-state dtypes (memory-fit tuning for the big archs)
    momentum_dtype: Any = jnp.float32
    center_dtype: Any = jnp.float32
    # gradient-accumulation factor for train_4k (activation-memory fit;
    # global batch and optimizer math are unchanged)
    train_microbatches: int = 8

    def supports(self, shape_id: str) -> bool:
        return shape_id in self.shapes
