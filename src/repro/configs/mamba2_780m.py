"""mamba2-780m [ssm]: 48L d_model=1536 attn-free, ssm_state=128, SSD.
[arXiv:2405.21060; unverified]"""
from repro.configs.base import ALL_SHAPES, ArchSpec
from repro.models.common import ModelConfig, SSMConfig

FULL = ModelConfig(
    name="mamba2-780m",
    family="ssm",
    n_layers=48,
    d_model=1536,
    n_heads=1,                  # unused (attn-free)
    n_kv_heads=1,
    d_ff=0,
    vocab_size=50280,
    pattern=("ssm",),
    ssm=SSMConfig(d_state=128, head_dim=64, expand=2, d_conv=4, chunk=256),
    tie_embeddings=True,
    fsdp=True,
)

REDUCED = ModelConfig(
    name="mamba2-reduced",
    family="ssm",
    n_layers=4,
    d_model=64,
    n_heads=1,
    n_kv_heads=1,
    d_ff=0,
    vocab_size=512,
    pattern=("ssm",),
    ssm=SSMConfig(d_state=16, head_dim=16, expand=2, d_conv=4, chunk=16),
    tie_embeddings=True,
    loss_chunk=64,
)

SPEC = ArchSpec(
    arch_id="mamba2-780m",
    config=FULL,
    reduced=REDUCED,
    shapes=ALL_SHAPES,          # long_500k RUNS: O(1)/token recurrence
    notes="SSD chunked scan (chunk 256); heads=d_inner/64=48 shard over "
          "`model`; decode state is O(1) in context length.",
)
