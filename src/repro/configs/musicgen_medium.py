"""musicgen-medium [audio]: 48L d_model=1536 24H d_ff=6144 vocab=2048,
decoder-only over EnCodec tokens. [arXiv:2306.05284; hf]

Backbone only — the EnCodec tokenizer/delay-pattern interleaver is a STUB:
inputs are already-flattened codebook token ids (vocab 2048).
Adaptation note (DESIGN.md): the original uses learned sinusoidal positions;
we use RoPE (TPU-idiomatic, numerically equivalent role).
"""
from repro.configs.base import QUADRATIC_SHAPES, ArchSpec
from repro.models.common import ModelConfig

FULL = ModelConfig(
    name="musicgen-medium",
    family="audio",
    n_layers=48,
    d_model=1536,
    n_heads=24,
    n_kv_heads=24,
    head_dim=64,
    d_ff=6144,
    vocab_size=2048,
    act="gelu",
    fsdp=True,
)

REDUCED = ModelConfig(
    name="musicgen-reduced",
    family="audio",
    n_layers=4,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    head_dim=16,
    d_ff=128,
    vocab_size=256,
    act="gelu",
    loss_chunk=64,
)

SPEC = ArchSpec(
    arch_id="musicgen-medium",
    config=FULL,
    reduced=REDUCED,
    shapes=QUADRATIC_SHAPES,   # long_500k SKIPPED: pure full attention
    notes="24 heads do not divide model axis 16 -> attention replicated "
          "over `model`; tiny 2048 vocab (EnCodec codes).",
)
