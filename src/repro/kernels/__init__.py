from repro.kernels import ops, ref
from repro.kernels.ops import (
    flash_attention, elastic_update, ssd_intra_chunk, fused_cross_entropy,
)
