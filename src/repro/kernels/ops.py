"""Jitted public wrappers for the Pallas kernels.

``interpret`` defaults to True off-TPU (this container is CPU-only — the
kernel bodies execute in Python for correctness validation); on a real TPU
backend it flips to compiled Mosaic automatically.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import elastic_update as _eu
from repro.kernels import flash_attention as _fa
from repro.kernels import ssd_chunk as _ssd


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@partial(jax.jit, static_argnames=("causal", "window", "block_q", "block_k",
                                   "interpret"))
def flash_attention(q, k, v, *, causal=True, window=0, block_q=128,
                    block_k=128, interpret=None):
    """q: (B, S, H, D); k, v: (B, S, KVH, D). GQA is expanded head-wise
    before the kernel (K/V stay small in HBM; expansion happens once)."""
    if interpret is None:
        interpret = _default_interpret()
    B, S, H, D = q.shape
    KVH = k.shape[2]
    G = H // KVH
    if G > 1:
        k = jnp.repeat(k, G, axis=2)
        v = jnp.repeat(v, G, axis=2)
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, S, D)
    kf = k.transpose(0, 2, 1, 3).reshape(B * H, S, D)
    vf = v.transpose(0, 2, 1, 3).reshape(B * H, S, v.shape[-1])
    out = _fa.flash_attention_bhsd(qf, kf, vf, causal=causal, window=window,
                                   block_q=block_q, block_k=block_k,
                                   interpret=interpret)
    return out.reshape(B, H, S, -1).transpose(0, 2, 1, 3)


@partial(jax.jit, static_argnames=("eta", "rho", "mu", "n_workers", "block",
                                   "interpret"))
def elastic_update(w, v, g, c, mean_w, *, eta, rho, mu, n_workers,
                   block=128 * 1024, interpret=None):
    if interpret is None:
        interpret = _default_interpret()
    n = w.shape[0]
    while n % block:
        block //= 2
    return _eu.fused_elastic_update(w, v, g, c, mean_w, eta=eta, rho=rho,
                                    mu=mu, n_workers=n_workers, block=block,
                                    interpret=interpret)


@partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_intra_chunk(a, x, b, c, *, chunk=256, interpret=None):
    if interpret is None:
        interpret = _default_interpret()
    return _ssd.ssd_intra_chunk(a, x, b, c, chunk=chunk, interpret=interpret)


@partial(jax.jit, static_argnames=("block_t", "block_v", "interpret"))
def fused_cross_entropy(h, w, targets, *, block_t=256, block_v=2048,
                        interpret=None):
    from repro.kernels import fused_ce as _ce
    if interpret is None:
        interpret = _default_interpret()
    return _ce.fused_cross_entropy(h, w, targets, block_t=block_t,
                                   block_v=block_v, interpret=interpret)
