"""Pallas TPU kernel: fused packed EASGD update (the paper's hot spot).

Paper Table 3: the weight update is 16–23% of step time — it is a pure
HBM-bandwidth elementwise pass. Done naively (eqs 5–6 then eq 2 as separate
jnp ops) the buffers round-trip HBM several times; fused, each of the five
input buffers is read ONCE and the three outputs written ONCE — the
bandwidth floor:

    V' = μ·V − η·G
    W' = W + V' − η·ρ·(W − C)
    C' = C + η·ρ·P·(M − C)          (M = cross-pod mean of W, pre-update)

All buffers are the packer's flat 1-D layout (contiguous — the §5.2
'single-layer layout'), tiled in (8·128·BLOCK)-element VMEM blocks.
Oracle: core.easgd.fused_elastic_step_flat.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import enable_x64
from jax.experimental import pallas as pl

from repro.core.packing import ELASTIC_UPDATE_BLOCK


def _update_kernel(w_ref, v_ref, g_ref, c_ref, m_ref, w_out, v_out, c_out, *,
                   eta: float, rho: float, mu: float, n_workers: int):
    w = w_ref[...].astype(jnp.float32)
    v = v_ref[...].astype(jnp.float32)
    g = g_ref[...].astype(jnp.float32)
    c = c_ref[...].astype(jnp.float32)
    m = m_ref[...].astype(jnp.float32)
    v_new = mu * v - eta * g
    w_new = w + v_new - eta * rho * (w - c)
    c_new = c + eta * rho * n_workers * (m - c)
    w_out[...] = w_new.astype(w_out.dtype)
    v_out[...] = v_new.astype(v_out.dtype)
    c_out[...] = c_new.astype(c_out.dtype)


def fused_elastic_update(w, v, g, c, mean_w, *, eta: float, rho: float,
                         mu: float, n_workers: int,
                         block: int = ELASTIC_UPDATE_BLOCK,
                         interpret=True):
    """All inputs 1-D, same length (packer-aligned). Returns (w', v', c')."""
    n = w.shape[0]
    bs = min(block, n)
    assert n % bs == 0, (n, bs, "pack with align=block")
    grid = (n // bs,)
    spec = pl.BlockSpec((bs,), lambda i: (i,))
    kernel = functools.partial(_update_kernel, eta=eta, rho=rho, mu=mu,
                               n_workers=n_workers)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[spec] * 5,
        out_specs=[spec] * 3,
        out_shape=[
            jax.ShapeDtypeStruct((n,), w.dtype),
            jax.ShapeDtypeStruct((n,), v.dtype),
            jax.ShapeDtypeStruct((n,), c.dtype),
        ],
        interpret=interpret,
    )(w, v, g, c, mean_w)


# ---------------------------------------------------------------------------
# f64 per-bucket sync-family updates — the p2p data plane's hot path
# ---------------------------------------------------------------------------
#
# These two kernels are BITWISE replacements for the easgd_flat update pair
# the p2p worker runs on each completed bucket (``net.worker._p2p_sync_loop``
# with ``update_backend="pallas"``): same f64 dtype, same operation ASTs as
# the numpy expressions, so IEEE-754 guarantees equal bits — PROVIDED the
# XLA CPU backend does not contract a·b+c into fused multiply-adds (an fma
# keeps the product's infinite precision through the add; numpy rounds
# twice). The worker/launcher therefore pin ``XLA_FLAGS=--xla_cpu_max_isa=
# SSE4_2`` before the first jax import — SSE4.2 predates the FMA ISA
# extension, so LLVM cannot emit fma and the kernels match numpy bit for
# bit (pinned at zero tolerance by tests/test_bucketing.py). Without the
# flag the results are still correct to ~1 ulp, just not identical.

def _sync_easgd_kernel(w_ref, g_ref, c_ref, r_ref, w_out, c_out, *,
                       eta: float, rho: float, alpha_p: float, p: int):
    w = w_ref[...]
    g = g_ref[...]
    c = c_ref[...]
    r = r_ref[...]
    # exact easgd_flat op order: worker_step's elastic rule on the PRE-
    # update center, then eq 2 on the exchanged pre-update weight sum r
    w_out[...] = w - eta * (g + rho * (w - c))
    c_out[...] = c + alpha_p * (r / p - c)


def _sync_sgd_kernel(c_ref, v_ref, r_ref, c_out, v_out, *,
                     eta: float, mu: float, p: int):
    c = c_ref[...]
    v = v_ref[...]
    r = r_ref[...]
    v_new = mu * v - eta * (r / p)
    c_out[...] = c + v_new
    v_out[...] = v_new


def _bucket_grid(n: int, block: int):
    """(block_size, grid): buckets cut at layer edges are rarely an exact
    multiple of the VMEM block, so an unaligned bucket runs as one block —
    functionally identical, just untiled."""
    bs = min(block, n)
    if n % bs:
        bs = n
    return bs, (n // bs,)


def fused_sync_easgd_update(w, grad, center, row, p: int,
                            eta: float, rho: float, *,
                            block: int = ELASTIC_UPDATE_BLOCK,
                            interpret=True):
    """One bucket's fused Sync EASGD update (worker rule + center pull in
    a single pass over the slices — five reads, two writes):

        W' = W − η(G + ρ(W − C))
        C' = C + ηρP(R/P − C)        (R = exchanged Σ_i W_i, pre-update)

    Returns ``(w', c')`` as f64 numpy arrays; the caller assigns them back
    into its bucket slices."""
    n = w.shape[0]
    bs, grid = _bucket_grid(n, block)
    spec = pl.BlockSpec((bs,), lambda i: (i,))
    kernel = functools.partial(_sync_easgd_kernel, eta=eta, rho=rho,
                               alpha_p=(eta * rho) * p, p=p)
    with enable_x64():
        w_new, c_new = pl.pallas_call(
            kernel,
            grid=grid,
            in_specs=[spec] * 4,
            out_specs=[spec] * 2,
            out_shape=[jax.ShapeDtypeStruct((n,), jnp.float64)] * 2,
            interpret=interpret,
        )(w, grad, center, row)
        return np.asarray(w_new), np.asarray(c_new)


def fused_sync_sgd_update(center, vel, row, p: int,
                          eta: float, mu: float, *,
                          block: int = ELASTIC_UPDATE_BLOCK,
                          interpret=True):
    """One bucket's fused synchronous momentum-SGD master update:

        V̄' = μV̄ − η(R/P);  C' = C + V̄'     (R = exchanged Σ_i grad_i)

    Returns ``(c', v̄')`` as f64 numpy arrays."""
    n = center.shape[0]
    bs, grid = _bucket_grid(n, block)
    spec = pl.BlockSpec((bs,), lambda i: (i,))
    kernel = functools.partial(_sync_sgd_kernel, eta=eta, mu=mu, p=p)
    with enable_x64():
        c_new, v_new = pl.pallas_call(
            kernel,
            grid=grid,
            in_specs=[spec] * 3,
            out_specs=[spec] * 2,
            out_shape=[jax.ShapeDtypeStruct((n,), jnp.float64)] * 2,
            interpret=interpret,
        )(center, vel, row)
        return np.asarray(c_new), np.asarray(v_new)
