"""Pallas TPU kernel: fused packed EASGD update (the paper's hot spot).

Paper Table 3: the weight update is 16–23% of step time — it is a pure
HBM-bandwidth elementwise pass. Done naively (eqs 5–6 then eq 2 as separate
jnp ops) the buffers round-trip HBM several times; fused, each of the five
input buffers is read ONCE and the three outputs written ONCE — the
bandwidth floor:

    V' = μ·V − η·G
    W' = W + V' − η·ρ·(W − C)
    C' = C + η·ρ·P·(M − C)          (M = cross-pod mean of W, pre-update)

All buffers are the packer's flat 1-D layout (contiguous — the §5.2
'single-layer layout'), tiled in (8·128·BLOCK)-element VMEM blocks.
Oracle: core.easgd.fused_elastic_step_flat.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.packing import ELASTIC_UPDATE_BLOCK


def _update_kernel(w_ref, v_ref, g_ref, c_ref, m_ref, w_out, v_out, c_out, *,
                   eta: float, rho: float, mu: float, n_workers: int):
    w = w_ref[...].astype(jnp.float32)
    v = v_ref[...].astype(jnp.float32)
    g = g_ref[...].astype(jnp.float32)
    c = c_ref[...].astype(jnp.float32)
    m = m_ref[...].astype(jnp.float32)
    v_new = mu * v - eta * g
    w_new = w + v_new - eta * rho * (w - c)
    c_new = c + eta * rho * n_workers * (m - c)
    w_out[...] = w_new.astype(w_out.dtype)
    v_out[...] = v_new.astype(v_out.dtype)
    c_out[...] = c_new.astype(c_out.dtype)


def fused_elastic_update(w, v, g, c, mean_w, *, eta: float, rho: float,
                         mu: float, n_workers: int,
                         block: int = ELASTIC_UPDATE_BLOCK,
                         interpret=True):
    """All inputs 1-D, same length (packer-aligned). Returns (w', v', c')."""
    n = w.shape[0]
    bs = min(block, n)
    assert n % bs == 0, (n, bs, "pack with align=block")
    grid = (n // bs,)
    spec = pl.BlockSpec((bs,), lambda i: (i,))
    kernel = functools.partial(_update_kernel, eta=eta, rho=rho, mu=mu,
                               n_workers=n_workers)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[spec] * 5,
        out_specs=[spec] * 3,
        out_shape=[
            jax.ShapeDtypeStruct((n,), w.dtype),
            jax.ShapeDtypeStruct((n,), v.dtype),
            jax.ShapeDtypeStruct((n,), c.dtype),
        ],
        interpret=interpret,
    )(w, v, g, c, mean_w)
