"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.easgd import EASGDConfig, fused_elastic_step_flat
from repro.models.attention import blocked_attention


def flash_attention_ref(q, k, v, *, causal=True, window=0):
    """q,k,v: (BH, S, D) — same-heads attention via the blocked oracle."""
    out = blocked_attention(q[:, :, None].swapaxes(1, 2).swapaxes(1, 1),
                            k[:, :, None], v[:, :, None],
                            causal=causal, window=window)
    return out[:, :, 0]


def flash_attention_dense_ref(q, k, v, *, causal=True, window=0):
    """Direct dense (S×S) reference — independent of the blocked code."""
    BH, S, D = q.shape
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / jnp.sqrt(jnp.float32(D))
    i = jnp.arange(S)
    m = jnp.ones((S, S), bool)
    if causal:
        m &= i[:, None] >= i[None, :]
    if window:
        m &= i[:, None] - i[None, :] < window
    s = jnp.where(m[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)).astype(q.dtype)


def elastic_update_ref(w, v, g, c, mean_w, *, eta, rho, mu, n_workers):
    cfg = EASGDConfig(eta=eta, rho=rho, mu=mu)
    w32, v32, g32, c32, m32 = (x.astype(jnp.float32)
                               for x in (w, v, g, c, mean_w))
    w2, v2, c2 = fused_elastic_step_flat(w32, v32, g32, c32, m32,
                                         n_workers, cfg)
    return w2.astype(w.dtype), v2.astype(v.dtype), c2.astype(c.dtype)


def fused_ce_ref(h, w, targets):
    """Dense reference: loss_t = logsumexp(h·W) − (h·W)[target]."""
    logits = jnp.einsum("td,dv->tv", h.astype(jnp.float32),
                        w.astype(jnp.float32))
    lse = jax.nn.logsumexp(logits, axis=-1)
    tgt = jnp.take_along_axis(logits, targets[:, None], axis=1)[:, 0]
    return lse - tgt


def ssd_intra_ref(a, x, b, c, *, chunk: int):
    """Intra-chunk SSD: per chunk, Y[i] = Σ_{j≤i} (C_i·B_j) e^{cum_i−cum_j} X_j."""
    BH, S = a.shape
    L = min(chunk, S)
    nc = S // L
    a_ = a.reshape(BH, nc, L).astype(jnp.float32)
    x_ = x.reshape(BH, nc, L, -1).astype(jnp.float32)
    b_ = b.reshape(BH, nc, L, -1).astype(jnp.float32)
    c_ = c.reshape(BH, nc, L, -1).astype(jnp.float32)
    cum = jnp.cumsum(a_, axis=2)
    g = jnp.einsum("hcln,hcmn->hclm", c_, b_)
    dec = jnp.exp(cum[..., :, None] - cum[..., None, :])
    mask = jnp.tril(jnp.ones((L, L), bool))
    m = jnp.where(mask[None, None], g * dec, 0.0)
    y = jnp.einsum("hclm,hcmp->hclp", m, x_)
    return y.reshape(BH, S, -1).astype(x.dtype)
