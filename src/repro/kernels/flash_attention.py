"""Pallas TPU flash attention: online-softmax with explicit VMEM tiling.

TARGET: TPU v5e MXU. Tiles are (block_q × head_dim) and (block_k × head_dim)
in VMEM (128-multiples → MXU-aligned); the (block_q × block_k) score tile
never leaves VMEM — HBM traffic is O(S·D) instead of O(S²).

Grid: (batch·heads, n_q_blocks, n_k_blocks) with the innermost dim
sequential — running max/denominator/accumulator live in VMEM scratch
across the k-block sweep (the standard TPU flash pattern; same math as the
pure-JAX oracle models/attention.blocked_attention).

Validated on CPU with interpret=True (kernels/ops.py flips interpretation
off on real TPU). Causal + sliding-window masks are supported; GQA is
handled in the wrapper by expanding K/V head-wise (ops.flash_attention).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.utils.jaxcompat import pallas_tpu_compiler_params

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                 scale: float, causal: bool, window: int, block_q: int,
                 block_k: int, n_k: int, seq_len: int):
    qi = pl.program_id(1)
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32) * scale          # (bq, D)
    k = k_ref[0].astype(jnp.float32)                  # (bk, D)
    v = v_ref[0].astype(jnp.float32)                  # (bk, Dv)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))   # (bq, bk)

    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
    k_pos = kj * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = k_pos < seq_len
    if causal:
        mask &= q_pos >= k_pos
    if window:
        mask &= q_pos - k_pos < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    l_prev = l_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_new = l_prev * corr + p.sum(axis=1)
    acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())))
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(kj == n_k - 1)
    def _finalize():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)[:, None]).astype(o_ref.dtype)


def flash_attention_bhsd(q, k, v, *, causal=True, window=0, block_q=128,
                         block_k=128, interpret=True):
    """q, k, v: (BH, S, D) with matched heads (GQA expanded by the wrapper).
    Returns (BH, S, Dv)."""
    BH, S, D = q.shape
    Dv = v.shape[-1]
    bq = min(block_q, S)
    bk = min(block_k, S)
    pad_q = (-S) % bq
    pad_k = (-S) % bk
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0)))
    nq = (S + pad_q) // bq
    nk = (S + pad_k) // bk

    kernel = functools.partial(
        _attn_kernel, scale=1.0 / math.sqrt(D), causal=causal, window=window,
        block_q=bq, block_k=bk, n_k=nk, seq_len=S)

    out = pl.pallas_call(
        kernel,
        grid=(BH, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, D), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, Dv), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, Dv), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S + pad_q, Dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, Dv), jnp.float32),
        ],
        compiler_params=pallas_tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)
    return out[:, :S]
