"""Pallas TPU kernel: Mamba-2 SSD intra-chunk block.

The chunked SSD forward (models/ssm._ssd_chunked) is dominated by the
intra-chunk quadratic part: per chunk, per head,
    Y_intra = (tril(C·Bᵀ ∘ exp(segsum(a)))) · X
Those are (L×N)·(N×L) and (L×L)·(L×P) matmuls — MXU food — with an (L×L)
decay mask that should never leave VMEM. This kernel computes one chunk's
intra-chunk output per grid cell with the (L,L) tile resident in VMEM;
the (cheap, sequential) inter-chunk state pass stays in JAX.

Grid: (batch·heads, n_chunks). Layout: X (BH, S, P); B,C (BH, S, N)
pre-broadcast per head; a (BH, S) log-decay. L must be a multiple of 8
(TPU sublane); N, P multiples of 128 preferred.

Oracle: kernels/ref.ssd_intra_ref.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ssd_intra_kernel(a_ref, x_ref, b_ref, c_ref, y_ref, *, L: int):
    a = a_ref[0].astype(jnp.float32)                 # (L,)
    x = x_ref[0].astype(jnp.float32)                 # (L, P)
    b = b_ref[0].astype(jnp.float32)                 # (L, N)
    c = c_ref[0].astype(jnp.float32)                 # (L, N)

    cum = jnp.cumsum(a)                              # (L,)
    g = jax.lax.dot_general(c, b, (((1,), (1,)), ((), ())))   # (L, L)
    dec = cum[:, None] - cum[None, :]
    ii = jax.lax.broadcasted_iota(jnp.int32, (L, L), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (L, L), 1)
    m = jnp.where(ii >= jj, g * jnp.exp(dec), 0.0)
    y = jax.lax.dot_general(m, x, (((1,), (0,)), ((), ())))   # (L, P)
    y_ref[0] = y.astype(y_ref.dtype)


def ssd_intra_chunk(a, x, b, c, *, chunk: int, interpret=True):
    """a: (BH, S) log-decay; x: (BH, S, P); b, c: (BH, S, N).
    Returns intra-chunk Y (BH, S, P) (inter-chunk term handled outside)."""
    BH, S = a.shape
    P = x.shape[-1]
    N = b.shape[-1]
    L = min(chunk, S)
    assert S % L == 0, (S, L)
    nc = S // L
    kernel = functools.partial(_ssd_intra_kernel, L=L)
    return pl.pallas_call(
        kernel,
        grid=(BH, nc),
        in_specs=[
            pl.BlockSpec((1, L), lambda h, i: (h, i)),
            pl.BlockSpec((1, L, P), lambda h, i: (h, i, 0)),
            pl.BlockSpec((1, L, N), lambda h, i: (h, i, 0)),
            pl.BlockSpec((1, L, N), lambda h, i: (h, i, 0)),
        ],
        out_specs=pl.BlockSpec((1, L, P), lambda h, i: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, P), x.dtype),
        interpret=interpret,
    )(a, x, b, c)
