"""Pallas TPU kernel: fused ("flash") cross-entropy.

§Perf cell A identified the LM loss as irreducible in XLA: the (T, V)
logits round-trip HBM (≈1 PB/step global for gemma-3's 262k vocab at 1M
tokens). This kernel tiles the vocab dim and keeps each (block_t × block_v)
logits tile in VMEM, maintaining an online logsumexp and the target-logit
gather — HBM traffic drops from O(T·V) to O(T·d + V·d):

    loss_t = logsumexp_v(h_t·W_v) − (h_t·W_{y_t})

Grid: (token_blocks, vocab_blocks), vocab innermost/sequential with
running (m, l, tgt) VMEM scratch. Forward-only (the training path's
backward still uses the chunked XLA loss; wiring a custom VJP through this
kernel is the documented next step). Oracle: kernels/ref.fused_ce_ref.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.utils.jaxcompat import pallas_tpu_compiler_params

NEG_INF = -1e30


def _ce_kernel(h_ref, w_ref, t_ref, loss_ref, m_ref, l_ref, tgt_ref, *,
               block_v: int, n_v: int, vocab: int):
    vj = pl.program_id(1)

    @pl.when(vj == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        tgt_ref[...] = jnp.zeros_like(tgt_ref)

    h = h_ref[...]                                  # (bt, d)
    w = w_ref[...]                                  # (d, bv)
    logits = jax.lax.dot_general(
        h, w, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)         # (bt, bv)
    v_ids = vj * block_v + jax.lax.broadcasted_iota(
        jnp.int32, logits.shape, 1)
    logits = jnp.where(v_ids < vocab, logits, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, logits.max(axis=1))
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + jnp.exp(
        logits - m_new[:, None]).sum(axis=1)
    m_ref[...] = m_new

    # target logit if it falls inside this vocab tile
    t = t_ref[...]                                  # (bt,)
    hit = (v_ids == t[:, None])
    tgt_ref[...] = tgt_ref[...] + jnp.where(hit, logits, 0.0).sum(axis=1)

    @pl.when(vj == n_v - 1)
    def _finalize():
        lse = m_ref[...] + jnp.log(jnp.maximum(l_ref[...], 1e-30))
        loss_ref[...] = (lse - tgt_ref[...]).astype(loss_ref.dtype)


def fused_cross_entropy(h, w, targets, *, block_t: int = 256,
                        block_v: int = 2048, interpret=True):
    """h: (T, d); w: (d, V); targets: (T,) int32 -> per-token loss (T,)."""
    T, d = h.shape
    V = w.shape[1]
    bt = min(block_t, T)
    bv = min(block_v, V)
    pt, pv = (-T) % bt, (-V) % bv
    if pt:
        h = jnp.pad(h, ((0, pt), (0, 0)))
        targets = jnp.pad(targets, (0, pt))
    if pv:
        w = jnp.pad(w, ((0, 0), (0, pv)))
    n_t, n_v = (T + pt) // bt, (V + pv) // bv

    kernel = functools.partial(_ce_kernel, block_v=bv, n_v=n_v, vocab=V)
    loss = pl.pallas_call(
        kernel,
        grid=(n_t, n_v),
        in_specs=[
            pl.BlockSpec((bt, d), lambda i, j: (i, 0)),
            pl.BlockSpec((d, bv), lambda i, j: (0, j)),
            pl.BlockSpec((bt,), lambda i, j: (i,)),
        ],
        out_specs=pl.BlockSpec((bt,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((T + pt,), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((bt,), jnp.float32),
            pltpu.VMEM((bt,), jnp.float32),
            pltpu.VMEM((bt,), jnp.float32),
        ],
        compiler_params=pallas_tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(h, w, targets)
    return loss[:T]
