"""BENCH_*.json perf-regression gate — compare two runs metric by metric.

    PYTHONPATH=src python -m repro.obs.regress BASELINE.json CURRENT.json \
        [--tol 0.25] [--metrics iters_per_sec] [--warn-only]
    PYTHONPATH=src python -m repro.obs.regress bench_history/fig6_8_convergence

Every ``BENCH_*.json`` the repo writes embeds run metadata and numeric
results in one of three shapes (benchmarks/run.py rows with ``derived``
k=v strings, fig6_8's ``algorithms`` list, ad-hoc smoke dicts);
``flatten_metrics`` reduces all of them to one flat
``{dotted.path: float}`` namespace so the comparison is shape-agnostic.

Direction is inferred from the metric name: throughput-like metrics
(``iters_per_sec``, ``rate_ips``, ``ef_ratio``) regress when they DROP
below tolerance, cost-like metrics (``*_s``, ``us_per_*``, ``*bytes*``,
``*err*``) when they RISE; unrecognized metrics are reported as two-sided
drift notes, never failures — a gate must not fail on a metric it cannot
interpret. Exit 1 on any regression unless ``--warn-only`` (CI's
first-landing mode). With a single directory argument (the
``bench_history/<name>/`` trail appended by ``benchmarks/run.py``) the two
newest files are compared.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

_SKIP_KEYS = {"meta", "curve_real", "curve_des", "history", "argv",
              "trace", "rows_meta"}
_HIGHER_BETTER = ("iters_per_sec", "per_sec", "rate_ips", "ef_ratio",
                  "overlapped", "ips")
_LOWER_BETTER = ("us_per", "_s", "time", "bytes", "err", "loss",
                 "exposed", "staleness", "alpha", "dropped")


def _direction(key: str) -> str:
    """'up' = higher is better, 'down' = lower is better, '?' = unknown."""
    low = key.lower()
    leaf = low.rsplit(".", 1)[-1]
    for pat in _HIGHER_BETTER:
        if pat in leaf:
            return "up"
    for pat in _LOWER_BETTER:
        if pat in leaf:
            return "down"
    return "?"


def _parse_derived(s: str) -> dict:
    """'final_err=0.040;t_to_0.25=0.202s' → numeric dict (units stripped)."""
    out = {}
    for part in str(s).split(";"):
        if "=" not in part:
            continue
        k, v = part.split("=", 1)
        v = v.strip().rstrip("xs%")      # 0.202s / 5.3x / 83%
        try:
            out[k.strip()] = float(v)
        except ValueError:
            pass
    return out


def flatten_metrics(obj, prefix: str = "") -> dict:
    """Reduce any BENCH record to {dotted.path: float}. Lists of dicts are
    keyed by their 'name'/'algorithm'/'module' field when present (rows,
    fig6_8 algorithms), by index otherwise; inf/nan leaves are dropped."""
    out: dict = {}

    def _put(key, v):
        try:
            f = float(v)
        except (TypeError, ValueError):
            return
        if f == f and abs(f) != float("inf"):    # not nan, not inf
            out[key] = f

    if isinstance(obj, dict):
        for k, v in obj.items():
            if k in _SKIP_KEYS:
                continue
            key = f"{prefix}.{k}" if prefix else str(k)
            if k == "derived":
                for dk, dv in _parse_derived(v).items():
                    _put(f"{prefix}.{dk}" if prefix else dk, dv)
            elif isinstance(v, bool):
                continue
            elif isinstance(v, (int, float)):
                _put(key, v)
            elif isinstance(v, (dict, list)):
                out.update(flatten_metrics(v, key))
    elif isinstance(obj, list):
        for i, item in enumerate(obj):
            if isinstance(item, dict):
                label = item.get("name") or item.get("algorithm") \
                    or item.get("module") or str(i)
                sub = {k: v for k, v in item.items()
                       if k not in ("name", "algorithm", "module")}
                out.update(flatten_metrics(
                    sub, f"{prefix}.{label}" if prefix else str(label)))
            elif isinstance(item, (int, float)) and not isinstance(item,
                                                                   bool):
                # numeric list (e.g. bucket_send_bytes): aggregate, a
                # per-element gate would churn on repartitioning
                _put(f"{prefix}.sum", sum(
                    x for x in obj if isinstance(x, (int, float))))
                break
    return out


def compare(base: dict, cur: dict, tol: float = 0.25,
            metric_filter: str = "") -> dict:
    """Compare two flattened metric dicts. Returns {"regressions": [...],
    "improvements": [...], "drift": [...]} — each entry
    (key, base, current, rel_change)."""
    regressions, improvements, drift = [], [], []
    for key in sorted(set(base) & set(cur)):
        if metric_filter and metric_filter not in key:
            continue
        b, c = base[key], cur[key]
        if b == 0.0:
            continue                     # no meaningful relative change
        rel = (c - b) / abs(b)
        if abs(rel) <= tol:
            continue
        d = _direction(key)
        entry = (key, b, c, rel)
        if d == "up":
            (regressions if rel < 0 else improvements).append(entry)
        elif d == "down":
            (regressions if rel > 0 else improvements).append(entry)
        else:
            drift.append(entry)
    return {"regressions": regressions, "improvements": improvements,
            "drift": drift}


def _load(path: str) -> dict:
    with open(path) as f:
        return flatten_metrics(json.load(f))


def _two_newest(dirpath: str) -> tuple:
    files = sorted((os.path.join(dirpath, f) for f in os.listdir(dirpath)
                    if f.endswith(".json")), key=os.path.getmtime)
    if len(files) < 2:
        raise SystemExit(
            f"{dirpath}: need ≥2 history files to compare, "
            f"found {len(files)}")
    return files[-2], files[-1]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("paths", nargs="+",
                    help="BASELINE.json CURRENT.json, or one "
                         "bench_history/<name>/ directory (compares the "
                         "two newest files)")
    ap.add_argument("--tol", type=float, default=0.25,
                    help="relative tolerance band (0.25 = ±25%%)")
    ap.add_argument("--metrics", default="",
                    help="only gate metrics whose dotted path contains "
                         "this substring (e.g. iters_per_sec)")
    ap.add_argument("--warn-only", action="store_true",
                    help="report regressions but exit 0 (first landing / "
                         "cross-machine baselines)")
    args = ap.parse_args(argv)

    if len(args.paths) == 1 and os.path.isdir(args.paths[0]):
        base_path, cur_path = _two_newest(args.paths[0])
    elif len(args.paths) == 2:
        base_path, cur_path = args.paths
    else:
        ap.error("pass BASELINE CURRENT or one history directory")
    base, cur = _load(base_path), _load(cur_path)
    shared = set(base) & set(cur)
    print(f"# regress: {base_path} -> {cur_path} "
          f"({len(shared)} shared metrics, tol=±{args.tol:.0%}"
          + (f", filter='{args.metrics}'" if args.metrics else "") + ")")
    if not shared:
        print("# no shared numeric metrics — nothing to gate")
        return 0
    r = compare(base, cur, tol=args.tol, metric_filter=args.metrics)
    for label, entries in (("REGRESSION", r["regressions"]),
                           ("improvement", r["improvements"]),
                           ("drift", r["drift"])):
        for key, b, c, rel in entries:
            print(f"{label:>12}  {key}: {b:g} -> {c:g} ({rel:+.1%})")
    if not any(r.values()):
        print("# all shared metrics within tolerance")
    if r["regressions"] and not args.warn_only:
        print(f"# FAIL: {len(r['regressions'])} metric(s) regressed "
              f"beyond ±{args.tol:.0%}")
        return 1
    if r["regressions"]:
        print("# warn-only: regressions reported, exit 0")
    return 0


if __name__ == "__main__":
    sys.exit(main())
