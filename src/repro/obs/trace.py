"""Per-thread append-only span recorder — the repro.obs hot path.

A ``Tracer`` is owned by exactly ONE thread (one per worker loop, one per
comm executor, one per master serve loop), so recording takes no locks.
Storage is preallocated numpy arrays; ``record`` is four scalar stores and
an integer bump (~100 ns), and beyond capacity it only bumps a ``dropped``
counter — never allocates, never raises. Tracing is DISABLED BY DEFAULT:
when ``PSConfig.trace`` is off no tracer is ever created and every
instrumentation site is behind an ``if tracer is not None`` guard, so the
off-cost is one pointer compare per site (no ``perf_counter`` calls, no
allocation — pinned by tests/test_obs.py).

Span kinds mirror the runtime's vocabulary. The classification sets at the
bottom are what ``obs.report.breakdown`` uses to reproduce the paper's
Table-3 accounting (compute% / exposed-comm% / update%) from real spans.

This module is jax-free: TCP workers import it on their ~0.4 s startup
path (pinned by tests/test_net.py::test_tcp_worker_is_jax_free).
"""
from __future__ import annotations

import json
import os
import threading

import numpy as np

# -- span kinds --------------------------------------------------------------
COMPUTE = 0        # one exchange-step gradient computation
LOCAL_STEP = 1     # the τ−1 local-only steps between exchanges (one span)
EXCHANGE = 2       # one full allreduce on the comm executor / comm thread
ROUND = 3          # one message round of an exchange (arg = round index)
BUCKET = 4         # one bucket's rounds on the p2p wire (arg = bucket)
BUCKET_WAIT = 5    # main thread blocked for a bucket to land (arg = bucket)
COMM_WAIT = 6      # main thread blocked on exchange completion (join/inline)
UPDATE = 7         # optimizer update application (arg = bucket, −1 = whole)
BARRIER = 8        # barrier wait (arg: 0 = A, 1 = B, 2 = C)
TURN_WAIT = 9      # turnstile / master-lock admission wait
RECV_WAIT = 10     # blocked on the master link (WEIGHTS down / grads in)
EVAL = 11          # eval-function snapshot (master only)

KIND_NAMES = {
    COMPUTE: "compute", LOCAL_STEP: "local_step", EXCHANGE: "exchange",
    ROUND: "round", BUCKET: "bucket", BUCKET_WAIT: "bucket_wait",
    COMM_WAIT: "comm_wait", UPDATE: "update", BARRIER: "barrier",
    TURN_WAIT: "turn_wait", RECV_WAIT: "recv_wait", EVAL: "eval",
}

# Table-3 accounting classes (obs.report.breakdown): a worker's wall time
# decomposes into gradient compute, EXPOSED communication (time its update
# path sat blocked on a wire or a barrier — what overlap exists to hide),
# and optimizer-update time. EXCHANGE/ROUND/BUCKET are comm-thread
# *busy* spans: they show where bytes moved, but only the wait kinds are
# time the training loop actually lost.
COMPUTE_KINDS = frozenset({COMPUTE, LOCAL_STEP})
EXPOSED_KINDS = frozenset({BUCKET_WAIT, COMM_WAIT, BARRIER, TURN_WAIT,
                           RECV_WAIT})
UPDATE_KINDS = frozenset({UPDATE})
COMM_BUSY_KINDS = frozenset({EXCHANGE})

DEFAULT_CAPACITY = 1 << 16


class Tracer:
    """One thread's span buffer. ``record(kind, t0, t1, arg)`` appends;
    past ``capacity`` it increments ``dropped`` instead of growing (the
    hot path must never allocate)."""

    __slots__ = ("name", "wid", "capacity", "n", "dropped",
                 "_t0", "_t1", "_kind", "_arg")

    def __init__(self, name: str, wid: int = -1,
                 capacity: int = DEFAULT_CAPACITY):
        self.name = name
        self.wid = wid
        self.capacity = int(capacity)
        self.n = 0
        self.dropped = 0
        self._t0 = np.empty(self.capacity, np.float64)
        self._t1 = np.empty(self.capacity, np.float64)
        self._kind = np.empty(self.capacity, np.int32)
        self._arg = np.empty(self.capacity, np.int64)

    def record(self, kind: int, t0: float, t1: float, arg: int = 0) -> None:
        i = self.n
        if i >= self.capacity:
            self.dropped += 1
            return
        self._t0[i] = t0
        self._t1[i] = t1
        self._kind[i] = kind
        self._arg[i] = arg
        self.n = i + 1

    def spans(self) -> list:
        """[[kind, t0, t1, arg], ...] in record (≈ end-time) order —
        the JSON-ready wire form carried home in BYE / spill files."""
        return [[int(self._kind[i]), float(self._t0[i]), float(self._t1[i]),
                 int(self._arg[i])] for i in range(self.n)]


# -- registry ----------------------------------------------------------------
# Creation takes the lock; recording never does (one tracer per thread).
_LOCK = threading.Lock()
_TRACERS: list = []


def tracer(name: str, wid: int = -1,
           capacity: int = DEFAULT_CAPACITY) -> Tracer:
    """Create AND register a tracer. Callers create one only when tracing
    is enabled — an empty registry IS the disabled state."""
    t = Tracer(name, wid=wid, capacity=capacity)
    with _LOCK:
        _TRACERS.append(t)
    return t


def drain() -> list:
    """Pop every registered tracer (one traced run per process at a time:
    launchers drain at run start for a clean slate and at run end to
    collect)."""
    with _LOCK:
        out, _TRACERS[:] = list(_TRACERS), []
    return out


def stats() -> dict:
    """Registry totals — the tracing-off overhead test pins these to 0."""
    with _LOCK:
        ts = list(_TRACERS)
    return {"tracers": len(ts), "records": sum(t.n for t in ts),
            "dropped": sum(t.dropped for t in ts)}


# -- spill files -------------------------------------------------------------

def spill_path(trace_dir: str, wid: int) -> str:
    return os.path.join(trace_dir, f"trace-w{wid}.json")


def dump_spill(trace_dir: str, wid: int, payload: dict) -> str:
    """Write one worker's trace payload (``{"clock", "threads", "dropped"}``)
    under ``trace_dir``; returns the path (what BYE advertises instead of
    the inline buffer when ``--trace-dir`` is set)."""
    os.makedirs(trace_dir, exist_ok=True)
    path = spill_path(trace_dir, wid)
    with open(path, "w") as f:
        json.dump(payload, f)
    return path


def load_spill(path: str) -> dict:
    with open(path) as f:
        return json.load(f)
