"""repro.obs — low-overhead tracing, clock alignment, and wire metrics.

Three small jax-free modules threaded through every layer of the runtime:

 * ``obs.trace``   — per-thread preallocated span recorder (off by
   default; ~100 ns per record when on, zero work when off).
 * ``obs.clock``   — NTP-style worker↔master offset estimation so
   per-worker traces merge onto one timeline (|error| ≤ rtt/2).
 * ``obs.metrics`` — the named counter/gauge registry (``.value`` cells)
   replacing the per-layer parallel counter dicts, plus ``count_round``,
   the one definition of schedule-level exchange accounting.
 * ``obs.report``  — trace merging, the measured Table-3 breakdown
   (compute% / exposed-comm% / update%), and Chrome-trace/Perfetto export.

Turn it on with ``PSConfig(trace=True)`` (CLI: ``--trace``); the merged
trace comes back on ``PSResult.trace`` with a ``report`` section attached.
See DESIGN.md §obs for the span taxonomy and overhead budget.
"""
from repro.obs import clock, metrics, report, trace  # noqa: F401

__all__ = ["clock", "metrics", "report", "trace"]
