"""repro.obs — low-overhead tracing, clock alignment, and wire metrics.

Three small jax-free modules threaded through every layer of the runtime:

 * ``obs.trace``   — per-thread preallocated span recorder (off by
   default; ~100 ns per record when on, zero work when off).
 * ``obs.clock``   — NTP-style worker↔master offset estimation so
   per-worker traces merge onto one timeline (|error| ≤ rtt/2).
 * ``obs.metrics`` — the named counter/gauge registry (``.value`` cells)
   replacing the per-layer parallel counter dicts, plus ``count_round``,
   the one definition of schedule-level exchange accounting.
 * ``obs.report``  — trace merging, the measured Table-3 breakdown
   (compute% / exposed-comm% / update%), and Chrome-trace/Perfetto export.
 * ``obs.live``    — the streaming plane: per-(wid, metric) ring-buffer
   time series fed by heartbeats + master gauges, the online
   straggler/health detector (``ft.straggler`` math on real telemetry),
   and the snapshot the STATS frame / ``launch.monitor`` renders.
 * ``obs.regress`` — the BENCH_*.json perf-regression gate
   (``python -m repro.obs.regress BASELINE CURRENT``).

Turn tracing on with ``PSConfig(trace=True)`` (CLI: ``--trace``); the
merged trace comes back on ``PSResult.trace`` with a ``report`` section
attached. Turn the live plane on with ``PSConfig(telemetry=True)`` /
``telemetry_jsonl=...`` (CLI: ``--telemetry[-jsonl]``); health events come
back on ``PSResult.health``. See DESIGN.md §obs for the span taxonomy,
the live-plane layout, and the overhead budget.
"""
import importlib

__all__ = ["clock", "live", "metrics", "regress", "report", "trace"]


def __getattr__(name):
    # PEP 562 lazy submodules: keeps `python -m repro.obs.regress` free of
    # runpy's found-in-sys.modules warning and imports only what's touched.
    if name in __all__:
        return importlib.import_module(f"repro.obs.{name}")
    raise AttributeError(f"module 'repro.obs' has no attribute {name!r}")
