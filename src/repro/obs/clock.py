"""Cross-worker clock alignment for trace merging (NTP-style, RTT-halved).

Each worker estimates its offset against the master's ``time.perf_counter``
during rendezvous (after WELCOME, before READY — the link is otherwise
quiet, so CLOCK replies are the only inbound frames): send an empty CLOCK
probe at local t0, the master's reader echoes its own clock t_m, note local
t1. Under the symmetric-delay assumption the master read the probe at
(t0+t1)/2 local, so

    offset = t_m − (t0 + t1) / 2,      master ≈ local + offset,

with error bounded by rtt/2. We keep the sample at the MINIMUM observed
round-trip (queueing only ever inflates rtt, so min-rtt is the closest to
symmetric) — the same filter NTP applies. ``obs.report.merge_traces``
shifts every worker span by its offset onto the master timeline; the
reported rtt doubles as a measured per-link α observation.

On one host, ``time.perf_counter`` is CLOCK_MONOTONIC — system-wide, so
thread/process-transport offsets are exactly 0 and the estimator here
returns ≈0 (bounded by loopback rtt). Jax-free, like all of repro.obs.
"""
from __future__ import annotations

import dataclasses
import time


@dataclasses.dataclass(frozen=True)
class ClockSync:
    """offset_s: add to local timestamps to land on the master clock;
    rtt_s: the minimum observed round-trip (|offset error| ≤ rtt/2)."""

    offset_s: float
    rtt_s: float
    probes: int

    def to_wire(self) -> dict:
        return {"offset_s": self.offset_s, "rtt_s": self.rtt_s,
                "probes": self.probes}


def combine(samples: list) -> ClockSync:
    """samples: [(t0_local, t_master, t1_local)] → the min-rtt estimate."""
    best_rtt, offset = float("inf"), 0.0
    for t0, tm, t1 in samples:
        rtt = t1 - t0
        if rtt < best_rtt:
            best_rtt = rtt
            offset = tm - (t0 + t1) / 2.0
    return ClockSync(offset_s=offset, rtt_s=best_rtt, probes=len(samples))


def sync_over_link(link, wid: int = 0, probes: int = 8) -> ClockSync:
    """Run the probe exchange over a ``net.wire.Link`` whose peer echoes
    CLOCK frames with ``{"t": perf_counter()}`` (the master's per-link
    reader does; ``answer`` below is the echo half for tests)."""
    from repro.net import wire
    samples = []
    for _ in range(probes):
        t0 = time.perf_counter()
        link.send_simple(wire.CLOCK, wid=wid)
        frame = link.recv_header()
        assert frame.ftype == wire.CLOCK, frame
        tm = float(link.recv_json(frame)["t"])
        samples.append((t0, tm, time.perf_counter()))
    return combine(samples)


def answer(link, frame, wid: int = 0) -> None:
    """The echo half: consume one CLOCK probe, reply with this clock's
    ``perf_counter`` (what ``net.server``'s reader does per probe)."""
    from repro.net import wire
    link.recv_discard(frame)
    link.send_json(wire.CLOCK, {"t": time.perf_counter()}, wid=wid)
