"""Named counter/gauge registry — one home for the runtime's accounting.

Before repro.obs every layer grew its own parallel dict of counter cells:
``ps.runtime`` handed ``{"sync_rounds": slot, ...}`` to the round executor,
``net.server`` kept a second dict feeding ``Link._count``, ``net.peer`` a
per-peer third. The cells themselves were fine — a ``.value`` attribute
shared by plain objects, ``multiprocessing.RawValue`` and ctypes — so the
``Registry`` here keeps exactly that protocol (``registry[name].value``)
and is mapping-like where the old dicts were: ``Link._count`` and the
round executor run unchanged against either.

``count_round`` is the ONE definition of schedule-level exchange
accounting (previously copy-pasted between ``_apply_round`` and the
bucketed branch of ``execute_rounds``): one executed message round costs
one sync_round, len(rnd) messages, and Σ frac·n·8 logical wire bytes —
independent of bucketing, which repartitions frames, not the schedule.

Jax-free (TCP workers import this through ``net.wire``).
"""
from __future__ import annotations


class Slot:
    """A mutable counter cell (mirrors mp.RawValue's ``.value``) — the unit
    of the counter protocol shared by the master server's aggregate
    counters, the peer mesh's per-link counters, and this registry."""

    __slots__ = ("value",)

    def __init__(self, value=0):
        self.value = value

    def __repr__(self):
        return f"Slot({self.value!r})"


class Registry:
    """Named slots with ``.value`` semantics. ``counter(name, cell=...)``
    ADOPTS an externally-owned cell (an mp.RawValue, a ctypes value) under
    a name instead of allocating — that is how the process transport's
    shared-memory counters join the registry without losing their
    cross-process backing. Mapping-style access returns the cell, so
    existing ``counters["wire_bytes"].value += n`` call sites are
    oblivious to whether they were handed a dict or a Registry."""

    def __init__(self):
        self._slots: dict = {}

    # -- definition ---------------------------------------------------------

    def counter(self, name: str, cell=None):
        """Get-or-create (optionally adopting ``cell``)."""
        slot = self._slots.get(name)
        if slot is None:
            slot = self._slots[name] = Slot() if cell is None else cell
        return slot

    gauge = counter          # same cell; gauges are set, counters are added

    # -- convenience --------------------------------------------------------

    def add(self, name: str, v) -> None:
        self.counter(name).value += v

    def set(self, name: str, v) -> None:
        self.counter(name).value = v

    def snapshot(self) -> dict:
        """{name: value} — the JSON-ready read of every cell."""
        return {k: s.value for k, s in self._slots.items()}

    # -- mapping protocol (what the old dicts provided) ---------------------

    def __getitem__(self, name: str):
        return self._slots[name]

    def get(self, name: str, default=None):
        return self._slots.get(name, default)

    def __contains__(self, name: str) -> bool:
        return name in self._slots

    def __iter__(self):
        return iter(self._slots)

    def items(self):
        return self._slots.items()

    def __len__(self) -> int:
        return len(self._slots)


def count_round(counters, rnd, n_elements: int) -> None:
    """Schedule-level accounting of ONE executed message round: counters is
    any mapping of cells with ``.value`` (dict or Registry, thread slots or
    mp.RawValue). Logical bytes are Σ frac·n·8 — the schedule's cost,
    invariant under bucketing (which repartitions frames, not messages)."""
    counters["sync_rounds"].value += 1
    counters["messages"].value += len(rnd)
    counters["wire_bytes"].value += int(
        sum(m.frac for m in rnd) * n_elements * 8)
