"""Trace merging, the measured time breakdown, and Chrome-trace export.

Input: per-worker trace payloads (what BYE carries home, what spill files
hold, what the thread transport reads straight off the registry):

    {"clock": {"offset_s": ..., "rtt_s": ...},      # obs.clock estimate
     "threads": {"main": [[kind, t0, t1, arg], ...], "comm": [...]},
     "dropped": 0}

``merge_traces`` shifts every worker span by its clock offset onto the
master timeline; ``breakdown`` reproduces the paper's Table-3 accounting
(compute% / exposed-comm% / update% of wall) from the aligned spans;
``chrome_trace`` emits the standard ``traceEvents`` JSON that Perfetto /
chrome://tracing open directly (one pid per worker, one tid per thread).

Jax-free, numpy-free — the master merges at shutdown, workers never
import this on the hot path.
"""
from __future__ import annotations

import json

from repro.obs import trace as _trace


def merge_traces(workers: dict, master: dict | None = None) -> dict:
    """workers: wid -> trace payload (above). Returns the merged structure
    with every worker span ALIGNED to the master clock (t + offset); the
    master's own threads (already on its clock) ride along unshifted."""
    out_workers = {}
    for wid, payload in sorted(workers.items(), key=lambda kv: int(kv[0])):
        clk = payload.get("clock") or {}
        off = float(clk.get("offset_s", 0.0))
        threads = {}
        for tname, spans in (payload.get("threads") or {}).items():
            threads[tname] = [[int(k), float(a) + off, float(b) + off,
                               int(arg)] for k, a, b, arg in spans]
        out_workers[int(wid)] = {
            "offset_s": off,
            "rtt_s": float(clk.get("rtt_s", 0.0)),
            "dropped": int(payload.get("dropped", 0)),
            "threads": threads,
        }
    merged = {"workers": out_workers}
    if master and master.get("threads"):
        merged["master"] = {"threads": {
            tname: [[int(k), float(a), float(b), int(arg)]
                    for k, a, b, arg in spans]
            for tname, spans in master["threads"].items()}}
    return merged


def _iter_spans(merged):
    for wid, w in merged["workers"].items():
        for tname, spans in w["threads"].items():
            for s in spans:
                yield wid, tname, s
    for tname, spans in merged.get("master", {}).get("threads", {}).items():
        for s in spans:
            yield "master", tname, s


def breakdown(merged: dict) -> dict:
    """The measured Table-3 accounting. Per worker, over aligned spans:

      compute_s       Σ COMPUTE + LOCAL_STEP            (gradient work)
      exposed_comm_s  Σ waits (BUCKET/COMM/BARRIER/TURN/RECV) — time the
                      training loop sat blocked on a wire or a peer; the
                      quantity overlap exists to shrink
      update_s        Σ UPDATE                           (optimizer math)
      comm_busy_s     Σ EXCHANGE — comm-thread activity (may overlap
                      compute; NOT added to the share decomposition)
      wall_s          span extent (max t1 − min t0 across its threads)

    Shares are fractions of wall; ``comm_share`` is the paper's
    "communication %" — EXPOSED comm only, which is why overlap lowers it
    while comm_busy_s stays put."""
    per = {}
    for wid, w in merged["workers"].items():
        lo, hi = float("inf"), float("-inf")
        acc = {"compute_s": 0.0, "exposed_comm_s": 0.0, "update_s": 0.0,
               "comm_busy_s": 0.0}
        for spans in w["threads"].values():
            for k, a, b, _arg in spans:
                lo, hi = min(lo, a), max(hi, b)
                d = b - a
                if k in _trace.COMPUTE_KINDS:
                    acc["compute_s"] += d
                elif k in _trace.EXPOSED_KINDS:
                    acc["exposed_comm_s"] += d
                elif k in _trace.UPDATE_KINDS:
                    acc["update_s"] += d
                elif k in _trace.COMM_BUSY_KINDS:
                    acc["comm_busy_s"] += d
        wall = max(hi - lo, 1e-12) if hi > lo else 0.0
        per[wid] = {
            "wall_s": round(wall, 6),
            **{k: round(v, 6) for k, v in acc.items()},
            "comm_share": round(acc["exposed_comm_s"] / wall, 4) if wall
            else 0.0,
            "compute_share": round(acc["compute_s"] / wall, 4) if wall
            else 0.0,
            "update_share": round(acc["update_s"] / wall, 4) if wall
            else 0.0,
        }
    n = max(len(per), 1)
    agg = {f"mean_{k}": round(sum(p[k] for p in per.values()) / n, 4)
           for k in ("comm_share", "compute_share", "update_share")}
    return {"workers": per, **agg}


def chrome_trace(merged: dict) -> dict:
    """The Chrome trace-event JSON (``ph:"X"`` complete events, µs units)
    — load the written file at https://ui.perfetto.dev or chrome://tracing.
    Worker wid → pid wid; the master is pid 9999; thread names become tid
    metadata so the timeline reads ``worker 0 / main``, ``… / comm``."""
    t_min = min((s[1] for _, _, s in _iter_spans(merged)),
                default=0.0)
    events = []
    tids: dict = {}

    def _tid(pid, tname):
        key = (pid, tname)
        if key not in tids:
            tids[key] = len([1 for (p, _), _v in tids.items() if p == pid])
            events.append({"name": "thread_name", "ph": "M", "pid": pid,
                           "tid": tids[key], "args": {"name": tname}})
        return tids[key]

    for wid in merged["workers"]:
        events.append({"name": "process_name", "ph": "M", "pid": int(wid),
                       "args": {"name": f"worker {wid}"}})
    if "master" in merged:
        events.append({"name": "process_name", "ph": "M", "pid": 9999,
                       "args": {"name": "master"}})
    for who, tname, (k, a, b, arg) in _iter_spans(merged):
        pid = 9999 if who == "master" else int(who)
        events.append({
            "name": _trace.KIND_NAMES.get(k, str(k)), "ph": "X",
            "pid": pid, "tid": _tid(pid, tname),
            "ts": round((a - t_min) * 1e6, 3),
            "dur": round((b - a) * 1e6, 3),
            "args": {"arg": arg},
        })
    return {"traceEvents": events, "displayTimeUnit": "ms"}


def write_chrome_trace(path: str, merged: dict) -> str:
    with open(path, "w") as f:
        json.dump(chrome_trace(merged), f)
    return path
