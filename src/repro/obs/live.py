"""The live telemetry plane: streaming time-series + online health detection.

PR 7 made runs explainable after the fact (spans → merged breakdown); this
module watches them WHILE they happen. Three pieces, all jax-free:

 * ``Ring`` / ``TimeSeries`` — preallocated ring buffers keyed
   ``(wid, metric)``. Every telemetry-bearing HEARTBEAT the tcp master
   receives lands here (push — ``net.wire.Link.hb_hook`` fires on the
   reader thread), and a master-side sampler thread adds periodic reads of
   the ``metrics.Registry`` gauges (hb staleness, ef_ratio, aggregate
   counters) under the reserved wid −1. Fixed capacity, overwrite-oldest:
   a week-long run costs the same memory as a minute-long one.
 * ``HealthDetector`` — ``ft.straggler.BoundedStaleness`` wired to REAL
   signal: per-window worker rates become per-exchange delays (1/rate),
   the policy's median-deadline mask flags stragglers, heartbeat age flags
   silence. Detection only — no membership change, no training-math change
   (that is PR 9's job; see DESIGN.md §obs "honest boundary").
 * ``LiveMonitor`` — owns both plus the optional JSONL stream
   (``PSConfig.telemetry_jsonl``); its ``snapshot()`` is what the master
   serves to ``launch/monitor`` over the STATS frame and what lands on
   ``PSResult.health``.

Events are structured dicts ``{"t", "kind", "wid", ...}`` with kinds
``straggler`` / ``hb_stale`` / ``recovered`` / ``worker_left`` /
``worker_dead``; each one increments ``counters["health_events"]``.
Everything here is OFF by default (``PSConfig.telemetry``): an untouched
config allocates no store, starts no thread, takes no timestamps.
"""
from __future__ import annotations

import json
import threading
import time
from typing import Optional

import numpy as np

from repro.ft.straggler import BoundedStaleness

AGG_WID = -1                 # the master's own aggregate-gauge series
_SPARK = "▁▂▃▄▅▆▇█"


class Ring:
    """Preallocated (t, value) ring buffer — push is O(1), no allocation
    after construction, oldest samples silently overwritten."""

    __slots__ = ("capacity", "n", "_i", "_t", "_v")

    def __init__(self, capacity: int = 512):
        assert capacity > 0, capacity
        self.capacity = capacity
        self.n = 0                       # samples held (≤ capacity)
        self._i = 0                      # next write slot
        self._t = np.zeros(capacity)
        self._v = np.zeros(capacity)

    def push(self, t: float, v: float) -> None:
        self._t[self._i] = t
        self._v[self._i] = v
        self._i = (self._i + 1) % self.capacity
        self.n = min(self.n + 1, self.capacity)

    def values(self) -> tuple:
        """(t, v) arrays in chronological order (copies)."""
        if self.n < self.capacity:
            return self._t[:self.n].copy(), self._v[:self.n].copy()
        idx = np.r_[self._i:self.capacity, 0:self._i]
        return self._t[idx], self._v[idx]

    def last(self):
        """(t, v) of the newest sample, or None if empty."""
        if not self.n:
            return None
        j = (self._i - 1) % self.capacity
        return float(self._t[j]), float(self._v[j])


class TimeSeries:
    """The store: ``(wid, metric) -> Ring``. Not thread-safe by itself —
    LiveMonitor serializes access (reader threads push, sampler samples,
    STATS acceptor snapshots)."""

    def __init__(self, capacity: int = 512):
        self.capacity = capacity
        self._series: dict = {}

    def record(self, wid: int, metric: str, value, t: float) -> None:
        try:
            v = float(value)
        except (TypeError, ValueError):
            return                       # non-numeric telemetry: not a series
        ring = self._series.get((wid, metric))
        if ring is None:
            ring = self._series[(wid, metric)] = Ring(self.capacity)
        ring.push(t, v)

    def series(self, wid: int, metric: str) -> Optional[Ring]:
        return self._series.get((wid, metric))

    def last(self, wid: int, metric: str):
        ring = self._series.get((wid, metric))
        return ring.last()[1] if ring is not None and ring.n else None

    def wids(self) -> list:
        return sorted({w for w, _ in self._series})

    def metrics(self, wid: int) -> list:
        return sorted(m for w, m in self._series if w == wid)

    def tail(self, k: int = 32) -> dict:
        """{wid: {metric: [[t, v], ...]}} — the newest ≤k samples of every
        series, JSON-ready (what the STATS frame carries)."""
        out: dict = {}
        for (wid, metric), ring in sorted(self._series.items()):
            t, v = ring.values()
            out.setdefault(wid, {})[metric] = [
                [round(float(a), 3), float(b)]
                for a, b in zip(t[-k:], v[-k:])]
        return out


def sparkline(values, width: int = 24) -> str:
    """Unicode sparkline of the last ``width`` values (monitor rendering)."""
    vals = [float(v) for v in values][-width:]
    if not vals:
        return ""
    lo, hi = min(vals), max(vals)
    span = hi - lo
    if span <= 0:
        return _SPARK[3] * len(vals)
    return "".join(_SPARK[int((v - lo) / span * (len(_SPARK) - 1))]
                   for v in vals)


class HealthDetector:
    """Online straggler / heartbeat-silence detection over the store's
    latest per-worker samples.

    Rates → delays: a worker iterating at r ips spends 1/r s per iteration,
    so ``BoundedStaleness.participation`` (median × deadline_factor over
    delays, quorum-floored) applies verbatim — the SAME policy the sync
    family would use to mask an exchange, here consuming measured signal.
    A worker is flagged only after ``strikes`` consecutive observations
    (one noisy sample must not flag; with the sampler at the heartbeat
    period, strikes=2 ⇒ detection within 2 heartbeat intervals). Rate
    detection waits until EVERY worker has a positive rate — during
    problem build rates are 0 and medians are meaningless. State
    transitions emit events; steady states do not.
    """

    RATE_METRIC = "rate_ips"

    def __init__(self, n_workers: int, deadline_factor: float = 2.0,
                 stale_after_s: float = 6.0, strikes: int = 2,
                 min_quorum: float = 0.5):
        self.n_workers = n_workers
        self.policy = BoundedStaleness(
            n_pods=n_workers, deadline_factor=deadline_factor,
            min_quorum=min_quorum)
        self.stale_after_s = stale_after_s
        self.strikes = max(int(strikes), 1)
        self._strike: dict = {}          # (wid, kind) -> consecutive count
        self.flagged: dict = {}          # wid -> kind currently flagged
        self._step = 0

    def set_n_workers(self, n_workers: int) -> None:
        """Elastic membership changed P: the straggler mask requires a full
        complement of rates, so the detector must learn the new P or it
        would wait forever for the dead worker's heartbeat. Strike state
        resets — the new epoch starts with a clean slate."""
        self.n_workers = n_workers
        self.policy.n_pods = n_workers
        self._strike.clear()

    def observe(self, t: float, rates: dict, staleness: dict) -> list:
        """One detector pass. ``rates``: {wid: latest rate_ips or None};
        ``staleness``: {wid: seconds since last heartbeat}. Returns the
        NEW events (transitions only)."""
        self._step += 1
        current: dict = {}               # wid -> kind observed this pass
        detail: dict = {}
        for wid, s in staleness.items():
            if s > self.stale_after_s:
                current[wid] = "hb_stale"
                detail[wid] = {"hb_age_s": round(float(s), 3)}
        active = {w: r for w, r in rates.items()
                  if r is not None and r > 0.0}
        if len(active) == self.n_workers:
            wids = sorted(active)
            delays = [1.0 / active[w] for w in wids]
            mask = self.policy.participation(self._step, delays)
            med = float(np.median([active[w] for w in wids]))
            for w, m in zip(wids, mask):
                if m == 0 and w not in current:
                    current[w] = "straggler"
                    detail[w] = {"rate_ips": active[w],
                                 "median_rate_ips": round(med, 2)}
        events = []
        for wid, kind in current.items():
            key = (wid, kind)
            self._strike[key] = self._strike.get(key, 0) + 1
            if (self._strike[key] >= self.strikes
                    and self.flagged.get(wid) != kind):
                self.flagged[wid] = kind
                events.append({"t": round(t, 3), "kind": kind, "wid": wid,
                               **detail.get(wid, {})})
        for key in list(self._strike):
            if current.get(key[0]) != key[1]:
                del self._strike[key]
        for wid in list(self.flagged):
            if wid not in current:
                events.append({"t": round(t, 3), "kind": "recovered",
                               "wid": wid,
                               "was": self.flagged.pop(wid)})
        return events


class LiveMonitor:
    """Store + detector + JSONL stream behind one lock. The master feeds it
    from three threads (per-link readers via ``ingest_hb``, the sampler via
    ``sample``, the STATS acceptor via ``snapshot``); the shared-memory
    transports call ``sample`` from the launcher poll loop with aggregate
    gauges only (no per-worker heartbeats there — honest boundary)."""

    def __init__(self, n_workers: int, deadline_factor: float = 2.0,
                 hb_interval_s: float = 2.0, stale_after_s: float = 0.0,
                 capacity: int = 512, jsonl_path: Optional[str] = None,
                 counters=None, meta: Optional[dict] = None):
        self.store = TimeSeries(capacity=capacity)
        self.detector = HealthDetector(
            n_workers, deadline_factor=deadline_factor,
            stale_after_s=stale_after_s or max(3.0 * hb_interval_s, 1.0))
        self.events: list = []
        self._retired: set = set()       # wids no longer in the run — their
        #                                  stale ring samples must not feed
        #                                  the detector after an epoch change
        self.counters = counters         # metrics.Registry (health_events)
        self.meta = dict(meta or {})
        self.n_samples = 0
        self._t0 = time.monotonic()
        self._lock = threading.Lock()
        self._jsonl = open(jsonl_path, "w") if jsonl_path else None
        if self._jsonl is not None:
            # eager run-header line: even a run shorter than the first
            # sampler tick leaves a parseable record of what it was
            json.dump({"meta": self.meta, "n_workers": n_workers,
                       "hb_interval_s": hb_interval_s}, self._jsonl)
            self._jsonl.write("\n")
            self._jsonl.flush()

    def _now(self) -> float:
        return time.monotonic() - self._t0

    def _emit(self, events: list) -> None:
        self.events.extend(events)
        if events and self.counters is not None:
            self.counters.counter("health_events").value += len(events)

    # -- feeds ---------------------------------------------------------------

    def ingest_hb(self, wid: int, payload: dict) -> None:
        """Called from a link reader thread on EVERY telemetry-bearing
        HEARTBEAT: each numeric field becomes one sample (covers future
        fields — a worker reporting ``loss`` lands here unchanged)."""
        t = self._now()
        with self._lock:
            for key, value in payload.items():
                self.store.record(wid, key, value, t)

    def sample(self, staleness: Optional[dict] = None,
               gauges: Optional[dict] = None) -> list:
        """One sampler pass: record master-side per-worker staleness and
        aggregate gauges (wid −1), run the detector over the latest rates,
        stream the sample to JSONL. Returns the new events."""
        t = self._now()
        with self._lock:
            staleness = dict(staleness or {})
            for wid, s in staleness.items():
                self.store.record(wid, "hb_staleness_s", s, t)
            for key, value in (gauges or {}).items():
                self.store.record(AGG_WID, key, value, t)
            rates = {w: self.store.last(w, HealthDetector.RATE_METRIC)
                     for w in self.store.wids()
                     if w >= 0 and w not in self._retired}
            if staleness and not rates:
                rates = {w: None for w in staleness}
            events = self.detector.observe(t, rates, staleness) \
                if rates else []
            self._emit(events)
            self.n_samples += 1
            if self._jsonl is not None:
                json.dump({"t": round(t, 3),
                           "workers": self._latest_locked(),
                           "gauges": {k: v for k, v in (gauges or {}).items()
                                      if isinstance(v, (int, float))},
                           "events": events}, self._jsonl)
                self._jsonl.write("\n")
                self._jsonl.flush()
        return events

    def set_membership(self, active_wids) -> None:
        """Elastic epoch change: the detector tracks the new P and retired
        wids stop feeding it (their last ring samples would otherwise count
        as live rates forever)."""
        wids = sorted(int(w) for w in active_wids)
        with self._lock:
            self._retired = {w for w in self.store.wids()
                             if w >= 0 and w not in wids}
            self.detector.set_n_workers(len(wids))

    def mark_worker_event(self, wid: int, kind: str, detail: str = ""
                          ) -> dict:
        """Lifecycle events the wire observes directly (mid-run BYE, dead
        socket) — no debouncing, the signal is unambiguous."""
        ev = {"t": round(self._now(), 3), "kind": kind, "wid": wid}
        if detail:
            ev["detail"] = detail
        with self._lock:
            self._emit([ev])
            if self._jsonl is not None:
                # event-only record: the JSONL stream must name the death /
                # recovery even if the run ends before the next sampler tick
                # (launch/monitor --from-jsonl folds bare event lines in)
                json.dump({"t": ev["t"], "events": [ev]}, self._jsonl)
                self._jsonl.write("\n")
                self._jsonl.flush()
        return ev

    # -- reads ---------------------------------------------------------------

    def _latest_locked(self) -> dict:
        out: dict = {}
        for wid in self.store.wids():
            if wid < 0:
                continue
            out[wid] = {m: self.store.last(wid, m)
                        for m in self.store.metrics(wid)}
        return out

    def snapshot(self, k: int = 32) -> dict:
        """JSON-ready state: what the STATS frame serves and what
        ``health()`` summarizes."""
        with self._lock:
            return {"t": round(self._now(), 3),
                    "meta": dict(self.meta),
                    "n_samples": self.n_samples,
                    "events": list(self.events),
                    "flagged": {str(w): k
                                for w, k in self.detector.flagged.items()},
                    "workers": self.store.tail(k),
                    "gauges": {m: self.store.last(AGG_WID, m)
                               for m in self.store.metrics(AGG_WID)}}

    def health(self) -> dict:
        """The ``PSResult.health`` payload: events + final per-worker
        telemetry, compact (no series history)."""
        with self._lock:
            return {"events": list(self.events),
                    "flagged": {str(w): k
                                for w, k in self.detector.flagged.items()},
                    "n_samples": self.n_samples,
                    "workers": self._latest_locked()}

    def close(self) -> None:
        with self._lock:
            if self._jsonl is not None:
                self._jsonl.close()
                self._jsonl = None


def render(snap: dict, width: int = 24) -> str:
    """The monitor's table: one row per worker from a ``snapshot()`` dict
    (shared by ``launch/monitor`` live mode and its --from-jsonl mode)."""
    meta = snap.get("meta", {})
    lines = [
        "run: {algo} [{transport}] t={t:.1f}s samples={n} "
        "health_events={ev}".format(
            algo=meta.get("algorithm", "?"),
            transport=meta.get("transport", "?"),
            t=snap.get("t", 0.0), n=snap.get("n_samples", 0),
            ev=len(snap.get("events", []))),
        f"{'wid':>4} {'iters':>8} {'rate_ips':>9} {'exposed_s':>9} "
        f"{'hb_age':>7} {'status':<10} rate history",
    ]
    flagged = snap.get("flagged", {})
    for wid, series in sorted(snap.get("workers", {}).items(),
                              key=lambda kv: int(kv[0])):
        w = int(wid)
        if w < 0:
            continue

        def _last(metric):
            pts = series.get(metric) or []
            return pts[-1][1] if pts else None

        rate_pts = series.get("rate_ips") or []
        kind = flagged.get(str(w)) or flagged.get(w)
        status = kind.upper() if kind else "ok"
        iters = _last("iters")
        rate = _last("rate_ips")
        hb = _last("hb_staleness_s")
        exposed = _last("exposed_s")
        lines.append(
            f"{w:>4} "
            f"{int(iters) if iters is not None else '-':>8} "
            f"{f'{rate:.1f}' if rate is not None else '-':>9} "
            f"{f'{exposed:.2f}' if exposed is not None else '-':>9} "
            f"{f'{hb:.1f}' if hb is not None else '-':>7} "
            f"{status:<10} "
            f"{sparkline([v for _, v in rate_pts], width)}")
    for ev in snap.get("events", [])[-5:]:
        lines.append(f"  event t={ev.get('t')}s wid={ev.get('wid')} "
                     f"{ev.get('kind')}"
                     + (f" ({ev.get('detail')})" if ev.get("detail") else ""))
    gauges = snap.get("gauges") or {}
    if gauges:
        shown = ", ".join(f"{k}={v}" for k, v in sorted(gauges.items())
                          if isinstance(v, (int, float)))
        if shown:
            lines.append(f"  master: {shown}")
    return "\n".join(lines)
