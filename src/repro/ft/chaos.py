"""Deterministic fault injection for the TCP runtime.

Every failure path the membership machinery handles must be reproducible in
a test: this module turns "a worker dies at iteration k" into configuration.
A :class:`ChaosSpec` rides ``PSConfig.chaos`` on the master, is serialized
into the spawned worker's environment (``REPRO_CHAOS`` JSON), and the worker
*self-inflicts* the failure — ``os.kill(os.getpid(), SIGKILL/SIGTERM)`` at a
step boundary — so no supervisor process or timing race is involved:

* ``signal="kill"``  — SIGKILL: the socket drops mid-run, the master sees a
  dead link / process exit (the DEAD path).
* ``signal="term"``  — SIGTERM: ``ft.Watchdog`` catches it and the worker
  departs with a clean ``preempted`` BYE (the LEFT path).
* ``dial_refuse_s`` — the worker's HELLO dial is synthetically refused for
  the first window seconds (``wire.dial_with_backoff``'s ``refuse_fn``),
  exercising the retry satellite without a real staggered start.

jax-free; imported by the thin TCP worker on its startup path.
"""
from __future__ import annotations

import json
import os
import signal as _signal
import time
from dataclasses import asdict, dataclass

ENV_VAR = "REPRO_CHAOS"
SIGNALS = ("kill", "term")


@dataclass(frozen=True)
class ChaosSpec:
    wid: int                      # the worker the fault targets
    kill_at_iter: int = -1        # self-signal at the first step >= this (-1 = never)
    signal: str = "kill"          # "kill" (SIGKILL) | "term" (clean preemption)
    dial_refuse_s: float = 0.0    # refuse the HELLO dial for this long

    def __post_init__(self):
        assert self.signal in SIGNALS, self.signal
        assert self.dial_refuse_s >= 0.0, self.dial_refuse_s

    def to_env(self) -> str:
        return json.dumps(asdict(self))

    @staticmethod
    def from_env(env: dict | None = None) -> "ChaosSpec | None":
        raw = (env if env is not None else os.environ).get(ENV_VAR)
        if not raw:
            return None
        return ChaosSpec(**json.loads(raw))

    @staticmethod
    def from_config(chaos) -> "ChaosSpec | None":
        """Normalize ``PSConfig.chaos`` (a ChaosSpec or a plain dict)."""
        if chaos is None:
            return None
        if isinstance(chaos, ChaosSpec):
            return chaos
        return ChaosSpec(**dict(chaos))


class ChaosClock:
    """The worker-side trigger: armed from ``REPRO_CHAOS`` at startup.

    ``maybe_fire(wid, step)`` is called at step boundaries; on the targeted
    worker at the targeted step it raises the configured signal against the
    calling process and (for SIGKILL) never returns. The dial-refuse window
    starts at construction time — i.e. worker process start — which is what
    a staggered launch looks like.
    """

    def __init__(self, spec: ChaosSpec | None):
        self.spec = spec
        self._t0 = time.monotonic()

    def refuse_dial(self, wid: int) -> bool:
        s = self.spec
        return (s is not None and s.wid == wid and s.dial_refuse_s > 0.0
                and (time.monotonic() - self._t0) < s.dial_refuse_s)

    def maybe_fire(self, wid: int, step: int) -> None:
        s = self.spec
        if s is None or s.wid != wid or s.kill_at_iter < 0:
            return
        if step >= s.kill_at_iter:
            signo = (_signal.SIGKILL if s.signal == "kill"
                     else _signal.SIGTERM)
            os.kill(os.getpid(), signo)
            # SIGTERM: the Watchdog handler runs; the loop notices at its
            # next watchdog check. Disarm so the signal fires exactly once.
            self.spec = None


def clock_from_env(env: dict | None = None) -> ChaosClock:
    return ChaosClock(ChaosSpec.from_env(env))
