"""Preemption watchdog: signal-triggered final checkpoint + heartbeats.

On SIGTERM/SIGINT (cluster preemption) the watchdog sets a stop flag; the
train loop checks it each step, writes a final checkpoint and exits cleanly.
A heartbeat file lets an external supervisor detect hung processes (the
'node failure' detection path at 1000+ nodes; here single-process)."""
from __future__ import annotations

import os
import signal
import threading
import time
from typing import Optional


class Watchdog:
    def __init__(self, heartbeat_path: Optional[str] = None,
                 interval_s: float = 10.0, install_signals: bool = True):
        self.should_stop = threading.Event()
        self.heartbeat_path = heartbeat_path
        self.interval_s = interval_s
        self._hb_thread: Optional[threading.Thread] = None
        self._prev_handlers = {}
        if install_signals:
            for sig in (signal.SIGTERM, signal.SIGINT):
                try:
                    self._prev_handlers[sig] = signal.signal(
                        sig, self._on_signal)
                except ValueError:      # not in main thread
                    pass

    def _on_signal(self, signum, frame):
        self.should_stop.set()

    def start_heartbeat(self):
        if self.heartbeat_path is None or self._hb_thread is not None:
            return self

        def beat():
            while not self.should_stop.is_set():
                try:
                    with open(self.heartbeat_path, "w") as f:
                        f.write(str(time.time()))
                except OSError:
                    pass
                self.should_stop.wait(self.interval_s)

        self._hb_thread = threading.Thread(target=beat, daemon=True)
        self._hb_thread.start()
        return self

    @staticmethod
    def is_alive(heartbeat_path: str, timeout_s: float = 60.0) -> bool:
        try:
            with open(heartbeat_path) as f:
                last = float(f.read().strip())
        except (OSError, ValueError):
            return False
        return (time.time() - last) < timeout_s

    def close(self):
        self.should_stop.set()
        for sig, h in self._prev_handlers.items():
            try:
                signal.signal(sig, h)
            except ValueError:
                pass
