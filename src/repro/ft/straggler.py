"""Straggler mitigation policies.

The paper's asynchronous variants (Async/Hogwild EASGD) tolerate stragglers
by construction — a slow worker simply contributes later. For the
deterministic Sync EASGD path we provide BOUNDED STALENESS: a pod that
misses the exchange deadline is excluded from this round's elastic mean
(its weights rejoin next round). Mathematically this is Hogwild EASGD's
partial update, made deterministic per round via an explicit participation
mask — the center update becomes
    W̄ ← W̄ + ηρ Σ_{i ∈ alive} (W⁽ⁱ⁾ − W̄).

These policies drive both the discrete-event simulator (benchmarks) and the
host-level training driver; the mask plugs into the jitted step as data.
The mask math itself is numpy-only, and ``obs.live`` feeds it REAL
telemetry (per-worker heartbeat rates) from the jax-free tcp master — so
this module must stay importable without jax; only ``masked_center_mean``
(the jitted-path helper) touches jax, lazily.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class StragglerPolicy:
    """Base: everyone always participates."""
    n_pods: int

    def participation(self, step: int, delays_s) -> np.ndarray:
        return np.ones((self.n_pods,), np.float32)


@dataclasses.dataclass
class BoundedStaleness(StragglerPolicy):
    """Exclude pods slower than ``deadline_factor`` × median round time."""
    deadline_factor: float = 1.5
    min_quorum: float = 0.5

    def participation(self, step: int, delays_s) -> np.ndarray:
        delays = np.asarray(delays_s, np.float64)
        deadline = np.median(delays) * self.deadline_factor
        mask = (delays <= deadline).astype(np.float32)
        if mask.mean() < self.min_quorum:   # keep quorum: admit fastest half
            order = np.argsort(delays)
            mask = np.zeros_like(mask)
            mask[order[: max(1, int(np.ceil(self.n_pods * self.min_quorum)))]] = 1
        return mask


def masked_center_mean(w_pods, center_flat, mask):
    """Mean over participating pods only (for the host-driven exchange).
    w_pods: (P, N); mask: (P,) 0/1. Returns the masked mean of W."""
    import jax.numpy as jnp
    m = jnp.asarray(mask, jnp.float32)[:, None]
    denom = jnp.maximum(m.sum(), 1.0)
    return center_flat + (m * (w_pods - center_flat[None])).sum(0) / denom
