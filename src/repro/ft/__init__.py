"""repro.ft — fault-tolerance primitives (straggler policies, elastic pod
scaling, membership, chaos injection, the preemption watchdog).

Lazy re-exports (PEP 562). Every submodule here is importable jax-free —
the tcp worker/master pull ``straggler``/``watchdog``/``membership``/
``chaos`` on their sub-second startup path, and ``elastic_scale`` defers
its jax import into the jitted-tree functions themselves (the flat-row
``pod_join_rows``/``pod_leave_rows`` variants are pure numpy).
"""
_SUBMODULES = ("straggler", "watchdog", "elastic_scale", "membership",
               "chaos")

_EXPORTS = {
    "StragglerPolicy": "repro.ft.straggler",
    "BoundedStaleness": "repro.ft.straggler",
    "masked_center_mean": "repro.ft.straggler",
    "Watchdog": "repro.ft.watchdog",
    "rescale_pods": "repro.ft.elastic_scale",
    "pod_join": "repro.ft.elastic_scale",
    "pod_leave": "repro.ft.elastic_scale",
    "pod_join_rows": "repro.ft.elastic_scale",
    "pod_leave_rows": "repro.ft.elastic_scale",
    "MembershipTable": "repro.ft.membership",
    "ChaosSpec": "repro.ft.chaos",
    "ChaosClock": "repro.ft.chaos",
}

__all__ = sorted(_EXPORTS) + list(_SUBMODULES)


def __getattr__(name):
    import importlib
    if name in _SUBMODULES:
        return importlib.import_module(f"repro.ft.{name}")
    mod = _EXPORTS.get(name)
    if mod is None:
        raise AttributeError(f"module 'repro.ft' has no attribute {name!r}")
    return getattr(importlib.import_module(mod), name)
