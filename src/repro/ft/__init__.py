from repro.ft.elastic_scale import rescale_pods, pod_join, pod_leave
from repro.ft.straggler import StragglerPolicy, BoundedStaleness
from repro.ft.watchdog import Watchdog
