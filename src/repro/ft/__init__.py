"""repro.ft — fault-tolerance primitives (straggler policies, elastic pod
scaling, the preemption watchdog).

Lazy re-exports (PEP 562): ``straggler`` and ``watchdog`` are jax-free and
are imported by the tcp worker/master (the live health detector wires
``BoundedStaleness`` to real heartbeat telemetry — obs/live.py);
``elastic_scale`` operates on jitted pod state and pulls jax, so it must
not load just because a jax-free process said ``import repro.ft``.
"""
_EXPORTS = {
    "StragglerPolicy": "repro.ft.straggler",
    "BoundedStaleness": "repro.ft.straggler",
    "masked_center_mean": "repro.ft.straggler",
    "Watchdog": "repro.ft.watchdog",
    "rescale_pods": "repro.ft.elastic_scale",
    "pod_join": "repro.ft.elastic_scale",
    "pod_leave": "repro.ft.elastic_scale",
}

__all__ = sorted(_EXPORTS) + ["straggler", "watchdog", "elastic_scale"]


def __getattr__(name):
    import importlib
    if name in ("straggler", "watchdog", "elastic_scale"):
        return importlib.import_module(f"repro.ft.{name}")
    mod = _EXPORTS.get(name)
    if mod is None:
        raise AttributeError(f"module 'repro.ft' has no attribute {name!r}")
    return getattr(importlib.import_module(mod), name)
