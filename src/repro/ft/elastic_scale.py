"""Elastic pod scaling — EASGD-native fault tolerance (DESIGN.md §8).

EASGD's center weight W̄ is the durable state: a worker's contribution
enters through the elastic mean, so pods can leave (failure/preemption) or
join (capacity) BETWEEN exchange rounds without a global barrier:

 * pod_leave: drop the pod's local (W, V) rows; the center is untouched —
   at most τ local steps of that pod's progress are lost.
 * pod_join:  the new pod seeds its local weights FROM the center (the
   same thing Alg. 4 lines 4-7 do at init) with zero momentum.

This is the principled version of checkpoint-restart: the restarted/new
worker starts from the consensus point, exactly like EASGD's theory assumes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.elastic import ElasticState


def pod_leave(state: ElasticState, pod_index: int) -> ElasticState:
    """Remove one pod's local replica (n_pods -> n_pods-1)."""
    take = lambda x: jnp.concatenate(
        [x[:pod_index], x[pod_index + 1:]], axis=0)
    new = state._replace(
        params=jax.tree_util.tree_map(take, state.params),
        momentum=jax.tree_util.tree_map(take, state.momentum),
    )
    if state.ef_error is not None:
        new = new._replace(
            ef_error=jax.tree_util.tree_map(take, state.ef_error))
    return new


def pod_join(state: ElasticState) -> ElasticState:
    """Add one pod seeded from the center (n_pods -> n_pods+1)."""
    def add_from_center(local, center):
        row = center.astype(local.dtype)[None]
        return jnp.concatenate([local, row], axis=0)

    params = jax.tree_util.tree_map(add_from_center, state.params,
                                    state.center)
    momentum = jax.tree_util.tree_map(
        lambda v: jnp.concatenate([v, jnp.zeros_like(v[:1])], axis=0),
        state.momentum)
    new = state._replace(params=params, momentum=momentum)
    if state.ef_error is not None:
        new = new._replace(ef_error=jax.tree_util.tree_map(
            lambda e: jnp.concatenate([e, jnp.zeros_like(e[:1])], axis=0),
            state.ef_error))
    return new


def rescale_pods(state: ElasticState, new_n_pods: int) -> ElasticState:
    """Resize to ``new_n_pods`` (shrink drops highest pods; grow seeds from
    the center)."""
    cur = jax.tree_util.tree_leaves(state.params)[0].shape[0]
    while cur > new_n_pods:
        state = pod_leave(state, cur - 1)
        cur -= 1
    while cur < new_n_pods:
        state = pod_join(state)
        cur += 1
    return state
