"""Elastic pod scaling — EASGD-native fault tolerance (DESIGN.md §8).

EASGD's center weight W̄ is the durable state: a worker's contribution
enters through the elastic mean, so pods can leave (failure/preemption) or
join (capacity) BETWEEN exchange rounds without a global barrier:

 * pod_leave: drop the pod's local (W, V) rows; the center is untouched —
   at most τ local steps of that pod's progress are lost.
 * pod_join:  the new pod seeds its local weights FROM the center (the
   same thing Alg. 4 lines 4-7 do at init) with zero momentum.

This is the principled version of checkpoint-restart: the restarted/new
worker starts from the consensus point, exactly like EASGD's theory assumes.

Two families live here: the jitted-tree forms (``pod_join``/``pod_leave``/
``rescale_pods`` on ``core.elastic.ElasticState`` — jax imported lazily, so
the TCP worker's jax-free import path survives) and the flat-row forms
(``pod_join_rows``/``pod_leave_rows`` on the PS runtime's (P, n) float64
arrays — pure numpy, what ``ft.membership`` reconfigurations reuse).
"""
from __future__ import annotations

import numpy as np


def pod_leave(state, pod_index: int):
    """Remove one pod's local replica (n_pods -> n_pods-1)."""
    import jax
    import jax.numpy as jnp

    take = lambda x: jnp.concatenate(
        [x[:pod_index], x[pod_index + 1:]], axis=0)
    new = state._replace(
        params=jax.tree_util.tree_map(take, state.params),
        momentum=jax.tree_util.tree_map(take, state.momentum),
    )
    if state.ef_error is not None:
        new = new._replace(
            ef_error=jax.tree_util.tree_map(take, state.ef_error))
    return new


def pod_join(state):
    """Add one pod seeded from the center (n_pods -> n_pods+1)."""
    import jax
    import jax.numpy as jnp

    def add_from_center(local, center):
        row = center.astype(local.dtype)[None]
        return jnp.concatenate([local, row], axis=0)

    params = jax.tree_util.tree_map(add_from_center, state.params,
                                    state.center)
    momentum = jax.tree_util.tree_map(
        lambda v: jnp.concatenate([v, jnp.zeros_like(v[:1])], axis=0),
        state.momentum)
    new = state._replace(params=params, momentum=momentum)
    if state.ef_error is not None:
        new = new._replace(ef_error=jax.tree_util.tree_map(
            lambda e: jnp.concatenate([e, jnp.zeros_like(e[:1])], axis=0),
            state.ef_error))
    return new


def rescale_pods(state, new_n_pods: int):
    """Resize to ``new_n_pods`` (shrink drops highest pods; grow seeds from
    the center)."""
    import jax

    cur = jax.tree_util.tree_leaves(state.params)[0].shape[0]
    while cur > new_n_pods:
        state = pod_leave(state, cur - 1)
        cur -= 1
    while cur < new_n_pods:
        state = pod_join(state)
        cur += 1
    return state


# --- flat-row variants: the PS runtime's state layout (numpy only) ---

def pod_leave_rows(workers_w: np.ndarray, workers_v: np.ndarray,
                   pod_index: int) -> tuple[np.ndarray, np.ndarray]:
    """Drop row ``pod_index`` from the (P, n) local-replica arrays.

    The center is deliberately NOT an argument: EASGD's center never changes
    when a pod leaves — only the elastic mean's denominator does, and that
    is the reconfigured P' the next exchange divides by.
    """
    assert workers_w.ndim == 2 and 0 <= pod_index < workers_w.shape[0]
    keep = np.r_[0:pod_index, pod_index + 1:workers_w.shape[0]]
    return (np.ascontiguousarray(workers_w[keep]),
            np.ascontiguousarray(workers_v[keep]))


def pod_join_rows(workers_w: np.ndarray, workers_v: np.ndarray,
                  center: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Append one row seeded from the center with zero momentum
    ((P, n) -> (P+1, n)) — Alg. 4's init, at runtime."""
    assert workers_w.ndim == 2 and center.shape == workers_w.shape[1:]
    row = np.asarray(center, dtype=workers_w.dtype)[None]
    return (np.concatenate([workers_w, row], axis=0),
            np.concatenate([workers_v, np.zeros_like(row)], axis=0))
