"""Elastic membership: the master-side worker state machine.

The paper's HPC regime assumes a fixed, reliable P; a production runtime has
to treat P as a fluid. This module owns the bookkeeping half of that story —
who is in the run, what state they are in, and which *epoch* of the schedule
they belong to — while ``net.server`` owns the wire actions (freezing the
superstep, re-resolving rounds, shipping RECONFIGURE frames).

State machine (per worker)::

    JOINED ──READY──► ACTIVE ──hb stale──► SUSPECT ──timeout/ERROR──► DEAD
       │                 │                    │
       │                 ├──BYE preempted────►└──────────────────────► LEFT
       │                 └──ERROR/socket drop───────────────────────► DEAD
    DEAD/LEFT ──rejoin HELLO──► JOINED (next epoch)

Transitions bump nothing by themselves; ``epoch`` advances only when the
server completes a reconfiguration (survivors re-scheduled, mesh rewired).
The table is jax-free and transport-agnostic — the thread/process transports
could drive it too, though today only the TCP master does.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field

JOINED = "joined"      # HELLO accepted, not yet READY
ACTIVE = "active"      # participating in the current epoch
SUSPECT = "suspect"    # heartbeat stale — not yet declared lost
DEAD = "dead"          # socket drop / ERROR frame / process exit
LEFT = "left"          # clean mid-run BYE (preemption)

STATES = (JOINED, ACTIVE, SUSPECT, DEAD, LEFT)
_LOST = (DEAD, LEFT)


@dataclass
class Member:
    wid: int
    state: str = JOINED
    epoch: int = 0          # epoch the member (re)joined at
    since: float = field(default_factory=time.monotonic)
    detail: str = ""

    def _move(self, state: str, detail: str = "") -> None:
        self.state = state
        self.since = time.monotonic()
        self.detail = detail


class MembershipTable:
    """Thread-safe membership table for one run.

    The master's reader threads mark transitions; the serve loop reads
    ``survivors()`` and drives reconfigurations. All mutation is under one
    lock — membership changes are rare (human-timescale) events, never on
    the per-round hot path.
    """

    def __init__(self, n_workers: int):
        self._lock = threading.Lock()
        self.epoch = 0
        self.members = {w: Member(w) for w in range(n_workers)}
        self.history: list[dict] = []     # transition log, JSON-able

    def _record(self, m: Member, prev: str) -> None:
        self.history.append({"wid": m.wid, "from": prev, "to": m.state,
                             "epoch": self.epoch, "detail": m.detail})

    def _transition(self, wid: int, state: str, detail: str = "") -> None:
        with self._lock:
            m = self.members.setdefault(wid, Member(wid))
            prev = m.state
            if prev == state:
                return
            m._move(state, detail)
            self._record(m, prev)

    # --- transitions, named for the wire events that drive them ---
    def mark_ready(self, wid: int) -> None:
        self._transition(wid, ACTIVE)

    def mark_suspect(self, wid: int, detail: str = "hb stale") -> None:
        with self._lock:
            m = self.members[wid]
            if m.state == ACTIVE:
                prev = m.state
                m._move(SUSPECT, detail)
                self._record(m, prev)

    def mark_dead(self, wid: int, detail: str = "") -> None:
        self._transition(wid, DEAD, detail)

    def mark_left(self, wid: int, detail: str = "preempted") -> None:
        self._transition(wid, LEFT, detail)

    def mark_rejoined(self, wid: int) -> None:
        """A respawned worker HELLOed with the rejoin flag: back to JOINED;
        it becomes ACTIVE at the next reconfiguration epoch."""
        with self._lock:
            m = self.members.setdefault(wid, Member(wid))
            prev = m.state
            m._move(JOINED, "rejoin")
            m.epoch = self.epoch + 1    # enters at the NEXT epoch
            self._record(m, prev)

    def advance_epoch(self) -> int:
        """A reconfiguration completed: everyone JOINED/SUSPECT-surviving
        becomes ACTIVE in the new epoch. Returns the new epoch number."""
        with self._lock:
            self.epoch += 1
            for m in self.members.values():
                if m.state in (JOINED, SUSPECT):
                    prev = m.state
                    m._move(ACTIVE, f"epoch {self.epoch}")
                    m.epoch = self.epoch
                    self._record(m, prev)
            return self.epoch

    # --- reads ---
    def state(self, wid: int) -> str:
        with self._lock:
            return self.members[wid].state

    def is_lost(self, wid: int) -> bool:
        with self._lock:
            m = self.members.get(wid)
            return m is not None and m.state in _LOST

    def survivors(self) -> list[int]:
        """wids still in the run (ACTIVE or SUSPECT — a suspect is given the
        benefit of the doubt until declared), sorted ascending so the lowest
        survivor is a deterministic leader choice."""
        with self._lock:
            return sorted(w for w, m in self.members.items()
                          if m.state in (ACTIVE, SUSPECT))

    def joiners(self) -> list[int]:
        with self._lock:
            return sorted(w for w, m in self.members.items()
                          if m.state == JOINED)

    def snapshot(self) -> dict:
        with self._lock:
            return {"epoch": self.epoch,
                    "members": {w: m.state for w, m in self.members.items()},
                    "transitions": list(self.history)}


def dense_rank_map(survivors: list[int]) -> dict[int, int]:
    """dense rank (0..P'−1) → real wid, for remapping schedule rounds built
    over a dense index space onto the surviving members."""
    return {rank: wid for rank, wid in enumerate(sorted(survivors))}
