"""The repro.net peer-to-peer data plane: workers execute ``Schedule.rounds``
over direct worker↔worker TCP links, bucketed for comm/compute overlap.

Under the centralized sync plane the master executes the allreduce on its
local mailbox, so every training round funnels Θ(P·N) bytes through the
master's links — the rank-ordered incast the paper's §5.1/§6.1 schedules
exist to eliminate. Here each worker owns ONE mailbox row and moves exactly
the registry's message pattern itself: for every ``Message`` whose ``src``
is this worker, the ``Message.span`` slice of the row goes out as a SEGMENT
frame on the persistent link to ``dst``; for every message whose ``dst`` is
this worker, the matching slice is received and combined (``add``/``set``).
The master degrades to a control-plane coordinator (rendezvous, eval,
heartbeats, shutdown) and its links carry only Θ(N_center) — worker 0's
CENTER reports — while per-worker ring traffic is ~2N(P−1)/P per exchange.

Wiring: every worker opens a peer listener BEFORE saying HELLO and
advertises its (host, port); the master's WELCOME carries the full
directory plus the resolved rounds (``comm.rounds`` wire form — this
module, like the worker, never imports the jax-side registry). For each
unordered pair (i, j) that appears in the rounds, the HIGHER wid dials the
lower's listener (PEERS handshake: {"wid", "token"} out, {"wid"} ack back);
dials complete against the listener backlog before anyone blocks in
accept, so the mesh setup cannot deadlock.

BUCKETS: ``set_rounds`` accepts element boundaries that partition the row
into per-layer-group buckets (``comm.rounds.bucket_rounds``). Each bucket
executes the SAME rounds with every message's span clipped to the bucket —
same sources, same op, same order per element as the monolithic exchange,
which is why bucketed rows stay bitwise equal to monolithic ones (and to
the centralized plane). The caller streams buckets in order and learns of
each completion via ``on_bucket``, so bucket i+1's SEGMENT frames fly
while bucket i's update computes — the paper's §6.1.3 overlap, on a real
wire. Per-bucket sign-EF keys (the ef_tag carries the bucket index) keep
every (peer, bucket, segment, direction) quantization residual separate.

ROUND ENGINE: each round's sends and receives progress together on
non-blocking sockets under ``select`` — any link that can move bytes
moves them, at kernel-buffer granularity. No ordering between sends and
receives is ever required, so rows (or buckets) of ANY size stream through
bounded socket buffers without the distributed-deadlock risk of an
everyone-sends-first cycle, and without PR 4's helper-thread escape hatch
(retired). Receives still apply AFTER the round's sends have snapshot
their data: codec-none ``op=set`` segments land directly in the row only
when their span is disjoint from every send span of the same round;
everything else lands in scratch and is applied once the round completes —
the exact PRE-round-value discipline of ``ps.execute_rounds``, which,
together with IEEE-754 addition's commutativity, makes every worker's row
bitwise equal to the centralized ``mailbox[0]`` (the thread↔tcp↔p2p
triangle pinned in tests/test_net.py).
"""
from __future__ import annotations

import select
import socket
import threading
from time import monotonic as _monotonic
from time import perf_counter as _perf_counter

import numpy as np

from repro.comm.rounds import MASTER, bucket_rounds, clip_span
from repro.net import wire
from repro.net.wire import Link
from repro.obs import trace as _trace

# socket-op granularity of the round engine: one non-blocking send() call
# hands the kernel at most this many bytes, so a single link can never
# monopolize a round's progress loop (receives interleave at the same
# grain). Purely a fairness knob — correctness never depends on it.
SEND_OP_MAX = 256 * 1024


class MeshAbort(Exception):
    """The mesh was asked to abandon the in-flight exchange (elastic
    reconfiguration): not a wire failure — the caller rewires and resumes."""


def predicted_link_bytes(rounds, padded_elements: int,
                         boundaries=None) -> dict:
    """Exact wire bytes (header + raw-f64 payload) per unordered worker
    pair for ONE exchange of the given rounds — what each endpoint's
    per-link counter must report per exchange under ``codec=none``. Both
    directions of a pair are summed, matching a Link's counter (it counts
    its sends AND its receives). With ``boundaries``, each message is
    clipped per bucket and each non-empty clip is its own frame (one more
    header), exactly as the bucketed engine sends them."""
    bounds = [0, padded_elements] if boundaries is None \
        else [int(x) for x in boundaries]
    out: dict[tuple, int] = {}
    for rnd in rounds:
        for m in rnd:
            if m.src == MASTER or m.dst == MASTER:
                continue
            pair = (min(m.src, m.dst), max(m.src, m.dst))
            for lo, hi in zip(bounds[:-1], bounds[1:]):
                span = clip_span(m, padded_elements, lo, hi)
                if span is None:
                    continue
                a, b = span
                out[pair] = out.get(pair, 0) + wire.HEADER_SIZE + (b - a) * 8
    return out


class _LinkIO:
    """Per-link engine state for one round: a FIFO of outgoing frame
    buffers and a FIFO of expected incoming segments, each with a byte
    cursor — resumable whenever ``select`` says the socket is ready."""

    __slots__ = ("link", "send_q", "send_vi", "send_off", "recv_q",
                 "hdr_buf", "hdr_got", "frame", "pay_view", "pay_buf",
                 "pay_got", "recv_cur")

    def __init__(self, link: Link):
        self.link = link
        self.send_q: list = []       # [ [views...], payload_len ]
        self.send_vi = 0             # view index within head frame
        self.send_off = 0            # byte offset within current view
        self.recv_q: list = []       # (a, b, op, scratch, direct)
        self.hdr_buf = bytearray(wire.HEADER_SIZE)
        self.hdr_got = 0
        self.frame = None
        self.pay_view = None
        self.pay_buf = None
        self.pay_got = 0
        self.recv_cur = None


class PeerMesh:
    """One worker's endpoint of the p2p data plane: listener + persistent
    links to every peer its rounds talk to, plus the bucketed round
    executor."""

    def __init__(self, wid: int, token: str, codec: str = "none",
                 bind_host: str = "0.0.0.0", port: int = 0,
                 timeout_s: float = 600.0):
        self.wid = wid
        self.token = token
        self.codec = codec
        self.timeout_s = timeout_s
        self.listener = socket.socket()
        self.listener.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        try:
            self.listener.bind((bind_host, port))
        except OSError:
            # bind_host is the interface the master link runs over; if it
            # is not bindable (NAT'd advertisement), fall back to any
            self.listener.bind(("0.0.0.0", port))
        self.listener.listen(16)
        self.port = self.listener.getsockname()[1]
        self.links: dict[int, Link] = {}
        self.counters: dict[int, dict] = {}
        self.rounds_executed = 0
        self.bucket_send_bytes: list[int] = []   # logical f64 payload sent,
        #                                          per bucket, all exchanges
        self.boundaries: list[int] = []
        self._plans: list = []           # per bucket: [(sends, recvs)]/round
        self._scratch: dict = {}         # (src, a, b) -> recv buffer
        self._rounds_len = 0
        self._nonblocking = False
        self._abort = threading.Event()  # elastic: set from the worker's
        #                                  control thread to pull the comm
        #                                  thread out of a doomed exchange
        self.tracer = None               # obs.trace.Tracer from the worker's
        #                                  comm thread (None = tracing off)
        self.host_of = None              # wid -> host id (set by the worker
        #                                  when WELCOME ships a topology):
        #                                  stats() then labels each peer link
        #                                  intra/cross so BYE reports carry
        #                                  the link class, not just the wid

    # -- mesh setup ----------------------------------------------------------

    def _register(self, peer: int, sock: socket.socket) -> Link:
        sock.settimeout(self.timeout_s)
        link = Link(sock, codec=self.codec)
        self.links[peer] = link
        return link

    def connect(self, directory: dict, pairs) -> None:
        """Establish one persistent link per pair involving this worker.
        ``directory``: wid -> (host, port). The higher wid dials, the lower
        accepts; all dials are issued (and their PEERS hello sent) before
        this worker blocks in accept, so setup cannot deadlock."""
        dial = sorted(p for (p, q) in pairs if q == self.wid)
        expect = {q for (p, q) in pairs if p == self.wid}
        dialed = {}
        for peer in dial:                # dials complete against backlogs
            host, port = directory[str(peer)] if str(peer) in directory \
                else directory[peer]
            # bounded retry: on a staggered multi-host start (or an elastic
            # rewire racing a peer's reset) the listener may not exist yet
            sock = wire.dial_with_backoff(
                host, port, deadline_s=min(self.timeout_s, 60.0),
                seed=(self.wid << 16) | peer)
            link = self._register(peer, sock)
            link.send_json(wire.PEERS, {"wid": self.wid, "token": self.token},
                           wid=self.wid)
            dialed[peer] = link
        deadline = _monotonic() + self.timeout_s
        self.listener.settimeout(1.0)
        while expect:
            if _monotonic() > deadline:
                raise wire.WireError(
                    f"p2p mesh setup timeout: still waiting for peers "
                    f"{sorted(expect)} to dial worker {self.wid}")
            try:
                conn, _ = self.listener.accept()
            except socket.timeout:
                continue
            # a stray connection (scanner, wrong peer) must neither crash
            # the worker nor stall the accept loop — short handshake
            # timeout, errors close just that socket
            conn.settimeout(10.0)
            probe = Link(conn, codec=self.codec)
            try:
                frame = probe.recv_header()
                if frame.ftype != wire.PEERS:
                    probe.close()
                    continue
                hello = probe.recv_json(frame)
                peer = int(hello.get("wid", -99))
                if hello.get("token") != self.token or peer not in expect:
                    probe.send_json(wire.ERROR,
                                    {"msg": f"bad peer hello {peer}"})
                    probe.close()
                    continue
                probe.send_json(wire.PEERS, {"wid": self.wid}, wid=self.wid)
            except (socket.timeout, wire.WireError, OSError, ValueError):
                probe.close()
                continue
            conn.settimeout(self.timeout_s)
            self.links[peer] = probe
            expect.discard(peer)
        for peer, link in dialed.items():          # acks from the acceptors
            frame = link.recv_header()
            if frame.ftype != wire.PEERS:
                raise wire.WireError(
                    f"peer {peer} rejected the handshake: "
                    f"{wire.FRAME_NAMES.get(frame.ftype, frame.ftype)}")
            ack = link.recv_json(frame)
            assert int(ack["wid"]) == peer, (ack, peer)
        # counters attach only now: stats contain SEGMENT traffic, not the
        # handshake (predicted_link_bytes prices the data plane alone).
        # setdefault: an elastic rewire reuses the cells, so per-peer byte
        # stats stay cumulative across epochs
        for peer, link in self.links.items():
            link.counters = self.counters.setdefault(
                peer, {"messages": wire.Slot(), "wire_bytes": wire.Slot()})

    # -- the round executor --------------------------------------------------

    @property
    def n_buckets(self) -> int:
        return len(self._plans)

    def set_rounds(self, rounds: list, padded: int,
                   boundaries=None) -> None:
        """Precompute the per-bucket, per-round send/recv plans and the
        receive buffers so execution is alloc-free: sends are (link, span,
        ef_tag) triples, receives get a preallocated per-(peer, segment)
        scratch buffer unless they can land directly in the row (``op=set``
        raw segments whose span is disjoint from every same-round send
        span). The sign-EF tag is (bucket, chunk, op): a ring link carries
        a chunk's reduce-scatter partial sums AND its all-gather broadcast
        values — per-bucket streams whose quantization residuals must not
        mix."""
        bounds = [0, padded] if boundaries is None \
            else [int(x) for x in boundaries]
        self.boundaries = bounds
        self._rounds_len = len(rounds)
        self._scratch = {}
        self._plans = []
        self.bucket_send_bytes = [0] * (len(bounds) - 1)
        for bidx, plan in enumerate(bucket_rounds(rounds, padded, bounds)):
            rplan = []
            for rnd in plan:
                sends, recvs = [], []
                send_spans = [(a, b) for m, (a, b) in rnd
                              if m.src == self.wid]
                for m, (a, b) in rnd:
                    if m.src == self.wid:
                        sends.append((self.links[m.dst], a, b,
                                      (bidx, m.chunk, m.op)))
                    elif m.dst == self.wid:
                        direct = (m.op == "set" and self.codec == "none"
                                  and all(b <= sa or a >= sb
                                          for sa, sb in send_spans))
                        scratch = None
                        if not direct:
                            key = (m.src, a, b)
                            if key not in self._scratch:
                                self._scratch[key] = np.zeros(b - a)
                            scratch = self._scratch[key]
                        recvs.append((self.links[m.src], a, b, m.op,
                                      scratch, direct))
                rplan.append((sends, recvs))
            self._plans.append(rplan)

    def _ensure_nonblocking(self) -> None:
        if not self._nonblocking:
            for link in self.links.values():
                link.sock.setblocking(False)
            self._nonblocking = True

    def _run_round(self, row: np.ndarray, sends, recvs, seq: int) -> None:
        """Progress every pending send and receive of one round under
        ``select`` until all complete, then apply scratch receives. Frame
        order per link is plan order on both ends (FIFO), and the round
        index rides the header's wid field as a desync detector."""
        ios: dict[Link, _LinkIO] = {}
        for link, a, b, tag in sends:
            io = ios.get(link)
            if io is None:
                io = ios[link] = _LinkIO(link)
            header, payload = link.encode_array(
                wire.SEGMENT, row[a:b], wid=seq, ef_tag=tag)
            io.send_q.append([[memoryview(header), payload], len(payload)])
        for link, a, b, op, scratch, direct in recvs:
            io = ios.get(link)
            if io is None:
                io = ios[link] = _LinkIO(link)
            io.recv_q.append((a, b, op, scratch, direct))
        by_sock = {io.link.sock: io for io in ios.values()}
        pending = []                     # (a, b, op, array) post-round
        deadline = _monotonic() + self.timeout_s
        while True:
            if self._abort.is_set():
                raise MeshAbort(f"exchange aborted at round {seq}")
            rl = [s for s, io in by_sock.items() if io.recv_q]
            wl = [s for s, io in by_sock.items() if io.send_q]
            if not rl and not wl:
                break
            readable, writable, _ = select.select(rl, wl, [], 1.0)
            if not readable and not writable:
                if _monotonic() > deadline:
                    raise wire.WireError(
                        f"p2p round {seq} stalled on worker {self.wid}: "
                        f"{len(rl)} recv / {len(wl)} send links pending")
                continue
            for s in writable:
                self._pump_send(by_sock[s])
            for s in readable:
                self._pump_recv(by_sock[s], row, seq, pending)
        for a, b, op, arr in pending:    # row mutations only after every
            if op == "set":              # send of the round snapshot it
                row[a:b] = arr
            else:
                row[a:b] += arr

    @staticmethod
    def _pump_send(io: _LinkIO) -> None:
        sock = io.link.sock
        while io.send_q:
            views, payload_len = io.send_q[0]
            view = views[io.send_vi]
            chunk = view[io.send_off:io.send_off + SEND_OP_MAX]
            try:
                k = sock.send(chunk)
            except (BlockingIOError, InterruptedError):
                return
            io.send_off += k
            if io.send_off < len(view):
                if k < len(chunk):       # kernel buffer full — come back
                    return
                continue
            io.send_vi += 1
            io.send_off = 0
            if io.send_vi == len(views):
                io.link._count(payload_len)
                io.send_q.pop(0)
                io.send_vi = 0

    def _pump_recv(self, io: _LinkIO, row: np.ndarray, seq: int,
                   pending: list) -> None:
        sock = io.link.sock
        while io.recv_q:
            if io.frame is None:         # header phase
                mv = memoryview(io.hdr_buf)
                try:
                    k = sock.recv_into(mv[io.hdr_got:])
                except (BlockingIOError, InterruptedError):
                    return
                if k == 0:
                    raise wire.WireError("peer closed mid-round "
                                         f"(round {seq})")
                io.hdr_got += k
                if io.hdr_got < wire.HEADER_SIZE:
                    return
                io.hdr_got = 0
                frame = wire.parse_header(bytes(io.hdr_buf))
                if frame.ftype != wire.SEGMENT or frame.wid != seq:
                    raise wire.WireError(
                        f"p2p desync: expected SEGMENT round {seq}, got "
                        f"{wire.FRAME_NAMES.get(frame.ftype, frame.ftype)} "
                        f"round {frame.wid}")
                a, b, op, scratch, direct = io.recv_q[0]
                if frame.codec == wire.CODEC_NONE:
                    if frame.size != (b - a) * 8:
                        raise wire.WireError(
                            f"p2p segment size {frame.size} != span "
                            f"{(b - a) * 8} (round {seq})")
                    target = row[a:b] if direct else scratch
                    io.pay_view = memoryview(target).cast("B")
                    io.pay_buf = None
                else:
                    io.pay_buf = bytearray(frame.size)
                    io.pay_view = memoryview(io.pay_buf)
                io.pay_got = 0
                io.frame = frame
            frame = io.frame
            if io.pay_got < frame.size:
                try:
                    k = sock.recv_into(io.pay_view[io.pay_got:])
                except (BlockingIOError, InterruptedError):
                    return
                if k == 0:
                    raise wire.WireError("peer closed mid-segment "
                                         f"(round {seq})")
                io.pay_got += k
                if io.pay_got < frame.size:
                    return
            a, b, op, scratch, direct = io.recv_q.pop(0)
            io.frame = None
            io.link._count(frame.size)
            if io.pay_buf is not None:   # sign_ef: decode, defer apply
                arr = wire.decode_array_payload(frame, io.pay_buf)
                pending.append((a, b, op, arr))
                io.pay_buf = None
            elif not direct:             # raw into scratch: defer apply
                pending.append((a, b, op, scratch))
            io.pay_view = None

    def execute_bucket(self, row: np.ndarray, bidx: int) -> None:
        """All rounds of one bucket, in schedule order. Safe to call only
        in bucket order (frame sequence numbers advance bucket-major)."""
        self._ensure_nonblocking()
        plan = self._plans[bidx]
        for r_idx, (sends, recvs) in enumerate(plan):
            if not sends and not recvs:
                continue
            seq = (bidx * self._rounds_len + r_idx) & 0x7FFF
            for _, a, b, _tag in sends:
                self.bucket_send_bytes[bidx] += (b - a) * 8
            self._run_round(row, sends, recvs, seq)

    def execute_exchange(self, row: np.ndarray, on_bucket=None) -> None:
        """One allreduce: every bucket's share of every round, bucket-major
        — all workers stream buckets in the same order, and disjoint bucket
        spans keep the per-element operation order identical to the
        monolithic exchange. ``on_bucket(bidx)`` fires as each bucket's
        rounds complete, which is the overlap hook: the caller can start
        bucket ``bidx``'s update while bucket ``bidx+1`` is on the wire."""
        tr = self.tracer
        for bidx in range(len(self._plans)):
            t0 = _perf_counter() if tr is not None else 0.0
            self.execute_bucket(row, bidx)
            if on_bucket is not None:
                on_bucket(bidx)              # pacing sleep included: the
            if tr is not None:               # span is the bucket's WIRE time
                tr.record(_trace.BUCKET, t0, _perf_counter(), bidx)
        self.rounds_executed += self._rounds_len

    # -- accounting / teardown ----------------------------------------------

    def stats(self) -> dict:
        """JSON-ready per-link counters, reported to the master in BYE."""
        return {
            "sync_rounds": self.rounds_executed,
            "n_buckets": len(self._plans),
            "bucket_send_bytes": list(self.bucket_send_bytes),
            "peer_links": {
                str(peer): {"messages": c["messages"].value,
                            "wire_bytes": c["wire_bytes"].value,
                            **({"link": ("intra" if self.host_of(peer)
                                         == self.host_of(self.wid)
                                         else "cross")}
                               if self.host_of is not None else {}),
                            **({"ef_ratio": r}
                               if (peer in self.links
                                   and (r := self.links[peer].ef_ratio()))
                               else {})}
                for peer, c in sorted(self.counters.items())},
        }

    def abort(self) -> None:
        """Ask the comm thread to abandon the in-flight exchange: the next
        ``_run_round`` loop iteration (≤1 s away — the select timeout)
        raises :class:`MeshAbort`. Idempotent; cleared by ``reset``."""
        self._abort.set()

    def reset(self) -> None:
        """Tear down every peer link but KEEP the listener — the elastic
        rewire: an aborted exchange leaves partial frames in flight, so
        reused sockets would desync framing; fresh links (and fresh EF
        state, which lives on the Link) are the only safe restart point.
        ``connect`` + ``set_rounds`` rebuild the mesh for the new epoch."""
        for link in self.links.values():
            link.close()
        self.links.clear()       # counters stay: cumulative across epochs
        self._plans = []
        self._scratch = {}
        self._rounds_len = 0
        self._nonblocking = False
        self._abort.clear()

    def close(self) -> None:
        for link in self.links.values():
            link.close()
        self.links.clear()
        try:
            self.listener.close()
        except OSError:
            pass
