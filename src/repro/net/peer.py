"""The repro.net peer-to-peer data plane: workers execute ``Schedule.rounds``
over direct worker↔worker TCP links.

Under the centralized sync plane the master executes the allreduce on its
local mailbox, so every training round funnels Θ(P·N) bytes through the
master's links — the rank-ordered incast the paper's §5.1/§6.1 schedules
exist to eliminate. Here each worker owns ONE mailbox row and moves exactly
the registry's message pattern itself: for every ``Message`` whose ``src``
is this worker, the ``Message.span`` slice of the row goes out as a SEGMENT
frame on the persistent link to ``dst``; for every message whose ``dst`` is
this worker, the matching slice is received and combined (``add``/``set``).
The master degrades to a control-plane coordinator (rendezvous, eval,
heartbeats, shutdown) and its links carry only Θ(N_center) — worker 0's
CENTER reports — while per-worker ring traffic is ~2N(P−1)/P per exchange.

Wiring: every worker opens a peer listener BEFORE saying HELLO and
advertises its (host, port); the master's WELCOME carries the full
directory plus the resolved rounds (``comm.rounds`` wire form — this
module, like the worker, never imports the jax-side registry). For each
unordered pair (i, j) that appears in the rounds, the HIGHER wid dials the
lower's listener (PEERS handshake: {"wid", "token"} out, {"wid"} ack back);
dials complete against the listener backlog before anyone blocks in
accept, so the mesh setup cannot deadlock.

Execution is alloc-free in steady state: the per-round send/recv plan and
the per-(peer, segment) receive buffers are precomputed once, sends are
``sendall`` on memoryviews of the row, ``op=set`` raw segments land via
``recv_into`` DIRECTLY in the row slice. Within a round every send happens
before any receive is applied — receivers read senders' PRE-round values,
the exact snapshot discipline of ``ps.execute_rounds`` — which, together
with IEEE-754 addition's commutativity (ring/tree literally copy one
accumulation chain to every rank; butterfly/hierarchical rows differ only
in addend ORDER of the same pairwise sums), makes every worker's row
bitwise equal to the centralized ``mailbox[0]``. That is what lets each
worker advance a local center replica bit-for-bit in lockstep with the
master-plane run (the thread↔tcp↔p2p triangle pinned in tests/test_net.py).

Per-link sign-EF composes exactly as on the master links: the sender of a
link carries its own quantization residual forward, keyed by (frame type,
segment length, ef_tag=chunk index), so every (peer, vector-segment)
stream has its own scale and error-feedback state.
"""
from __future__ import annotations

import socket
import threading
from time import monotonic as _monotonic

import numpy as np

from repro.comm.rounds import MASTER, Message
from repro.net import wire
from repro.net.wire import Link

# Above this per-message payload size the round executor moves sends to a
# helper thread: with everyone inside a round sending before receiving, a
# segment larger than the kernel's socket buffering would otherwise leave
# every worker blocked in sendall with nobody draining — a distributed
# deadlock. 64 KiB sits safely under Linux's default wmem/rmem (~208 KiB
# each side), so the common model-sized path stays inline and alloc-free.
INLINE_SEND_MAX = 64 * 1024


def predicted_link_bytes(rounds, padded_elements: int) -> dict:
    """Exact wire bytes (header + raw-f64 payload) per unordered worker
    pair for ONE exchange of the given rounds — what each endpoint's
    per-link counter must report per exchange under ``codec=none``. Both
    directions of a pair are summed, matching a Link's counter (it counts
    its sends AND its receives)."""
    out: dict[tuple, int] = {}
    for rnd in rounds:
        for m in rnd:
            if m.src == MASTER or m.dst == MASTER:
                continue
            a, b = m.span(padded_elements)
            pair = (min(m.src, m.dst), max(m.src, m.dst))
            out[pair] = out.get(pair, 0) + wire.HEADER_SIZE + (b - a) * 8
    return out


class PeerMesh:
    """One worker's endpoint of the p2p data plane: listener + persistent
    links to every peer its rounds talk to, plus the round executor."""

    def __init__(self, wid: int, token: str, codec: str = "none",
                 bind_host: str = "0.0.0.0", port: int = 0,
                 timeout_s: float = 600.0):
        self.wid = wid
        self.token = token
        self.codec = codec
        self.timeout_s = timeout_s
        self.listener = socket.socket()
        self.listener.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        try:
            self.listener.bind((bind_host, port))
        except OSError:
            # bind_host is the interface the master link runs over; if it
            # is not bindable (NAT'd advertisement), fall back to any
            self.listener.bind(("0.0.0.0", port))
        self.listener.listen(16)
        self.port = self.listener.getsockname()[1]
        self.links: dict[int, Link] = {}
        self.counters: dict[int, dict] = {}
        self.rounds_executed = 0
        self._plan: list = []            # [(sends, recvs)] per round
        self._scratch: dict = {}         # (src, a, b) -> recv buffer

    # -- mesh setup ----------------------------------------------------------

    def _register(self, peer: int, sock: socket.socket) -> Link:
        sock.settimeout(self.timeout_s)
        link = Link(sock, codec=self.codec)
        self.links[peer] = link
        return link

    def connect(self, directory: dict, pairs) -> None:
        """Establish one persistent link per pair involving this worker.
        ``directory``: wid -> (host, port). The higher wid dials, the lower
        accepts; all dials are issued (and their PEERS hello sent) before
        this worker blocks in accept, so setup cannot deadlock."""
        dial = sorted(p for (p, q) in pairs if q == self.wid)
        expect = {q for (p, q) in pairs if p == self.wid}
        dialed = {}
        for peer in dial:                # dials complete against backlogs
            host, port = directory[str(peer)] if str(peer) in directory \
                else directory[peer]
            sock = socket.create_connection((host, int(port)),
                                            timeout=self.timeout_s)
            link = self._register(peer, sock)
            link.send_json(wire.PEERS, {"wid": self.wid, "token": self.token},
                           wid=self.wid)
            dialed[peer] = link
        deadline = _monotonic() + self.timeout_s
        self.listener.settimeout(1.0)
        while expect:
            if _monotonic() > deadline:
                raise wire.WireError(
                    f"p2p mesh setup timeout: still waiting for peers "
                    f"{sorted(expect)} to dial worker {self.wid}")
            try:
                conn, _ = self.listener.accept()
            except socket.timeout:
                continue
            # a stray connection (scanner, wrong peer) must neither crash
            # the worker nor stall the accept loop — short handshake
            # timeout, errors close just that socket
            conn.settimeout(10.0)
            probe = Link(conn, codec=self.codec)
            try:
                frame = probe.recv_header()
                if frame.ftype != wire.PEERS:
                    probe.close()
                    continue
                hello = probe.recv_json(frame)
                peer = int(hello.get("wid", -99))
                if hello.get("token") != self.token or peer not in expect:
                    probe.send_json(wire.ERROR,
                                    {"msg": f"bad peer hello {peer}"})
                    probe.close()
                    continue
                probe.send_json(wire.PEERS, {"wid": self.wid}, wid=self.wid)
            except (socket.timeout, wire.WireError, OSError, ValueError):
                probe.close()
                continue
            conn.settimeout(self.timeout_s)
            self.links[peer] = probe
            expect.discard(peer)
        for peer, link in dialed.items():          # acks from the acceptors
            frame = link.recv_header()
            if frame.ftype != wire.PEERS:
                raise wire.WireError(
                    f"peer {peer} rejected the handshake: "
                    f"{wire.FRAME_NAMES.get(frame.ftype, frame.ftype)}")
            ack = link.recv_json(frame)
            assert int(ack["wid"]) == peer, (ack, peer)
        # counters attach only now: stats contain SEGMENT traffic, not the
        # handshake (predicted_link_bytes prices the data plane alone)
        for peer, link in self.links.items():
            self.counters[peer] = {"messages": wire.Slot(),
                                   "wire_bytes": wire.Slot()}
            link.counters = self.counters[peer]

    # -- the round executor --------------------------------------------------

    def set_rounds(self, rounds: list, padded: int) -> None:
        """Precompute the per-round send/recv plan and the receive buffers
        so ``execute_exchange`` is alloc-free: sends are (link, span) pairs,
        receives get a preallocated per-(peer, segment) scratch buffer
        (``op=set`` raw receives land directly in the row on the inline
        path). The sign-EF tag is (chunk, op): a ring link carries a
        chunk's reduce-scatter partial sums AND its all-gather broadcast
        values — two streams whose quantization residuals must not mix."""
        self._plan = []
        self._scratch = {}
        max_send = 0
        for rnd in rounds:
            sends = []
            recvs = []
            for m in rnd:
                if m.src == self.wid:
                    a, b = m.span(padded)
                    max_send = max(max_send, (b - a) * 8)
                    sends.append((self.links[m.dst], a, b, (m.chunk, m.op)))
                elif m.dst == self.wid:
                    a, b = m.span(padded)
                    key = (m.src, a, b)
                    if key not in self._scratch:
                        self._scratch[key] = np.zeros(b - a)
                    recvs.append((self.links[m.src], a, b, m.op,
                                  self._scratch[key]))
            self._plan.append((sends, recvs))
        # segments past the kernel's socket buffering would deadlock the
        # everyone-sends-first cycle — move those sends to a helper thread
        self._threaded = max_send > INLINE_SEND_MAX

    def _do_sends(self, row, sends, seq, err_box=None) -> None:
        try:
            for link, a, b, tag in sends:
                link.send_array(wire.SEGMENT, row[a:b], wid=seq, ef_tag=tag)
        except BaseException as e:               # noqa: BLE001 — re-raised
            if err_box is None:
                raise
            err_box.append(e)

    def execute_exchange(self, row: np.ndarray) -> None:
        """One allreduce: this worker's share of every round, in schedule
        order, receivers reading senders' PRE-round values. Inline path
        (segments ≤ INLINE_SEND_MAX): all sends complete against kernel
        buffers (``sendall`` returns once the kernel owns the bytes), then
        receives apply — zero-copy ``recv_into`` the row for raw ``set``
        segments. Threaded path (large segments): sends run in a helper
        thread while receives drain into scratch, and the row is only
        mutated after the sends — which read it — have finished."""
        for r_idx, (sends, recvs) in enumerate(self._plan):
            seq = r_idx & 0x7FFF         # rides the header's wid field
            sender = None
            err_box: list = []
            if self._threaded and sends:
                sender = threading.Thread(
                    target=self._do_sends, args=(row, sends, seq, err_box))
                sender.start()
            else:
                self._do_sends(row, sends, seq)
            pending = []
            for link, a, b, op, scratch in recvs:
                frame = link.recv_header()
                if frame.ftype != wire.SEGMENT or frame.wid != seq:
                    raise wire.WireError(
                        f"p2p desync: expected SEGMENT round {seq}, got "
                        f"{wire.FRAME_NAMES.get(frame.ftype, frame.ftype)} "
                        f"round {frame.wid}")
                if sender is None and op == "set" \
                        and frame.codec == wire.CODEC_NONE:
                    link.recv_array(frame, row[a:b])   # straight into the row
                else:
                    link.recv_array(frame, scratch)
                    pending.append((a, b, op, scratch))
            if sender is not None:
                sender.join()
                if err_box:
                    raise err_box[0]
            for a, b, op, scratch in pending:          # row mutations only
                if op == "set":                        # after sends read it
                    row[a:b] = scratch
                else:
                    row[a:b] += scratch
            self.rounds_executed += 1

    # -- accounting / teardown ----------------------------------------------

    def stats(self) -> dict:
        """JSON-ready per-link counters, reported to the master in BYE."""
        return {
            "sync_rounds": self.rounds_executed,
            "peer_links": {
                str(peer): {"messages": c["messages"].value,
                            "wire_bytes": c["wire_bytes"].value}
                for peer, c in sorted(self.counters.items())},
        }

    def close(self) -> None:
        for link in self.links.values():
            link.close()
        self.links.clear()
        try:
            self.listener.close()
        except OSError:
            pass
