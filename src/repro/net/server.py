"""The repro.net master: the PS runtime's concurrency disciplines served
over TCP connections instead of shared memory.

The state layout is the thread transport's, verbatim (center, per-worker
weights/velocities, the padded allreduce mailbox); what changes is WHO moves
the bytes. Shared memory made publication implicit — here every exchange is
an explicit frame on a link, so the master OWNS all optimizer state and the
workers hold only what they need to compute gradients:

 * ``original_easgd`` — the master serves one worker at a time end to end
   (sends WEIGHTS only to the worker whose turn it is, waits for its GRAD):
   the Θ(P) serialization is enforced by the wire itself.
 * async FCFS — GRAD frames are absorbed in ARRIVAL order; under
   ``deterministic=True`` arrivals are buffered per worker and absorbed in
   strict cyclic order — the DES zero-jitter event schedule, which makes
   TCP-vs-thread weights BITWISE identical (tests/test_net.py).
 * hogwild — absorb on arrival with no admission discipline at all. A
   central server linearizes updates at message granularity, so TCP hogwild
   is the DES's sequential-consistency model rather than the shared-memory
   transports' torn writes (see DESIGN.md §net — the honest boundary).
 * sync family — per training round the master distributes WEIGHTS, runs
   the registered schedule's ``Schedule.rounds`` over its local mailbox
   (same numpy executor as the thread transport ⇒ same summation order ⇒
   same bits) while the workers' gradient computation genuinely overlaps
   (paper §6.1.3), then absorbs the GRADs and applies the center update.
   Under ``PSConfig.sync_plane="p2p"`` the master instead degrades to a
   CONTROL-PLANE coordinator: WELCOME ships the peer directory + the
   resolved rounds, the workers execute them over direct worker↔worker
   links (``net.peer``) and advance bitwise-identical center replicas,
   and the master links carry only worker 0's CENTER reports at eval
   rounds plus one final WSTATE per worker — Θ(N_center) instead of the
   centralized plane's Θ(P·N) per round (see DESIGN.md §net).

τ>1 communication periods: workers take τ−1 local steps
(``easgd_flat.local_step``) between exchanges, so their local (w, v)
diverge from the master's copy; the exchange frame then stacks [grad|w|v]
(async) or sends a WSTATE frame ahead of the overlap (sync).

Wire emulation (``PSConfig.emulate_net``) composes with the real socket:
deadlines are taken BEFORE a transfer and slept to AFTER it, so only the
excess over the measured link is slept, and the emulated α–β floors the
real one. Pacing prices the POST-compression payload size, so ``sign_ef``
on the wire shortens emulated time as well as measured bytes.
"""
from __future__ import annotations

import heapq
import os
import queue
import socket
import subprocess
import sys
import threading
import time

import numpy as np

from repro.comm import rounds as comm_rounds
from repro.comm import schedules as comm_schedules
from repro.core import costmodel, easgd_flat
from repro.core.compression import sign_ef_wire_nbytes
from repro.ft import chaos as ft_chaos
from repro.ft import membership as ft_membership
from repro.net import wire
from repro.net.wire import Link, sleep_until
from repro.obs import live as obs_live
from repro.obs import metrics as obs_metrics
from repro.obs import report as obs_report
from repro.obs import trace as obs_trace
from repro.ps.runtime import (PSResult, execute_rounds,
                              measured_link_profile)

SYNC = easgd_flat.SYNC_FAMILY
DEFAULT_TOKEN = "repro-net"


def wire_payload_nbytes(n_elements: int, codec: str) -> int:
    """Exact framed payload size of one n-element array message."""
    if codec == "sign_ef":
        return sign_ef_wire_nbytes(n_elements)
    return n_elements * 8


def worker_env(pallas: bool = False) -> dict:
    """Environment for a spawned worker interpreter: the repo's src dir on
    PYTHONPATH (shared by the training spawn and the calibration burners —
    one definition of how a worker process is launched). ``pallas`` pins
    the XLA CPU backend to a no-FMA ISA BEFORE the child's first jax
    import, so the fused elastic-update kernel stays bitwise equal to
    easgd_flat (see kernels/elastic_update.py)."""
    src = os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))
    env = dict(os.environ)
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    if pallas:
        env.setdefault("JAX_PLATFORMS", "cpu")
        env.setdefault("XLA_FLAGS", "--xla_cpu_max_isa=SSE4_2")
    return env


def cluster_spec_env(role: str, wid: int, host: str, port: int,
                     token: str = DEFAULT_TOKEN,
                     sync_plane: str | None = None,
                     peer_port: int | None = None) -> str:
    """The declarative ``REPRO_CLUSTER_SPEC`` JSON one process needs to
    (re)join a run: a respawn is a re-exec of ``python -m repro.net.worker``
    with this env var set (plus ``--rejoin``), not a hand-crafted command
    line. launch/cluster prints the same spec for multi-host workers."""
    import json as _json
    spec = {"role": role, "wid": wid, "host": host, "port": int(port),
            "token": token}
    if sync_plane is not None:
        spec["sync_plane"] = sync_plane
    if peer_port is not None:
        spec["peer_port"] = int(peer_port)
    return _json.dumps(spec)


def spawn_local_workers(host: str, port: int, n_workers: int,
                        token: str = DEFAULT_TOKEN,
                        pallas: bool = False,
                        env_extra: dict | None = None) -> list:
    """Launch localhost worker processes (fresh interpreters — the same
    isolation a remote host gives, minus the cable). Each child also gets a
    ``REPRO_CLUSTER_SPEC`` describing its own role, so a respawn is a
    re-exec; ``env_extra`` carries run-scoped injections (REPRO_CHAOS)."""
    base = worker_env(pallas=pallas)
    if env_extra:
        base.update(env_extra)
    procs = []
    for i in range(n_workers):
        env = dict(base)
        env["REPRO_CLUSTER_SPEC"] = cluster_spec_env(
            "worker", i, host, port, token)
        procs.append(subprocess.Popen(
            [sys.executable, "-m", "repro.net.worker",
             "--connect", f"{host}:{port}", "--wid", str(i),
             "--token", token],
            env=env))
    return procs


def worker_command(addr: str, wid: int, token: str = DEFAULT_TOKEN,
                   sync_plane: str | None = None,
                   peer_port: int | None = None) -> str:
    """The shell line a REMOTE host runs to join this master (printed by
    launch/cluster for --hosts; also what --ssh executes). For a p2p run
    the line pins the worker's peer-listener port (``--peer-port``) so the
    worker↔worker data plane is firewall-predictable, and carries
    ``--sync-plane`` so the one-liner is launchable verbatim."""
    cmd = (f"PYTHONPATH=src python -m repro.net.worker "
           f"--connect {addr} --wid {wid} --token {token}")
    if sync_plane is not None:
        cmd += f" --sync-plane {sync_plane}"
    if peer_port is not None:
        cmd += f" --peer-port {peer_port}"
    return cmd


class MasterServer:
    """One training run: rendezvous P links, run the discipline, shut down."""

    def __init__(self, problem, easgd, cfg, eval_fn_override=None,
                 join_timeout_s: float = 600.0):
        if not hasattr(problem, "build"):
            raise ValueError(
                "tcp transport needs a ProblemSpec (module:function) — "
                "remote workers rebuild the problem from its factory")
        if cfg.deterministic and cfg.wire_compression != "none":
            raise ValueError(
                "deterministic admission is the bitwise DES/thread "
                "cross-check mode; lossy wire compression "
                f"('{cfg.wire_compression}') would break it — run one or "
                "the other")
        self.problem = problem
        self.easgd = easgd
        self.cfg = cfg
        self.timeout = join_timeout_s
        w0, grad_fn, eval_fn = problem.build()
        self.eval_fn = eval_fn_override or eval_fn
        self.w0 = np.asarray(w0, np.float64)
        self.n = self.w0.size
        P = cfg.n_workers
        self.tau = max(int(getattr(easgd, "tau", 1)), 1)
        # heterogeneous fabric: the topology prices every pacing sleep per
        # link class; with schedule="auto" and no profile supplied, measure
        # one NOW (short pairwise burst over the real substrate) so the
        # choice below ranks candidates on the fabric the run actually has
        self.topology = getattr(cfg, "topology", None)
        self.profile = getattr(cfg, "link_profile", None)
        if (self.topology is not None and self.profile is None
                and cfg.schedule == "auto"):
            self.profile = measured_link_profile(cfg)
        self.sched_name = cfg.resolved_schedule(self.n * 8,
                                                profile=self.profile)
        self.rounds = (comm_schedules.get(self.sched_name)
                       .rounds(P, self.n * 8, cfg.net,
                               topology=self.topology)
                       if cfg.algorithm in SYNC else [])
        self.sync_p2p = (cfg.algorithm in SYNC
                         and getattr(cfg, "sync_plane", "master") == "p2p")
        if self.sync_p2p and any(
                m.src == comm_schedules.MASTER or m.dst == comm_schedules.MASTER
                for rnd in self.rounds for m in rnd):
            raise ValueError(
                f"schedule '{self.sched_name}' routes through the master "
                f"endpoint — it IS the master plane; pick a peer schedule "
                f"(ring/tree/butterfly/hierarchical) for sync_plane='p2p'")
        padded = self.n + (-self.n) % max(P, 1)
        self.padded = padded
        # layer sizes survive past build so an elastic reconfiguration can
        # re-derive bucket boundaries for the new padded size
        self._layer_sizes = getattr(grad_fn, "layer_sizes", None)
        self.boundaries = None
        if getattr(cfg, "bucket_bytes", 0) > 0 and cfg.algorithm in SYNC:
            self.boundaries = comm_rounds.default_bucket_boundaries(
                self._layer_sizes, padded, cfg.bucket_bytes)
        # -- master-owned optimizer state (thread-transport layout) --------
        self.center = self.w0.copy()
        self.master_vel = np.zeros(self.n)
        self.workers_w = np.tile(self.w0, (P, 1))
        self.workers_v = np.zeros((P, self.n))
        self.mailbox = np.zeros((P + 1, padded))
        # -- wiring --------------------------------------------------------
        # master_link_bytes counts ONLY frames on the master's own links
        # (wire_bytes additionally absorbs the local-mailbox round bytes of
        # the centralized sync plane) — the p2p-vs-master incast comparison
        # reads this slot on both planes. The registry replaces the old
        # parallel counter dicts: one namespace, same ``.value`` cells.
        self.counters = obs_metrics.Registry()
        for name in ("sync_rounds", "messages", "wire_bytes",
                     "master_link_bytes"):
            self.counters.counter(name)
        self.link_counters = {"messages": self.counters["messages"],
                              "wire_bytes": self.counters["wire_bytes"],
                              "link_bytes": self.counters["master_link_bytes"]}
        if cfg.trace:
            obs_trace.drain()                # clean registry for THIS run
        self.tracer = (obs_trace.tracer("serve") if cfg.trace else None)
        self.links: dict[int, Link] = {}
        self.peer_addrs: dict[int, list] = {}
        self.bye_stats: dict[int, dict] = {}
        self.events: queue.Queue = queue.Queue()
        self.grad_bufs = [np.zeros(self._up_elems()) for _ in range(P)]
        self.wstate_bufs = [np.zeros(self.n) for _ in range(P)]
        self.iters = 0
        self.history: list = []
        self._last_eval = 0
        self._t0 = 0.0
        self._err: list = []
        self._closing = threading.Event()
        self._threads: list = []
        self._procs: list = []
        self.live = None                 # obs.live.LiveMonitor (telemetry)
        self._draining = False           # True once DONE went out: BYE is
        #                                  then the expected shutdown frame,
        #                                  not a mid-run departure
        # -- elastic membership (ft.membership) ----------------------------
        self.elastic = bool(getattr(cfg, "elastic", False))
        self.membership = (ft_membership.MembershipTable(P)
                           if self.elastic else None)
        self._serving = False            # member_lost conversion applies
        #                                  only once the disciplines run —
        #                                  a rendezvous death still raises
        self._elastic_events: list = []  # lifecycle record when telemetry
        #                                  (and therefore LiveMonitor) is off
        self._proc_reported: set = set()
        self._epoch_round_base = 0       # p2p iteration accounting across
        self._epoch_iters_base = 0       # epochs: iters(k) = base_iters +
        self._epoch_p = P                # (k − base_round) · P_epoch · τ

    # -- payload shapes ------------------------------------------------------

    def _up_elems(self) -> int:
        """Element count of one GRAD frame: with τ>1 the async families
        stack [grad|w] (+[v] for the velocity rules) because the worker's
        local state diverged between exchanges."""
        if self.tau == 1 or self.cfg.algorithm in SYNC:
            return self.n
        k = 3 if easgd_flat.uses_velocity(self.cfg.algorithm) else 2
        return k * self.n

    def _split_up(self, wid: int):
        """(grad, w_up, v_up) views of a received GRAD payload."""
        buf = self.grad_bufs[wid]
        if buf.size == self.n:
            return buf, None, None
        parts = buf.reshape(-1, self.n)
        return parts[0], parts[1], (parts[2] if parts.shape[0] == 3 else None)

    @property
    def _down_stacked(self) -> bool:
        """τ>1 velocity rules evolve V locally between exchanges, so the
        master's WEIGHTS frame must carry [w|v] down."""
        return (self.tau > 1 and self.cfg.algorithm not in SYNC
                and easgd_flat.uses_velocity(self.cfg.algorithm))

    def _absorb_upload(self, wid: int) -> np.ndarray:
        """Fold a τ>1 upload back into the master's per-worker state and
        return the gradient."""
        grad, w_up, v_up = self._split_up(wid)
        if w_up is not None:
            self.workers_w[wid] = w_up
        if v_up is not None:
            self.workers_v[wid] = v_up
        return grad

    def _down_elems(self) -> int:
        return 2 * self.n if self._down_stacked else self.n

    def _up_segments(self) -> int:
        """Logical segments of a GRAD frame (per-segment sign-EF scales)."""
        return self._up_elems() // self.n

    # -- pacing --------------------------------------------------------------

    def _t_msg_pair(self, wid: int | None = None) -> tuple:
        """(t_down, t_up) emulated per-message times — the two directions
        differ in size once τ>1 stacks state into the frames. ``wid``
        applies that worker's ``PSConfig.link_slow`` stretch: a controlled
        per-link straggler on the pacing plane only (admission order and
        math are untouched — the DETECTOR must find it, not the iterates)."""
        codec = self.cfg.wire_compression
        slow = self.cfg.link_slow_factor(wid) if wid is not None else 1.0
        if self.topology is not None:
            # master links ride the topology's class for (MASTER, wid):
            # cross-host whenever hosts > 1 — the master is its own box
            link = self.topology.link(comm_rounds.MASTER,
                                      0 if wid is None else wid)
            return (slow * costmodel.t_msg(
                        wire_payload_nbytes(self._down_elems(), codec),
                        link),
                    slow * costmodel.t_msg(
                        wire_payload_nbytes(self._up_elems(), codec),
                        link))
        return (slow * self.cfg.t_msg_emulated(
                    wire_payload_nbytes(self._down_elems(), codec)),
                slow * self.cfg.t_msg_emulated(
                    wire_payload_nbytes(self._up_elems(), codec)))

    # -- sync-family round arithmetic (shared by both planes) ---------------

    def _n_sync_rounds(self) -> int:
        return -(-self.cfg.total_iters // (self.cfg.n_workers * self.tau))

    def _t_sync_wire(self, wid: int | None = None) -> float:
        """Emulated α–β time of one full exchange: the rounds serialize,
        each costs α + max_frac·n·β (its messages fly concurrently). With
        a topology each message is priced over ITS link class, and ``wid``
        restricts to that worker's own segments — its personal deadline on
        a heterogeneous mesh (intra-host pairs finish early and wait on
        cross-host peers at the blocking recv, not by sleeping)."""
        if self.topology is not None:
            return comm_rounds.t_rounds(self.rounds, self.n * 8,
                                        topology=self.topology, wid=wid)
        return sum(
            self.cfg.t_msg_emulated(max(m.frac for m in rnd) * self.n * 8)
            for rnd in self.rounds)

    def _t_sync_wire_buckets(self, wid: int | None = None) -> list:
        """Per-bucket emulated wire time: under bucketing each round
        fragments into per-bucket frames, so bucket b pays α + its own
        max clipped span·β for every round it appears in. Σ_b can exceed
        ``_t_sync_wire`` (more frames ⇒ more α) — that extra latency is
        exactly what the overlap pipeline is for. Topology/``wid`` as in
        ``_t_sync_wire``: per-link-class, per-worker SEGMENT pacing."""
        if self.topology is not None:
            return comm_rounds.t_rounds_buckets(
                self.rounds, self.padded, self.boundaries,
                topology=self.topology, wid=wid)
        plans = comm_rounds.bucket_rounds(self.rounds, self.padded,
                                          self.boundaries)
        out = []
        for plan in plans:
            t = 0.0
            for rnd in plan:
                if rnd:
                    t += self.cfg.t_msg_emulated(
                        max(b - a for _, (a, b) in rnd) * 8)
            out.append(t)
        return out

    def _eval_rounds(self) -> list:
        """Exchange-round indices after which the eval cadence fires —
        the `_maybe_eval` trigger precomputed, so the p2p workers and this
        master agree on exactly when worker 0 reports its CENTER."""
        evals, last = [], 0
        per = self.cfg.n_workers * self.tau
        for k in range(self._n_sync_rounds()):
            if (k + 1) * per - last >= self.cfg.eval_every_iters:
                evals.append(k)
                last = (k + 1) * per
        return evals

    # -- lifecycle -----------------------------------------------------------

    def _welcome_payload(self, wid: int, rejoin: bool = False) -> dict:
        """One worker's WELCOME: problem spec + algorithm, plus the full p2p
        geometry when the data plane is peer-to-peer. A rejoin WELCOME names
        the CURRENT epoch's geometry but the worker holds off joining the
        mesh until the RECONFIGURE that folds it in (``rejoin`` flag)."""
        cfg, e = self.cfg, self.easgd
        welcome = {
            "wid": wid,
            "factory": self.problem.factory,
            "kwargs": list(self.problem.kwargs),
            "algorithm": cfg.algorithm,
            "n": self.n,
            "tau": self.tau,
            "eta": e.eta, "mu": e.mu, "rho": e.rho,
            "codec": cfg.wire_compression,
            "warmup": 2,
            "hb_interval_s": cfg.hb_interval_eff_s(),
            "trace": bool(cfg.trace),
            "trace_dir": cfg.trace_dir,
        }
        if self.topology is not None:
            welcome["topology"] = self.topology.to_wire()
        if self.profile is not None:
            welcome["link_profile"] = self.profile.to_wire()
        if self.sync_p2p:
            # a link_slow worker paces ITS exchange deadlines slower —
            # the mesh is lockstep, so its lag surfaces in every
            # worker's clock, but its own heartbeat telemetry is what
            # names it
            slow = cfg.link_slow_factor(wid)
            welcome.update({
                "sync_plane": "p2p",
                "p": len(self.links) if rejoin else cfg.n_workers,
                "padded": self.padded,
                "rounds": comm_schedules.rounds_to_wire(self.rounds),
                "n_rounds": self._n_sync_rounds(),
                "eval_rounds": self._eval_rounds(),
                "t_wire_s": slow * self._t_sync_wire(
                    wid if self.topology is not None else None),
                "peers": {str(w): a for w, a in self.peer_addrs.items()},
                "bucket_bounds": self.boundaries,
                "overlap": getattr(cfg, "overlap", True),
                "update_backend": getattr(cfg, "update_backend",
                                          "numpy"),
                "t_wire_bucket_s": ([slow * t for t in
                                     self._t_sync_wire_buckets(
                                         wid if self.topology is not None
                                         else None)]
                                    if self.boundaries else []),
                "elastic": self.elastic,
            })
        if rejoin:
            welcome["rejoin"] = True
        return welcome

    def rendezvous(self, listener: socket.socket, token: str) -> None:
        """Accept until every wid 0..P−1 has said HELLO, send WELCOME, wait
        for every READY (worker built its problem and warmed up)."""
        cfg, P = self.cfg, self.cfg.n_workers
        deadline = time.monotonic() + self.timeout
        listener.settimeout(1.0)
        while len(self.links) < P:
            if time.monotonic() > deadline:
                raise RuntimeError(
                    f"rendezvous timeout: {len(self.links)}/{P} workers "
                    f"connected (algorithm={cfg.algorithm})")
            self._check_procs()
            try:
                conn, _ = listener.accept()
            except socket.timeout:
                continue
            conn.settimeout(30.0)       # a connected-but-silent client must
            link = Link(conn, codec=cfg.wire_compression,   # not stall HELLO
                        counters=self.link_counters)
            try:
                frame = link.recv_header()
            except (socket.timeout, wire.WireError, OSError):
                link.close()
                continue
            if frame.ftype != wire.HELLO:
                link.close()
                continue
            hello = link.recv_json(frame)
            if hello.get("token") != token:
                link.send_json(wire.ERROR, {"msg": "bad token"})
                link.close()
                continue
            wid = int(hello["wid"])
            if not (0 <= wid < P) or wid in self.links:
                link.send_json(wire.ERROR, {"msg": f"bad wid {wid}"})
                link.close()
                continue
            if "peer" in hello:
                self.peer_addrs[wid] = list(hello["peer"])
            self.links[wid] = link
        if self.sync_p2p:
            missing = [w for w in self.links if w not in self.peer_addrs]
            if missing:
                for link in self.links.values():
                    link.send_json(wire.ERROR, {
                        "msg": f"sync_plane=p2p but worker(s) {missing} "
                               f"advertised no peer listener "
                               f"(started with --sync-plane master?)"})
                raise RuntimeError(
                    f"p2p rendezvous failed: worker(s) {missing} advertised "
                    f"no peer listener")
        for wid, link in self.links.items():
            link.send_json(wire.WELCOME, self._welcome_payload(wid))
        for wid, link in self.links.items():
            self._threads.append(threading.Thread(
                target=self._reader, args=(wid, link), daemon=True))
            self._threads[-1].start()
        ready = set()
        while len(ready) < P:
            wid, kind, detail = self._next_event(deadline - time.monotonic())
            if kind != "ready":
                raise RuntimeError(
                    f"worker {wid} failed during rendezvous: {kind} {detail}")
            ready.add(wid)
            if self.membership is not None:
                self.membership.mark_ready(wid)

    def _reader(self, wid: int, link: Link) -> None:
        """Per-link reader: decodes frames into per-worker buffers and turns
        them into events. One outstanding exchange per worker by protocol,
        so the preallocated buffers are never overwritten early."""
        try:
            while True:
                frame = link.recv_header()
                if frame.ftype == wire.GRAD:
                    link.recv_array(frame, self.grad_bufs[wid])
                    self.events.put((wid, "grad", None))
                elif frame.ftype == wire.WSTATE:
                    link.recv_array(frame, self.wstate_bufs[wid])
                    self.events.put((wid, "wstate", None))
                elif frame.ftype == wire.CENTER:
                    # the header wid field carries the report tag (eval
                    # round index ≥ 0, −1 final, −2 reconfigure state
                    # upload); the fresh array keeps a slow eval from
                    # racing the next report into a shared buffer
                    self.events.put((wid, "center",
                                     (frame.wid,
                                      link.recv_array(frame).copy())))
                elif frame.ftype == wire.RECONFIGURE:
                    # a survivor acking phase 1 with its completed round
                    self.events.put((wid, "reconf_ack",
                                     link.recv_json(frame)))
                elif frame.ftype == wire.READY:
                    link.recv_discard(frame)
                    self.events.put((wid, "ready", None))
                elif frame.ftype == wire.CLOCK:
                    # NTP-style probe: echo this side's clock immediately —
                    # answered on the reader thread so serve() never blocks
                    # a probe behind an exchange (that would inflate rtt)
                    link.recv_discard(frame)
                    link.send_json(wire.CLOCK,
                                   {"t": time.perf_counter()}, wid=wid)
                elif frame.ftype == wire.BYE:
                    if frame.size:      # p2p workers attach per-link stats
                        self.bye_stats[wid] = link.recv_json(frame)
                    else:
                        link.recv_discard(frame)
                    self.events.put((wid, "bye", None))
                    return
                elif frame.ftype == wire.ERROR:
                    msg = link.recv_json(frame)
                    self.events.put((wid, "error", msg.get("msg", "?")))
                    return
                else:
                    link.recv_discard(frame)
        except (wire.WireError, OSError) as exc:
            if not self._closing.is_set():
                self.events.put((wid, "dead", repr(exc)))

    def _check_procs(self) -> None:
        for i, proc in enumerate(self._procs):
            rc = proc.poll()
            if rc in (None, 0):
                continue
            if self.elastic and self._serving:
                # under elastic membership a nonzero exit is a membership
                # signal, not a run-killer: surface it as a dead event once
                # (the reader's socket-drop event usually beats this poll)
                if (not self.membership.is_lost(i)
                        and i not in self._proc_reported):
                    self._proc_reported.add(i)
                    self.events.put((i, "dead", f"process exited {rc}"))
                continue
            raise RuntimeError(
                f"tcp worker process exited with code {rc} "
                f"(algorithm={self.cfg.algorithm})")

    def _mark_event(self, wid: int, kind: str, detail: str = "") -> None:
        """One lifecycle record: through the LiveMonitor when telemetry is
        on (events + JSONL + health counters), into the local log always —
        PSResult.health must name the death/recovery even on a bare run."""
        if self.live is not None:
            ev = self.live.mark_worker_event(wid, kind, detail)
        else:
            ev = {"t": round(time.monotonic() - (self._t0 or
                                                 time.monotonic()), 3),
                  "kind": kind, "wid": wid,
                  **({"detail": detail} if detail else {})}
        self._elastic_events.append(ev)

    def _member_lost(self, wid: int, kind: str, detail):
        """Elastic conversion of a failure into a membership transition:
        close the link, record the state change, hand the serve loop a
        ``member_lost`` event instead of raising."""
        left = kind == "bye"                       # clean preemption BYE
        if left:
            self.membership.mark_left(wid, "clean BYE mid-run")
        else:
            self.membership.mark_dead(wid, str(detail))
        link = self.links.pop(wid, None)
        if link is not None:
            link.hb_hook = None
            link.close()
        self._mark_event(wid, "worker_left" if left else "worker_dead",
                         str(detail or ""))
        return wid, "member_lost", str(detail or "")

    def _next_event(self, timeout: float):
        """Pop one event; surface worker failures and heartbeat silence as
        RuntimeError instead of hanging the launcher — unless elastic
        membership is on and the disciplines are running, in which case a
        loss becomes a ``member_lost`` event the serve loop absorbs."""
        deadline = time.monotonic() + max(timeout, 0.0)
        absorb = self.elastic and self._serving
        while True:
            self._check_procs()
            if self.links:
                worst = max(time.monotonic() - l.last_seen
                            for l in self.links.values())
                cell = self.counters.gauge("hb_staleness_max_s")
                cell.value = max(cell.value, round(worst, 3))
            hb_timeout = self.cfg.hb_timeout_eff_s()
            stale = [w for w, l in self.links.items()
                     if time.monotonic() - l.last_seen > hb_timeout]
            if stale:
                if absorb:
                    return self._member_lost(
                        stale[0], "dead",
                        f"silent for more than {hb_timeout}s")
                raise RuntimeError(
                    f"worker(s) {stale} silent for more than "
                    f"{hb_timeout}s (heartbeats stopped)")
            try:
                wid, kind, detail = self.events.get(timeout=0.5)
            except queue.Empty:
                if time.monotonic() > deadline:
                    raise RuntimeError(
                        f"timed out waiting for workers "
                        f"(algorithm={self.cfg.algorithm})") from None
                continue
            if kind in ("error", "dead"):
                if absorb and wid in self.links:
                    return self._member_lost(wid, kind, detail)
                if absorb:
                    continue             # duplicate signal for a known loss
                if self.live is not None:
                    self.live.mark_worker_event(wid, "worker_dead",
                                                str(detail))
                raise RuntimeError(f"worker {wid} failed: {detail}")
            if kind == "bye" and not self._draining:
                # a clean mid-run departure (watchdog-triggered SIGTERM →
                # BYE instead of a dead socket): its trace/telemetry flush
                # already landed in bye_stats — surface it as a structured
                # failure naming the worker, not a protocol violation
                if absorb:
                    return self._member_lost(wid, "bye", "preempted")
                if self.live is not None:
                    self.live.mark_worker_event(wid, "worker_left",
                                                "clean BYE mid-run")
                raise RuntimeError(
                    f"worker {wid} left the run (clean BYE mid-run — "
                    f"preempted?)")
            return wid, kind, detail

    def _await(self, kind: str, need: set, ignore: tuple = ()) -> None:
        """Block until every wid in ``need`` delivered one ``kind`` event.
        ``ignore`` lets the shutdown drain skip exchanges that were already
        in flight when DONE went out (their grads are discarded, exactly
        like the shared-memory transports discard a computed-but-unserved
        gradient at termination)."""
        pending = set(need)
        while pending:
            wid, got, _ = self._next_event(self.timeout)
            if got in ignore:
                continue
            if got == "member_lost":     # elastic: the lost worker can no
                pending.discard(wid)     # longer owe us anything
                continue
            if got != kind:
                raise RuntimeError(
                    f"protocol violation: expected {kind} from {pending}, "
                    f"got {got} from worker {wid}")
            pending.discard(wid)

    # -- live telemetry plane (obs.live) -------------------------------------

    def _start_live(self, listener: socket.socket, token: str) -> None:
        """Telemetry on: build the LiveMonitor, point every link's
        heartbeat hook at its store (push — every telemetry-bearing
        HEARTBEAT becomes samples), and start the sampler + STATS-acceptor
        threads. Telemetry off (default) never reaches here: no store, no
        threads, no timestamps — the zero-overhead pin stays intact."""
        cfg = self.cfg
        self.counters.counter("health_events")
        self.live = obs_live.LiveMonitor(
            cfg.n_workers, deadline_factor=cfg.straggler_factor,
            hb_interval_s=cfg.hb_interval_eff_s(),
            jsonl_path=cfg.telemetry_jsonl,
            counters=self.counters,
            meta={"algorithm": cfg.algorithm, "transport": "tcp",
                  "schedule": self.sched_name
                  + ("+p2p" if self.sync_p2p else "")})
        for wid, link in self.links.items():
            link.hb_hook = (lambda payload, w=wid:
                            self.live.ingest_hb(w, payload))
        th = threading.Thread(target=self._live_sampler, daemon=True)
        th.start()
        self._threads.append(th)

    def _live_sampler(self) -> None:
        """Periodic master-side pass: per-link heartbeat age + per-link
        ef_ratio into the store, aggregate gauges under wid −1, one
        detector pass (straggler / hb_stale events). Links are snapshot
        per pass — elastic membership mutates the dict concurrently."""
        period = self.cfg.telemetry_period_s()
        while not self._closing.wait(period):
            now = time.monotonic()
            links = list(self.links.items())
            staleness = {w: round(now - link.last_seen, 3)
                         for w, link in links}
            for w, link in links:
                ratio = link.ef_ratio()
                if ratio is not None:
                    self.live.ingest_hb(w, {"ef_ratio": round(ratio, 2)})
            gauges = {k: v for k, v in self.counters.snapshot().items()
                      if isinstance(v, (int, float))}
            gauges["iters"] = self.iters
            self.live.sample(staleness=staleness, gauges=gauges)

    def _start_acceptor(self, listener: socket.socket, token: str) -> None:
        th = threading.Thread(target=self._control_acceptor,
                              args=(listener, token), daemon=True)
        th.start()
        self._threads.append(th)

    def _control_acceptor(self, listener: socket.socket, token: str) -> None:
        """Post-rendezvous connections on the rendezvous listener: STATS
        snapshot requests from monitors (one request per connection), and —
        under elastic membership — HELLO frames from respawned workers
        rejoining the run (the link is handed to the serve loop as a
        ``rejoin_hello`` event; everything else about admission happens
        there, on the thread that owns the run state)."""
        while not self._closing.is_set():
            try:
                conn, _ = listener.accept()
            except socket.timeout:
                continue
            except OSError:
                return                   # listener closed at shutdown
            client = None
            keep = False
            try:
                conn.settimeout(10.0)
                client = Link(conn, codec=self.cfg.wire_compression,
                              counters=self.link_counters)
                frame = client.recv_header()
                if frame.ftype == wire.STATS and self.live is not None:
                    req = client.recv_json(frame)
                    if req.get("token") != token:
                        client.send_json(wire.ERROR, {"msg": "bad token"})
                        continue
                    client.send_json(
                        wire.STATS,
                        self.live.snapshot(int(req.get("k", 32))))
                    continue
                if frame.ftype == wire.HELLO and self.elastic:
                    hello = client.recv_json(frame)
                    wid = int(hello.get("wid", -1))
                    if hello.get("token") != token:
                        client.send_json(wire.ERROR, {"msg": "bad token"})
                        continue
                    if not (0 <= wid < self.cfg.n_workers):
                        client.send_json(wire.ERROR,
                                         {"msg": f"bad wid {wid}"})
                        continue
                    if wid in self.links or not self.membership.is_lost(wid):
                        client.send_json(wire.ERROR, {
                            "msg": f"wid {wid} is not rejoinable "
                                   f"(state {self.membership.state(wid)})"})
                        continue
                    if not self.sync_p2p:
                        client.send_json(wire.ERROR, {
                            "msg": "rejoin is a p2p sync-plane feature"})
                        continue
                    conn.settimeout(self.timeout)
                    keep = True
                    self.events.put((wid, "rejoin_hello",
                                     {"link": client,
                                      "peer": hello.get("peer")}))
            except (socket.timeout, wire.WireError, OSError, ValueError):
                pass
            finally:
                if not keep:
                    if client is not None:
                        client.close()
                    else:
                        conn.close()

    # -- eval ----------------------------------------------------------------

    def _maybe_eval(self, force: bool = False) -> None:
        if force or self.iters - self._last_eval >= self.cfg.eval_every_iters:
            t0 = time.perf_counter()
            self.history.append((t0 - self._t0, self.iters,
                                 float(self.eval_fn(self.center.copy()))))
            self._last_eval = self.iters
            if self.tracer is not None:
                self.tracer.record(obs_trace.EVAL, t0, time.perf_counter())

    # -- disciplines ---------------------------------------------------------

    def _send_weights(self, wid: int) -> int:
        link = self.links.get(wid)
        if link is None:                 # elastic: lost since we scheduled it
            return 0
        try:
            if self._down_stacked:
                payload = np.concatenate(
                    [self.workers_w[wid], self.workers_v[wid]])
                return link.send_array(wire.WEIGHTS, payload,
                                       wid=wid, segments=2)
            return link.send_array(wire.WEIGHTS, self.workers_w[wid],
                                   wid=wid)
        except (wire.WireError, OSError):
            if self.elastic and self._serving:
                return 0                 # its reader surfaces the loss
            raise

    def serve(self) -> None:
        algo = self.cfg.algorithm
        self._t0 = time.perf_counter()
        self._serving = True             # elastic: losses are now absorbed
        if self.sync_p2p:
            self._serve_sync_p2p()
        elif algo in SYNC:
            self._serve_sync()
        elif algo == "original_easgd":
            self._serve_original()
        elif self.cfg.deterministic:
            self._serve_turnstile()
        elif algo.startswith("hogwild"):
            self._serve_hogwild()
        else:
            self._serve_fcfs()

    def _serve_original(self) -> None:
        """Round-robin with compute-in-turn: WEIGHTS go out only when the
        turn arrives, so the wire itself serializes the whole pipeline.
        Elastic: the rotation runs over the LIVE roster each turn — a lost
        worker simply drops out of the cycle, its turn is re-served."""
        e, cfg = self.easgd, self.cfg
        n_turns = -(-cfg.total_iters // self.tau)
        turn = served = 0
        while served < n_turns:
            roster = sorted(self.links)
            if not roster:
                raise RuntimeError("elastic: every worker was lost")
            j = roster[turn % len(roster)]
            turn += 1
            t_down, t_up = self._t_msg_pair(j)
            deadline = time.monotonic() + t_down
            self._send_weights(j)
            if t_down:
                sleep_until(deadline)            # W̄ down
            self._await("grad", {j})
            if j not in self.links:              # lost while we waited
                continue
            grad = self._absorb_upload(j)
            deadline = time.monotonic() + t_up
            easgd_flat.master_absorb_round_robin(
                self.center, self.workers_w[j], self.workers_v[j], grad, e)
            if t_up:
                sleep_until(deadline)            # W⁽ʲ⁾ up
            served += 1
            self.iters += self.tau
            self._maybe_eval()

    def _serve_turnstile(self) -> None:
        """Deterministic admission: all workers compute ahead, the master
        absorbs in strict cyclic order — the DES zero-jitter event order,
        hence bitwise-identical weights to the thread transport."""
        e, cfg = self.easgd, self.cfg
        ready = [False] * cfg.n_workers
        for wid in self.links:
            self._send_weights(wid)
        turn = 0
        while self.iters < cfg.total_iters:
            j = turn % cfg.n_workers
            t_pair = sum(self._t_msg_pair(j))
            while not ready[j]:
                wid, kind, _ = self._next_event(self.timeout)
                assert kind == "grad", kind
                ready[wid] = True
            ready[j] = False
            deadline = time.monotonic() + t_pair
            grad = self._absorb_upload(j)
            easgd_flat.master_absorb(
                cfg.algorithm, self.center, self.master_vel,
                self.workers_w[j], self.workers_v[j], grad, e)
            if t_pair:
                sleep_until(deadline)
            turn += 1
            self.iters += self.tau
            self._maybe_eval()
            if self.iters < cfg.total_iters:
                self._send_weights(j)

    def _serve_fcfs(self) -> None:
        """Async family: absorb in arrival order; the single master wire
        serializes both messages of each exchange (same ``wire_free_at``
        reservation as the thread transport, slept inline because here the
        master really is the link's endpoint)."""
        e, cfg = self.easgd, self.cfg
        wire_free_at = 0.0
        for wid in self.links:
            self._send_weights(wid)
        while self.iters < cfg.total_iters:
            j, kind, _ = self._next_event(self.timeout)
            if kind == "member_lost":    # elastic: its quota is re-absorbed
                if not self.links:       # by arrival order naturally
                    raise RuntimeError("elastic: every worker was lost")
                continue
            assert kind == "grad", kind
            t_pair = sum(self._t_msg_pair(j))
            deadline = None
            if t_pair:
                start = max(time.monotonic(), wire_free_at)
                deadline = start + t_pair
                wire_free_at = deadline
            grad = self._absorb_upload(j)
            easgd_flat.master_absorb(
                cfg.algorithm, self.center, self.master_vel,
                self.workers_w[j], self.workers_v[j], grad, e)
            if deadline is not None:
                sleep_until(deadline)
            self.iters += self.tau
            self._maybe_eval()
            if self.iters < cfg.total_iters:
                self._send_weights(j)

    def _serve_hogwild(self) -> None:
        """Absorb on arrival, no discipline; per-exchange wire times OVERLAP
        — a delayed-sender thread releases each worker's reply at its own
        deadline, so one worker's wire time never serializes another's
        (the thread transport's lock-free sleep, relocated to the master).
        Per-worker quotas mirror the thread transport's termination."""
        e, cfg = self.easgd, self.cfg
        P, total = cfg.n_workers, cfg.total_iters
        t_pairs = [sum(self._t_msg_pair(w)) for w in range(P)]
        quota = [(total // P + (1 if w < total % P else 0)) for w in range(P)]
        target = [-(-q // self.tau) for q in quota]   # exchanges per worker
        done = [0] * P
        replies: queue.Queue = queue.Queue()          # (deadline, wid)
        stop = threading.Event()

        def _delayed_sender():
            # deadline heap, not FIFO: with per-link pacing (link_slow) a
            # slow worker's long reservation must not head-of-line block
            # the fast workers' short ones — each reply releases at ITS
            # deadline (equal pacing made FIFO coincide with this; unequal
            # pacing does not)
            pend: list = []
            while not stop.is_set():
                timeout = (max(0.0, min(pend[0][0] - time.monotonic(), 0.2))
                           if pend else 0.2)
                try:
                    heapq.heappush(pend, replies.get(timeout=timeout))
                except queue.Empty:
                    pass
                now = time.monotonic()
                while pend and pend[0][0] <= now:
                    _, w = heapq.heappop(pend)
                    self._send_weights(w)

        sender = threading.Thread(target=_delayed_sender, daemon=True)
        sender.start()
        lost_any = False
        try:
            for wid in self.links:
                self._send_weights(wid)
            while any(d < t for d, t in zip(done, target)):
                j, kind, _ = self._next_event(self.timeout)
                if kind == "member_lost":
                    # elastic: forgive the dead worker's remaining quota —
                    # hogwild has no barrier to re-balance, the run just
                    # ends those iterations short
                    target[j] = done[j]
                    lost_any = True
                    if not self.links:
                        raise RuntimeError("elastic: every worker was lost")
                    continue
                assert kind == "grad", kind
                grad = self._absorb_upload(j)
                deadline = time.monotonic() + t_pairs[j]
                easgd_flat.master_absorb(
                    cfg.algorithm, self.center, self.master_vel,
                    self.workers_w[j], self.workers_v[j], grad, e)
                done[j] += 1
                self.iters += self.tau
                self._maybe_eval()
                if done[j] < target[j]:
                    if t_pairs[j]:
                        replies.put((deadline, j))
                    else:
                        self._send_weights(j)
        finally:
            stop.set()
            sender.join(timeout=5)
        if not lost_any:
            self.iters = total                        # quota-exact by design

    def _rebuild_sync_plan(self, p: int) -> None:
        """Elastic membership shrank the centralized sync family to ``p``
        workers: re-resolve dense rounds, padding, bucket boundaries and
        mailbox for P′ — the participation mask realized as geometry. The
        workers are stateless request-reply clients here, so nothing ships
        to them; only the master's exchange plan changes."""
        self.rounds = comm_schedules.get(self.sched_name).rounds(
            p, self.n * 8, self.cfg.net)
        self.padded = self.n + (-self.n) % max(p, 1)
        if getattr(self.cfg, "bucket_bytes", 0) > 0:
            self.boundaries = comm_rounds.default_bucket_boundaries(
                self._layer_sizes, self.padded, self.cfg.bucket_bytes)
        self.mailbox = np.zeros((p + 1, self.padded))
        epoch = self.membership.advance_epoch()
        self.counters.gauge("epoch").value = epoch
        if self.live is not None:
            self.live.set_membership(sorted(self.links))
        self._mark_event(-1, "reconfigure",
                         f"epoch {epoch}: p={p} "
                         f"survivors={sorted(self.links)} (centralized)")

    def _serve_sync(self) -> None:
        """Barriered rounds over links. sync_easgd's allreduce runs on the
        master's mailbox WHILE the workers compute (their gradient follows
        the WEIGHTS/WSTATE they just sent/received) — the §6.1.3 overlap is
        real; sync_sgd's gradient exchange must wait for the GRADs.

        Elastic: each round runs over the live roster — on a loss the
        surviving rows are packed densely, the rounds re-resolved for P′
        and the mean taken over P′ (the participation mask). A worker lost
        AFTER its state entered the mailbox still contributes to that one
        exchange (its grad is simply skipped); it is out of the roster from
        the next round on."""
        e, cfg = self.easgd, self.cfg
        algo, n = cfg.algorithm, self.n
        plan_p = cfg.n_workers
        # the centralized exchange is one barriered pipeline: a slow link
        # slows the whole round, so link_slow stretches the shared pacing
        # by the worst factor (per-worker divergence needs p2p/async)
        t_factor = max(cfg.link_slow) if cfg.link_slow else 1.0
        t_wire = self._t_sync_wire() * t_factor
        tr = self.tracer
        _pc = time.perf_counter
        while self.iters < cfg.total_iters:
            roster = sorted(self.links)
            if not roster:
                raise RuntimeError("elastic: every worker was lost")
            for wid in roster:
                self._send_weights(wid)
            if algo == "sync_easgd":
                got_grad: set = set()
                if self.tau > 1:
                    # workers do τ−1 local steps, then post their evolved
                    # weights (WSTATE) before computing the exchange grad —
                    # the allreduce still overlaps that last computation.
                    # A fast worker's GRAD may arrive before a slow one's
                    # WSTATE, so grads are buffered while we collect.
                    got_w: set = set()
                    need = set(roster)
                    while not need <= got_w:
                        wid, kind, _ = self._next_event(self.timeout)
                        if kind == "member_lost":
                            need.discard(wid)
                            got_grad.discard(wid)
                            continue
                        if kind == "wstate":
                            got_w.add(wid)
                        else:
                            assert kind == "grad", kind
                            got_grad.add(wid)
                    for i in sorted(need):
                        self.workers_w[i] = self.wstate_bufs[i]
                roster = [w for w in roster if w in self.links]
                P = len(roster)
                if P == 0:
                    continue             # everyone died this round
                if P != plan_p:
                    self._rebuild_sync_plan(P)
                    plan_p = P
                    t_wire = self._t_sync_wire() * t_factor
                self.mailbox[:P, :n] = self.workers_w[roster]
                deadline = time.monotonic() + t_wire
                if tr is not None:
                    t0 = _pc()
                execute_rounds(self.mailbox, n, self.rounds, self.counters,
                               boundaries=self.boundaries, tracer=tr)
                if t_wire:
                    sleep_until(deadline)
                if tr is not None:
                    tr.record(obs_trace.EXCHANGE, t0, (t0 := _pc()))
                self._await("grad", set(roster) - got_grad)
                if tr is not None:
                    tr.record(obs_trace.RECV_WAIT, t0, (t0 := _pc()))
                for i in roster:
                    if i in self.links:  # a late loss: skip its local step
                        easgd_flat.worker_step(
                            algo, self.workers_w[i], self.workers_v[i],
                            self.grad_bufs[i], self.center, e)
                easgd_flat.sync_master_easgd(
                    self.center, self.mailbox[0, :n] / P, P, e)
                if tr is not None:
                    tr.record(obs_trace.UPDATE, t0, _pc())
            else:                                     # sync_sgd
                if tr is not None:
                    t0 = _pc()
                self._await("grad", set(roster))
                if tr is not None:
                    tr.record(obs_trace.RECV_WAIT, t0, (t0 := _pc()))
                roster = [w for w in roster if w in self.links]
                P = len(roster)
                if P == 0:
                    continue
                if P != plan_p:
                    self._rebuild_sync_plan(P)
                    plan_p = P
                    t_wire = self._t_sync_wire() * t_factor
                self.mailbox[:P, :n] = [self.grad_bufs[w] for w in roster]
                deadline = time.monotonic() + t_wire
                execute_rounds(self.mailbox, n, self.rounds, self.counters,
                               boundaries=self.boundaries, tracer=tr)
                if t_wire:
                    sleep_until(deadline)
                if tr is not None:
                    tr.record(obs_trace.EXCHANGE, t0, (t0 := _pc()))
                easgd_flat.sync_master_sgd(
                    self.center, self.master_vel, self.mailbox[0, :n] / P, e)
                self.workers_w[:] = self.center
                if tr is not None:
                    tr.record(obs_trace.UPDATE, t0, _pc())
            self.iters += P * self.tau
            self._maybe_eval()

    def _p2p_iters_at(self, k: int) -> int:
        """Total iterations once exchange round ``k`` completes, summed
        across epochs: rounds before the epoch base ran at earlier P's."""
        return (self._epoch_iters_base
                + (k + 1 - self._epoch_round_base) * self._epoch_p
                * self.tau)

    def _p2p_center_report(self, tag: int, payload: np.ndarray) -> bool:
        """Consume one tagged CENTER report. Tag ≥ 0 is an eval report
        after exchange round ``tag``; −1 is the final center. Returns True
        for the final report."""
        n = self.n
        self.center[:] = payload[:n]
        if payload.size >= 2 * n:        # sync_sgd state: [center|vel]
            self.master_vel[:] = payload[n:2 * n]
        if tag >= 0:
            self.iters = self._p2p_iters_at(tag)
            self._maybe_eval(force=True)
            return False
        self.iters = self._p2p_iters_at(self._n_sync_rounds() - 1)
        return True

    def _serve_sync_p2p(self) -> None:
        """The control plane of the p2p sync family: the workers execute
        the rounds among themselves (net/peer.py), so this loop only
        consumes the reporter's CENTER reports (tagged with the exchange
        round in the header's wid field — reports and reconfigurations can
        interleave, so the cadence can't be inferred from arrival order),
        each worker's one final WSTATE, and the heartbeat/error machinery
        of ``_next_event``. No WEIGHTS go out, no GRADs come back: the
        master link moves Θ(N_center), not Θ(P·N) per round.

        Under ``PSConfig.elastic`` this loop is also the membership driver:
        a ``member_lost`` event freezes the superstep and runs
        ``_reconfigure_p2p``; a respawned worker's HELLO (handed over by
        the control acceptor) is admitted here and folded in by another
        reconfiguration once its READY lands."""
        self._epoch_members = set(self.links)
        self._epoch_p = len(self.links)
        final_center = False
        wstates: set = set()
        self._pending_rejoin: list = []
        while not (final_center and wstates >= set(self.links)):
            wid, kind, detail = self._next_event(self.timeout)
            if kind == "center":
                final_center |= self._p2p_center_report(*detail)
            elif kind == "wstate":
                self.workers_w[wid] = self.wstate_bufs[wid]
                wstates.add(wid)
            elif kind == "member_lost":
                if final_center:
                    continue             # already past the last exchange
                self._reconfigure_p2p()
            elif kind == "rejoin_hello":
                if final_center:
                    detail["link"].close()
                else:
                    self._admit_rejoin(wid, detail["link"], detail["peer"])
            elif kind == "ready":
                # a respawned worker finished building: fold it in at the
                # next epoch (the reconfigure ships it rounds + state)
                self.membership.mark_rejoined(wid)
                self._mark_event(wid, "worker_rejoined",
                                 f"enters at epoch {self.membership.epoch + 1}")
                self._reconfigure_p2p()
            elif kind == "reconf_ack":
                # a restarted reconfigure makes workers ack the same epoch
                # twice (once per phase 1 they saw); the collection loop
                # consumed one set, the leftovers are harmless latecomers
                continue
            else:
                raise RuntimeError(
                    f"protocol violation on the p2p control plane: "
                    f"got {kind} from worker {wid} ({detail!r})")

    def _admit_rejoin(self, wid: int, link: Link, peer) -> None:
        """Wire a respawned worker back in: register its link + reader and
        send a rejoin WELCOME. The worker builds its problem and warms up
        while the run keeps going; its READY triggers the reconfiguration
        that actually folds it into the mesh."""
        if not peer:
            link.send_json(wire.ERROR,
                           {"msg": "p2p rejoin needs a peer listener"})
            link.close()
            return
        self.peer_addrs[wid] = list(peer)
        self.links[wid] = link
        # the ORIGINAL spawned process for this wid is a corpse that stays
        # in self._procs; mark it reported forever so its exit code is
        # never mistaken for a death of the respawn (an external process
        # whose loss surfaces through its socket, not this poll)
        self._proc_reported.add(wid)
        self._mark_event(wid, "worker_rejoining")
        link.send_json(wire.WELCOME, self._welcome_payload(wid, rejoin=True))
        th = threading.Thread(target=self._reader, args=(wid, link),
                              daemon=True)
        th.start()
        self._threads.append(th)

    def _reconfigure_p2p(self) -> None:
        """Freeze → re-resolve → rewire → resume (the membership tentpole's
        master half).

        Phase 1 ships the next epoch's full geometry — survivor roster,
        rounds re-resolved for P′ and remapped onto the surviving wids, new
        padding and bucket boundaries, peer directory — to every member.
        Survivors stop at an exchange boundary (or fall out of the doomed
        exchange), tear their mesh links down, and ack with the number of
        exchange rounds they have fully completed. Phase 2 broadcasts the
        agreed resume round — the MINIMUM over acks, every worker ahead of
        it rolls back to its start-of-round snapshot so the new epoch's
        first exchange runs over bitwise-agreeing replicas — plus the new
        eval cadence; when a rejoiner is present, the lowest previous
        survivor uploads its rolled-back state and the master relays it so
        the rejoiner enters with the exact center (and velocity) bits.
        Another loss mid-reconfigure restarts the procedure with the
        smaller roster."""
        cfg = self.cfg
        while True:
            prev = sorted(w for w in self._epoch_members if w in self.links)
            roster = sorted(self.links)
            if not prev:
                raise RuntimeError(
                    "elastic: no previous-epoch survivor holds the state — "
                    "the run cannot continue")
            p = len(roster)
            epoch = self.membership.epoch + 1
            padded = self.n + (-self.n) % p
            rounds = comm_schedules.get(self.sched_name).rounds(
                p, self.n * 8, cfg.net)
            self.rounds = comm_rounds.remap_rounds(
                rounds, ft_membership.dense_rank_map(roster))
            self.padded = padded
            if getattr(cfg, "bucket_bytes", 0) > 0:
                self.boundaries = comm_rounds.default_bucket_boundaries(
                    self._layer_sizes, padded, cfg.bucket_bytes)
            joiners = [w for w in roster if w not in prev]
            sync_wid = prev[0]
            phase1 = {
                "phase": 1, "epoch": epoch, "p": p,
                "survivors": roster,
                "rounds": comm_schedules.rounds_to_wire(self.rounds),
                "padded": padded,
                "peers": {str(w): self.peer_addrs[w] for w in roster},
                "bucket_bounds": self.boundaries,
                "n_rounds": self._n_sync_rounds(),
                "sync_wid": sync_wid,
                "reporter": roster[0],
            }
            try:
                for w in roster:
                    slow = cfg.link_slow_factor(w)
                    self.links[w].send_json(wire.RECONFIGURE, {
                        **phase1,
                        "t_wire_s": slow * self._t_sync_wire(),
                        "t_wire_bucket_s": (
                            [slow * t for t in self._t_sync_wire_buckets()]
                            if self.boundaries else []),
                    }, wid=w)
            except (wire.WireError, OSError) as exc:
                # a member died under the broadcast: its reader will surface
                # the loss; drain it below and restart with the new roster
                self._mark_event(-1, "reconfigure_retry", repr(exc))
            # -- collect acks (and absorb whatever else is in flight) -------
            acks: dict[int, dict] = {}
            restart = False
            while set(acks) < set(roster):
                wid, kind, detail = self._next_event(self.timeout)
                if kind == "reconf_ack":
                    if int(detail.get("epoch", -1)) == epoch:
                        acks[wid] = detail
                elif kind == "member_lost":
                    restart = True
                    break
                elif kind == "center":
                    self._p2p_center_report(*detail)   # pre-freeze report
                elif kind == "wstate":
                    self.workers_w[wid] = self.wstate_bufs[wid]
                elif kind == "rejoin_hello":
                    # stash: admitted after this reconfigure completes (the
                    # serve loop re-enqueues it) — re-queuing here would
                    # spin this very collection loop
                    self._pending_rejoin.append((wid, detail))
                elif kind == "ready":
                    # an already-admitted rejoiner finished building while
                    # this reconfigure was in flight: it is in the roster,
                    # its ack follows
                    self.membership.mark_rejoined(wid)
                    self._mark_event(wid, "worker_rejoined",
                                     f"enters at epoch {epoch}")
                else:
                    raise RuntimeError(
                        f"protocol violation during reconfigure: "
                        f"got {kind} from worker {wid}")
            if restart:
                continue
            resume = min(int(acks[w]["round"]) for w in prev)
            # -- phase 2: agreed resume round + new eval cadence ------------
            per = p * self.tau
            last = self._last_eval
            base_iters = self._p2p_iters_at(resume - 1)
            evals = []
            for k in range(resume, self._n_sync_rounds()):
                it = base_iters + (k + 1 - resume) * per
                if it - last >= cfg.eval_every_iters:
                    evals.append(k)
                    last = it
            phase2 = {"phase": 2, "epoch": epoch, "resume_round": resume,
                      "eval_rounds": evals,
                      "upload_state": bool(joiners)}
            try:
                for w in roster:
                    self.links[w].send_json(wire.RECONFIGURE, phase2, wid=w)
                if joiners:
                    # the sync_wid uploads its rolled-back state; relay it
                    # to every joiner so they enter with the exact bits
                    state = None
                    while state is None:
                        wid, kind, detail = self._next_event(self.timeout)
                        if kind == "center" and detail[0] == -2:
                            state = detail[1]
                        elif kind == "center":
                            self._p2p_center_report(*detail)
                        elif kind == "member_lost":
                            restart = True
                            break
                        elif kind == "reconf_ack":
                            continue     # stale duplicate from a restart
                        elif kind == "wstate":
                            self.workers_w[wid] = self.wstate_bufs[wid]
                        elif kind == "rejoin_hello":
                            self._pending_rejoin.append((wid, detail))
                        else:
                            raise RuntimeError(
                                f"protocol violation waiting for the state "
                                f"upload: got {kind} from worker {wid}")
                    if restart:
                        continue
                    for w in joiners:
                        # raw: exact-state transfer, never through a lossy
                        # wire codec
                        self.links[w].send_array(wire.CENTER, state,
                                                 wid=-2, raw=True)
            except (wire.WireError, OSError) as exc:
                self._mark_event(-1, "reconfigure_retry", repr(exc))
                continue
            # -- bookkeeping: the epoch turns over --------------------------
            self._epoch_iters_base = base_iters
            self._epoch_round_base = resume
            self._epoch_p = p
            self._epoch_members = set(roster)
            new_epoch = self.membership.advance_epoch()
            assert new_epoch == epoch, (new_epoch, epoch)
            if self.live is not None:
                self.live.set_membership(roster)
            self.counters.gauge("epoch").value = epoch
            self._mark_event(
                -1, "reconfigure",
                f"epoch {epoch}: p={p} survivors={roster} "
                f"resume_round={resume}")
            for w, d in self._pending_rejoin:      # stashed mid-freeze
                self.events.put((w, "rejoin_hello", d))
            self._pending_rejoin.clear()
            return

    # -- top level -----------------------------------------------------------

    def run(self, listener: socket.socket, token: str = DEFAULT_TOKEN,
            procs: list | None = None):
        """Rendezvous → serve → clean shutdown. Returns a PSResult."""
        self._procs = procs or []
        try:
            self.rendezvous(listener, token)
            if self.cfg.telemetry_on:
                self._start_live(listener, token)
            if self.cfg.telemetry_on or self.elastic:
                # the rendezvous listener stays open: STATS for monitors,
                # rejoin HELLOs for respawned workers
                self._start_acceptor(listener, token)
            self.serve()
            total_time = time.perf_counter() - self._t0
            self._maybe_eval(force=True)
            self._draining = True        # BYEs are expected from here on
            for wid, link in list(self.links.items()):
                try:
                    link.send_simple(wire.DONE)
                except (wire.WireError, OSError):
                    if not self.elastic:
                        raise            # elastic: its loss drains below
            self._await("bye", set(self.links),
                        ignore=("grad", "wstate", "center"))
        finally:
            self._closing.set()
            for link in self.links.values():
                link.close()
            listener.close()
            for proc in self._procs:
                try:
                    proc.wait(timeout=10)
                except subprocess.TimeoutExpired:
                    proc.kill()
        counters = self.counters.snapshot()
        # heartbeat-piggybacked worker telemetry (iteration rate, exposed
        # comm so far) — last value seen per worker; absent for workers
        # whose client predates the telemetry heartbeats (empty frames)
        telemetry = {w: link.hb_telemetry
                     for w, link in self.links.items() if link.hb_telemetry}
        if telemetry:
            counters["worker_telemetry"] = telemetry
        if self.cfg.wire_compression == "sign_ef":
            raw = sum(link.raw_bytes_out for link in self.links.values())
            comp = sum(link.wire_bytes_out for link in self.links.values())
            if comp:
                counters["ef_raw_bytes_out"] = raw
                counters["ef_wire_bytes_out"] = comp
                counters["ef_ratio"] = round(raw / comp, 2)
        # per-link α observations: each worker's measured master-link RTT
        # (the clock-sync probes double as the α measurement — rtt/2 is
        # this link's one-way latency floor)
        link_alpha = {w: round(st["clock"]["rtt_s"] / 2, 6)
                      for w, st in self.bye_stats.items()
                      if isinstance(st.get("clock"), dict)
                      and "rtt_s" in st["clock"]}
        if link_alpha:
            counters["link_alpha_s"] = link_alpha
        if self.sync_p2p:
            # fold the workers' per-link data-plane counters in: each
            # unordered link (i, j) once, from the LOWER endpoint's report
            # (both endpoints count every frame on the link — sends and
            # receives — so the two reports agree; tests pin that)
            link_bytes: dict[str, int] = {}
            msgs = 0
            for wid, st in sorted(self.bye_stats.items()):
                for peer, c in st.get("peer_links", {}).items():
                    if wid < int(peer):
                        link_bytes[f"{wid}-{peer}"] = c["wire_bytes"]
                        msgs += c["messages"]
            counters["peer_link_bytes"] = link_bytes
            counters["peer_wire_bytes"] = sum(link_bytes.values())
            counters["peer_messages"] = msgs
            if self.topology is not None and self.topology.hosts > 1:
                # per-link-class totals: how many bytes stayed on fast
                # intra-host links vs crossed hosts — hierarchical's whole
                # point is driving cross_host_bytes down
                intra_b = cross_b = 0
                for key, v in link_bytes.items():
                    i, j = (int(x) for x in key.split("-"))
                    if self.topology.host_of(i) == self.topology.host_of(j):
                        intra_b += int(v)
                    else:
                        cross_b += int(v)
                counters["intra_host_bytes"] = intra_b
                counters["cross_host_bytes"] = cross_b
            # representative per-worker stats come from the LOWEST reporting
            # wid — under elastic membership worker 0 may not have survived
            rep = (self.bye_stats[min(self.bye_stats)]
                   if self.bye_stats else {})
            counters["sync_rounds"] = rep.get("sync_rounds", 0)
            # overlap accounting: summed across workers (wall seconds of
            # comm-thread activity vs seconds the update path sat blocked
            # on the wire); per-bucket logical payload summed elementwise
            for key in ("comm_s", "exposed_s", "overlapped_s"):
                counters[key] = sum(
                    st.get(key, 0.0) for st in self.bye_stats.values())
            counters["n_buckets"] = rep.get("n_buckets", 1)
            bucket_bytes = [0] * counters["n_buckets"]
            for st in self.bye_stats.values():
                for i, v in enumerate(st.get("bucket_send_bytes", [])):
                    if i < len(bucket_bytes):  # epochs can differ in buckets
                        bucket_bytes[i] += int(v)
            counters["bucket_send_bytes"] = bucket_bytes
        health = None
        if self.live is not None:
            health = self.live.health()
            self.live.close()
        if self.elastic:
            # PSResult.health must name every death / rejoin / reconfigure
            # even on a bare (telemetry-off) run — and always carries the
            # final membership table + epoch
            if health is None:
                health = {"events": list(self._elastic_events)}
            health["membership"] = self.membership.snapshot()
            health["epoch"] = self.membership.epoch
        trace = self._collect_trace() if self.cfg.trace else None
        return PSResult(
            algorithm=self.cfg.algorithm, transport="tcp",
            schedule=((self.sched_name + "+p2p") if self.sync_p2p
                      else self.sched_name if self.cfg.algorithm in SYNC
                      else "master"),
            history=self.history, total_time_s=total_time,
            total_iters=self.iters,
            counters=counters,
            final_metric=self.history[-1][2],
            center=self.center.copy(), workers=self.workers_w.copy(),
            trace=trace, health=health)

    def _collect_trace(self):
        """Merge the workers' BYE-delivered (or spilled) trace buffers with
        this master's own tracers onto the master clock — each worker span
        is shifted by its ``obs.clock`` offset estimate."""
        workers: dict = {}
        for wid, st in self.bye_stats.items():
            payload = st.get("trace")
            if payload is None and st.get("trace_file"):
                try:
                    payload = obs_trace.load_spill(st["trace_file"])
                except OSError:
                    payload = None
            if payload:
                workers[wid] = payload
        master_threads = {t.name: t.spans() for t in obs_trace.drain()
                          if t.n}
        merged = obs_report.merge_traces(
            workers,
            {"threads": master_threads} if master_threads else None)
        merged["report"] = obs_report.breakdown(merged)
        return merged


def accept_backlog(n_workers: int) -> int:
    """Rendezvous listen() backlog: every worker dials within the same
    spawn burst, so at P = 64 a backlog of P + 2 overflows the SYN queue
    the moment the accept loop blocks on a slow HELLO and late dialers
    see connection-refused. Floor of 16 keeps small runs unchanged in
    behavior; + 8 leaves room for monitor/STATS dials on top of P."""
    return max(16, n_workers + 8)


def run_ps_tcp(problem, easgd, cfg, eval_fn_override=None,
               join_timeout_s: float = 600.0):
    """The tcp transport's ``run_ps``: bind, spawn localhost workers (unless
    ``cfg.spawn_workers`` is off — then external workers join, see
    launch/cluster), serve, return the same PSResult the shared-memory
    transports produce."""
    master = MasterServer(problem, easgd, cfg,
                          eval_fn_override=eval_fn_override,
                          join_timeout_s=join_timeout_s)
    listener = socket.socket()
    listener.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
    listener.bind((cfg.tcp_host, cfg.tcp_port))
    listener.listen(accept_backlog(cfg.n_workers))
    port = listener.getsockname()[1]
    env_extra = None
    spec = ft_chaos.ChaosSpec.from_config(getattr(cfg, "chaos", None))
    if spec is not None:
        env_extra = {ft_chaos.ENV_VAR: spec.to_env()}
    procs = (spawn_local_workers(
        cfg.tcp_host, port, cfg.n_workers,
        pallas=getattr(cfg, "update_backend", "numpy") == "pallas",
        env_extra=env_extra)
        if cfg.spawn_workers else [])
    return master.run(listener, procs=procs)
