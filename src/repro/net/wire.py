"""The repro.net wire: a length-prefixed binary protocol for the PS runtime.

One frame = one 16-byte header + payload:

    !2sBBhBBQ  =  magic "RN" | version | type | wid | flags | codec | length

Frame types mirror the runtime's message vocabulary (``comm.Message`` is the
in-memory form; these are the same exchanges serialized): HELLO/WELCOME/READY
for rendezvous, WEIGHTS (master→worker, W⁽ⁱ⁾ or W̄ down), GRAD (worker→master,
∇ up — with τ>1 the payload stacks [grad|w|v] since the worker's local state
diverged), WSTATE (worker→master start-of-exchange weights for the sync
family's overlap under τ>1), HEARTBEAT, DONE/BYE for clean shutdown, ERROR.

Array payloads are float64 and move through two codecs:

 * ``none``    — raw bytes. Zero-copy on both sides: ``sendall`` takes a
   memoryview of the numpy buffer, ``recv_into`` lands directly in the
   receiver's preallocated array (no intermediate bytes objects for the
   big-buffer path).
 * ``sign_ef`` — 1-bit sign compression with error feedback
   (``core.compression.sign_ef_encode_np``): the EF state lives HERE, per
   link per direction — the sender of a link carries its own quantization
   residual forward, exactly like the per-pod EF buffers of the jitted path.

This module is deliberately jax-free: TCP worker processes import it (plus
numpy and the problem factory) and nothing else.
"""
from __future__ import annotations

import json
import socket
import struct
import threading
import time

import numpy as np

from repro.core.compression import (
    sign_ef_decode_np,
    sign_ef_encode_np,
    sign_ef_wire_nbytes,
)
from repro.obs.metrics import Slot  # noqa: F401 — the counter cell lives in
#                                     repro.obs now; re-exported here because
#                                     the Link counter protocol is defined in
#                                     terms of it (and tests/peers import it)

MAGIC = b"RN"
VERSION = 1
_HEADER = struct.Struct("!2sBBhBBQ")
HEADER_SIZE = _HEADER.size                      # 16

# frame types
HELLO = 1
WELCOME = 2
READY = 3
WEIGHTS = 4
GRAD = 5
WSTATE = 6
HEARTBEAT = 7
DONE = 8
BYE = 9
ERROR = 10
SEGMENT = 11        # p2p data plane: one Message of a Schedule round over a
#                     worker↔worker link; the round index (mod 0x8000)
#                     rides the header's wid field as a desync detector
#                     (the link itself identifies the peer); payload is the
#                     Message.span slice of the sender's mailbox row
PEERS = 12          # p2p handshake on a worker↔worker link: JSON
#                     {"wid", "token"} from the connector, {"wid"} ack back
CENTER = 13         # p2p control plane: worker 0 → master, the center
#                     replica at an eval round (finality is by count — the
#                     master knows the eval schedule it shipped in WELCOME)
CLOCK = 14          # clock-sync probe (obs.clock): empty worker→master ping,
#                     master echoes {"t": perf_counter()} — offset = t −
#                     (t0+t1)/2 at min rtt aligns trace timelines
STATS = 15          # live-telemetry snapshot request (obs.live): a monitor
#                     client connects to the master's listener after
#                     rendezvous, sends {"token", "k"}, receives one JSON
#                     LiveMonitor.snapshot(k) back, and the connection
#                     closes — read-only, off the training links entirely
RECONFIGURE = 16    # elastic membership (ft.membership): master → worker, a
#                     JSON epoch directive — phase 1 carries the survivor
#                     set, re-resolved rounds, peer directory and bucket
#                     bounds; phase 2 carries {"epoch", "resume_round"} and
#                     is followed by the authoritative CENTER array. The
#                     designated sync worker acks phase 1 with its own
#                     worker→master RECONFIGURE {"epoch", "round", "step"}
#                     plus a CENTER(wid=-2) state upload.

FRAME_NAMES = {HELLO: "HELLO", WELCOME: "WELCOME", READY: "READY",
               WEIGHTS: "WEIGHTS", GRAD: "GRAD", WSTATE: "WSTATE",
               HEARTBEAT: "HEARTBEAT", DONE: "DONE", BYE: "BYE",
               ERROR: "ERROR", SEGMENT: "SEGMENT", PEERS: "PEERS",
               CENTER: "CENTER", CLOCK: "CLOCK", STATS: "STATS",
               RECONFIGURE: "RECONFIGURE"}

CODEC_NONE = 0
CODEC_SIGN_EF = 1
CODECS = {"none": CODEC_NONE, "sign_ef": CODEC_SIGN_EF}

_COUNT_LOCK = threading.Lock()    # guards every counters-dict update (the
#                                   dicts are shared across links/threads)


class WireError(ConnectionError):
    """Framing violation or peer gone."""


class DialError(ConnectionError):
    """A bounded retry-with-backoff dial exhausted its deadline."""


def dial_with_backoff(host, port, deadline_s=30.0, base_s=0.05, max_s=1.0,
                      seed=None, refuse_fn=None):
    """Dial ``(host, port)`` with jittered exponential backoff until
    ``deadline_s`` elapses, then raise :class:`DialError` naming the target.

    A staggered multi-host start means the listener may simply not exist yet
    — ``ConnectionRefusedError``/timeouts are retried; anything else (bad
    address family, unreachable network after the deadline) surfaces as
    ``DialError`` with the last underlying error attached.

    ``refuse_fn`` is the fault-injection hook (``ft.chaos``): called before
    every attempt; returning True simulates a refused dial without touching
    the socket, so the retry path is testable deterministically.
    """
    deadline = time.monotonic() + deadline_s
    # deterministic per-target jitter stream: retry storms from P dialers
    # de-synchronize without a global RNG (and without perturbing the run's
    # seeded math)
    rng = np.random.default_rng(
        seed if seed is not None else (hash((host, int(port))) & 0xFFFFFFFF))
    delay = base_s
    attempt = 0
    last_exc = None
    while True:
        attempt += 1
        try:
            if refuse_fn is not None and refuse_fn():
                raise ConnectionRefusedError(
                    f"chaos: dial to {host}:{port} refused by injection")
            remaining = deadline - time.monotonic()
            if remaining <= 0:
                break
            return socket.create_connection(
                (host, int(port)), timeout=min(max(remaining, 0.01), 10.0))
        except (ConnectionRefusedError, ConnectionResetError, OSError) as exc:
            last_exc = exc
            if time.monotonic() >= deadline:
                break
            sleep_s = min(delay, max_s) * (0.5 + float(rng.random()))
            time.sleep(min(sleep_s, max(deadline - time.monotonic(), 0.0)))
            delay *= 2.0
    raise DialError(
        f"dial to {host}:{port} failed after {attempt} attempts over "
        f"{deadline_s:.1f}s: {last_exc!r}")


class Frame:
    __slots__ = ("ftype", "wid", "flags", "codec", "size")

    def __init__(self, ftype, wid, flags, codec, size):
        self.ftype = ftype
        self.wid = wid
        self.flags = flags
        self.codec = codec
        self.size = size

    def __repr__(self):
        return (f"Frame({FRAME_NAMES.get(self.ftype, self.ftype)}, "
                f"wid={self.wid}, codec={self.codec}, size={self.size})")


def sleep_until(deadline: float) -> None:
    """Absolute-deadline sleep on the ``time.monotonic`` clock (oversleep on
    a loaded box does not accumulate — same discipline as ``repro.ps``)."""
    dt = deadline - time.monotonic()
    if dt > 0:
        time.sleep(dt)


def parse_header(buf: bytes) -> Frame:
    """Validate and unpack one 16-byte frame header (the p2p round engine
    fills header buffers itself on non-blocking sockets)."""
    magic, ver, ftype, wid, flags, codec, size = _HEADER.unpack(buf)
    if magic != MAGIC or ver != VERSION:
        raise WireError(f"bad frame header: magic={magic!r} v={ver}")
    return Frame(ftype, wid, flags, codec, size)


def _recv_exact(sock: socket.socket, view: memoryview) -> None:
    """Fill ``view`` completely, looping over partial reads."""
    got = 0
    n = len(view)
    while got < n:
        k = sock.recv_into(view[got:], n - got)
        if k == 0:
            raise WireError("peer closed mid-frame "
                            f"({got}/{n} bytes received)")
        got += k


class Link:
    """One framed endpoint: send lock (header+payload atomic per frame),
    per-direction error-feedback state, byte/message counters, last-seen
    timestamp (heartbeats refresh it)."""

    def __init__(self, sock: socket.socket, codec: str = "none",
                 counters=None):
        try:
            sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        except OSError:
            pass                        # AF_UNIX socketpair (tests) — no Nagle
        self.sock = sock
        self.codec = CODECS[codec]
        self.counters = counters            # dict of slots with .value, or None
        self.last_seen = time.monotonic()
        self.hb_telemetry: dict = {}        # last HEARTBEAT payload (worker
        #                                     iteration-rate / exposed-comm
        #                                     gauges — see net/worker.py)
        self.hb_hook = None                 # optional callable(payload):
        #                                     fires on the receiving thread
        #                                     for every telemetry-bearing
        #                                     HEARTBEAT (obs.live feeds its
        #                                     time-series store push-style)
        self.raw_bytes_out = 0              # pre-codec payload bytes encoded
        self.wire_bytes_out = 0             # post-codec payload bytes encoded
        self._send_lock = threading.Lock()
        self._hdr_buf = bytearray(HEADER_SIZE)
        self._ef = {}                       # payload size -> EF state (send)

    # -- send ---------------------------------------------------------------

    def _count(self, nbytes: int) -> None:
        if self.counters is not None:
            # locked: counts may run concurrently — a send and a receive on
            # one link (the p2p threaded-sender path), or several links
            # sharing one counters dict (the master's P reader threads) —
            # and `slot.value += n` alone loses increments between threads.
            # One module-wide lock keeps any sharing pattern exact; at
            # frame granularity the contention cost is noise.
            with _COUNT_LOCK:
                self.counters["messages"].value += 1
                self.counters["wire_bytes"].value += HEADER_SIZE + nbytes
                extra = self.counters.get("link_bytes")
                if extra is not None:   # an additional per-link-class slot
                    extra.value += HEADER_SIZE + nbytes

    def _send(self, ftype: int, wid: int, flags: int, codec: int,
              payload) -> int:
        header = _HEADER.pack(MAGIC, VERSION, ftype, wid, flags, codec,
                              len(payload))
        with self._send_lock:
            self.sock.sendall(header)
            if len(payload):
                self.sock.sendall(payload)
        self._count(len(payload))
        return len(payload)

    def send_simple(self, ftype: int, wid: int = 0) -> int:
        return self._send(ftype, wid, 0, CODEC_NONE, b"")

    def send_json(self, ftype: int, obj, wid: int = 0) -> int:
        return self._send(ftype, wid, 0, CODEC_NONE,
                          json.dumps(obj).encode())

    def encode_array(self, ftype: int, arr: np.ndarray, wid: int = 0,
                     segments: int = 1, ef_tag=0, raw: bool = False
                     ) -> tuple[bytes, memoryview]:
        """Serialize an array frame WITHOUT sending: ``(header, payload)``.
        The p2p round engine queues these on non-blocking sockets and
        streams them itself. With codec none the payload is a zero-copy
        memoryview of ``arr``; sign_ef encodes (and therefore snapshots)
        the data here, advancing this link's error-feedback state — so
        encode order must be deterministic (it is: plan order)."""
        arr = np.ascontiguousarray(arr, np.float64)
        if self.codec == CODEC_SIGN_EF and not raw:
            assert arr.size % max(segments, 1) == 0, (arr.size, segments)
            segs = arr.reshape(max(segments, 1), -1)
            parts = []
            for i in range(segs.shape[0]):
                key = (ftype, segs.shape[1], i, ef_tag)
                err = self._ef.get(key)
                if err is None:
                    err = self._ef[key] = np.zeros(segs.shape[1], np.float64)
                payload, self._ef[key] = sign_ef_encode_np(segs[i], err)
                parts.append(payload)
            payload = memoryview(b"".join(parts))
            codec = CODEC_SIGN_EF
        else:
            payload = memoryview(arr).cast("B")
            codec = CODEC_NONE
        header = _HEADER.pack(MAGIC, VERSION, ftype, wid, max(segments, 1),
                              codec, len(payload))
        # compression-ratio accounting (obs.metrics): raw vs on-the-wire
        # payload bytes, per link. Encode sites are single-threaded per
        # link (plan order / the send path), so plain adds are exact.
        self.raw_bytes_out += arr.nbytes
        self.wire_bytes_out += len(payload)
        return header, payload

    def ef_ratio(self):
        """Measured compression ratio raw/wire of everything this link
        encoded (≈ 64 for pure sign_ef streams; None before any send)."""
        if not self.wire_bytes_out:
            return None
        return self.raw_bytes_out / self.wire_bytes_out

    def send_array(self, ftype: int, arr: np.ndarray, wid: int = 0,
                   segments: int = 1, ef_tag=0, raw: bool = False) -> int:
        """Send a flat float64 array through the link's codec. Returns the
        payload byte count that actually crossed the wire.

        ``segments``: number of equal-size logical segments in ``arr``
        (τ>1 exchanges stack [grad|w|v] into one frame). sign_ef encodes
        EACH segment with its own scale and error-feedback state — one
        shared scale would let weight magnitudes drown the gradient's.
        EF state is keyed by (frame type, segment, ef_tag), so e.g. a
        WSTATE weights stream never shares residuals with a GRAD stream of
        the same size. ``ef_tag`` (any hashable) distinguishes same-size
        streams of one frame type on one link: the p2p data plane tags
        SEGMENT frames with (bucket, chunk index, op), so every (peer,
        bucket, vector segment, direction-of-flow) carries its own
        quantization residual forward. ``raw=True`` bypasses a lossy codec
        for this one frame — one-shot reports (the p2p final CENTER/WSTATE)
        must arrive exact; error feedback can only amortize quantization
        across a STREAM."""
        header, payload = self.encode_array(ftype, arr, wid=wid,
                                            segments=segments, ef_tag=ef_tag,
                                            raw=raw)
        with self._send_lock:
            self.sock.sendall(header)
            if len(payload):
                self.sock.sendall(payload)
        self._count(len(payload))
        return len(payload)

    # -- recv ---------------------------------------------------------------

    def recv_header(self, skip_heartbeat: bool = True) -> Frame:
        while True:
            _recv_exact(self.sock, memoryview(self._hdr_buf))
            magic, ver, ftype, wid, flags, codec, size = _HEADER.unpack(
                bytes(self._hdr_buf))
            if magic != MAGIC or ver != VERSION:
                raise WireError(f"bad frame header: magic={magic!r} v={ver}")
            self.last_seen = time.monotonic()
            frame = Frame(ftype, wid, flags, codec, size)
            if skip_heartbeat and ftype == HEARTBEAT:
                if frame.size:
                    # telemetry-bearing heartbeat (worker iteration rate /
                    # exposed-comm gauges): latch the payload instead of
                    # discarding — the master reads link.hb_telemetry
                    try:
                        self.hb_telemetry = json.loads(
                            bytes(self.recv_payload(frame)).decode())
                    except ValueError:
                        pass
                    else:
                        if self.hb_hook is not None:
                            self.hb_hook(self.hb_telemetry)
                continue
            return frame

    def recv_payload(self, frame: Frame) -> bytearray:
        buf = bytearray(frame.size)
        if frame.size:
            _recv_exact(self.sock, memoryview(buf))
        self._count(frame.size)
        return buf

    def recv_discard(self, frame: Frame) -> None:
        if frame.size:
            self.recv_payload(frame)

    def recv_json(self, frame: Frame) -> dict:
        return json.loads(bytes(self.recv_payload(frame)).decode())

    def recv_array(self, frame: Frame, out: np.ndarray | None = None
                   ) -> np.ndarray:
        """Decode an array payload. With codec none and a preallocated
        ``out``, the socket writes STRAIGHT into the target buffer
        (``recv_into`` — the zero-copy big-buffer path)."""
        if frame.codec == CODEC_NONE:
            n = frame.size // 8
            if out is not None:
                assert out.dtype == np.float64 and out.size == n, \
                    (out.dtype, out.size, n)
                _recv_exact(self.sock, memoryview(out).cast("B"))
                self._count(frame.size)
                return out
            buf = self.recv_payload(frame)
            return np.frombuffer(buf, np.float64)
        if frame.codec == CODEC_SIGN_EF:
            buf = self.recv_payload(frame)
            arr = decode_array_payload(frame, buf)
            if out is not None:
                out[:] = arr
                return out
            return arr
        raise WireError(f"unknown payload codec {frame.codec}")

    def close(self) -> None:
        try:
            self.sock.shutdown(socket.SHUT_RDWR)
        except OSError:
            pass
        self.sock.close()


def decode_array_payload(frame: Frame, buf) -> np.ndarray:
    """Decode a fully-received sign_ef payload buffer (shared by
    ``Link.recv_array`` and the p2p round engine, which fills its own
    buffers on non-blocking sockets)."""
    if frame.flags <= 1:
        return sign_ef_decode_np(buf)
    mv = memoryview(buf)                # per-segment scales (see send_array)
    parts, off = [], 0
    for _ in range(frame.flags):
        n_i = int(np.frombuffer(mv[off:off + 8], np.uint64)[0])
        nb = sign_ef_wire_nbytes(n_i)
        parts.append(sign_ef_decode_np(mv[off:off + nb]))
        off += nb
    return np.concatenate(parts)


# ---------------------------------------------------------------------------
# link micro-benchmark — the measured α–β of a real socket pair, reported by
# ``ps.calibrate`` for the DES comparison (the emulated-wire deadline pacing
# COMPOSES with this: pacing sleeps only the excess over the real transfer).
# ---------------------------------------------------------------------------

def measure_link(host: str = "127.0.0.1", reps: int = 40,
                 big_bytes: int = 4_000_000) -> tuple[float, float]:
    """(alpha_s, beta_s_per_byte) of a loopback/host TCP link, measured with
    this module's own framing: α from small-frame round-trips, β from a
    one-way big-buffer transfer."""
    srv = socket.socket()
    srv.bind((host, 0))
    srv.listen(1)
    port = srv.getsockname()[1]
    out = {}

    def _echo():
        conn, _ = srv.accept()
        link = Link(conn)
        small = np.zeros(8, np.float64)
        for _ in range(reps):
            f = link.recv_header()
            link.recv_array(f, small)
            link.send_array(WEIGHTS, small)
        f = link.recv_header()
        big = link.recv_array(f)
        out["big_ok"] = big.size
        link.send_simple(BYE)
        link.close()

    th = threading.Thread(target=_echo, daemon=True)
    th.start()
    cli = Link(socket.create_connection((host, port), timeout=10))
    small = np.zeros(8, np.float64)
    cli.send_array(WEIGHTS, small)          # warm the path
    cli.recv_array(cli.recv_header(), small)
    t0 = time.perf_counter()
    for _ in range(reps - 1):
        cli.send_array(WEIGHTS, small)
        cli.recv_array(cli.recv_header(), small)
    alpha = (time.perf_counter() - t0) / (reps - 1) / 2   # one-way
    big = np.zeros(big_bytes // 8, np.float64)
    t0 = time.perf_counter()
    cli.send_array(GRAD, big)
    f = cli.recv_header()                   # BYE: peer finished reading
    cli.recv_discard(f)
    beta = (time.perf_counter() - t0 - alpha) / big_bytes
    cli.close()
    srv.close()
    th.join(timeout=5)
    return max(alpha, 1e-7), max(beta, 1e-12)
