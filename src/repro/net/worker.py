"""The repro.net worker: a thin gradient client, runnable on any host.

    PYTHONPATH=src python -m repro.net.worker --connect HOST:PORT --wid 0

Deliberately minimal: numpy, the wire, and the problem factory named by the
master's WELCOME — no jax, no optimizer state beyond what τ>1 local steps
need. All concurrency disciplines look identical from here (the master
decides when WEIGHTS arrive):

    HELLO → WELCOME (problem spec + algorithm + τ) → build + warmup → READY
    then per exchange:  recv WEIGHTS → [τ−1 local steps] → grad → send GRAD
    until DONE → BYE.

A background thread heartbeats every ``hb_interval_s`` so the master can
tell a slow gradient from a dead host. With τ>1 the worker's local (w, v)
evolve between exchanges (``easgd_flat.local_step`` — the same rule the
shared-memory transports run), so frames stack [w|v] down and [grad|w|v]
up; sync_easgd instead posts its evolved weights (WSTATE) BEFORE computing
the exchange gradient, keeping the master's allreduce overlapped with
compute (paper §6.1.3).
"""
from __future__ import annotations

import argparse
import importlib
import socket
import sys
import threading
import time
from types import SimpleNamespace

import numpy as np

from repro.core import easgd_flat
from repro.net import wire
from repro.net.wire import Link

SYNC = easgd_flat.SYNC_FAMILY


def _connect(host: str, port: int, timeout_s: float = 30.0) -> socket.socket:
    deadline = time.monotonic() + timeout_s
    while True:
        try:
            return socket.create_connection((host, port), timeout=10)
        except OSError:
            if time.monotonic() > deadline:
                raise
            time.sleep(0.2)


def _build_problem(factory: str, kwargs):
    mod_name, fn_name = factory.split(":")
    fn = getattr(importlib.import_module(mod_name), fn_name)
    return fn(**dict((k, v) for k, v in kwargs))


def worker_loop(host: str, port: int, wid: int,
                token: str = "repro-net", timeout_s: float = 600.0) -> None:
    link = Link(_connect(host, port))
    link.sock.settimeout(timeout_s)
    link.send_json(wire.HELLO, {"wid": wid, "token": token}, wid=wid)
    frame = link.recv_header()
    if frame.ftype == wire.ERROR:
        raise RuntimeError(f"master rejected us: {link.recv_json(frame)}")
    assert frame.ftype == wire.WELCOME, frame
    cfg = link.recv_json(frame)
    link.codec = wire.CODECS[cfg.get("codec", "none")]
    algo, n, tau = cfg["algorithm"], int(cfg["n"]), int(cfg["tau"])
    local_cfg = SimpleNamespace(eta=cfg["eta"], mu=cfg["mu"])
    velocity = easgd_flat.uses_velocity(algo) and algo not in SYNC

    stop_hb = threading.Event()

    def _heartbeat():
        interval = float(cfg.get("hb_interval_s", 2.0))
        while not stop_hb.wait(interval):
            try:
                link.send_simple(wire.HEARTBEAT, wid=wid)
            except OSError:
                return

    # heartbeat from BEFORE the problem build: a slow build (jax import +
    # jit in a fresh interpreter) must read as alive, not silent
    hb = threading.Thread(target=_heartbeat, daemon=True)
    hb.start()

    _, grad_fn, _ = _build_problem(cfg["factory"], cfg["kwargs"])
    w = np.zeros(n)
    v = np.zeros(n) if velocity else None
    down = np.zeros(2 * n) if (velocity and tau > 1) else w
    for k in range(int(cfg.get("warmup", 2))):   # private RNG streams ≤ −2:
        grad_fn(w, k, -(wid + 2))                # worker streams untouched
    link.send_simple(wire.READY, wid=wid)

    step = 0
    try:
        while True:
            frame = link.recv_header()
            if frame.ftype == wire.DONE:
                link.recv_discard(frame)
                link.send_simple(wire.BYE, wid=wid)
                return
            if frame.ftype == wire.ERROR:
                raise RuntimeError(
                    f"master error: {link.recv_json(frame)}")
            assert frame.ftype == wire.WEIGHTS, frame
            link.recv_array(frame, down)
            if down is not w:
                w[:] = down[:n]
                v[:] = down[n:]
            for _ in range(tau - 1):             # τ−1 local-only steps
                grad = grad_fn(w, step, wid)
                easgd_flat.local_step(algo, w, v if velocity else w,
                                      grad, local_cfg)
                step += 1
            if algo == "sync_easgd" and tau > 1:
                # post evolved weights FIRST: the master's allreduce
                # overlaps the gradient we are about to compute
                link.send_array(wire.WSTATE, w, wid=wid)
            grad = grad_fn(w, step, wid)
            step += 1
            if tau > 1 and algo not in SYNC:
                # stacked upload: one frame, but each segment keeps its own
                # sign-EF scale/state (grad and weight magnitudes must not
                # share a quantization scale)
                up = (np.concatenate([grad, w, v]) if velocity
                      else np.concatenate([grad, w]))
                link.send_array(wire.GRAD, up, wid=wid,
                                segments=3 if velocity else 2)
            else:
                link.send_array(wire.GRAD, grad, wid=wid)
    except BaseException as exc:                 # noqa: BLE001 — tell master
        try:
            link.send_json(wire.ERROR, {"msg": repr(exc)}, wid=wid)
        except OSError:
            pass
        raise
    finally:
        stop_hb.set()
        link.close()


def burn_main(spec_json: str, samples: int, wid: int) -> None:
    """Calibration burner: the EXACT worker substrate (same interpreter,
    same jax-free import footprint), measuring its own per-gradient wall
    period while its siblings run. Protocol: build+warm, print "R", wait
    for a line on stdin (the gate), burn, print the per-grad seconds.
    ``ps.calibrate`` uses the median across burners as the tcp transport's
    concurrent compute rate."""
    import json
    spec = json.loads(spec_json)
    w0, grad_fn, _ = _build_problem(spec["factory"], spec["kwargs"])
    w = np.asarray(w0, np.float64).copy()
    for k in range(5):
        grad_fn(w, k, -(wid + 2))
    print("R", flush=True)
    sys.stdin.readline()
    t0 = time.perf_counter()
    for k in range(samples):
        grad_fn(w, k, -(wid + 2))
    print((time.perf_counter() - t0) / samples, flush=True)


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--connect", default=None, metavar="HOST:PORT")
    ap.add_argument("--wid", type=int, required=True)
    ap.add_argument("--token", default="repro-net")
    ap.add_argument("--timeout", type=float, default=600.0)
    ap.add_argument("--burn", default=None, metavar="SPEC_JSON",
                    help="calibration mode: measure this interpreter's "
                         "concurrent gradient rate instead of training")
    ap.add_argument("--samples", type=int, default=20)
    args = ap.parse_args(argv)
    if args.burn is not None:
        burn_main(args.burn, args.samples, args.wid)
        return
    if args.connect is None:
        ap.error("--connect is required (unless --burn)")
    host, port = args.connect.rsplit(":", 1)
    worker_loop(host, int(port), args.wid, token=args.token,
                timeout_s=args.timeout)


if __name__ == "__main__":
    main()
