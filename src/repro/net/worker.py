"""The repro.net worker: a thin gradient client, runnable on any host.

    PYTHONPATH=src python -m repro.net.worker --connect HOST:PORT --wid 0

Deliberately minimal: numpy, the wire, and the problem factory named by the
master's WELCOME — no jax, no optimizer state beyond what τ>1 local steps
need. Under the MASTER sync plane all concurrency disciplines look
identical from here (the master decides when WEIGHTS arrive):

    HELLO → WELCOME (problem spec + algorithm + τ) → build + warmup → READY
    then per exchange:  recv WEIGHTS → [τ−1 local steps] → grad → send GRAD
    until DONE → BYE.

Under the P2P sync plane (``PSConfig.sync_plane="p2p"``, sync family only)
the worker IS the data plane: it opens a peer listener before HELLO and
advertises it, receives the peer directory + the registry's resolved
``Schedule.rounds`` in WELCOME, wires a ``net.peer.PeerMesh`` to every peer
its rounds talk to, and then trains WITHOUT per-round master traffic —
each exchange executes the rounds over direct worker↔worker SEGMENT
frames, every worker advancing its own bitwise-identical center replica
(see net/peer.py for why the rows agree). The master link carries only
control traffic plus worker 0's CENTER reports at eval rounds and one
final WSTATE per worker, so the Θ(P·N) master incast of the centralized
plane collapses to Θ(N_center).

A background thread heartbeats every ``hb_interval_s`` so the master can
tell a slow gradient from a dead host. With τ>1 the worker's local (w, v)
evolve between exchanges (``easgd_flat.local_step`` — the same rule the
shared-memory transports run), so frames stack [w|v] down and [grad|w|v]
up; sync_easgd instead posts its evolved weights (WSTATE) BEFORE computing
the exchange gradient, keeping the master's allreduce overlapped with
compute (paper §6.1.3) — in p2p mode the same overlap is preserved by
running the round executor in a background thread while the exchange
gradient is computed.
"""
from __future__ import annotations

import argparse
import importlib
import os
import socket
import sys
import threading
import time
from types import SimpleNamespace

import numpy as np

from repro.core import easgd_flat
from repro.ft.watchdog import Watchdog
from repro.net import wire
from repro.net.peer import PeerMesh
from repro.net.wire import Link, sleep_until
from repro.obs import clock as obs_clock
from repro.obs import trace as obs_trace

SYNC = easgd_flat.SYNC_FAMILY


def _connect(host: str, port: int, timeout_s: float = 30.0) -> socket.socket:
    deadline = time.monotonic() + timeout_s
    while True:
        try:
            return socket.create_connection((host, port), timeout=10)
        except OSError:
            if time.monotonic() > deadline:
                raise
            time.sleep(0.2)


def _build_problem(factory: str, kwargs):
    mod_name, fn_name = factory.split(":")
    fn = getattr(importlib.import_module(mod_name), fn_name)
    return fn(**dict((k, v) for k, v in kwargs))


def _drain_after_bye(link: Link, timeout_s: float = 5.0) -> None:
    """After a mid-run BYE, read (and discard) until the master hangs up.
    Closing our end first with unread frames in the receive buffer would
    RST the connection and can destroy the master's still-unread BYE —
    the clean departure would then look like a dead socket."""
    try:
        link.sock.settimeout(timeout_s)
        while True:
            link.recv_discard(link.recv_header())
    except (OSError, wire.WireError):
        pass


def worker_loop(host: str, port: int, wid: int,
                token: str = "repro-net", timeout_s: float = 600.0,
                peer_host: str | None = None, peer_port: int = 0,
                sync_plane: str = "auto",
                heartbeat_file: str | None = None) -> None:
    # preemption plane: SIGTERM/SIGINT set a flag the train loops poll at
    # exchange boundaries — the worker then flushes its trace/telemetry in
    # a clean BYE instead of vanishing mid-frame. The optional heartbeat
    # file lets an external supervisor (launch/cluster --heartbeat-file)
    # tell a hung interpreter from a slow one.
    wd = Watchdog(heartbeat_path=heartbeat_file, interval_s=2.0)
    wd.start_heartbeat()
    link = Link(_connect(host, port))
    link.sock.settimeout(timeout_s)
    # the peer listener binds BEFORE HELLO so its port can ride in it
    # (sync_plane="master" skips it — no point advertising a dead port).
    # It binds to the interface the master link runs over — a loopback-only
    # run must not expose worker listeners on every interface — and
    # advertises that same address unless --peer-host overrides it.
    local_addr = link.sock.getsockname()[0]
    mesh = (PeerMesh(wid, token, bind_host=peer_host or local_addr,
                     port=peer_port, timeout_s=timeout_s)
            if sync_plane != "master" else None)
    hello = {"wid": wid, "token": token}
    if mesh is not None:
        hello["peer"] = [peer_host or local_addr, mesh.port]
    link.send_json(wire.HELLO, hello, wid=wid)
    frame = link.recv_header()
    if frame.ftype == wire.ERROR:
        raise RuntimeError(f"master rejected us: {link.recv_json(frame)}")
    assert frame.ftype == wire.WELCOME, frame
    cfg = link.recv_json(frame)
    link.codec = wire.CODECS[cfg.get("codec", "none")]
    algo, n, tau = cfg["algorithm"], int(cfg["n"]), int(cfg["tau"])
    local_cfg = SimpleNamespace(eta=cfg["eta"], mu=cfg["mu"],
                                rho=cfg.get("rho", 0.0),
                                alpha=cfg["eta"] * cfg.get("rho", 0.0))
    velocity = easgd_flat.uses_velocity(algo) and algo not in SYNC
    p2p = cfg.get("sync_plane") == "p2p"
    if p2p and mesh is None:
        raise RuntimeError(
            "master runs sync_plane=p2p but this worker was started with "
            "--sync-plane master (no peer listener to join the mesh with)")
    if not p2p and mesh is not None:
        mesh.close()                             # advertised, never needed
        mesh = None

    # tracing rides in WELCOME; the clock handshake runs NOW, while the
    # link is otherwise quiet (CLOCK replies are the only inbound frames
    # between WELCOME and the first WEIGHTS), so rtt is measured clean
    tracing = bool(cfg.get("trace"))
    trace_dir = cfg.get("trace_dir") or None
    tr = obs_trace.tracer("main", wid=wid) if tracing else None
    clk = obs_clock.sync_over_link(link, wid=wid) if tracing else None
    telem = {"iters": 0, "rate_ips": 0.0, "exposed_s": 0.0}
    t_start = time.perf_counter()

    stop_hb = threading.Event()

    def _heartbeat():
        interval = float(cfg.get("hb_interval_s", 2.0))
        while not stop_hb.wait(interval):
            try:
                # liveness + telemetry in one frame: current iteration
                # count, smoothed rate, and exposed comm so far — the
                # master keeps the last sample per worker
                el = max(time.perf_counter() - t_start, 1e-9)
                link.send_json(wire.HEARTBEAT, {
                    "iters": telem["iters"],
                    "rate_ips": round(telem["iters"] / el, 2),
                    "exposed_s": round(telem["exposed_s"], 4),
                }, wid=wid)
            except OSError:
                return

    def _trace_payload():
        threads = {"main": tr.spans()}
        for t in obs_trace.drain():
            if t is not tr and t.wid == wid:
                threads[t.name] = t.spans()
        return {"clock": clk.to_wire(), "threads": threads,
                "dropped": tr.dropped}

    def _bye_stats(stats: dict) -> dict:
        if not tracing:
            return stats
        payload = _trace_payload()
        if trace_dir:
            stats["trace_file"] = obs_trace.dump_spill(
                trace_dir, wid, payload)
        else:
            stats["trace"] = payload
        stats["clock"] = clk.to_wire()
        return stats

    # heartbeat from BEFORE the problem build: a slow build (jax import +
    # jit in a fresh interpreter) must read as alive, not silent
    hb = threading.Thread(target=_heartbeat, daemon=True)
    hb.start()

    w0, grad_fn, _ = _build_problem(cfg["factory"], cfg["kwargs"])
    w = np.zeros(n)
    v = np.zeros(n) if velocity else None
    down = np.zeros(2 * n) if (velocity and tau > 1) else w
    for k in range(int(cfg.get("warmup", 2))):   # private RNG streams ≤ −2:
        grad_fn(w, k, -(wid + 2))                # worker streams untouched
    try:
        if p2p:
            _p2p_sync_loop(link, mesh, cfg, grad_fn,
                           np.asarray(w0, np.float64), wid, local_cfg,
                           tr=tr, telem=telem, bye_wrap=_bye_stats,
                           watchdog=wd)
            return
    except BaseException as exc:                 # noqa: BLE001 — tell master
        try:
            link.send_json(wire.ERROR, {"msg": repr(exc)}, wid=wid)
        except OSError:
            pass
        raise
    finally:
        if p2p:
            stop_hb.set()
            wd.close()
            if mesh is not None:
                mesh.close()
            link.close()
    link.send_simple(wire.READY, wid=wid)

    step = 0
    _pc = time.perf_counter
    try:
        while True:
            if wd.should_stop.is_set():
                # preempted: flush traces/telemetry and leave cleanly —
                # the master surfaces this as a named worker_left event
                link.send_json(wire.BYE, _bye_stats(
                    {"preempted": True, "iters": telem["iters"]}), wid=wid)
                _drain_after_bye(link)
                return
            if tr is not None:
                t0 = _pc()
            frame = link.recv_header()
            if frame.ftype == wire.DONE:
                link.recv_discard(frame)
                if tracing:
                    link.send_json(wire.BYE, _bye_stats({}), wid=wid)
                else:
                    link.send_simple(wire.BYE, wid=wid)
                return
            if frame.ftype == wire.ERROR:
                raise RuntimeError(
                    f"master error: {link.recv_json(frame)}")
            assert frame.ftype == wire.WEIGHTS, frame
            link.recv_array(frame, down)
            if tr is not None:
                # blocked on the master's WEIGHTS: exposed communication
                t1 = _pc()
                tr.record(obs_trace.RECV_WAIT, t0, t1)
                telem["exposed_s"] += t1 - t0
                t0 = t1
            if down is not w:
                w[:] = down[:n]
                v[:] = down[n:]
            for _ in range(tau - 1):             # τ−1 local-only steps
                grad = grad_fn(w, step, wid)
                easgd_flat.local_step(algo, w, v if velocity else w,
                                      grad, local_cfg)
                step += 1
            if tr is not None and tau > 1:
                tr.record(obs_trace.LOCAL_STEP, t0, (t0 := _pc()), tau - 1)
            if algo == "sync_easgd" and tau > 1:
                # post evolved weights FIRST: the master's allreduce
                # overlaps the gradient we are about to compute
                link.send_array(wire.WSTATE, w, wid=wid)
            grad = grad_fn(w, step, wid)
            step += 1
            if tr is not None:
                tr.record(obs_trace.COMPUTE, t0, _pc())
            telem["iters"] = step
            if tau > 1 and algo not in SYNC:
                # stacked upload: one frame, but each segment keeps its own
                # sign-EF scale/state (grad and weight magnitudes must not
                # share a quantization scale)
                up = (np.concatenate([grad, w, v]) if velocity
                      else np.concatenate([grad, w]))
                link.send_array(wire.GRAD, up, wid=wid,
                                segments=3 if velocity else 2)
            else:
                link.send_array(wire.GRAD, grad, wid=wid)
    except BaseException as exc:                 # noqa: BLE001 — tell master
        try:
            link.send_json(wire.ERROR, {"msg": repr(exc)}, wid=wid)
        except OSError:
            pass
        raise
    finally:
        stop_hb.set()
        wd.close()
        link.close()


def _p2p_sync_loop(link: Link, mesh: PeerMesh, cfg: dict, grad_fn,
                   w0: np.ndarray, wid: int, local_cfg,
                   tr=None, telem=None, bye_wrap=None,
                   watchdog=None) -> None:
    """The p2p sync family: this worker executes its share of the
    registry's rounds over the peer mesh and advances its OWN center
    replica — bitwise in lockstep with every other worker and with the
    centralized planes (same ops on bitwise-equal rows, see net/peer.py).
    The master link goes quiet between READY and DONE except for worker
    0's CENTER reports at the eval rounds shipped in WELCOME.

    With ``bucket_bounds`` in WELCOME the exchange streams the row as
    per-layer-group buckets and PIPELINES comm with compute: the mesh's
    ``on_bucket`` hook hands completed buckets to this thread, which
    applies bucket b's elastic update while bucket b+1 is still on the
    wire. Bucket updates are elementwise on disjoint slices in schedule
    order, so the iterates stay bitwise-identical to the monolithic path
    — overlap moves time, never math. ``overlap=False`` runs the same
    bucketed exchange inline first (the paper's no-overlap baseline);
    ``update_backend="pallas"`` applies each bucket through the fused
    elastic-update kernel instead of easgd_flat (still bitwise — see
    kernels/elastic_update.py for the ISA pin that makes it so)."""
    import queue as _queue

    from repro.comm.rounds import peer_pairs, rounds_from_wire

    algo, n, tau = cfg["algorithm"], int(cfg["n"]), int(cfg["tau"])
    P, padded = int(cfg["p"]), int(cfg["padded"])
    n_rounds = int(cfg["n_rounds"])
    eval_rounds = set(int(k) for k in cfg["eval_rounds"])
    t_wire = float(cfg.get("t_wire_s", 0.0))
    bounds = cfg.get("bucket_bounds") or None
    overlap = bool(cfg.get("overlap", True))
    backend = cfg.get("update_backend", "numpy")
    t_bucket = [float(x) for x in (cfg.get("t_wire_bucket_s") or [])]
    rounds = rounds_from_wire(cfg["rounds"])
    directory = {int(k): v for k, v in cfg["peers"].items()}
    mesh.codec = cfg.get("codec", "none")
    mesh.connect(directory, peer_pairs(rounds))
    mesh.set_rounds(rounds, padded, boundaries=bounds)

    fused_easgd = fused_sgd = None
    if backend == "pallas":
        # first jax import in this (otherwise jax-free) process: pin the
        # CPU backend to a no-FMA ISA so the fused kernel stays BITWISE
        # equal to easgd_flat (XLA contracts a*b+c to fma otherwise);
        # worker_env ships the same flags, setdefault keeps them
        if "jax" not in sys.modules:
            os.environ.setdefault("JAX_PLATFORMS", "cpu")
            os.environ.setdefault("XLA_FLAGS", "--xla_cpu_max_isa=SSE4_2")
        # importlib: the kernels package re-exports an `elastic_update`
        # FUNCTION that shadows the submodule on attribute-style imports
        _fk = importlib.import_module("repro.kernels.elastic_update")
        fused_easgd = _fk.fused_sync_easgd_update
        fused_sgd = _fk.fused_sync_sgd_update
    link.send_simple(wire.READY, wid=wid)        # mesh up, clock may start

    w = w0.copy()                  # same bits as the master's problem build
    center = w0.copy()             # the center replica (all workers agree)
    vel = np.zeros(n)              # sync_sgd's master velocity replica
    row = np.zeros(padded)         # this worker's mailbox row
    exc_box: list = []
    done_q: _queue.SimpleQueue = _queue.SimpleQueue()
    n_buckets = mesh.n_buckets
    # update slices: bucket spans clamped to the real row (beyond n is pad)
    u_spans = [(a, min(b, n)) for a, b in zip(mesh.boundaries[:-1],
                                              mesh.boundaries[1:])]
    pace = t_bucket if len(t_bucket) == n_buckets else None
    comm_s = exposed_s = 0.0                     # overlap accounting
    _pc = time.perf_counter
    tr_comm = obs_trace.tracer("comm", wid=wid) if tr is not None else None
    mesh.tracer = tr_comm                        # per-bucket wire spans

    def _on_bucket(bidx, deadlines):
        if deadlines is not None:                # serialized-wire pacing:
            sleep_until(deadlines[bidx])         # bucket lands on schedule
        done_q.put(bidx)

    def _exchange():
        nonlocal comm_s
        t0 = _pc()
        try:
            start = time.monotonic()
            deadlines = ([start + sum(t_bucket[:i + 1])
                          for i in range(n_buckets)] if pace else None)
            mesh.execute_exchange(
                row, on_bucket=lambda b: _on_bucket(b, deadlines))
            if t_wire and deadlines is None:
                sleep_until(start + t_wire)
        except BaseException as e:               # noqa: BLE001 — re-raised
            exc_box.append(e)
            done_q.put(None)                     # unblock the update loop
        finally:
            t1 = _pc()
            comm_s += t1 - t0
            if tr_comm is not None:
                tr_comm.record(obs_trace.EXCHANGE, t0, t1)

    def _apply_easgd(bidx, grad):
        a, b = u_spans[bidx]
        if a >= b:
            return
        if fused_easgd is not None:
            w[a:b], center[a:b] = fused_easgd(
                w[a:b], grad[a:b], center[a:b], row[a:b], P,
                local_cfg.eta, local_cfg.rho)
        else:
            easgd_flat.worker_step(algo, w[a:b], vel[a:b], grad[a:b],
                                   center[a:b], local_cfg)
            easgd_flat.sync_master_easgd(center[a:b], row[a:b] / P, P,
                                         local_cfg)

    def _apply_sgd(bidx):
        a, b = u_spans[bidx]
        if a >= b:
            return
        if fused_sgd is not None:
            center[a:b], vel[a:b] = fused_sgd(
                center[a:b], vel[a:b], row[a:b], P,
                local_cfg.eta, local_cfg.mu)
        else:
            easgd_flat.sync_master_sgd(center[a:b], vel[a:b],
                                       row[a:b] / P, local_cfg)

    def _drain(apply_fn):
        """Apply each bucket's update as it lands; time blocked on the
        wire is the EXPOSED communication this pipeline exists to hide."""
        nonlocal exposed_s
        for _ in range(n_buckets):
            t0 = time.perf_counter()
            bidx = done_q.get()
            t1 = time.perf_counter()
            exposed_s += t1 - t0
            if bidx is None:
                break
            if tr is not None:
                tr.record(obs_trace.BUCKET_WAIT, t0, t1, bidx)
            apply_fn(bidx)
            if tr is not None:
                tr.record(obs_trace.UPDATE, t1, time.perf_counter(), bidx)

    def _join_comm(comm):
        """Wait out the comm thread's tail — exposed by definition."""
        nonlocal exposed_s
        t0 = time.perf_counter()
        comm.join()
        t1 = time.perf_counter()
        exposed_s += t1 - t0
        if tr is not None:
            tr.record(obs_trace.COMM_WAIT, t0, t1)

    def _exchange_inline():
        """No-overlap baseline: the whole wire is exposed."""
        nonlocal exposed_s
        t0 = time.perf_counter()
        _exchange()
        t1 = time.perf_counter()
        exposed_s += t1 - t0
        if tr is not None:
            tr.record(obs_trace.COMM_WAIT, t0, t1)

    def _grad_traced(step):
        t0 = time.perf_counter()
        g = grad_fn(w, step, wid)
        if tr is not None:
            tr.record(obs_trace.COMPUTE, t0, time.perf_counter())
        return g

    step = 0
    for k in range(n_rounds):
        if watchdog is not None and watchdog.should_stop.is_set():
            # preempted between rounds: the mesh is only safe to leave at
            # a round boundary (peers block on our segments mid-exchange)
            stats = {"preempted": True, "iters": step}
            if bye_wrap is not None:
                stats = bye_wrap(stats)
            link.send_json(wire.BYE, stats, wid=wid)
            _drain_after_bye(link)
            return
        if tau > 1:
            t0 = time.perf_counter()
            for _ in range(tau - 1):             # τ−1 local-only steps
                g = grad_fn(w, step, wid)
                easgd_flat.local_step(algo, w, vel, g, local_cfg)
                step += 1
            if tr is not None:
                tr.record(obs_trace.LOCAL_STEP, t0, time.perf_counter(),
                          tau - 1)
        if algo == "sync_easgd":
            row[:n] = w                          # start-of-exchange weights
            if overlap:
                comm = threading.Thread(target=_exchange)
                comm.start()                     # buckets fly while the
                grad = _grad_traced(step)        # gradient computes
                step += 1                        # (paper §6.1.3)
                _drain(lambda b: _apply_easgd(b, grad))
                _join_comm(comm)
            else:
                _exchange_inline()
                grad = _grad_traced(step)
                step += 1
                _drain(lambda b: _apply_easgd(b, grad))
            if exc_box:
                raise exc_box[0]
        else:                                    # sync_sgd: grads first, so
            grad = _grad_traced(step)            # only the per-bucket master
            step += 1                            # update overlaps (§5.1)
            row[:n] = grad
            if overlap:
                comm = threading.Thread(target=_exchange)
                comm.start()
                _drain(_apply_sgd)
                _join_comm(comm)
            else:
                _exchange_inline()
                _drain(_apply_sgd)
            if exc_box:
                raise exc_box[0]
            w[:] = center
        if telem is not None:
            telem["iters"] = step
            telem["exposed_s"] = exposed_s
            telem["comm_s"] = comm_s
        if wid == 0 and k in eval_rounds:
            # control-plane reports go RAW even under wire compression:
            # these are one-shot exact-state transfers, not a stream error
            # feedback could correct over time
            link.send_array(wire.CENTER, center, wid=wid, raw=True)
    if wid == 0:                                 # the final center update —
        link.send_array(wire.CENTER, center, wid=wid,   # Θ(N), not Θ(P·N)
                        raw=True)
    link.send_array(wire.WSTATE, w, wid=wid, raw=True)  # final weights
    stats = mesh.stats()
    stats.update({"comm_s": comm_s, "exposed_s": exposed_s,
                  "overlapped_s": max(0.0, comm_s - exposed_s),
                  "overlap": overlap, "update_backend": backend})
    if bye_wrap is not None:
        stats = bye_wrap(stats)
    while True:                                  # control plane: DONE → BYE
        frame = link.recv_header()
        if frame.ftype == wire.DONE:
            link.recv_discard(frame)
            link.send_json(wire.BYE, stats, wid=wid)
            return
        if frame.ftype == wire.ERROR:
            raise RuntimeError(f"master error: {link.recv_json(frame)}")
        link.recv_discard(frame)


def burn_main(spec_json: str, samples: int, wid: int) -> None:
    """Calibration burner: the EXACT worker substrate (same interpreter,
    same jax-free import footprint), measuring its own per-gradient wall
    period while its siblings run. Protocol: build+warm, print "R", wait
    for a line on stdin (the gate), burn, print the per-grad seconds.
    ``ps.calibrate`` uses the median across burners as the tcp transport's
    concurrent compute rate."""
    import json
    spec = json.loads(spec_json)
    w0, grad_fn, _ = _build_problem(spec["factory"], spec["kwargs"])
    w = np.asarray(w0, np.float64).copy()
    for k in range(5):
        grad_fn(w, k, -(wid + 2))
    print("R", flush=True)
    sys.stdin.readline()
    t0 = time.perf_counter()
    for k in range(samples):
        grad_fn(w, k, -(wid + 2))
    print((time.perf_counter() - t0) / samples, flush=True)


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--connect", default=None, metavar="HOST:PORT")
    ap.add_argument("--wid", type=int, required=True)
    ap.add_argument("--token", default="repro-net")
    ap.add_argument("--timeout", type=float, default=600.0)
    ap.add_argument("--sync-plane", default="auto",
                    choices=["auto", "master", "p2p"],
                    help="auto/p2p: open a peer listener and advertise it "
                         "in HELLO (the master's WELCOME decides whether "
                         "the p2p data plane is used); master: skip it")
    ap.add_argument("--peer-port", type=int, default=0,
                    help="fixed bind port for the peer listener (multi-host "
                         "p2p behind firewalls; 0 = ephemeral)")
    ap.add_argument("--peer-host", default=None,
                    help="address to advertise for the peer listener "
                         "(default: the local endpoint of the master link)")
    ap.add_argument("--heartbeat-file", default=None,
                    help="touch this file every ~2 s so an external "
                         "supervisor can detect a hung worker "
                         "(ft.Watchdog.is_alive)")
    ap.add_argument("--burn", default=None, metavar="SPEC_JSON",
                    help="calibration mode: measure this interpreter's "
                         "concurrent gradient rate instead of training")
    ap.add_argument("--samples", type=int, default=20)
    args = ap.parse_args(argv)
    if args.burn is not None:
        burn_main(args.burn, args.samples, args.wid)
        return
    if args.connect is None:
        ap.error("--connect is required (unless --burn)")
    host, port = args.connect.rsplit(":", 1)
    worker_loop(host, int(port), args.wid, token=args.token,
                timeout_s=args.timeout, peer_host=args.peer_host,
                peer_port=args.peer_port, sync_plane=args.sync_plane,
                heartbeat_file=args.heartbeat_file)


if __name__ == "__main__":
    main()
