"""The repro.net worker: a thin gradient client, runnable on any host.

    PYTHONPATH=src python -m repro.net.worker --connect HOST:PORT --wid 0

Deliberately minimal: numpy, the wire, and the problem factory named by the
master's WELCOME — no jax, no optimizer state beyond what τ>1 local steps
need. Under the MASTER sync plane all concurrency disciplines look
identical from here (the master decides when WEIGHTS arrive):

    HELLO → WELCOME (problem spec + algorithm + τ) → build + warmup → READY
    then per exchange:  recv WEIGHTS → [τ−1 local steps] → grad → send GRAD
    until DONE → BYE.

Under the P2P sync plane (``PSConfig.sync_plane="p2p"``, sync family only)
the worker IS the data plane: it opens a peer listener before HELLO and
advertises it, receives the peer directory + the registry's resolved
``Schedule.rounds`` in WELCOME, wires a ``net.peer.PeerMesh`` to every peer
its rounds talk to, and then trains WITHOUT per-round master traffic —
each exchange executes the rounds over direct worker↔worker SEGMENT
frames, every worker advancing its own bitwise-identical center replica
(see net/peer.py for why the rows agree). The master link carries only
control traffic plus worker 0's CENTER reports at eval rounds and one
final WSTATE per worker, so the Θ(P·N) master incast of the centralized
plane collapses to Θ(N_center).

A background thread heartbeats every ``hb_interval_s`` so the master can
tell a slow gradient from a dead host. With τ>1 the worker's local (w, v)
evolve between exchanges (``easgd_flat.local_step`` — the same rule the
shared-memory transports run), so frames stack [w|v] down and [grad|w|v]
up; sync_easgd instead posts its evolved weights (WSTATE) BEFORE computing
the exchange gradient, keeping the master's allreduce overlapped with
compute (paper §6.1.3) — in p2p mode the same overlap is preserved by
running the round executor in a background thread while the exchange
gradient is computed.
"""
from __future__ import annotations

import argparse
import importlib
import os
import socket
import sys
import threading
import time
from types import SimpleNamespace

import numpy as np

from repro.core import easgd_flat
from repro.ft import chaos as ft_chaos
from repro.ft.watchdog import Watchdog
from repro.net import wire
from repro.net.peer import MeshAbort, PeerMesh
from repro.net.wire import Link, sleep_until
from repro.obs import clock as obs_clock
from repro.obs import trace as obs_trace

SYNC = easgd_flat.SYNC_FAMILY


def _connect(host: str, port: int, timeout_s: float = 30.0,
             seed: int | None = None, refuse_fn=None) -> socket.socket:
    """Dial the master with jittered exponential backoff and a hard
    deadline (``wire.dial_with_backoff``) — a worker that starts before
    the master's listener, or during a chaos dial-refuse window, absorbs
    the gap instead of crashing the launch."""
    return wire.dial_with_backoff(host, port, deadline_s=timeout_s,
                                  seed=seed, refuse_fn=refuse_fn)


def _build_problem(factory: str, kwargs):
    mod_name, fn_name = factory.split(":")
    fn = getattr(importlib.import_module(mod_name), fn_name)
    return fn(**dict((k, v) for k, v in kwargs))


def _drain_after_bye(link: Link, timeout_s: float = 5.0) -> None:
    """After a mid-run BYE, read (and discard) until the master hangs up.
    Closing our end first with unread frames in the receive buffer would
    RST the connection and can destroy the master's still-unread BYE —
    the clean departure would then look like a dead socket."""
    try:
        link.sock.settimeout(timeout_s)
        while True:
            link.recv_discard(link.recv_header())
    except (OSError, wire.WireError):
        pass


def worker_loop(host: str, port: int, wid: int,
                token: str = "repro-net", timeout_s: float = 600.0,
                peer_host: str | None = None, peer_port: int = 0,
                sync_plane: str = "auto",
                heartbeat_file: str | None = None,
                rejoin: bool = False) -> None:
    # preemption plane: SIGTERM/SIGINT set a flag the train loops poll at
    # exchange boundaries — the worker then flushes its trace/telemetry in
    # a clean BYE instead of vanishing mid-frame. The optional heartbeat
    # file lets an external supervisor (launch/cluster --heartbeat-file)
    # tell a hung interpreter from a slow one.
    wd = Watchdog(heartbeat_path=heartbeat_file, interval_s=2.0)
    wd.start_heartbeat()
    # fault injection (ft.chaos): armed from REPRO_CHAOS, inert otherwise
    chaos = ft_chaos.clock_from_env()
    link = Link(_connect(host, port, timeout_s=min(timeout_s, 30.0),
                         seed=wid,
                         refuse_fn=lambda: chaos.refuse_dial(wid)))
    link.sock.settimeout(timeout_s)
    # the peer listener binds BEFORE HELLO so its port can ride in it
    # (sync_plane="master" skips it — no point advertising a dead port).
    # It binds to the interface the master link runs over — a loopback-only
    # run must not expose worker listeners on every interface — and
    # advertises that same address unless --peer-host overrides it.
    local_addr = link.sock.getsockname()[0]
    mesh = (PeerMesh(wid, token, bind_host=peer_host or local_addr,
                     port=peer_port, timeout_s=timeout_s)
            if sync_plane != "master" else None)
    hello = {"wid": wid, "token": token}
    if mesh is not None:
        hello["peer"] = [peer_host or local_addr, mesh.port]
    if rejoin:
        # respawned mid-run: the master's control acceptor (not the
        # rendezvous) answers this HELLO and folds us in at the next epoch
        hello["rejoin"] = True
    link.send_json(wire.HELLO, hello, wid=wid)
    frame = link.recv_header()
    if frame.ftype == wire.ERROR:
        raise RuntimeError(f"master rejected us: {link.recv_json(frame)}")
    assert frame.ftype == wire.WELCOME, frame
    cfg = link.recv_json(frame)
    link.codec = wire.CODECS[cfg.get("codec", "none")]
    algo, n, tau = cfg["algorithm"], int(cfg["n"]), int(cfg["tau"])
    local_cfg = SimpleNamespace(eta=cfg["eta"], mu=cfg["mu"],
                                rho=cfg.get("rho", 0.0),
                                alpha=cfg["eta"] * cfg.get("rho", 0.0))
    velocity = easgd_flat.uses_velocity(algo) and algo not in SYNC
    p2p = cfg.get("sync_plane") == "p2p"
    if p2p and mesh is None:
        raise RuntimeError(
            "master runs sync_plane=p2p but this worker was started with "
            "--sync-plane master (no peer listener to join the mesh with)")
    if not p2p and mesh is not None:
        mesh.close()                             # advertised, never needed
        mesh = None

    # tracing rides in WELCOME; the clock handshake runs NOW, while the
    # link is otherwise quiet (CLOCK replies are the only inbound frames
    # between WELCOME and the first WEIGHTS), so rtt is measured clean
    tracing = bool(cfg.get("trace"))
    trace_dir = cfg.get("trace_dir") or None
    tr = obs_trace.tracer("main", wid=wid) if tracing else None
    clk = obs_clock.sync_over_link(link, wid=wid) if tracing else None
    telem = {"iters": 0, "rate_ips": 0.0, "exposed_s": 0.0}
    t_start = time.perf_counter()

    stop_hb = threading.Event()

    def _heartbeat():
        interval = float(cfg.get("hb_interval_s", 2.0))
        while not stop_hb.wait(interval):
            try:
                # liveness + telemetry in one frame: current iteration
                # count, smoothed rate, and exposed comm so far — the
                # master keeps the last sample per worker
                el = max(time.perf_counter() - t_start, 1e-9)
                link.send_json(wire.HEARTBEAT, {
                    "iters": telem["iters"],
                    "rate_ips": round(telem["iters"] / el, 2),
                    "exposed_s": round(telem["exposed_s"], 4),
                }, wid=wid)
            except OSError:
                return

    def _trace_payload():
        threads = {"main": tr.spans()}
        for t in obs_trace.drain():
            if t is not tr and t.wid == wid:
                threads[t.name] = t.spans()
        return {"clock": clk.to_wire(), "threads": threads,
                "dropped": tr.dropped}

    def _bye_stats(stats: dict) -> dict:
        if not tracing:
            return stats
        payload = _trace_payload()
        if trace_dir:
            stats["trace_file"] = obs_trace.dump_spill(
                trace_dir, wid, payload)
        else:
            stats["trace"] = payload
        stats["clock"] = clk.to_wire()
        return stats

    # heartbeat from BEFORE the problem build: a slow build (jax import +
    # jit in a fresh interpreter) must read as alive, not silent
    hb = threading.Thread(target=_heartbeat, daemon=True)
    hb.start()

    w0, grad_fn, _ = _build_problem(cfg["factory"], cfg["kwargs"])
    w = np.zeros(n)
    v = np.zeros(n) if velocity else None
    down = np.zeros(2 * n) if (velocity and tau > 1) else w
    for k in range(int(cfg.get("warmup", 2))):   # private RNG streams ≤ −2:
        grad_fn(w, k, -(wid + 2))                # worker streams untouched
    try:
        if p2p:
            _p2p_sync_loop(link, mesh, cfg, grad_fn,
                           np.asarray(w0, np.float64), wid, local_cfg,
                           tr=tr, telem=telem, bye_wrap=_bye_stats,
                           watchdog=wd, chaos=chaos)
            return
    except BaseException as exc:                 # noqa: BLE001 — tell master
        try:
            link.send_json(wire.ERROR, {"msg": repr(exc)}, wid=wid)
        except OSError:
            pass
        raise
    finally:
        if p2p:
            stop_hb.set()
            wd.close()
            if mesh is not None:
                mesh.close()
            link.close()
    link.send_simple(wire.READY, wid=wid)

    step = 0
    _pc = time.perf_counter
    try:
        while True:
            if wd.should_stop.is_set():
                # preempted: flush traces/telemetry and leave cleanly —
                # the master surfaces this as a named worker_left event
                link.send_json(wire.BYE, _bye_stats(
                    {"preempted": True, "iters": telem["iters"]}), wid=wid)
                _drain_after_bye(link)
                return
            chaos.maybe_fire(wid, step)          # deterministic fault point
            if tr is not None:
                t0 = _pc()
            frame = link.recv_header()
            if frame.ftype == wire.DONE:
                link.recv_discard(frame)
                if tracing:
                    link.send_json(wire.BYE, _bye_stats({}), wid=wid)
                else:
                    link.send_simple(wire.BYE, wid=wid)
                return
            if frame.ftype == wire.ERROR:
                raise RuntimeError(
                    f"master error: {link.recv_json(frame)}")
            assert frame.ftype == wire.WEIGHTS, frame
            link.recv_array(frame, down)
            if tr is not None:
                # blocked on the master's WEIGHTS: exposed communication
                t1 = _pc()
                tr.record(obs_trace.RECV_WAIT, t0, t1)
                telem["exposed_s"] += t1 - t0
                t0 = t1
            if down is not w:
                w[:] = down[:n]
                v[:] = down[n:]
            for _ in range(tau - 1):             # τ−1 local-only steps
                grad = grad_fn(w, step, wid)
                easgd_flat.local_step(algo, w, v if velocity else w,
                                      grad, local_cfg)
                step += 1
            if tr is not None and tau > 1:
                tr.record(obs_trace.LOCAL_STEP, t0, (t0 := _pc()), tau - 1)
            if algo == "sync_easgd" and tau > 1:
                # post evolved weights FIRST: the master's allreduce
                # overlaps the gradient we are about to compute
                link.send_array(wire.WSTATE, w, wid=wid)
            grad = grad_fn(w, step, wid)
            step += 1
            if tr is not None:
                tr.record(obs_trace.COMPUTE, t0, _pc())
            telem["iters"] = step
            if tau > 1 and algo not in SYNC:
                # stacked upload: one frame, but each segment keeps its own
                # sign-EF scale/state (grad and weight magnitudes must not
                # share a quantization scale)
                up = (np.concatenate([grad, w, v]) if velocity
                      else np.concatenate([grad, w]))
                link.send_array(wire.GRAD, up, wid=wid,
                                segments=3 if velocity else 2)
            else:
                link.send_array(wire.GRAD, grad, wid=wid)
    except BaseException as exc:                 # noqa: BLE001 — tell master
        try:
            link.send_json(wire.ERROR, {"msg": repr(exc)}, wid=wid)
        except OSError:
            pass
        raise
    finally:
        stop_hb.set()
        wd.close()
        link.close()


def _p2p_sync_loop(link: Link, mesh: PeerMesh, cfg: dict, grad_fn,
                   w0: np.ndarray, wid: int, local_cfg,
                   tr=None, telem=None, bye_wrap=None,
                   watchdog=None, chaos=None) -> None:
    """The p2p sync family: this worker executes its share of the
    registry's rounds over the peer mesh and advances its OWN center
    replica — bitwise in lockstep with every other worker and with the
    centralized planes (same ops on bitwise-equal rows, see net/peer.py).
    The master link goes quiet between READY and DONE except for worker
    0's CENTER reports at the eval rounds shipped in WELCOME.

    With ``bucket_bounds`` in WELCOME the exchange streams the row as
    per-layer-group buckets and PIPELINES comm with compute: the mesh's
    ``on_bucket`` hook hands completed buckets to this thread, which
    applies bucket b's elastic update while bucket b+1 is still on the
    wire. Bucket updates are elementwise on disjoint slices in schedule
    order, so the iterates stay bitwise-identical to the monolithic path
    — overlap moves time, never math. ``overlap=False`` runs the same
    bucketed exchange inline first (the paper's no-overlap baseline);
    ``update_backend="pallas"`` applies each bucket through the fused
    elastic-update kernel instead of easgd_flat (still bitwise — see
    kernels/elastic_update.py for the ISA pin that makes it so).

    Under ``elastic`` (WELCOME flag, from ``PSConfig.elastic``) this loop
    is also the worker half of the membership tentpole (ft.membership): a
    control-reader thread owns the master link's inbound side and routes
    RECONFIGURE/CENTER/DONE/ERROR into a queue; a peer death surfaces as a
    failed exchange (``mesh.reset()`` cascades so every survivor falls out
    fast), a pure join as a flag checked at the round boundary, and both
    enter ``_recover`` — ack the freeze with the rounds completed, roll
    back to the 2-deep start-of-round snapshot the master's agreed
    ``resume_round`` names, rewire the mesh to the new epoch's geometry,
    and continue in the same process. With ``elastic`` off none of this
    machinery exists at runtime (no thread, no snapshots): the happy path
    stays bitwise AND cost-identical to the pre-membership loop."""
    import queue as _queue

    from repro.comm.rounds import peer_pairs, rounds_from_wire

    algo, n, tau = cfg["algorithm"], int(cfg["n"]), int(cfg["tau"])
    P, padded = int(cfg["p"]), int(cfg["padded"])
    n_rounds = int(cfg["n_rounds"])
    eval_rounds = set(int(k) for k in cfg["eval_rounds"])
    t_wire = float(cfg.get("t_wire_s", 0.0))
    bounds = cfg.get("bucket_bounds") or None
    overlap = bool(cfg.get("overlap", True))
    backend = cfg.get("update_backend", "numpy")
    t_bucket = [float(x) for x in (cfg.get("t_wire_bucket_s") or [])]
    rounds = rounds_from_wire(cfg["rounds"])
    directory = {int(k): v for k, v in cfg["peers"].items()}
    elastic = bool(cfg.get("elastic"))
    rejoin = bool(cfg.get("rejoin"))
    reporter = 0                   # lowest live wid sends CENTER reports
    mesh.codec = cfg.get("codec", "none")
    topo_wire = cfg.get("topology")
    if topo_wire and int(topo_wire.get("hosts", 1)) > 1:
        # two-level fabric: label this worker's peer links intra/cross in
        # the BYE stats (pacing itself needs nothing here — the master
        # ships per-wid t_wire_s already priced for OUR links)
        slots = int(topo_wire["slots"])
        mesh.host_of = lambda w: -1 if w < 0 else w // slots
    if not rejoin:
        # a rejoiner holds off: the RECONFIGURE that folds it in names the
        # epoch's actual geometry (the WELCOME's copy is already stale the
        # moment the next membership event lands)
        mesh.connect(directory, peer_pairs(rounds))
        mesh.set_rounds(rounds, padded, boundaries=bounds)

    fused_easgd = fused_sgd = None
    if backend == "pallas":
        # first jax import in this (otherwise jax-free) process: pin the
        # CPU backend to a no-FMA ISA so the fused kernel stays BITWISE
        # equal to easgd_flat (XLA contracts a*b+c to fma otherwise);
        # worker_env ships the same flags, setdefault keeps them
        if "jax" not in sys.modules:
            os.environ.setdefault("JAX_PLATFORMS", "cpu")
            os.environ.setdefault("XLA_FLAGS", "--xla_cpu_max_isa=SSE4_2")
        # importlib: the kernels package re-exports an `elastic_update`
        # FUNCTION that shadows the submodule on attribute-style imports
        _fk = importlib.import_module("repro.kernels.elastic_update")
        fused_easgd = _fk.fused_sync_easgd_update
        fused_sgd = _fk.fused_sync_sgd_update

    # -- elastic control plane: ONE thread owns the master link's inbound
    # side for the whole run (RECONFIGURE can land at any moment, so the
    # main thread can never block on a direct recv) and routes frames into
    # a queue the train loop and the recovery path consume from
    ctrl_q: _queue.SimpleQueue = _queue.SimpleQueue()
    pending_reconf = [0]           # phase-1s seen, not yet consumed (GIL-
    ctrl_th = None                 # atomic int updates, no lock needed)

    def _ctrl_reader():
        try:
            while True:
                frame = link.recv_header()
                if frame.ftype == wire.RECONFIGURE:
                    payload = link.recv_json(frame)
                    if payload.get("phase") == 1:
                        pending_reconf[0] += 1
                    ctrl_q.put(("reconf", payload))
                elif frame.ftype == wire.CENTER:
                    ctrl_q.put(("center", link.recv_array(frame)))
                elif frame.ftype == wire.DONE:
                    link.recv_discard(frame)
                    ctrl_q.put(("done", None))
                    return
                elif frame.ftype == wire.ERROR:
                    ctrl_q.put(("error", link.recv_json(frame)))
                    return
                else:
                    link.recv_discard(frame)
        except (wire.WireError, OSError):
            ctrl_q.put(("dead", None))

    def _ctrl_get():
        kind, payload = ctrl_q.get()
        if kind == "error":
            raise RuntimeError(f"master error: {payload}")
        if kind == "dead":
            raise wire.WireError("master link died mid-run")
        return kind, payload

    if elastic:
        # started BEFORE READY: a rejoiner's READY makes the master fire
        # the folding RECONFIGURE immediately
        ctrl_th = threading.Thread(target=_ctrl_reader, daemon=True)
        ctrl_th.start()
    link.send_simple(wire.READY, wid=wid)        # mesh up, clock may start

    w = w0.copy()                  # same bits as the master's problem build
    center = w0.copy()             # the center replica (all workers agree)
    vel = np.zeros(n)              # sync_sgd's master velocity replica
    row = np.zeros(padded)         # this worker's mailbox row
    exc_box: list = []
    done_q: _queue.SimpleQueue = _queue.SimpleQueue()
    if rejoin:
        n_buckets, u_spans, pace = 0, [], None   # set by the folding epoch
    else:
        n_buckets = mesh.n_buckets
        # update slices: bucket spans clamped to the real row (past n: pad)
        u_spans = [(a, min(b, n)) for a, b in zip(mesh.boundaries[:-1],
                                                  mesh.boundaries[1:])]
        pace = t_bucket if len(t_bucket) == n_buckets else None
    comm_s = exposed_s = 0.0                     # overlap accounting
    _pc = time.perf_counter
    tr_comm = obs_trace.tracer("comm", wid=wid) if tr is not None else None
    mesh.tracer = tr_comm                        # per-bucket wire spans

    def _on_bucket(bidx, deadlines):
        if deadlines is not None:                # serialized-wire pacing:
            sleep_until(deadlines[bidx])         # bucket lands on schedule
        done_q.put(bidx)

    def _exchange():
        nonlocal comm_s
        t0 = _pc()
        try:
            start = time.monotonic()
            deadlines = ([start + sum(t_bucket[:i + 1])
                          for i in range(n_buckets)] if pace else None)
            mesh.execute_exchange(
                row, on_bucket=lambda b: _on_bucket(b, deadlines))
            if t_wire and deadlines is None:
                sleep_until(start + t_wire)
        except BaseException as e:               # noqa: BLE001 — re-raised
            exc_box.append(e)
            done_q.put(None)                     # unblock the update loop
        finally:
            t1 = _pc()
            comm_s += t1 - t0
            if tr_comm is not None:
                tr_comm.record(obs_trace.EXCHANGE, t0, t1)

    def _apply_easgd(bidx, grad):
        a, b = u_spans[bidx]
        if a >= b:
            return
        if fused_easgd is not None:
            w[a:b], center[a:b] = fused_easgd(
                w[a:b], grad[a:b], center[a:b], row[a:b], P,
                local_cfg.eta, local_cfg.rho)
        else:
            easgd_flat.worker_step(algo, w[a:b], vel[a:b], grad[a:b],
                                   center[a:b], local_cfg)
            easgd_flat.sync_master_easgd(center[a:b], row[a:b] / P, P,
                                         local_cfg)

    def _apply_sgd(bidx):
        a, b = u_spans[bidx]
        if a >= b:
            return
        if fused_sgd is not None:
            center[a:b], vel[a:b] = fused_sgd(
                center[a:b], vel[a:b], row[a:b], P,
                local_cfg.eta, local_cfg.mu)
        else:
            easgd_flat.sync_master_sgd(center[a:b], vel[a:b],
                                       row[a:b] / P, local_cfg)

    def _drain(apply_fn):
        """Apply each bucket's update as it lands; time blocked on the
        wire is the EXPOSED communication this pipeline exists to hide."""
        nonlocal exposed_s
        for _ in range(n_buckets):
            t0 = time.perf_counter()
            bidx = done_q.get()
            t1 = time.perf_counter()
            exposed_s += t1 - t0
            if bidx is None:
                break
            if tr is not None:
                tr.record(obs_trace.BUCKET_WAIT, t0, t1, bidx)
            apply_fn(bidx)
            if tr is not None:
                tr.record(obs_trace.UPDATE, t1, time.perf_counter(), bidx)

    def _join_comm(comm):
        """Wait out the comm thread's tail — exposed by definition."""
        nonlocal exposed_s
        t0 = time.perf_counter()
        comm.join()
        t1 = time.perf_counter()
        exposed_s += t1 - t0
        if tr is not None:
            tr.record(obs_trace.COMM_WAIT, t0, t1)

    def _exchange_inline():
        """No-overlap baseline: the whole wire is exposed."""
        nonlocal exposed_s
        t0 = time.perf_counter()
        _exchange()
        t1 = time.perf_counter()
        exposed_s += t1 - t0
        if tr is not None:
            tr.record(obs_trace.COMM_WAIT, t0, t1)

    def _grad_traced(step):
        t0 = time.perf_counter()
        g = grad_fn(w, step, wid)
        if tr is not None:
            tr.record(obs_trace.COMPUTE, t0, time.perf_counter())
        return g

    # start-of-round snapshot ring, kept 2 deep (elastic only): the agreed
    # resume round is the MIN over survivor acks, and the allreduce mesh
    # bounds the completed-round spread to 1 (a worker finishes exchange k
    # only once every peer has entered it), so rolling back ever needs at
    # most the previous boundary
    snaps: dict = {}
    cur_epoch = 0

    def _recover(rounds_done, step_now, failed, first_p1=None,
                 joiner=False):
        """Worker half of the two-phase reconfigure (see server.py's
        ``_reconfigure_p2p``): tear the mesh down, ack phase 1 with the
        rounds fully completed, adopt phase 2's resume round (rolling back
        to its snapshot — or, for a joiner, the state the master relays),
        rewire to the new epoch's geometry, and return (resume, step)."""
        nonlocal P, padded, n_rounds, rounds, row, u_spans, n_buckets, \
            pace, t_wire, t_bucket, eval_rounds, reporter, cur_epoch
        p1 = first_p1
        while True:
            mesh.reset()                 # closes peer links: every survivor
            exc_box.clear()              # still blocked in the doomed
            while True:                  # exchange falls out right away
                try:
                    done_q.get_nowait()
                except _queue.Empty:
                    break
            while p1 is None:
                kind, payload = _ctrl_get()
                if kind == "done":
                    raise RuntimeError("master finished mid-reconfigure")
                if kind == "reconf" and payload.get("phase") == 1:
                    pending_reconf[0] -= 1
                    p1 = payload
            p2 = None
            while p2 is None:
                link.send_json(wire.RECONFIGURE,
                               {"epoch": int(p1["epoch"]),
                                "round": rounds_done,
                                "step": step_now}, wid=wid)
                while True:
                    kind, payload = _ctrl_get()
                    if kind == "done":
                        raise RuntimeError(
                            "master finished mid-reconfigure")
                    if kind != "reconf":
                        continue
                    if payload.get("phase") == 1:
                        # another loss mid-handshake: the master restarted
                        # with a smaller roster — re-ack the fresh epoch
                        pending_reconf[0] -= 1
                        p1 = payload
                        break
                    if int(payload.get("epoch", -1)) == int(p1["epoch"]):
                        p2 = payload
                        break
            resume = int(p2["resume_round"])
            if pending_reconf[0] > 0:
                # a fresh phase 1 is already queued (loss after phase 2
                # went out) — don't wire a doomed mesh, restart instead
                p1 = None
                continue
            # -- state: roll back, upload, or adopt -------------------------
            if joiner:
                arr = None
                while arr is None:
                    kind, payload = _ctrl_get()
                    if kind == "center":     # the relayed sync_wid state
                        arr = payload
                    elif kind == "done":
                        raise RuntimeError(
                            "master finished mid-reconfigure")
                center[:] = arr[:n]
                vel[:] = arr[n:2 * n] if arr.size >= 2 * n else 0.0
                w[:] = center
                step_now = resume * tau  # the survivors' step at resume
            else:
                if failed or resume != rounds_done:
                    try:
                        sw, sv, sc, sstep = snaps[resume]
                    except KeyError:
                        raise RuntimeError(
                            f"elastic: no snapshot for resume round "
                            f"{resume} (have {sorted(snaps)})") from None
                    w[:], vel[:], center[:] = sw, sv, sc
                    step_now = sstep
                if p2.get("upload_state") and wid == int(p1["sync_wid"]):
                    # lowest previous survivor: ship the rolled-back state
                    # so joiners enter with the exact center (and vel) bits
                    state = (center if algo == "sync_easgd"
                             else np.concatenate([center, vel]))
                    link.send_array(wire.CENTER, state, wid=-2, raw=True)
            # -- adopt the new epoch's geometry -----------------------------
            cur_epoch = int(p1["epoch"])
            P, padded = int(p1["p"]), int(p1["padded"])
            n_rounds = int(p1["n_rounds"])
            rounds = rounds_from_wire(p1["rounds"])
            t_wire = float(p1.get("t_wire_s", 0.0))
            t_bucket = [float(x) for x in (p1.get("t_wire_bucket_s") or [])]
            eval_rounds = set(int(x) for x in p2["eval_rounds"])
            reporter = int(p1["reporter"])
            row = np.zeros(padded)
            if resume < n_rounds:        # exchanges remain: rewire
                new_dir = {int(x): a for x, a in p1["peers"].items()}
                mesh.connect(new_dir, peer_pairs(rounds))
                mesh.set_rounds(rounds, padded,
                                boundaries=p1.get("bucket_bounds") or None)
                n_buckets = mesh.n_buckets
                u_spans = [(a, min(b, n))
                           for a, b in zip(mesh.boundaries[:-1],
                                           mesh.boundaries[1:])]
                pace = t_bucket if len(t_bucket) == n_buckets else None
            snaps.clear()                # pre-epoch snapshots are stale
            return resume, step_now

    step = 0
    k = 0
    if rejoin:
        # a respawn enters through recovery: ack round −1 (it is not a
        # previous-epoch survivor, so its ack never constrains the resume
        # round), adopt the relayed state, and start at the resume round
        k, step = _recover(-1, 0, failed=False, joiner=True)
    reported_final = False
    while True:
        while k < n_rounds:
            if elastic:
                snaps[k] = (w.copy(), vel.copy(), center.copy(), step)
                snaps.pop(k - 2, None)
                if pending_reconf[0] > 0:        # a join (no death) folds
                    k, step = _recover(k, step, failed=False)  # in here,
                    continue                     # at the round boundary
            if watchdog is not None and watchdog.should_stop.is_set():
                # preempted between rounds: the mesh is only safe to leave
                # at a round boundary (peers block on our segments
                # mid-exchange)
                stats = {"preempted": True, "iters": step}
                if bye_wrap is not None:
                    stats = bye_wrap(stats)
                link.send_json(wire.BYE, stats, wid=wid)
                if ctrl_th is not None:
                    ctrl_th.join(timeout=5.0)    # ends when master hangs up
                else:
                    _drain_after_bye(link)
                return
            if chaos is not None:
                chaos.maybe_fire(wid, step)      # deterministic fault point
            try:
                if tau > 1:
                    t0 = time.perf_counter()
                    for _ in range(tau - 1):     # τ−1 local-only steps
                        g = grad_fn(w, step, wid)
                        easgd_flat.local_step(algo, w, vel, g, local_cfg)
                        step += 1
                    if tr is not None:
                        tr.record(obs_trace.LOCAL_STEP, t0,
                                  time.perf_counter(), tau - 1)
                if algo == "sync_easgd":
                    row[:n] = w                  # start-of-exchange weights
                    if overlap:
                        comm = threading.Thread(target=_exchange)
                        comm.start()             # buckets fly while the
                        grad = _grad_traced(step)    # gradient computes
                        step += 1                # (paper §6.1.3)
                        _drain(lambda b: _apply_easgd(b, grad))
                        _join_comm(comm)
                    else:
                        _exchange_inline()
                        grad = _grad_traced(step)
                        step += 1
                        _drain(lambda b: _apply_easgd(b, grad))
                    if exc_box:
                        raise exc_box[0]
                else:                            # sync_sgd: grads first, so
                    grad = _grad_traced(step)    # only the per-bucket
                    step += 1                    # master update overlaps
                    row[:n] = grad               # (§5.1)
                    if overlap:
                        comm = threading.Thread(target=_exchange)
                        comm.start()
                        _drain(_apply_sgd)
                        _join_comm(comm)
                    else:
                        _exchange_inline()
                        _drain(_apply_sgd)
                    if exc_box:
                        raise exc_box[0]
                    w[:] = center
                if telem is not None:
                    telem["iters"] = step
                    telem["exposed_s"] = exposed_s
                    telem["comm_s"] = comm_s
                if wid == reporter and k in eval_rounds:
                    # control-plane reports go RAW even under wire
                    # compression (one-shot exact-state transfers, not a
                    # stream error feedback could correct over time) and
                    # TAGGED with the exchange round: reports and
                    # reconfigurations interleave, so the master can't
                    # infer the cadence from arrival order
                    link.send_array(wire.CENTER, center, wid=k, raw=True)
            except (wire.WireError, OSError, MeshAbort):
                if not elastic:
                    raise
                # a peer died: the exchange collapsed under us (mesh.reset
                # on any survivor cascades the collapse) — freeze, ack the
                # rounds completed, resume in the reconfigured epoch
                k, step = _recover(k, step, failed=True)
                continue
            k += 1
        # -- final reports: tagged center (−1) + this worker's weights ------
        if wid == reporter and not reported_final:
            link.send_array(wire.CENTER, center, wid=-1,    # Θ(N), not
                            raw=True)                       # Θ(P·N)
            reported_final = True
        link.send_array(wire.WSTATE, w, wid=wid, raw=True)  # final weights
        stats = mesh.stats()
        stats.update({"comm_s": comm_s, "exposed_s": exposed_s,
                      "overlapped_s": max(0.0, comm_s - exposed_s),
                      "overlap": overlap, "update_backend": backend})
        if mesh.host_of is not None:
            stats["host"] = mesh.host_of(wid)
        if elastic:
            stats["epoch"] = cur_epoch
        if bye_wrap is not None:
            stats = bye_wrap(stats)
        if not elastic:
            while True:                          # control plane: DONE → BYE
                frame = link.recv_header()
                if frame.ftype == wire.DONE:
                    link.recv_discard(frame)
                    link.send_json(wire.BYE, stats, wid=wid)
                    return
                if frame.ftype == wire.ERROR:
                    raise RuntimeError(
                        f"master error: {link.recv_json(frame)}")
                link.recv_discard(frame)
        recovered = False
        while not recovered:                     # elastic: DONE → BYE, via
            kind, payload = _ctrl_get()          # the control thread
            if kind == "done":
                link.send_json(wire.BYE, stats, wid=wid)
                return
            if kind == "reconf" and payload.get("phase") == 1:
                # a member died during the final drain, before the last
                # CENTER landed: every exchange already completed
                # everywhere (resume == n_rounds), but the reporter may
                # have changed — recover, loop back, and re-report (the
                # master folds duplicate reports idempotently)
                pending_reconf[0] -= 1
                k, step = _recover(k, step, failed=False, first_p1=payload)
                reported_final = False
                recovered = True


def burn_main(spec_json: str, samples: int, wid: int) -> None:
    """Calibration burner: the EXACT worker substrate (same interpreter,
    same jax-free import footprint), measuring its own per-gradient wall
    period while its siblings run. Protocol: build+warm, print "R", wait
    for a line on stdin (the gate), burn, print the per-grad seconds.
    ``ps.calibrate`` uses the median across burners as the tcp transport's
    concurrent compute rate."""
    import json
    spec = json.loads(spec_json)
    w0, grad_fn, _ = _build_problem(spec["factory"], spec["kwargs"])
    w = np.asarray(w0, np.float64).copy()
    for k in range(5):
        grad_fn(w, k, -(wid + 2))
    print("R", flush=True)
    sys.stdin.readline()
    t0 = time.perf_counter()
    for k in range(samples):
        grad_fn(w, k, -(wid + 2))
    print((time.perf_counter() - t0) / samples, flush=True)


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--connect", default=None, metavar="HOST:PORT")
    ap.add_argument("--wid", type=int, default=-1,
                    help="worker id (default: from REPRO_CLUSTER_SPEC)")
    ap.add_argument("--token", default="repro-net")
    ap.add_argument("--timeout", type=float, default=600.0)
    ap.add_argument("--sync-plane", default="auto",
                    choices=["auto", "master", "p2p"],
                    help="auto/p2p: open a peer listener and advertise it "
                         "in HELLO (the master's WELCOME decides whether "
                         "the p2p data plane is used); master: skip it")
    ap.add_argument("--peer-port", type=int, default=0,
                    help="fixed bind port for the peer listener (multi-host "
                         "p2p behind firewalls; 0 = ephemeral)")
    ap.add_argument("--peer-host", default=None,
                    help="address to advertise for the peer listener "
                         "(default: the local endpoint of the master link)")
    ap.add_argument("--heartbeat-file", default=None,
                    help="touch this file every ~2 s so an external "
                         "supervisor can detect a hung worker "
                         "(ft.Watchdog.is_alive)")
    ap.add_argument("--burn", default=None, metavar="SPEC_JSON",
                    help="calibration mode: measure this interpreter's "
                         "concurrent gradient rate instead of training")
    ap.add_argument("--samples", type=int, default=20)
    ap.add_argument("--rejoin", action="store_true",
                    help="rejoin a running elastic master mid-run (a "
                         "respawn is a re-exec with REPRO_CLUSTER_SPEC "
                         "set plus this flag)")
    args = ap.parse_args(argv)
    if args.burn is not None:
        burn_main(args.burn, args.samples, args.wid)
        return
    # the declarative spec (server.cluster_spec_env) fills any connection
    # detail the command line leaves out — a respawn needs no hand-crafted
    # flags beyond --rejoin
    spec = os.environ.get("REPRO_CLUSTER_SPEC")
    if spec:
        import json as _json
        spec = _json.loads(spec)
        if args.connect is None:
            args.connect = f"{spec['host']}:{spec['port']}"
        if args.wid < 0:
            args.wid = int(spec["wid"])
        if args.token == "repro-net" and "token" in spec:
            args.token = spec["token"]
        if args.sync_plane == "auto" and "sync_plane" in spec:
            args.sync_plane = spec["sync_plane"]
        if args.peer_port == 0 and "peer_port" in spec:
            args.peer_port = int(spec["peer_port"])
    if args.connect is None:
        ap.error("--connect is required (unless --burn or "
                 "REPRO_CLUSTER_SPEC is set)")
    if args.wid < 0:
        ap.error("--wid is required (unless REPRO_CLUSTER_SPEC names it)")
    host, port = args.connect.rsplit(":", 1)
    worker_loop(host, int(port), args.wid, token=args.token,
                timeout_s=args.timeout, peer_host=args.peer_host,
                peer_port=args.peer_port, sync_plane=args.sync_plane,
                heartbeat_file=args.heartbeat_file, rejoin=args.rejoin)


if __name__ == "__main__":
    main()
