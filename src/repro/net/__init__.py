"""repro.net — the PS runtime's real network transport.

A length-prefixed binary wire protocol (``wire``: framing, typed frames,
zero-copy float64 paths, per-link sign-EF compression with error feedback),
a master server that services the runtime's concurrency disciplines over
TCP connections (``server``), and a thin gradient worker runnable on any
host (``worker``). Registered as ``transport="tcp"`` in
``repro.ps.transport``; orchestrated across hosts by ``launch/cluster``.
See DESIGN.md §net.

Import note: ``wire`` and ``worker`` are deliberately jax-free so worker
processes start fast; ``server`` runs in the launcher and shares the
``repro.comm`` registry with the rest of the stack.
"""
from repro.net import wire
from repro.net.wire import Link, measure_link

__all__ = ["Link", "measure_link", "wire"]
