from repro.runtime.train import TrainBuild, build_train_step, make_batch_defs
from repro.runtime.serve import ServeBuild, build_serve_steps, BatchingEngine
from repro.runtime import sharding
