"""Train-step builder: model fwd/bwd (per pod, vmapped) + Sync-EASGD
exchange (core.elastic) under one jit.

The step is the paper's Algorithm 4 adapted to the pod mesh:
  1. each pod computes grads on its own batch shard (intra-pod DP over
     `data` via GSPMD — the paper's within-node sync step);
  2. the ONE packed cross-pod collective exchanges start-of-step weights
     (overlappable with (1) — Sync EASGD3);
  3. fused elementwise EASGD update (eqs. 5–6 + 2).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import elastic
from repro.core.elastic import ElasticConfig, ElasticState
from repro.models import sctx
from repro.models import transformer as tfm
from repro.models.common import ModelConfig, abstract_params, init_params
from repro.runtime import sharding as shd


@dataclasses.dataclass(frozen=True)
class TrainBuild:
    """Everything the launcher / dry-run needs for one training setup."""
    step: Any                 # jitted (state, batch) -> (state, metrics)
    state_specs: Any          # ElasticState PartitionSpecs
    batch_spec_tree: Any      # batch PartitionSpecs
    abstract_state: Any       # ShapeDtypeStruct ElasticState
    init_state: Any           # () -> concrete ElasticState (allocates!)
    param_specs: Any
    n_pods: int
    exchange_plan: Any = None  # repro.comm.ExchangePlan the step executes


def _per_pod_loss(cfg: ModelConfig, constrain=None):
    def loss(params, batch):
        return tfm.lm_loss(cfg, params, batch,
                           extra_fwd_kwargs={"constrain": constrain})
    return loss


def make_batch_defs(cfg: ModelConfig, n_pods: int, per_pod_batch: int,
                    seq: int):
    """Abstract training batch with leading (n_pods, B_local, S) layout."""
    B, S = per_pod_batch, seq
    sd = jax.ShapeDtypeStruct
    batch = {
        "tokens": sd((n_pods, B, S), jnp.int32),
        "targets": sd((n_pods, B, S), jnp.int32),
        "mask": sd((n_pods, B, S), jnp.float32),
    }
    if cfg.mrope_sections is not None:
        batch["mrope_positions"] = sd((n_pods, 3, B, S), jnp.int32)
    if cfg.patch_embed_tokens:
        batch["patch_embeds"] = sd(
            (n_pods, B, cfg.patch_embed_tokens, cfg.d_model),
            cfg.compute_dtype)
    return batch


def build_train_step(cfg: ModelConfig, ecfg: ElasticConfig, mesh,
                     *, n_pods: int, per_pod_batch: int, seq: int,
                     seed: int = 0, microbatches: int = 1) -> TrainBuild:
    """``microbatches`` > 1 scans gradient accumulation over batch slices —
    activation memory scales with the microbatch while the optimizer step
    (and the cross-pod exchange) still sees the full global batch. Same
    math: grads are means over the full batch either way."""
    pspecs = shd.param_specs(cfg, mesh)
    pod_axis = "pod" if "pod" in mesh.axis_names else None
    sspecs = elastic.state_specs(pspecs, ecfg, pod_axis)
    defs = tfm.model_defs(cfg)
    abstract_p = abstract_params(defs, cfg.param_dtype)
    n_param_elems = sum(
        l.size for l in jax.tree_util.tree_leaves(abstract_p))
    # the ONE cross-pod exchange (schedule × packing × compression ×
    # overlap), built once and executed by every step; "auto" resolves here
    # from the packed wire bytes and pod count
    exchange_plan = ecfg.exchange_plan(
        axis_name=pod_axis if (n_pods > 1 and pod_axis is not None) else None,
        n_total=n_pods, n_elements=n_param_elems)
    bspecs = shd.batch_specs(cfg, mesh, pod_dim=pod_axis is not None)
    assert per_pod_batch % microbatches == 0, (per_pod_batch, microbatches)

    loss_fn = _per_pod_loss(cfg, shd.block_constrainer(cfg, mesh))
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)
    vmap_kw = {"spmd_axis_name": pod_axis} if pod_axis else {}
    act_fn = shd.activation_constrainer(cfg, mesh)

    def grads_of(params_pod, batch):
        with sctx.use(act_fn):
            (loss, metrics), grads = jax.vmap(grad_fn, **vmap_kw)(
                params_pod, batch)
        return loss, metrics, grads

    def step(state: ElasticState, batch):
        # per-pod fwd/bwd; intra-pod data-parallel reduction happens via the
        # batch's `data` sharding (GSPMD inserts the gradient all-reduce).
        if microbatches == 1:
            loss, metrics, grads = grads_of(state.params, batch)
        else:
            # batch leaves: (n_pods, B, ...) -> (m, n_pods, B/m, ...);
            # mrope_positions carries batch at axis 2: (n_pods, 3, B, S)
            def split(x, axis):
                shape = (x.shape[:axis] + (microbatches, -1)
                         + x.shape[axis + 1:])
                return jnp.moveaxis(x.reshape(shape), axis, 0)
            micro = {
                k: split(v, 2 if k == "mrope_positions" else 1)
                for k, v in batch.items()
            }

            def acc_fn(carry, mb):
                g_acc, loss_acc, m_acc = carry
                loss, metrics, grads = grads_of(state.params, mb)
                g_acc = jax.tree_util.tree_map(
                    lambda a, g: a + g.astype(a.dtype) / microbatches,
                    g_acc, grads)
                m_acc = jax.tree_util.tree_map(
                    lambda a, m: a + m / microbatches, m_acc, metrics)
                return (g_acc, loss_acc + loss / microbatches, m_acc), None

            g0 = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params)
            zero_metrics = {
                "ce": jnp.zeros((n_pods,)), "aux": jnp.zeros((n_pods,)),
                "accuracy": jnp.zeros((n_pods,)),
                "tokens": jnp.zeros((n_pods,)),
            }
            (grads, loss, metrics), _ = jax.lax.scan(
                acc_fn, (g0, jnp.zeros((n_pods,)), zero_metrics), micro)
        new_state = elastic.apply_gradients(
            state, grads, ecfg, mesh=mesh, param_specs=pspecs,
            pod_axis=pod_axis, plan=exchange_plan)
        out_metrics = {
            "loss": jnp.mean(loss),
            **{k: jnp.mean(v) for k, v in metrics.items()},
        }
        return new_state, out_metrics

    abstract_state = elastic.init_abstract(abstract_p, ecfg, n_pods)

    def init_state():
        params = init_params(defs, jax.random.PRNGKey(seed), cfg.param_dtype)
        return elastic.init(params, ecfg, n_pods)

    jit_step = jax.jit(
        step,
        in_shardings=(shd.named(mesh, sspecs), shd.named(mesh, bspecs)),
        out_shardings=(shd.named(mesh, sspecs), None),
        donate_argnums=(0,),
    )
    return TrainBuild(
        step=jit_step, state_specs=sspecs, batch_spec_tree=bspecs,
        abstract_state=abstract_state, init_state=init_state,
        param_specs=pspecs, n_pods=n_pods, exchange_plan=exchange_plan,
    )
