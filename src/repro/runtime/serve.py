"""Serving-step builders: sharded prefill and decode, plus a simple
continuous-batching engine used by examples/serve.py.

Dry-run shapes: ``prefill_32k`` lowers the prefill step (B=32, S=32768);
``decode_32k`` / ``long_500k`` lower ONE decode step against a KV cache of
the given length (the assignment's definition of the decode cells).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import sctx
from repro.models import transformer as tfm
from repro.models.common import ModelConfig, abstract_params
from repro.runtime import sharding as shd


@dataclasses.dataclass(frozen=True)
class ServeBuild:
    prefill: Any              # (params, tokens, [extras]) -> (logits, caches)
    decode: Any               # (params, caches, token, pos) -> (logits, caches)
    abstract_params: Any
    abstract_caches: Any
    param_specs: Any
    cache_spec_tree: Any
    token_spec: Any


def _extra_kwargs(cfg, B, S):
    sd = jax.ShapeDtypeStruct
    extras = {}
    if cfg.mrope_sections is not None:
        extras["mrope_positions"] = sd((3, B, S), jnp.int32)
    if cfg.patch_embed_tokens and S > cfg.patch_embed_tokens:
        extras["patch_embeds"] = sd((B, cfg.patch_embed_tokens, cfg.d_model),
                                    cfg.compute_dtype)
    return extras


def build_serve_steps(cfg: ModelConfig, mesh, *, batch: int, max_len: int):
    pspecs = shd.param_specs(cfg, mesh)
    cspecs = shd.cache_specs(cfg, mesh, batch, max_len)
    tok_spec = shd.serve_token_specs(cfg, mesh, batch)
    named = lambda t: shd.named(mesh, t)
    constrain = shd.block_constrainer(cfg, mesh)

    act_fn = shd.activation_constrainer(cfg, mesh)

    def _extra_specs(S):
        b_ax = tok_spec[0]
        specs = {}
        if cfg.mrope_sections is not None:
            specs["mrope_positions"] = P(None, b_ax, None)
        if cfg.patch_embed_tokens and S > cfg.patch_embed_tokens:
            specs["patch_embeds"] = P(b_ax, None, None)
        return specs

    # modality extras travel as a positional dict (jit with in_shardings
    # does not accept kwargs)
    def prefill_fn(params, tokens, extras):
        caches = jax.tree_util.tree_map(
            lambda s: jnp.zeros(s.shape, s.dtype),
            tfm.init_cache_defs(cfg, batch, max_len))
        caches = jax.lax.with_sharding_constraint(caches, named(cspecs))
        with sctx.use(act_fn):
            return tfm.prefill(cfg, params, tokens, caches,
                               constrain=constrain, **extras)

    def decode_fn(params, caches, token, pos, extras):
        with sctx.use(act_fn):
            return tfm.decode_step(cfg, params, token, caches, pos,
                                   constrain=constrain, **extras)

    jit_prefill = jax.jit(
        prefill_fn,
        in_shardings=(named(pspecs), named(tok_spec),
                      named(_extra_specs(max_len))),
        out_shardings=(None, named(cspecs)),
    )
    jit_decode = jax.jit(
        decode_fn,
        in_shardings=(named(pspecs), named(cspecs), named(tok_spec),
                      named(P("data" if tok_spec == P("data", None) else None)),
                      named(_extra_specs(1))),
        out_shardings=(None, named(cspecs)),
        donate_argnums=(1,),
    )
    return ServeBuild(
        prefill=jit_prefill,
        decode=jit_decode,
        abstract_params=abstract_params(tfm.model_defs(cfg), cfg.param_dtype),
        abstract_caches=tfm.init_cache_defs(cfg, batch, max_len),
        param_specs=pspecs,
        cache_spec_tree=cspecs,
        token_spec=tok_spec,
    )


# ---------------------------------------------------------------------------
# minimal continuous-batching engine (examples/serve.py)
# ---------------------------------------------------------------------------

class BatchingEngine:
    """Greedy decode over a fixed batch of request slots.

    Requests join free slots; each step decodes one token for every active
    slot; finished requests free their slot. Small-model CPU demo of the
    serving path (the same jitted decode step the dry-run lowers).
    """

    def __init__(self, cfg: ModelConfig, params, batch: int, max_len: int):
        self.cfg = cfg
        self.params = params
        self.batch = batch
        self.max_len = max_len
        self.caches = jax.tree_util.tree_map(
            lambda s: jnp.zeros(s.shape, s.dtype),
            tfm.init_cache_defs(cfg, batch, max_len))
        self.pos = jnp.zeros((batch,), jnp.int32)
        self.cur = jnp.zeros((batch, 1), jnp.int32)
        self.active = [False] * batch
        self.outputs: dict[int, list] = {}
        self._decode = jax.jit(
            lambda p, c, t, pos: tfm.decode_step(cfg, p, t, c, pos))
        self._next_id = 0

    def submit(self, prompt_tokens) -> int | None:
        """Prefill a single request into a free slot; returns request id."""
        try:
            slot = self.active.index(False)
        except ValueError:
            return None
        rid = self._next_id
        self._next_id += 1
        # single-request prefill (slot-wise): decode tokens one by one to
        # fill this slot's cache without disturbing others.
        for t, tok in enumerate(prompt_tokens):
            tok_arr = self.cur.at[slot, 0].set(int(tok))
            pos_arr = self.pos.at[slot].set(t)
            logits, self.caches = self._decode(self.params, self.caches,
                                               tok_arr, pos_arr)
        self.pos = self.pos.at[slot].set(len(prompt_tokens))
        nxt = int(jnp.argmax(logits[slot]))
        self.cur = self.cur.at[slot, 0].set(nxt)
        self.active[slot] = True
        self.outputs[rid] = [nxt]
        self._slot_of = getattr(self, "_slot_of", {})
        self._slot_of[rid] = slot
        return rid

    def step(self, stop_len: int = 16):
        logits, self.caches = self._decode(self.params, self.caches,
                                           self.cur, self.pos)
        nxt = jnp.argmax(logits, axis=-1)
        self.cur = nxt[:, None].astype(jnp.int32)
        self.pos = self.pos + jnp.asarray(
            [1 if a else 0 for a in self.active], jnp.int32)
        done = []
        for rid, slot in list(getattr(self, "_slot_of", {}).items()):
            if not self.active[slot]:
                continue
            self.outputs[rid].append(int(nxt[slot]))
            if len(self.outputs[rid]) >= stop_len or \
                    int(self.pos[slot]) >= self.max_len - 1:
                self.active[slot] = False
                done.append(rid)
                del self._slot_of[rid]
        return done
