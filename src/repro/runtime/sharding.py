"""Sharding rules: logical axes → mesh PartitionSpecs for params, batches,
and serving caches (DESIGN.md §5).

Mesh axes: optional ``pod`` (EASGD workers), ``data`` (intra-pod DP/FSDP),
``model`` (TP/EP). All divisibility checks happen here so every arch maps
onto the fixed production mesh without invalid shardings (e.g. 20 heads on a
16-way model axis → attention replicates, FFN/vocab still shard).
"""
from __future__ import annotations

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models import transformer as tfm
from repro.models.common import ModelConfig, make_rules, partition_specs


def mesh_axis_sizes(mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def param_specs(cfg: ModelConfig, mesh):
    """PartitionSpecs for the model parameter pytree (no pod dim)."""
    sizes = mesh_axis_sizes(mesh)
    rules = make_rules(cfg, sizes)
    return partition_specs(tfm.model_defs(cfg), rules)


def _div(n: int, size: int) -> bool:
    return size > 1 and n % size == 0


def batch_specs(cfg: ModelConfig, mesh, *, pod_dim: bool):
    """Specs for a training batch with leading (n_pods, B_local, S) dims."""
    pod = "pod" if (pod_dim and "pod" in mesh.axis_names) else None
    tok = P(pod, "data", None)
    specs = {"tokens": tok, "targets": tok, "mask": tok}
    if cfg.mrope_sections is not None:
        specs["mrope_positions"] = P(pod, None, "data", None)
    if cfg.patch_embed_tokens:
        specs["patch_embeds"] = P(pod, "data", None, None)
    return specs


def serve_token_specs(cfg: ModelConfig, mesh, B: int):
    sizes = mesh_axis_sizes(mesh)
    b_ax = "data" if _div(B, sizes.get("data", 1)) else None
    return P(b_ax, None)


def cache_specs(cfg: ModelConfig, mesh, B: int, max_len: int):
    """PartitionSpecs mirroring transformer.init_cache_defs.

    Batch shards over `data` when divisible; otherwise (long-context decode
    with B=1) the SEQUENCE dim of attention/MLA caches shards over `data`
    — flash-decoding style: GSPMD reduces the partial softmax terms.
    Head/feature dims shard over `model` when divisible.
    """
    sizes = mesh_axis_sizes(mesh)
    dsz, msz = sizes.get("data", 1), sizes.get("model", 1)
    D = cfg.resolved_head_dim
    b_ax = "data" if _div(B, dsz) else None

    def seq_ax(S, *, model_free: bool):
        """Shard the cache's TIME dim over every axis not already used:
        `data` when the batch can't take it (long-context B=1), `model`
        when the kv-head/feature dim can't (GQA kv < model size). Partial
        softmax over the sharded seq dim is a GSPMD reduction
        (flash-decoding)."""
        axes = []
        if b_ax is None and _div(S, dsz):
            axes.append("data")
        if model_free and _div(S, msz * (dsz if axes else 1)):
            axes.append("model")
        if not axes:
            return None
        return tuple(axes) if len(axes) > 1 else axes[0]

    def kind_spec(kind: str):
        if kind in ("attn", "local"):
            S = max_len if kind == "attn" else min(cfg.window, max_len)
            kv_ax = "model" if _div(cfg.n_kv_heads, msz) else None
            s = P(b_ax, seq_ax(S, model_free=kv_ax is None), kv_ax, None)
            return {"k": s, "v": s}
        if kind == "mla":
            a = cfg.mla
            rank_ax = "model" if _div(a.kv_lora_rank, msz) else None
            return {
                "ckv": P(b_ax, seq_ax(max_len, model_free=rank_ax is None),
                         rank_ax),
                "kpe": P(b_ax, seq_ax(max_len, model_free=False), None),
            }
        if kind == "ssm":
            s = cfg.ssm
            d_inner = s.expand * cfg.d_model
            H = d_inner // s.head_dim
            conv_dim = d_inner + 2 * s.d_state
            return {
                "conv": P(b_ax, None,
                          "model" if _div(conv_dim, msz) else None),
                "state": P(b_ax, "model" if _div(H, msz) else None, None,
                           None),
            }
        if kind == "rglru":
            g = cfg.rglru
            w_ax = "model" if _div(g.width, msz) else None
            return {"conv": P(b_ax, None, w_ax), "state": P(b_ax, w_ax)}
        raise ValueError(kind)

    def stack(spec_tree):
        return jax.tree_util.tree_map(lambda s: P(None, *s), spec_tree,
                                      is_leaf=lambda x: isinstance(x, P))

    return {
        "stacked": tuple(stack(kind_spec(k)) for k in cfg.pattern),
        "rem": tuple(kind_spec(k) for k in cfg.remainder_kinds),
    }


def named(mesh, spec_tree):
    """Wrap a PartitionSpec pytree into NamedShardings for jit."""
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def activation_constrainer(cfg: ModelConfig, mesh):
    """Build the models.sctx constraint fn: logical activation axes →
    PartitionSpec on this mesh. batch/groups→data, heads/ff/vocab/inner→
    model, experts_dp→data (EP buffers; takes priority over groups so the
    dispatch buffer resharding is the token all-to-all). Dims that don't
    divide their axis stay replicated."""
    sizes = mesh_axis_sizes(mesh)
    dsz, msz = sizes.get("data", 1), sizes.get("model", 1)

    data_axes = {"experts_dp": 0, "batch": 2, "groups": 2}
    model_axes = {"heads": 1, "kv_heads": 1, "ff": 1, "vocab": 1,
                  "experts": 1, "inner": 1}

    def fn(x, logical):
        axes = [None] * len(logical)
        used = set()
        order = sorted(
            range(len(logical)),
            key=lambda i: data_axes.get(logical[i],
                                        model_axes.get(logical[i], 9)))
        for i in order:
            dim, name = x.shape[i], logical[i]
            if name in data_axes and "data" not in used and dim % dsz == 0:
                axes[i] = "data"
                used.add("data")
            elif name in model_axes and "model" not in used \
                    and dim % msz == 0:
                axes[i] = "model"
                used.add("model")
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, P(*axes)))

    return fn


def block_constrainer(cfg: ModelConfig, mesh):
    """Streaming-FSDP gather: returns ``constrain(kind, params_subtree)``
    that re-shards one layer's params to their COMPUTE layout (TP only, no
    `data` factor). Inside the layer scan this forces exactly one weight
    all-gather per layer per pass — and its transpose in backward is the
    reduce-scatter of the weight grads (ZeRO semantics). Without it, the
    SPMD partitioner may all-reduce activations instead (measured 20×
    worse on the gemma3-4b probe). Returns None when cfg.fsdp is off.
    """
    if not cfg.fsdp:
        return None
    from repro.models import transformer as tfm
    from repro.models.common import make_rules, partition_specs

    sizes = mesh_axis_sizes(mesh)
    rules = make_rules(cfg, sizes)
    rules.pop("_fsdp_axis", None)
    # flatten specs once (P is tuple-like, so flatten with an explicit leaf
    # predicate and zip against the array leaves — structures mirror)
    spec_cache = {}
    for kind in set(cfg.pattern) | set(cfg.remainder_kinds):
        tree = partition_specs(tfm._block_defs(cfg, kind), rules)
        spec_cache[kind] = jax.tree_util.tree_flatten(
            tree, is_leaf=lambda x: isinstance(x, P))[0]

    def constrain(kind, subtree):
        leaves, treedef = jax.tree_util.tree_flatten(subtree)
        specs = spec_cache[kind]
        assert len(leaves) == len(specs), (kind, len(leaves), len(specs))
        out = [
            jax.lax.with_sharding_constraint(x, NamedSharding(mesh, s))
            for x, s in zip(leaves, specs)
        ]
        return jax.tree_util.tree_unflatten(treedef, out)

    return constrain
