"""Synthetic datasets (this container has no network: MNIST/CIFAR/ImageNet
are replaced by generated tasks with the same shapes and a learnable signal).

 * ``make_classification_dataset`` — Gaussian-mixture images, LeNet/AlexNet
   shaped. Linearly separable enough for the EASGD-family convergence
   comparisons (the paper's Figs 6/8 measure RELATIVE convergence, which is
   preserved); hard enough that optimizer differences show.
 * ``teacher_dataset`` — labels from a fixed random teacher MLP (harder,
   non-linear).
 * ``SyntheticLMStream`` — deterministic token stream for LM training: a
   simple Markov-ish structure (next token correlated with current) so loss
   demonstrably falls.
"""
from __future__ import annotations

import numpy as np


def make_classification_dataset(n: int, shape=(28, 28, 1), n_classes: int = 10,
                                seed: int = 0, noise: float = 1.2):
    """Gaussian class prototypes + noise. Returns (x (n,*shape), y (n,))."""
    rng = np.random.RandomState(seed)
    protos = rng.randn(n_classes, *shape).astype(np.float32)
    y = rng.randint(0, n_classes, size=n)
    x = protos[y] + noise * rng.randn(n, *shape).astype(np.float32)
    # normalize like the paper (Alg 1 line 1): zero mean, unit variance
    x = (x - x.mean()) / (x.std() + 1e-8)
    return x.astype(np.float32), y.astype(np.int32)


def teacher_dataset(n: int, d_in: int = 64, n_classes: int = 10,
                    seed: int = 0, temperature: float = 2.0):
    rng = np.random.RandomState(seed)
    w1 = rng.randn(d_in, 128).astype(np.float32) / np.sqrt(d_in)
    w2 = rng.randn(128, n_classes).astype(np.float32) / np.sqrt(128)
    x = rng.randn(n, d_in).astype(np.float32)
    h = np.maximum(x @ w1, 0.0)
    logits = h @ w2 * temperature
    y = logits.argmax(-1)
    return x, y.astype(np.int32)


class SyntheticLMStream:
    """Deterministic, seekable LM token stream.

    Tokens follow t_{i+1} = (a·t_i + b + structured noise) mod V with a
    per-position pattern — next-token prediction is learnable well below
    uniform entropy. ``batch_at(step)`` is a pure function of (seed, step,
    shard), which is what makes checkpoint-resume exact and data sharding
    across pods/hosts deterministic (DESIGN.md §8).
    """

    def __init__(self, vocab_size: int, seq: int, batch: int, seed: int = 0,
                 shard: int = 0, n_shards: int = 1):
        self.V = vocab_size
        self.seq = seq
        self.batch = batch
        self.seed = seed
        self.shard = shard
        self.n_shards = n_shards

    def batch_at(self, step: int):
        rng = np.random.RandomState(
            (self.seed * 1_000_003 + step * self.n_shards + self.shard)
            % (2**31 - 1))
        B, S, V = self.batch, self.seq, self.V
        a = 31 % V or 1
        t0 = rng.randint(0, V, size=(B, 1))
        noise = (rng.rand(B, S) < 0.15) * rng.randint(0, V, size=(B, S))
        toks = [t0]
        for i in range(1, S):
            nxt = (a * toks[-1] + 7 + (i % 5)) % V
            toks.append(np.where(noise[:, i:i + 1] > 0,
                                 noise[:, i:i + 1] % V, nxt))
        tokens = np.concatenate(toks, axis=1).astype(np.int32)
        targets = np.concatenate(
            [tokens[:, 1:], tokens[:, :1]], axis=1).astype(np.int32)
        mask = np.ones((B, S), np.float32)
        mask[:, -1] = 0.0
        return {"tokens": tokens, "targets": targets, "mask": mask}
