"""Sharded, prefetching, exactly-resumable data pipeline.

The paper's data path (Alg 4 line 1: every node reads its own shard; line
10: each worker samples from local memory) maps to: each pod consumes a
disjoint deterministic shard of the stream, keyed by (seed, step, pod), so
 * no two pods ever see the same batch at the same step,
 * restart from a checkpointed ``step`` reproduces the exact batch sequence
   (no cursor files needed — the cursor IS the step),
 * elastic rescale (pods joining/leaving) just changes ``n_shards``.

A background thread prefetches ``depth`` batches ahead (the paper's
'asynchronously copies b samples' — overlap of data movement with compute).
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Callable, Optional

import numpy as np


@dataclasses.dataclass
class PipelineState:
    step: int


class ShardedPipeline:
    """Wraps a ``batch_at(step) -> dict`` source with pod-stacking and
    prefetch. ``source_factory(shard, n_shards)`` builds one shard's
    stream."""

    def __init__(self, source_factory: Callable, n_pods: int = 1,
                 depth: int = 2, start_step: int = 0):
        self.factory = source_factory
        self.n_pods = n_pods
        self.depth = depth
        self.state = PipelineState(step=start_step)
        self.sources = [source_factory(i, n_pods) for i in range(n_pods)]
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self._next_produce = start_step

    def _produce(self, step: int):
        shards = [s.batch_at(step) for s in self.sources]
        return {
            k: np.stack([sh[k] for sh in shards], axis=0)
            for k in shards[0]
        }

    def _worker(self):
        while not self._stop.is_set():
            step = self._next_produce
            batch = self._produce(step)
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.1)
                    self._next_produce = step + 1
                    break
                except queue.Full:
                    continue

    def start(self):
        if self._thread is None:
            self._thread = threading.Thread(target=self._worker, daemon=True)
            self._thread.start()
        return self

    def next(self):
        """Next batch, stacked (n_pods, B, ...). Prefetched if started."""
        if self._thread is None:
            batch = self._produce(self.state.step)
            self.state.step += 1
            return batch
        step, batch = self._q.get()
        # if a restore rewound the cursor, regenerate deterministically
        if step != self.state.step:
            batch = self._produce(self.state.step)
        self.state.step += 1
        return batch

    def restore(self, step: int):
        self.state.step = step
        self._next_produce = step
        # drain stale prefetch
        while True:
            try:
                self._q.get_nowait()
            except queue.Empty:
                break

    def stop(self):
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=2)
            self._thread = None

    def rescale(self, n_pods: int):
        """Elastic pod count change: re-shard the stream (DESIGN.md §8)."""
        self.stop()
        self._stop = threading.Event()
        self.n_pods = n_pods
        self.sources = [self.factory(i, n_pods) for i in range(n_pods)]
        self.restore(self.state.step)
