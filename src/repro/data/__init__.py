from repro.data.synthetic import (
    SyntheticLMStream, make_classification_dataset, teacher_dataset,
)
from repro.data.pipeline import ShardedPipeline, PipelineState
