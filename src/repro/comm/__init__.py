"""repro.comm — the unified cross-pod exchange stack.

Single import point for communication schedules: each registered
``Schedule`` carries BOTH the runnable shard_map implementation and the α–β
cost function, so one definition is simultaneously runnable (runtime),
simulatable (DES engines) and benchmarkable (table3/table4 sweeps).
``ExchangePlan`` composes schedule × packing × compression × overlap into
the single ``exchange(weights) -> mean_weights`` callable the Sync-EASGD
runtime consumes. See DESIGN.md §comm for the paper mapping.

Exports resolve lazily (PEP 562): the round STRUCTURE (``repro.comm.rounds``
— Message, the per-schedule round builders, wire serialization) is
stdlib-only and must stay importable without paying the jax import, because
the repro.net TCP workers execute those rounds over direct worker↔worker
links in interpreters that never load jax.
"""
_SCHEDULES = ("SCHEDULES", "Schedule", "choose", "get",
              "hierarchical_allreduce", "names", "register",
              "shard_map_allreduce")
_ROUNDS = ("MASTER", "Message", "bytes_from_rounds", "peer_pairs",
           "rounds_from_wire", "rounds_to_wire")
_PLAN = ("ExchangePlan", "make_plan")
_SUBMODULES = ("plan", "rounds", "schedules")

__all__ = _SCHEDULES + _ROUNDS + _PLAN + _SUBMODULES


def __getattr__(name):
    import importlib
    if name in _SCHEDULES:
        from repro.comm import schedules
        return getattr(schedules, name)
    if name in _ROUNDS:
        from repro.comm import rounds
        return getattr(rounds, name)
    if name in _PLAN:
        from repro.comm import plan
        return getattr(plan, name)
    if name in _SUBMODULES:
        return importlib.import_module(f"repro.comm.{name}")
    raise AttributeError(f"module 'repro.comm' has no attribute '{name}'")


def __dir__():
    return sorted(__all__)
