"""repro.comm — the unified cross-pod exchange stack.

Single import point for communication schedules: each registered
``Schedule`` carries BOTH the runnable shard_map implementation and the α–β
cost function, so one definition is simultaneously runnable (runtime),
simulatable (DES engines) and benchmarkable (table3/table4 sweeps).
``ExchangePlan`` composes schedule × packing × compression × overlap into
the single ``exchange(weights) -> mean_weights`` callable the Sync-EASGD
runtime consumes. See DESIGN.md §comm for the paper mapping.
"""
from repro.comm.schedules import (
    SCHEDULES,
    Schedule,
    choose,
    get,
    hierarchical_allreduce,
    names,
    register,
    shard_map_allreduce,
)
from repro.comm.plan import ExchangePlan, make_plan
