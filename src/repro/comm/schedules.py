"""The exchange-schedule registry: ONE definition per schedule, carrying BOTH
the runnable ``shard_map`` implementation AND the α–β cost function.

This is the single source of truth for how cross-pod exchanges move bytes.
A schedule registered here is simultaneously

 * **runnable**  — ``Schedule.allreduce(x, axis_name)`` inside ``shard_map``
   on a real mesh (``core.elastic`` / ``runtime.train`` consume it through an
   ``ExchangePlan``, see ``repro.comm.plan``),
 * **simulatable** — ``Schedule.cost(n_bytes, p, net)`` prices the same
   exchange under the paper's α–β model (``core.async_engine`` and
   ``core.des`` charge their discrete-event clocks through it), and
 * **benchmarkable** — the table3/table4 sweeps iterate ``names()``.

Paper mapping (§5.1/§6.1): Original EASGD's round-robin master↔worker
exchange is Θ(P) serialized messages; the paper's fix is a tree reduction
Θ(log P). ``ring`` is the bandwidth-optimal schedule a tuned library picks
for large buffers; ``psum`` is XLA's native all-reduce (priced as the best
of butterfly/ring — what a tuned library achieves).

All implementations compute the global **sum** over the bound mesh axis,
exactly like ``lax.psum`` — equivalence is pinned by tests on host meshes.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.core import costmodel
from repro.comm.rounds import (       # noqa: F401 — re-exported: one
    MASTER,                           # definition of the round structure,
    Message,                          # importable jax-free from comm.rounds
    _inner_size,                      # (the TCP workers' p2p data plane)
    butterfly_rounds,
    bytes_from_rounds,
    hierarchical_rounds,
    peer_pairs,
    psum_rounds,
    ring_rounds,
    round_robin_rounds,
    rounds_from_wire,
    rounds_to_wire,
    t_rounds,
    t_rounds_buckets,
    topology_group,
    tree_rounds,
)
from repro.utils.jaxcompat import axis_size, shard_map


# ---------------------------------------------------------------------------
# shard_map implementations (call INSIDE shard_map with the axis name bound)
# ---------------------------------------------------------------------------

def psum_allreduce(x, axis_name):
    """Baseline: XLA-native all-reduce."""
    return lax.psum(x, axis_name)


def tree_allreduce(x, axis_name):
    """Binomial-tree reduce-to-root + broadcast: 2·⌈log2 P⌉ rounds.

    The paper's §5.1 'tree reduction' in its literal two-phase form (the
    BCube/master-rooted variant). Requires a power-of-two axis size.
    """
    p = axis_size(axis_name)
    assert p & (p - 1) == 0, f"tree needs power-of-two axis, got {p}"
    if p == 1:
        return x
    r = lax.axis_index(axis_name)
    # reduce phase: rank i+d sends its partial sum to rank i
    d = 1
    while d < p:
        perm = [(i + d, i) for i in range(0, p, 2 * d)]
        recv = lax.ppermute(x, axis_name, perm)  # non-receivers get zeros
        x = x + recv
        d *= 2
    # broadcast phase: mirror the tree back down from rank 0
    d = p // 2
    while d >= 1:
        perm = [(i, i + d) for i in range(0, p, 2 * d)]
        recv = lax.ppermute(x, axis_name, perm)
        x = jnp.where(r % (2 * d) == d, recv, x)
        d //= 2
    return x


def butterfly_allreduce(x, axis_name):
    """Recursive-doubling all-reduce: ⌈log2 P⌉ rounds, XOR partners.

    The Θ(log P) schedule of Sync EASGD without the separate broadcast
    phase. Requires a power-of-two axis size.
    """
    p = axis_size(axis_name)
    assert p & (p - 1) == 0, f"butterfly needs power-of-two axis, got {p}"
    d = 1
    while d < p:
        perm = [(i, i ^ d) for i in range(p)]
        x = x + lax.ppermute(x, axis_name, perm)
        d *= 2
    return x


def ring_allreduce(x, axis_name):
    """Bandwidth-optimal ring all-reduce: reduce-scatter + all-gather.

    2(P−1) steps of (n/P)-byte messages. ``x`` must be 1-D (the registry's
    ``allreduce`` wrapper flattens automatically).
    """
    p = axis_size(axis_name)
    if p == 1:
        return x
    r = lax.axis_index(axis_name)
    n = x.shape[0]
    pad = (-n) % p
    if pad:
        x = jnp.concatenate([x, jnp.zeros((pad,), x.dtype)])
    chunks = x.reshape(p, -1)
    perm = [(i, (i + 1) % p) for i in range(p)]

    def rs_step(s, ch):
        send = jax.lax.dynamic_index_in_dim(ch, (r - s) % p, 0, keepdims=False)
        recv = lax.ppermute(send, axis_name, perm)
        return ch.at[(r - s - 1) % p].add(recv)

    chunks = lax.fori_loop(0, p - 1, rs_step, chunks)
    # rank r now holds the fully-reduced chunk (r+1) mod p

    def ag_step(s, ch):
        send = jax.lax.dynamic_index_in_dim(ch, (r + 1 - s) % p, 0,
                                            keepdims=False)
        recv = lax.ppermute(send, axis_name, perm)
        return ch.at[(r - s) % p].set(recv)

    chunks = lax.fori_loop(0, p - 1, ag_step, chunks)
    out = chunks.reshape(-1)
    return out[:n] if pad else out


def _grouped_ring(x, axis_name, p, m, r):
    """Ring reduce-scatter + all-gather WITHIN groups of ``m`` consecutive
    ranks (all groups in parallel). 1-D x; requires m | p."""
    n = x.shape[0]
    pad = (-n) % m
    if pad:
        x = jnp.concatenate([x, jnp.zeros((pad,), x.dtype)])
    chunks = x.reshape(m, -1)
    r_in = r % m
    perm = [(g * m + j, g * m + (j + 1) % m)
            for g in range(p // m) for j in range(m)]

    def rs_step(s, ch):
        send = jax.lax.dynamic_index_in_dim(ch, (r_in - s) % m, 0,
                                            keepdims=False)
        recv = lax.ppermute(send, axis_name, perm)
        return ch.at[(r_in - s - 1) % m].add(recv)

    chunks = lax.fori_loop(0, m - 1, rs_step, chunks)

    def ag_step(s, ch):
        send = jax.lax.dynamic_index_in_dim(ch, (r_in + 1 - s) % m, 0,
                                            keepdims=False)
        recv = lax.ppermute(send, axis_name, perm)
        return ch.at[(r_in - s) % m].set(recv)

    chunks = lax.fori_loop(0, m - 1, ag_step, chunks)
    out = chunks.reshape(-1)
    return out[:n] if pad else out


def hierarchical_grouped_allreduce(x, axis_name):
    """Two-level all-reduce on ONE axis (paper §6.2 made first-class):
    bandwidth-optimal ring within groups of ``inner`` consecutive ranks
    (the fast ICI domain), then latency-optimal butterfly across groups
    (the slow DCI domain) — the cross-group message count is 1/inner of a
    flat exchange. Requires a power-of-two axis size; 1-D x.

    ``hierarchical_allreduce`` below is the two-axis form for meshes that
    expose the pod/ICI split explicitly.
    """
    p = axis_size(axis_name)
    assert p & (p - 1) == 0, f"hierarchical needs power-of-two axis, got {p}"
    if p == 1:
        return x
    r = lax.axis_index(axis_name)
    m = _inner_size(p)
    if m > 1:
        x = _grouped_ring(x, axis_name, p, m, r)
    d = m
    while d < p:
        perm = [(i, i ^ d) for i in range(p)]
        x = x + lax.ppermute(x, axis_name, perm)
        d *= 2
    return x


def round_robin_allreduce(x, axis_name):
    """The Original-EASGD wire schedule: the master (rank 0) exchanges with
    workers ONE AT A TIME, in rank order — Θ(P) serialized messages.

    Kept as the paper-faithful *baseline* schedule (this is intentionally
    the slow one). Semantics here: global sum, like the others, so
    correctness tests can compare directly.
    """
    p = axis_size(axis_name)
    if p == 1:
        return x
    r = lax.axis_index(axis_name)
    acc = x
    # gather phase: worker i -> master, sequentially (i = 1..P-1)
    for i in range(1, p):
        recv = lax.ppermute(x, axis_name, [(i, 0)])
        acc = jnp.where(r == 0, acc + recv, acc)
    # broadcast phase: master -> worker i, sequentially
    out = acc
    for i in range(1, p):
        recv = lax.ppermute(acc, axis_name, [(0, i)])
        out = jnp.where(r == i, recv, out)
    return out


# ---------------------------------------------------------------------------
# round structure — the wire pattern as DATA
# ---------------------------------------------------------------------------
#
# The round structure itself lives in ``repro.comm.rounds`` (jax-free:
# the repro.net TCP workers execute it over direct worker↔worker links
# without importing this module) and is re-exported above. The α–β cost of
# a round is α + max_frac·n·β, and summing rounds reproduces the
# closed-form ``cost_fn`` exactly (pinned by tests) — while the repro.ps
# runtime EXECUTES the same rounds over its transports, so the real system
# and the simulator move the identical message pattern.


def t_hierarchical_allreduce(n: float, p: int, net: costmodel.Network
                             ) -> float:
    """ring over the inner group + butterfly across groups (paper §6.2)."""
    m = _inner_size(p)
    return (costmodel.t_ring_allreduce(n, m, net)
            + costmodel.t_butterfly_allreduce(n, max(p // m, 1), net))


# ---------------------------------------------------------------------------
# the registry
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Schedule:
    """One exchange schedule = runnable implementation + α–β cost model.

    ``impl(x, axis_name)`` — global sum over the bound mesh axis, called
    inside ``shard_map`` (use ``allreduce`` which handles flattening).
    ``cost_fn(n_bytes, p, net)`` — seconds for one full group exchange of
    an n-byte buffer among p participants over ``net`` (α–β model).
    """

    name: str
    impl: Callable
    cost_fn: Callable
    flat_only: bool = False     # impl requires a 1-D buffer
    pow2_only: bool = False     # impl requires a power-of-two axis size
    rounds_fn: Callable | None = None   # (p, n_bytes, net) -> [[Message]]
    doc: str = ""

    def allreduce(self, x, axis_name: str):
        """Sum ``x`` over the mesh axis; flattens/reshapes for flat-only
        schedules so callers can pass any shape (scalars included)."""
        if self.flat_only and x.ndim != 1:
            return self.impl(x.reshape(-1), axis_name).reshape(x.shape)
        return self.impl(x, axis_name)

    def cost(self, n_bytes: float, p: int,
             net: costmodel.Network = costmodel.TPU_ICI) -> float:
        """α–β time of one full group exchange (0 for a single participant)."""
        if p <= 1:
            return 0.0
        return self.cost_fn(n_bytes, p, net)

    def rounds(self, p: int, n_bytes: float = 0.0,
               net: costmodel.Network = costmodel.TPU_ICI,
               topology: costmodel.Topology | None = None) -> list:
        """The exchange as explicit message rounds (empty for p ≤ 1).

        The repro.ps runtime executes exactly these over its transports;
        ``cost_from_rounds`` prices them and equals ``cost`` (pinned by
        tests) — one structure, run AND simulated. A ``topology`` shapes
        topology-aware builders (hierarchical groups by host) and lifts
        the flat pow2 gate for hierarchical — any p with a power-of-two
        GROUP count resolves there; the builder itself rejects the rest.
        """
        if p <= 1 or self.rounds_fn is None:
            return []
        if self.pow2_only and p & (p - 1) != 0 and not (
                self.name == "hierarchical" and topology is not None):
            raise ValueError(
                f"schedule '{self.name}' needs a power-of-two participant "
                f"count, got {p} — its round structure would address "
                f"nonexistent ranks (use ring/round_robin instead)")
        if topology is not None:
            return self.rounds_fn(p, n_bytes, net, topology=topology)
        return self.rounds_fn(p, n_bytes, net)

    def cost_from_rounds(self, n_bytes: float, p: int,
                         net: costmodel.Network = costmodel.TPU_ICI
                         ) -> float:
        """Per-round α–β pricing: each round costs α + max_frac·n·β (its
        messages fly concurrently); rounds are serialized."""
        return sum(net.alpha + max(m.frac for m in rnd) * n_bytes * net.beta
                   for rnd in self.rounds(p, n_bytes, net))

    def cost_topo(self, n_bytes: float, p: int,
                  topology: costmodel.Topology | None = None) -> float:
        """α–β time of one exchange on a two-level fabric: the schedule's
        own rounds, priced message-by-message over the topology's link
        classes (``comm.rounds.t_rounds``). A missing or uniform topology
        degrades to the closed-form ``cost`` on the intra network — same
        floats, so homogeneous callers stay bitwise-equal to today."""
        if topology is None or topology.uniform:
            net = topology.intra if topology is not None else \
                costmodel.TPU_ICI
            return self.cost(n_bytes, p, net)
        if p <= 1:
            return 0.0
        return t_rounds(
            self.rounds(p, n_bytes, topology.intra, topology=topology),
            n_bytes, net=topology.intra, topology=topology)

    def bytes_from_rounds(self, n_bytes: float, p: int,
                          net: costmodel.Network = costmodel.TPU_ICI
                          ) -> float:
        """TOTAL payload bytes the schedule's messages move for one
        exchange of an n-byte buffer (every message counted — this is what
        the p2p data plane's measured per-link byte counters must sum to;
        ``cost_from_rounds`` prices the same structure in time)."""
        return bytes_from_rounds(self.rounds(p, n_bytes, net), n_bytes)


SCHEDULES: dict[str, Schedule] = {}


def register(schedule: Schedule) -> Schedule:
    SCHEDULES[schedule.name] = schedule
    return schedule


def get(name: str) -> Schedule:
    try:
        return SCHEDULES[name]
    except KeyError:
        raise ValueError(
            f"unknown schedule '{name}', have {sorted(SCHEDULES)}"
        ) from None


def names() -> tuple:
    """Registered schedule names, in registration order."""
    return tuple(SCHEDULES)


register(Schedule(
    "psum", psum_allreduce, costmodel.t_allreduce_best,
    rounds_fn=psum_rounds,
    doc="XLA-native all-reduce; priced as min(butterfly, ring) — what a "
        "tuned library achieves."))
register(Schedule(
    "tree", tree_allreduce, costmodel.t_tree_allreduce, pow2_only=True,
    rounds_fn=tree_rounds,
    doc="reduce-to-root + broadcast, 2·⌈log2 P⌉ rounds (paper §5.1)."))
register(Schedule(
    "butterfly", butterfly_allreduce, costmodel.t_butterfly_allreduce,
    pow2_only=True, rounds_fn=butterfly_rounds,
    doc="recursive doubling, ⌈log2 P⌉ rounds — latency-optimal."))
register(Schedule(
    "ring", ring_allreduce, costmodel.t_ring_allreduce, flat_only=True,
    rounds_fn=ring_rounds,
    doc="reduce-scatter + all-gather, 2(P−1) steps of n/P bytes — "
        "bandwidth-optimal."))
register(Schedule(
    "round_robin", round_robin_allreduce, costmodel.t_round_robin_allreduce,
    rounds_fn=round_robin_rounds,
    doc="Original EASGD's serialized master↔worker exchange, Θ(P) — the "
        "paper's baseline."))
register(Schedule(
    "hierarchical", hierarchical_grouped_allreduce, t_hierarchical_allreduce,
    flat_only=True, pow2_only=True, rounds_fn=hierarchical_rounds,
    doc="two-level divide-and-conquer (paper §6.2): ring within groups of "
        "2^⌈log2(P)/2⌉ ranks (ICI), butterfly across groups (DCI)."))


# ---------------------------------------------------------------------------
# derived helpers
# ---------------------------------------------------------------------------

def choose(n_bytes: float, p: int,
           net: costmodel.Network = costmodel.TPU_ICI,
           topology: costmodel.Topology | None = None,
           profile: costmodel.LinkProfile | None = None) -> str:
    """α–β-model-driven schedule choice (paper Table 2 reasoning):
    latency-bound small buffers → butterfly; bandwidth-bound → ring.
    butterfly is pow2-only, so a non-power-of-two group always gets ring
    (valid for any p) — the chooser never proposes an unrunnable schedule.

    With a ``topology`` (or a measured ``profile``, which carries one) the
    candidates are priced link-by-link via ``cost_topo``: ``hierarchical``
    joins the candidate set whenever its rounds resolve on that topology,
    and wins exactly when confining full-size traffic to ⌈log2 hosts⌉
    cross-host rounds beats the flat schedules' cross-host α bill — the
    paper's §6.2 regime. Candidate order (butterfly, ring, hierarchical)
    breaks ties, so hierarchical is only picked on a STRICT improvement
    and a uniform topology reproduces today's flat choice bitwise."""
    if profile is not None and topology is None:
        topology = profile.topology
    if p <= 1:
        return "psum"
    if topology is not None and not topology.uniform:
        cands = ["butterfly"] if p & (p - 1) == 0 else []
        cands.append("ring")
        try:
            get("hierarchical").rounds(p, n_bytes, topology.intra,
                                       topology=topology)
        except ValueError:
            pass
        else:
            cands.append("hierarchical")
        return min(cands,
                   key=lambda nm: get(nm).cost_topo(n_bytes, p, topology))
    if topology is not None:
        net = topology.intra
    if p & (p - 1) == 0 and get("butterfly").cost(n_bytes, p, net) <= \
            get("ring").cost(n_bytes, p, net):
        return "butterfly"
    return "ring"


def hierarchical_allreduce(x, inner_axis, outer_axis, inner="psum",
                           outer="psum"):
    """Two-level reduction: fast domain first, slow domain second.

    This is the paper's §6.2 divide-and-conquer generalized: reduce within
    the pod over ICI (cheap), then across pods over DCI (expensive) — the
    cross-pod message count is 1/pod_size of a flat all-reduce.
    """
    x = get(inner).allreduce(x, inner_axis)
    x = get(outer).allreduce(x, outer_axis)
    return x


def shard_map_allreduce(mesh, x, axis_name: str, algorithm: str = "auto"):
    """Run a registered schedule over a 1-D buffer replicated on
    ``axis_name`` and sharded on no other axis. Test/benchmark entry point."""
    if algorithm == "auto":
        algorithm = choose(x.size * x.dtype.itemsize, mesh.shape[axis_name])
    sched = get(algorithm)
    spec = P(axis_name)

    @partial(
        shard_map,
        mesh=mesh,
        in_specs=(spec,),
        out_specs=spec,
        check_vma=False,
    )
    def run(xs):
        # xs: (1, n) slice per device along axis_name
        return sched.allreduce(xs[0], axis_name)[None]

    stacked = jnp.broadcast_to(x, (mesh.shape[axis_name],) + x.shape)
    return run(stacked)
