"""ExchangePlan: schedule × packer × compression × overlap as ONE object.

The plan is the single thing the runtime consumes for the cross-pod
exchange: build it once from an ``ElasticConfig`` (or by name) and every
layer sees the same composition —

 * ``exchange(weights) -> mean_weights`` — the public callable: pack the
   pytree into one flat buffer (paper §5.2), run the registered schedule's
   collective over the bound mesh axis (§5.1), unpack the cross-pod mean.
 * ``reduce_mean_flat(delta, ef)`` — the traced inner form used by
   ``core.elastic``'s packed shard_map body: compression (encode / int8
   wire / decode-mean) + local-pod reduction + the ONE cross-pod collective.
 * ``cost_s`` / ``visible_cost_s`` — the SAME exchange priced under the α–β
   model (wire bytes after compression), so the DES simulator and the
   benchmarks charge exactly what the runtime would execute; ``overlap``
   (paper §6.1.3) decides whether compute hides the collective.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.comm import schedules as schedules_lib
from repro.core import compression as compression_lib
from repro.core import costmodel
from repro.core import packing as packing_lib


def _sum_local(x):
    """Sum the leading local-pod dim, keeping int8 payloads int8 ON THE WIRE
    (±1 signs summed over ≤127 pods cannot overflow int8; casting to f32
    before the collective would quadruple the cross-pod bytes)."""
    return jnp.sum(x, axis=0, dtype=x.dtype if x.dtype == jnp.int8 else None)


@dataclasses.dataclass(frozen=True)
class ExchangePlan:
    """A fully-composed cross-pod exchange.

    ``axis_name`` is the mesh axis the collective runs over (None: no
    collective — single pod, or the pod dim lives outside the mesh);
    ``n_total`` is the TOTAL number of participants the mean divides by
    (local stacked pods × mesh axis size).
    """

    schedule: schedules_lib.Schedule
    compression: compression_lib.Compression
    overlap: bool = True
    axis_name: str | None = None
    n_total: int = 1
    # two-level fabric for PRICING only (the traced collective is
    # topology-blind — XLA owns placement); None keeps every cost path
    # bitwise-identical to the flat model
    topology: costmodel.Topology | None = None

    # -- traced exchange (inside shard_map when axis_name is bound) ---------
    def allreduce_sum(self, x):
        """Sum over the plan's mesh axis via the registered schedule."""
        if self.axis_name is None:
            return x
        return self.schedule.allreduce(x, self.axis_name)

    def reduce_mean_flat(self, delta, ef=None):
        """Cross-participant mean of a packed buffer: (local_pods, n) ->
        ((n,), new_ef). ``ef`` is the error-feedback state (required when
        compression is on, shaped like ``delta``)."""
        n = float(max(self.n_total, 1))
        if self.compression.name != "none":
            assert ef is not None, "compression requires error-feedback state"
            payload, ef_new = jax.vmap(self.compression.encode)(delta, ef)
            payload = jax.tree_util.tree_map(_sum_local, payload)
            payload = jax.tree_util.tree_map(self.allreduce_sum, payload)
            payload = jax.tree_util.tree_map(
                lambda x: x.astype(jnp.float32) / n, payload)
            return self.compression.decode_mean(payload), ef_new
        d = self.allreduce_sum(jnp.sum(delta, axis=0))
        return d / n, ef

    def exchange(self, tree):
        """weights -> cross-pod mean weights, as ONE packed collective.

        Call inside ``shard_map`` with ``axis_name`` bound (each device
        passes its local values); with ``axis_name=None`` it is the local
        identity mean. Stateless: with compression on, error feedback starts
        from zero and is discarded — carry EF through ``reduce_mean_flat``
        for training.
        """
        packer = packing_lib.Packer(tree, align=1)
        delta = packer.pack(tree)[None]                      # (1, n)
        ef = (jnp.zeros_like(delta)
              if self.compression.name != "none" else None)
        mean, _ = self.reduce_mean_flat(delta, ef)
        return packer.unpack(mean)

    # -- the SAME exchange under the α–β model ------------------------------
    def wire_bytes(self, n_elements: int) -> float:
        """Bytes the JITTED collective actually moves after compression.

        sign_ef signs stay int8 across the mesh (the in-flight sum must
        address them), so this is 1 byte/element — exactly what the
        compiled HLO's all-reduce carries (launch/hloparse verifies the
        agreement). The 1-bit ideal is ``framed_wire_bytes`` — achieved
        for real by the repro.net byte-stream wire, where no reduction
        happens in flight and signs are bit-packed.
        """
        return n_elements * self.compression.jit_wire_bytes_per_element

    def framed_wire_bytes(self, n_elements: int) -> float:
        """Bytes on a framed point-to-point wire (repro.net): bit-packed."""
        return n_elements * self.compression.wire_bytes_per_element

    def cost_s(self, n_elements: int, net: costmodel.Network,
               p: int | None = None) -> float:
        """α–β time of one exchange of ``n_elements`` packed fp32 elements.
        With a plan ``topology`` the rounds are priced per link class
        (cost_topo); otherwise the flat closed form on ``net``."""
        nb = self.wire_bytes(n_elements)
        np_ = p if p is not None else self.n_total
        if self.topology is not None and not self.topology.uniform:
            return self.schedule.cost_topo(nb, np_, self.topology)
        return self.schedule.cost(nb, np_, net)

    def visible_cost_s(self, n_elements: int, net: costmodel.Network,
                       t_compute: float, p: int | None = None) -> float:
        """Exchange time NOT hidden by compute: with overlap (paper §6.1.3)
        the collective reads start-of-step weights and hides behind fwd/bwd;
        without it the full cost is serialized."""
        t = self.cost_s(n_elements, net, p)
        return max(t - t_compute, 0.0) if self.overlap else t


def make_plan(schedule: str = "psum", compression: str = "none",
              overlap: bool = True, axis_name: str | None = None,
              n_total: int = 1,
              topology: costmodel.Topology | None = None) -> ExchangePlan:
    """Resolve names through the registries and compose a plan.

    Fails fast (clear ValueError) when a pow2-only schedule is composed
    with a non-power-of-two participant count — otherwise the constraint
    would only surface as an assert buried in shard_map tracing. (The
    shard_map impls really do need pow2; the rounds-level topology lift
    applies to the byte-stream runtimes, not the traced collective.)
    """
    sched = (schedules_lib.get(schedule) if isinstance(schedule, str)
             else schedule)
    comp = (compression_lib.get(compression) if isinstance(compression, str)
            else compression)
    if (sched.pow2_only and axis_name is not None
            and n_total & (n_total - 1) != 0):
        raise ValueError(
            f"schedule '{sched.name}' needs a power-of-two participant "
            f"count, got {n_total} — use ring/psum/round_robin instead")
    return ExchangePlan(schedule=sched, compression=comp, overlap=overlap,
                        axis_name=axis_name, n_total=n_total,
                        topology=topology)
