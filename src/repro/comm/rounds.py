"""Exchange-schedule round structure — the wire pattern as DATA, jax-free.

Each schedule in the ``repro.comm`` registry can describe itself as a list
of ROUNDS; a round is a list of point-to-point messages that fly
concurrently. This is the bridge between four consumers:

 * the α–β cost of a round is α + max_frac·n·β, and summing rounds
   reproduces the closed-form ``Schedule.cost_fn`` exactly (pinned by
   tests),
 * the repro.ps shared-memory runtime EXECUTES the same rounds over its
   transport mailboxes (``ps.execute_rounds``),
 * the repro.net MASTER executes them on its local mailbox for the
   centralized sync plane, and
 * the repro.net WORKERS execute them over direct worker↔worker TCP links
   for the peer-to-peer sync plane (``net.peer``) — each worker owns one
   mailbox row and ``Message.span`` tells it which byte range of the row a
   SEGMENT frame moves.

This module is deliberately jax-free (stdlib + ``core.costmodel`` only):
TCP worker processes deserialize rounds from the master's WELCOME and
import nothing heavier than numpy. The registry (``comm.schedules``)
re-exports everything here, so jax-side consumers see one definition.
"""
from __future__ import annotations

import dataclasses

from repro.core import costmodel

MASTER = -1   # in a parameter-server wiring the master is an endpoint of
#               its own, distinct from the p workers (round_robin uses it;
#               peer-to-peer schedules do not)


@dataclasses.dataclass(frozen=True)
class Message:
    """One point-to-point transfer inside a round.

    ``src``/``dst`` are worker ranks (or ``MASTER``). ``frac`` is the
    fraction of the buffer moved (ring moves 1/p chunks). For chunked
    schedules, the buffer is viewed as ``chunks`` equal slices and the
    receiver applies ``op`` to slice ``chunk``; chunk=None means the whole
    buffer. ``op`` is "add" (accumulate into the receiver) or "set"
    (overwrite) — receivers always read the sender's PRE-round value.
    """

    src: int
    dst: int
    frac: float = 1.0
    chunk: int | None = None
    chunks: int = 1
    op: str = "add"

    def span(self, n_elements: int) -> tuple[int, int]:
        """Element offsets ``[start, stop)`` of the buffer segment this
        message moves in an ``n_elements`` buffer (``chunks`` must divide
        it — the runtime pads rows to a multiple of P for exactly this).
        This is the SEGMENT frame's address on the p2p wire and the
        executor's slice on the shared-memory mailbox: one definition of
        which bytes a message touches."""
        if self.chunk is None:
            return 0, n_elements
        assert n_elements % self.chunks == 0, (n_elements, self.chunks)
        seg = n_elements // self.chunks
        return self.chunk * seg, (self.chunk + 1) * seg

    def nbytes(self, n_bytes: float) -> float:
        """Payload bytes this message moves out of an n_bytes buffer."""
        return self.frac * n_bytes


def round_robin_rounds(p, n_bytes=0.0, net=None, topology=None):
    """2·p serialized master↔worker messages: gather (add into the master,
    rank order — the same summation order as ``np.mean`` over workers, which
    the DES↔real bitwise cross-check relies on), then broadcast."""
    gather = [[Message(i, MASTER, op="add")] for i in range(p)]
    bcast = [[Message(MASTER, i, op="set")] for i in range(p)]
    return gather + bcast


def tree_rounds(p, n_bytes=0.0, net=None, topology=None):
    rounds = []
    d = 1
    while d < p:
        rounds.append([Message(i + d, i, op="add")
                       for i in range(0, p, 2 * d)])
        d *= 2
    d = p // 2
    while d >= 1:
        rounds.append([Message(i, i + d, op="set")
                       for i in range(0, p, 2 * d)])
        d //= 2
    return rounds


def butterfly_rounds(p, n_bytes=0.0, net=None, topology=None):
    rounds = []
    d = 1
    while d < p:
        rounds.append([Message(i, i ^ d, op="add") for i in range(p)])
        d *= 2
    return rounds


def ring_rounds(p, n_bytes=0.0, net=None, topology=None):
    rounds = []
    for s in range(p - 1):      # reduce-scatter
        rounds.append([Message(r, (r + 1) % p, frac=1.0 / p,
                               chunk=(r - s) % p, chunks=p, op="add")
                       for r in range(p)])
    for s in range(p - 1):      # all-gather
        rounds.append([Message(r, (r + 1) % p, frac=1.0 / p,
                               chunk=(r + 1 - s) % p, chunks=p, op="set")
                       for r in range(p)])
    return rounds


def psum_rounds(p, n_bytes=0.0, net=None, topology=None):
    """psum is 'whatever a tuned library picks': butterfly when the α–β
    model says latency-bound (and p is a power of two), else ring. On a
    NON-uniform topology the closed forms lie (they price one link class),
    so the two candidates are priced round-by-round over the actual links."""
    net = net or costmodel.TPU_ICI
    if topology is not None and not topology.uniform:
        if p & (p - 1) == 0:
            btf = butterfly_rounds(p)
            if t_rounds(btf, n_bytes, topology=topology) \
                    <= t_rounds(ring_rounds(p), n_bytes, topology=topology):
                return btf
        return ring_rounds(p)
    if p & (p - 1) == 0 and costmodel.t_butterfly_allreduce(n_bytes, p, net) \
            <= costmodel.t_ring_allreduce(n_bytes, p, net):
        return butterfly_rounds(p)
    return ring_rounds(p)


def _inner_size(p: int) -> int:
    """Two-level split p = inner × outer for the hierarchical schedule:
    inner = 2^⌈log2(p)/2⌉ (the near-square decomposition, paper §6.2's
    ICI-pod × DCI split collapsed onto one axis)."""
    if p <= 1:
        return 1
    log2p = p.bit_length() - 1
    return 1 << ((log2p + 1) // 2)


def topology_group(p: int, topology=None) -> int:
    """Group size for the hierarchical schedule: the topology's slot count
    when it actually tiles p (groups = hosts, so the inner ring stays on
    intra-host links and only the outer butterfly crosses hosts); otherwise
    the flat near-square split — which keeps default rounds byte-identical
    to before topologies existed (tests pin this)."""
    if topology is not None and topology.hosts > 1 and topology.p == p:
        return topology.slots
    return _inner_size(p)


def hierarchical_rounds(p, n_bytes=0.0, net=None, topology=None, group=None):
    """Grouped ring × butterfly (paper §6.2): ring reduce-scatter +
    all-gather inside each group of ``m`` ranks, then a recursive-doubling
    butterfly across the ``p // m`` groups. ``m`` comes from the topology
    (slots-per-host) when one is given, so the ring rides intra-host links
    and only ⌈log2 hosts⌉ rounds cross hosts. Any ``m ≥ 1`` works — the
    ring has no power-of-two needs — but the GROUP COUNT must be a power
    of two for the butterfly, which is how non-pow2 p (e.g. 24 = 4 hosts
    × 6 slots) becomes schedulable."""
    m = int(group) if group is not None else topology_group(p, topology)
    if m < 1 or p % m != 0:
        raise ValueError(
            f"hierarchical group size {m} does not tile p={p}")
    groups = p // m
    if groups & (groups - 1) != 0:
        raise ValueError(
            f"hierarchical needs a power-of-two group count, got "
            f"{groups} groups of {m} for p={p}")
    rounds = []
    for s in range(m - 1):      # inner grouped-ring reduce-scatter
        rounds.append([Message(g * m + j, g * m + (j + 1) % m, frac=1.0 / m,
                               chunk=(j - s) % m, chunks=m, op="add")
                       for g in range(groups) for j in range(m)])
    for s in range(m - 1):      # inner grouped-ring all-gather
        rounds.append([Message(g * m + j, g * m + (j + 1) % m, frac=1.0 / m,
                               chunk=(j + 1 - s) % m, chunks=m, op="set")
                       for g in range(groups) for j in range(m)])
    d = 1                       # outer butterfly across groups: rank g*m+j
    while d < groups:           # partners with (g^d)*m+j — for pow2 m this
        rounds.append([Message(g * m + j, (g ^ d) * m + j, op="add")
                       for g in range(groups) for j in range(m)])
        d *= 2                  # is byte-identical to the old i ^ (d*m) form
    return rounds


# ---------------------------------------------------------------------------
# pricing rounds over a (possibly heterogeneous) fabric
# ---------------------------------------------------------------------------

def _link_net(m: Message, net, topology):
    return topology.link(m.src, m.dst) if topology is not None else net


def t_rounds(rounds, n_bytes: float, net=None, topology=None,
             wid: int | None = None) -> float:
    """α–β time of a round structure with PER-MESSAGE link pricing: each
    round costs the max over its messages of ``link.α + frac·n·link.β``,
    rounds serialize. With a ``topology`` each message rides its own link
    class; without one every message rides ``net`` — in which case this is
    bitwise-equal to the closed ``cost_from_rounds`` formula (α + max_frac
    ·n·β: the max is attained at the max-frac message and the float ops
    match). ``wid`` restricts to messages touching that worker — its OWN
    pacing deadline on a heterogeneous mesh, where an intra-host pair
    finishes its segment early and waits on cross-host peers at the
    blocking recv rather than by sleeping."""
    net = net or costmodel.TPU_ICI
    total = 0.0
    for rnd in rounds:
        worst = None
        for m in rnd:
            if wid is not None and m.src != wid and m.dst != wid:
                continue
            link = _link_net(m, net, topology)
            t = link.alpha + m.frac * n_bytes * link.beta
            if worst is None or t > worst:
                worst = t
        if worst is not None:
            total += worst
    return total


def t_rounds_buckets(rounds, n_elements: int, boundaries, net=None,
                     topology=None, wid: int | None = None) -> list[float]:
    """Per-bucket wire time of the bucketed VIEW of ``rounds``, with the
    same per-message link pricing as ``t_rounds``: bucket b pays, for every
    round it appears in, the max over its clipped messages of
    ``link.α + clipped_bytes·link.β``. The f64 payload of a clipped span
    (a, b) is (b − a)·8 bytes — exactly what the SEGMENT frame moves."""
    net = net or costmodel.TPU_ICI
    out = []
    for plan in bucket_rounds(rounds, n_elements, boundaries):
        t = 0.0
        for rnd in plan:
            worst = None
            for m, (a, b) in rnd:
                if wid is not None and m.src != wid and m.dst != wid:
                    continue
                link = _link_net(m, net, topology)
                tm = link.alpha + (b - a) * 8 * link.beta
                if worst is None or tm > worst:
                    worst = tm
            if worst is not None:
                t += worst
        out.append(t)
    return out


# ---------------------------------------------------------------------------
# bucketed view — the SAME rounds, clipped at bucket boundaries
# ---------------------------------------------------------------------------
#
# Bucketing for comm/compute overlap must not change a single bit of the
# result, so it is defined as a VIEW of the monolithic schedule rather than
# a per-bucket re-run of the schedule builder: every message keeps its
# src/dst/op and its position in the round order, and bucket b simply clips
# the message's element span to [lo_b, hi_b). Because buckets partition the
# row into disjoint element ranges, each element still sees exactly the
# same operations from the same sources in the same order as in the
# monolithic exchange — which is why bucketed ring/tree chains are
# bit-identical to monolithic ones (pinned by tests). Re-chunking the
# schedule per bucket instead (e.g. ring with chunks=p over each bucket)
# would reassign which rank owns which element's reduction chain and change
# the addition order — NOT bitwise-safe. Don't do that.

# jax-free copy of core.packing.ELASTIC_UPDATE_BLOCK (that module imports
# jax at the top; this one must stay importable in jax-free workers) —
# pinned equal by tests/test_bucketing.py
ELASTIC_UPDATE_ALIGN = 8 * 128 * 128


def default_bucket_boundaries(sizes, n_elements: int,
                              bucket_bytes: int) -> list[int]:
    """The runtime's boundary policy for ``bucket_bytes`` f64 payload bytes
    per bucket: align cuts to the fused-update kernel's block only when the
    buckets themselves are at least one block (small test problems need
    small buckets; the kernel falls back below a block anyway)."""
    target = max(1, int(bucket_bytes) // 8)
    align = ELASTIC_UPDATE_ALIGN if target >= ELASTIC_UPDATE_ALIGN else None
    return bucket_boundaries(sizes, n_elements, target, align=align)


def bucket_boundaries(sizes, n_elements: int, target_elems: int,
                      align: int | None = None) -> list[int]:
    """Cut offsets ``[0, b1, ..., n_elements]`` grouping consecutive layers
    into buckets of ~``target_elems`` elements.

    ``sizes`` is the per-layer element count sequence (e.g.
    ``Packer`` leaf sizes, or ``grad_fn.layer_sizes``); a cut is emitted at
    the first layer edge where the open bucket has reached ``target_elems``.
    With ``align``, each cut is rounded UP to a multiple of ``align`` (the
    fused-update kernel wants block-aligned buckets); cuts that would
    collide or overrun are dropped. Empty/None ``sizes`` falls back to
    uniform ``target_elems`` slabs. Always returns at least ``[0, n]``."""
    assert n_elements > 0 and target_elems > 0
    edges: list[int] = []
    if sizes:
        off = 0
        for s in sizes:
            off += int(s)
            edges.append(off)
    else:
        edges = list(range(target_elems, n_elements, target_elems))
        edges.append(n_elements)
    cuts = [0]
    for e in edges:
        if e >= n_elements:
            break
        if e - cuts[-1] >= target_elems:
            c = e if align is None else -(-e // align) * align
            if cuts[-1] < c < n_elements:
                cuts.append(c)
    cuts.append(n_elements)
    # align-rounding can make a later layer edge land on/before a cut
    out = [cuts[0]]
    for c in cuts[1:]:
        if c > out[-1]:
            out.append(c)
    return out


def clip_span(m: Message, n_elements: int, lo: int, hi: int
              ) -> tuple[int, int] | None:
    """Intersection of ``m.span(n_elements)`` with bucket ``[lo, hi)`` —
    ``None`` when the message moves no bytes of this bucket."""
    a, b = m.span(n_elements)
    a, b = max(a, lo), min(b, hi)
    return (a, b) if a < b else None


def bucket_rounds(rounds, n_elements: int, boundaries) -> list:
    """Per-bucket execution plans: element ``boundaries`` ``[0, .., n]`` →
    one plan per bucket, each a list of rounds of ``(message, (start,
    stop))`` pairs with spans clipped to the bucket (messages that miss the
    bucket are dropped, empty rounds kept so round indices — and therefore
    p2p frame sequence numbers — stay aligned across buckets)."""
    assert boundaries[0] == 0 and boundaries[-1] == n_elements, boundaries
    plans = []
    for lo, hi in zip(boundaries[:-1], boundaries[1:]):
        plan = []
        for rnd in rounds:
            clipped = []
            for m in rnd:
                span = clip_span(m, n_elements, lo, hi)
                if span is not None:
                    clipped.append((m, span))
            plan.append(clipped)
        plans.append(plan)
    return plans


# ---------------------------------------------------------------------------
# derived structure — what the p2p data plane needs to wire itself up
# ---------------------------------------------------------------------------

def bytes_from_rounds(rounds, n_bytes: float) -> float:
    """TOTAL payload bytes all messages of ``rounds`` move for an n-byte
    buffer (cost_from_rounds prices the same structure in TIME: per round
    α + max_frac·n·β, messages concurrent; this sums them in BYTES, every
    message counted — what the p2p per-link byte counters must add up to)."""
    return sum(m.nbytes(n_bytes) for rnd in rounds for m in rnd)


def peer_pairs(rounds) -> list[tuple[int, int]]:
    """The worker↔worker links a round structure needs: unordered (i, j)
    pairs with i < j, first-use order, MASTER-endpoint messages excluded
    (those ride the existing master links)."""
    pairs: list[tuple[int, int]] = []
    seen = set()
    for rnd in rounds:
        for m in rnd:
            if m.src == MASTER or m.dst == MASTER:
                continue
            pair = (min(m.src, m.dst), max(m.src, m.dst))
            if pair not in seen:
                seen.add(pair)
                pairs.append(pair)
    return pairs


def remap_rounds(rounds, rank_to_wid) -> list:
    """Relabel a round structure built over DENSE ranks 0..P'−1 onto real
    worker ids (``ft.membership.dense_rank_map``): after a membership
    change the schedule builders still produce dense indices, but the
    surviving wids are a sparse subset — e.g. {0, 1, 3} after wid 2 dies.
    MASTER endpoints pass through unchanged. Chunk ownership, fractions and
    op order are untouched, so the remapped structure prices and executes
    exactly like the dense one."""
    import dataclasses

    def _m(i):
        return MASTER if i == MASTER else rank_to_wid[i]

    return [[dataclasses.replace(m, src=_m(m.src), dst=_m(m.dst))
             for m in rnd] for rnd in rounds]


def rounds_to_wire(rounds) -> list:
    """JSON-ready form of a round structure (the master ships this to the
    p2p workers in WELCOME — workers never import the jax-side registry)."""
    return [[[m.src, m.dst, m.frac, m.chunk, m.chunks, m.op] for m in rnd]
            for rnd in rounds]


def rounds_from_wire(obj) -> list:
    """Inverse of ``rounds_to_wire``."""
    return [[Message(src, dst, frac, chunk, chunks, op)
             for src, dst, frac, chunk, chunks, op in rnd]
            for rnd in obj]
