"""Launchers: mesh construction, multi-pod dry-run, train/serve drivers.

NOTE: ``repro.launch.dryrun`` must be imported/executed FIRST in a fresh
process (it sets XLA_FLAGS before jax initializes); do not import it from
library code.
"""
from repro.launch.mesh import make_production_mesh, make_host_mesh, n_pods_of
