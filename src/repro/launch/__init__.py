"""Launchers: mesh construction, multi-pod dry-run, train/serve drivers.

NOTE: ``repro.launch.dryrun`` must be imported/executed FIRST in a fresh
process (it sets XLA_FLAGS before jax initializes); do not import it from
library code.

Lazy exports (PEP 562): ``repro.launch.mesh`` pulls jax at import, but
``repro.launch.monitor`` must stay importable from jax-free processes (it
rides the tcp worker's import-footprint pin) — so nothing here imports a
submodule until the name is actually touched.
"""
import importlib

_EXPORTS = {
    "make_production_mesh": "repro.launch.mesh",
    "make_host_mesh": "repro.launch.mesh",
    "n_pods_of": "repro.launch.mesh",
}
__all__ = sorted(_EXPORTS) + ["cluster", "hloparse", "mesh", "monitor",
                              "train"]


def __getattr__(name):
    target = _EXPORTS.get(name)
    if target is not None:
        return getattr(importlib.import_module(target), name)
    try:
        return importlib.import_module(f"repro.launch.{name}")
    except ModuleNotFoundError:
        raise AttributeError(
            f"module 'repro.launch' has no attribute {name!r}") from None
