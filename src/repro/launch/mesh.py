"""Production mesh definitions.

Single pod: (data=16, model=16) = 256 chips (one TPU v5e pod).
Multi-pod:  (pod=2, data=16, model=16) = 512 chips — the `pod` axis carries
the EASGD elastic exchange (slow cross-pod links), `data`/`model` stay
inside a pod (fast ICI).

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run must set XLA_FLAGS before the first jax call).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(n_data: int = 2, n_model: int = 2, n_pods: int = 0):
    """Small mesh over however many (host) devices exist — tests/examples."""
    if n_pods:
        return jax.make_mesh((n_pods, n_data, n_model),
                             ("pod", "data", "model"))
    return jax.make_mesh((n_data, n_model), ("data", "model"))


def n_pods_of(mesh) -> int:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    return sizes.get("pod", 1)
