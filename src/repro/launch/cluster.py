"""Cluster launcher for the repro.ps runtime over the repro.net transport.

Localhost (spawns worker processes itself):

    PYTHONPATH=src python -m repro.launch.cluster --workers 4 \
        --algorithm sync_easgd --schedule ring --iters 400

Multi-host: the master binds a fixed port and WAITS; each worker host runs
the printed one-liner (or pass --ssh to have this process run them):

    # on the master host
    PYTHONPATH=src python -m repro.launch.cluster --workers 4 \
        --algorithm async_easgd --hosts knl01,knl02 --port 29500

    # printed for each wid, round-robin over --hosts:
    #   PYTHONPATH=src python -m repro.net.worker \
    #       --connect <master>:29500 --wid 0 --token repro-net

Rendezvous: the master accepts until all P workers said HELLO (within
--timeout), ships each the problem factory + algorithm + τ in WELCOME, and
starts the clock only after every worker reported READY (problem built,
caches warm). Heartbeats let the master tell a slow gradient from a dead
host; DONE/BYE shuts everything down cleanly. ``--compression sign_ef``
turns on 1-bit sign+error-feedback payloads on every link.

``--sync-plane p2p`` (sync family): the workers execute the schedule's
rounds over direct worker↔worker links instead of the master's mailbox —
the master degrades to a control-plane coordinator and its links carry
Θ(N_center) instead of Θ(P·N) per round. With --hosts the printed worker
one-liners pin each peer listener to --port+1+wid, so the whole p2p mesh
is firewall-predictable and launchable verbatim:

    PYTHONPATH=src python -m repro.launch.cluster --workers 4 \
        --algorithm sync_easgd --schedule ring --sync-plane p2p \
        --hosts knl01,knl02 --port 29500
"""
from __future__ import annotations

import argparse
import dataclasses
import shlex
import socket
import subprocess

from repro import comm


def _advertised_addr(port: int) -> str:
    try:
        host = socket.gethostbyname(socket.gethostname())
    except OSError:
        host = socket.gethostname()
    return f"{host}:{port}"


def main(argv=None):
    from repro import ps
    from repro.core import costmodel
    from repro.core.easgd import EASGDConfig
    from repro.net.server import cluster_spec_env, worker_command

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--algorithm", default="sync_easgd",
                    help="one of core.async_engine.ALGORITHMS, or 'all'")
    ap.add_argument("--transport", default="tcp",
                    choices=["tcp", "thread", "process"],
                    help="tcp is the point of this launcher; the "
                         "shared-memory transports are accepted for "
                         "side-by-side runs")
    ap.add_argument("--schedule", default="ring",
                    choices=list(comm.names()) + ["auto"])
    ap.add_argument("--iters", type=int, default=400)
    ap.add_argument("--eval-every", type=int, default=200)
    ap.add_argument("--eta", type=float, default=0.1)
    ap.add_argument("--rho", type=float, default=0.1)
    ap.add_argument("--tau", type=int, default=1,
                    help="communication period: τ−1 local steps per exchange")
    ap.add_argument("--compression", default="none",
                    choices=["none", "sign_ef"],
                    help="per-link wire codec (sign_ef: 1 bit/element + "
                         "error feedback)")
    ap.add_argument("--sync-plane", default="master",
                    choices=["master", "p2p"],
                    help="sync-family data plane: 'master' centralizes the "
                         "allreduce at the master (Θ(P·N) through its "
                         "links per round); 'p2p' has the workers execute "
                         "Schedule.rounds over direct worker↔worker links "
                         "(master degrades to control plane)")
    ap.add_argument("--emulate", default="none", choices=["wire", "none"],
                    help="'wire': deadline-pace every message under "
                         "costmodel.PS_WIRE on top of the real socket")
    ap.add_argument("--topology", default=None, metavar="HOSTSxSLOTS",
                    help="sync family: emulate a two-level fabric (e.g. 2x8; "
                         "HOSTSxSLOTS must equal --workers). Cross-host "
                         "links pace at --cross-alpha-x/--cross-beta-x "
                         "times the intra-host wire; '--schedule auto' then "
                         "chooses per link class from a measured profile. "
                         "Replaces --emulate wire")
    ap.add_argument("--cross-alpha-x", type=float, default=20.0,
                    help="cross-host latency multiplier for --topology")
    ap.add_argument("--cross-beta-x", type=float, default=4.0,
                    help="cross-host inverse-bandwidth multiplier for "
                         "--topology")
    ap.add_argument("--hosts", default=None,
                    help="comma-separated worker hosts; master binds "
                         "0.0.0.0:--port and waits for them to join "
                         "(omit: spawn localhost workers)")
    ap.add_argument("--port", type=int, default=None,
                    help="fixed rendezvous port (default: 29500 with "
                         "--hosts, ephemeral for localhost runs; pin one "
                         "explicitly so launch.monitor can find the run)")
    ap.add_argument("--ssh", action="store_true",
                    help="with --hosts: launch the printed worker commands "
                         "over ssh instead of just printing them")
    ap.add_argument("--model", default="tiny-mlp",
                    help="training problem (repro.ps.zoo): tiny-mlp "
                         "(default, unchanged), mlp-large, jax-mlp, lenet, "
                         "alexnet, or a repro.configs arch id — e.g. "
                         "gemma3-27b streams a ~5.7 MB reduced LM through "
                         "the wire")
    ap.add_argument("--bucket-bytes", type=int, default=0,
                    help="sync family: bucket the exchange into ~this many "
                         "payload bytes per bucket at layer edges (0 = "
                         "monolithic row). On the p2p plane buckets stream "
                         "while compute runs")
    ap.add_argument("--no-overlap", action="store_true",
                    help="p2p: run the bucketed exchange inline instead of "
                         "pipelined (the no-overlap baseline; math is "
                         "bitwise identical either way)")
    ap.add_argument("--update-backend", default="numpy",
                    choices=["numpy", "pallas"],
                    help="p2p per-bucket update: easgd_flat numpy or the "
                         "fused Pallas elastic-update kernel")
    ap.add_argument("--trace", action="store_true",
                    help="record per-thread spans on every worker and the "
                         "master, merge onto the master clock (obs.clock "
                         "offsets), write trace-<algo>-tcp.json (Perfetto) "
                         "and print the measured time breakdown")
    ap.add_argument("--trace-dir", default=None,
                    help="directory for worker trace spills + the merged "
                         "trace (implies --trace). Multi-host note: spills "
                         "are written on the WORKER's filesystem — leave "
                         "unset to carry trace buffers in-band via BYE")
    ap.add_argument("--telemetry", action="store_true",
                    help="turn on the live plane (obs.live): per-worker "
                         "heartbeat time series, the online straggler/"
                         "health detector, and the STATS frame that "
                         "`python -m repro.launch.monitor` renders")
    ap.add_argument("--telemetry-jsonl", default=None, metavar="PATH",
                    help="stream one JSON line per telemetry sample to "
                         "PATH (implies --telemetry)")
    ap.add_argument("--heartbeat-file", default=None, metavar="PATH",
                    help="touch PATH every ~2 s while the run is alive so "
                         "an external supervisor can detect a hung master "
                         "(ft.Watchdog.is_alive PATH)")
    ap.add_argument("--elastic", action="store_true",
                    help="elastic membership (ft.membership): a worker "
                         "death freezes the superstep, the survivors are "
                         "RECONFIGUREd onto a re-resolved schedule, and a "
                         "respawned worker rejoins at the next epoch. The "
                         "printed respawn one-liner re-execs the worker "
                         "from its REPRO_CLUSTER_SPEC")
    ap.add_argument("--timeout", type=float, default=600.0)
    args = ap.parse_args(argv)

    if args.compression != "none" and args.transport != "tcp":
        ap.error("--compression is a tcp wire feature; the shared-memory "
                 "transports move no frames")
    if args.sync_plane == "p2p" and args.transport != "tcp":
        ap.error("--sync-plane p2p is a tcp feature: the p2p data plane is "
                 "worker↔worker sockets")
    algos = (list(ps.ALGORITHMS) if args.algorithm == "all"
             else [args.algorithm])
    if args.sync_plane == "p2p":
        from repro.core.easgd_flat import SYNC_FAMILY
        bad = [a for a in algos if a not in SYNC_FAMILY]
        if bad:
            ap.error(f"--sync-plane p2p applies to the sync family only; "
                     f"{bad} exchange through the master by definition")
    if args.update_backend == "pallas" and (args.transport != "tcp"
                                            or args.sync_plane != "p2p"):
        ap.error("--update-backend pallas rides the p2p worker loop "
                 "(--transport tcp --sync-plane p2p)")
    if args.elastic and args.transport != "tcp":
        ap.error("--elastic reconfigures real links (tcp only)")
    easgd = EASGDConfig(eta=args.eta, rho=args.rho, mu=0.9, tau=args.tau)
    emulate = costmodel.PS_WIRE if args.emulate == "wire" else None
    topology = None
    if args.topology:
        from repro.core.easgd_flat import SYNC_FAMILY as _SYNC_T
        try:
            t_hosts, t_slots = (int(x)
                                for x in args.topology.lower().split("x"))
        except ValueError:
            ap.error(f"--topology wants HOSTSxSLOTS (e.g. 2x8), got "
                     f"'{args.topology}'")
        if t_hosts * t_slots != args.workers:
            ap.error(f"--topology {t_hosts}x{t_slots} does not tile "
                     f"--workers {args.workers}")
        if args.transport not in ("thread", "tcp"):
            ap.error("--topology needs --transport thread or tcp")
        bad = [a for a in algos if a not in _SYNC_T]
        if bad:
            ap.error(f"--topology prices the sync-family exchange; {bad} "
                     f"are not sync algorithms")
        if args.elastic:
            ap.error("--topology and --elastic are not yet composed (an "
                     "epoch's survivors no longer tile the declared grid)")
        topology = costmodel.emulated_topology(
            t_hosts, t_slots, cross_alpha_x=args.cross_alpha_x,
            cross_beta_x=args.cross_beta_x)
        emulate = None  # topology REPLACES the global emulated wire
    multi_host = bool(args.hosts)
    # --port pins the rendezvous listener even on localhost (so a monitor
    # knows where to connect); without it localhost stays ephemeral
    port = args.port if args.port is not None else (29500 if multi_host
                                                    else 0)
    from repro.ps import zoo
    problem = zoo.resolve(args.model)
    base = ps.PSConfig(
        algorithm=algos[0], n_workers=args.workers,
        transport=args.transport, schedule=args.schedule,
        total_iters=args.iters, eval_every_iters=args.eval_every,
        emulate_net=emulate, wire_compression=args.compression,
        tcp_host="0.0.0.0" if multi_host else "127.0.0.1",
        tcp_port=port,
        spawn_workers=not multi_host,
        sync_plane=args.sync_plane,
        topology=topology,
        bucket_bytes=args.bucket_bytes, overlap=not args.no_overlap,
        update_backend=args.update_backend,
        trace=args.trace or bool(args.trace_dir),
        trace_dir=args.trace_dir,
        telemetry=args.telemetry,
        telemetry_jsonl=args.telemetry_jsonl,
        elastic=args.elastic)
    if port and args.transport == "tcp" and (args.telemetry
                                             or args.telemetry_jsonl):
        print(f"# telemetry: watch with  PYTHONPATH=src python -m "
              f"repro.launch.monitor --connect 127.0.0.1:{port} --follow",
              flush=True)
    watchdog = None
    if args.heartbeat_file:
        from repro.ft.watchdog import Watchdog
        watchdog = Watchdog(heartbeat_path=args.heartbeat_file,
                            install_signals=False, interval_s=2.0)
        watchdog.start_heartbeat()

    results = []
    for algo in algos:
        cfg = dataclasses.replace(base, algorithm=algo)
        ssh_procs = []
        if multi_host:
            hosts = [h for h in args.hosts.split(",") if h]
            addr = _advertised_addr(port)
            p2p = args.sync_plane == "p2p"
            note = ""
            if p2p:
                # pinned peer-listener range so the worker↔worker data
                # plane is firewall-predictable: wid i binds --port+1+i
                note = (f" (p2p data plane: peer listeners bind ports "
                        f"{port + 1}..{port + args.workers})")
            print(f"# master: {algo} on {addr} "
                  f"sync_plane={args.sync_plane}{note}; start each worker:")
            for wid in range(args.workers):
                host = hosts[wid % len(hosts)]
                cmd = worker_command(
                    addr, wid,
                    sync_plane=args.sync_plane if p2p else None,
                    peer_port=port + 1 + wid if p2p else None)
                print(f"#   [{host}] {cmd}")
                if args.elastic:
                    # a respawn is a re-exec from the declarative spec,
                    # not a hand-reconstructed command line
                    mhost, mport = addr.rsplit(":", 1)
                    spec = cluster_spec_env(
                        "worker", wid, mhost, int(mport),
                        sync_plane=args.sync_plane if p2p else None,
                        peer_port=port + 1 + wid if p2p else None)
                    print(f"#   [{host}] respawn: "
                          f"REPRO_CLUSTER_SPEC={shlex.quote(spec)} "
                          f"PYTHONPATH=src python -m repro.net.worker "
                          f"--rejoin")
                if args.ssh:
                    ssh_procs.append(subprocess.Popen(
                        ["ssh", host, *shlex.split(cmd)]))
        try:
            res = ps.run_ps(problem, easgd, cfg,
                            join_timeout_s=args.timeout)
        finally:
            for proc in ssh_procs:
                proc.terminate()
        print(f"{algo:16s} [{res.transport}/{res.schedule}] "
              f"iters={res.total_iters} err={res.final_metric:.3f} "
              f"time={res.total_time_s:.2f}s counters={res.counters}",
              flush=True)
        if res.health is not None:
            n_ev = len(res.health.get("events", []))
            flagged = res.health.get("flagged", {})
            print(f"# health: {n_ev} event(s)"
                  + (f", flagged={flagged}" if flagged else "")
                  + (f", jsonl={args.telemetry_jsonl}"
                     if args.telemetry_jsonl else ""), flush=True)
            for ev in res.health.get("events", [])[-5:]:
                print(f"#   {ev}", flush=True)
        if res.trace is not None:
            from repro.launch.train import _report_trace
            _report_trace(res, algo, args.trace_dir)
        results.append(res)
    if watchdog is not None:
        watchdog.close()
    return results


if __name__ == "__main__":
    main()
