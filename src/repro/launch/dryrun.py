import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell and
extract the roofline terms from the compiled artifact.

MUST be run as a script/module so the XLA_FLAGS lines above execute before
any jax import (jax locks the device count on first init):

    PYTHONPATH=src python -m repro.launch.dryrun --arch gemma3-4b \
        --shape train_4k --mesh pod --out results/dryrun.jsonl
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both

Per cell we record:
  * compiled.memory_analysis()  — per-device bytes (proves it fits 16 GB)
  * compiled.cost_analysis()    — HLO FLOPs / bytes accessed (per device)
  * collective bytes parsed from the optimized HLO (launch.hloparse)
  * the three roofline terms + MODEL_FLOPS = 6·N_active·D (core.costmodel)
"""
import argparse
import dataclasses
import json
import sys
import time
import traceback

import jax
import jax.numpy as jnp

from repro import configs
from repro.core import costmodel
from repro.core.easgd import EASGDConfig
from repro.core.elastic import ElasticConfig
from repro.launch import hloparse
from repro.launch.mesh import make_production_mesh, n_pods_of
from repro.models import transformer as tfm
from repro.models.common import abstract_params
from repro.runtime.serve import build_serve_steps, _extra_kwargs
from repro.runtime.train import build_train_step, make_batch_defs


def count_params(cfg):
    """(total, active) parameter counts from the abstract defs."""
    defs = tfm.model_defs(cfg)
    total = active = 0
    leaves = jax.tree_util.tree_leaves(
        defs, is_leaf=lambda x: hasattr(x, "logical"))
    for d in leaves:
        n = 1
        for s in d.shape:
            n *= s
        total += n
        if cfg.moe is not None and "experts" in d.logical:
            active += n * cfg.moe.top_k / cfg.moe.n_experts
        else:
            active += n
    return int(total), int(active)


def make_elastic_config(spec, *, overrides=None) -> ElasticConfig:
    kw = dict(
        easgd=EASGDConfig(eta=0.01, rho=0.01, mu=0.9, tau=1),
        mode="sync_easgd",
        packed=True,
        overlap=True,
        momentum_dtype=spec.momentum_dtype,
        center_dtype=spec.center_dtype,
    )
    kw.update(overrides or {})
    return ElasticConfig(**kw)


def lower_cell(arch_id: str, shape_id: str, mesh, *, elastic_overrides=None,
               cfg_override=None, microbatches_override=None):
    """Lower (but don't compile) one cell. Returns (lowered, meta)."""
    spec = configs.get(arch_id)
    cfg = cfg_override or spec.config
    shape = configs.SHAPES[shape_id]
    n_pods = n_pods_of(mesh)
    meta = dict(arch=arch_id, shape=shape_id,
                mesh="x".join(map(str, mesh.devices.shape)),
                n_devices=int(mesh.devices.size),
                n_pods=max(n_pods, 1))
    ecfg = None                          # train cells set it below

    if shape["kind"] == "train":
        gb, seq = shape["global_batch"], shape["seq"]
        assert gb % n_pods == 0
        ecfg = make_elastic_config(spec, overrides=elastic_overrides)
        per_pod = gb // n_pods
        data_size = dict(zip(mesh.axis_names,
                             mesh.devices.shape)).get("data", 1)
        # the per-microbatch batch must still divide the data axis, or the
        # batch dim replicates and per-device compute multiplies
        mb = microbatches_override or spec.train_microbatches
        while mb > 1 and (per_pod % mb or (per_pod // mb) % data_size):
            mb //= 2
        build = build_train_step(cfg, ecfg, mesh, n_pods=n_pods,
                                 per_pod_batch=per_pod, seq=seq,
                                 microbatches=mb)
        batch = make_batch_defs(cfg, n_pods, per_pod, seq)
        meta["microbatches"] = mb
        lowered = build.step.lower(build.abstract_state, batch)
        meta["tokens"] = gb * seq
        meta["step"] = "train_step"
    elif shape["kind"] == "prefill":
        b, seq = shape["global_batch"], shape["seq"]
        build = build_serve_steps(cfg, mesh, batch=b, max_len=seq)
        tokens = jax.ShapeDtypeStruct((b, seq), jnp.int32)
        extras = _extra_kwargs(cfg, b, seq)
        lowered = build.prefill.lower(build.abstract_params, tokens, extras)
        meta["tokens"] = b * seq
        meta["step"] = "prefill"
    else:  # decode
        b, seq = shape["global_batch"], shape["seq"]
        build = build_serve_steps(cfg, mesh, batch=b, max_len=seq)
        token = jax.ShapeDtypeStruct((b, 1), jnp.int32)
        pos = jax.ShapeDtypeStruct((b,), jnp.int32)
        extras = _extra_kwargs(cfg, b, 1)
        lowered = build.decode.lower(build.abstract_params,
                                     build.abstract_caches, token, pos,
                                     extras)
        meta["tokens"] = b  # one new token per sequence
        meta["step"] = "decode_step"
    return lowered, meta, cfg, ecfg


def analyze(compiled, meta, cfg, chips: int, ecfg=None):
    rec = dict(meta)
    # --- memory ------------------------------------------------------------
    try:
        ma = compiled.memory_analysis()
        for field in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "generated_code_size_in_bytes",
                      "alias_size_in_bytes"):
            v = getattr(ma, field, None)
            if v is not None:
                rec[field] = int(v)
        live = (rec.get("argument_size_in_bytes", 0)
                + rec.get("temp_size_in_bytes", 0)
                + rec.get("output_size_in_bytes", 0)
                - rec.get("alias_size_in_bytes", 0))
        rec["peak_bytes_per_device"] = int(live)
        rec["fits_16gb"] = bool(live < 16 * 1024**3)
    except Exception as e:  # pragma: no cover
        rec["memory_analysis_error"] = repr(e)

    # --- cost (XLA's own numbers — NOT loop-aware, kept for reference) -----
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        rec["xla_cost_flops"] = float(ca.get("flops", 0.0))
        rec["xla_cost_bytes"] = float(ca.get("bytes accessed", 0.0))
    except Exception as e:  # pragma: no cover
        rec["cost_analysis_error"] = repr(e)

    # --- loop-aware HLO walk: flops, bytes, collectives ---------------------
    try:
        text = compiled.as_text()
        # on the multipod mesh, device ids 0..255 = pod0, 256..511 = pod1:
        # collectives whose replica groups span the stride cross pods (DCI)
        pod_stride = 256 if chips == 512 else 0
        costs = hloparse.parse_costs(text, pod_stride=pod_stride)
        rec["hlo_flops_per_device"] = float(costs.flops)
        rec["hlo_bytes_per_device"] = float(costs.bytes)
        rec["collective_bytes_by_type"] = costs.bytes_by_collective
        rec["collective_counts"] = costs.counts_by_collective
        rec["collective_bytes_per_device"] = int(costs.collective_bytes)
        rec["cross_pod_bytes_per_device"] = int(costs.cross_pod_bytes)
        rec["collective_bytes_by_dtype"] = costs.collective_bytes_by_dtype
        rec["cross_pod_bytes_by_dtype"] = costs.cross_pod_bytes_by_dtype
        rec["hlo_text_bytes"] = len(text)
        del text
    except Exception as e:  # pragma: no cover
        rec["hlo_parse_error"] = repr(e)

    # --- roofline ------------------------------------------------------------
    n_total, n_active = count_params(cfg)
    rec["n_params"] = n_total
    rec["n_params_active"] = n_active
    rec["param_bytes"] = int(n_total * jnp.dtype(cfg.param_dtype).itemsize)
    flops_fn = (costmodel.model_flops_train if meta["step"] == "train_step"
                else costmodel.model_flops_infer)
    rec["model_flops"] = flops_fn(n_active, meta["tokens"])

    hlo_flops = rec.get("hlo_flops_per_device", 0.0) * chips
    hlo_bytes = rec.get("hlo_bytes_per_device", 0.0) * chips
    coll_bytes = rec.get("collective_bytes_per_device", 0) * chips
    rl = costmodel.roofline(hlo_flops, hlo_bytes, coll_bytes, chips)
    rec["roofline"] = dict(
        compute_s=rl.compute_s, memory_s=rl.memory_s,
        collective_s=rl.collective_s, dominant=rl.dominant,
        bound_s=rl.bound_s,
        # cross-pod portion over the slow DCI links (multipod mesh only)
        cross_pod_s=(rec.get("cross_pod_bytes_per_device", 0)
                     * costmodel.TPU_DCI.beta),
    )
    rec["useful_flops_ratio"] = (
        rec["model_flops"] / hlo_flops if hlo_flops else 0.0)

    # --- post-compression wire accounting (train cells) ---------------------
    # the α–β model's jit accounting (sign_ef = int8 on the collective) and
    # the HLO's parsed cross-pod bytes must AGREE — this record makes the
    # comparison part of every dry-run, and shows the auto-schedule choice
    # made from the very same byte count.
    if ecfg is not None:
        from repro.core import compression as compression_lib
        comp = compression_lib.get(ecfg.compression)
        n_pods = max(int(meta.get("n_pods", 1)), 1)   # mesh-derived, not
        devices_per_pod = chips // n_pods             # a topology guess
        shard_elems = -(-n_total // devices_per_pod)
        model_bytes = shard_elems * comp.jit_wire_bytes_per_element
        hlo_bytes = rec.get("cross_pod_bytes_per_device", 0)
        rec["wire_model"] = {
            "compression": comp.name,
            "jit_bytes_per_element": comp.jit_wire_bytes_per_element,
            "framed_bytes_per_element": comp.wire_bytes_per_element,
            "cross_pod_model_bytes_per_device": model_bytes,
            "cross_pod_hlo_bytes_per_device": hlo_bytes,
            "hlo_over_model": (hlo_bytes / model_bytes if model_bytes
                               else None),
            # resolved EXACTLY like the training path does (runtime/train.py
            # passes the full model element count — each pod exchanges the
            # whole packed model), so this names the schedule a real run
            # with schedule="auto" would execute
            "auto_schedule_choice": ecfg.resolve_schedule(n_pods, n_total),
        }
    # roofline fraction: ideal model-flops time / achievable bound
    ideal_s = rec["model_flops"] / (chips * costmodel.TPU_V5E.peak_flops)
    rec["roofline_fraction"] = ideal_s / rl.bound_s if rl.bound_s else 0.0
    return rec


def run_cell(arch_id, shape_id, mesh_kind, out_path=None,
             elastic_overrides=None, variant="baseline", cfg_override=None,
             microbatches_override=None):
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multipod"))
    chips = int(mesh.devices.size)
    t0 = time.time()
    rec = dict(arch=arch_id, shape=shape_id, mesh_kind=mesh_kind,
               variant=variant)
    try:
        lowered, meta, cfg, ecfg = lower_cell(
            arch_id, shape_id, mesh, elastic_overrides=elastic_overrides,
            cfg_override=cfg_override,
            microbatches_override=microbatches_override)
        rec.update(meta)
        rec["lower_s"] = time.time() - t0
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = time.time() - t1
        rec.update(analyze(compiled, meta, cfg, chips, ecfg=ecfg))
        rec["ok"] = True
        del compiled, lowered
    except Exception as e:
        rec["ok"] = False
        rec["error"] = repr(e)
        rec["traceback"] = traceback.format_exc()[-2000:]
    rec["total_s"] = time.time() - t0
    if out_path:
        with open(out_path, "a") as f:
            f.write(json.dumps(rec) + "\n")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="pod", choices=["pod", "multipod",
                                                      "both"])
    ap.add_argument("--schedule", default=None,
                    help="override the cross-pod exchange schedule "
                         "(repro.comm registry name)")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/dryrun.jsonl")
    ap.add_argument("--skip-done", action="store_true")
    args = ap.parse_args()

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    done = set()
    if args.skip_done and os.path.exists(args.out):
        for line in open(args.out):
            try:
                r = json.loads(line)
                if r.get("ok"):
                    done.add((r["arch"], r["shape"], r["mesh_kind"]))
            except json.JSONDecodeError:
                pass

    cells = []
    if args.all:
        for aid, shape_id, supported in configs.cells():
            if supported:
                cells.append((aid, shape_id))
    else:
        assert args.arch and args.shape
        cells.append((args.arch, args.shape))

    meshes = ["pod", "multipod"] if args.mesh == "both" else [args.mesh]
    overrides = {"schedule": args.schedule} if args.schedule else None
    for aid, shape_id in cells:
        for mk in meshes:
            if (aid, shape_id, mk) in done:
                print(f"SKIP {aid} {shape_id} {mk} (done)", flush=True)
                continue
            print(f"=== {aid} × {shape_id} × {mk} ===", flush=True)
            rec = run_cell(aid, shape_id, mk, args.out,
                           elastic_overrides=overrides)
            if rec["ok"]:
                rl = rec["roofline"]
                print(f"  ok  compile={rec['compile_s']:.0f}s "
                      f"peak={rec.get('peak_bytes_per_device', 0)/2**30:.2f}GiB "
                      f"dom={rl['dominant']} "
                      f"terms=({rl['compute_s']:.2e},{rl['memory_s']:.2e},"
                      f"{rl['collective_s']:.2e})s "
                      f"frac={rec['roofline_fraction']:.2f}", flush=True)
            else:
                print(f"  FAIL {rec['error']}", flush=True)


if __name__ == "__main__":
    main()
