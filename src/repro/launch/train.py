"""Training driver: data pipeline → jitted Sync-EASGD step → checkpoints,
with preemption watchdog and elastic-restart support.

    PYTHONPATH=src python -m repro.launch.train --arch gemma3-4b \
        --reduced --steps 50 --batch 8 --seq 64 --ckpt-dir /tmp/run1

``--mode ps`` instead runs the repro.ps parameter-server runtime: any of
the paper's nine algorithms (or ``--algorithm all``) executed for real on
the thread or multiprocessing transport, with measured vs DES-predicted
per-iteration time printed side by side:

    PYTHONPATH=src python -m repro.launch.train --mode ps \
        --algorithm hogwild_easgd --transport thread --ps-workers 4

On this CPU container use --reduced; on a real cluster drop it and point
--mesh at the production topology.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro import comm, configs
from repro.checkpoint import CheckpointManager
from repro.core.easgd import EASGDConfig
from repro.core.elastic import ElasticConfig
from repro.data import ShardedPipeline, SyntheticLMStream
from repro.ft import Watchdog
from repro.launch.mesh import make_host_mesh, n_pods_of
from repro.runtime.train import build_train_step


def run_ps_mode(args) -> list:
    """--mode ps: execute algorithms on the real parameter-server runtime
    and cross-check the measured clock against the calibrated DES."""
    import dataclasses as _dc

    from repro import ps
    from repro.core import costmodel

    from repro.ps import zoo

    algos = (list(ps.ALGORITHMS) if args.algorithm == "all"
             else [args.algorithm])
    easgd = EASGDConfig(eta=args.eta, rho=args.rho, mu=0.9, tau=args.tau)
    net = costmodel.PS_WIRE if args.emulate == "wire" else None
    wire_codec = args.compression if args.transport == "tcp" else "none"
    if wire_codec not in ("none", "sign_ef"):
        raise SystemExit(
            f"--mode ps --transport tcp supports wire compression "
            f"none|sign_ef, got '{wire_codec}'")
    if args.sync_plane == "p2p" and args.transport != "tcp":
        raise SystemExit("--sync-plane p2p needs --transport tcp (the p2p "
                         "data plane is worker↔worker sockets)")
    problem = zoo.resolve(args.model)
    topology = None
    if args.topology:
        from repro.core.easgd_flat import SYNC_FAMILY as _SYNC_T
        try:
            hosts, slots = (int(x) for x in args.topology.lower().split("x"))
        except ValueError:
            raise SystemExit(f"--topology wants HOSTSxSLOTS (e.g. 2x8), "
                             f"got '{args.topology}'")
        if hosts * slots != args.ps_workers:
            raise SystemExit(f"--topology {hosts}x{slots} does not tile "
                             f"--ps-workers {args.ps_workers}")
        if args.transport not in ("thread", "tcp"):
            raise SystemExit("--topology needs --transport thread or tcp "
                             "(per-link pacing lives on those planes)")
        topology = costmodel.emulated_topology(
            hosts, slots, cross_alpha_x=args.cross_alpha_x,
            cross_beta_x=args.cross_beta_x)
        algos = [a for a in algos if a in _SYNC_T]
        if not algos:
            raise SystemExit("--topology prices the sync-family exchange — "
                             "pick a sync_* algorithm (or 'all')")
        net = None      # topology REPLACES the global emulated wire
    base = ps.PSConfig(
        algorithm=algos[0], n_workers=args.ps_workers,
        transport=args.transport, schedule=args.schedule or "ring",
        total_iters=args.ps_iters, eval_every_iters=args.ps_eval_every,
        emulate_net=net, wire_compression=wire_codec,
        bucket_bytes=args.bucket_bytes, overlap=not args.no_overlap,
        topology=topology,
        trace=args.trace or bool(args.trace_dir),
        trace_dir=args.trace_dir)
    cal = ps.calibrate(problem, base)
    out = []
    from repro.core.easgd_flat import SYNC_FAMILY as _SYNC
    for algo in algos:
        # the p2p plane only exists for the sync family; `--algorithm all
        # --sync-plane p2p` runs the rest through the master as usual —
        # and the fused-kernel update path rides the p2p worker loop only
        plane = args.sync_plane if algo in _SYNC else "master"
        backend = args.update_backend if plane == "p2p" else "numpy"
        cfg = _dc.replace(base, algorithm=algo, sync_plane=plane,
                          update_backend=backend)
        res, _, rec = ps.run_vs_des(problem, easgd, cfg, cal=cal)
        print(f"{algo:16s} [{res.transport}/{res.schedule}] "
              f"iters={res.total_iters} err={res.final_metric:.3f} "
              f"measured={rec['measured_us_per_iter']:.1f}us/iter "
              f"des={rec['des_us_per_iter']:.1f}us/iter "
              f"ratio={rec['measured_over_des']:.2f} "
              f"counters={res.counters}", flush=True)
        if res.trace is not None:
            _report_trace(res, algo, args.trace_dir)
        out.append(res)
    return out


def _report_trace(res, algo: str, trace_dir) -> None:
    """Write the merged Chrome trace next to the run and print the measured
    time breakdown (open the .json at https://ui.perfetto.dev)."""
    import os as _os

    from repro.obs import report as obs_report

    rep = res.trace.get("report", {})
    out_dir = trace_dir or "."
    path = _os.path.join(out_dir, f"trace-{algo}-{res.transport}.json")
    obs_report.write_chrome_trace(path, res.trace)
    print(f"{algo:16s} trace: comm={rep.get('mean_comm_share', 0):.1%} "
          f"compute={rep.get('mean_compute_share', 0):.1%} "
          f"update={rep.get('mean_update_share', 0):.1%} -> {path}",
          flush=True)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", default="sync", choices=["sync", "ps"],
                    help="sync: jitted multi-pod Sync-EASGD (default); "
                         "ps: real parameter-server runtime (repro.ps)")
    ap.add_argument("--arch", default="gemma3-4b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8,
                    help="global batch (sequences)")
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--n-pods", type=int, default=1)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--eta", type=float, default=0.02)
    ap.add_argument("--rho", type=float, default=0.01)
    ap.add_argument("--tau", type=int, default=1)
    ap.add_argument("--schedule", default=None,
                    choices=list(comm.names()) + ["auto"],
                    help="cross-pod exchange schedule (repro.comm registry; "
                         "'auto' picks via comm.choose from buffer size and "
                         "pod count at build time). Default: psum in sync "
                         "mode, ring in ps mode")
    # --mode ps options (repro.ps runtime)
    ap.add_argument("--algorithm", default="all",
                    help="ps algorithm (core.async_engine.ALGORITHMS) or "
                         "'all'")
    ap.add_argument("--transport", default="thread",
                    choices=["thread", "process", "tcp"],
                    help="ps worker substrate: in-process threads, spawned "
                         "multiprocessing on shared memory, or the "
                         "repro.net TCP transport (real sockets; "
                         "launch/cluster adds multi-host)")
    ap.add_argument("--ps-workers", type=int, default=4)
    ap.add_argument("--ps-iters", type=int, default=400)
    ap.add_argument("--ps-eval-every", type=int, default=200)
    ap.add_argument("--model", default="tiny-mlp",
                    help="ps training problem (repro.ps.zoo): tiny-mlp "
                         "(default, unchanged), mlp-large, jax-mlp, lenet, "
                         "alexnet, or any repro.configs arch id (e.g. "
                         "gemma3-4b — a real reduced-config LM on the wire)")
    ap.add_argument("--bucket-bytes", type=int, default=0,
                    help="bucket the sync-family exchange into ~this many "
                         "payload bytes per bucket, cut at layer edges "
                         "(0 = monolithic). With the tcp p2p plane buckets "
                         "stream while compute runs — bitwise-identical "
                         "math, overlapped wire")
    ap.add_argument("--update-backend", default="numpy",
                    choices=["numpy", "pallas"],
                    help="p2p per-bucket update path: easgd_flat numpy or "
                         "the fused Pallas elastic-update kernel (bitwise "
                         "under the worker's pinned XLA flags)")
    ap.add_argument("--sync-plane", default="master",
                    choices=["master", "p2p"],
                    help="tcp sync family: 'p2p' executes Schedule.rounds "
                         "over direct worker↔worker links (the master "
                         "becomes control plane — see repro.net.peer)")
    ap.add_argument("--topology", default=None, metavar="HOSTSxSLOTS",
                    help="ps sync family: emulate a two-level fabric (e.g. "
                         "2x8 = 2 hosts x 8 slots; HOSTSxSLOTS must equal "
                         "--ps-workers). Cross-host links cost "
                         "--cross-alpha-x/--cross-beta-x times the "
                         "intra-host wire; pacing, schedule choice "
                         "(--schedule auto) and byte counters all become "
                         "per-link-class. Replaces --emulate")
    ap.add_argument("--cross-alpha-x", type=float, default=20.0,
                    help="cross-host latency multiplier for --topology "
                         "(default 20)")
    ap.add_argument("--cross-beta-x", type=float, default=4.0,
                    help="cross-host inverse-bandwidth multiplier for "
                         "--topology (default 4)")
    ap.add_argument("--emulate", default="wire", choices=["wire", "none"],
                    help="ps wire emulation: 'wire' sleeps each message's "
                         "α+nβ under costmodel.PS_WIRE (paper's regime); "
                         "'none' uses raw shared memory")
    ap.add_argument("--no-overlap", action="store_true",
                    help="disable compute/comm overlap (Sync EASGD1/2 "
                         "baseline, paper §6.1.3)")
    ap.add_argument("--trace", action="store_true",
                    help="record per-thread spans in every worker "
                         "(repro.obs), merge them onto the master clock, "
                         "and write a Perfetto-loadable trace-<algo>.json "
                         "plus a measured comm/compute/update breakdown")
    ap.add_argument("--trace-dir", default=None,
                    help="directory for trace spill files and the merged "
                         "trace JSON (implies --trace; default: BYE frames "
                         "carry buffers in-band, trace written to cwd)")
    ap.add_argument("--compression", default="none")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--log-every", type=int, default=5)
    args = ap.parse_args(argv)

    if args.mode == "ps":
        return run_ps_mode(args)

    args.schedule = args.schedule or "psum"
    spec = configs.get(args.arch)
    cfg = spec.reduced if args.reduced else spec.config
    n_dev = jax.device_count()
    mesh = make_host_mesh(n_data=max(1, n_dev // max(args.n_pods, 1)),
                          n_model=1,
                          n_pods=args.n_pods if args.n_pods > 1 else 0)
    n_pods = n_pods_of(mesh) if args.n_pods > 1 else args.n_pods

    ecfg = ElasticConfig(
        easgd=EASGDConfig(eta=args.eta, rho=args.rho, mu=0.9, tau=args.tau),
        schedule=args.schedule,
        overlap=not args.no_overlap,
        compression=args.compression,
        momentum_dtype=spec.momentum_dtype,
        center_dtype=spec.center_dtype,
    )
    print(f"exchange: schedule={args.schedule} "
          f"compression={args.compression} "
          f"overlap={not args.no_overlap} n_pods={n_pods}", flush=True)
    per_pod = args.batch // n_pods
    build = build_train_step(cfg, ecfg, mesh, n_pods=n_pods,
                             per_pod_batch=per_pod, seq=args.seq,
                             microbatches=args.microbatches)
    state = build.init_state()

    pipe = ShardedPipeline(
        lambda shard, n: SyntheticLMStream(cfg.vocab_size, args.seq, per_pod,
                                           seed=13, shard=shard, n_shards=n),
        n_pods=n_pods).start()

    ckpt = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    start_step = 0
    if ckpt and ckpt.latest_step() is not None:
        state, meta = ckpt.restore(state)
        start_step = meta["extra"]["data_step"]
        pipe.restore(start_step)
        print(f"resumed from step {start_step}")

    wd = Watchdog().start_heartbeat()
    t0 = time.time()
    losses = []
    step = start_step
    try:
        for step in range(start_step, args.steps):
            if wd.should_stop.is_set():
                print("preemption signal — checkpoint + clean exit")
                break
            batch = {k: jax.numpy.asarray(v) for k, v in pipe.next().items()}
            state, metrics = build.step(state, batch)
            losses.append(float(metrics["loss"]))
            if step % args.log_every == 0:
                print(f"step {step:5d} loss {losses[-1]:.4f} "
                      f"acc {float(metrics['accuracy']):.3f} "
                      f"({time.time()-t0:.1f}s)", flush=True)
            if ckpt and step and step % args.ckpt_every == 0:
                ckpt.save_async(step, state, extra={"data_step": step + 1})
    finally:
        pipe.stop()
        if ckpt:
            ckpt.wait()
            ckpt.save(step, state, extra={"data_step": step + 1})
        wd.close()
    if len(losses) > 10:
        first = np.mean(losses[:5])
        last = np.mean(losses[-5:])
        print(f"loss {first:.4f} -> {last:.4f} "
              f"({'improved' if last < first else 'NOT improved'})")
    return losses


if __name__ == "__main__":
    main()
