import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""§Perf hillclimb driver: run labeled variants of the three chosen cells
(hypothesis → change → re-lower → re-analyse) and append each record to
results/perf.jsonl. The narrative (hypothesis + confirmed/refuted) lives in
EXPERIMENTS.md §Perf; this produces the measurements.

    PYTHONPATH=src python -m repro.launch.perf [--only gemma3-4b]
"""
import argparse
import dataclasses
import json

from repro import configs
from repro.core.easgd import EASGDConfig
from repro.launch.dryrun import run_cell


def variants():
    """(cell, variant_name, elastic_overrides, cfg_transform[, mb]) tuples."""
    v = []

    # --- cell A: gemma3-4b × train_4k × pod (worst useful-flops ratio,
    #     memory-dominated) ------------------------------------------------
    A = ("gemma3-4b", "train_4k", "pod")
    v.append((A, "A1_bigger_attn_blocks", None,
              lambda c: dataclasses.replace(c, attn_q_block=1024,
                                            attn_kv_block=4096)))
    v.append((A, "A2_bigger_loss_chunks", None,
              lambda c: dataclasses.replace(c, loss_chunk=131072)))
    v.append((A, "A3_both", None,
              lambda c: dataclasses.replace(c, attn_q_block=1024,
                                            attn_kv_block=4096,
                                            loss_chunk=131072)))
    v.append((A, "A4_blocks_mb4", None,
              lambda c: dataclasses.replace(c, attn_q_block=1024,
                                            attn_kv_block=4096), 4))
    v.append((A, "A5_blocks_remat_dots", None,
              lambda c: dataclasses.replace(c, attn_q_block=1024,
                                            attn_kv_block=4096,
                                            remat="none"), 8))

    # --- cell B: deepseek-v2 × train_4k × pod (most collective-bound) ----
    B = ("deepseek-v2-236b", "train_4k", "pod")
    v.append((B, "B1_no_ep_expert_tp", None,
              lambda c: dataclasses.replace(c, moe_ep=False)))
    v.append((B, "B2_capacity_1.0", None,
              lambda c: dataclasses.replace(
                  c, moe=dataclasses.replace(c.moe, capacity_factor=1.0))))
    v.append((B, "B3_ep_and_cap1_bigblocks", None,
              lambda c: dataclasses.replace(
                  c, moe=dataclasses.replace(c.moe, capacity_factor=1.0),
                  attn_q_block=1024, attn_kv_block=4096)))

    # --- cell C: gemma3-27b × train_4k × multipod (the paper's technique:
    #     cross-pod elastic exchange) --------------------------------------
    C = ("gemma3-27b", "train_4k", "multipod")
    v.append((C, "C0_unpacked_nooverlap",
              dict(packed=False, overlap=False), None))
    v.append((C, "C1_packed_nooverlap",
              dict(packed=True, overlap=False), None))
    # C2 == the baseline already in dryrun.jsonl (packed+overlap)
    v.append((C, "C3_packed_overlap_signef",
              dict(compression="sign_ef"), None))
    v.append((C, "C4_msgd_plain_dp",
              dict(mode="msgd"), None))
    return v


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--out", default="results/perf.jsonl")
    args = ap.parse_args()
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)

    done = set()
    if os.path.exists(args.out):
        for line in open(args.out):
            try:
                r = json.loads(line)
                if r.get("ok"):
                    done.add(r.get("variant"))
            except json.JSONDecodeError:
                pass

    for item in variants():
        (arch, shape, mesh_kind), name, eo, cfg_tf = item[:4]
        mb = item[4] if len(item) > 4 else None
        if args.only and args.only not in arch:
            continue
        if name in done:
            print(f"SKIP {name}")
            continue
        cfg = configs.get(arch).config
        cfg2 = cfg_tf(cfg) if cfg_tf else None
        print(f"=== {name}: {arch} × {shape} × {mesh_kind} ===", flush=True)
        rec = run_cell(arch, shape, mesh_kind, args.out,
                       elastic_overrides=eo, variant=name, cfg_override=cfg2,
                       microbatches_override=mb)
        if rec["ok"]:
            rl = rec["roofline"]
            print(f"  c={rl['compute_s']:.2f} m={rl['memory_s']:.2f} "
                  f"n={rl['collective_s']:.2f} peak="
                  f"{rec['peak_bytes_per_device']/2**30:.1f}GiB "
                  f"useful={rec['useful_flops_ratio']:.3f}", flush=True)
        else:
            print(f"  FAIL {rec['error'][:300]}", flush=True)


if __name__ == "__main__":
    main()
