"""Loop-aware cost extraction from optimized HLO text.

XLA's ``compiled.cost_analysis()`` counts a ``while`` body ONCE, so for
scan-heavy programs (layers, microbatches, attention blocks) its FLOPs and
bytes are under-counted by the product of trip counts (verified: a
10-iteration scan of matmuls reports 10× fewer flops than its unrolled
twin). Collective bytes aren't reported at all. This module walks the HLO
text and produces trip-count-aware totals:

 * ``flops``            — 2·M·N·K for every dot (+ conv), × loop trips
 * ``bytes``            — operands+results of every instruction (HBM-traffic
                          proxy; fusion bodies are internal and skipped)
 * ``collective bytes`` — result sizes of all-reduce / all-gather /
                          reduce-scatter / all-to-all / collective-permute

Trip counts come from the while condition's comparison constant. Validated
against cost_analysis on loop-free graphs (tests/test_hloparse.py).
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

# ops whose "bytes" are bookkeeping, not HBM traffic
_FREE_OPS = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "after-all", "partition-id", "replica-id", "iota", "copy-start",
    "copy-done",
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
# op token: first lowercase word directly followed by '(' in the RHS —
# result types (even nested tuples) never contain `word(` sequences.
_OP_RE = re.compile(r"(?:^|\s)([a-z][a-z0-9\-]*)(?:\.\d+)?\(")
_COMP_START_RE = re.compile(r"^\s*(?:ENTRY\s+)?%?([\w.\-]+)[\s(].*\{\s*$")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_ATTR_COMP_RE = re.compile(
    r"(?:to_apply|calls|condition|body|branch_computations)="
    r"\s*\{?%?([\w.\-]+(?:\s*,\s*%?[\w.\-]+)*)\}?")
_DIMS_RE = {
    "lhs_contracting": re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}"),
    "lhs_batch": re.compile(r"lhs_batch_dims=\{([0-9,]*)\}"),
}


def _shapes_of(type_str):
    """[(dtype, [dims...]), ...] for a (possibly tuple) HLO type string."""
    out = []
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        out.append((dt, [int(d) for d in dims.split(",")] if dims else []))
    return out


def _tensor_bytes(type_str) -> int:
    total = 0
    for dt, dims in _shapes_of(type_str):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


def _tensor_bytes_by_dtype(type_str) -> dict:
    """dtype -> bytes for a (possibly tuple) HLO type string."""
    out = {}
    for dt, dims in _shapes_of(type_str):
        n = 1
        for d in dims:
            n *= d
        out[dt] = out.get(dt, 0) + n * _DTYPE_BYTES[dt]
    return out


def _prod(xs):
    n = 1
    for x in xs:
        n *= x
    return n


@dataclasses.dataclass
class HloCosts:
    flops: float
    bytes: float
    collective_bytes: float
    bytes_by_collective: dict
    counts_by_collective: dict
    while_trip_counts: dict
    cross_pod_bytes: float = 0.0     # collectives whose replica groups span
    #                                  pods (device ids ≥ pod_stride apart)
    # post-compression accounting: collective payload bytes split by element
    # dtype, so a sign-EF exchange (int8 signs + f32 scale) is visible as
    # such — this is what lets the dry-run report agree with the α–β
    # model's jit_wire_bytes_per_element (comm.choose's auto decision)
    collective_bytes_by_dtype: dict = dataclasses.field(default_factory=dict)
    cross_pod_bytes_by_dtype: dict = dataclasses.field(default_factory=dict)


_GROUPS_RE = re.compile(r"replica_groups=\{?\{([0-9,{} ]*)\}")
_GROUPS_IOTA_RE = re.compile(
    r"replica_groups=\[([0-9,]+)\]<=\[([0-9,]+)\](?:T\(([0-9,]+)\))?")


def _crosses_pods(line: str, pod_stride: int) -> bool:
    """True if any replica group contains device ids in different pods.
    Handles both explicit groups ({{0,256},{1,257}}) and the iota form
    ([2,256]<=[512] or <=[2,16,16]T(1,0,2))."""
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        import numpy as _np
        gshape = [int(x) for x in m.group(1).split(",")]
        ishape = [int(x) for x in m.group(2).split(",")]
        ids = _np.arange(int(_np.prod(ishape))).reshape(ishape)
        if m.group(3):
            ids = ids.transpose([int(x) for x in m.group(3).split(",")])
        ids = ids.reshape(gshape)
        per_group = ids.reshape(gshape[0], -1)
        pods = per_group // pod_stride
        return bool((pods.max(axis=1) != pods.min(axis=1)).any())
    m = _GROUPS_RE.search(line)
    if not m:
        return False
    for grp in m.group(1).split("}"):
        ids = [int(x) for x in re.findall(r"\d+", grp)]
        if ids and (max(ids) // pod_stride) != (min(ids) // pod_stride):
            return True
    return False


@dataclasses.dataclass
class _Instr:
    name: str
    type_str: str
    op: str
    operands: list
    line: str


def _split_computations(text: str) -> dict:
    comps: dict[str, list] = {}
    cur = None
    depth = 0
    for line in text.splitlines():
        if cur is None:
            m = _COMP_START_RE.match(line)
            if m and "{" in line:
                cur = m.group(1)
                comps[cur] = []
                depth = line.count("{") - line.count("}")
                if depth <= 0:
                    cur = None
        else:
            depth += line.count("{") - line.count("}")
            if depth <= 0:
                cur = None
            else:
                comps[cur].append(line)
    return comps


def _parse_instrs(lines):
    out = []
    for ln in lines:
        if "=" not in ln:
            continue
        lhs, _, rhs = ln.partition("=")
        lhs = lhs.replace("ROOT", "").strip().lstrip("%")
        if not lhs or " " in lhs:
            continue
        m = _OP_RE.search(rhs)
        if not m:
            continue
        op = m.group(1)
        type_str = rhs[: m.start()]
        args = rhs[m.end(): rhs.find(")", m.end())]
        # older XLA text spells operands WITH their types
        # (``dot(f32[8,256]{1,0} %Arg_0.1, ...)``) — when % markers are
        # present, they identify the operand names exactly; otherwise the
        # args are bare names.
        operands = re.findall(r"%([\w.\-]+)", args) or \
            re.findall(r"([\w.\-]+)", args)
        out.append(_Instr(lhs, type_str, op, operands, rhs))
    return out


def _dot_flops(instr: _Instr, symbols: dict) -> float:
    """2 × prod(result dims) × prod(contracting dims)."""
    res_shapes = _shapes_of(instr.type_str)
    if not res_shapes:
        return 0.0
    out_elems = _prod(res_shapes[0][1])
    m = _DIMS_RE["lhs_contracting"].search(instr.line)
    if not m:
        return 2.0 * out_elems  # dot without attrs — degenerate
    lhs_name = instr.operands[0] if instr.operands else None
    lhs_dims = symbols.get(lhs_name, (None, []))[1]
    k = 1
    if m.group(1):
        for di in m.group(1).split(","):
            di = int(di)
            if di < len(lhs_dims):
                k *= lhs_dims[di]
    return 2.0 * out_elems * k


def _conv_flops(instr: _Instr, symbols: dict) -> float:
    res = _shapes_of(instr.type_str)
    if not res or len(instr.operands) < 2:
        return 0.0
    out_elems = _prod(res[0][1])
    rhs = symbols.get(instr.operands[1], (None, []))[1]
    # kernel: spatial... × in_ch × out_ch (out_ch excluded from the multiply)
    k = _prod(rhs[:-1]) if rhs else 1
    return 2.0 * out_elems * k


def parse_costs(hlo_text: str, pod_stride: int = 0) -> HloCosts:
    comps = _split_computations(hlo_text)
    instrs = {name: _parse_instrs(lines) for name, lines in comps.items()}

    # symbol tables (per computation): name -> (dtype, dims) of first shape
    symbols = {}
    for name, ins in instrs.items():
        tab = {}
        for i in ins:
            shp = _shapes_of(i.type_str)
            tab[i.name] = shp[0] if shp else (None, [])
        symbols[name] = tab

    # sub-computation references per computation
    refs = defaultdict(list)        # comp -> [(kind, callee)]
    whiles = defaultdict(list)      # comp -> [(cond, body)]
    for name, ins in instrs.items():
        for i in ins:
            if i.op == "while":
                m = re.search(r"condition=\s*%?([\w.\-]+)", i.line)
                m2 = re.search(r"body=\s*%?([\w.\-]+)", i.line)
                if m and m2:
                    whiles[name].append((m.group(1), m2.group(1)))
            elif i.op in ("call", "fusion", "conditional", "map", "reduce",
                          "sort", "scatter", "reduce-window", "custom-call",
                          "async-start"):
                for mm in _ATTR_COMP_RE.finditer(i.line):
                    for callee in re.split(r"\s*,\s*", mm.group(1)):
                        refs[name].append((i.op, callee.lstrip("%")))

    def trip_count(cond_name: str) -> int:
        """Trip count from the while condition: resolve the ROOT's constant
        operand. The ROOT is either a raw ``compare(gte, const)`` or a
        ``fusion(gte, const)`` wrapping the compare (XLA:CPU wraps it)."""
        ins = instrs.get(cond_name, [])
        if not ins:
            return 1
        by_name = {i.name: i for i in ins}
        root = ins[-1]
        if root.op in ("compare", "fusion", "call"):
            vals = []
            for opn in root.operands:
                src = by_name.get(opn)
                if src is not None and src.op == "constant":
                    m = _CONST_RE.search(src.line)
                    if m:
                        vals.append(int(m.group(1)))
            if len(vals) == 1:
                return vals[0]
            m = _CONST_RE.search(root.line)
            if m:
                return int(m.group(1))
            if vals:
                return max(vals)
        # fallback: a single scalar constant instruction in the condition
        consts = [int(_CONST_RE.search(i.line).group(1)) for i in ins
                  if i.op == "constant" and _CONST_RE.search(i.line)]
        if len(consts) == 1:
            return consts[0]
        return max(consts) if consts else 1

    def sym_bytes(comp, opname):
        dt, dims = symbols[comp].get(opname, (None, []))
        if dt is None:
            return 0
        return _prod(dims) * _DTYPE_BYTES[dt]

    trip_counts = {}
    memo = {}

    def cost_of(comp: str, depth=0, inside_fusion=False):
        key = (comp, inside_fusion)
        if key in memo:
            return memo[key]
        if depth > 60 or comp not in instrs:
            z = (0.0, 0.0, defaultdict(float), defaultdict(int), 0.0,
                 defaultdict(float), defaultdict(float))
            return z
        flops = 0.0
        byts = 0.0
        cross = 0.0
        coll = defaultdict(float)
        coll_n = defaultdict(int)
        coll_dt = defaultdict(float)
        cross_dt = defaultdict(float)
        for i in instrs[comp]:
            if i.op == "dot":
                flops += _dot_flops(i, symbols[comp])
            elif i.op == "convolution":
                flops += _conv_flops(i, symbols[comp])
            is_coll = None
            for ct in COLLECTIVES:
                if i.op == ct or i.op == ct + "-start":
                    is_coll = ct
                    break
            if is_coll:
                b = _tensor_bytes(i.type_str)
                coll[is_coll] += b
                coll_n[is_coll] += 1
                by_dt = _tensor_bytes_by_dtype(i.type_str)
                for dt, db in by_dt.items():
                    coll_dt[dt] += db
                if pod_stride and _crosses_pods(i.line, pod_stride):
                    cross += b
                    for dt, db in by_dt.items():
                        cross_dt[dt] += db
            if not inside_fusion and i.op not in _FREE_OPS \
                    and i.op != "while":
                byts += _tensor_bytes(i.type_str)
                for opn in i.operands:
                    byts += sym_bytes(comp, opn)
        # recurse
        for kind, callee in refs.get(comp, []):
            f2, b2, c2, n2, x2, cd2, xd2 = cost_of(
                callee, depth + 1, inside_fusion or kind == "fusion")
            flops += f2
            byts += 0.0 if kind == "fusion" else b2
            cross += x2
            for k in c2:
                coll[k] += c2[k]
                coll_n[k] += n2[k]
            for k in cd2:
                coll_dt[k] += cd2[k]
            for k in xd2:
                cross_dt[k] += xd2[k]
        for cond, body in whiles.get(comp, []):
            tc = trip_count(cond)
            trip_counts[body] = tc
            f2, b2, c2, n2, x2, cd2, xd2 = cost_of(body, depth + 1,
                                                   inside_fusion)
            flops += f2 * tc
            byts += b2 * tc
            cross += x2 * tc
            for k in c2:
                coll[k] += c2[k] * tc
                coll_n[k] += n2[k] * tc
            for k in cd2:
                coll_dt[k] += cd2[k] * tc
            for k in xd2:
                cross_dt[k] += xd2[k] * tc
        memo[key] = (flops, byts, coll, coll_n, cross, coll_dt, cross_dt)
        return memo[key]

    # entry = computations never referenced
    referenced = set()
    for name in comps:
        for _, callee in refs.get(name, []):
            referenced.add(callee)
        for cond, body in whiles.get(name, []):
            referenced.add(cond)
            referenced.add(body)
    entries = [n for n in comps if n not in referenced]
    flops = byts = cross = 0.0
    coll = defaultdict(float)
    coll_n = defaultdict(int)
    coll_dt = defaultdict(float)
    cross_dt = defaultdict(float)
    for e in entries:
        f2, b2, c2, n2, x2, cd2, xd2 = cost_of(e)
        flops += f2
        byts += b2
        cross += x2
        for k in c2:
            coll[k] += c2[k]
            coll_n[k] += n2[k]
        for k in cd2:
            coll_dt[k] += cd2[k]
        for k in xd2:
            cross_dt[k] += xd2[k]

    return HloCosts(
        flops=flops,
        bytes=byts,
        collective_bytes=sum(coll.values()),
        bytes_by_collective=dict(coll),
        counts_by_collective=dict(coll_n),
        while_trip_counts=trip_counts,
        cross_pod_bytes=cross,
        collective_bytes_by_dtype=dict(coll_dt),
        cross_pod_bytes_by_dtype=dict(cross_dt),
    )


# ---------------------------------------------------------------------------
# backwards-compatible collective-only view
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class CollectiveStats:
    bytes_by_type: dict
    count_by_type: dict
    total_bytes: int
    while_trip_counts: dict


def parse_collectives(hlo_text: str) -> CollectiveStats:
    c = parse_costs(hlo_text)
    return CollectiveStats(
        bytes_by_type=c.bytes_by_collective,
        count_by_type=c.counts_by_collective,
        total_bytes=int(c.collective_bytes),
        while_trip_counts=c.while_trip_counts,
    )


def _tensor_bytes_public(type_str: str) -> int:
    return _tensor_bytes(type_str)
