"""Watch a live repro.net run: per-worker table + sparklines over STATS.

    # one-shot snapshot (prints the table once and exits)
    PYTHONPATH=src python -m repro.launch.monitor --connect 127.0.0.1:29500

    # live view, redrawn every --interval seconds until the run ends
    PYTHONPATH=src python -m repro.launch.monitor --connect HOST:PORT --follow

    # offline: re-render a --telemetry-jsonl stream after the fact
    PYTHONPATH=src python -m repro.launch.monitor --from-jsonl telem.jsonl

The master serves STATS on its rendezvous listener AFTER rendezvous (all
training links are connected by then, so any new connection is a monitor).
One snapshot per connection: send ``STATS {"token", "k"}``, read back the
``LiveMonitor.snapshot(k)`` JSON, done — the monitor never holds a socket
open into the data plane. Requires the run to have the live plane on
(``--telemetry`` / ``--telemetry-jsonl`` on launch.cluster, or
``PSConfig(telemetry=True)``) and a pinned ``--port``.
"""
from __future__ import annotations

import argparse
import json
import socket
import sys
import time

from repro.net import wire
from repro.net.wire import Link
from repro.obs import live as obs_live


def fetch_stats(host: str, port: int, token: str = "repro-net",
                k: int = 32, timeout_s: float = 5.0) -> dict:
    """One STATS round trip. Raises OSError (incl. WireError) while the
    master is still in rendezvous or already gone; RuntimeError on a
    token mismatch."""
    sock = socket.create_connection((host, port), timeout=timeout_s)
    link = Link(sock)
    try:
        link.sock.settimeout(timeout_s)
        link.send_json(wire.STATS, {"token": token, "k": int(k)})
        frame = link.recv_header()
        payload = link.recv_json(frame)
        if frame.ftype == wire.ERROR:
            raise RuntimeError(f"master refused STATS: {payload}")
        assert frame.ftype == wire.STATS, frame
        return payload
    finally:
        link.close()


def snap_from_jsonl(path: str) -> dict:
    """Fold a --telemetry-jsonl stream back into a snapshot()-shaped dict
    (each line carries latest-per-worker values; we accumulate them into
    the per-metric histories the table's sparklines want)."""
    workers: dict = {}
    events: list = []
    gauges: dict = {}
    meta = {"algorithm": "(jsonl)", "transport": path}
    t, n = 0.0, 0
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            rec = json.loads(line)
            if "meta" in rec and "workers" not in rec:   # run-header line
                meta.update(rec.get("meta") or {})
                continue
            t = float(rec.get("t", t))
            n += 1
            for wid, metrics in (rec.get("workers") or {}).items():
                for m, v in (metrics or {}).items():
                    if isinstance(v, (int, float)):
                        workers.setdefault(int(wid), {}) \
                            .setdefault(m, []).append([t, float(v)])
            for k, v in (rec.get("gauges") or {}).items():
                if isinstance(v, (int, float)):
                    gauges[k] = v
            events.extend(rec.get("events") or [])
    # a worker whose last event was never recovered stays flagged
    flagged: dict = {}
    for ev in events:
        wid = ev.get("wid")
        if ev.get("kind") == "recovered":
            flagged.pop(str(wid), None)
        elif ev.get("kind") in ("straggler", "hb_stale"):
            flagged[str(wid)] = ev["kind"]
    return {"t": t, "meta": meta,
            "n_samples": n, "events": events, "flagged": flagged,
            "workers": workers, "gauges": gauges}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--connect", default=None, metavar="HOST:PORT",
                    help="the master's rendezvous address (its --port)")
    ap.add_argument("--token", default="repro-net")
    ap.add_argument("--k", type=int, default=32,
                    help="history samples per series in each snapshot")
    ap.add_argument("--follow", action="store_true",
                    help="redraw every --interval s until the run ends")
    ap.add_argument("--interval", type=float, default=1.0)
    ap.add_argument("--retry-for", type=float, default=15.0,
                    help="keep retrying this long while the master is "
                         "still in rendezvous / not yet listening")
    ap.add_argument("--from-jsonl", default=None, metavar="PATH",
                    help="render a --telemetry-jsonl stream instead of "
                         "connecting to a live master")
    ap.add_argument("--width", type=int, default=24,
                    help="sparkline width (characters)")
    args = ap.parse_args(argv)

    if args.from_jsonl:
        print(obs_live.render(snap_from_jsonl(args.from_jsonl),
                              width=args.width))
        return 0
    if not args.connect:
        ap.error("pass --connect HOST:PORT or --from-jsonl PATH")
    host, port_s = args.connect.rsplit(":", 1)
    port = int(port_s)
    deadline = time.monotonic() + args.retry_for
    got_one = False
    while True:
        try:
            snap = fetch_stats(host, port, token=args.token, k=args.k)
        except OSError as exc:
            if got_one:
                # we were following a live run and the listener is gone:
                # the run ended — a clean exit, not an error
                print("# run ended (master gone)", flush=True)
                return 0
            if time.monotonic() > deadline:
                print(f"# no master at {args.connect}: {exc}",
                      file=sys.stderr)
                return 2
            time.sleep(min(args.interval, 0.5))
            continue
        got_one = True
        out = obs_live.render(snap, width=args.width)
        if args.follow and sys.stdout.isatty():
            sys.stdout.write("\x1b[H\x1b[2J")     # home + clear
        print(out, flush=True)
        if not args.follow:
            return 0
        time.sleep(args.interval)


if __name__ == "__main__":
    sys.exit(main())
