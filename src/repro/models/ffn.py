"""Dense gated FFN (SwiGLU / GeGLU)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.models import sctx
from repro.models.common import ModelConfig, ParamDef, act_fn


def ffn_defs(cfg: ModelConfig, d_ff: int | None = None) -> dict:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    return {
        "w_gate": ParamDef((d, f), ("embed", "ff")),
        "w_up": ParamDef((d, f), ("embed", "ff")),
        "w_down": ParamDef((f, d), ("ff", "embed_out")),
    }


def ffn_block(cfg: ModelConfig, p, x):
    cd = cfg.compute_dtype
    act = act_fn(cfg.act)
    g = act(sctx.shard(jnp.einsum("bsd,df->bsf", x, p["w_gate"].astype(cd)),
                       "batch", "seq", "ff"))
    u = sctx.shard(jnp.einsum("bsd,df->bsf", x, p["w_up"].astype(cd)),
                   "batch", "seq", "ff")
    return sctx.shard(
        jnp.einsum("bsf,fd->bsd", g * u, p["w_down"].astype(cd)),
        "batch", "seq", "embed")
