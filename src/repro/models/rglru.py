"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

Real-Gated Linear Recurrent Unit:
    r_t = σ(W_a x_t + b_a)                  (recurrence gate)
    i_t = σ(W_x x_t + b_x)                  (input gate)
    log a_t = −c · r_t · softplus(Λ)        (so a_t = σ(Λ)^{c·r_t} ∈ (0,1))
    h_t = a_t ⊙ h_{t−1} + √(1 − a_t²) ⊙ (i_t ⊙ x_t)

Training/prefill evaluates the diagonal recurrence with an associative scan
(log-depth on TPU); decode is the O(1) step. The recurrent block follows
Griffin: two branches (GeLU gate ∥ conv1d→RG-LRU), multiplied, projected.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import sctx
from repro.models.common import ModelConfig, ParamDef


def rglru_defs(cfg: ModelConfig) -> dict:
    g = cfg.rglru
    d, w = cfg.d_model, g.width
    return {
        "w_y": ParamDef((d, w), ("embed", "inner")),       # gate branch
        "w_x": ParamDef((d, w), ("embed", "inner")),       # recurrent branch
        "conv_w": ParamDef((g.d_conv, w), ("conv", "inner")),
        "conv_b": ParamDef((w,), ("inner",), init="zeros"),
        "wa": ParamDef((w, w), ("inner", "inner2")),
        "ba": ParamDef((w,), ("inner",), init="zeros"),
        "wi": ParamDef((w, w), ("inner", "inner2")),
        "bi": ParamDef((w,), ("inner",), init="zeros"),
        "lam": ParamDef((w,), ("inner",), init="ones"),    # Λ
        "w_out": ParamDef((w, d), ("inner", "embed_out")),
    }


def _causal_conv(x, w, b):
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(xp[:, i:i + x.shape[1]] * w[i][None, None] for i in range(K))
    return out + b[None, None]


def _rglru_gates(cfg, p, x):
    g = cfg.rglru
    x32 = x.astype(jnp.float32)
    r = jax.nn.sigmoid(x32 @ p["wa"].astype(jnp.float32)
                       + p["ba"].astype(jnp.float32))
    i = jax.nn.sigmoid(x32 @ p["wi"].astype(jnp.float32)
                       + p["bi"].astype(jnp.float32))
    log_a = -g.c * r * jax.nn.softplus(p["lam"].astype(jnp.float32))
    a = jnp.exp(log_a)
    gated_in = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (i * x32)
    return a, gated_in


def rglru_scan(cfg, p, x):
    """x: (B,S,w) -> h: (B,S,w) via associative scan over time."""
    a, b = _rglru_gates(cfg, p, x)

    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 * a2, a2 * b1 + b2

    a_sc, h = lax.associative_scan(combine, (a, b), axis=1)
    return h


def rglru_step(cfg, p, x_t, h_prev):
    """x_t: (B,w); h_prev: (B,w) -> (y_t, h_t)."""
    a, b = _rglru_gates(cfg, p, x_t[:, None])
    h = a[:, 0] * h_prev + b[:, 0]
    return h, h


def rglru_block(cfg: ModelConfig, p, x, positions=None, *, cache=None,
                cache_pos=None, **_unused):
    """Griffin recurrent block. cache = {conv: (B,K-1,w), state: (B,w)}."""
    g = cfg.rglru
    cd = cfg.compute_dtype
    B_, S, _ = x.shape

    y_gate = jax.nn.gelu(sctx.shard(
        jnp.einsum("bsd,dw->bsw", x, p["w_y"].astype(cd)),
        "batch", "seq", "inner"))
    xr = sctx.shard(jnp.einsum("bsd,dw->bsw", x, p["w_x"].astype(cd)),
                    "batch", "seq", "inner")

    if cache is not None and S == 1:
        conv_hist = jnp.concatenate([cache["conv"], xr], axis=1)
        conv_out = jnp.einsum("bkw,kw->bw", conv_hist.astype(cd),
                              p["conv_w"].astype(cd)) + p["conv_b"].astype(cd)
        h, state = rglru_step(cfg, p, conv_out, cache["state"])
        h = h[:, None]
        new_cache = {"conv": conv_hist[:, 1:], "state": state}
    else:
        conv_out = _causal_conv(xr.astype(cd), p["conv_w"].astype(cd),
                                p["conv_b"].astype(cd))
        h = rglru_scan(cfg, p, conv_out)
        new_cache = cache
        if cache is not None:
            K = g.d_conv
            new_cache = {"conv": xr[:, -(K - 1):].astype(cache["conv"].dtype),
                         "state": h[:, -1].astype(jnp.float32)}

    out = h.astype(cd) * y_gate
    return jnp.einsum("bsw,wd->bsd", out, p["w_out"].astype(cd)), new_cache
