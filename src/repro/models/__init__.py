from repro.models.common import (
    ModelConfig, MoEConfig, MLAConfig, SSMConfig, RGLRUConfig,
    ParamDef, init_params, abstract_params, partition_specs, make_rules,
)
from repro.models import transformer, attention, ffn, moe, mla, ssm, rglru, cnn
