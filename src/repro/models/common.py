"""Model substrate: configs, parameter definitions, init, sharding specs.

Parameters are declared as ``ParamDef`` pytrees (shape + logical axes +
init rule). From one declaration we derive:
  * concrete initialized params        (``init_params``)
  * ShapeDtypeStruct abstract params   (``abstract_params`` — dry-run path,
    no allocation)
  * PartitionSpecs                     (``partition_specs`` via logical→mesh
    axis rules)
keeping shapes, init and sharding impossible to drift apart.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P


# ---------------------------------------------------------------------------
# sub-configs
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    n_shared: int = 0
    d_expert: int = 0            # per-expert FFN width
    capacity_factor: float = 1.25
    dispatch_groups: int = 16    # grouped dispatch (matches data-axis size;
                                 # makes routing cumsums shard-local)
    aux_loss_weight: float = 0.01


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-V2 Multi-head Latent Attention."""
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """Mamba-2 SSD."""
    d_state: int = 128
    head_dim: int = 64
    expand: int = 2
    d_conv: int = 4
    chunk: int = 256


@dataclasses.dataclass(frozen=True)
class RGLRUConfig:
    """RecurrentGemma RG-LRU recurrent block."""
    width: int = 2560            # lru width (= d_model for recurrentgemma)
    d_conv: int = 4
    c: float = 8.0               # power in a_t = a^(c·r_t)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0            # 0 -> d_model // n_heads
    # layer pattern, cycled over n_layers. entries: "attn" (global),
    # "local" (sliding window attn), "ssm", "rglru", "moe" (attn+moe ffn),
    # "moe_local"…  The ffn kind is inferred: "moe*" → MoE, else dense.
    pattern: tuple = ("attn",)
    window: int = 1024           # sliding window for "local" layers
    rope_theta: float = 10_000.0
    rope_theta_global: float = 0.0   # 0 -> same as rope_theta
    mrope_sections: Optional[tuple] = None   # qwen2-vl (t, h, w) rotary split
    qkv_bias: bool = False
    qk_norm: bool = False        # gemma3
    act: str = "silu"            # silu (swiglu) | gelu (geglu)
    tie_embeddings: bool = False
    logit_softcap: float = 0.0
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    rglru: Optional[RGLRUConfig] = None
    # precisions
    param_dtype: Any = jnp.float32
    compute_dtype: Any = jnp.bfloat16
    # distribution hints
    fsdp: bool = False           # shard params over 'data' too (ZeRO-3 style)
    # modality stubs
    patch_embed_tokens: int = 0  # vlm: leading positions fed by patch embeds
    # loss
    loss_chunk: int = 32768      # cross-entropy token chunking (vocab memory)
    remat: str = "full"          # full | dots | none  (per-layer policy)
    # perf knobs (hillclimb levers; defaults are the measured baseline)
    attn_q_block: int = 512
    attn_kv_block: int = 1024
    moe_ep: bool = True          # experts over `data` (EP) vs replicated+TP

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    def layer_kinds(self) -> tuple:
        """Concrete per-layer kind list, cycling ``pattern``."""
        reps = math.ceil(self.n_layers / len(self.pattern))
        return tuple((self.pattern * reps)[: self.n_layers])

    @property
    def n_periods(self) -> int:
        return self.n_layers // len(self.pattern)

    @property
    def remainder_kinds(self) -> tuple:
        r = self.n_layers % len(self.pattern)
        return tuple(self.pattern[:r])


# ---------------------------------------------------------------------------
# parameter definitions
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ParamDef:
    shape: tuple
    logical: tuple               # logical axis name per dim
    init: str = "normal"         # normal | zeros | ones | embed
    scale: float = 1.0           # fan-in handled at call site via scale

    def __post_init__(self):
        assert len(self.shape) == len(self.logical), (self.shape, self.logical)


def _is_def(x):
    return isinstance(x, ParamDef)


def init_params(defs, key, dtype):
    """Materialize a ParamDef pytree into arrays (truncated-normal/zeros)."""
    leaves, treedef = jax.tree_util.tree_flatten(defs, is_leaf=_is_def)
    keys = jax.random.split(key, len(leaves))
    out = []
    for k, d in zip(keys, leaves):
        if d.init == "zeros":
            out.append(jnp.zeros(d.shape, dtype))
        elif d.init == "ones":
            out.append(jnp.ones(d.shape, dtype))
        else:
            fan_in = d.shape[-2] if len(d.shape) >= 2 else d.shape[-1]
            std = d.scale / math.sqrt(max(fan_in, 1))
            out.append(
                (jax.random.truncated_normal(k, -2.0, 2.0, d.shape,
                                              jnp.float32) * std).astype(dtype)
            )
    return jax.tree_util.tree_unflatten(treedef, out)


def abstract_params(defs, dtype):
    """ShapeDtypeStruct pytree — the dry-run path (no allocation)."""
    return jax.tree_util.tree_map(
        lambda d: jax.ShapeDtypeStruct(d.shape, dtype), defs, is_leaf=_is_def
    )


def partition_specs(defs, rules: dict):
    """Map each ParamDef's logical axes to mesh axes via ``rules``.

    ``rules`` maps logical axis name -> mesh axis name (or None). A mesh
    axis is used at most once per param (first logical dim wins) and only
    when the dim size divides the mesh axis size (callers bake sizes into
    the rules via ``make_rules``).

    Selective FSDP (``rules["_fsdp_axis"]``): after TP assignment, the
    largest still-unsharded eligible dim additionally shards over the data
    axis — EXCEPT vocab-carrying params (a 2D-sharded embedding table makes
    the token gather pathological under SPMD: 'involuntary full
    rematerialization'). This bounds per-device weight residency for the
    100B+ archs while keeping gathers clean.
    """
    fsdp_axis = rules.get("_fsdp_axis")

    def spec(d: ParamDef) -> P:
        used = set()
        axes = []
        for dim, logical in zip(d.shape, d.logical):
            ax = rules.get(logical)
            if ax is None or ax in used:
                axes.append(None)
                continue
            size = rules.get(("_axis_size", ax), 0)
            if size and dim % size != 0:
                axes.append(None)
                continue
            axes.append(ax)
            used.add(ax)
        if fsdp_axis and fsdp_axis not in used \
                and "vocab" not in d.logical:
            dsize = rules.get(("_axis_size", fsdp_axis), 0)
            cands = [
                (dim, i) for i, (dim, logical)
                in enumerate(zip(d.shape, d.logical))
                if axes[i] is None and logical not in ("layers", "conv")
                and dsize and dim % dsize == 0
            ]
            if cands:
                _, i = max(cands)
                axes[i] = fsdp_axis
        return P(*axes)

    return jax.tree_util.tree_map(spec, defs, is_leaf=_is_def)


def make_rules(cfg: ModelConfig, mesh_axes: dict) -> dict:
    """Logical-axis → mesh-axis rules for a model on a mesh.

    mesh_axes: {"data": size, "model": size} (pod handled outside via vmap).
    TP axes go on 'model'; FSDP (when cfg.fsdp) additionally shards the
    d_model ("embed") dim of weight matrices over 'data'.
    """
    model_size = mesh_axes.get("model", 1)
    data_size = mesh_axes.get("data", 1)
    rules = {
        "vocab": "model",
        "ff": "model",
        "expert_ff": "model",
        # EP over the DATA axis (2-axis EP layout): expert weights live
        # E-sharded on `data` + f-sharded on `model`; the dispatch buffer's
        # G→E reshard IS the token all-to-all. (E on `model` makes the
        # combine gather all-gather the whole buffer — measured 1000×
        # worse.) Non-divisible expert counts (grok: 8) replicate E and
        # 2D-shard (d×f) instead.
        "experts": "data" if (cfg.moe and cfg.moe_ep and cfg.moe.n_experts % max(data_size, 1) == 0) else None,
        "q_heads": "model",
        "kv_heads": "model",
        "heads_x_dim": "model",
        "inner": "model",        # ssm/rglru inner channels
        "embed": None,           # fsdp handled by the _fsdp_axis post-pass
        "embed_out": None,
        "layers": None,
        "head_dim": None,
        "state": None,
        "conv": None,
        "lora": None,
        ("_axis_size", "model"): model_size,
        ("_axis_size", "data"): data_size,
    }
    if cfg.fsdp:
        rules["_fsdp_axis"] = "data"
    return rules


# ---------------------------------------------------------------------------
# numerics helpers shared by blocks
# ---------------------------------------------------------------------------

def rms_norm(x, gamma, eps=1e-6):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    out = x32 * jax.lax.rsqrt(var + eps) * (1.0 + gamma.astype(jnp.float32))
    return out.astype(x.dtype)


def softcap(x, cap: float):
    if not cap:
        return x
    return jnp.tanh(x / cap) * cap


def act_fn(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu}[name]
