"""Decoder-LM assembly: heterogeneous layer patterns under lax.scan.

A model is ``cfg.pattern`` cycled ``n_periods`` times (plus an unrolled
remainder): e.g. gemma-3 is ("local",)*5 + ("attn",), recurrentgemma is
("rglru", "rglru", "attn"). Per pattern-slot the layer params are STACKED on
a leading (n_periods,) dim and the whole period is one ``lax.scan`` body —
HLO stays small for 80-layer models and remat applies per period.

Public surface (used by runtime / launch / tests):
  model_defs(cfg)                         — ParamDef pytree
  forward(cfg, params, tokens, ...)       — hidden states (+ caches)
  lm_loss(cfg, params, batch)             — scalar loss + metrics
  init_cache_defs(cfg, B, max_len)        — abstract cache pytree
  prefill(cfg, params, tokens, caches)    — logits of last pos + filled caches
  decode_step(cfg, params, tok, caches, pos) — next-token logits + caches
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import attention, ffn as ffn_lib, mla, moe as moe_lib
from repro.models import rglru as rglru_lib, sctx, ssm as ssm_lib
from repro.models.common import ModelConfig, ParamDef, rms_norm, softcap


# ---------------------------------------------------------------------------
# parameter definitions
# ---------------------------------------------------------------------------

def _block_defs(cfg: ModelConfig, kind: str) -> dict:
    d = cfg.d_model
    out = {"norm1": ParamDef((d,), ("embed",), init="zeros")}
    if kind in ("attn", "local"):
        out["attn"] = attention.attention_defs(cfg)
    elif kind == "mla":
        out["attn"] = mla.mla_defs(cfg)
    elif kind == "ssm":
        out["ssm"] = ssm_lib.ssm_defs(cfg)
        return out                                   # mamba: no separate FFN
    elif kind == "rglru":
        out["rec"] = rglru_lib.rglru_defs(cfg)
    else:
        raise ValueError(f"unknown layer kind {kind}")
    out["norm2"] = ParamDef((d,), ("embed",), init="zeros")
    if cfg.moe is not None:
        out["moe"] = moe_lib.moe_defs(cfg)
    else:
        out["ffn"] = ffn_lib.ffn_defs(cfg)
    return out


def _stack_defs(defs, n: int):
    return jax.tree_util.tree_map(
        lambda p: ParamDef((n,) + p.shape, ("layers",) + p.logical, p.init,
                           p.scale),
        defs, is_leaf=lambda x: isinstance(x, ParamDef),
    )


def model_defs(cfg: ModelConfig) -> dict:
    d, V = cfg.d_model, cfg.vocab_size
    defs = {
        "embed": ParamDef((V, d), ("vocab", "embed")),
        "final_norm": ParamDef((d,), ("embed",), init="zeros"),
        "blocks": tuple(
            _stack_defs(_block_defs(cfg, kind), cfg.n_periods)
            for kind in cfg.pattern
        ),
        "rem": tuple(_block_defs(cfg, kind) for kind in cfg.remainder_kinds),
    }
    if not cfg.tie_embeddings:
        defs["unembed"] = ParamDef((d, V), ("embed", "vocab"))
    return defs


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------

def _cache_def_one(cfg: ModelConfig, kind: str, B: int, max_len: int):
    cd = cfg.compute_dtype
    D = cfg.resolved_head_dim
    if kind == "attn":
        S = max_len
        return {"k": jax.ShapeDtypeStruct((B, S, cfg.n_kv_heads, D), cd),
                "v": jax.ShapeDtypeStruct((B, S, cfg.n_kv_heads, D), cd)}
    if kind == "local":
        S = min(cfg.window, max_len)
        return {"k": jax.ShapeDtypeStruct((B, S, cfg.n_kv_heads, D), cd),
                "v": jax.ShapeDtypeStruct((B, S, cfg.n_kv_heads, D), cd)}
    if kind == "mla":
        a = cfg.mla
        return {"ckv": jax.ShapeDtypeStruct((B, max_len, a.kv_lora_rank), cd),
                "kpe": jax.ShapeDtypeStruct((B, max_len, a.qk_rope_head_dim),
                                            cd)}
    if kind == "ssm":
        s = cfg.ssm
        d_inner = s.expand * cfg.d_model
        H = d_inner // s.head_dim
        conv_dim = d_inner + 2 * s.d_state
        return {"conv": jax.ShapeDtypeStruct((B, s.d_conv - 1, conv_dim), cd),
                "state": jax.ShapeDtypeStruct((B, H, s.head_dim, s.d_state),
                                              jnp.float32)}
    if kind == "rglru":
        g = cfg.rglru
        return {"conv": jax.ShapeDtypeStruct((B, g.d_conv - 1, g.width), cd),
                "state": jax.ShapeDtypeStruct((B, g.width), jnp.float32)}
    raise ValueError(kind)


def init_cache_defs(cfg: ModelConfig, B: int, max_len: int):
    """Abstract cache pytree: (per-slot stacked, remainder list)."""
    def stack(tree):
        return jax.tree_util.tree_map(
            lambda s: jax.ShapeDtypeStruct((cfg.n_periods,) + s.shape,
                                           s.dtype), tree)
    stacked = tuple(stack(_cache_def_one(cfg, kind, B, max_len))
                    for kind in cfg.pattern)
    rem = tuple(_cache_def_one(cfg, kind, B, max_len)
                for kind in cfg.remainder_kinds)
    return {"stacked": stacked, "rem": rem}


def init_caches(cfg: ModelConfig, B: int, max_len: int):
    return jax.tree_util.tree_map(
        lambda s: jnp.zeros(s.shape, s.dtype),
        init_cache_defs(cfg, B, max_len))


# ---------------------------------------------------------------------------
# one layer
# ---------------------------------------------------------------------------

_MIXERS = {
    "attn": attention.attention_block,
    "local": attention.attention_block,
    "mla": mla.mla_block,
    "ssm": ssm_lib.ssm_block,
    "rglru": rglru_lib.rglru_block,
}


def _apply_block(cfg: ModelConfig, kind: str, p, x, positions, cache,
                 cache_pos, mrope_positions):
    key = {"attn": "attn", "local": "attn", "mla": "attn",
           "ssm": "ssm", "rglru": "rec"}[kind]
    mixer = _MIXERS[kind]
    h = rms_norm(x, p["norm1"])
    kwargs = dict(cache=cache, cache_pos=cache_pos)
    if kind in ("attn", "local"):
        kwargs.update(kind=kind, mrope_positions=mrope_positions)
    y, new_cache = mixer(cfg, p[key], h, positions, **kwargs)
    x = x + y
    aux = jnp.zeros((), jnp.float32)
    if kind == "ssm":
        return x, new_cache, aux
    h2 = rms_norm(x, p["norm2"])
    if cfg.moe is not None:
        y2, aux = moe_lib.moe_block(cfg, p["moe"], h2)
    else:
        y2 = ffn_lib.ffn_block(cfg, p["ffn"], h2)
    return x + y2, new_cache, aux


def _remat_wrap(cfg: ModelConfig, fn, training: bool):
    # per-SLOT checkpointing inside period_body already bounds residuals to
    # one layer; an additional period-level checkpoint only adds recompute.
    return fn


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def forward(cfg: ModelConfig, params, tokens, *, positions=None, caches=None,
            cache_pos=None, mrope_positions=None, patch_embeds=None,
            constrain=None):
    """tokens: (B, S) int32. Returns (hidden (B,S,d), new_caches, aux_loss).

    ``constrain(kind, params_subtree)`` (optional): re-shards one layer's
    sliced params before use — the runtime passes a gather-to-compute-layout
    constraint here, which is how streaming FSDP/ZeRO-3 is made explicit
    (one all-gather per layer per pass instead of GSPMD choosing to
    all-reduce activations).
    """
    cd = cfg.compute_dtype
    B, S = tokens.shape
    h = sctx.shard(jnp.take(params["embed"], tokens, axis=0).astype(cd),
                   "batch", "seq", "embed")
    if patch_embeds is not None:
        P_ = patch_embeds.shape[1]
        h = jnp.concatenate([patch_embeds.astype(cd), h[:, P_:]], axis=1)
    if positions is None:
        if cache_pos is not None and S == 1:
            positions = cache_pos[:, None]
        else:
            positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))

    training = caches is None
    aux_total = jnp.zeros((), jnp.float32)

    def one_block(kind, p_s, x, cache):
        p_s = constrain(kind, p_s) if constrain else p_s
        x, nc, a = _apply_block(cfg, kind, p_s, x, positions, cache,
                                cache_pos, mrope_positions)
        return sctx.shard(x, "batch", "seq", "embed"), nc, a

    def period_body(carry, xs):
        x, aux = carry
        slot_params = xs[0] if caches is not None else xs
        slot_caches = xs[1] if caches is not None else (None,) * len(cfg.pattern)
        new_slot_caches = []
        for s, kind in enumerate(cfg.pattern):
            # per-SLOT remat: backward holds one layer's residuals at a time
            # even when the pattern period contains several layers.
            blk = partial(one_block, kind)
            if training and cfg.remat != "none":
                blk = jax.checkpoint(blk, static_argnums=())
            x, nc, a = blk(slot_params[s], x, slot_caches[s])
            aux = aux + a
            new_slot_caches.append(nc)
        ys = tuple(new_slot_caches) if caches is not None else 0
        return (x, aux), ys

    body = _remat_wrap(cfg, period_body, training)
    if cfg.n_periods:
        xs = (params["blocks"], caches["stacked"]) if caches is not None \
            else params["blocks"]
        (h, aux_total), new_stacked = lax.scan(
            body, (h, aux_total), xs)
    else:
        new_stacked = caches["stacked"] if caches is not None else ()

    new_rem = []
    for i, kind in enumerate(cfg.remainder_kinds):
        c = caches["rem"][i] if caches is not None else None
        p_i = constrain(kind, params["rem"][i]) if constrain else \
            params["rem"][i]
        h, nc, a = _apply_block(cfg, kind, p_i, h, positions,
                                c, cache_pos, mrope_positions)
        aux_total = aux_total + a
        new_rem.append(nc)

    h = rms_norm(h, params["final_norm"])
    new_caches = None
    if caches is not None:
        new_caches = {"stacked": new_stacked, "rem": tuple(new_rem)}
    return h, new_caches, aux_total


# ---------------------------------------------------------------------------
# LM head / loss
# ---------------------------------------------------------------------------

def _unembed_weight(cfg: ModelConfig, params):
    if cfg.tie_embeddings:
        return params["embed"].T                      # (d, V)
    return params["unembed"]


def logits_at(cfg: ModelConfig, params, h):
    """Logits for the given hidden states (use on a few positions only)."""
    w = _unembed_weight(cfg, params)
    out = jnp.einsum("...d,dv->...v", h.astype(jnp.float32),
                     w.astype(jnp.float32))
    return softcap(out, cfg.logit_softcap)


def _divisor_chunk(T: int, want: int) -> int:
    c = min(want, T)
    while T % c:
        c -= 1
    return max(c, 1)


def lm_loss(cfg: ModelConfig, params, batch, extra_fwd_kwargs=None):
    """Next-token cross-entropy, chunked along the SEQUENCE dim so the
    (B, S, V) logits never fully materialize (262k vocab × 1M tokens would
    be TBs of HBM). Chunking along S keeps the batch dim — and therefore
    the `data` sharding — intact on every chunk (chunking flat tokens would
    split across data shards and force replication).

    batch: {tokens (B,S), targets (B,S), mask (B,S)} + modality extras.
    """
    kwargs = dict(extra_fwd_kwargs or {})
    for k in ("mrope_positions", "patch_embeds"):
        if k in batch:
            kwargs[k] = batch[k]
    h, _, aux = forward(cfg, params, batch["tokens"], **kwargs)
    B, S, d = h.shape
    w = _unembed_weight(cfg, params)
    mask = batch["mask"].astype(jnp.float32)

    Cs = _divisor_chunk(S, max(1, cfg.loss_chunk // B))
    nc = S // Cs

    def chunk_fn(carry, inp):
        h_c, t_c, m_c = inp                     # (B,Cs,d), (B,Cs), (B,Cs)
        logits = jnp.einsum("bcd,dv->bcv", h_c, w.astype(h_c.dtype),
                            preferred_element_type=jnp.float32)
        logits = sctx.shard(logits, "batch", "seq", "vocab")
        logits = softcap(logits, cfg.logit_softcap)
        logz = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, t_c[..., None], axis=2)[..., 0]
        loss_sum, n_tok, correct = carry
        pred = jnp.argmax(logits, axis=-1)
        correct = correct + jnp.sum((pred == t_c) * m_c)
        return (loss_sum + jnp.sum((logz - ll) * m_c),
                n_tok + jnp.sum(m_c), correct), None

    xs = (
        jnp.moveaxis(h.reshape(B, nc, Cs, d), 1, 0),
        jnp.moveaxis(batch["targets"].reshape(B, nc, Cs), 1, 0),
        jnp.moveaxis(mask.reshape(B, nc, Cs), 1, 0),
    )
    (loss_sum, n_tok, correct), _ = lax.scan(
        jax.checkpoint(chunk_fn),
        (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32),
         jnp.zeros((), jnp.float32)),
        xs,
    )
    denom = jnp.maximum(n_tok, 1.0)
    ce = loss_sum / denom
    loss = ce + aux
    return loss, {"ce": ce, "aux": aux, "accuracy": correct / denom,
                  "tokens": n_tok}


# ---------------------------------------------------------------------------
# serving entry points
# ---------------------------------------------------------------------------

def prefill(cfg: ModelConfig, params, tokens, caches, *, mrope_positions=None,
            patch_embeds=None, constrain=None):
    """Teacher-forced pass that fills caches; returns last-position logits."""
    h, new_caches, _ = forward(cfg, params, tokens, caches=caches,
                               cache_pos=None, mrope_positions=mrope_positions,
                               patch_embeds=patch_embeds, constrain=constrain)
    return logits_at(cfg, params, h[:, -1]), new_caches


def decode_step(cfg: ModelConfig, params, token, caches, cache_pos, *,
                mrope_positions=None, constrain=None):
    """token: (B,1); cache_pos: (B,) current position. Returns (B,V) logits."""
    h, new_caches, _ = forward(cfg, params, token, caches=caches,
                               cache_pos=cache_pos,
                               mrope_positions=mrope_positions,
                               constrain=constrain)
    return logits_at(cfg, params, h[:, -1]), new_caches
