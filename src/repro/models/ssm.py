"""Mamba-2 (SSD — state-space duality, arXiv:2405.21060), TPU-adapted.

The SSD layer computes, per head h with scalar decay a_t = exp(Δt·A_h):

    S_t = a_t · S_{t-1} + Δt·B_t ⊗ x_t          (state: head_dim × d_state)
    y_t = C_t · S_t + D_h · x_t

Training/prefill uses the CHUNKED form (the paper's matmul-friendly
decomposition, which is exactly what the MXU wants):
  * intra-chunk: quadratic attention-like matmuls within a chunk,
  * inter-chunk: a sequential scan over chunk states.
We scan over chunks (lax.scan) so the (L×L) decay tensor exists for one
chunk at a time — heads shard over `model`, batch over `data`, keeping the
per-device tile VMEM-sized. This mirrors the Pallas kernel's blocking
(kernels/ssd_chunk.py); this function is also its oracle.

Decode is the O(1) recurrent step on the carried (B, H, P, N) state.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import sctx
from repro.models.common import ModelConfig, ParamDef


def _dims(cfg: ModelConfig):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    n_heads = d_inner // s.head_dim
    conv_dim = d_inner + 2 * s.d_state
    return d_inner, n_heads, conv_dim


def ssm_defs(cfg: ModelConfig) -> dict:
    s = cfg.ssm
    d = cfg.d_model
    d_inner, n_heads, conv_dim = _dims(cfg)
    # in_proj emits [z (d_inner) | x (d_inner) | B (N) | C (N) | dt (H)]
    return {
        "w_in": ParamDef((d, 2 * d_inner + 2 * s.d_state + n_heads),
                         ("embed", "inner")),
        "conv_w": ParamDef((s.d_conv, conv_dim), ("conv", "inner")),
        "conv_b": ParamDef((conv_dim,), ("inner",), init="zeros"),
        "A_log": ParamDef((n_heads,), ("state",), init="zeros"),
        "D": ParamDef((n_heads,), ("state",), init="ones"),
        "dt_bias": ParamDef((n_heads,), ("state",), init="zeros"),
        "norm": ParamDef((d_inner,), ("inner",), init="zeros"),
        "w_out": ParamDef((d_inner, d), ("inner", "embed_out")),
    }


def _split_in(cfg, h):
    s = cfg.ssm
    d_inner, n_heads, _ = _dims(cfg)
    z = h[..., :d_inner]
    x = h[..., d_inner:2 * d_inner]
    B = h[..., 2 * d_inner:2 * d_inner + s.d_state]
    C = h[..., 2 * d_inner + s.d_state:2 * d_inner + 2 * s.d_state]
    dt = h[..., 2 * d_inner + 2 * s.d_state:]
    return z, x, B, C, dt


def _causal_conv(x, w, b):
    """Depthwise causal conv over time. x: (B,S,C); w: (K,C); b: (C,)."""
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(xp[:, i:i + x.shape[1]] * w[i][None, None] for i in range(K))
    return out + b[None, None]


def _ssd_chunked(xh, dt, A, Bm, Cm, chunk: int, state0=None):
    """Chunked SSD scan.

    xh: (B,S,H,P) inputs (already Δt-scaled NOT applied; we apply here),
    dt: (B,S,H) softplus'ed step sizes, A: (H,) negative decay rates,
    Bm, Cm: (B,S,N) input/output projections (single group),
    Returns y: (B,S,H,P) and final state (B,H,P,N).
    """
    Bsz, S, H, P = xh.shape
    N = Bm.shape[-1]
    L = min(chunk, S)
    pad = (-S) % L
    if pad:
        # dt=0 padding: decay=1 and zero input, so the state is unaffected
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
        S = S + pad
    nc = S // L

    a = dt * A[None, None, :]                       # (B,S,H) log-decay ≤ 0
    xbar = xh * dt[..., None]                       # Δt·x
    r = lambda t: t.reshape(Bsz, nc, L, *t.shape[2:])
    a_c, x_c, B_c, C_c = r(a), r(xbar), r(Bm), r(Cm)

    if state0 is None:
        state0 = jnp.zeros((Bsz, H, P, N), jnp.float32)

    def chunk_step(state, inp):
        ac, xc, bc, cc = inp                        # (B,L,H), (B,L,H,P), (B,L,N)
        ac = ac.astype(jnp.float32)
        cum = jnp.cumsum(ac, axis=1)                # decay from chunk start
        total = cum[:, -1]                          # (B,H)

        # inter-chunk: y_prev[i] = exp(cum_i) · C_i · S_prev
        y_prev = jnp.einsum("bln,bhpn->blhp", cc.astype(jnp.float32), state)
        y_prev = y_prev * jnp.exp(cum)[..., None]

        # intra-chunk (the quadratic/matmul part)
        g = jnp.einsum("bln,bmn->blm", cc.astype(jnp.float32),
                       bc.astype(jnp.float32))      # (B,L,L)
        dec = jnp.exp(cum[:, :, None, :] - cum[:, None, :, :])  # (B,L,L,H)
        mask = jnp.tril(jnp.ones((L, L), bool))
        m = jnp.where(mask[None, :, :, None], g[..., None] * dec, 0.0)
        y_intra = jnp.einsum("blmh,bmhp->blhp", m, xc.astype(jnp.float32))

        # state passing: S_new = exp(total)·S + Σ_j exp(total-cum_j) B_j x_jᵀ
        decay_in = jnp.exp(total[:, None, :] - cum)  # (B,L,H)
        s_in = jnp.einsum("bln,blh,blhp->bhpn", bc.astype(jnp.float32),
                          decay_in, xc.astype(jnp.float32))
        state_new = state * jnp.exp(total)[:, :, None, None] + s_in
        return state_new, y_prev + y_intra

    state, y = lax.scan(chunk_step, state0,
                        (a_c.swapaxes(0, 1), x_c.swapaxes(0, 1),
                         B_c.swapaxes(0, 1), C_c.swapaxes(0, 1)))
    y = y.swapaxes(0, 1).reshape(Bsz, S, H, P)
    if pad:
        y = y[:, :S - pad]
    return y, state


def ssd_step(state, x_t, dt_t, A, B_t, C_t):
    """O(1) decode recurrence. state: (B,H,P,N); x_t: (B,H,P);
    dt_t: (B,H); B_t, C_t: (B,N)."""
    a = jnp.exp(dt_t * A[None, :])[..., None, None]          # (B,H,1,1)
    upd = jnp.einsum("bn,bhp->bhpn", B_t.astype(jnp.float32),
                     (x_t * dt_t[..., None]).astype(jnp.float32))
    state = state * a + upd
    y = jnp.einsum("bn,bhpn->bhp", C_t.astype(jnp.float32), state)
    return state, y


def ssm_block(cfg: ModelConfig, p, x, positions=None, *, cache=None,
              cache_pos=None, **_unused):
    """Mamba-2 block. cache = {conv: (B,K-1,convdim), state: (B,H,P,N)}."""
    s = cfg.ssm
    cd = cfg.compute_dtype
    d_inner, n_heads, conv_dim = _dims(cfg)
    B_, S, _ = x.shape

    h = jnp.einsum("bsd,de->bse", x, p["w_in"].astype(cd))
    z, xi, Bm, Cm, dt = _split_in(cfg, h)
    z = sctx.shard(z, "batch", "seq", "inner")
    xbc = sctx.shard(jnp.concatenate([xi, Bm, Cm], axis=-1),
                     "batch", "seq", "inner")

    if cache is not None and S == 1:
        # decode: sliding conv state + recurrent SSD step
        conv_hist = jnp.concatenate([cache["conv"], xbc], axis=1)  # (B,K,cd)
        conv_out = jnp.einsum("bkc,kc->bc", conv_hist.astype(cd),
                              p["conv_w"].astype(cd)) + p["conv_b"].astype(cd)
        conv_out = jax.nn.silu(conv_out)[:, None]                  # (B,1,cd)
        xi, Bm, Cm = (conv_out[..., :d_inner],
                      conv_out[..., d_inner:d_inner + s.d_state],
                      conv_out[..., d_inner + s.d_state:])
        dt_t = jax.nn.softplus(dt[:, 0] + p["dt_bias"].astype(jnp.float32))
        A = -jnp.exp(p["A_log"].astype(jnp.float32))
        xh = xi.reshape(B_, n_heads, s.head_dim)
        state, y = ssd_step(cache["state"], xh, dt_t, A, Bm[:, 0], Cm[:, 0])
        y = y + p["D"].astype(jnp.float32)[None, :, None] * xh
        y = y.reshape(B_, 1, d_inner)
        new_cache = {"conv": conv_hist[:, 1:], "state": state}
    else:
        conv_out = jax.nn.silu(_causal_conv(xbc.astype(cd),
                                            p["conv_w"].astype(cd),
                                            p["conv_b"].astype(cd)))
        xi = conv_out[..., :d_inner]
        Bm = conv_out[..., d_inner:d_inner + s.d_state]
        Cm = conv_out[..., d_inner + s.d_state:]
        dt_sp = jax.nn.softplus(dt.astype(jnp.float32)
                                + p["dt_bias"].astype(jnp.float32))
        A = -jnp.exp(p["A_log"].astype(jnp.float32))
        xh = sctx.shard(xi.reshape(B_, S, n_heads, s.head_dim),
                        "batch", "seq", "heads", "head_dim")
        y, state = _ssd_chunked(xh.astype(jnp.float32), dt_sp, A, Bm, Cm,
                                s.chunk)
        y = y + p["D"].astype(jnp.float32)[None, None, :, None] * xh
        y = y.reshape(B_, S, d_inner)
        new_cache = cache
        if cache is not None:
            K = s.d_conv
            new_cache = {"conv": xbc[:, -(K - 1):].astype(cache["conv"].dtype),
                         "state": state}

    # gated RMSNorm (Mamba-2) + out proj
    y = sctx.shard(y.astype(cd), "batch", "seq", "inner") * jax.nn.silu(z)
    y32 = y.astype(jnp.float32)
    var = jnp.mean(jnp.square(y32), axis=-1, keepdims=True)
    y = (y32 * lax.rsqrt(var + 1e-6)
         * (1.0 + p["norm"].astype(jnp.float32))).astype(cd)
    return jnp.einsum("bse,ed->bsd", y, p["w_out"].astype(cd)), new_cache
