"""Mixture-of-Experts FFN: top-k routing, capacity-bounded grouped dispatch,
optional shared experts (DeepSeek-V2 style), load-balance aux loss.

Dispatch design (DESIGN.md §5): tokens are processed in ``dispatch_groups``
groups sized to match the data-parallel axis, so the routing cumsum (the
position-in-expert rank) is local to a shard — no cross-shard prefix scan.
The dispatch buffer is (G, E, C, d): G sharded over `data`, E over `model`
(when E % model == 0, else experts replicate and d_ff shards). GSPMD turns
the buffer resharding into the expert-parallel all-to-all and the combine
scatter into a reduce over `model`.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import sctx
from repro.models.common import ModelConfig, ParamDef, act_fn


def _effective_groups(T: int, G: int) -> int:
    g = min(G, T)
    while T % g:
        g -= 1
    return max(g, 1)


def moe_defs(cfg: ModelConfig) -> dict:
    m = cfg.moe
    d, E, f = cfg.d_model, m.n_experts, m.d_expert
    defs = {
        "router": ParamDef((d, E), ("embed", "router_experts")),
        "we_gate": ParamDef((E, d, f), ("experts", "embed", "expert_ff")),
        "we_up": ParamDef((E, d, f), ("experts", "embed", "expert_ff")),
        "we_down": ParamDef((E, f, d), ("experts", "expert_ff", "embed_out")),
    }
    if m.n_shared:
        fs = m.n_shared * f
        defs.update({
            "ws_gate": ParamDef((d, fs), ("embed", "ff")),
            "ws_up": ParamDef((d, fs), ("embed", "ff")),
            "ws_down": ParamDef((fs, d), ("ff", "embed_out")),
        })
    return defs


def moe_block(cfg: ModelConfig, p, x):
    """x: (B, S, d) -> (y, aux_loss)."""
    m = cfg.moe
    cd = cfg.compute_dtype
    act = act_fn(cfg.act)
    B, S, d = x.shape
    T = B * S
    E, k = m.n_experts, m.top_k
    G = _effective_groups(T, m.dispatch_groups)
    Tg = T // G
    C = max(1, math.ceil(Tg * k * m.capacity_factor / E))

    xg = x.reshape(G, Tg, d)

    # ---- routing (fp32) ----------------------------------------------------
    logits = jnp.einsum("gtd,de->gte", xg.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = lax.top_k(probs, k)                      # (G, Tg, k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    # load-balance aux loss (Switch): E * Σ_e f_e · p̄_e
    me = probs.mean(axis=(0, 1))                            # (E,)
    ce = jnp.zeros((E,), jnp.float32).at[top_e.reshape(-1)].add(
        1.0 / (T * k))
    aux = E * jnp.sum(me * ce) * m.aux_loss_weight

    # ---- grouped dispatch ---------------------------------------------------
    ids = top_e.reshape(G, Tg * k)                          # slot -> expert
    oh = jax.nn.one_hot(ids, E, dtype=jnp.float32)          # (G, Tg*k, E)
    pos = (jnp.cumsum(oh, axis=1) - 1.0)                    # rank within expert
    pos = jnp.take_along_axis(pos, ids[..., None], axis=-1)[..., 0]
    pos = pos.astype(jnp.int32)                             # (G, Tg*k)
    keep = (pos < C)
    slot = jnp.where(keep, ids * C + pos, 0)

    x_slots = jnp.repeat(xg, k, axis=1).astype(cd)          # (G, Tg*k, d)
    gidx = jnp.arange(G)[:, None]
    buf = jnp.zeros((G, E * C, d), cd).at[gidx, slot].add(
        x_slots * keep[..., None].astype(cd))
    # 2-axis EP: resharding the buffer from token-major (G over data) to
    # expert-major (E over data) IS the dispatch all-to-all.
    buf = sctx.shard(buf.reshape(G, E, C, d),
                     "groups", "experts_dp" if cfg.moe_ep else "experts_off",
                     "cap", "embed")

    # ---- expert FFN (E over `data`, d_expert over `model`) ------------------
    h = act(sctx.shard(
        jnp.einsum("gecd,edf->gecf", buf, p["we_gate"].astype(cd)),
        "groups", "experts_dp" if cfg.moe_ep else "experts_off",
        "cap", "ff")) * \
        jnp.einsum("gecd,edf->gecf", buf, p["we_up"].astype(cd))
    out = jnp.einsum("gecf,efd->gecd", h, p["we_down"].astype(cd))
    # combine all-to-all: back to token-major so the slot gather is local
    out = sctx.shard(out.reshape(G, E * C, d), "groups", "cap", "embed")

    # ---- combine -------------------------------------------------------------
    y_slots = jnp.take_along_axis(out, slot[..., None], axis=1)
    w = (top_p.reshape(G, Tg * k) * keep.astype(jnp.float32)).astype(cd)
    y = (y_slots * w[..., None]).reshape(G, Tg, k, d).sum(axis=2)
    y = y.reshape(B, S, d)

    # ---- shared experts (always-on dense path) ------------------------------
    if m.n_shared:
        g = act(sctx.shard(
            jnp.einsum("bsd,df->bsf", x, p["ws_gate"].astype(cd)),
            "batch", "seq", "ff"))
        u = sctx.shard(jnp.einsum("bsd,df->bsf", x, p["ws_up"].astype(cd)),
                       "batch", "seq", "ff")
        y = y + jnp.einsum("bsf,fd->bsd", g * u, p["ws_down"].astype(cd))

    return sctx.shard(y, "batch", "seq", "embed"), aux
