"""Attention: blocked (flash-style) pure-JAX attention + RoPE/M-RoPE + GQA
+ sliding-window + decode-with-cache.

The blocked implementation is the production CPU/dry-run path AND the oracle
for the Pallas kernel (kernels/flash_attention.py). It never materializes the
full (Sq × Skv) score matrix: an outer scan over query blocks and an inner
online-softmax scan over KV blocks keep the working set at
(q_block × kv_block) per head — the same tiling the TPU kernel uses in VMEM.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import sctx
from repro.models.common import ModelConfig, ParamDef, rms_norm, softcap

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def _rope_inv_freq(head_dim: int, theta: float):
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, D); positions: broadcastable to (..., S)."""
    half = x.shape[-1] // 2
    inv = _rope_inv_freq(x.shape[-1], theta)
    ang = positions[..., None].astype(jnp.float32) * inv      # (..., S, half)
    sin = jnp.sin(ang)[..., None, :]                          # (..., S, 1, half)
    cos = jnp.cos(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    x32_1, x32_2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate(
        [x32_1 * cos - x32_2 * sin, x32_2 * cos + x32_1 * sin], axis=-1
    )
    return out.astype(x.dtype)


def apply_mrope(x, positions, theta: float, sections):
    """Qwen2-VL multimodal RoPE. positions: (3, ..., S) for (t, h, w);
    ``sections`` splits the rotary half-dim across the three streams."""
    half = x.shape[-1] // 2
    assert sum(sections) == half, (sections, half)
    inv = _rope_inv_freq(x.shape[-1], theta)                  # (half,)
    # pick, per rotary channel, which position stream drives it
    sec_id = jnp.repeat(
        jnp.arange(3), jnp.array(sections), total_repeat_length=half
    )                                                          # (half,)
    # positions: (3, ..., S) -> (..., S, half) by selecting stream per channel
    pos = jnp.moveaxis(positions[sec_id], 0, -1)               # (..., S, half)
    ang = pos.astype(jnp.float32) * inv
    sin, cos = jnp.sin(ang)[..., None, :], jnp.cos(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    x32_1, x32_2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate(
        [x32_1 * cos - x32_2 * sin, x32_2 * cos + x32_1 * sin], axis=-1
    )
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# blocked flash-style attention (training / prefill)
#
# Two paths:
#  * autodiff path (kv_valid_len / softcap support) — serving only;
#  * custom-VJP path (training default): the backward recomputes score
#    tiles from (q, k, v, out, lse) — flash-attention backward — instead of
#    saving the online-softmax carries of every KV step, which costs
#    O(S·D·n_kv_blocks) residual memory under scan autodiff.
# ---------------------------------------------------------------------------

def _tile_mask(q_pos, kv_pos, causal: bool, window: int):
    """(qb, kb) boolean mask tile from absolute positions."""
    m = jnp.ones((q_pos.shape[0], kv_pos.shape[0]), bool)
    if causal:
        m &= q_pos[:, None] >= kv_pos[None, :]
    if window:
        m &= q_pos[:, None] - kv_pos[None, :] < window
    return m


def blocked_attention(q, k, v, *, causal=True, window=0, q_offset=0,
                      kv_valid_len=None, q_block=512, kv_block=1024,
                      cap=0.0):
    """Online-softmax attention without materializing (Sq × Skv).

    q: (B, Sq, H, D); k, v: (B, Skv, KVH, D) with H % KVH == 0.
    window: 0 = none, else sliding window (local attention).
    q_offset: absolute position of q[0] (prefill continuation / decode).
    kv_valid_len: mask kv positions >= this (cache not yet filled).

    Inputs keep their (bf16) dtype — scores/accumulators are fp32 via MXU
    native mixed precision (preferred_element_type), which halves the
    activation footprint vs upcasting q/k/v.
    """
    B, Sq, H, D = q.shape
    _, Skv, KVH, _ = k.shape
    Dv = v.shape[-1]                      # may differ from D (MLA)
    G = H // KVH
    scale = 1.0 / math.sqrt(D)

    qb = min(q_block, Sq)
    kb = min(kv_block, Skv)
    # pad to block multiples
    pq, pk = (-Sq) % qb, (-Skv) % kb
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
    nq, nk = (Sq + pq) // qb, (Skv + pk) // kb

    out_dtype = q.dtype
    q = q.reshape(B, nq, qb, KVH, G, D)
    k = k.reshape(B, nk, kb, KVH, D)
    v = v.reshape(B, nk, kb, KVH, Dv)

    q_pos = q_offset + jnp.arange(Sq + pq).reshape(nq, qb)
    kv_pos = jnp.arange(Skv + pk).reshape(nk, kb)
    kv_lim = Skv if kv_valid_len is None else kv_valid_len

    def q_block_fn(qpos_tile, q_tile):
        # q_tile: (B, qb, KVH, G, D); qpos_tile: (qb,)
        def kv_step(carry, inputs):
            m_run, l_run, acc = carry
            k_tile, v_tile, kpos = inputs           # (B,kb,KVH,D), (kb,)
            s = jnp.einsum("bqhgd,bkhd->bhgqk", q_tile, k_tile,
                           preferred_element_type=jnp.float32) * scale
            s = softcap(s, cap)
            mask = _tile_mask(qpos_tile, kpos, causal, window)
            mask &= (kpos < kv_lim)[None, :]
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m_run, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m_run - m_new)
            l_new = l_run * corr + p.sum(axis=-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bhgqk,bkhd->bhgqd", p.astype(v_tile.dtype), v_tile,
                preferred_element_type=jnp.float32)
            return (m_new, l_new, acc), None

        m0 = jnp.full((B, KVH, G, qb), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, KVH, G, qb), jnp.float32)
        a0 = jnp.zeros((B, KVH, G, qb, Dv), jnp.float32)
        (m, l, acc), _ = lax.scan(
            kv_step, (m0, l0, a0),
            (k.swapaxes(0, 1), v.swapaxes(0, 1), kv_pos),
        )
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return out.astype(out_dtype)                 # (B, KVH, G, qb, Dv)

    # outer scan over q blocks keeps the HLO size O(1) in sequence length
    _, out = lax.scan(
        lambda _, inp: (0, jax.checkpoint(q_block_fn)(inp[0], inp[1])),
        0, (q_pos, q.swapaxes(0, 1)),
    )                                                # (nq, B, KVH, G, qb, Dv)
    out = out.transpose(1, 0, 4, 2, 3, 5).reshape(B, Sq + pq, H, Dv)
    return out[:, :Sq]


# ---------------------------------------------------------------------------
# custom-VJP flash path (training)
# ---------------------------------------------------------------------------

def _flash_fwd_impl(q, k, v, causal, window, qb, kb):
    """Returns out (B,Sq,H,Dv) and lse (B,Sq,H) fp32."""
    B, Sq, H, D = q.shape
    _, Skv, KVH, _ = k.shape
    Dv = v.shape[-1]
    G = H // KVH
    scale = 1.0 / math.sqrt(D)
    nq, nk = Sq // qb, Skv // kb
    qr = q.reshape(B, nq, qb, KVH, G, D).swapaxes(0, 1)
    kr = k.reshape(B, nk, kb, KVH, D).swapaxes(0, 1)
    vr = v.reshape(B, nk, kb, KVH, Dv).swapaxes(0, 1)
    q_pos = jnp.arange(Sq).reshape(nq, qb)
    kv_pos = jnp.arange(Skv).reshape(nk, kb)

    def q_block(qpos_tile, q_tile):
        def kv_step(carry, inputs):
            m_run, l_run, acc = carry
            k_tile, v_tile, kpos = inputs
            s = jnp.einsum("bqhgd,bkhd->bhgqk", q_tile, k_tile,
                           preferred_element_type=jnp.float32) * scale
            mask = _tile_mask(qpos_tile, kpos, causal, window)
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m_run, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m_run - m_new)
            l_new = l_run * corr + p.sum(axis=-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bhgqk,bkhd->bhgqd", p.astype(v_tile.dtype), v_tile,
                preferred_element_type=jnp.float32)
            return (m_new, l_new, acc), None

        m0 = jnp.full((B, KVH, G, qb), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, KVH, G, qb), jnp.float32)
        a0 = jnp.zeros((B, KVH, G, qb, Dv), jnp.float32)
        (m, l, acc), _ = lax.scan(kv_step, (m0, l0, a0), (kr, vr, kv_pos))
        out = (acc / jnp.maximum(l, 1e-30)[..., None]).astype(q.dtype)
        lse = m + jnp.log(jnp.maximum(l, 1e-30))
        return out, lse                              # (B,KVH,G,qb,·)

    _, (out, lse) = lax.scan(
        lambda _, inp: (0, jax.checkpoint(q_block)(inp[0], inp[1])),
        0, (q_pos, qr))
    out = out.transpose(1, 0, 4, 2, 3, 5).reshape(B, Sq, H, Dv)
    lse = lse.transpose(1, 0, 4, 2, 3).reshape(B, Sq, H)
    return out, lse


def _flash_bwd_impl(q, k, v, out, lse, dout, causal, window, qb, kb):
    """Flash backward: recompute p = exp(s − lse) per tile; never saves the
    online-softmax carries. dk/dv accumulate in fp32 over the q-block scan."""
    B, Sq, H, D = q.shape
    _, Skv, KVH, _ = k.shape
    Dv = v.shape[-1]
    G = H // KVH
    scale = 1.0 / math.sqrt(D)
    nq, nk = Sq // qb, Skv // kb

    delta = jnp.sum(dout.astype(jnp.float32) * out.astype(jnp.float32),
                    axis=-1)                          # (B,Sq,H)
    r5 = lambda t, n, b_: t.reshape(B, n, b_, KVH, G, -1).swapaxes(0, 1)
    qr = r5(q, nq, qb)
    dor = r5(dout, nq, qb)
    lser = lse.reshape(B, nq, qb, KVH, G).swapaxes(0, 1)
    deltar = delta.reshape(B, nq, qb, KVH, G).swapaxes(0, 1)
    kr = k.reshape(B, nk, kb, KVH, D)
    vr = v.reshape(B, nk, kb, KVH, Dv)
    q_pos = jnp.arange(Sq).reshape(nq, qb)
    kv_pos = jnp.arange(Skv).reshape(nk, kb)

    def q_step(carry, inp):
        dk_acc, dv_acc = carry                       # fp32 (B,nk,kb,KVH,·)
        q_i, do_i, lse_i, delta_i, qpos_i = inp

        def kv_step(dq_i, j):
            k_j = kr[:, j]
            v_j = vr[:, j]
            s = jnp.einsum("bqhgd,bkhd->bhgqk", q_i, k_j,
                           preferred_element_type=jnp.float32) * scale
            mask = _tile_mask(qpos_i, kv_pos[j], causal, window)
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            p = jnp.exp(s - lse_i.transpose(0, 2, 3, 1)[..., None])
            dv_j = jnp.einsum("bhgqk,bqhgv->bkhv", p.astype(do_i.dtype),
                              do_i, preferred_element_type=jnp.float32)
            dp = jnp.einsum("bqhgv,bkhv->bhgqk", do_i, v_j,
                            preferred_element_type=jnp.float32)
            ds = p * (dp - delta_i.transpose(0, 2, 3, 1)[..., None]) * scale
            dq_i = dq_i + jnp.einsum("bhgqk,bkhd->bqhgd",
                                     ds.astype(k_j.dtype), k_j,
                                     preferred_element_type=jnp.float32)
            dk_j = jnp.einsum("bhgqk,bqhgd->bkhd", ds.astype(q_i.dtype),
                              q_i, preferred_element_type=jnp.float32)
            return dq_i, (dk_j, dv_j)

        dq0 = jnp.zeros((B, qb, KVH, G, D), jnp.float32)
        dq_i, (dk_js, dv_js) = lax.scan(kv_step, dq0, jnp.arange(nk))
        # dk_js: (nk, B, kb, KVH, D) — add into the accumulators
        dk_acc = dk_acc + dk_js.swapaxes(0, 1)
        dv_acc = dv_acc + dv_js.swapaxes(0, 1)
        return (dk_acc, dv_acc), dq_i

    dk0 = jnp.zeros((B, nk, kb, KVH, D), jnp.float32)
    dv0 = jnp.zeros((B, nk, kb, KVH, Dv), jnp.float32)
    (dk, dv), dq = lax.scan(
        lambda c, inp: jax.checkpoint(q_step)(c, inp),
        (dk0, dv0), (qr, dor, lser, deltar, q_pos))

    dq = dq.swapaxes(0, 1).reshape(B, Sq, H, D).astype(q.dtype)
    dk = dk.reshape(B, Skv, KVH, D).astype(k.dtype)
    dv = dv.reshape(B, Skv, KVH, Dv).astype(v.dtype)
    return dq, dk, dv


@partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def _flash_attention(q, k, v, causal, window, qb, kb):
    out, _ = _flash_fwd_impl(q, k, v, causal, window, qb, kb)
    return out


def _flash_vjp_fwd(q, k, v, causal, window, qb, kb):
    out, lse = _flash_fwd_impl(q, k, v, causal, window, qb, kb)
    return out, (q, k, v, out, lse)


def _flash_vjp_bwd(causal, window, qb, kb, res, dout):
    q, k, v, out, lse = res
    return _flash_bwd_impl(q, k, v, out, lse, dout, causal, window, qb, kb)


_flash_attention.defvjp(_flash_vjp_fwd, _flash_vjp_bwd)


def flash_attention_train(q, k, v, *, causal=True, window=0, q_block=512,
                          kv_block=1024):
    """Training-path attention with the manual flash backward. Pads to
    block multiples; no kv_valid_len/softcap (serving uses the autodiff
    path)."""
    B, Sq, H, D = q.shape
    Skv = k.shape[1]
    qb = min(q_block, Sq)
    kb = min(kv_block, Skv)
    pq, pk = (-Sq) % qb, (-Skv) % kb
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
        if not causal:
            # padded KV columns must be masked out; causal+window masks
            # already exclude them for q < Sq, but pure full attention
            # needs the length mask — fall back to the autodiff path.
            raise ValueError("flash_attention_train requires causal=True "
                             "when padding KV")
    out = _flash_attention(q, k, v, causal, window, qb, kb)
    return out[:, :Sq]


def decode_attention(q, k_cache, v_cache, valid_mask, cap=0.0):
    """Single-position attention vs a cache.

    q: (B, 1, H, D); k_cache/v_cache: (B, S, KVH, D);
    valid_mask: (B, S) or (S,) bool — which cache slots participate.
    O(S) per new token; the cache's S dim may be sharded (GSPMD reduces
    the partial softmax terms — flash-decoding style).
    """
    B, _, H, D = q.shape
    KVH = k_cache.shape[2]
    Dv = v_cache.shape[-1]
    G = H // KVH
    scale = 1.0 / math.sqrt(D)
    # keep the (large, sharded) cache in its storage dtype; accumulate the
    # contractions in fp32 on the MXU instead of materializing an fp32 copy
    qg = q.reshape(B, KVH, G, D).astype(k_cache.dtype)
    s = jnp.einsum("bhgd,bshd->bhgs", qg, k_cache,
                   preferred_element_type=jnp.float32) * scale
    s = softcap(s, cap)
    if valid_mask.ndim == 1:
        valid_mask = valid_mask[None]
    s = jnp.where(valid_mask[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgs,bshd->bhgd", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, 1, H, Dv)


# ---------------------------------------------------------------------------
# the attention block (params + forward)
# ---------------------------------------------------------------------------

def attention_defs(cfg: ModelConfig) -> dict:
    D = cfg.resolved_head_dim
    d = cfg.d_model
    H, KVH = cfg.n_heads, cfg.n_kv_heads
    defs = {
        "wq": ParamDef((d, H, D), ("embed", "q_heads", "head_dim")),
        "wk": ParamDef((d, KVH, D), ("embed", "kv_heads", "head_dim")),
        "wv": ParamDef((d, KVH, D), ("embed", "kv_heads", "head_dim")),
        "wo": ParamDef((H, D, d), ("q_heads", "head_dim", "embed_out")),
    }
    if cfg.qkv_bias:
        defs["bq"] = ParamDef((H, D), ("q_heads", "head_dim"), init="zeros")
        defs["bk"] = ParamDef((KVH, D), ("kv_heads", "head_dim"), init="zeros")
        defs["bv"] = ParamDef((KVH, D), ("kv_heads", "head_dim"), init="zeros")
    if cfg.qk_norm:
        defs["q_norm"] = ParamDef((D,), ("head_dim",), init="zeros")
        defs["k_norm"] = ParamDef((D,), ("head_dim",), init="zeros")
    return defs


def _project_qkv(cfg: ModelConfig, p, x, positions, *, theta,
                 mrope_positions=None):
    cd = cfg.compute_dtype
    q = sctx.shard(jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(cd)),
                   "batch", "seq", "heads", "head_dim")
    k = sctx.shard(jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(cd)),
                   "batch", "seq", "kv_heads", "head_dim")
    v = sctx.shard(jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(cd)),
                   "batch", "seq", "kv_heads", "head_dim")
    if cfg.qkv_bias:
        q = q + p["bq"].astype(cd)
        k = k + p["bk"].astype(cd)
        v = v + p["bv"].astype(cd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"])
        k = rms_norm(k, p["k_norm"])
    if cfg.mrope_sections is not None and mrope_positions is not None:
        q = apply_mrope(q, mrope_positions, theta, cfg.mrope_sections)
        k = apply_mrope(k, mrope_positions, theta, cfg.mrope_sections)
    else:
        q = apply_rope(q, positions, theta)
        k = apply_rope(k, positions, theta)
    return q, k, v


def attention_block(cfg: ModelConfig, p, x, positions, *, kind="attn",
                    cache=None, cache_pos=None, mrope_positions=None):
    """One attention block.

    Modes:
      * cache is None              — training / teacher-forced forward.
      * cache given, x.shape[1]==1 — decode: read+update cache at cache_pos.
      * cache given, x longer      — prefill: fill cache, return outputs.

    cache: dict(k=(B,Sc,KVH,D), v=..., offset=()) — for "local" layers Sc is
    the ring-buffer window; for "attn" (global) layers Sc is the max context.
    """
    cd = cfg.compute_dtype
    window = cfg.window if kind == "local" else 0
    theta = cfg.rope_theta if kind == "local" or not cfg.rope_theta_global \
        else cfg.rope_theta_global
    q, k, v = _project_qkv(cfg, p, x, positions, theta=theta,
                           mrope_positions=mrope_positions)

    new_cache = cache
    if cache is None:
        out = flash_attention_train(q, k, v, causal=True, window=window,
                                    q_block=cfg.attn_q_block,
                                    kv_block=cfg.attn_kv_block)
    elif x.shape[1] == 1:
        Sc = cache["k"].shape[1]
        if window:
            slot = (cache_pos % Sc)[..., None]
        else:
            slot = cache_pos[..., None]
        bidx = jnp.arange(x.shape[0])[:, None]
        k_c = cache["k"].at[bidx, slot].set(k.astype(cache["k"].dtype))
        v_c = cache["v"].at[bidx, slot].set(v.astype(cache["v"].dtype))
        slots = jnp.arange(Sc)
        if window:
            # ring buffer: before wrap-around only slots 0..pos are written;
            # after wrap-around every slot holds one of the last Sc tokens.
            valid = (slots[None, :] <= cache_pos[:, None]) | \
                    (cache_pos[:, None] >= Sc)
        else:
            valid = slots[None, :] <= cache_pos[:, None]
        out = decode_attention(q, k_c.astype(cd), v_c.astype(cd), valid,
                               cap=0.0)
        new_cache = {"k": k_c, "v": v_c}
    else:
        out = blocked_attention(q, k, v, causal=True, window=window)
        Sc = cache["k"].shape[1]
        S = x.shape[1]
        if S >= Sc:
            k_w, v_w = k[:, -Sc:], v[:, -Sc:]
            k_c = k_w.astype(cache["k"].dtype)
            v_c = v_w.astype(cache["v"].dtype)
            if window and Sc:
                # keep ring-buffer slot alignment: roll so that token t sits
                # at slot t % Sc
                shift = S % Sc
                k_c = jnp.roll(k_c, shift, axis=1)
                v_c = jnp.roll(v_c, shift, axis=1)
        else:
            k_c = cache["k"].at[:, :S].set(k.astype(cache["k"].dtype))
            v_c = cache["v"].at[:, :S].set(v.astype(cache["v"].dtype))
        new_cache = {"k": k_c, "v": v_c}

    out = sctx.shard(out.astype(cd), "batch", "seq", "heads", "head_dim")
    y = sctx.shard(jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(cd)),
                   "batch", "seq", "embed")
    return y, new_cache
