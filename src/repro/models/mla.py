"""Multi-head Latent Attention (DeepSeek-V2, arXiv:2405.04434).

Queries and keys/values are low-rank compressed:
  c_q  = RMSNorm(x · W_dq)            (q_lora_rank)
  q    = c_q · W_uq  -> split [q_nope | q_pe];  q_pe gets RoPE
  c_kv | k_pe = x · W_dkv             (kv_lora_rank + rope_dim)
  c_kv = RMSNorm(c_kv);  k_pe gets RoPE (shared across heads)
  k    = [c_kv · W_uk | k_pe],  v = c_kv · W_uv

The decode cache stores ONLY (c_kv, k_pe) — kv_lora+rope floats per token
(576 for DeepSeek-V2) instead of 2·H·D. Decode uses the absorbed form:
  score_t = (q_nope · W_ukᵀ) · c_kv_t + q_pe · k_pe_t
  out     = (Σ p_t c_kv_t) · W_uv
so per-step FLOPs never expand the cache into per-head keys.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models import sctx
from repro.models.common import ModelConfig, ParamDef, rms_norm, softcap
from repro.models.attention import (
    apply_rope, blocked_attention, flash_attention_train, NEG_INF,
)


def mla_defs(cfg: ModelConfig) -> dict:
    a = cfg.mla
    d, H = cfg.d_model, cfg.n_heads
    qk = a.qk_nope_head_dim + a.qk_rope_head_dim
    return {
        "w_dq": ParamDef((d, a.q_lora_rank), ("embed", "lora")),
        "q_norm": ParamDef((a.q_lora_rank,), ("lora",), init="zeros"),
        "w_uq": ParamDef((a.q_lora_rank, H, qk), ("lora", "q_heads", "head_dim")),
        "w_dkv": ParamDef((d, a.kv_lora_rank + a.qk_rope_head_dim),
                          ("embed", "lora")),
        "kv_norm": ParamDef((a.kv_lora_rank,), ("lora",), init="zeros"),
        "w_uk": ParamDef((a.kv_lora_rank, H, a.qk_nope_head_dim),
                         ("lora", "q_heads", "head_dim")),
        "w_uv": ParamDef((a.kv_lora_rank, H, a.v_head_dim),
                         ("lora", "q_heads", "head_dim")),
        "wo": ParamDef((H, a.v_head_dim, d), ("q_heads", "head_dim",
                                              "embed_out")),
    }


def _q_proj(cfg, p, x, positions):
    a = cfg.mla
    cd = cfg.compute_dtype
    cq = rms_norm(jnp.einsum("bsd,dr->bsr", x, p["w_dq"].astype(cd)),
                  p["q_norm"])
    q = sctx.shard(jnp.einsum("bsr,rhk->bshk", cq, p["w_uq"].astype(cd)),
                   "batch", "seq", "heads", "head_dim")
    q_nope = q[..., : a.qk_nope_head_dim]
    q_pe = apply_rope(q[..., a.qk_nope_head_dim:], positions, cfg.rope_theta)
    return q_nope, q_pe


def _kv_compress(cfg, p, x, positions):
    a = cfg.mla
    cd = cfg.compute_dtype
    ckv_full = jnp.einsum("bsd,dr->bsr", x, p["w_dkv"].astype(cd))
    c_kv = rms_norm(ckv_full[..., : a.kv_lora_rank], p["kv_norm"])
    k_pe = ckv_full[..., a.kv_lora_rank:][:, :, None, :]     # (B,S,1,rope)
    k_pe = apply_rope(k_pe, positions, cfg.rope_theta)[:, :, 0]
    return c_kv, k_pe


def mla_block(cfg: ModelConfig, p, x, positions, *, cache=None,
              cache_pos=None, **_unused):
    """Same interface as attention.attention_block. Cache holds the
    COMPRESSED representation: {ckv: (B,Sc,rank), kpe: (B,Sc,rope)}."""
    a = cfg.mla
    cd = cfg.compute_dtype
    H = cfg.n_heads
    B, S, _ = x.shape

    q_nope, q_pe = _q_proj(cfg, p, x, positions)

    if cache is not None and S == 1:
        # ---- absorbed decode ------------------------------------------------
        c_kv_t, k_pe_t = _kv_compress(cfg, p, x, positions)
        bidx = jnp.arange(B)[:, None]
        slot = cache_pos[..., None]
        ckv = cache["ckv"].at[bidx, slot].set(c_kv_t.astype(cache["ckv"].dtype))
        kpe = cache["kpe"].at[bidx, slot].set(k_pe_t.astype(cache["kpe"].dtype))
        Sc = ckv.shape[1]
        valid = jnp.arange(Sc)[None, :] <= cache_pos[:, None]

        # absorb W_uk into q:  (B,1,H,nope) x (rank,H,nope) -> (B,H,rank)
        q_abs = jnp.einsum("bshk,rhk->bhr", q_nope, p["w_uk"].astype(cd))
        scale = 1.0 / math.sqrt(a.qk_nope_head_dim + a.qk_rope_head_dim)
        # cache stays in storage dtype; fp32 accumulation on the MXU
        s = (jnp.einsum("bhr,bsr->bhs", q_abs.astype(ckv.dtype), ckv,
                        preferred_element_type=jnp.float32)
             + jnp.einsum("bshk,btk->bht", q_pe.astype(kpe.dtype), kpe,
                          preferred_element_type=jnp.float32)) * scale
        s = jnp.where(valid[:, None, :], s, NEG_INF)
        prob = jax.nn.softmax(s, axis=-1)
        ctx = jnp.einsum("bhs,bsr->bhr", prob.astype(ckv.dtype), ckv,
                         preferred_element_type=jnp.float32)
        out = jnp.einsum("bhr,rhv->bhv", ctx.astype(cd),
                         p["w_uv"].astype(cd))[:, None]       # (B,1,H,v)
        new_cache = {"ckv": ckv, "kpe": kpe}
    else:
        # ---- training / prefill: expand and use the blocked kernel --------
        c_kv, k_pe = _kv_compress(cfg, p, x, positions)
        k_nope = sctx.shard(
            jnp.einsum("bsr,rhk->bshk", c_kv, p["w_uk"].astype(cd)),
            "batch", "seq", "heads", "head_dim")
        v = sctx.shard(jnp.einsum("bsr,rhv->bshv", c_kv, p["w_uv"].astype(cd)),
                       "batch", "seq", "heads", "head_dim")
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_pe[:, :, None, :],
                                      k_nope.shape[:3] + (a.qk_rope_head_dim,))],
            axis=-1,
        )
        q = jnp.concatenate([q_nope, q_pe], axis=-1)
        if cache is None:
            out = flash_attention_train(q, k, v, causal=True,
                                        q_block=cfg.attn_q_block,
                                        kv_block=cfg.attn_kv_block)
        else:
            out = blocked_attention(q, k, v, causal=True)
        new_cache = cache
        if cache is not None:
            Sc = cache["ckv"].shape[1]
            ckv = cache["ckv"].at[:, :S].set(
                c_kv[:, :Sc].astype(cache["ckv"].dtype))
            kpe = cache["kpe"].at[:, :S].set(
                k_pe[:, :Sc].astype(cache["kpe"].dtype))
            new_cache = {"ckv": ckv, "kpe": kpe}

    out = sctx.shard(out.astype(cd), "batch", "seq", "heads", "head_dim")
    y = sctx.shard(jnp.einsum("bshv,hvd->bsd", out, p["wo"].astype(cd)),
                   "batch", "seq", "embed")
    return y, new_cache
