"""Small CNNs for reproducing the paper's own experiments.

The paper trains LeNet on MNIST (Table 3 / Fig 8-11: 4-GPU EASGD variants)
and AlexNet on CIFAR (Fig 12-13: KNL partitioning). We implement both
(LeNet-5 faithful; AlexNet scaled to 32×32 as in the paper's CIFAR runs) and
use them with the async engine + synthetic datasets for the convergence
reproductions. Pure jnp — small enough to train on this CPU.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
from jax import lax


def _conv(x, w, b, stride=1, padding="SAME"):
    out = lax.conv_general_dilated(
        x, w, (stride, stride), padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return out + b[None, None, None]


def _init(key, shape, fan_in):
    return jax.random.normal(key, shape, jnp.float32) * math.sqrt(2.0 / fan_in)


# ---------------------------------------------------------------------------
# LeNet-5 (28x28x1, 10 classes) — the paper's MNIST model
# ---------------------------------------------------------------------------

def lenet_init(key, n_classes=10):
    ks = jax.random.split(key, 8)
    return {
        "c1w": _init(ks[0], (5, 5, 1, 6), 25), "c1b": jnp.zeros(6),
        "c2w": _init(ks[1], (5, 5, 6, 16), 150), "c2b": jnp.zeros(16),
        "f1w": _init(ks[2], (7 * 7 * 16, 120), 784), "f1b": jnp.zeros(120),
        "f2w": _init(ks[3], (120, 84), 120), "f2b": jnp.zeros(84),
        "f3w": _init(ks[4], (84, n_classes), 84), "f3b": jnp.zeros(n_classes),
    }


def lenet_apply(p, x):
    """x: (B, 28, 28, 1) -> logits (B, 10)."""
    h = jnp.tanh(_conv(x, p["c1w"], p["c1b"]))
    h = lax.reduce_window(h, -jnp.inf, lax.max, (1, 2, 2, 1), (1, 2, 2, 1),
                          "SAME")
    h = jnp.tanh(_conv(h, p["c2w"], p["c2b"]))
    h = lax.reduce_window(h, -jnp.inf, lax.max, (1, 2, 2, 1), (1, 2, 2, 1),
                          "SAME")
    h = h.reshape(h.shape[0], -1)
    h = jnp.tanh(h @ p["f1w"] + p["f1b"])
    h = jnp.tanh(h @ p["f2w"] + p["f2b"])
    return h @ p["f3w"] + p["f3b"]


# ---------------------------------------------------------------------------
# AlexNet-for-CIFAR (32x32x3, 10 classes) — the paper's CIFAR model
# ---------------------------------------------------------------------------

def alexnet_init(key, n_classes=10):
    ks = jax.random.split(key, 10)
    return {
        "c1w": _init(ks[0], (3, 3, 3, 64), 27), "c1b": jnp.zeros(64),
        "c2w": _init(ks[1], (3, 3, 64, 192), 576), "c2b": jnp.zeros(192),
        "c3w": _init(ks[2], (3, 3, 192, 384), 1728), "c3b": jnp.zeros(384),
        "c4w": _init(ks[3], (3, 3, 384, 256), 3456), "c4b": jnp.zeros(256),
        "c5w": _init(ks[4], (3, 3, 256, 256), 2304), "c5b": jnp.zeros(256),
        "f1w": _init(ks[5], (4 * 4 * 256, 1024), 4096), "f1b": jnp.zeros(1024),
        "f2w": _init(ks[6], (1024, 512), 1024), "f2b": jnp.zeros(512),
        "f3w": _init(ks[7], (512, n_classes), 512), "f3b": jnp.zeros(n_classes),
    }


def alexnet_apply(p, x):
    """x: (B, 32, 32, 3) -> logits."""
    pool = partial(lax.reduce_window, init_value=-jnp.inf, computation=lax.max,
                   window_dimensions=(1, 2, 2, 1), window_strides=(1, 2, 2, 1),
                   padding="SAME")
    h = jax.nn.relu(_conv(x, p["c1w"], p["c1b"]))
    h = pool(h)
    h = jax.nn.relu(_conv(h, p["c2w"], p["c2b"]))
    h = pool(h)
    h = jax.nn.relu(_conv(h, p["c3w"], p["c3b"]))
    h = jax.nn.relu(_conv(h, p["c4w"], p["c4b"]))
    h = jax.nn.relu(_conv(h, p["c5w"], p["c5b"]))
    h = pool(h)
    h = h.reshape(h.shape[0], -1)
    h = jax.nn.relu(h @ p["f1w"] + p["f1b"])
    h = jax.nn.relu(h @ p["f2w"] + p["f2b"])
    return h @ p["f3w"] + p["f3b"]


# ---------------------------------------------------------------------------
# small MLP (fast CPU convergence experiments)
# ---------------------------------------------------------------------------

def mlp_init(key, d_in=64, d_hidden=128, n_classes=10, depth=2):
    ks = jax.random.split(key, depth + 1)
    p = {}
    d = d_in
    for i in range(depth):
        p[f"w{i}"] = _init(ks[i], (d, d_hidden), d)
        p[f"b{i}"] = jnp.zeros(d_hidden)
        d = d_hidden
    p["w_out"] = _init(ks[-1], (d, n_classes), d)
    p["b_out"] = jnp.zeros(n_classes)
    return p


def mlp_apply(p, x, depth=2):
    h = x
    for i in range(depth):
        h = jax.nn.relu(h @ p[f"w{i}"] + p[f"b{i}"])
    return h @ p["w_out"] + p["b_out"]


def xent_loss(logits, labels):
    logz = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[:, None], axis=1)[:, 0]
    return jnp.mean(logz - ll)


def accuracy(logits, labels):
    return jnp.mean((jnp.argmax(logits, -1) == labels).astype(jnp.float32))
