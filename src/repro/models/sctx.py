"""Activation-sharding context.

Model code is mesh-agnostic; the runtime installs a constraint function
here (active during tracing) and blocks call ``shard(x, *logical_axes)`` at
layout-critical points (projection outputs, block boundaries, FFN hidden,
logits chunks). Without these constraints the SPMD partitioner may choose
replicated activations (measured: one unconstrained QKV projection cost
18.5 GiB/device on the gemma3-4b probe).

Logical activation axes: "batch", "seq", "embed", "heads", "kv_heads",
"head_dim", "ff", "vocab", "experts", "groups", "inner".
"""
from __future__ import annotations

import contextlib
import contextvars

_ctx = contextvars.ContextVar("activation_sharding", default=None)


def shard(x, *logical):
    """Apply the installed constraint (no-op when none installed)."""
    fn = _ctx.get()
    if fn is None:
        return x
    return fn(x, logical)


@contextlib.contextmanager
def use(fn):
    token = _ctx.set(fn)
    try:
        yield
    finally:
        _ctx.reset(token)
