"""Checkpoint/restart: atomic, async, keep-N, preemption-safe.

EASGD makes checkpointing cheap at scale: the durable state is the CENTER
weight + step (small, slowly-moving); per-pod local weights are best-effort
(a restarted pod may re-seed from the center — that is EASGD's own
semantics, see ft/elastic_scale.py). We still checkpoint the full
ElasticState for exact resume.

Layout:  <dir>/step_<N>/ {meta.json, arrays.npz}  written to a tmp dir and
renamed (atomic on POSIX). ``save_async`` hands the (host-fetched) state to
a background thread so the train loop never blocks on disk.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Optional

import jax
import numpy as np


def _flatten_with_paths(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(str(p) for p in path)
        out[key] = np.asarray(leaf)
    return out


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._last_error: Optional[BaseException] = None

    # -- write ---------------------------------------------------------------
    def save(self, step: int, state: Any, extra: Optional[dict] = None):
        """Blocking atomic save."""
        state = jax.device_get(state)
        self._write(step, state, extra or {})

    def save_async(self, step: int, state: Any, extra: Optional[dict] = None):
        """Non-blocking: fetch to host now, write on a background thread."""
        self.wait()
        state = jax.device_get(state)   # snapshot before training mutates it
        self._thread = threading.Thread(
            target=self._write_safe, args=(step, state, extra or {}),
            daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._last_error is not None:
            err, self._last_error = self._last_error, None
            raise err

    def _write_safe(self, step, state, extra):
        try:
            self._write(step, state, extra)
        except BaseException as e:  # surfaced on next wait()
            self._last_error = e

    def _write(self, step: int, state, extra: dict):
        leaves, treedef = jax.tree_util.tree_flatten(state)
        tmp = os.path.join(self.dir, f".tmp_step_{step}_{os.getpid()}")
        final = os.path.join(self.dir, f"step_{step:012d}")
        os.makedirs(tmp, exist_ok=True)
        np.savez(os.path.join(tmp, "arrays.npz"),
                 **{f"leaf_{i}": np.asarray(x) for i, x in enumerate(leaves)})
        meta = {
            "step": step,
            "time": time.time(),
            "n_leaves": len(leaves),
            "treedef": str(treedef),
            "extra": extra,
        }
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump(meta, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)           # atomic publish
        self._gc()

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:012d}"),
                          ignore_errors=True)

    # -- read ----------------------------------------------------------------
    def all_steps(self):
        out = []
        for name in sorted(os.listdir(self.dir)):
            if name.startswith("step_"):
                try:
                    out.append(int(name.split("_")[1]))
                except ValueError:
                    pass
        return out

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, template: Any, step: Optional[int] = None):
        """Restore into the structure of ``template`` (values replaced).
        Returns (state, meta)."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        path = os.path.join(self.dir, f"step_{step:012d}")
        with open(os.path.join(path, "meta.json")) as f:
            meta = json.load(f)
        data = np.load(os.path.join(path, "arrays.npz"))
        leaves_t, treedef = jax.tree_util.tree_flatten(template)
        assert len(leaves_t) == meta["n_leaves"], (
            f"checkpoint has {meta['n_leaves']} leaves, template has "
            f"{len(leaves_t)} — architecture mismatch")
        leaves = []
        for i, t in enumerate(leaves_t):
            arr = data[f"leaf_{i}"]
            assert arr.shape == tuple(t.shape), (
                f"leaf {i}: checkpoint {arr.shape} vs template {t.shape}")
            leaves.append(jax.numpy.asarray(arr, dtype=t.dtype))
        return jax.tree_util.tree_unflatten(treedef, leaves), meta
