"""Version compatibility for the jax APIs this repo uses.

The code targets the modern API (``jax.shard_map`` with ``check_vma``,
``jax.make_mesh(..., axis_types=...)``); the container bakes in jax 0.4.x
where shard_map lives in ``jax.experimental.shard_map`` (with ``check_rep``)
and ``make_mesh`` has no ``axis_types``. Every shard_map/mesh call site goes
through THIS module so the whole stack — runtime, comm schedules, tests,
benchmarks — runs on either version.
"""
from __future__ import annotations

import jax

if hasattr(jax, "shard_map"):                      # jax >= 0.6 style

    def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = False):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)

else:                                              # jax 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map

    def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = False):
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_rep=check_vma)


if hasattr(jax.lax, "axis_size"):

    def axis_size(axis_name) -> int:
        """Static size of a bound mesh axis (inside shard_map)."""
        return jax.lax.axis_size(axis_name)

else:

    def axis_size(axis_name) -> int:
        """Static size of a bound mesh axis (inside shard_map).
        jax 0.4.x: the axis env frame is the plain int size."""
        import jax.core as _core
        return int(_core.axis_frame(axis_name))


# AxisType only exists on newer jax; all call sites here use Auto everywhere,
# which is also the old default — so it is safe to drop when unsupported.
AXIS_TYPE_AUTO = getattr(getattr(jax.sharding, "AxisType", None), "Auto",
                         None)


def make_mesh(axis_shapes, axis_names, *, axis_types=None, devices=None):
    kwargs = {"devices": devices} if devices is not None else {}
    if axis_types is not None and AXIS_TYPE_AUTO is not None:
        return jax.make_mesh(axis_shapes, axis_names, axis_types=axis_types,
                             **kwargs)
    return jax.make_mesh(axis_shapes, axis_names, **kwargs)


def pallas_tpu_compiler_params(**kwargs):
    """pltpu.CompilerParams on modern jax, TPUCompilerParams on 0.4.x."""
    from jax.experimental.pallas import tpu as pltpu
    cls = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams
    return cls(**kwargs)


def auto_mesh(axis_shapes, axis_names, *, devices=None):
    """A mesh with every axis Auto (the common case in tests/benchmarks)."""
    types = (None if AXIS_TYPE_AUTO is None
             else (AXIS_TYPE_AUTO,) * len(axis_names))
    return make_mesh(axis_shapes, axis_names, axis_types=types,
                     devices=devices)
