"""Wall-clock timing helpers (block_until_ready-aware).

Jax-free at import time: jax loads lazily, only when a caller actually
hands us a tree to synchronize. TCP workers (which must never import jax
— see tests/test_net.py::test_tcp_worker_is_jax_free) can use ``now`` and
bare ``Timer()`` freely.
"""
from __future__ import annotations

import time


def _block_until_ready(tree):
    import jax
    jax.block_until_ready(tree)


def now() -> float:
    return time.perf_counter()


class Timer:
    """Context manager measuring wall time, sync'ing JAX async dispatch."""

    def __init__(self, sync_tree=None):
        self._sync_tree = sync_tree
        self.elapsed = 0.0

    def __enter__(self):
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        if self._sync_tree is not None:
            _block_until_ready(self._sync_tree)
        self.elapsed = time.perf_counter() - self._t0
        return False


def time_fn(fn, *args, iters: int = 3, warmup: int = 1):
    """Time a jitted fn: returns best-of-iters seconds."""
    for _ in range(warmup):
        _block_until_ready(fn(*args))
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        _block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best
