from repro.utils.pytree import (
    tree_size,
    tree_bytes,
    tree_zeros_like,
    tree_add,
    tree_sub,
    tree_scale,
    tree_dot,
    tree_norm,
    tree_cast,
    tree_map,
)
from repro.utils.timing import Timer, now
