"""repro.utils — pytree helpers (jax-backed) and timing (jax-free).

The package init is lazy (PEP 562): ``repro.utils.timing`` must be
importable from jax-free processes (TCP workers), and an eager
``from repro.utils.pytree import …`` here would pull jax into every one
of them. Attribute access (``repro.utils.tree_size``) still works and
resolves to the pytree module on first touch.
"""
from repro.utils.timing import Timer, now  # noqa: F401 — jax-free

_PYTREE = (
    "tree_size", "tree_bytes", "tree_zeros_like", "tree_add", "tree_sub",
    "tree_scale", "tree_dot", "tree_norm", "tree_cast", "tree_map",
)

__all__ = ["Timer", "now", *_PYTREE]


def __getattr__(name):
    if name in _PYTREE:
        from repro.utils import pytree
        return getattr(pytree, name)
    raise AttributeError(f"module 'repro.utils' has no attribute {name!r}")
