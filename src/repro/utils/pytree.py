"""Pytree arithmetic helpers used across the framework.

These are deliberately tiny: the framework builds its own optimizer /
elastic-averaging machinery (no optax dependency), so pointwise pytree
algebra shows up everywhere.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

tree_map = jax.tree_util.tree_map


def tree_size(tree) -> int:
    """Total number of scalar elements in a pytree of arrays."""
    return sum(int(x.size) for x in jax.tree_util.tree_leaves(tree))


def tree_bytes(tree) -> int:
    """Total bytes of a pytree of arrays (or ShapeDtypeStructs)."""
    return sum(
        int(x.size) * jnp.dtype(x.dtype).itemsize
        for x in jax.tree_util.tree_leaves(tree)
    )


def tree_zeros_like(tree):
    return tree_map(jnp.zeros_like, tree)


def tree_add(a, b):
    return tree_map(jnp.add, a, b)


def tree_sub(a, b):
    return tree_map(jnp.subtract, a, b)


def tree_scale(a, s):
    return tree_map(lambda x: x * s, a)


def tree_dot(a, b):
    """Sum of elementwise products across two pytrees (fp32 accumulate)."""
    parts = tree_map(
        lambda x, y: jnp.sum(x.astype(jnp.float32) * y.astype(jnp.float32)), a, b
    )
    return jax.tree_util.tree_reduce(jnp.add, parts)


def tree_norm(a):
    return jnp.sqrt(tree_dot(a, a))


def tree_cast(tree, dtype):
    return tree_map(lambda x: x.astype(dtype), tree)
