"""Core: the paper's contribution — EASGD family + communication co-design."""
from repro.core.easgd import (
    EASGDConfig,
    sgd_update,
    msgd_update,
    easgd_worker_update,
    measgd_worker_update,
    center_update_from_sum,
    center_update_from_mean,
    center_update_single,
    fused_elastic_step_flat,
)
from repro.core.elastic import (
    ElasticConfig,
    ElasticState,
    init as elastic_init,
    apply_gradients as elastic_apply_gradients,
    state_specs as elastic_state_specs,
)
from repro.core.packing import ELASTIC_UPDATE_BLOCK, Packer, packed_apply
from repro.core import collectives, compression, costmodel
