"""Core: the paper's contribution — EASGD family + communication co-design.

Exports resolve lazily (PEP 562): the numpy-only corners of core
(``compression``'s wire codecs, ``easgd_flat``, ``costmodel``) must stay
importable without paying the jax import — repro.net TCP worker processes
depend on that for sub-second startup.
"""
_EASGD = ("EASGDConfig", "sgd_update", "msgd_update", "easgd_worker_update",
          "measgd_worker_update", "center_update_from_sum",
          "center_update_from_mean", "center_update_single",
          "fused_elastic_step_flat")
_ELASTIC = {"ElasticConfig": "ElasticConfig", "ElasticState": "ElasticState",
            "elastic_init": "init",
            "elastic_apply_gradients": "apply_gradients",
            "elastic_state_specs": "state_specs"}
_PACKING = ("ELASTIC_UPDATE_BLOCK", "Packer", "packed_apply")
_SUBMODULES = ("collectives", "compression", "costmodel", "des", "easgd",
               "easgd_flat", "elastic", "packing", "async_engine")

__all__ = _EASGD + tuple(_ELASTIC) + _PACKING + _SUBMODULES


def __getattr__(name):
    import importlib
    if name in _EASGD:
        return getattr(importlib.import_module("repro.core.easgd"), name)
    if name in _ELASTIC:
        return getattr(importlib.import_module("repro.core.elastic"),
                       _ELASTIC[name])
    if name in _PACKING:
        return getattr(importlib.import_module("repro.core.packing"), name)
    if name in _SUBMODULES:
        return importlib.import_module(f"repro.core.{name}")
    raise AttributeError(f"module 'repro.core' has no attribute '{name}'")
