"""Single-buffer ("packed layer") parameter communication — paper §5.2.

The paper's observation: deep nets have hundreds of small tensors; sending
them one-by-one costs ``L·(α + nᵢ·β)`` under the α–β model, where the latency
term ``L·α`` dominates. Packing the whole parameter set into ONE contiguous
buffer reduces this to ``α + N·β`` and gives contiguous memory access.

On TPU the same logic applies to collectives: one big all-reduce on a flat
buffer beats hundreds of small per-tensor all-reduces (collective launch
overhead + ICI latency per hop), and lets the compiler use full-bandwidth
transfers.

``Packer`` turns an arbitrary parameter pytree into a single 1-D buffer and
back, with static (traced-once) metadata. Padding aligns the buffer to a
configurable multiple (lane/segment alignment for TPU collectives and for the
fused Pallas update kernel).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


# The fused Pallas elastic-update kernel (kernels/elastic_update.py) tiles
# packed buffers in (sublane × lane × block) = 8·128·128-element VMEM
# blocks. The packer pads to the SAME multiple so any default-aligned packed
# buffer divides evenly into kernel tiles — kernel and packer share this one
# constant and cannot drift.
ELASTIC_UPDATE_BLOCK = 8 * 128 * 128


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclasses.dataclass(frozen=True)
class _LeafSpec:
    shape: tuple
    dtype: Any
    offset: int  # element offset in the flat buffer
    size: int


@jax.tree_util.register_pytree_node_class
class Packer:
    """Flattens a pytree of arrays into one contiguous 1-D buffer.

    The packer is built once from a template pytree (arrays or
    ShapeDtypeStructs); ``pack``/``unpack`` are pure jittable functions.
    All leaves are stored in ``buffer_dtype`` (default fp32) — the packed
    buffer is the *communication* representation, so a uniform dtype is both
    required (single buffer) and desirable (deterministic reduction).
    """

    def __init__(self, template, buffer_dtype=jnp.float32,
                 align: int = ELASTIC_UPDATE_BLOCK):
        leaves, treedef = jax.tree_util.tree_flatten(template)
        self.treedef = treedef
        self.buffer_dtype = jnp.dtype(buffer_dtype)
        self.align = align
        specs = []
        off = 0
        for leaf in leaves:
            size = int(np.prod(leaf.shape)) if leaf.shape else 1
            specs.append(
                _LeafSpec(tuple(leaf.shape), jnp.dtype(leaf.dtype), off, size)
            )
            off += size
        self.specs = tuple(specs)
        self.n_elements = off
        self.buffer_size = _round_up(max(off, 1), align)

    # -- pytree protocol (lets a Packer ride inside jitted closures) --------
    def tree_flatten(self):
        return (), (self.treedef, self.buffer_dtype, self.align, self.specs,
                    self.n_elements, self.buffer_size)

    @classmethod
    def tree_unflatten(cls, aux, children):
        obj = cls.__new__(cls)
        (obj.treedef, obj.buffer_dtype, obj.align, obj.specs,
         obj.n_elements, obj.buffer_size) = aux
        return obj

    # -- core ----------------------------------------------------------------
    def pack(self, tree) -> jnp.ndarray:
        """Pytree -> single 1-D buffer (buffer_dtype), padded to alignment."""
        leaves = jax.tree_util.tree_leaves(tree)
        assert len(leaves) == len(self.specs), (
            f"packer built for {len(self.specs)} leaves, got {len(leaves)}"
        )
        flat = [x.astype(self.buffer_dtype).reshape(-1) for x in leaves]
        pad = self.buffer_size - self.n_elements
        if pad:
            flat.append(jnp.zeros((pad,), self.buffer_dtype))
        return jnp.concatenate(flat) if len(flat) > 1 else flat[0]

    def unpack(self, buffer: jnp.ndarray):
        """Single 1-D buffer -> pytree with original shapes/dtypes."""
        leaves = []
        for s in self.specs:
            chunk = jax.lax.dynamic_slice_in_dim(buffer, s.offset, s.size)
            leaves.append(chunk.reshape(s.shape).astype(s.dtype))
        return jax.tree_util.tree_unflatten(self.treedef, leaves)

    def zeros(self) -> jnp.ndarray:
        return jnp.zeros((self.buffer_size,), self.buffer_dtype)

    # -- bucketing ----------------------------------------------------------
    def layer_sizes(self) -> list:
        """Per-leaf element counts in packed order — the layer structure
        the bucketed exchange cuts on (comm.rounds.bucket_boundaries)."""
        return [s.size for s in self.specs]

    def bucket_bounds(self, target_elems: int) -> list:
        """Bucket cut offsets over the PADDED buffer: leaf edges grouped to
        ~``target_elems`` elements and rounded up to this packer's align,
        so every bucket is a whole number of fused-update kernel tiles.
        Same policy as the PS runtime's ``default_bucket_boundaries`` —
        the packed-collective and wire data planes bucket identically."""
        from repro.comm.rounds import bucket_boundaries
        return bucket_boundaries(self.layer_sizes(), self.buffer_size,
                                 target_elems, align=self.align)


def packed_apply(packer: Packer, fn, tree):
    """Apply ``fn`` to the packed representation and unpack the result.

    This is the paper's "one communication per exchange" pattern:
    ``packed_apply(p, lambda b: lax.pmean(b, 'pod'), local_weights)``.
    """
    return packer.unpack(fn(packer.pack(tree)))
