"""Explicit collective schedules — round-robin vs tree vs ring (paper §5.1/§6.1).

The paper's core scaling fix is replacing Original EASGD's round-robin
master↔worker exchange (Θ(P)) with a tree reduction (Θ(log P)). In XLA the
production path is GSPMD's native all-reduce (already tree/ring), but to
*demonstrate and benchmark* the schedules — and to control the hierarchy
(intra-pod ICI vs cross-pod DCI) — we implement them explicitly with
``lax.ppermute`` inside ``shard_map``.

All functions here are written to be called INSIDE ``shard_map`` with the
axis name(s) bound. Equivalence vs ``lax.psum`` is covered by tests on host
device meshes.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.core import costmodel


def psum_allreduce(x, axis_name):
    """Baseline: XLA-native all-reduce."""
    return lax.psum(x, axis_name)


def butterfly_allreduce(x, axis_name):
    """Recursive-doubling all-reduce: ⌈log2 P⌉ rounds, XOR partners.

    This is the Θ(log P) 'tree' schedule of Sync EASGD. Requires a
    power-of-two axis size.
    """
    p = lax.axis_size(axis_name)
    assert p & (p - 1) == 0, f"butterfly needs power-of-two axis, got {p}"
    d = 1
    while d < p:
        perm = [(i, i ^ d) for i in range(p)]
        x = x + lax.ppermute(x, axis_name, perm)
        d *= 2
    return x


def ring_allreduce(x, axis_name):
    """Bandwidth-optimal ring all-reduce: reduce-scatter + all-gather.

    2(P−1) steps of (n/P)-byte messages. ``x`` must be 1-D (use the packer).
    """
    p = lax.axis_size(axis_name)
    if p == 1:
        return x
    r = lax.axis_index(axis_name)
    n = x.shape[0]
    pad = (-n) % p
    if pad:
        x = jnp.concatenate([x, jnp.zeros((pad,), x.dtype)])
    chunks = x.reshape(p, -1)
    perm = [(i, (i + 1) % p) for i in range(p)]

    def rs_step(s, ch):
        send = jax.lax.dynamic_index_in_dim(ch, (r - s) % p, 0, keepdims=False)
        recv = lax.ppermute(send, axis_name, perm)
        return ch.at[(r - s - 1) % p].add(recv)

    chunks = lax.fori_loop(0, p - 1, rs_step, chunks)
    # rank r now holds the fully-reduced chunk (r+1) mod p

    def ag_step(s, ch):
        send = jax.lax.dynamic_index_in_dim(ch, (r + 1 - s) % p, 0, keepdims=False)
        recv = lax.ppermute(send, axis_name, perm)
        return ch.at[(r - s) % p].set(recv)

    chunks = lax.fori_loop(0, p - 1, ag_step, chunks)
    out = chunks.reshape(-1)
    return out[:n] if pad else out


def round_robin_allreduce(x, axis_name):
    """The Original-EASGD wire schedule: the master (rank 0) exchanges with
    workers ONE AT A TIME, in rank order — Θ(P) serialized messages.

    Kept as the paper-faithful *baseline* schedule (benchmarks only; this is
    intentionally the slow one). Semantics here: global sum, like the others,
    so correctness tests can compare directly.
    """
    p = lax.axis_size(axis_name)
    if p == 1:
        return x
    r = lax.axis_index(axis_name)
    acc = x
    # gather phase: worker i -> master, sequentially (i = 1..P-1)
    for i in range(1, p):
        recv = lax.ppermute(x, axis_name, [(i, 0)])
        acc = jnp.where(r == 0, acc + recv, acc)
    # broadcast phase: master -> worker i, sequentially
    out = acc
    for i in range(1, p):
        recv = lax.ppermute(acc, axis_name, [(0, i)])
        out = jnp.where(r == i, recv, out)
    return out


def hierarchical_allreduce(x, inner_axis, outer_axis, inner="psum",
                           outer="psum"):
    """Two-level reduction: fast domain first, slow domain second.

    This is the paper's §6.2 divide-and-conquer generalized: reduce within
    the pod over ICI (cheap), then across pods over DCI (expensive) — the
    cross-pod message count is 1/pod_size of a flat all-reduce.
    """
    algos = {
        "psum": psum_allreduce,
        "butterfly": butterfly_allreduce,
        "ring": ring_allreduce,
        "round_robin": round_robin_allreduce,
    }
    x = algos[inner](x, inner_axis)
    x = algos[outer](x, outer_axis)
    return x


ALGORITHMS = {
    "psum": psum_allreduce,
    "butterfly": butterfly_allreduce,
    "ring": ring_allreduce,
    "round_robin": round_robin_allreduce,
}


def choose_algorithm(n_bytes: float, p: int,
                     net: costmodel.Network = costmodel.TPU_ICI) -> str:
    """α–β-model-driven schedule choice (paper Table 2 reasoning):
    latency-bound small buffers → butterfly; bandwidth-bound → ring."""
    if p <= 1:
        return "psum"
    if costmodel.t_butterfly_allreduce(n_bytes, p, net) <= \
            costmodel.t_ring_allreduce(n_bytes, p, net):
        return "butterfly"
    return "ring"


def shard_map_allreduce(mesh, x, axis_name: str, algorithm: str = "auto"):
    """Run an explicit schedule over a 1-D buffer replicated on ``axis_name``
    and sharded on no other axis. Test/benchmark entry point."""
    if algorithm == "auto":
        algorithm = choose_algorithm(
            x.size * x.dtype.itemsize, mesh.shape[axis_name]
        )
    fn = ALGORITHMS[algorithm]
    other_axes = tuple(a for a in mesh.axis_names if a != axis_name)
    spec = P(axis_name)

    @partial(
        jax.shard_map,
        mesh=mesh,
        in_specs=(spec,),
        out_specs=spec,
        check_vma=False,
    )
    def run(xs):
        # xs: (1, n) slice per device along axis_name
        return fn(xs[0], axis_name)[None]

    stacked = jnp.broadcast_to(x, (mesh.shape[axis_name],) + x.shape)
    return run(stacked)
