"""Compatibility shim — the explicit collective schedules moved to
``repro.comm.schedules``, the single registry shared by the real Sync-EASGD
runtime, the DES simulators, and the benchmarks.

Import from ``repro.comm`` in new code; this module keeps the seed-era
names working. Resolution is lazy (PEP 562) so that
``repro.core`` ↔ ``repro.comm`` can import each other's submodules without
ordering constraints.
"""
from __future__ import annotations

_FORWARDED = (
    "SCHEDULES",
    "Schedule",
    "butterfly_allreduce",
    "hierarchical_allreduce",
    "psum_allreduce",
    "ring_allreduce",
    "round_robin_allreduce",
    "shard_map_allreduce",
    "tree_allreduce",
)


def __getattr__(name: str):
    from repro.comm import schedules

    if name in _FORWARDED:
        return getattr(schedules, name)
    if name == "choose_algorithm":
        return schedules.choose
    if name == "ALGORITHMS":
        # legacy name -> bare impl mapping (prefer Schedule.allreduce, which
        # handles flattening for flat-only schedules)
        return {n: s.impl for n, s in schedules.SCHEDULES.items()}
    raise AttributeError(f"module 'repro.core.collectives' has no "
                         f"attribute '{name}'")


def __dir__():
    return sorted(_FORWARDED + ("choose_algorithm", "ALGORITHMS"))
