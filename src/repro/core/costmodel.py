"""α–β communication model (paper Table 2) + TPU v5e roofline constants.

The paper models a message of n words as costing (α + n·β) seconds — α the
latency, β the reciprocal bandwidth. All schedule comparisons in the paper
(round-robin Θ(P) vs tree Θ(log P); per-layer vs packed) are instances of
this model; we reuse it for the discrete-event simulator, the collective-
algorithm chooser, and the weak-scaling projections.

Hardware constants:
 * the paper's 2017 interconnects (Table 2) — used when reproducing the
   paper's own numbers;
 * TPU v5e (the target platform) — used for the roofline analysis. Values
   fixed by the assignment: 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses
import math


@dataclasses.dataclass(frozen=True)
class Network:
    name: str
    alpha: float   # seconds per message
    beta: float    # seconds per byte


# Paper Table 2 (β given per 4-byte word there; stored per byte here).
MELLANOX_FDR = Network("Mellanox 56Gb/s FDR IB", 0.7e-6, 0.2e-9 / 4)
INTEL_QDR = Network("Intel 40Gb/s QDR IB", 1.2e-6, 0.3e-9 / 4)
INTEL_10GBE = Network("Intel 10GbE NE020", 7.2e-6, 0.9e-9 / 4)

# TPU v5e ICI: ~50 GB/s per link; α ≈ 1 µs per collective step (hop latency
# + launch). DCI (cross-pod, data-center network) modeled ~4x slower with
# higher latency — the motivation for EASGD's reduced cross-pod traffic.
TPU_ICI = Network("TPU v5e ICI", 1.0e-6, 1.0 / 50e9)
TPU_DCI = Network("TPU v5e cross-pod DCI", 10.0e-6, 1.0 / 12.5e9)

# the repro.ps runtime's default EMULATED wire (PSConfig.emulate_net):
# Ethernet-class latency with bandwidth scaled so the full-model message
# time vs per-minibatch compute time on the benchmark MLP matches the
# paper's AlexNet-over-Ethernet regime (ratio ≈ 1–3) — that asymmetry,
# not this box's memcpy, is where the schedule orderings live.
# Deadline-paced sleeps make it precise under load.
PS_WIRE = Network("emulated PS wire (Ethernet-class, model-scaled)",
                  50e-6, 1.0 / 9e6)


@dataclasses.dataclass(frozen=True)
class Chip:
    name: str
    peak_flops: float      # FLOP/s (bf16 for TPU)
    hbm_bandwidth: float   # bytes/s
    hbm_bytes: float       # capacity
    link_bandwidth: float  # bytes/s per ICI link


TPU_V5E = Chip(
    name="TPU v5e",
    peak_flops=197e12,
    hbm_bandwidth=819e9,
    hbm_bytes=16 * 1024**3,
    link_bandwidth=50e9,
)

# 2017 hardware, for reproducing the paper's own tables.
KNL_7250 = Chip("Intel KNL 7250", 6e12 / 2, 475e9, 384 * 1024**3, 56e9 / 8)
K80_HALF = Chip("NVIDIA K80 (half)", 4.37e12 / 2, 240e9, 12 * 1024**3, 16e9)


# ---------------------------------------------------------------------------
# collective schedule costs (n = message bytes, p = participants)
# ---------------------------------------------------------------------------

def t_msg(n: float, net: Network) -> float:
    """Point-to-point message cost: α + nβ."""
    return net.alpha + n * net.beta


def t_round_robin(n: float, p: int, net: Network) -> float:
    """Paper's Original-EASGD schedule: master exchanges with each worker in
    rank order — P sequential messages, Θ(P)."""
    return p * t_msg(n, net)


def t_round_robin_allreduce(n: float, p: int, net: Network) -> float:
    """Full round-robin exchange CYCLE (gather + broadcast, serialized):
    2·P messages of n bytes — the all-reduce-equivalent cost of the paper's
    Original-EASGD wire schedule (``t_round_robin`` is the one-way half)."""
    return 2 * p * t_msg(n, net)


def t_tree_allreduce(n: float, p: int, net: Network) -> float:
    """Tree reduce + broadcast: 2·⌈log2 P⌉ rounds of full-size messages."""
    if p <= 1:
        return 0.0
    return 2 * math.ceil(math.log2(p)) * t_msg(n, net)


def t_butterfly_allreduce(n: float, p: int, net: Network) -> float:
    """Recursive-doubling all-reduce: ⌈log2 P⌉ rounds of full-size messages."""
    if p <= 1:
        return 0.0
    return math.ceil(math.log2(p)) * t_msg(n, net)


def t_ring_allreduce(n: float, p: int, net: Network) -> float:
    """Bandwidth-optimal ring: 2(P−1) steps of n/P bytes."""
    if p <= 1:
        return 0.0
    return 2 * (p - 1) * t_msg(n / p, net)


def t_allreduce_best(n: float, p: int, net: Network) -> float:
    """What a tuned library (NCCL / XLA) would pick: min(tree, ring).

    Small n → latency-bound → tree/butterfly; large n → bandwidth-bound →
    ring. This switch is exactly why the paper's packed buffer matters: many
    small messages can never reach the ring's bandwidth regime.
    """
    return min(t_butterfly_allreduce(n, p, net), t_ring_allreduce(n, p, net))


def t_per_layer(layer_bytes: list[float], p: int, net: Network,
                schedule=t_allreduce_best) -> float:
    """Per-layer communication (paper Fig. 10 'unpacked'): one collective
    per tensor."""
    return sum(schedule(n, p, net) for n in layer_bytes)


def t_packed(layer_bytes: list[float], p: int, net: Network,
             schedule=t_allreduce_best) -> float:
    """Packed single-buffer communication (paper Fig. 10 'packed')."""
    return schedule(sum(layer_bytes), p, net)


# ---------------------------------------------------------------------------
# roofline terms (assignment formulas)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class RooflineTerms:
    compute_s: float
    memory_s: float
    collective_s: float

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        """Lower bound on step time if the three resources fully overlap."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def serial_s(self) -> float:
        """Upper bound: no overlap at all."""
        return self.compute_s + self.memory_s + self.collective_s


def roofline(hlo_flops: float, hlo_bytes: float, collective_bytes: float,
             chips: int, chip: Chip = TPU_V5E) -> RooflineTerms:
    """Three-term roofline per the assignment:

      compute    = HLO_FLOPs / (chips × peak)
      memory     = HLO_bytes / (chips × HBM bw)
      collective = collective_bytes / (chips × link bw)

    FLOPs/bytes arguments are WHOLE-PROGRAM totals (all chips); if you have
    per-chip numbers multiply by ``chips`` first.
    """
    return RooflineTerms(
        compute_s=hlo_flops / (chips * chip.peak_flops),
        memory_s=hlo_bytes / (chips * chip.hbm_bandwidth),
        collective_s=collective_bytes / (chips * chip.link_bandwidth),
    )


def model_flops_train(n_params_active: float, n_tokens: float) -> float:
    """MODEL_FLOPS = 6·N·D (fwd 2ND + bwd 4ND), N = active params."""
    return 6.0 * n_params_active * n_tokens


def model_flops_infer(n_params_active: float, n_tokens: float) -> float:
    """Forward-only: 2·N·D."""
    return 2.0 * n_params_active * n_tokens
