"""α–β communication model (paper Table 2) + TPU v5e roofline constants.

The paper models a message of n words as costing (α + n·β) seconds — α the
latency, β the reciprocal bandwidth. All schedule comparisons in the paper
(round-robin Θ(P) vs tree Θ(log P); per-layer vs packed) are instances of
this model; we reuse it for the discrete-event simulator, the collective-
algorithm chooser, and the weak-scaling projections.

Hardware constants:
 * the paper's 2017 interconnects (Table 2) — used when reproducing the
   paper's own numbers;
 * TPU v5e (the target platform) — used for the roofline analysis. Values
   fixed by the assignment: 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses
import math


@dataclasses.dataclass(frozen=True)
class Network:
    name: str
    alpha: float   # seconds per message
    beta: float    # seconds per byte


# Paper Table 2 (β given per 4-byte word there; stored per byte here).
MELLANOX_FDR = Network("Mellanox 56Gb/s FDR IB", 0.7e-6, 0.2e-9 / 4)
INTEL_QDR = Network("Intel 40Gb/s QDR IB", 1.2e-6, 0.3e-9 / 4)
INTEL_10GBE = Network("Intel 10GbE NE020", 7.2e-6, 0.9e-9 / 4)

# TPU v5e ICI: ~50 GB/s per link; α ≈ 1 µs per collective step (hop latency
# + launch). DCI (cross-pod, data-center network) modeled ~4x slower with
# higher latency — the motivation for EASGD's reduced cross-pod traffic.
TPU_ICI = Network("TPU v5e ICI", 1.0e-6, 1.0 / 50e9)
TPU_DCI = Network("TPU v5e cross-pod DCI", 10.0e-6, 1.0 / 12.5e9)

# the repro.ps runtime's default EMULATED wire (PSConfig.emulate_net):
# Ethernet-class latency with bandwidth scaled so the full-model message
# time vs per-minibatch compute time on the benchmark MLP matches the
# paper's AlexNet-over-Ethernet regime (ratio ≈ 1–3) — that asymmetry,
# not this box's memcpy, is where the schedule orderings live.
# Deadline-paced sleeps make it precise under load.
PS_WIRE = Network("emulated PS wire (Ethernet-class, model-scaled)",
                  50e-6, 1.0 / 9e6)


# ---------------------------------------------------------------------------
# heterogeneous fabrics: hosts × slots topologies and measured link profiles
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Topology:
    """A two-level fabric: ``hosts`` nodes of ``slots`` workers each, worker
    i living on host ``i // slots`` (block placement — worker ids are dense
    per host, which is what launch/cluster's --hosts rendezvous produces).
    Links within a host pay the ``intra`` α–β; links that cross hosts — or
    touch the master endpoint, which sits outside every host — pay
    ``cross``. The degenerate 1-host topology prices every link ``intra``
    and must reproduce today's flat costs bitwise (tests pin this)."""

    hosts: int
    slots: int
    intra: Network = PS_WIRE
    cross: Network = PS_WIRE

    @property
    def p(self) -> int:
        return self.hosts * self.slots

    def host_of(self, wid: int) -> int:
        """Host index of a worker; the master (negative wid) is its own
        pseudo-host so master links price as cross-host when hosts > 1."""
        return -1 if wid < 0 else wid // self.slots

    def link(self, i: int, j: int) -> Network:
        """The network class the (i, j) link rides."""
        if self.hosts <= 1:
            return self.intra
        return (self.intra if self.host_of(i) == self.host_of(j)
                else self.cross)

    @property
    def uniform(self) -> bool:
        """True when every link prices identically — the topology adds no
        information over a flat ``Network`` and cost paths must stay
        bitwise-equal to the flat formulas."""
        return self.hosts <= 1 or self.intra == self.cross

    def to_wire(self) -> dict:
        """JSON-safe form (WELCOME ships this to workers)."""
        return {"hosts": self.hosts, "slots": self.slots,
                "intra": [self.intra.name, self.intra.alpha,
                          self.intra.beta],
                "cross": [self.cross.name, self.cross.alpha,
                          self.cross.beta]}

    @staticmethod
    def from_wire(d: dict) -> "Topology":
        return Topology(hosts=int(d["hosts"]), slots=int(d["slots"]),
                        intra=Network(*d["intra"]),
                        cross=Network(*d["cross"]))


@dataclasses.dataclass(frozen=True)
class LinkProfile:
    """Per-link-class α–β as *measured* on a live mesh (``ps.calibrate``
    learns one from clock-probe RTTs plus a short pairwise burst), in the
    same two-level shape the chooser prices. ``source`` names where the
    numbers came from ('analytic', 'measured:thread', 'measured:tcp');
    ``detail`` carries the raw observations for the bench records."""

    topology: Topology
    source: str = "analytic"
    detail: dict = dataclasses.field(default_factory=dict)

    def to_wire(self) -> dict:
        return {"topology": self.topology.to_wire(), "source": self.source,
                "detail": dict(self.detail)}

    @staticmethod
    def from_wire(d: dict) -> "LinkProfile":
        return LinkProfile(topology=Topology.from_wire(d["topology"]),
                           source=str(d.get("source", "analytic")),
                           detail=dict(d.get("detail", {})))


def emulated_topology(hosts: int, slots: int, intra: Network = PS_WIRE,
                      cross_alpha_x: float = 20.0,
                      cross_beta_x: float = 4.0) -> Topology:
    """The canonical emulated two-level fabric: intra-host links are the
    PS wire; cross-host links stretch α by ``cross_alpha_x`` and β by
    ``cross_beta_x`` (defaults: 1 ms / ~2.25 MB/s — an oversubscribed
    Ethernet uplink against the paper's in-rack fabric). At these defaults
    hierarchical's single cross-host butterfly beats flat schedules from
    P = 16 up on 8-slot hosts, which is exactly the regime §6.2 claims."""
    if hosts < 1 or slots < 1:
        raise ValueError(f"topology needs hosts, slots >= 1, "
                         f"got {hosts}x{slots}")
    if cross_alpha_x == 1.0 and cross_beta_x == 1.0:
        cross = intra        # exactly uniform: link class carries no info
    else:
        cross = Network(
            f"{intra.name} [cross-host {cross_alpha_x:g}xA "
            f"{cross_beta_x:g}xB]",
            intra.alpha * cross_alpha_x, intra.beta * cross_beta_x)
    return Topology(hosts=hosts, slots=slots, intra=intra, cross=cross)


def t_hierarchical_two_level(n: float, topo: Topology) -> float:
    """Closed-form two-level hierarchical all-reduce cost on ``topo``:
    a bandwidth-optimal ring inside each host (slots participants, intra
    links) plus a recursive-doubling butterfly across hosts (full-size
    messages, cross links). The rounds-level pricing in comm.rounds is the
    authoritative number; this is the analytic cross-check."""
    inner = t_ring_allreduce(n, topo.slots, topo.intra)
    outer = t_butterfly_allreduce(n, topo.hosts, topo.cross)
    return inner + outer


@dataclasses.dataclass(frozen=True)
class Chip:
    name: str
    peak_flops: float      # FLOP/s (bf16 for TPU)
    hbm_bandwidth: float   # bytes/s
    hbm_bytes: float       # capacity
    link_bandwidth: float  # bytes/s per ICI link


TPU_V5E = Chip(
    name="TPU v5e",
    peak_flops=197e12,
    hbm_bandwidth=819e9,
    hbm_bytes=16 * 1024**3,
    link_bandwidth=50e9,
)

# 2017 hardware, for reproducing the paper's own tables.
KNL_7250 = Chip("Intel KNL 7250", 6e12 / 2, 475e9, 384 * 1024**3, 56e9 / 8)
K80_HALF = Chip("NVIDIA K80 (half)", 4.37e12 / 2, 240e9, 12 * 1024**3, 16e9)


# ---------------------------------------------------------------------------
# collective schedule costs (n = message bytes, p = participants)
# ---------------------------------------------------------------------------

def t_msg(n: float, net: Network) -> float:
    """Point-to-point message cost: α + nβ."""
    return net.alpha + n * net.beta


def t_round_robin(n: float, p: int, net: Network) -> float:
    """Paper's Original-EASGD schedule: master exchanges with each worker in
    rank order — P sequential messages, Θ(P)."""
    return p * t_msg(n, net)


def t_round_robin_allreduce(n: float, p: int, net: Network) -> float:
    """Full round-robin exchange CYCLE (gather + broadcast, serialized):
    2·P messages of n bytes — the all-reduce-equivalent cost of the paper's
    Original-EASGD wire schedule (``t_round_robin`` is the one-way half)."""
    return 2 * p * t_msg(n, net)


def t_tree_allreduce(n: float, p: int, net: Network) -> float:
    """Tree reduce + broadcast: 2·⌈log2 P⌉ rounds of full-size messages."""
    if p <= 1:
        return 0.0
    return 2 * math.ceil(math.log2(p)) * t_msg(n, net)


def t_butterfly_allreduce(n: float, p: int, net: Network) -> float:
    """Recursive-doubling all-reduce: ⌈log2 P⌉ rounds of full-size messages."""
    if p <= 1:
        return 0.0
    return math.ceil(math.log2(p)) * t_msg(n, net)


def t_ring_allreduce(n: float, p: int, net: Network) -> float:
    """Bandwidth-optimal ring: 2(P−1) steps of n/P bytes."""
    if p <= 1:
        return 0.0
    return 2 * (p - 1) * t_msg(n / p, net)


def t_allreduce_best(n: float, p: int, net: Network) -> float:
    """What a tuned library (NCCL / XLA) would pick: min(tree, ring).

    Small n → latency-bound → tree/butterfly; large n → bandwidth-bound →
    ring. This switch is exactly why the paper's packed buffer matters: many
    small messages can never reach the ring's bandwidth regime.
    """
    return min(t_butterfly_allreduce(n, p, net), t_ring_allreduce(n, p, net))


def t_per_layer(layer_bytes: list[float], p: int, net: Network,
                schedule=t_allreduce_best) -> float:
    """Per-layer communication (paper Fig. 10 'unpacked'): one collective
    per tensor."""
    return sum(schedule(n, p, net) for n in layer_bytes)


def t_packed(layer_bytes: list[float], p: int, net: Network,
             schedule=t_allreduce_best) -> float:
    """Packed single-buffer communication (paper Fig. 10 'packed')."""
    return schedule(sum(layer_bytes), p, net)


# ---------------------------------------------------------------------------
# roofline terms (assignment formulas)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class RooflineTerms:
    compute_s: float
    memory_s: float
    collective_s: float

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        """Lower bound on step time if the three resources fully overlap."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def serial_s(self) -> float:
        """Upper bound: no overlap at all."""
        return self.compute_s + self.memory_s + self.collective_s


def roofline(hlo_flops: float, hlo_bytes: float, collective_bytes: float,
             chips: int, chip: Chip = TPU_V5E) -> RooflineTerms:
    """Three-term roofline per the assignment:

      compute    = HLO_FLOPs / (chips × peak)
      memory     = HLO_bytes / (chips × HBM bw)
      collective = collective_bytes / (chips × link bw)

    FLOPs/bytes arguments are WHOLE-PROGRAM totals (all chips); if you have
    per-chip numbers multiply by ``chips`` first.
    """
    return RooflineTerms(
        compute_s=hlo_flops / (chips * chip.peak_flops),
        memory_s=hlo_bytes / (chips * chip.hbm_bandwidth),
        collective_s=collective_bytes / (chips * chip.link_bandwidth),
    )


def model_flops_train(n_params_active: float, n_tokens: float) -> float:
    """MODEL_FLOPS = 6·N·D (fwd 2ND + bwd 4ND), N = active params."""
    return 6.0 * n_params_active * n_tokens


def model_flops_infer(n_params_active: float, n_tokens: float) -> float:
    """Forward-only: 2·N·D."""
    return 2.0 * n_params_active * n_tokens
