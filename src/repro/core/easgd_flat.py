"""Flat-vector, IN-PLACE updates for the paper's nine algorithms — the ONE
implementation of the optimizer math shared by

 * the discrete-event simulator (``core.async_engine.PSEngine``), and
 * the real parameter-server runtime (``repro.ps``).

Both call these functions with the same float64 numpy buffers in the same
event order, so the DES↔real cross-check (tests/test_ps.py) can assert
bitwise-identical iterates: same event order ⇒ same weights.

All functions mutate their buffers in place. That is load-bearing twice
over: (a) the ``repro.ps`` shared-memory transports hand the SAME arrays to
every thread/process, so an in-place update IS the publication; (b) the
Hogwild variants run these without a lock — the torn, racy interleavings
are then real, not simulated.

The pytree functions in ``core.easgd`` are the mathematical oracle
(eqs. 1–6 of the paper); equivalence is pinned by tests.
"""
from __future__ import annotations

from typing import TYPE_CHECKING

import numpy as np

if TYPE_CHECKING:                 # annotation only — keeping this module
    from repro.core.easgd import EASGDConfig   # numpy-only lets the jax-free
#                                   repro.net TCP workers import it cheaply

# algorithm families (names match core.async_engine.ALGORITHMS)
EASGD_WORKER_RULE = ("original_easgd", "async_easgd", "hogwild_easgd",
                     "sync_easgd")
SYNC_FAMILY = ("sync_sgd", "sync_easgd")
ASYNC_FAMILY = ("async_sgd", "async_easgd", "async_msgd", "async_measgd")
HOGWILD_FAMILY = ("hogwild_sgd", "hogwild_easgd")


def uses_velocity(algorithm: str) -> bool:
    """Does the worker-side rule carry a velocity buffer V⁽ⁱ⁾?"""
    return algorithm in ("async_msgd", "async_measgd")


def worker_step(algorithm: str, w: np.ndarray, v: np.ndarray,
                grad: np.ndarray, center: np.ndarray,
                cfg: EASGDConfig) -> None:
    """Worker-side update, in place on (w, v).

    EASGD rule (eq 1):   W ← W − η(ΔW + ρ(W − W̄))
    MEASGD (eqs 5–6):    V ← μV − ηΔW;  W ← W + V − ηρ(W − W̄)
    MSGD (eqs 3–4):      V ← μV − ηΔW;  W ← W + V
    SGD:                 W ← W − ηΔW
    """
    eta, rho, mu = cfg.eta, cfg.rho, cfg.mu
    if algorithm in EASGD_WORKER_RULE:
        w -= eta * (grad + rho * (w - center))
    elif algorithm == "async_measgd":
        v[:] = mu * v - eta * grad
        w += v - cfg.alpha * (w - center)
    elif algorithm == "async_msgd":
        v[:] = mu * v - eta * grad
        w += v
    else:  # sgd family: worker tracks the master copy
        w -= eta * grad


def local_step(algorithm: str, w: np.ndarray, v: np.ndarray,
               grad: np.ndarray, cfg: EASGDConfig) -> None:
    """Between-exchange update for τ>1 communication periods, in place on
    (w, v): the worker's own rule WITHOUT any center/master interaction
    (the elastic attraction and the center pull happen only every τ-th
    step, at the exchange). Mirrors ``core.elastic._momentum_only``:

    velocity rules (MSGD/MEASGD):  V ← μV − ηΔW;  W ← W + V
    everything else:               W ← W − ηΔW
    """
    if uses_velocity(algorithm):
        v[:] = cfg.mu * v - cfg.eta * grad
        w += v
    else:
        w -= cfg.eta * grad


def master_absorb(algorithm: str, center: np.ndarray,
                  master_vel: np.ndarray, w_i: np.ndarray, v_i: np.ndarray,
                  grad: np.ndarray, cfg: EASGDConfig) -> None:
    """Process ONE worker arrival at the master (async / Hogwild families),
    in place on (center, master_vel, w_i, v_i).

    SGD:    W̄ ← W̄ − ηΔW;                     worker re-reads W̄
    MSGD:   V̄ ← μV̄ − ηΔW;  W̄ ← W̄ + V̄;      worker re-reads W̄
    elastic: worker rule (eq 1 / 5–6), then W̄ ← W̄ + ηρ(W⁽ⁱ⁾ − W̄)
             (paper Alg. 1 line 14 — one worker at a time).

    Under the FCFS lock this whole block is atomic; lock-free (Hogwild) it
    races for real.
    """
    if algorithm in ("async_sgd", "hogwild_sgd"):
        center -= cfg.eta * grad
        w_i[:] = center
    elif algorithm == "async_msgd":
        master_vel[:] = cfg.mu * master_vel - cfg.eta * grad
        center += master_vel
        w_i[:] = center
    else:  # async_easgd / async_measgd / hogwild_easgd
        worker_step(algorithm, w_i, v_i, grad, center, cfg)
        center += cfg.alpha * (w_i - center)


def master_absorb_round_robin(center: np.ndarray, w_j: np.ndarray,
                              v_j: np.ndarray, grad: np.ndarray,
                              cfg: EASGDConfig) -> None:
    """Original EASGD's serialized turn: worker rule + single-worker center
    pull, executed while worker j holds its round-robin turn."""
    worker_step("original_easgd", w_j, v_j, grad, center, cfg)
    center += cfg.alpha * (w_j - center)


def sync_master_easgd(center: np.ndarray, mean_w: np.ndarray, p: int,
                      cfg: EASGDConfig) -> None:
    """Eq 2 given the cross-worker mean of the PRE-update weights:
    W̄ ← W̄ + ηρP(mean − W̄)."""
    center += cfg.alpha * p * (mean_w - center)


def sync_master_sgd(center: np.ndarray, master_vel: np.ndarray,
                    gmean: np.ndarray, cfg: EASGDConfig) -> None:
    """Synchronous momentum SGD on the mean gradient:
    V̄ ← μV̄ − η·ḡ;  W̄ ← W̄ + V̄."""
    master_vel[:] = cfg.mu * master_vel - cfg.eta * gmean
    center += master_vel
