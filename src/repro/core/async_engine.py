"""Event-driven parameter-server engine: the paper's nine algorithms with
REAL convergence and MODELED wall-time.

Reproduces §5.1 (Figs 6, 8): Original (round-robin) EASGD, Async SGD/EASGD,
Async MSGD/MEASGD, Hogwild SGD/EASGD, Sync SGD/EASGD. The optimizer math
runs for real (numpy/jax on flat weights — accuracy curves are genuine);
time advances on a discrete-event clock with an α–β communication model and
per-worker compute times (this box has 1 CPU core, so parallel wall-clock
is simulated; the SCHEDULES — serialization, FCFS, lock-free interleaving,
tree reduction — are exact).

Asynchrony semantics: a worker's exchange uses the master state AT ITS
SIMULATED ARRIVAL TIME — staleness and lock-free interleaving emerge from
event order exactly as on real hardware (Hogwild's concurrent updates
linearize to interleaved single-word updates; with flat-vector granularity
this is the standard sequential-consistency model of Hogwild analyses).
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Callable, Optional

import numpy as np

from repro.comm import schedules as comm_schedules
from repro.core import costmodel, easgd_flat
from repro.core.easgd import EASGDConfig

ALGORITHMS = (
    "original_easgd",
    "async_sgd", "async_easgd",
    "async_msgd", "async_measgd",
    "hogwild_sgd", "hogwild_easgd",
    "sync_sgd", "sync_easgd",
)


@dataclasses.dataclass
class SimConfig:
    n_workers: int = 4
    # communication (defaults: PCIe-switch multi-GPU box, paper §10.4)
    net: costmodel.Network = costmodel.Network("PCIe3x16", 5e-6, 1 / 12e9)
    schedule: str = "tree"           # repro.comm schedule for the sync
    #                                  exchange (same registry the real
    #                                  runtime executes)
    t_compute: float = 1e-3          # fwd/bwd per minibatch, seconds
    compute_jitter: float = 0.10     # lognormal sigma (stragglers)
    t_update_per_byte: float = 1 / 100e9   # elementwise update bandwidth
    eval_every_s: float = 0.0        # 0: eval on schedule below
    eval_every_iters: int = 100
    seed: int = 0
    # two-level fabric (hosts × slots): when set and non-uniform, sync
    # exchanges are priced per link class (cost_topo) so the DES charges
    # the same heterogeneous wire the paced runtime sleeps on; None keeps
    # every charge bitwise-identical to the flat ``net`` model
    topology: Optional[costmodel.Topology] = None


@dataclasses.dataclass
class RunResult:
    algorithm: str
    history: list                    # [(sim_time_s, total_iters, metric)]
    total_time_s: float
    total_iters: int
    breakdown: dict                  # category -> seconds (Table 3 analogue)
    final_metric: float
    center: Optional[np.ndarray] = None    # final W̄ (DES↔real cross-check)
    workers: Optional[np.ndarray] = None   # final (P, n) worker weights


class PSEngine:
    """grad_fn(w_flat, step, worker) -> grad_flat;
    eval_fn(w_flat) -> scalar metric (e.g. test error)."""

    def __init__(self, grad_fn: Callable, eval_fn: Callable,
                 w0: np.ndarray, easgd: EASGDConfig, sim: SimConfig):
        self.grad_fn = grad_fn
        self.eval_fn = eval_fn
        self.w0 = np.asarray(w0, np.float64)
        self.cfg = easgd
        self.sim = sim
        self.nbytes = self.w0.nbytes

    # -- timing helpers -------------------------------------------------------
    def _t_compute(self, rng) -> float:
        j = self.sim.compute_jitter
        return self.sim.t_compute * float(rng.lognormal(0.0, j)) if j else \
            self.sim.t_compute

    def _t_msg(self) -> float:
        return costmodel.t_msg(self.nbytes, self.sim.net)

    def _t_update(self) -> float:
        return self.nbytes * self.sim.t_update_per_byte

    def t_exchange(self, schedule: str | None = None,
                   p: int | None = None) -> float:
        """α–β price of ONE full group exchange of the flat weights — taken
        from the SHARED ``repro.comm`` registry, so the simulator charges
        exactly what the registered schedule's real implementation moves."""
        sched = comm_schedules.get(schedule or self.sim.schedule)
        pp = p if p is not None else self.sim.n_workers
        topo = self.sim.topology
        if topo is not None and not topo.uniform:
            return sched.cost_topo(self.nbytes, pp, topo)
        return sched.cost(self.nbytes, pp, self.sim.net)

    # -- algorithms -----------------------------------------------------------
    def run(self, algorithm: str, total_iters: int,
            time_budget_s: Optional[float] = None) -> RunResult:
        assert algorithm in ALGORITHMS, algorithm
        rng = np.random.RandomState(self.sim.seed)
        cfg, sim = self.cfg, self.sim
        P = sim.n_workers
        center = self.w0.copy()
        workers = [self.w0.copy() for _ in range(P)]
        vel = [np.zeros_like(self.w0) for _ in range(P)]
        master_vel = np.zeros_like(self.w0)
        history = []
        breakdown = {"fwd_bwd": 0.0, "param_comm": 0.0, "worker_update": 0.0,
                     "master_update": 0.0, "idle": 0.0}
        iters = 0
        last_eval_iter = -1

        def evaluate(t):
            nonlocal last_eval_iter
            if iters - last_eval_iter >= sim.eval_every_iters:
                w_eval = center if "easgd" in algorithm else \
                    (center if algorithm.startswith(("async", "hogwild"))
                     else workers[0])
                history.append((t, iters, float(self.eval_fn(w_eval))))
                last_eval_iter = iters

        # the optimizer math itself lives in core.easgd_flat — the SAME
        # in-place functions the repro.ps real runtime executes, so identical
        # event order gives bitwise-identical iterates (DES↔real cross-check)

        # ---------------- Original EASGD: round-robin, one worker at a time --
        if algorithm == "original_easgd":
            t = 0.0
            while iters < total_iters and \
                    (time_budget_s is None or t < time_budget_s):
                j = iters % P
                tc = self._t_compute(rng)
                grad = self.grad_fn(workers[j], iters, j)
                # serialized: this iteration is 1/P of a full round-robin
                # cycle (registry-priced: 2·P messages per cycle → 2 here).
                # P=1 still pays its 2 master↔worker messages — the master
                # is a separate host even with one worker.
                t_rr = (self.t_exchange("round_robin") / P if P > 1
                        else 2 * self._t_msg())
                t += t_rr / 2               # master -> worker (W̄)
                t += tc
                t += t_rr / 2               # worker -> master (W_j)
                breakdown["param_comm"] += t_rr
                breakdown["fwd_bwd"] += tc
                easgd_flat.master_absorb_round_robin(center, workers[j],
                                                     vel[j], grad, cfg)
                t += 2 * self._t_update()
                breakdown["worker_update"] += self._t_update()
                breakdown["master_update"] += self._t_update()
                iters += 1
                evaluate(t)
            return RunResult(algorithm, history, t, iters, breakdown,
                             history[-1][2] if history else float("nan"),
                             center=center.copy(),
                             workers=np.array(workers))

        # ---------------- synchronous family ---------------------------------
        if algorithm in ("sync_sgd", "sync_easgd"):
            t = 0.0
            steps = 0
            while iters < total_iters and \
                    (time_budget_s is None or t < time_budget_s):
                tcs = [self._t_compute(rng) for _ in range(P)]
                grads = [self.grad_fn(workers[i], steps, i) for i in range(P)]
                t_compute = max(tcs)
                t_comm = self.t_exchange()
                if algorithm == "sync_easgd":
                    # paper §6.1.3: exchange uses start-of-step weights —
                    # overlaps with compute
                    t += max(t_compute, t_comm)
                    mean_w = np.mean(workers, axis=0)
                    for i in range(P):
                        easgd_flat.worker_step(algorithm, workers[i], vel[i],
                                               grads[i], center, cfg)
                    easgd_flat.sync_master_easgd(center, mean_w, P, cfg)
                else:
                    # sync SGD: gradient all-reduce cannot overlap
                    t += t_compute + t_comm
                    gmean = np.mean(grads, axis=0)
                    easgd_flat.sync_master_sgd(center, master_vel, gmean, cfg)
                    for i in range(P):
                        workers[i][:] = center
                breakdown["fwd_bwd"] += t_compute
                breakdown["param_comm"] += t_comm if algorithm == "sync_sgd" \
                    else max(0.0, t_comm - t_compute)
                t += 2 * self._t_update()
                breakdown["worker_update"] += self._t_update()
                breakdown["master_update"] += self._t_update()
                iters += P
                steps += 1
                evaluate(t)
            return RunResult(algorithm, history, t, iters, breakdown,
                             history[-1][2] if history else float("nan"),
                             center=center.copy(),
                             workers=np.array(workers))

        # ---------------- asynchronous family (FCFS / lock-free) -------------
        # event heap of (time, seq, worker, phase)
        heap = []
        for i in range(P):
            heapq.heappush(heap, (self._t_compute(rng), i, i, "arrive"))
        master_free_at = 0.0
        seq = P
        t = 0.0
        lock_free = algorithm.startswith("hogwild")
        while iters < total_iters and heap and \
                (time_budget_s is None or t < time_budget_s):
            t, _, i, phase = heapq.heappop(heap)
            # worker i arrives with its contribution
            service = 2 * self._t_msg() + self._t_update()
            if not lock_free and t < master_free_at:
                breakdown["idle"] += master_free_at - t
                t = master_free_at          # FCFS: wait for the lock
            grad = self.grad_fn(workers[i], iters, i)
            easgd_flat.master_absorb(algorithm, center, master_vel,
                                     workers[i], vel[i], grad, cfg)
            if not lock_free:
                master_free_at = t + service
            breakdown["param_comm"] += 2 * self._t_msg()
            breakdown["master_update"] += self._t_update()
            tc = self._t_compute(rng)
            breakdown["fwd_bwd"] += tc
            done_at = t + service + tc
            heapq.heappush(heap, (done_at, seq, i, "arrive"))
            seq += 1
            iters += 1
            evaluate(t)
        return RunResult(algorithm, history, t, iters, breakdown,
                         history[-1][2] if history else float("nan"),
                         center=center.copy(), workers=np.array(workers))
