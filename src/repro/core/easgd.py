"""Elastic Averaging SGD family — paper §3.3, §5.1 (eqs. 1, 2, 5, 6).

The rules (paper notation; η learning rate, ρ elastic strength, μ momentum):

  worker  (eq 1):  W⁽ⁱ⁾ ← W⁽ⁱ⁾ − η·(ΔW⁽ⁱ⁾ + ρ·(W⁽ⁱ⁾ − W̄))
  center  (eq 2):  W̄    ← W̄ + η·ρ·Σᵢ (W⁽ⁱ⁾ − W̄)
  MEASGD  (eq 5):  V⁽ⁱ⁾ ← μ·V⁽ⁱ⁾ − η·ΔW⁽ⁱ⁾
  MEASGD  (eq 6):  W⁽ⁱ⁾ ← W⁽ⁱ⁾ + V⁽ⁱ⁾ − η·ρ·(W⁽ⁱ⁾ − W̄)

All functions below are pure, operate on pytrees, and are shared by
 * the synchronous multi-pod runtime (``core.elastic`` — Sync EASGD),
 * the asynchronous engine (``core.async_engine`` — Original / Async /
   Hogwild EASGD and their SGD counterparts), and
 * the unit/property tests (the oracle is this module run on scalars).

Identities used as test invariants:
 * ρ = 0   → eq 1 degenerates to plain SGD, eq 5–6 to momentum SGD.
 * 1 worker, ρ>0 → worker and center contract toward each other; the
   average (W + W̄)/2 follows plain SGD up to O(ηρ)².
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from repro.utils.pytree import tree_map


@dataclasses.dataclass(frozen=True)
class EASGDConfig:
    """Hyper-parameters of the elastic-averaging family.

    tau: communication period — workers exchange with the center every
    ``tau`` local steps (paper uses τ=1; EASGD supports τ≥1, and τ is the
    natural cross-pod bandwidth lever at 1000+ nodes).
    """

    eta: float = 0.01          # learning rate η
    rho: float = 0.01          # elastic strength ρ  (β = η·ρ·P in EASGD paper)
    mu: float = 0.9            # momentum μ (MEASGD only)
    tau: int = 1               # communication period
    nesterov: bool = False

    @property
    def alpha(self) -> float:
        """Elastic step size α = η·ρ (the EASGD paper's notation)."""
        return self.eta * self.rho


# ---------------------------------------------------------------------------
# worker-side updates
# ---------------------------------------------------------------------------

def sgd_update(w, grad, cfg: EASGDConfig):
    """Plain SGD: W ← W − η·ΔW (the ρ=0 degenerate case of eq 1)."""
    return tree_map(lambda w_, g_: w_ - cfg.eta * g_.astype(w_.dtype), w, grad)


def msgd_update(w, v, grad, cfg: EASGDConfig):
    """Momentum SGD (eqs 3–4): V ← μV − ηΔW;  W ← W + V."""
    v_new = tree_map(
        lambda v_, g_: cfg.mu * v_ - cfg.eta * g_.astype(v_.dtype), v, grad
    )
    if cfg.nesterov:
        w_new = tree_map(
            lambda w_, v_, g_: w_ + cfg.mu * v_ - cfg.eta * g_.astype(w_.dtype),
            w, v_new, grad,
        )
    else:
        w_new = tree_map(lambda w_, v_: w_ + v_.astype(w_.dtype), w, v_new)
    return w_new, v_new


def easgd_worker_update(w, grad, center, cfg: EASGDConfig):
    """Eq 1: W ← W − η(ΔW + ρ(W − W̄))."""
    return tree_map(
        lambda w_, g_, c_: w_
        - cfg.eta * (g_.astype(w_.dtype) + cfg.rho * (w_ - c_.astype(w_.dtype))),
        w, grad, center,
    )


def measgd_worker_update(w, v, grad, center, cfg: EASGDConfig):
    """Eqs 5–6: V ← μV − ηΔW;  W ← W + V − ηρ(W − W̄)."""
    v_new = tree_map(
        lambda v_, g_: cfg.mu * v_ - cfg.eta * g_.astype(v_.dtype), v, grad
    )
    w_new = tree_map(
        lambda w_, v_, c_: w_
        + v_.astype(w_.dtype)
        - cfg.eta * cfg.rho * (w_ - c_.astype(w_.dtype)),
        w, v_new, center,
    )
    return w_new, v_new


# ---------------------------------------------------------------------------
# center-side updates
# ---------------------------------------------------------------------------

def center_update_from_sum(center, sum_w, n_workers: int, cfg: EASGDConfig):
    """Eq 2 given Σᵢ W⁽ⁱ⁾:  W̄ ← W̄ + ηρ (Σᵢ W⁽ⁱ⁾ − P·W̄)."""
    a = cfg.alpha
    return tree_map(
        lambda c_, s_: c_ + a * (s_.astype(c_.dtype) - n_workers * c_),
        center, sum_w,
    )


def center_update_from_mean(center, mean_w, n_workers: int, cfg: EASGDConfig):
    """Eq 2 given meanᵢ W⁽ⁱ⁾ (the form the packed cross-pod collective emits).

    W̄ ← W̄ + ηρP·(mean − W̄)  ≡  W̄ + ηρ Σᵢ(W⁽ⁱ⁾ − W̄).
    """
    a = cfg.alpha * n_workers
    return tree_map(
        lambda c_, m_: c_ + a * (m_.astype(c_.dtype) - c_), center, mean_w
    )


def center_update_single(center, w_i, cfg: EASGDConfig):
    """Round-robin / async form: one worker at a time (paper Alg. 1 line 14):
    W̄ ← W̄ + ηρ (W⁽ⁱ⁾ − W̄).
    """
    a = cfg.alpha
    return tree_map(
        lambda c_, w_: c_ + a * (w_.astype(c_.dtype) - c_), center, w_i
    )


# ---------------------------------------------------------------------------
# fused packed-buffer form (what the Pallas kernel implements)
# ---------------------------------------------------------------------------

def fused_elastic_step_flat(w_flat, v_flat, g_flat, c_flat, mean_w_flat,
                            n_workers: int, cfg: EASGDConfig):
    """One fused pass over the packed buffers: eqs 5–6 + eq 2.

    This is the pure-jnp oracle for ``kernels/elastic_update.py`` and the
    reference semantics of the packed Sync-EASGD step:

        V  ← μV − ηG
        W  ← W + V − ηρ(W − C)
        C  ← C + ηρP(mean_W − C)      # mean over workers of PRE-update W

    All buffers are 1-D and the same dtype (the packer guarantees this).
    """
    v_new = cfg.mu * v_flat - cfg.eta * g_flat
    w_new = w_flat + v_new - cfg.eta * cfg.rho * (w_flat - c_flat)
    c_new = c_flat + cfg.alpha * n_workers * (mean_w_flat - c_flat)
    return w_new, v_new, c_new
