"""Multi-pod Sync EASGD — the paper's technique as a first-class JAX module.

Mapping (DESIGN.md §2): each **pod** is one EASGD worker. Inside a pod the
gradient is reduced synchronously over the `data` axis (fast ICI — GSPMD does
this automatically from the batch sharding). Across pods, workers exchange
*weights, not gradients*, every ``tau`` steps through the elastic-averaging
rules (paper eqs. 1–2 / 5–6), using the paper's three co-design techniques:

 1. **Packed single-buffer exchange** (paper §5.2): inside ``shard_map`` each
    device flattens its *local shards* of every parameter into one contiguous
    buffer and issues a SINGLE cross-pod all-reduce. Packing in shard-space
    is a pure local reshape — no resharding traffic — while guaranteeing one
    collective (one α) instead of one per tensor.
 2. **Device-resident weights** (paper §6.1.2): all state lives in HBM; the
    step never round-trips the host.
 3. **Compute/communication overlap** (paper §6.1.3): the exchange reads only
    the *start-of-step* weights W_t — by construction it has no data
    dependency on the current forward/backward, so XLA's latency-hiding
    scheduler overlaps the cross-pod collective with compute.
    ``overlap=False`` inserts an optimization barrier to reproduce the
    non-overlapped baseline (Sync EASGD1/2).

Representation: every worker-local tensor carries a leading ``pod`` dimension
of size ``n_pods`` sharded on the mesh's ``pod`` axis (size 1 and unsharded
on a single-pod mesh — same code path). The center weight W̄ has no pod dim
(replicated across pods, sharded over data/model like the params).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.comm import plan as comm_plan
from repro.comm import schedules as comm_schedules
from repro.core import compression as compression_lib
from repro.core import costmodel
from repro.core.easgd import EASGDConfig
from repro.utils.jaxcompat import shard_map
from repro.utils.pytree import tree_map


@dataclasses.dataclass(frozen=True)
class ElasticConfig:
    easgd: EASGDConfig = EASGDConfig()
    mode: str = "sync_easgd"        # "sync_easgd" | "msgd" (plain DP baseline)
    packed: bool = True             # paper §5.2: single-buffer exchange
    schedule: str = "psum"          # repro.comm schedule for the packed
    #                                 cross-pod collective (paper §5.1/§6.1);
    #                                 "auto" picks via comm.choose from the
    #                                 packed wire bytes + pod count at build
    compression: str = "none"       # none | bf16 | sign_ef (cross-pod only)
    overlap: bool = True            # paper §6.1.3 (Sync EASGD3)
    momentum_dtype: Any = jnp.float32
    center_dtype: Any = jnp.float32

    def __post_init__(self):
        assert self.mode in ("sync_easgd", "msgd"), self.mode
        if self.schedule != "auto":
            comm_schedules.get(self.schedule)   # validate
        compression_lib.get(self.compression)   # validate

    def resolve_schedule(self, n_total: int,
                         n_elements: int | None = None) -> str:
        """Resolve "auto" to a concrete registry name via ``comm.choose``
        on the POST-compression wire bytes over the cross-pod (DCI) link —
        the JIT accounting (sign_ef travels as int8 in the compiled
        collective), so the choice and the HLO report agree on bytes.
        Without a buffer size, fall back to psum (XLA-native)."""
        if self.schedule != "auto":
            return self.schedule
        if n_elements is None or n_total <= 1:
            return "psum"
        comp = compression_lib.get(self.compression)
        wire = n_elements * comp.jit_wire_bytes_per_element
        return comm_schedules.choose(wire, n_total, costmodel.TPU_DCI)

    def exchange_plan(self, axis_name: str | None, n_total: int,
                      n_elements: int | None = None
                      ) -> comm_plan.ExchangePlan:
        """The fully-composed cross-pod exchange this config describes.
        ``n_elements`` (packed fp32 buffer size) feeds the "auto" schedule
        choice; ignored for a concrete schedule name."""
        return comm_plan.make_plan(
            schedule=self.resolve_schedule(n_total, n_elements),
            compression=self.compression,
            overlap=self.overlap, axis_name=axis_name, n_total=n_total)


class ElasticState(NamedTuple):
    step: jnp.ndarray       # () int32
    params: Any             # pytree, leaves (n_pods, …) — local W⁽ⁱ⁾
    momentum: Any           # pytree, leaves (n_pods, …) — V⁽ⁱ⁾
    center: Any             # pytree, leaves (…) — W̄ (None for msgd)
    ef_error: Any           # pytree like params (compression only) or None


def n_pods_of(state: ElasticState) -> int:
    leaf = jax.tree_util.tree_leaves(state.params)[0]
    return leaf.shape[0]


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init(params, cfg: ElasticConfig, n_pods: int = 1) -> ElasticState:
    """Broadcast a single parameter pytree into per-pod local weights
    (paper Alg. 4 lines 4–7: broadcast W, create local + global copies)."""
    pod = lambda x: jnp.broadcast_to(x[None], (n_pods,) + x.shape)
    params_pod = tree_map(pod, params)
    momentum = tree_map(
        lambda x: jnp.zeros((n_pods,) + x.shape, cfg.momentum_dtype), params
    )
    if cfg.mode == "msgd":
        center = None
    else:
        center = tree_map(lambda x: x.astype(cfg.center_dtype), params)
    if cfg.compression != "none" and cfg.mode != "msgd":
        ef = tree_map(
            lambda x: jnp.zeros((n_pods,) + x.shape, jnp.float32), params
        )
    else:
        ef = None
    return ElasticState(jnp.zeros((), jnp.int32), params_pod, momentum,
                        center, ef)


def init_abstract(params_abs, cfg: ElasticConfig, n_pods: int = 1):
    """ShapeDtypeStruct version of ``init`` (for the dry-run / AOT path)."""
    return jax.eval_shape(lambda p: init(p, cfg, n_pods), params_abs)


# ---------------------------------------------------------------------------
# state sharding specs
# ---------------------------------------------------------------------------

def state_specs(param_specs, cfg: ElasticConfig, pod_axis: str | None):
    """PartitionSpecs for an ElasticState given per-param specs (no pod dim).

    Local (per-pod) tensors get a leading pod-axis entry; the center is
    replicated across pods (no pod dim in its shape).
    """
    def podded(spec: P) -> P:
        return P(pod_axis, *spec)

    params = tree_map(podded, param_specs)
    center = None if cfg.mode == "msgd" else param_specs
    ef = params if (cfg.compression != "none" and cfg.mode != "msgd") else None
    return ElasticState(P(), params, params, center, ef)


# ---------------------------------------------------------------------------
# flat (packed) math — shared with kernels/ref and tests
# ---------------------------------------------------------------------------

def _pack_local(tree, pods: int | None = None):
    """Flatten a pytree of local shards into one contiguous fp32 buffer.

    Inside shard_map this is a per-device reshape+concat: zero communication.
    This IS the paper's 'single-layer layout' (§5.2) adapted to shard-space.
    With ``pods`` set, the leading pod dim stays OUTER: result (pods, n).
    """
    leaves = jax.tree_util.tree_leaves(tree)
    if pods is None:
        return jnp.concatenate(
            [l.reshape(-1).astype(jnp.float32) for l in leaves]
        )
    return jnp.concatenate(
        [l.reshape(pods, -1).astype(jnp.float32) for l in leaves], axis=1
    )


def _unpack_local(buf, template, pods: int | None = None):
    leaves, treedef = jax.tree_util.tree_flatten(template)
    out, off = [], 0
    for l in leaves:
        if pods is None:
            size = l.size
            chunk = lax.slice_in_dim(buf, off, off + size)
        else:
            size = l.size // pods
            chunk = lax.slice_in_dim(buf, off, off + size, axis=1)
        out.append(chunk.reshape(l.shape).astype(l.dtype))
        off += size
    return jax.tree_util.tree_unflatten(treedef, out)


# ---------------------------------------------------------------------------
# the update — one optimizer step given per-pod gradients
# ---------------------------------------------------------------------------

def _momentum_only(state: ElasticState, grads, cfg: ElasticConfig):
    """Between exchanges (step % τ ≠ 0) and for mode='msgd': eqs 3–4."""
    e = cfg.easgd
    v_new = tree_map(
        lambda v, g: (e.mu * v.astype(jnp.float32)
                      - e.eta * g.astype(jnp.float32)).astype(v.dtype),
        state.momentum, grads,
    )
    p_new = tree_map(
        lambda w, v: (w.astype(jnp.float32) + v.astype(jnp.float32)
                      ).astype(w.dtype),
        state.params, v_new,
    )
    return state._replace(step=state.step + 1, params=p_new, momentum=v_new)


def _elastic_tensors(state, grads, cfg, mean_w):
    """Per-tensor eqs 5–6 + eq 2 given the cross-pod mean of W_t."""
    e = cfg.easgd
    n_pods = n_pods_of(state)
    v_new = tree_map(
        lambda v, g: (e.mu * v.astype(jnp.float32)
                      - e.eta * g.astype(jnp.float32)).astype(v.dtype),
        state.momentum, grads,
    )
    p_new = tree_map(
        lambda w, v, c: (
            w.astype(jnp.float32) + v.astype(jnp.float32)
            - e.eta * e.rho * (w.astype(jnp.float32)
                               - c.astype(jnp.float32)[None])
        ).astype(w.dtype),
        state.params, v_new, state.center,
    )
    a = e.alpha * n_pods
    c_new = tree_map(
        lambda c, m: (c.astype(jnp.float32)
                      + a * (m.astype(jnp.float32) - c.astype(jnp.float32))
                      ).astype(c.dtype),
        state.center, mean_w,
    )
    return state._replace(step=state.step + 1, params=p_new, momentum=v_new,
                          center=c_new)


def _exchange_unpacked(state, grads, cfg):
    """Per-tensor cross-pod mean: one collective per parameter (the paper's
    'multiple rounds of communication for different layers' baseline).
    GSPMD may still combine small all-reduces; the packed path below makes
    the single message structural."""
    mean_w = tree_map(lambda w: jnp.mean(w.astype(jnp.float32), axis=0),
                      state.params)
    return _elastic_tensors(state, grads, cfg, mean_w)


def _exchange_packed(state, grads, cfg, mesh, param_specs, pod_axis,
                     plan=None):
    """Packed single-buffer exchange inside shard_map (paper §5.2 + §6.1).

    Every device: (a) locally flattens its shards of W_t into one buffer,
    (b) optionally compresses the delta vs W̄, (c) ONE collective over the
    pod axis using the plan's registered schedule (repro.comm — tree, ring,
    …), (d) fused elementwise update of W, V, W̄ (eqs 5–6, 2).
    """
    e = cfg.easgd
    n_pods = n_pods_of(state)
    pod_in_mesh = pod_axis is not None and pod_axis in mesh.axis_names
    if plan is None:
        n_elems = sum(l.size for l in
                      jax.tree_util.tree_leaves(state.params)) // n_pods
        plan = cfg.exchange_plan(
            axis_name=pod_axis if (n_pods > 1 and pod_in_mesh) else None,
            n_total=n_pods, n_elements=n_elems)

    specs = state_specs(param_specs, cfg,
                        pod_axis if (n_pods > 1 and pod_in_mesh) else None)
    grads_spec = specs.params
    out_specs = ElasticState(
        step=P(), params=specs.params, momentum=specs.momentum,
        center=specs.center, ef_error=specs.ef_error,
    )

    def body(step, params, momentum, center, ef, g):
        # local shards; pod-dim is size n_pods/|pod axis| locally (=1 on the
        # production mesh). The pod dim stays outer in the packed buffers.
        local_pods = jax.tree_util.tree_leaves(params)[0].shape[0]
        w2 = _pack_local(params, local_pods)      # (local_pods, n_local)
        g2 = _pack_local(g, local_pods)
        v2 = _pack_local(momentum, local_pods)
        c2 = _pack_local(center)[None]            # (1, n_local)

        # --- the ONE cross-pod collective (plan = schedule × compression) --
        delta = (w2 - c2)
        if cfg.compression != "none":
            ef_flat = _pack_local(ef, local_pods)
            mean_delta, ef_new2 = plan.reduce_mean_flat(delta, ef_flat)
            ef_new = _unpack_local(ef_new2, ef, local_pods)
        else:
            mean_delta, _ = plan.reduce_mean_flat(delta)
            ef_new = ef
        mean_w = c2[0] + mean_delta

        # --- fused elementwise update (eqs 5–6 + 2) ------------------------
        v_new = e.mu * v2 - e.eta * g2
        w_new = w2 + v_new - e.eta * e.rho * (w2 - c2)
        c_new = c2[0] + e.alpha * n_pods * (mean_w - c2[0])

        return (
            step + 1,
            _unpack_local(w_new, params, local_pods),
            _unpack_local(v_new, momentum, local_pods),
            _unpack_local(c_new, center),
            ef_new,
        )

    in_specs = (P(), specs.params, specs.momentum, specs.center,
                specs.ef_error if cfg.compression != "none" else P(),
                grads_spec)
    shmapped = shard_map(
        body, mesh=mesh, in_specs=in_specs,
        out_specs=(P(), out_specs.params, out_specs.momentum,
                   out_specs.center,
                   out_specs.ef_error if cfg.compression != "none" else P()),
        check_vma=False,
    )
    ef_in = state.ef_error if cfg.compression != "none" else jnp.zeros((), jnp.float32)
    step, p_new, v_new, c_new, ef_new = shmapped(
        state.step, state.params, state.momentum, state.center, ef_in, grads
    )
    if cfg.compression == "none":
        ef_new = state.ef_error
    return ElasticState(step, p_new, v_new, c_new, ef_new)


def apply_gradients(state: ElasticState, grads, cfg: ElasticConfig,
                    mesh=None, param_specs=None,
                    pod_axis: str | None = "pod",
                    plan=None) -> ElasticState:
    """One optimizer step. ``grads`` is a pytree like ``state.params``
    (leading pod dim), already mean-reduced over the intra-pod data axis
    (GSPMD does that from the batch sharding). ``plan`` (an
    ``repro.comm.ExchangePlan``) overrides the exchange composition derived
    from ``cfg`` — the runtime builds it once per train-step.
    """
    if cfg.mode == "msgd":
        # plain synchronous momentum SGD: grads are averaged over pods too,
        # so all pods stay identical (pure DP baseline).
        n_pods = n_pods_of(state)
        if n_pods > 1:
            gmean = tree_map(
                lambda g: jnp.broadcast_to(
                    jnp.mean(g.astype(jnp.float32), axis=0, keepdims=True),
                    g.shape).astype(g.dtype),
                grads,
            )
        else:
            gmean = grads
        return _momentum_only(state, gmean, cfg)

    if not cfg.overlap:
        # Sync EASGD1/2 baseline: force the exchange to wait for the
        # gradients (kills the paper's §6.1.3 overlap).
        state_params, grads = lax.optimization_barrier((state.params, grads))
        state = state._replace(params=state_params)

    def do_exchange(st, g):
        if cfg.packed and mesh is not None and param_specs is not None:
            return _exchange_packed(st, g, cfg, mesh, param_specs, pod_axis,
                                    plan=plan)
        return _exchange_unpacked(st, g, cfg)

    tau = cfg.easgd.tau
    if tau <= 1:
        return do_exchange(state, grads)
    return lax.cond(
        state.step % tau == 0,
        lambda s, g: do_exchange(s, g),
        lambda s, g: _momentum_only(s, g, cfg),
        state, grads,
    )
