"""Discrete-event schedule models for the paper's system experiments.

Pure timing (no training): given hardware constants (α–β links, compute
rates) and a schedule (round-robin / tree / placement / overlap), produce
per-part time breakdowns. Drives:
  * Table 3 / Fig 11 — EASGD variant breakdown + 5.3× claim,
  * Fig 10 — packed vs per-layer communication,
  * Fig 12 — chip partitioning (pods) sweep,
  * Table 4 — weak scaling to thousands of cores,
and the TPU-fleet projections in EXPERIMENTS.md.
"""
from __future__ import annotations

import dataclasses
import math

from repro.comm import schedules as comm_schedules
from repro.core import costmodel


@dataclasses.dataclass(frozen=True)
class GpuBox:
    """The paper's 4-GPU node (§10.4), CALIBRATED to Table 3's measured
    part-times (the paper's contribution is the SCHEDULE; the link/compute
    constants are theirs):
      * t_fwd_bwd = 6 ms/iter (Table 3: 6 s / 1000 iters, and Original
        EASGD*'s 30 s / 5000),
      * unpinned per-iteration CPU↔GPU exchange ≈ 3.47 ms/message (Original
        EASGD: 86% of 8.2 ms/iter over 2 messages),
      * pinned/batched tree rounds ≈ 0.57 ms (Sync EASGD1: 21% of 11 ms),
      * GPU↔GPU switch rounds ≈ 0.33 ms (Sync EASGD2: 16% of 8.2 ms).
    """
    n_gpus: int = 4
    # Original EASGD's per-iteration master↔worker path (driver-synced)
    pcie_unpinned: costmodel.Network = costmodel.Network(
        "PCIe h2d unpinned", 3.3e-3, 1 / 10e9)
    # Sync EASGD1: CPU-rooted tree, pinned transfers
    pcie_h2d: costmodel.Network = costmodel.Network("PCIe h2d", 0.4e-3,
                                                    1 / 10e9)
    # Sync EASGD2/3: GPU-GPU over the 96-lane PCIe switch
    pcie_p2p: costmodel.Network = costmodel.Network("PCIe p2p", 0.2e-3,
                                                    1 / 24e9)
    t_fwd_bwd: float = 6e-3          # per iteration (Table 3)
    t_gpu_update: float = 0.4e-3
    t_cpu_update: float = 0.7e-3
    weight_bytes: float = 1.7e6      # LeNet
    data_bytes: float = 64 * 28 * 28 * 4.0


GPU_BOX = GpuBox()


@dataclasses.dataclass
class Breakdown:
    iters: int
    parts: dict                      # name -> seconds

    @property
    def total_s(self) -> float:
        return sum(self.parts.values())

    @property
    def comm_ratio(self) -> float:
        comm = sum(v for k, v in self.parts.items() if "comm" in k)
        return comm / max(self.total_s, 1e-12)


def breakdown_original_easgd(box: GpuBox, iters: int,
                             overlap: bool = True) -> Breakdown:
    """Alg. 1: round-robin; ONE worker computes per iteration; master↔worker
    weight exchange is serialized. ``overlap=True`` is the paper's Original
    EASGD row (comm hides the compute: fwd/bwd shows 3%); ``False`` is
    Original EASGD* (69 s: 52% comm, 44% fwd/bwd)."""
    W, net = box.weight_bytes, box.pcie_unpinned
    per_iter_comm = 2 * costmodel.t_msg(W, net)          # W̄ down, W_j up
    per_iter_fb = box.t_fwd_bwd                          # one GPU working
    t_data = costmodel.t_msg(box.data_bytes, box.pcie_h2d)
    if overlap:
        fb_visible = max(per_iter_fb - per_iter_comm, 0.0)
    else:
        fb_visible = per_iter_fb
    parts = {
        "cpu_gpu_data_comm": iters * t_data,
        "cpu_gpu_para_comm": iters * per_iter_comm,
        "fwd_bwd": iters * fb_visible,
        "gpu_update": iters * box.t_gpu_update,
        "cpu_update": iters * box.t_cpu_update,
    }
    return Breakdown(iters, parts)


def breakdown_sync_easgd(box: GpuBox, iters: int, *, weights_on: str,
                         overlap: bool,
                         schedule: str = "tree") -> Breakdown:
    """Sync EASGD1 (weights on CPU), 2 (weights on GPU), 3 (+overlap).
    All GPUs compute every iteration; the exchange is priced through the
    shared ``repro.comm`` registry (default: the paper's tree reduction) —
    pass any registered ``schedule`` to sweep alternatives."""
    G = box.n_gpus
    W = box.weight_bytes
    net = box.pcie_h2d if weights_on == "cpu" else box.pcie_p2p
    t_comm = comm_schedules.get(schedule).cost(W, G, net)
    t_data = costmodel.t_msg(box.data_bytes, box.pcie_h2d)
    t_fb = box.t_fwd_bwd
    key = "cpu_gpu_para_comm" if weights_on == "cpu" else "gpu_gpu_para_comm"
    if overlap:
        # §6.1.3: the exchange reads start-of-step weights and overlaps
        # with fwd/bwd — but only PARTIALLY on the shared PCIe switch
        # (paper Table 3: sync3 still shows 10% gpu-gpu comm): ~45% of the
        # exchange stays visible.
        visible_comm = max(t_comm * 0.45, t_comm - t_fb)
        fb = t_fb
    else:
        visible_comm = t_comm
        fb = t_fb
    parts = {
        "cpu_gpu_data_comm": iters * t_data,
        key: iters * visible_comm,
        "fwd_bwd": iters * fb,
        "gpu_update": iters * box.t_gpu_update,
        "cpu_update": iters * (box.t_cpu_update if weights_on == "cpu"
                               else box.t_gpu_update),
    }
    return Breakdown(iters, parts)


# ---------------------------------------------------------------------------
# Fig 12: chip partitioning (divide-and-conquer pods)
# ---------------------------------------------------------------------------

def partition_sweep_time(n_parts: int, *, t_compute_1: float,
                         weight_bytes: float, fast_mem_bytes: float,
                         data_bytes: float,
                         net: costmodel.Network,
                         saturation: float = 6.0,
                         floor: float = 0.30,
                         schedule: str = "tree") -> float:
    """Time-to-accuracy with the chip split into ``n_parts`` NUMA groups
    (paper §6.2 / Fig 12). The gain combines NUMA locality + faster
    gradient propagation and SATURATES (the chip's FLOPs don't multiply):
    modeled as t(P) = t1·(floor + (1−floor)·e^{−(P−1)/saturation}),
    calibrated to the paper's 1/4/8/16-part points, PLUS the capacity
    cliff: when n_parts copies of (weights+data) no longer fit MCDRAM,
    compute drops to DDR4 speed (the paper's 3× bandwidth ratio) — this
    reproduces the observed ≤16-part limit."""
    fits = n_parts * (weight_bytes + data_bytes) <= fast_mem_bytes
    speed = 1.0 if fits else 3.0
    decay = math.exp(-(n_parts - 1) / saturation)
    t_compute = speed * t_compute_1 * (floor + (1 - floor) * decay)
    t_comm = comm_schedules.get(schedule).cost(weight_bytes, n_parts, net)
    return t_compute + t_comm


# ---------------------------------------------------------------------------
# Table 4: weak scaling
# ---------------------------------------------------------------------------

def weak_scaling_efficiency(n_nodes: int, *, t_compute: float,
                            weight_bytes: float,
                            net: costmodel.Network,
                            jitter_sigma: float = 0.0,
                            overlap: bool = True,
                            schedule: str = "psum",
                            topology: costmodel.Topology | None = None
                            ) -> float:
    """Weak scaling: per-node work constant; per-step time = slowest node
    (synchronous) + packed all-reduce. With lognormal per-node jitter σ the
    expected max over N nodes grows ≈ σ·√(2 ln N) — at cluster scale the
    STRAGGLER term, not bandwidth, limits weak scaling (the α–β comm term
    is <1% here). ``jitter_sigma`` is calibrated from a measured 2-node
    efficiency and then PREDICTS the rest of the curve. ``schedule`` is a
    ``repro.comm`` registry name (default ``psum``: what a tuned library
    picks — min of butterfly/ring). With a non-uniform ``topology`` the
    exchange is priced per link class (``cost_topo``) — the analytic half
    of the Table-4 curve then shares its fabric with the measured one."""
    if topology is not None and not topology.uniform:
        t_comm = comm_schedules.get(schedule).cost_topo(
            weight_bytes, n_nodes, topology)
    else:
        t_comm = comm_schedules.get(schedule).cost(weight_bytes, n_nodes, net)
    straggle = jitter_sigma * math.sqrt(2 * math.log(n_nodes)) \
        if n_nodes > 1 else 0.0
    tn = t_compute * (1 + straggle) + t_comm * (0.0 if overlap else 1.0)
    if overlap:
        tn = max(tn, t_comm)
    return t_compute / tn


def jitter_from_two_node_eff(eff2: float) -> float:
    """Invert the straggler model at N=2: eff(2)=1/(1+σ√(2 ln 2))."""
    return (1.0 / eff2 - 1.0) / math.sqrt(2 * math.log(2))
