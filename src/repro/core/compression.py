"""Gradient/weight compression for the cross-pod exchange (beyond-paper).

The paper cites 1-bit SGD [Seide et al., 22] as future work. At 1000+ nodes
the cross-pod elastic exchange is the scaling bottleneck, so we implement:

 * ``bf16``    — cast the packed buffer to bfloat16 (2× fewer bytes), with
   error feedback so quantization error is carried to the next round.
 * ``sign_ef`` — 1-bit sign compression with error feedback (à la 1-bit
   SGD / signSGD-EF). Signs travel as int8 (±1); the per-pod scale travels
   separately. Reduction of int8 signs is exact for ≤127 pods; the mean of
   per-pod scales approximates the per-pod magnitudes — error feedback
   absorbs the approximation (this is the standard 1-bit-Adam trick).

Compression operates on the *packed* 1-D buffer (core.packing), i.e. it
composes with the paper's single-message exchange: one small collective
instead of one large one.

All functions are pure; error-feedback state is a buffer of the same shape
as the payload, carried in the training state (per pod).
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Compression:
    """A compression scheme for a mean-over-pods of a flat buffer.

    ``encode(buf, err) -> (payload_tree, new_err)`` — payload_tree is what
    travels over the wire (pytree of arrays; bytes counted for the roofline).
    ``decode_mean(payload_mean_tree) -> buf`` — applied after the arithmetic
    mean over pods of each payload leaf.
    """

    name: str
    encode: Callable
    decode_mean: Callable
    wire_bytes_per_element: float  # for the cost model


def _identity_encode(buf, err):
    return (buf,), err


def _identity_decode(payload):
    return payload[0]


NONE = Compression("none", _identity_encode, _identity_decode, 4.0)


def _bf16_encode(buf, err):
    corrected = buf + err
    q = corrected.astype(jnp.bfloat16)
    new_err = corrected - q.astype(buf.dtype)
    return (q,), new_err


def _bf16_decode(payload):
    return payload[0].astype(jnp.float32)


BF16 = Compression("bf16", _bf16_encode, _bf16_decode, 2.0)


def _sign_encode(buf, err):
    corrected = buf + err
    scale = jnp.mean(jnp.abs(corrected))
    signs = jnp.where(corrected >= 0, jnp.int8(1), jnp.int8(-1))
    decompressed = signs.astype(buf.dtype) * scale
    new_err = corrected - decompressed
    return (signs, scale), new_err


def _sign_decode(payload):
    signs_mean, scale_mean = payload
    # signs_mean is mean over pods of ±1 (fp after mean); scale_mean is the
    # mean per-pod magnitude. Product approximates mean of sign_i*scale_i.
    return signs_mean.astype(jnp.float32) * scale_mean.astype(jnp.float32)


SIGN_EF = Compression("sign_ef", _sign_encode, _sign_decode, 0.125 + 1e-9)


SCHEMES = {c.name: c for c in (NONE, BF16, SIGN_EF)}


def get(name: str) -> Compression:
    try:
        return SCHEMES[name]
    except KeyError:
        raise ValueError(
            f"unknown compression '{name}', have {sorted(SCHEMES)}"
        ) from None
