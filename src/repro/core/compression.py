"""Gradient/weight compression for the cross-pod exchange (beyond-paper).

The paper cites 1-bit SGD [Seide et al., 22] as future work. At 1000+ nodes
the cross-pod elastic exchange is the scaling bottleneck, so we implement:

 * ``bf16``    — cast the packed buffer to bfloat16 (2× fewer bytes), with
   error feedback so quantization error is carried to the next round.
 * ``sign_ef`` — 1-bit sign compression with error feedback (à la 1-bit
   SGD / signSGD-EF). Signs travel as int8 (±1); the per-pod scale travels
   separately. Reduction of int8 signs is exact for ≤127 pods; the mean of
   per-pod scales approximates the per-pod magnitudes — error feedback
   absorbs the approximation (this is the standard 1-bit-Adam trick).

Compression operates on the *packed* 1-D buffer (core.packing), i.e. it
composes with the paper's single-message exchange: one small collective
instead of one large one.

Two wire realizations, two byte accountings:

 * the **jitted collective path** (``core.elastic`` / ``ExchangePlan``)
   must keep signs addressable for the sum-reduction, so they cross the
   mesh as int8 — ``jit_wire_bytes_per_element`` (sign_ef: 1.0) is what
   the compiled HLO actually moves, and is what ``comm.choose`` and the
   dry-run report price (launch/hloparse verifies the agreement);
 * the **framed byte-stream path** (``repro.net`` TCP wire) has no
   reduction in flight, so signs are bit-packed for real
   (``np.packbits``) — ``wire_bytes_per_element`` (sign_ef: 0.125) is the
   1-bit ideal that wire achieves.

All functions are pure; error-feedback state is a buffer of the same shape
as the payload, carried in the training state (per pod) or per link
(``repro.net.wire``). jax is imported lazily so the numpy codecs below are
usable from processes that must stay jax-free (TCP workers).
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import numpy as np


@dataclasses.dataclass(frozen=True)
class Compression:
    """A compression scheme for a mean-over-pods of a flat buffer.

    ``encode(buf, err) -> (payload_tree, new_err)`` — payload_tree is what
    travels over the wire (pytree of arrays; bytes counted for the roofline).
    ``decode_mean(payload_mean_tree) -> buf`` — applied after the arithmetic
    mean over pods of each payload leaf.
    """

    name: str
    encode: Callable
    decode_mean: Callable
    wire_bytes_per_element: float       # framed/ideal wire (repro.net packs
    #                                     sign bits for real: 1 bit/element)
    jit_wire_bytes_per_element: float = 0.0   # what the XLA collective path
    #                                     moves (signs stay int8 so the sum-
    #                                     reduction can address them)

    def __post_init__(self):
        if self.jit_wire_bytes_per_element == 0.0:
            object.__setattr__(self, "jit_wire_bytes_per_element",
                               self.wire_bytes_per_element)


def _identity_encode(buf, err):
    return (buf,), err


def _identity_decode(payload):
    return payload[0]


NONE = Compression("none", _identity_encode, _identity_decode, 4.0)


def _bf16_encode(buf, err):
    import jax.numpy as jnp
    corrected = buf + err
    q = corrected.astype(jnp.bfloat16)
    new_err = corrected - q.astype(buf.dtype)
    return (q,), new_err


def _bf16_decode(payload):
    import jax.numpy as jnp
    return payload[0].astype(jnp.float32)


BF16 = Compression("bf16", _bf16_encode, _bf16_decode, 2.0)


def _sign_encode(buf, err):
    import jax.numpy as jnp
    corrected = buf + err
    scale = jnp.mean(jnp.abs(corrected))
    signs = jnp.where(corrected >= 0, jnp.int8(1), jnp.int8(-1))
    decompressed = signs.astype(buf.dtype) * scale
    new_err = corrected - decompressed
    return (signs, scale), new_err


def _sign_decode(payload):
    import jax.numpy as jnp
    signs_mean, scale_mean = payload
    # signs_mean is mean over pods of ±1 (fp after mean); scale_mean is the
    # mean per-pod magnitude. Product approximates mean of sign_i*scale_i.
    return signs_mean.astype(jnp.float32) * scale_mean.astype(jnp.float32)


SIGN_EF = Compression("sign_ef", _sign_encode, _sign_decode,
                      0.125 + 1e-9, 1.0 + 1e-9)


SCHEMES = {c.name: c for c in (NONE, BF16, SIGN_EF)}


def get(name: str) -> Compression:
    try:
        return SCHEMES[name]
    except KeyError:
        raise ValueError(
            f"unknown compression '{name}', have {sorted(SCHEMES)}"
        ) from None


# ---------------------------------------------------------------------------
# numpy wire codecs — the SAME sign-EF math as ``_sign_encode`` above, but
# realized as a byte stream for the repro.net TCP wire: no in-flight
# reduction means the signs can be bit-packed for real (np.packbits), so one
# float64 element costs 1 bit + amortized scale on the wire. jax-free so TCP
# worker processes never pay the jax import.
# ---------------------------------------------------------------------------

def sign_ef_encode_np(buf: np.ndarray, err: np.ndarray
                      ) -> tuple[bytes, np.ndarray]:
    """(flat float64 buf, EF state) -> (wire payload, new EF state).

    Payload layout: [u64 n][f64 scale][packbits(signs)] — the receiver
    reconstructs ``sign * scale`` exactly; the sender's error-feedback state
    carries the quantization residual to its next message on this link.
    """
    corrected = buf + err
    scale = float(np.mean(np.abs(corrected))) if buf.size else 0.0
    bits = (corrected >= 0)
    decompressed = np.where(bits, scale, -scale)
    new_err = corrected - decompressed
    header = np.array([buf.size], np.uint64).tobytes() + \
        np.array([scale], np.float64).tobytes()
    return header + np.packbits(bits).tobytes(), new_err


def sign_ef_decode_np(payload) -> np.ndarray:
    """Inverse of ``sign_ef_encode_np`` (stateless)."""
    mv = memoryview(payload)
    n = int(np.frombuffer(mv[:8], np.uint64)[0])
    scale = float(np.frombuffer(mv[8:16], np.float64)[0])
    bits = np.unpackbits(np.frombuffer(mv[16:], np.uint8), count=n)
    return np.where(bits.astype(bool), scale, -scale)


def sign_ef_wire_nbytes(n: int) -> int:
    """Exact framed payload size for an n-element sign_ef message."""
    return 16 + (n + 7) // 8
