"""Picklable, numpy-only training problems for the repro.ps runtime.

The multiprocessing transport spawns workers with a fresh interpreter, so a
problem is described by a ``ProblemSpec`` (dotted factory path + kwargs) and
REBUILT inside each worker — no jax import in children, no pickling of
jitted closures. The thread transport accepts either a spec or a prebuilt
``(w0, grad_fn, eval_fn)`` triple (e.g. ``benchmarks.common.make_mlp_problem``,
which is jax-backed).

Contract (same as ``core.async_engine.PSEngine``):
    grad_fn(w_flat, step, worker) -> grad_flat   # float64
    eval_fn(w_flat) -> scalar metric             # e.g. test error

Worker-private minibatch RNG streams are keyed by the worker id and advance
one draw per call — so two independently-built instances of the same spec
feed IDENTICAL gradients to the DES simulator and the real runtime whenever
the per-worker call orders match. That is the substrate of the DES↔real
bitwise cross-check.
"""
from __future__ import annotations

import dataclasses
import importlib

import numpy as np

from repro.data.synthetic import make_classification_dataset


@dataclasses.dataclass(frozen=True)
class ProblemSpec:
    """factory = "module:function"; building imports the module and calls
    ``function(**kwargs)`` -> (w0, grad_fn, eval_fn)."""

    factory: str
    kwargs: tuple = ()        # tuple of (key, value) pairs — hashable/picklable

    def build(self):
        mod_name, fn_name = self.factory.split(":")
        fn = getattr(importlib.import_module(mod_name), fn_name)
        return fn(**dict(self.kwargs))


def spec(factory: str, **kwargs) -> ProblemSpec:
    return ProblemSpec(factory=factory, kwargs=tuple(sorted(kwargs.items())))


# ---------------------------------------------------------------------------
# numpy MLP classification (manual backprop — no jax anywhere)
# ---------------------------------------------------------------------------

def _mlp_shapes(d_in, d_hidden, n_classes):
    return ((d_in, d_hidden), (d_hidden,), (d_hidden, n_classes),
            (n_classes,))


def _unpack(w, shapes):
    out, off = [], 0
    for s in shapes:
        size = int(np.prod(s))
        out.append(w[off:off + size].reshape(s))
        off += size
    return out


def make_numpy_mlp(seed: int = 0, n_train: int = 2048, n_test: int = 512,
                   d_in: int = 32, d_hidden: int = 32, n_classes: int = 4,
                   batch: int = 16, noise: float = 1.6):
    """One-hidden-layer tanh MLP on the Gaussian-mixture task; gradients by
    hand so worker processes never touch jax. Returns (w0, grad_fn, eval_fn)
    with w0 float64 flat."""
    x, y = make_classification_dataset(n_train + n_test, shape=(d_in,),
                                       n_classes=n_classes, noise=noise,
                                       seed=seed)
    x = x.astype(np.float64)
    xtr, ytr = x[:n_train], y[:n_train]
    xte, yte = x[n_train:], y[n_train:]
    shapes = _mlp_shapes(d_in, d_hidden, n_classes)
    rng = np.random.RandomState(seed + 1)
    w0 = np.concatenate([
        (rng.randn(*s) / np.sqrt(max(s[0], 1) if len(s) > 1 else 1)
         ).reshape(-1)
        for s in shapes]).astype(np.float64)

    def forward(w, xb):
        w1, b1, w2, b2 = _unpack(w, shapes)
        h = np.tanh(xb @ w1 + b1)
        return h, h @ w2 + b2

    rngs = {}

    def grad_fn(w, step, worker):
        r = rngs.setdefault(worker, np.random.RandomState(1000 + worker))
        idx = r.randint(0, n_train, size=batch)
        xb, yb = xtr[idx], ytr[idx]
        w1, b1, w2, b2 = _unpack(w, shapes)
        h, logits = forward(w, xb)
        logits -= logits.max(axis=1, keepdims=True)
        p = np.exp(logits)
        p /= p.sum(axis=1, keepdims=True)
        p[np.arange(batch), yb] -= 1.0
        p /= batch                              # d loss / d logits
        dw2 = h.T @ p
        db2 = p.sum(axis=0)
        dh = (p @ w2.T) * (1.0 - h * h)
        dw1 = xb.T @ dh
        db1 = dh.sum(axis=0)
        return np.concatenate([dw1.reshape(-1), db1, dw2.reshape(-1), db2])

    def eval_fn(w):
        _, logits = forward(w, xte)
        return float(np.mean(logits.argmax(axis=1) != yte))

    # layer structure for the bucketed exchange (bucket cuts land on layer
    # edges — comm.rounds.default_bucket_boundaries)
    grad_fn.layer_sizes = [int(np.prod(s)) for s in shapes]
    return w0, grad_fn, eval_fn


NUMPY_MLP = spec("repro.ps.problems:make_numpy_mlp")

# the BENCH_ps_runtime problem (~9k params, ~70 KB packed): small enough
# that this box's compute noise stays small in absolute terms, while the
# emulated wire (costmodel.PS_WIRE) prices its full-model message at a few
# ms — the paper's comm/compute regime
NUMPY_MLP_MED = spec("repro.ps.problems:make_numpy_mlp",
                     d_in=64, d_hidden=128, batch=32, n_train=4096,
                     n_test=1024, n_classes=4)

# a bandwidth-heavy variant (~68k params, ~0.5 MB packed) for experiments
# where the exchange should cost real memory bandwidth
NUMPY_MLP_LARGE = spec("repro.ps.problems:make_numpy_mlp",
                       d_in=128, d_hidden=512, batch=32, n_train=4096,
                       n_test=1024, n_classes=4)


# ---------------------------------------------------------------------------
# jax-backed problem, spawn-safe: the factory gates the platform BEFORE the
# first jax import, so spawned/remote workers rebuild it on CPU without
# grabbing an accelerator (and without re-initializing the parent's devices)
# ---------------------------------------------------------------------------

def make_jax_mlp(seed: int = 0, n_train: int = 2048, n_test: int = 512,
                 d_in: int = 32, d_hidden: int = 64, n_classes: int = 4,
                 batch: int = 16, noise: float = 1.6, depth: int = 2):
    """The thread transport's jax closures, packaged as a ``ProblemSpec``
    factory so PROCESS and TCP workers can run jax-backed problems too:
    same jit/grad structure as ``benchmarks.common.make_mlp_problem`` (f32
    compute inside jit, float64 at the runtime boundary — no global x64
    flip), but rebuildable from a dotted path inside a fresh interpreter.

    Platform gate: a spawned child must never race the parent for a GPU/TPU,
    so if this process hasn't initialized jax yet we pin it to CPU.
    """
    import os
    import sys
    if "jax" not in sys.modules:
        os.environ.setdefault("JAX_PLATFORMS", "cpu")
    import jax
    import jax.numpy as jnp
    from jax import flatten_util

    from repro.models import cnn

    x, y = make_classification_dataset(n_train + n_test, shape=(d_in,),
                                       n_classes=n_classes, noise=noise,
                                       seed=seed)
    xtr, ytr = x[:n_train], y[:n_train]
    xte, yte = x[n_train:], y[n_train:]
    params = cnn.mlp_init(jax.random.PRNGKey(seed), d_in=d_in,
                          d_hidden=d_hidden, depth=depth,
                          n_classes=n_classes)
    flat, unravel = flatten_util.ravel_pytree(params)

    @jax.jit
    def loss_flat(w, xb, yb):
        return cnn.xent_loss(cnn.mlp_apply(unravel(w), xb), yb)

    gfn = jax.jit(jax.grad(loss_flat))

    @jax.jit
    def err_flat(w):
        return 1.0 - cnn.accuracy(cnn.mlp_apply(unravel(w), xte), yte)

    rngs = {}

    def grad_fn(w, step, worker):
        rng = rngs.setdefault(worker, np.random.RandomState(1000 + worker))
        idx = rng.randint(0, n_train, size=batch)
        return np.asarray(gfn(jnp.asarray(w, jnp.float32), xtr[idx],
                              ytr[idx]), np.float64)

    def eval_fn(w):
        return float(err_flat(jnp.asarray(w, jnp.float32)))

    grad_fn.layer_sizes = [
        int(np.prod(leaf.shape)) if leaf.shape else 1
        for leaf in jax.tree_util.tree_leaves(params)]
    return np.asarray(flat, np.float64), grad_fn, eval_fn


JAX_MLP = spec("repro.ps.problems:make_jax_mlp")
