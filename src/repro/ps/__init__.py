"""repro.ps — the real asynchronous parameter-server runtime.

Executes all nine algorithms of the paper (Original/Async/Hogwild EASGD,
Async M(EA)SGD, Sync SGD/EASGD) on genuine transports — in-process threads
(lock / lock-free master), multiprocessing on shared RawArrays, and TCP
sockets (repro.net — the runtime spans hosts) — with the optimizer math
shared with the DES simulator (``core.easgd_flat``) and the sync exchange
executing the ``repro.comm`` registry's message rounds. See DESIGN.md §ps
and §net.

Exports resolve lazily (PEP 562): ``repro.ps.problems`` is numpy-only and
must stay importable without paying the jax import — that is what keeps
repro.net TCP worker processes starting in well under a second.
"""
_RUNTIME = ("Calibration", "PSConfig", "PSResult", "calibrate",
            "calibrate_sim", "execute_rounds", "measured_link_profile",
            "run_ps", "run_vs_des")
_PROBLEMS = ("NUMPY_MLP", "NUMPY_MLP_LARGE", "NUMPY_MLP_MED", "JAX_MLP",
             "ProblemSpec", "make_numpy_mlp", "make_jax_mlp", "spec")
_TRANSPORT = ("TRANSPORTS", "get_transport")
_SUBMODULES = ("problems", "runtime", "transport")

__all__ = ("ALGORITHMS",) + _RUNTIME + _PROBLEMS + _TRANSPORT + _SUBMODULES


def __getattr__(name):
    import importlib
    if name in _PROBLEMS:
        from repro.ps import problems
        return getattr(problems, name)
    if name in _RUNTIME:
        from repro.ps import runtime
        return getattr(runtime, name)
    if name in _TRANSPORT:
        from repro.ps import transport
        return getattr(transport, name)
    if name in _SUBMODULES:
        return importlib.import_module(f"repro.ps.{name}")
    if name == "ALGORITHMS":
        from repro.core.async_engine import ALGORITHMS
        return ALGORITHMS
    raise AttributeError(f"module 'repro.ps' has no attribute '{name}'")
