"""repro.ps — the real asynchronous parameter-server runtime.

Executes all nine algorithms of the paper (Original/Async/Hogwild EASGD,
Async M(EA)SGD, Sync SGD/EASGD) on genuine shared-memory transports —
in-process threads (lock / lock-free master) and multiprocessing — with the
optimizer math shared with the DES simulator (``core.easgd_flat``) and the
sync exchange executing the ``repro.comm`` registry's message rounds.
See DESIGN.md §ps.
"""
from repro.core.async_engine import ALGORITHMS
from repro.ps.problems import (
    NUMPY_MLP,
    NUMPY_MLP_LARGE,
    NUMPY_MLP_MED,
    ProblemSpec,
    make_numpy_mlp,
    spec,
)
from repro.ps.runtime import (
    Calibration,
    PSConfig,
    PSResult,
    calibrate,
    calibrate_sim,
    execute_rounds,
    run_ps,
    run_vs_des,
)
from repro.ps.transport import TRANSPORTS, get_transport
