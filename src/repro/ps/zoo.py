"""The model zoo as PS problems: real architectures on the PS wire.

`repro.models` + `repro.configs` define the paper-scale architectures
(transformers, MoE, SSM — reduced configs sized for CPU smoke tests);
this module packages them as ``ProblemSpec`` factories so the parameter-
server runtime can train them over any transport — including the TCP p2p
data plane, where a multi-MB flat parameter row is exactly what the
bucketed overlap exchange exists for.

Every factory attaches ``grad_fn.layer_sizes`` — the per-leaf element
counts of the parameter pytree in ravel order. That is the layer structure
``comm.rounds.default_bucket_boundaries`` cuts the exchange row on: bucket
edges land on real layer edges, the §5.2 packed-layout analogue of
NCCL-style gradient bucketing.

Spawn safety follows ``make_jax_mlp``: the platform is gated to CPU
BEFORE the first jax import, so remote/spawned workers rebuild the model
without grabbing an accelerator. Worker-private minibatch RNG streams are
keyed by worker id (one draw per call), preserving the determinism
contract the bitwise cross-checks rely on.
"""
from __future__ import annotations

import numpy as np

from repro.ps.problems import (NUMPY_MLP, NUMPY_MLP_LARGE, NUMPY_MLP_MED,
                               ProblemSpec, spec)


def _gate_cpu():
    import os
    import sys
    if "jax" not in sys.modules:
        os.environ.setdefault("JAX_PLATFORMS", "cpu")


def _layer_sizes(params) -> list[int]:
    """Per-leaf element counts in ravel_pytree order (= tree_leaves order)."""
    import jax
    return [int(np.prod(leaf.shape)) if leaf.shape else 1
            for leaf in jax.tree_util.tree_leaves(params)]


# ---------------------------------------------------------------------------
# decoder LMs (transformer / MoE / SSM) — any repro.configs arch id
# ---------------------------------------------------------------------------

def make_zoo_lm(arch: str = "gemma3-4b", seq: int = 24, batch: int = 2,
                seed: int = 0):
    """A reduced-config decoder LM from the arch registry as a PS problem:
    next-token loss on synthetic token streams. ``arch`` is any
    ``repro.configs.ARCHS`` id — that includes the MoE (deepseek-v2,
    grok-1) and SSM/recurrent (mamba2, recurrentgemma) families, so the
    whole zoo flows through one factory. The flat f64 row is the
    ravel_pytree packing of the init params (hundreds of KB to several MB
    depending on the arch — real multi-frame streaming on the TCP wire)."""
    _gate_cpu()
    import jax
    import jax.numpy as jnp
    from jax import flatten_util

    from repro import configs
    from repro.models import transformer as tfm
    from repro.models.common import init_params

    cfg = configs.get(arch).reduced
    params = init_params(tfm.model_defs(cfg), jax.random.PRNGKey(seed),
                         jnp.float32)
    flat, unravel = flatten_util.ravel_pytree(params)
    sizes = _layer_sizes(params)

    def _loss(w32, tokens, targets, mask):
        batch_d = {"tokens": tokens, "targets": targets, "mask": mask}
        if cfg.mrope_sections is not None:
            S = tokens.shape[1]
            batch_d["mrope_positions"] = jnp.broadcast_to(
                jnp.arange(S)[None, None],
                (3, tokens.shape[0], S)).astype(jnp.int32)
        return tfm.lm_loss(cfg, unravel(w32), batch_d)[0]

    gfn = jax.jit(jax.grad(_loss))
    lfn = jax.jit(_loss)

    def _tokens(rng):
        t = rng.randint(0, cfg.vocab_size, size=(batch, seq + 1))
        return (jnp.asarray(t[:, :-1]), jnp.asarray(t[:, 1:]),
                jnp.ones((batch, seq), jnp.float32))

    rngs: dict = {}

    def grad_fn(w, step, worker):
        rng = rngs.setdefault(worker, np.random.RandomState(1000 + worker))
        tok, tgt, mask = _tokens(rng)
        return np.asarray(gfn(jnp.asarray(w, jnp.float32), tok, tgt, mask),
                          np.float64)

    eval_rng = np.random.RandomState(seed + 7)
    eval_batch = _tokens(eval_rng)

    def eval_fn(w):
        return float(lfn(jnp.asarray(w, jnp.float32), *eval_batch))

    grad_fn.layer_sizes = sizes
    return np.asarray(flat, np.float64), grad_fn, eval_fn


# ---------------------------------------------------------------------------
# CNNs — the paper's image models (LeNet / AlexNet shapes)
# ---------------------------------------------------------------------------

def make_zoo_cnn(model: str = "lenet", seed: int = 0, n_train: int = 512,
                 n_test: int = 256, batch: int = 8, noise: float = 1.6):
    """LeNet on 28×28×1 or AlexNet on 32×32×3 Gaussian-mixture images —
    the paper's CIFAR/MNIST-shaped workloads as PS problems."""
    _gate_cpu()
    import jax
    import jax.numpy as jnp
    from jax import flatten_util

    from repro.data.synthetic import make_classification_dataset
    from repro.models import cnn

    if model == "lenet":
        shape, init, apply = (28, 28, 1), cnn.lenet_init, cnn.lenet_apply
    elif model == "alexnet":
        shape, init, apply = (32, 32, 3), cnn.alexnet_init, cnn.alexnet_apply
    else:
        raise ValueError(f"unknown cnn '{model}' (lenet/alexnet)")
    x, y = make_classification_dataset(n_train + n_test, shape=shape,
                                       n_classes=10, noise=noise, seed=seed)
    xtr, ytr = x[:n_train], y[:n_train]
    xte, yte = x[n_train:], y[n_train:]
    params = init(jax.random.PRNGKey(seed))
    flat, unravel = flatten_util.ravel_pytree(params)
    sizes = _layer_sizes(params)

    @jax.jit
    def loss_flat(w32, xb, yb):
        return cnn.xent_loss(apply(unravel(w32), xb), yb)

    gfn = jax.jit(jax.grad(loss_flat))

    @jax.jit
    def err_flat(w32):
        return 1.0 - cnn.accuracy(apply(unravel(w32), xte), yte)

    rngs: dict = {}

    def grad_fn(w, step, worker):
        rng = rngs.setdefault(worker, np.random.RandomState(1000 + worker))
        idx = rng.randint(0, n_train, size=batch)
        return np.asarray(gfn(jnp.asarray(w, jnp.float32), xtr[idx],
                              ytr[idx]), np.float64)

    def eval_fn(w):
        return float(err_flat(jnp.asarray(w, jnp.float32)))

    grad_fn.layer_sizes = sizes
    return np.asarray(flat, np.float64), grad_fn, eval_fn


# ---------------------------------------------------------------------------
# the named zoo — what `--model` resolves (launch/train, launch/cluster)
# ---------------------------------------------------------------------------

def zoo_names() -> list[str]:
    from repro import configs
    return (["tiny-mlp", "mlp-large", "jax-mlp", "lenet", "alexnet"]
            + sorted(configs.ARCHS))


def resolve(name: str) -> ProblemSpec:
    """``--model`` name -> ProblemSpec. MLP names map to the seed problems
    (tiny-mlp is the default everywhere — nothing changes without the
    flag); arch ids map to ``make_zoo_lm``; lenet/alexnet to the CNNs."""
    fixed = {"tiny-mlp": NUMPY_MLP_MED, "mlp": NUMPY_MLP,
             "mlp-large": NUMPY_MLP_LARGE,
             "jax-mlp": spec("repro.ps.problems:make_jax_mlp")}
    if name in fixed:
        return fixed[name]
    if name in ("lenet", "alexnet"):
        return spec("repro.ps.zoo:make_zoo_cnn", model=name)
    from repro import configs
    if name in configs.ARCHS:
        return spec("repro.ps.zoo:make_zoo_lm", arch=name)
    raise ValueError(f"unknown model '{name}'; have: {zoo_names()}")
