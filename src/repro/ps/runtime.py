"""The repro.ps runtime: the paper's nine algorithms EXECUTED, not simulated.

Same optimizer math as the DES simulator (``core.easgd_flat`` — shared, not
copied), same exchange registry (``repro.comm`` — the sync family executes
the registered schedule's ``Schedule.rounds`` message pattern over the
transport mailboxes), but time is wall-clock and concurrency is real
threads/processes on shared memory.

Concurrency disciplines (paper §4–5):

 * ``original_easgd`` — round-robin TURNSTILE: the master serves workers
   strictly in rank order (Θ(P) serialized exchange, the paper's baseline).
 * ``async_*``        — FCFS: workers hit the master lock in arrival order;
   with ``deterministic=True`` the turnstile replaces the lock, which is
   exactly the zero-jitter event order of the DES — the bitwise DES↔real
   cross-check runs in this mode.
 * ``hogwild_*``      — the SAME absorb with NO lock. Lock-free for real:
   concurrent in-place numpy updates tear and interleave.
 * ``sync_*``         — barriered rounds; the weight (EASGD) or gradient
   (SGD) all-reduce runs the registered schedule's message rounds in a comm
   executor thread. Sync EASGD posts start-of-step weights BEFORE computing
   gradients, so the exchange genuinely overlaps compute (paper §6.1.3);
   sync SGD needs the gradients first, so it cannot (§5.1).

τ (``EASGDConfig.tau``, the communication period) is honored by every
loop: workers take τ−1 local-only steps (``easgd_flat.local_step``)
between exchanges, so communication drops by 1/τ — Table 3's bandwidth
lever, executed. The DES cross-check and the bitwise tests run at τ=1
(the DES models τ=1 event orders).

``transport="tcp"`` dispatches the whole run to the repro.net master
server (workers are processes on other ends of a real wire — localhost
subprocesses by default, other hosts via launch/cluster); the PSResult
comes back in the same shape.
"""
from __future__ import annotations

import dataclasses
import os
import threading
import time
from typing import Optional

import numpy as np

from repro.comm import rounds as comm_rounds
from repro.comm import schedules as comm_schedules
from repro.core import costmodel, easgd_flat
from repro.core.async_engine import ALGORITHMS, PSEngine, SimConfig
from repro.core.easgd import EASGDConfig
from repro.obs import metrics as obs_metrics
from repro.obs import report as obs_report
from repro.obs import trace as obs_trace
from repro.ps.transport import PSContext, get_transport

SYNC = easgd_flat.SYNC_FAMILY

# default α–β network (only prices psum's butterfly-vs-ring choice for the
# sync rounds; the measured run doesn't consult it)
_DEFAULT_NET = costmodel.Network("PCIe3x16", 5e-6, 1 / 12e9)


@dataclasses.dataclass(frozen=True)
class PSConfig:
    algorithm: str
    n_workers: int = 4
    transport: str = "thread"        # "thread" | "process" | "tcp"
    schedule: str = "ring"           # sync-family exchange ("auto" allowed)
    total_iters: int = 1000
    deterministic: bool = False      # cyclic admission == DES zero-jitter
    eval_every_iters: int = 200
    net: costmodel.Network = _DEFAULT_NET
    # netem-style wire emulation: every master message / exchange round
    # ADDITIONALLY sleeps its α+nβ under this network (None: shared memory
    # IS the wire). The bytes still move and the concurrency discipline
    # still decides what serializes, overlaps, or amortizes — the sleep
    # restores the interconnect-bound regime the paper ran in (10GbE/IB),
    # which a single box's memcpy cannot reproduce. Charge the SAME network
    # to the DES (Calibration.sim_config(net=...)) for a fair cross-check.
    emulate_net: Optional[costmodel.Network] = None
    seed: int = 0
    # -- tcp transport only (repro.net) ------------------------------------
    wire_compression: str = "none"   # "none" | "sign_ef": per-link payload
    #                                  codec with error-feedback state (the
    #                                  framed 1-bit wire — core.compression)
    sync_plane: str = "master"       # "master": the net master executes the
    #                                  sync-family rounds on its local
    #                                  mailbox (every round funnels Θ(P·N)
    #                                  through its links); "p2p": workers
    #                                  execute the SAME rounds over direct
    #                                  worker↔worker links (net.peer) and
    #                                  the master degrades to control plane
    #                                  — Θ(N_center) on the master link
    tcp_host: str = "127.0.0.1"
    tcp_port: int = 0                # 0: ephemeral (launch/cluster pins one
    #                                  for multi-host rendezvous)
    spawn_workers: bool = True       # False: external workers join (--hosts)
    hb_interval_s: float = 2.0       # worker heartbeat period
    hb_timeout_s: float = 60.0       # master declares a silent link dead
    # -- bucketed overlap (sync family) -------------------------------------
    bucket_bytes: int = 0            # >0: partition the exchange row into
    #                                  per-layer-group buckets of ~this many
    #                                  payload bytes (comm.rounds.
    #                                  bucket_boundaries over the problem's
    #                                  layer_sizes) — a bitwise-identical
    #                                  VIEW of the same rounds. 0: monolithic
    overlap: bool = True             # p2p only: stream buckets while the
    #                                  gradient / per-bucket update computes
    #                                  (§6.1.3). False = the paper's
    #                                  no-overlap baseline — same math, the
    #                                  worker just waits out the wire
    update_backend: str = "numpy"    # p2p worker update: "numpy"
    #                                  (easgd_flat) or "pallas" (the fused
    #                                  elastic-update kernel on the real
    #                                  per-bucket path; workers are spawned
    #                                  with XLA flags that keep it bitwise)
    # -- observability (repro.obs) ------------------------------------------
    trace: bool = False              # record per-thread spans (compute /
    #                                  waits / exchange rounds / buckets /
    #                                  updates) and return the merged,
    #                                  clock-aligned timeline + Table-3
    #                                  breakdown on PSResult.trace. Off by
    #                                  default: the hot paths then take no
    #                                  timestamps at all
    trace_dir: Optional[str] = None  # spill per-worker trace buffers as
    #                                  JSON files here instead of carrying
    #                                  them inline in BYE (process workers
    #                                  always spill; a temp dir is made if
    #                                  unset). Assumes a filesystem the
    #                                  master can read (localhost / NFS)
    # -- live telemetry plane (obs.live) ------------------------------------
    telemetry: bool = False          # stream heartbeat telemetry + master
    #                                  gauges into a ring-buffer time-series
    #                                  store, run the online straggler /
    #                                  health detector, serve STATS frames
    #                                  (tcp) and attach PSResult.health.
    #                                  Off by default: no store, no sampler
    #                                  thread, no acceptor — zero work
    telemetry_jsonl: Optional[str] = None    # stream one JSON line per
    #                                  sample to this path (implies
    #                                  telemetry; offline analysis /
    #                                  launch.monitor --from-jsonl)
    telemetry_interval_s: float = 0.0        # sampler/detector period;
    #                                  0 = follow hb_interval_s (one
    #                                  detector pass per heartbeat)
    straggler_factor: float = 2.0    # health detector deadline: flag a
    #                                  worker whose per-iteration delay
    #                                  exceeds this × the median
    #                                  (ft.straggler.BoundedStaleness)
    link_slow: Optional[tuple] = None        # per-wid emulated-wire
    #                                  multipliers (len n_workers, ≥1.0):
    #                                  worker i's master-link / p2p pacing
    #                                  deadlines stretch by link_slow[i] —
    #                                  a controlled straggler for testing
    #                                  detection (tcp + emulate_net only;
    #                                  clock-plane only, the math is
    #                                  untouched)
    # -- elastic membership (ft.membership) ---------------------------------
    elastic: bool = False            # tcp only: a worker death/preemption
    #                                  becomes a membership transition + a
    #                                  RECONFIGURE epoch instead of a dead
    #                                  run; rejoining workers are admitted
    #                                  mid-run. Off (default): failures
    #                                  raise exactly as before, and the
    #                                  happy path runs zero extra frames
    chaos: Optional[dict] = None     # deterministic fault injection
    #                                  (ft.chaos.ChaosSpec fields as a
    #                                  dict: wid / kill_at_iter / signal
    #                                  "kill"|"term" / dial_refuse_s) —
    #                                  serialized to the spawned workers'
    #                                  REPRO_CHAOS env; tcp only
    # -- heterogeneous fabric (topology-aware scale-out) --------------------
    topology: Optional[costmodel.Topology] = None    # hosts × slots link
    #                                  model: it REPLACES emulate_net for
    #                                  the sync family — every pacing sleep
    #                                  (master rounds, p2p segment
    #                                  deadlines) prices each message over
    #                                  ITS link class (fast intra-host /
    #                                  slow cross-host), and schedule="auto"
    #                                  ranks candidates per-topology
    link_profile: Optional[costmodel.LinkProfile] = None     # a MEASURED
    #                                  per-link-class profile (ps.
    #                                  measured_link_profile / calibrate):
    #                                  when set, "auto" choice prices over
    #                                  it instead of the nominal topology

    def __post_init__(self):
        assert self.algorithm in ALGORITHMS, self.algorithm
        assert self.bucket_bytes >= 0, self.bucket_bytes
        assert self.update_backend in ("numpy", "pallas"), \
            self.update_backend
        # the fused-kernel update path lives in the p2p worker loop — the
        # shared-memory planes update through easgd_flat directly
        assert self.update_backend == "numpy" or (
            self.transport == "tcp" and self.sync_plane == "p2p"), (
            f"update_backend='pallas' runs in the p2p worker loop "
            f"(transport='{self.transport}', sync_plane='{self.sync_plane}')")
        assert self.wire_compression in ("none", "sign_ef"), \
            self.wire_compression
        # the shared-memory transports have no wire to compress — a config
        # that claims compression there would silently report raw bytes
        assert self.wire_compression == "none" or self.transport == "tcp", (
            f"wire_compression='{self.wire_compression}' is a tcp-transport "
            f"feature (transport='{self.transport}' moves no frames)")
        assert self.sync_plane in ("master", "p2p"), self.sync_plane
        # the p2p data plane is worker↔worker sockets executing the sync
        # family's rounds — it has no meaning off tcp or off that family
        assert self.sync_plane == "master" or (
            self.transport == "tcp" and self.algorithm in SYNC), (
            f"sync_plane='p2p' needs transport='tcp' and a sync-family "
            f"algorithm (got transport='{self.transport}', "
            f"algorithm='{self.algorithm}') — only the sync family "
            f"executes Schedule.rounds, and only repro.net has peer links")
        assert self.telemetry_interval_s >= 0.0, self.telemetry_interval_s
        assert self.straggler_factor > 1.0, self.straggler_factor
        if self.link_slow is not None:
            assert self.transport == "tcp", (
                "link_slow stretches per-link wire pacing — only the tcp "
                f"transport has per-worker links (transport="
                f"'{self.transport}')")
            assert self.emulate_net is not None, (
                "link_slow multiplies EMULATED wire time; without "
                "emulate_net there is no pacing to stretch")
            assert len(self.link_slow) == self.n_workers, (
                f"link_slow needs one factor per worker "
                f"({len(self.link_slow)} != {self.n_workers})")
            assert all(f >= 1.0 for f in self.link_slow), self.link_slow
        assert not self.elastic or self.transport == "tcp", (
            "elastic membership reconfigures real links — only the tcp "
            f"transport has them (transport='{self.transport}')")
        if self.chaos is not None:
            assert self.transport == "tcp", (
                "chaos injection targets spawned tcp worker processes "
                f"(transport='{self.transport}')")
            from repro.ft.chaos import ChaosSpec
            ChaosSpec.from_config(self.chaos)   # validates the fields
        if self.topology is not None:
            assert self.algorithm in SYNC, (
                "a topology prices the sync family's exchange rounds — "
                f"algorithm '{self.algorithm}' has none")
            assert self.topology.p == self.n_workers, (
                f"topology is {self.topology.hosts}x{self.topology.slots}="
                f"{self.topology.p} slots but n_workers={self.n_workers}")
            assert self.transport in ("thread", "tcp"), (
                f"topology pacing exists on the thread and tcp planes "
                f"(transport='{self.transport}')")
            assert self.emulate_net is None, (
                "topology REPLACES emulate_net: per-link pacing and the "
                "global emulated wire would double-charge the clock")
            assert not self.elastic, (
                "topology-aware pacing + elastic membership are not yet "
                "composed (an epoch's survivors no longer tile the "
                "declared hosts x slots grid)")
        if self.link_profile is not None:
            assert self.topology is not None, (
                "link_profile rides a topology — set PSConfig.topology to "
                "the fabric the profile was measured on")

    @property
    def telemetry_on(self) -> bool:
        return self.telemetry or self.telemetry_jsonl is not None

    def telemetry_period_s(self) -> float:
        return self.telemetry_interval_s or self.hb_interval_s

    def link_slow_factor(self, wid: int) -> float:
        if self.link_slow is None:
            return 1.0
        return float(self.link_slow[wid])

    def resolved_schedule(self, n_bytes: float,
                          profile: Optional[costmodel.LinkProfile] = None
                          ) -> str:
        """Schedule name for an n-byte exchange. "auto" ranks candidates
        over, in preference order: an explicitly passed measured
        ``profile``, ``self.link_profile``, the nominal ``self.topology``,
        else the flat ``self.net`` — exactly today's choice when no
        topology is in play."""
        if self.schedule != "auto":
            return comm_schedules.get(self.schedule).name
        prof = profile if profile is not None else self.link_profile
        if prof is not None:
            return comm_schedules.choose(n_bytes, self.n_workers,
                                         profile=prof)
        if self.topology is not None:
            return comm_schedules.choose(n_bytes, self.n_workers,
                                         topology=self.topology)
        return comm_schedules.choose(n_bytes, self.n_workers, self.net)

    def hb_interval_eff_s(self, p: Optional[int] = None) -> float:
        """Heartbeat period scaled with mesh size: P links at a fixed 2 s
        period flood the master's reader threads and trip false hb_stale
        verdicts at high P. Scale by max(1, P/16) — every P ≤ 16 config
        keeps EXACTLY its configured period (tests pin this), P = 64 beats
        4× slower."""
        pp = self.n_workers if p is None else p
        return self.hb_interval_s * max(1.0, pp / 16.0)

    def hb_timeout_eff_s(self, p: Optional[int] = None) -> float:
        """Staleness threshold that scales WITH the interval: never below
        the configured timeout, and at least 12 effective periods so a
        scaled-up interval cannot outrun its own deadline."""
        return max(self.hb_timeout_s, 12.0 * self.hb_interval_eff_s(p))

    def t_msg_emulated(self, n_bytes: float) -> float:
        """Per-message emulated wire time (0 without emulation)."""
        if self.emulate_net is None:
            return 0.0
        return costmodel.t_msg(n_bytes, self.emulate_net)


@dataclasses.dataclass
class PSResult:
    algorithm: str
    transport: str
    schedule: str
    history: list                    # [(wall_s, total_iters, metric)]
    total_time_s: float
    total_iters: int
    counters: dict                   # sync_rounds / messages / wire_bytes
    final_metric: float
    center: np.ndarray
    workers: np.ndarray              # (P, n) final worker weights
    trace: Optional[dict] = None     # cfg.trace: the merged, clock-aligned
    #                                  timeline (obs.report.merge_traces
    #                                  shape) with a "report" breakdown
    health: Optional[dict] = None    # cfg.telemetry: the live plane's
    #                                  summary — structured health events
    #                                  (straggler / hb_stale / recovered /
    #                                  worker_left), currently-flagged
    #                                  workers, final per-worker telemetry
    #                                  (obs.live.LiveMonitor.health())


# ---------------------------------------------------------------------------
# the sync-family exchange: execute the registry's message rounds
# ---------------------------------------------------------------------------

def _sleep_until(deadline: float) -> None:
    """Absolute-deadline sleep (``time.monotonic`` clock): oversleeps on a
    loaded box don't accumulate — the next deadline is computed from the
    schedule, not from when this sleep happened to return."""
    dt = deadline - time.monotonic()
    if dt > 0:
        time.sleep(dt)


def _apply_round(mailbox, n: int, rnd, counters=None) -> None:
    """One message round: receivers read the senders' PRE-round values
    (snapshot, then apply) — messages within a round are concurrent.
    ``Message.span`` addresses the slice each message moves — the same
    offsets the p2p data plane puts on the wire as SEGMENT frames."""
    row_len = mailbox.shape[-1]
    payloads = []
    for m in rnd:
        a, b = m.span(row_len)
        payloads.append((m, mailbox[m.src, a:b].copy()))
    for m, pay in payloads:
        a, b = m.span(row_len)
        tgt = mailbox[m.dst, a:b]
        if m.op == "add":
            tgt += pay
        else:
            tgt[:] = pay
    if counters is not None:
        obs_metrics.count_round(counters, rnd, n)


def _apply_clipped_round(mailbox, rnd_clipped) -> None:
    """``_apply_round`` over pre-clipped ``(message, (a, b))`` pairs — the
    bucketed view's unit of work. Same snapshot-then-apply discipline."""
    payloads = []
    for m, (a, b) in rnd_clipped:
        payloads.append((m, a, b, mailbox[m.src, a:b].copy()))
    for m, a, b, pay in payloads:
        tgt = mailbox[m.dst, a:b]
        if m.op == "add":
            tgt += pay
        else:
            tgt[:] = pay


def execute_rounds(mailbox, n: int, rounds, counters=None,
                   boundaries=None, tracer=None) -> None:
    """Apply one allreduce = the schedule's message rounds over the mailbox
    (rows 0..P-1 = workers, row P = the master endpoint used by
    round_robin). Rounds are serialized — the execution IS the α–β model's
    structure.

    ``boundaries`` (bucket cuts over the row) switches to the bucketed
    VIEW: the same rounds execute bucket-major with every message span
    clipped per bucket (``comm.rounds.bucket_rounds``). Buckets partition
    the row into disjoint element ranges, so each element sees the same
    ops from the same sources in the same order — bitwise-identical to the
    monolithic path (pinned by tests). Counters stay schedule-level: one
    exchange contributes the SAME sync_rounds/messages/wire_bytes either
    way (bucketing repartitions frames, not the schedule's cost).
    """
    mailbox[-1].fill(0.0)            # master endpoint accumulates from zero
    if boundaries is not None and len(boundaries) > 2:
        row_len = mailbox.shape[-1]
        plans = comm_rounds.bucket_rounds(rounds, row_len, boundaries)
        for bidx, plan in enumerate(plans):
            t0 = time.perf_counter() if tracer is not None else 0.0
            for rnd_clipped in plan:
                _apply_clipped_round(mailbox, rnd_clipped)
            if tracer is not None:
                tracer.record(obs_trace.BUCKET, t0, time.perf_counter(),
                              bidx)
        if counters is not None:
            for rnd in rounds:
                obs_metrics.count_round(counters, rnd, n)
        return
    for i, rnd in enumerate(rounds):
        t0 = time.perf_counter() if tracer is not None else 0.0
        _apply_round(mailbox, n, rnd, counters)
        if tracer is not None:
            tracer.record(obs_trace.ROUND, t0, time.perf_counter(), i)


def _comm_executor(ctx: PSContext) -> None:
    """The sync family's 'NIC': runs the allreduce rounds between barriers
    A and B of every training round while the workers compute (Sync EASGD —
    real overlap) or wait (Sync SGD). sync_easgd's version-flipped center
    needs no post-update barrier (see ``_sync_worker``), so its round has
    two barriers; sync_sgd keeps a third."""
    v = ctx.views()
    counters = {"sync_rounds": ctx.sync_rounds, "messages": ctx.messages,
                "wire_bytes": ctx.wire_bytes}
    tau = max(ctx.easgd.tau, 1)
    n_rounds = -(-ctx.cfg.total_iters // (ctx.cfg.n_workers * tau))
    third = ctx.cfg.algorithm == "sync_sgd"
    tr = obs_trace.tracer("comm") if ctx.cfg.trace else None
    _pc = time.perf_counter
    # emulated wire: the message rounds serialize, so one exchange costs
    # Σ (α + max_frac·n·β) on top of the real copies — paced as a single
    # absolute deadline per exchange to be robust to oversleep. With a
    # topology each round is priced over its own link classes instead of
    # one global wire (comm.rounds.t_rounds)
    if ctx.cfg.topology is not None:
        t_wire = comm_rounds.t_rounds(ctx.rounds, ctx.n * 8,
                                      topology=ctx.cfg.topology)
    else:
        t_wire = sum(
            ctx.cfg.t_msg_emulated(max(m.frac for m in rnd) * ctx.n * 8)
            for rnd in ctx.rounds)
    try:
        for _ in range(n_rounds):
            if tr is not None:
                t0 = _pc()
            ctx.barrier.wait()       # A: mailboxes posted
            if tr is not None:
                tr.record(obs_trace.BARRIER, t0, (tx := _pc()), 0)
            deadline = time.monotonic() + t_wire
            execute_rounds(v.mailbox, ctx.n, ctx.rounds, counters,
                           boundaries=getattr(ctx, "boundaries", None),
                           tracer=tr)
            if t_wire:
                _sleep_until(deadline)
            if tr is not None:
                tr.record(obs_trace.EXCHANGE, tx, (t0 := _pc()))
            ctx.barrier.wait()       # B: exchange complete
            if tr is not None:
                tr.record(obs_trace.BARRIER, t0, _pc(), 1)
            if third:
                ctx.barrier.wait()   # C: master update complete
    except threading.BrokenBarrierError:
        pass
    except Exception:                # noqa: BLE001 — surface via err flag
        ctx.err.value = 1
        ctx.barrier.abort()


# ---------------------------------------------------------------------------
# worker loops
# ---------------------------------------------------------------------------

def worker_main(ctx: PSContext, wid: int) -> None:
    w0, grad_fn, _ = ctx.built_problem()
    # warm caches/pages before the start gate so the measured clock sees
    # steady state; ids ≤ −2 are private RNG streams (worker streams and
    # therefore the DES↔real iterate equality are untouched)
    wu = np.asarray(w0, np.float64).copy()
    for k in range(2):
        grad_fn(wu, k, -(wid + 2))
    ctx.start_barrier.wait()
    tr = obs_trace.tracer("main", wid=wid) if ctx.cfg.trace else None
    algo = ctx.cfg.algorithm
    if algo in SYNC:
        _sync_worker(ctx, wid, grad_fn, tr)
    elif algo == "original_easgd" or ctx.cfg.deterministic:
        _turnstile_worker(ctx, wid, grad_fn, tr)
    elif algo.startswith("hogwild"):
        _hogwild_worker(ctx, wid, grad_fn, tr)
    else:
        _fcfs_worker(ctx, wid, grad_fn, tr)
    if tr is not None and ctx.cfg.trace_dir:
        # process transport: the registry dies with this process — spill
        # the buffer to disk for the launcher to merge (perf_counter is
        # system-wide CLOCK_MONOTONIC, so offsets between local processes
        # are already ~0 and no clock sync is needed)
        obs_trace.dump_spill(ctx.cfg.trace_dir, wid, {
            "clock": {"offset_s": 0.0, "rtt_s": 0.0},
            "threads": {"main": tr.spans()},
            "dropped": tr.dropped,
        })


def _turnstile_worker(ctx, wid, grad_fn, tr=None):
    """Strict cyclic admission: worker ``turn % P`` owns the master next.
    This is Original EASGD's round-robin wire — and, for the async family
    under ``deterministic=True``, exactly the DES zero-jitter event order.

    original_easgd computes its gradient INSIDE the turn: the master serves
    one worker at a time end to end, so the whole pipeline serializes —
    the Θ(P) behavior the paper attacks (and what the DES charges). The
    async family computes ahead of the turn (w⁽ⁱ⁾ only changes during our
    own turn and the gradient never reads W̄, so the iterates are identical
    either way — only the clock differs)."""
    v, e = ctx.views(), ctx.easgd
    algo, P, total = ctx.cfg.algorithm, ctx.cfg.n_workers, ctx.cfg.total_iters
    w, vel = v.workers_w[wid], v.workers_v[wid]
    serial_compute = algo == "original_easgd"
    t_msg = ctx.cfg.t_msg_emulated(ctx.n * 8)
    tau = max(e.tau, 1)
    total_turns = -(-total // tau)           # one turn = one exchange = τ steps
    local_step = 0
    _pc = time.perf_counter

    def _tau_block():
        """τ−1 local-only steps + the exchange gradient."""
        nonlocal local_step
        if tr is not None:
            t0 = _pc()
        for _ in range(tau - 1):
            g = grad_fn(w, local_step, wid)
            easgd_flat.local_step(algo, w, vel, g, e)
            local_step += 1
        if tr is not None and tau > 1:
            tr.record(obs_trace.LOCAL_STEP, t0, (t0 := _pc()), tau - 1)
        g = grad_fn(w, local_step, wid)
        local_step += 1
        if tr is not None:
            tr.record(obs_trace.COMPUTE, t0, _pc())
        return g

    while True:
        grad = None if serial_compute else _tau_block()
        if tr is not None:
            t0 = _pc()
        with ctx.turn_cond:
            while ctx.turn.value < total_turns and ctx.turn.value % P != wid:
                ctx.turn_cond.wait(0.05)
            if tr is not None:
                tr.record(obs_trace.TURN_WAIT, t0, (t0 := _pc()))
            if ctx.turn.value >= total_turns:
                ctx.turn_cond.notify_all()
                return
            if t_msg:                        # master → worker (W̄ down)
                _sleep_until(time.monotonic() + t_msg)
                if tr is not None:
                    tr.record(obs_trace.COMM_WAIT, t0, (t0 := _pc()), 0)
            if serial_compute:
                grad = _tau_block()
                if tr is not None:
                    t0 = _pc()
                easgd_flat.master_absorb_round_robin(
                    v.center, w, vel, grad, e)
            else:
                easgd_flat.master_absorb(
                    algo, v.center, v.master_vel, w, vel, grad, e)
            if tr is not None:
                tr.record(obs_trace.UPDATE, t0, (t0 := _pc()))
            if t_msg:                        # worker → master (W⁽ⁱ⁾ up)
                _sleep_until(time.monotonic() + t_msg)
                if tr is not None:
                    tr.record(obs_trace.COMM_WAIT, t0, _pc(), 1)
            ctx.turn.value += 1
            ctx.iters.value += tau
            ctx.messages.value += 2          # worker↔master, both ways
            ctx.wire_bytes.value += 2 * ctx.n * 8
            ctx.turn_cond.notify_all()


def _fcfs_worker(ctx, wid, grad_fn, tr=None):
    """Async family: first-come-first-served on the master lock."""
    v, e = ctx.views(), ctx.easgd
    algo, total = ctx.cfg.algorithm, ctx.cfg.total_iters
    w, vel = v.workers_w[wid], v.workers_v[wid]
    t_msg = ctx.cfg.t_msg_emulated(ctx.n * 8)
    tau = max(e.tau, 1)
    local_step = 0
    _pc = time.perf_counter
    while ctx.iters.value < total:
        if tr is not None:
            t0 = _pc()
        for _ in range(tau - 1):             # τ−1 local-only steps
            g = grad_fn(w, local_step, wid)
            easgd_flat.local_step(algo, w, vel, g, e)
            local_step += 1
        if tr is not None and tau > 1:
            tr.record(obs_trace.LOCAL_STEP, t0, (t0 := _pc()), tau - 1)
        grad = grad_fn(w, local_step, wid)
        local_step += 1
        if tr is not None:
            tr.record(obs_trace.COMPUTE, t0, (t0 := _pc()))
        deadline = None
        with ctx.master_lock:
            if tr is not None:
                tr.record(obs_trace.TURN_WAIT, t0, (t0 := _pc()))
            if ctx.iters.value >= total:
                return
            if t_msg:
                # the ONE master link serializes both messages of every
                # exchange: reserve wire time as an absolute deadline (the
                # sleep happens OUTSIDE the lock — the wire is busy, the
                # master CPU is not)
                start = max(time.monotonic(), ctx.wire_free_at.value)
                deadline = start + 2 * t_msg
                ctx.wire_free_at.value = deadline
            easgd_flat.master_absorb(
                algo, v.center, v.master_vel, w, vel, grad, e)
            ctx.iters.value += tau
            ctx.messages.value += 2
            ctx.wire_bytes.value += 2 * ctx.n * 8
            if tr is not None:
                tr.record(obs_trace.UPDATE, t0, (t0 := _pc()))
        if deadline is not None:
            _sleep_until(deadline)
            if tr is not None:
                tr.record(obs_trace.COMM_WAIT, t0, _pc())


def _hogwild_worker(ctx, wid, grad_fn, tr=None):
    """The SAME absorb as FCFS with NO lock — concurrent in-place updates
    of the shared center interleave (and tear) for real. Termination is by
    per-worker quota: the racy shared counter is monitoring-only."""
    v, e = ctx.views(), ctx.easgd
    algo, P, total = ctx.cfg.algorithm, ctx.cfg.n_workers, ctx.cfg.total_iters
    w, vel = v.workers_w[wid], v.workers_v[wid]
    t_msg = ctx.cfg.t_msg_emulated(ctx.n * 8)
    tau = max(e.tau, 1)
    quota = total // P + (1 if wid < total % P else 0)
    _pc = time.perf_counter
    for local_step in range(quota):
        if tr is not None:
            t0 = _pc()
        grad = grad_fn(w, local_step, wid)
        if (local_step + 1) % tau and local_step != quota - 1:
            easgd_flat.local_step(algo, w, vel, grad, e)   # τ local-only
            if tr is not None:
                tr.record(obs_trace.LOCAL_STEP, t0, _pc(), 1)
            ctx.iters.value += 1             # racy — monitoring only
            continue
        if tr is not None:
            tr.record(obs_trace.COMPUTE, t0, (t0 := _pc()))
        deadline = (time.monotonic() + 2 * t_msg) if t_msg else None
        easgd_flat.master_absorb(
            algo, v.center, v.master_vel, w, vel, grad, e)
        if tr is not None:
            tr.record(obs_trace.UPDATE, t0, (t0 := _pc()))
        if deadline is not None:
            _sleep_until(deadline)           # lock-free: wire times OVERLAP
            if tr is not None:
                tr.record(obs_trace.COMM_WAIT, t0, _pc())
        ctx.iters.value += 1                 # racy — monitoring only
        ctx.messages.value += 2
        ctx.wire_bytes.value += 2 * ctx.n * 8


def _sync_worker(ctx, wid, grad_fn, tr=None):
    """Barriered rounds; barriers are shared with the comm executor.

    sync_easgd: post W_t → [A] → grad ∥ allreduce → [B] → worker rule →
                rank 0 applies eq 2. TWO barriers per round: W̄ is
                version-flipped — round k reads W̄[k mod 2] while rank 0
                writes W̄[(k+1) mod 2], so the center update needs no
                post-update barrier (real readers and the writer never
                touch the same buffer; the next round's A orders the flip).
    sync_sgd:   grad → post → [A] → allreduce (workers idle — a gradient
                exchange cannot overlap its own compute, §5.1) → [B] →
                rank 0 momentum step on ḡ → [C] → all copy W̄.
    """
    v, e = ctx.views(), ctx.easgd
    algo, P, total = ctx.cfg.algorithm, ctx.cfg.n_workers, ctx.cfg.total_iters
    w, vel = v.workers_w[wid], v.workers_v[wid]
    n = ctx.n
    tau = max(e.tau, 1)
    n_rounds = -(-total // (P * tau))
    it = 0
    _pc = time.perf_counter

    def _local_block():
        """τ−1 local-only steps before the barriered exchange step."""
        nonlocal it
        if tr is not None and tau > 1:
            t0 = _pc()
        for _ in range(tau - 1):
            g = grad_fn(w, it, wid)
            easgd_flat.local_step(algo, w, vel, g, e)
            it += 1
        if tr is not None and tau > 1:
            tr.record(obs_trace.LOCAL_STEP, t0, _pc(), tau - 1)

    if algo == "sync_easgd":
        versions = (v.center, v.center_alt)
        for step in range(n_rounds):
            _local_block()
            c_read, c_write = versions[step % 2], versions[(step + 1) % 2]
            v.mailbox[wid, :n] = w           # start-of-exchange-step weights
            if tr is not None:
                t0 = _pc()
            ctx.barrier.wait()               # A — exchange begins
            if tr is not None:
                tr.record(obs_trace.BARRIER, t0, (t0 := _pc()), 0)
            grad = grad_fn(w, it, wid)       # …and overlaps this compute
            it += 1
            if tr is not None:
                tr.record(obs_trace.COMPUTE, t0, (t0 := _pc()))
            ctx.barrier.wait()               # B — sum of W_t in every row
            if tr is not None:
                tr.record(obs_trace.BARRIER, t0, (t0 := _pc()), 1)
            easgd_flat.worker_step(algo, w, vel, grad, c_read, e)
            if wid == 0:
                c_write[:] = c_read
                easgd_flat.sync_master_easgd(
                    c_write, v.mailbox[0, :n] / P, P, e)
                ctx.iters.value += P * tau
            if tr is not None:
                tr.record(obs_trace.UPDATE, t0, _pc())
        # NOTE: after an odd round count the final W̄ lives in center_alt;
        # the LAUNCHER copies it back post-join (doing it here would race
        # with the other workers' last worker_step, which reads .center)
        return
    for step in range(n_rounds):             # sync_sgd
        _local_block()
        if tr is not None:
            t0 = _pc()
        grad = grad_fn(w, it, wid)
        it += 1
        if tr is not None:
            tr.record(obs_trace.COMPUTE, t0, (t0 := _pc()))
        v.mailbox[wid, :n] = grad
        ctx.barrier.wait()                   # A — gradient allreduce
        ctx.barrier.wait()                   # B — workers idle through both
        if tr is not None:
            tr.record(obs_trace.BARRIER, t0, (t0 := _pc()), 1)
        if wid == 0:
            easgd_flat.sync_master_sgd(
                v.center, v.master_vel, v.mailbox[0, :n] / P, e)
            ctx.iters.value += P * tau
            if tr is not None:
                tr.record(obs_trace.UPDATE, t0, (t0 := _pc()))
        ctx.barrier.wait()                   # C — W̄ updated
        if tr is not None:
            tr.record(obs_trace.BARRIER, t0, _pc(), 2)
        w[:] = v.center


# ---------------------------------------------------------------------------
# the launcher
# ---------------------------------------------------------------------------

def run_ps(problem, easgd: EASGDConfig, cfg: PSConfig,
           eval_fn_override=None, join_timeout_s: float = 600.0) -> PSResult:
    """Run one algorithm for real. ``problem`` is a ``ProblemSpec`` or a
    prebuilt (w0, grad_fn, eval_fn) triple (thread transport only)."""
    tr = get_transport(cfg.transport)
    if hasattr(tr, "run"):
        # network transports own the whole run (no shared buffers to hand
        # out): repro.net's master server returns the same PSResult shape
        return tr.run(problem, easgd, cfg,
                      eval_fn_override=eval_fn_override,
                      join_timeout_s=join_timeout_s)
    built = problem.build() if hasattr(problem, "build") else problem
    w0, _, eval_fn = built
    if eval_fn_override is not None:
        eval_fn = eval_fn_override
    if cfg.trace:
        obs_trace.drain()                    # clean registry for THIS run
        if tr.name == "process" and not cfg.trace_dir:
            # worker tracers live in other processes: give them somewhere
            # to spill (BYE-equivalent; the launcher merges from disk)
            import tempfile
            cfg = dataclasses.replace(
                cfg, trace_dir=tempfile.mkdtemp(prefix="repro-trace-"))
    w0 = np.asarray(w0, np.float64)
    n, P = w0.size, cfg.n_workers
    sched_name = cfg.resolved_schedule(n * 8)
    rounds = (comm_schedules.get(sched_name)
              .rounds(P, n * 8, cfg.net, topology=cfg.topology)
              if cfg.algorithm in SYNC else [])
    padded = n + (-n) % max(P, 1)

    shapes = {"center": (n,), "center_alt": (n,), "master_vel": (n,),
              "workers_w": (P, n), "workers_v": (P, n),
              "mailbox": (P + 1, padded)}
    buffers = {k: tr.array(*shape) for k, shape in shapes.items()}
    prims = {
        "master_lock": tr.lock(),
        "barrier": tr.barrier(P + 1),            # workers + comm executor
        "start_barrier": tr.barrier(P + 1),      # workers + launcher
        "turn_cond": tr.condition(),
        "wire_free_at": tr.float_slot(),
        "turn": tr.int_slot(), "iters": tr.int_slot(),
        "sync_rounds": tr.int_slot(), "messages": tr.int_slot(),
        "wire_bytes": tr.int_slot(), "err": tr.int_slot(),
    }
    worker_problem = built if tr.name == "thread" else problem
    bounds = None
    if cfg.bucket_bytes > 0 and cfg.algorithm in SYNC:
        # layer edges come from the problem when it declares them (zoo
        # problems attach ``layer_sizes`` to their grad_fn); uniform slabs
        # otherwise — either way the exchange math is bitwise unchanged
        bounds = comm_rounds.default_bucket_boundaries(
            getattr(built[1], "layer_sizes", None), padded, cfg.bucket_bytes)
    ctx = PSContext(cfg, easgd, n, padded, buffers, shapes, worker_problem,
                    rounds, prims, boundaries=bounds)
    v = ctx.views()
    v.center[:] = w0
    v.center_alt[:] = w0
    v.workers_w[:] = w0[None]

    handles = tr.launch(ctx)
    comm_thread = None
    if cfg.algorithm in SYNC:
        comm_thread = threading.Thread(target=_comm_executor, args=(ctx,),
                                       daemon=True)
        comm_thread.start()

    # watchdog: a worker dying outside our try/except (e.g. a spawn-import
    # failure) must break the barriers instead of hanging the launcher
    stop_watch = threading.Event()

    def _watchdog():
        while not stop_watch.is_set():
            for h in handles:
                if getattr(h, "exitcode", None) not in (None, 0):
                    ctx.err.value = 1
                    for b in (ctx.barrier, ctx.start_barrier):
                        try:
                            b.abort()
                        except Exception:    # noqa: BLE001
                            pass
                    return
            time.sleep(0.05)

    watchdog = threading.Thread(target=_watchdog, daemon=True)
    watchdog.start()
    try:
        ctx.start_barrier.wait(join_timeout_s)   # workers built problems
    except threading.BrokenBarrierError:
        stop_watch.set()
        tr.join(handles, timeout=1.0)
        raise RuntimeError(
            f"ps workers failed to start (algorithm={cfg.algorithm}, "
            f"transport={cfg.transport})") from None
    t0 = time.perf_counter()
    history, last_eval = [], 0
    deadline = t0 + join_timeout_s
    # live telemetry (obs.live): the shared-memory transports have no
    # per-worker heartbeats, so the launcher poll loop samples AGGREGATE
    # gauges only (store wid −1) — per-worker series and straggler
    # detection need per-worker links, i.e. the tcp transport
    live = None
    if cfg.telemetry_on:
        from repro.obs import live as obs_live
        live = obs_live.LiveMonitor(
            P, deadline_factor=cfg.straggler_factor,
            hb_interval_s=cfg.hb_interval_s,
            jsonl_path=cfg.telemetry_jsonl,
            meta={"algorithm": cfg.algorithm, "transport": cfg.transport})
        live_period = cfg.telemetry_period_s()
        next_sample = time.monotonic() + live_period

    def _live_gauges():
        el = max(time.perf_counter() - t0, 1e-9)
        return {"iters": ctx.iters.value,
                "rate_ips": round(ctx.iters.value / el, 2),
                "wire_bytes": ctx.wire_bytes.value,
                "messages": ctx.messages.value,
                "sync_rounds": ctx.sync_rounds.value}

    while any(h.is_alive() for h in handles):
        if ctx.err.value:
            break
        it = ctx.iters.value
        if it - last_eval >= cfg.eval_every_iters:
            history.append((time.perf_counter() - t0, it,
                            float(eval_fn(v.center.copy()))))
            last_eval = it
        if live is not None and time.monotonic() >= next_sample:
            live.sample(gauges=_live_gauges())
            next_sample += live_period
        if time.perf_counter() > deadline:
            break
        time.sleep(1e-3)
    total_time = time.perf_counter() - t0
    stop_watch.set()
    ok = tr.join(handles, timeout=5.0)
    if comm_thread is not None:
        comm_thread.join(timeout=5.0)
    if ctx.err.value or not ok:
        raise RuntimeError(
            f"ps run failed (algorithm={cfg.algorithm}, "
            f"transport={cfg.transport}, err={ctx.err.value}, joined={ok})")

    n_sync_rounds = -(-cfg.total_iters // (P * max(easgd.tau, 1)))
    if cfg.algorithm == "sync_easgd" and n_sync_rounds % 2 == 1:
        v.center[:] = v.center_alt           # final version of the flip
    total_iters = (cfg.total_iters if cfg.algorithm.startswith("hogwild")
                   else ctx.iters.value)
    final = float(eval_fn(v.center.copy()))
    history.append((total_time, total_iters, final))
    trace = _collect_local_trace(cfg, tr.name, P) if cfg.trace else None
    counters = {"sync_rounds": ctx.sync_rounds.value,
                "messages": ctx.messages.value,
                "wire_bytes": ctx.wire_bytes.value}
    health = None
    if live is not None:
        live.sample(gauges=_live_gauges())   # final sample at end state
        health = live.health()
        counters["health_events"] = len(health["events"])
        live.close()
    return PSResult(
        algorithm=cfg.algorithm, transport=cfg.transport,
        schedule=sched_name if cfg.algorithm in SYNC else "master",
        history=history, total_time_s=total_time, total_iters=total_iters,
        counters=counters,
        final_metric=final, center=v.center.copy(),
        workers=v.workers_w.copy(), trace=trace, health=health)


def _collect_local_trace(cfg: PSConfig, transport: str, P: int):
    """Gather worker/comm tracers after a thread or process run and merge
    them (offsets are 0: perf_counter is system-wide on one host). Thread
    transport reads the registry; process transport reads the spill files
    the workers wrote on exit. The comm executor's tracer (wid=-1) rides
    as the 'master' plane, mirroring where the exchange runs on tcp."""
    workers: dict = {}
    master_threads: dict = {}
    if transport == "thread":
        for t in obs_trace.drain():
            if t.wid >= 0:
                workers.setdefault(t.wid, {"threads": {}, "dropped": 0})
                workers[t.wid]["threads"][t.name] = t.spans()
                workers[t.wid]["dropped"] += t.dropped
            else:
                master_threads[t.name] = t.spans()
    else:
        for t in obs_trace.drain():          # launcher-side tracers (comm)
            if t.wid < 0:
                master_threads[t.name] = t.spans()
        for wid in range(P):
            path = obs_trace.spill_path(cfg.trace_dir, wid)
            if os.path.exists(path):
                workers[wid] = obs_trace.load_spill(path)
    merged = obs_report.merge_traces(
        workers, {"threads": master_threads} if master_threads else None)
    merged["report"] = obs_report.breakdown(merged)
    return merged


# ---------------------------------------------------------------------------
# DES calibration — so simulated and measured clocks are comparable
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Calibration:
    """Micro-benchmarked machine constants for DES↔real comparison.

    ``t_grad_serial`` — one gradient on an otherwise idle box;
    ``t_grad_concurrent`` — a worker's per-gradient WALL period when all P
    workers run at once on this transport (measured with real threads /
    real processes: GIL, caches, and cgroup CPU quotas included);
    ``t_axpy`` / ``alpha`` — shared-memory 'wire' bandwidth and
    small-message overhead; ``link_alpha`` / ``link_beta`` — the measured
    α–β of the real socket link (tcp transport: loopback or host NIC,
    micro-benchmarked with the repro.net framing itself), reported so the
    DES charges the wire the run actually has.
    """

    n: int
    n_workers: int
    transport: str
    t_grad_serial: float
    t_grad_concurrent: float
    t_axpy: float
    alpha: float
    link_alpha: float = 0.0
    link_beta: float = 0.0
    profile: Optional[costmodel.LinkProfile] = None   # measured per-link-
    #                                  class α–β (cfg.topology runs only):
    #                                  what comm.choose consumes at build
    #                                  time and WELCOME ships to workers

    def sim_config(self, algorithm: str, schedule: str,
                   eval_every_iters: int = 200, seed: int = 0,
                   net: Optional[costmodel.Network] = None,
                   topology: Optional[costmodel.Topology] = None
                   ) -> SimConfig:
        """The DES's per-worker compute time depends on the concurrency
        discipline: original_easgd serializes the whole pipeline (one
        worker computes at a time, at full-core speed — and that is
        exactly what it is criticized for); everyone else runs P workers
        concurrently, so each 'device' delivers a gradient every
        ``t_grad_concurrent``. Pass ``net`` = the run's
        ``PSConfig.emulate_net`` so both clocks charge the same wire;
        default: the measured shared-memory 'network'."""
        if algorithm == "original_easgd":
            t_compute = self.t_grad_serial
        else:
            t_compute = self.t_grad_concurrent
        if topology is None and self.profile is not None:
            topology = self.profile.topology
        if net is None:
            if topology is not None:
                # topology runs pace on the declared link classes (the
                # measured profile = declared + physical floor), so the
                # DES must charge intra — the raw loopback link would
                # undercharge a UNIFORM topology by the whole emulation
                net = topology.intra
            else:
                net = (costmodel.Network("tcp-link", self.link_alpha,
                                         self.link_beta)
                       if self.transport == "tcp" and self.link_alpha
                       else costmodel.Network("shm", self.alpha,
                                              self.t_axpy / (self.n * 8)))
        return SimConfig(
            n_workers=self.n_workers,
            net=net,
            schedule=schedule,
            t_compute=t_compute,
            compute_jitter=0.0,
            t_update_per_byte=self.t_axpy / (self.n * 8),
            eval_every_iters=eval_every_iters,
            seed=seed,
            topology=topology)


def _tcp_concurrent_rate(problem, P: int, samples: int) -> float:
    """Median per-gradient wall period across P jax-free worker
    interpreters running at once (``repro.net.worker --burn``). The stdin
    gate excludes interpreter startup + problem build from the clock."""
    import json
    import subprocess
    import sys as _sys

    from repro.net.server import worker_env
    env = worker_env()
    spec_json = json.dumps({"factory": problem.factory,
                            "kwargs": list(problem.kwargs)})
    procs = [subprocess.Popen(
        [_sys.executable, "-m", "repro.net.worker", "--wid", str(i),
         "--burn", spec_json, "--samples", str(samples)],
        stdin=subprocess.PIPE, stdout=subprocess.PIPE, text=True, env=env)
        for i in range(P)]
    try:
        for pr in procs:
            assert pr.stdout.readline().strip() == "R"   # built + warm
        for pr in procs:
            pr.stdin.write("go\n")
            pr.stdin.flush()
        periods = [float(pr.stdout.readline()) for pr in procs]
    finally:
        for pr in procs:
            pr.stdin.close()
            pr.wait()
    return float(np.median(periods))


def _process_burner(problem, samples, wid, gate):
    """Module-level so spawn can pickle it (process calibration)."""
    w0, grad_fn, _ = problem.build()
    w = np.asarray(w0, np.float64).copy()
    for k in range(5):                       # warmup: imports, pages, caches
        grad_fn(w, k, -(wid + 2))
    gate.wait()
    for k in range(samples):
        grad_fn(w, k, -(wid + 2))


def calibrate(problem, cfg: PSConfig, samples: int = 10) -> Calibration:
    """Measure this box. Calibration gradients use worker ids ≤ −1
    (private RNG streams), so a subsequent measured run's per-worker
    streams are untouched."""
    built = problem.build() if hasattr(problem, "build") else problem
    w0, grad_fn, _ = built
    w = np.asarray(w0, np.float64).copy()
    n, P = w.size, cfg.n_workers
    grad_fn(w, 0, -1)                        # warmup
    t = time.perf_counter()
    for k in range(samples):
        grad_fn(w, k, -1)
    t_serial = (time.perf_counter() - t) / samples

    if cfg.transport == "thread":
        # threads share one GIL: measure the real concurrent rate
        def _burn(wid):
            wl = w.copy()
            for k in range(samples):
                grad_fn(wl, k, -(wid + 2))
        ths = [threading.Thread(target=_burn, args=(i,)) for i in range(P)]
        t = time.perf_counter()
        for th in ths:
            th.start()
        for th in ths:
            th.join()
        t_concurrent = (time.perf_counter() - t) / samples
    elif cfg.transport == "tcp" and hasattr(problem, "build"):
        # the tcp transport's workers are jax-free, self-paced
        # subprocesses — calibrate with EXACTLY that substrate
        # (repro.net.worker --burn): each burner times its own gradient
        # period while all P run; the median is the concurrent rate
        t_concurrent = _tcp_concurrent_rate(problem, P, samples)
    elif hasattr(problem, "build"):
        # real processes from a gate: spawn/import excluded from the clock
        import multiprocessing
        mp = multiprocessing.get_context("spawn")
        gate = mp.Barrier(P + 1)
        procs = [mp.Process(target=_process_burner,
                            args=(problem, samples, i, gate), daemon=True)
                 for i in range(P)]
        for pr in procs:
            pr.start()
        gate.wait()
        t = time.perf_counter()
        for pr in procs:
            pr.join()
        t_concurrent = (time.perf_counter() - t) / samples
    else:
        ncores = os.cpu_count() or 1
        t_concurrent = t_serial * max(1.0, P / ncores)

    big, src = np.zeros(n), np.ones(n)
    t = time.perf_counter()
    for _ in range(10):
        big += 0.5 * src
    t_axpy = (time.perf_counter() - t) / 10
    tiny_dst, tiny_src = np.zeros(64), np.ones(64)
    t = time.perf_counter()
    for _ in range(100):
        np.copyto(tiny_dst, tiny_src)
    alpha = (time.perf_counter() - t) / 100 + 15e-6   # + wakeup allowance
    link_alpha = link_beta = 0.0
    if cfg.transport == "tcp":
        # the REAL α–β of the socket link (loopback here; the host NIC on a
        # real cluster), measured through the repro.net framing itself —
        # this is what the DES charges when no wire is emulated
        from repro.net.wire import measure_link
        link_alpha, link_beta = measure_link(cfg.tcp_host)
    profile = None
    if cfg.topology is not None:
        profile = measured_link_profile(
            cfg, base=(link_alpha, link_beta) if link_alpha else None)
    return Calibration(n=n, n_workers=P, transport=cfg.transport,
                       t_grad_serial=t_serial, t_grad_concurrent=t_concurrent,
                       t_axpy=t_axpy, alpha=alpha,
                       link_alpha=link_alpha, link_beta=link_beta,
                       profile=profile)


def measured_link_profile(cfg: PSConfig, counters=None,
                          base: Optional[tuple] = None
                          ) -> costmodel.LinkProfile:
    """Learn a per-link-class α–β profile from the live machinery.

    The physical floor comes from a short pairwise burst over the real
    substrate — ``net.wire.measure_link`` frames small-RTT + one-way-bulk
    probes through the actual repro.net framing for tcp, a timed memcpy
    for the thread plane (its 'wire' is shared memory). A traced run's
    ``counters['link_alpha_s']`` (clock-probe rtt/2 per master link)
    overrides the burst α when present. The floor composes ADDITIVELY
    with the emulated topology classes: pacing sleeps ride on top of real
    transfer, so measured-α + class-α is the honest per-message estimate
    (an upper bound when the OS overlaps them). ``base`` short-circuits
    the burst with an already-measured (α, β) pair."""
    topo = cfg.topology
    assert topo is not None, "measured_link_profile needs cfg.topology"
    detail: dict = {}
    if base is not None:
        alpha0, beta0 = base
        source = f"measured:{cfg.transport}"
    elif cfg.transport == "tcp":
        from repro.net.wire import measure_link
        alpha0, beta0 = measure_link(cfg.tcp_host, reps=12,
                                     big_bytes=1_000_000)
        source = "measured:tcp"
    else:
        buf, src = np.zeros(1 << 17), np.ones(1 << 17)
        np.copyto(buf, src)                       # warm pages
        t0 = time.perf_counter()
        for _ in range(8):
            np.copyto(buf, src)
        beta0 = (time.perf_counter() - t0) / 8 / buf.nbytes
        tiny_d, tiny_s = np.zeros(64), np.ones(64)
        t0 = time.perf_counter()
        for _ in range(100):
            np.copyto(tiny_d, tiny_s)
        alpha0 = (time.perf_counter() - t0) / 100
        source = "measured:thread"
    detail["alpha0_s"] = float(alpha0)
    detail["beta0_s_per_byte"] = float(beta0)
    probes = (counters or {}).get("link_alpha_s")
    if isinstance(probes, dict) and probes:
        vals = sorted(probes.values())
        alpha0 = float(vals[len(vals) // 2])
        detail["alpha0_s"] = alpha0
        detail["alpha0_source"] = "clock-probe rtt/2 median"
    intra = costmodel.Network(f"{topo.intra.name} +measured",
                              topo.intra.alpha + alpha0,
                              topo.intra.beta + beta0)
    cross = (intra if topo.cross == topo.intra else
             costmodel.Network(f"{topo.cross.name} +measured",
                               topo.cross.alpha + alpha0,
                               topo.cross.beta + beta0))
    measured = costmodel.Topology(hosts=topo.hosts, slots=topo.slots,
                                  intra=intra, cross=cross)
    return costmodel.LinkProfile(topology=measured, source=source,
                                 detail=detail)


def calibrate_sim(problem, cfg: PSConfig, samples: int = 10,
                  eval_every_iters: Optional[int] = None) -> SimConfig:
    """One-call convenience: ``calibrate`` + ``sim_config`` for cfg's own
    algorithm/schedule."""
    cal = calibrate(problem, cfg, samples=samples)
    return cal.sim_config(
        cfg.algorithm, cfg.resolved_schedule(cal.n * 8, profile=cal.profile),
        eval_every_iters=eval_every_iters or cfg.eval_every_iters,
        seed=cfg.seed)


def run_vs_des(problem, easgd: EASGDConfig, cfg: PSConfig,
               cal: Optional[Calibration] = None) -> tuple:
    """THE measured-vs-simulated comparison protocol, in one place (the
    launch CLI and benchmarks/fig6_8 --real both use it): run ``cfg`` for
    real AND through the DES calibrated on the same box, charging the DES
    the run's own emulated wire. Returns (PSResult, RunResult, record) —
    ``record`` is the flat JSON-ready comparison.
    """
    if cal is None:
        cal = calibrate(problem, cfg)
    built = problem.build() if hasattr(problem, "build") else problem
    w0, grad_fn, eval_fn = built
    sched_name = cfg.resolved_schedule(cal.n * 8, profile=cal.profile)
    sim = cal.sim_config(
        cfg.algorithm, sched_name,
        eval_every_iters=cfg.eval_every_iters, seed=cfg.seed,
        net=cfg.emulate_net)
    des = PSEngine(grad_fn, eval_fn, np.asarray(w0, np.float64), easgd,
                   sim).run(cfg.algorithm, total_iters=cfg.total_iters)
    if cal.profile is not None and cfg.link_profile is None:
        # the measured run must consume the SAME profile the chooser and
        # the DES just priced — build-time choice, not a fresh guess
        cfg = dataclasses.replace(cfg, link_profile=cal.profile)
    res = run_ps(problem, easgd, cfg)
    meas = res.total_time_s / max(res.total_iters, 1)
    pred = des.total_time_s / max(des.total_iters, 1)
    record = {
        "algorithm": cfg.algorithm,
        "transport": cfg.transport,
        "schedule": res.schedule,
        "iters": res.total_iters,
        "measured_us_per_iter": 1e6 * meas,
        "des_us_per_iter": 1e6 * pred,
        "measured_over_des": meas / pred,
        "iters_per_sec": 1.0 / meas,
        "final_err": res.final_metric,
        "counters": res.counters,
        "curve_real": [(round(t, 4), it, e) for t, it, e in res.history],
        "curve_des": [(round(t, 4), it, e) for t, it, e in des.history],
    }
    if cal.profile is not None:
        record["profile_source"] = cal.profile.source
        record["profile_detail"] = dict(cal.profile.detail)
    return res, des, record
