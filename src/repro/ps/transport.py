"""Transports for the repro.ps parameter-server runtime: who owns the
shared buffers and how workers execute.

Two backends, one contract:

 * ``thread``  — workers are ``threading.Thread``s in this process. The
   master state is plain numpy; the FCFS master mutex is a
   ``threading.Lock``; the Hogwild variants run the SAME in-place update
   with NO lock, so torn/interleaved writes happen for real.
 * ``process`` — workers are ``multiprocessing`` (spawn) processes; all
   state lives in ``RawArray`` shared memory (lock-free by construction —
   Hogwild races across address spaces). Problems must be given as a
   ``ProblemSpec`` so each child rebuilds its gradient function without
   pickling closures (and without importing jax).

The master is not a thread: it is shared state plus a mutual-exclusion
discipline (lock, turnstile, or barrier). Whoever holds the discipline
executes the master update — exactly how shared-memory parameter servers
are deployed. The launcher contributes two helper threads: the sync-family
COMM EXECUTOR (runs the registered schedule's message rounds over the
mailboxes, overlapping with worker compute — the DMA engine of this
software NIC) and the monitor (eval snapshots).
"""
from __future__ import annotations

import multiprocessing
import threading
from types import SimpleNamespace

import numpy as np


class _Slot:
    """Thread-backend shared integer (mirrors mp.RawValue's .value)."""

    __slots__ = ("value",)

    def __init__(self, value=0):
        self.value = value


def _as_view(buf, shape):
    if isinstance(buf, np.ndarray):
        return buf.reshape(shape)
    return np.frombuffer(buf, dtype=np.float64).reshape(shape)


class PSContext:
    """Everything a worker needs, picklable for spawn.

    ``buffers`` maps name -> raw storage (numpy array for the thread
    backend, mp.RawArray for the process backend); ``views()`` wraps them
    as numpy arrays lazily on each side of the fork.
    """

    def __init__(self, cfg, easgd, n, padded, buffers, shapes, problem,
                 rounds, prims, boundaries=None):
        self.cfg = cfg
        self.easgd = easgd
        self.n = n
        self.padded = padded
        self.buffers = buffers
        self.shapes = shapes
        self.problem = problem          # ProblemSpec, or (w0, grad, eval)
        self.rounds = rounds            # sync-family message rounds
        self.boundaries = boundaries    # bucket cuts over the padded row,
        #                                 or None for a monolithic exchange
        for k, v in prims.items():
            setattr(self, k, v)
        self._prim_names = tuple(prims)
        self._v = None
        self._built = None

    def __getstate__(self):
        d = dict(self.__dict__)
        d["_v"] = None
        d["_built"] = None
        return d

    def views(self) -> SimpleNamespace:
        if self._v is None:
            self._v = SimpleNamespace(**{
                k: _as_view(self.buffers[k], self.shapes[k])
                for k in self.buffers})
        return self._v

    def built_problem(self):
        """(w0, grad_fn, eval_fn) — builds a ProblemSpec once per process."""
        if self._built is None:
            p = self.problem
            self._built = p.build() if hasattr(p, "build") else p
        return self._built


def _worker_entry(ctx: PSContext, worker_id: int):
    """Module-level so the spawn start method can pickle the target."""
    from repro.ps import runtime
    try:
        runtime.worker_main(ctx, worker_id)
    except Exception:                    # noqa: BLE001 — see err handling
        ctx.err.value = 1
        for b in (ctx.barrier, ctx.start_barrier):
            try:
                b.abort()
            except Exception:            # noqa: BLE001
                pass
        raise


class ThreadTransport:
    name = "thread"

    def array(self, *shape):
        return np.zeros(shape, np.float64)

    def int_slot(self):
        return _Slot()

    def float_slot(self):
        return _Slot(0.0)

    def lock(self):
        return threading.Lock()

    def condition(self):
        return threading.Condition()

    def barrier(self, parties):
        return threading.Barrier(parties)

    def launch(self, ctx: PSContext):
        handles = [
            threading.Thread(target=_worker_entry, args=(ctx, i), daemon=True)
            for i in range(ctx.cfg.n_workers)
        ]
        for h in handles:
            h.start()
        return handles

    def join(self, handles, timeout=None):
        for h in handles:
            h.join(timeout)
        return not any(h.is_alive() for h in handles)


class ProcessTransport:
    name = "process"

    def __init__(self):
        self._mp = multiprocessing.get_context("spawn")

    def array(self, *shape):
        return self._mp.RawArray("d", int(np.prod(shape)))

    def int_slot(self):
        return self._mp.RawValue("l", 0)

    def float_slot(self):
        return self._mp.RawValue("d", 0.0)

    def lock(self):
        return self._mp.Lock()

    def condition(self):
        return self._mp.Condition()

    def barrier(self, parties):
        return self._mp.Barrier(parties)

    def launch(self, ctx: PSContext):
        if not hasattr(ctx.problem, "build"):
            raise ValueError(
                "process transport needs a ProblemSpec (module:function), "
                "not prebuilt closures — children rebuild the problem")
        handles = [
            self._mp.Process(target=_worker_entry, args=(ctx, i), daemon=True)
            for i in range(ctx.cfg.n_workers)
        ]
        for h in handles:
            h.start()
        return handles

    def join(self, handles, timeout=None):
        for h in handles:
            h.join(timeout)
        alive = [h for h in handles if h.is_alive()]
        for h in alive:
            h.terminate()
        return not alive


class TcpTransport:
    """The repro.net socket transport: workers are PROCESSES ON OTHER ENDS
    OF A WIRE (localhost subprocesses by default, any host via
    launch/cluster --hosts). No shared buffers exist, so this transport
    does not hand out arrays/locks — it owns the whole run: ``run_ps``
    dispatches to ``run`` (the repro.net master server), which returns the
    same PSResult the shared-memory transports produce."""

    name = "tcp"

    def run(self, problem, easgd, cfg, eval_fn_override=None,
            join_timeout_s: float = 600.0):
        from repro.net.server import run_ps_tcp
        return run_ps_tcp(problem, easgd, cfg,
                          eval_fn_override=eval_fn_override,
                          join_timeout_s=join_timeout_s)


TRANSPORTS = {"thread": ThreadTransport, "process": ProcessTransport,
              "tcp": TcpTransport}


def get_transport(name: str):
    try:
        return TRANSPORTS[name]()
    except KeyError:
        raise ValueError(
            f"unknown transport '{name}', have {sorted(TRANSPORTS)}"
        ) from None
