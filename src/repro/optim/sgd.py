"""SGD / momentum SGD as (init, update) transform pairs (no optax).

These are the *inner* optimizers of the EASGD family (the paper's worker
update). ``core.elastic`` hard-codes the momentum form for the fused packed
step; these standalone versions serve the async engine, the CNN repro
experiments and the examples.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class SGDState(NamedTuple):
    step: jnp.ndarray


def sgd(lr):
    def init(params):
        return SGDState(jnp.zeros((), jnp.int32))

    def update(grads, state, params):
        lr_t = lr(state.step) if callable(lr) else lr
        new_params = jax.tree_util.tree_map(
            lambda p, g: p - lr_t * g.astype(p.dtype), params, grads)
        return new_params, SGDState(state.step + 1)

    return init, update


class MomentumState(NamedTuple):
    step: jnp.ndarray
    velocity: object


def momentum_sgd(lr, mu: float = 0.9, nesterov: bool = False):
    """Paper eqs (3)-(4): V ← μV − ηΔW; W ← W + V."""
    def init(params):
        v = jax.tree_util.tree_map(jnp.zeros_like, params)
        return MomentumState(jnp.zeros((), jnp.int32), v)

    def update(grads, state, params):
        lr_t = lr(state.step) if callable(lr) else lr
        v = jax.tree_util.tree_map(
            lambda v_, g: mu * v_ - lr_t * g.astype(v_.dtype),
            state.velocity, grads)
        if nesterov:
            new_params = jax.tree_util.tree_map(
                lambda p, v_, g: p + mu * v_ - lr_t * g.astype(p.dtype),
                params, v, grads)
        else:
            new_params = jax.tree_util.tree_map(
                lambda p, v_: p + v_.astype(p.dtype), params, v)
        return new_params, MomentumState(state.step + 1, v)

    return init, update
